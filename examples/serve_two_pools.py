"""End-to-end driver: serve a small model with batched requests through the
paper's two-pool system — REAL JAX engines, continuous batching, token-
budget routing, live EMA calibration.

    PYTHONPATH=src python examples/serve_two_pools.py [--arch yi-6b]
"""

import argparse

from repro.launch.serve import serve

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=40)
    args = ap.parse_args()
    serve(args.arch, requests=args.requests)
