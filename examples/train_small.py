"""Train a ~100M-parameter model for a few hundred steps, with checkpoints
and (optionally) a mid-run simulated failure + elastic restart.

    PYTHONPATH=src python examples/train_small.py
    PYTHONPATH=src python examples/train_small.py --crash   # failure drill

The model is a width-scaled granite-3-8b (same wiring, d_model=768,
12 layers ≈ 100M params). The synthetic corpus has learnable n-gram
structure, so the loss curve is a real learning curve.
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.distributed.fault import SimulatedFailure
from repro.launch.train import train
from repro.configs.base import ArchConfig


def hundred_m_config() -> ArchConfig:
    base = get_config("granite-3-8b")
    return dataclasses.replace(
        base,
        name="granite-100m",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        head_dim=64,
        d_ff=2048,
        vocab=32_768,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--crash", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    args = ap.parse_args()

    # register the custom config so the generic driver can find it
    from repro import configs as cfg_mod

    cfg = hundred_m_config()
    cfg_mod.REGISTRY[cfg.name] = cfg

    kwargs = dict(
        steps=args.steps,
        seq_len=256,
        global_batch=8,
        reduced=False,
        peak_lr=6e-4,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=50,
    )
    if args.crash:
        try:
            train(cfg.name, simulate_failure_at=args.steps // 2, **kwargs)
        except SimulatedFailure as e:
            print(f"[example] {e} — restarting from latest checkpoint...")
        out = train(cfg.name, **kwargs)  # resumes automatically
    else:
        out = train(cfg.name, **kwargs)
    print(f"[example] final loss: {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
