"""Quickstart: the paper's algorithm in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Estimate a request's token budget from bytes (no tokenizer).
2. Route it between right-sized pools (Algorithm 1).
3. Feed usage.prompt_tokens back → the EMA self-calibrates.
4. Predict fleet savings with the closed-form model (Eq. 7).
"""

import numpy as np

from repro.core import (
    EmaCalibrator,
    PoolState,
    Request,
    TokenBudgetRouter,
    closed_form_savings,
    long_pool,
    short_pool,
)

# --- 1. two right-sized pools + the router --------------------------------
router = TokenBudgetRouter(
    PoolState(config=short_pool(c_max=8192)),   # 128 concurrent seqs
    PoolState(config=long_pool(c_max=65_536)),  # 16 concurrent seqs
    b_short=8192,
)

# --- 2. route a mixed workload ---------------------------------------------
rng = np.random.default_rng(0)
requests = [
    # (bytes, max_output_tokens, category, description)
    (1_800, 256, 0, "short chat turn"),
    (120_000, 512, 0, "long RAG context"),
    (900, 8_192, 0, "short prompt, BIG output cap"),
    (6_000, 128, 1, "code completion"),
    (4_000, 256, 2, "CJK text (2.0 bytes/token!)"),
]
for i, (nbytes, max_out, cat, desc) in enumerate(requests):
    d = router.route(Request(i, nbytes, max_out, cat))
    print(f"  {desc:34s} → {d.pool:5s} (est. {d.estimated_total} tokens)")

# --- 3. closed-loop calibration --------------------------------------------
print("\ncalibrating CJK from usage.prompt_tokens feedback:")
before = router.calibrator.conservative_ratio(2)
for _ in range(50):
    tokens = int(rng.integers(200, 3000))
    router.on_response(
        Request(99, int(tokens * 2.01), 128, 2), prompt_tokens=tokens
    )
after = router.calibrator.conservative_ratio(2)
print(f"  bytes/token for CJK: {before:.2f} → {after:.2f} (true: 2.01)")

# --- 4. audit your own fleet with the closed form ---------------------------
print("\nEq. 7 savings = α(1 − 1/ρ):")
for alpha, rho in [(0.80, 4.0), (0.92, 4.5), (0.70, 2.0)]:
    print(
        f"  α={alpha:.2f}, ρ={rho:.1f} → "
        f"{closed_form_savings(alpha, rho):.0%} fewer GPUs"
    )
print("\n(heavy tails need the corrected Eq. 8 — see examples/cost_planner.py)")
