"""Fleet cost planner: audit the savings opportunity for YOUR workload
before touching infrastructure (the paper's contribution 3).

    PYTHONPATH=src python examples/cost_planner.py \
        --trace azure --rate 1000 --b-short 8192

Prints the closed-form estimate (Eq. 7), the corrected fleet (Eq. 8), the
threshold sensitivity curve, and dollar figures.
"""

import argparse

from repro.core import A100_80G, annual_savings, closed_form_savings
from repro.sim import A100_LLAMA3_70B, plan_fleet, sensitivity_sweep
from repro.traces import TraceSpec, generate_trace


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="azure", choices=["azure", "lmsys"])
    ap.add_argument("--rate", type=float, default=1000.0)
    ap.add_argument("--b-short", type=int, default=8192)
    ap.add_argument("--gpus-per-instance", type=int, default=2)
    args = ap.parse_args()

    reqs = generate_trace(
        TraceSpec(trace=args.trace, num_requests=10_000, rate=args.rate, seed=42)
    )
    plan = plan_fleet(
        args.trace, reqs, A100_LLAMA3_70B, args.rate, b_short=args.b_short
    )

    print(f"=== {args.trace} @ {args.rate:.0f} req/s, B_short={args.b_short} ===")
    print(f"α (short fraction): {plan.alpha:.3f}   ρ (μ_s/μ_h): {plan.rho:.2f}")
    print(f"Eq. 7 (planning estimate): {closed_form_savings(plan.alpha, plan.rho):.1%}")
    print(
        f"Eq. 8 (corrected fleet):   {plan.savings:.1%}  "
        f"[{plan.g_homo} → {plan.g_dual} instances]"
    )
    print(
        f"  homogeneous: {plan.g_homo} × μ={plan.homogeneous.mu:.2f}\n"
        f"  short pool:  {plan.short.instances} × μ={plan.short.mu:.2f} "
        f"(N_seq={plan.short.n_seq})\n"
        f"  long pool:   {plan.long.instances} × μ={plan.long.mu:.2f}"
    )
    dollars = annual_savings(
        plan.g_homo, plan.g_dual, A100_80G, args.gpus_per_instance
    )
    print(f"annual savings @ ${A100_80G.cost_per_hour}/GPU-hr: ${dollars/1e6:.2f}M")

    print("\nthreshold sensitivity (Fig. 6):")
    for p in sensitivity_sweep(args.trace, reqs, A100_LLAMA3_70B, args.rate):
        bar = "#" * int(p.savings * 80)
        print(f"  B_short={p.b_short:>6}: {p.savings:6.1%} {bar}")
    print("\nguidance (§8): heavy tails → push B_short up; concentrated →")
    print("set it at the distribution's effective support. 8K–16K is forgiving.")


if __name__ == "__main__":
    main()
