"""Table 5 / §4.7: Qwen3-235B-A22B on MI300X at 10,000 req/s.

Memory math (exact reproduction): 23.5 KB/token/GPU KV, 133.4 GB KV budget,
676 vs 169 concurrent sequences (4×).

Fleet projection: the paper's Table 5 is the *analytical* (Eq. 6/7) bound —
homogeneous 197 nodes → token-budget 137 nodes (30.5%), $15.4 M/yr at
$3.67/GPU-hr — computed with the full-mix throughput at both slot counts
(the paper itself notes "the formula provides an upper bound"). We
reproduce that bound, then ALSO apply the corrected fleet formula (Eq. 8)
with routed-traffic long-pool throughput — the paper's own §4.2 correction,
which it does not apply to Table 5 — and report both.
"""

from __future__ import annotations

import math

from benchmarks.common import emit, time_us
from repro.core import MI300X, annual_cost, mi300x_case_study
from repro.sim import TimingModel
from repro.sim.profiler import mean_iterations, split_by_budget
from repro.traces import TraceSpec, generate_trace

GPUS_PER_NODE = 8
PAPER_HOMO_NODES = 197  # paper's homogeneous operating point


def run(rate: float = 10_000.0, b_short: int = 8192) -> dict:
    # --- memory side (Eq. 1–2, exact) ---
    cs = mi300x_case_study()
    us = time_us(mi300x_case_study, repeats=10)
    emit(
        "table5/memory",
        us,
        f"kv_kb_per_tok_gpu={cs.kv_kb_per_token_per_gpu:.1f};"
        f"kv_budget_gb={cs.kv_budget_gb_per_gpu:.1f};"
        f"n_seq_8k={cs.n_seq_short};n_seq_32k={cs.n_seq_long};"
        f"ratio={cs.concurrency_ratio:.1f}",
    )

    # --- timing constants back-derived from the paper's operating point ---
    reqs = generate_trace(
        TraceSpec(trace="azure", num_requests=10_000, rate=rate, seed=42)
    )
    probe = TimingModel("probe", 1e-3, 0.0)
    mean_iters = mean_iterations(reqs, probe)
    mu_homo = rate / PAPER_HOMO_NODES
    t_iter_long = cs.n_seq_long / (mu_homo * mean_iters)
    # keep the A100 calibration's W:(H·n) split (8.0 : 0.65×16)
    w = 0.435 * t_iter_long
    h = 0.565 * t_iter_long / cs.n_seq_long
    timing = TimingModel("mi300x-qwen3-derived", w, h)

    # --- paper's analytical projection: Eq. 7 with full-mix throughputs ---
    mu_short_fullmix = timing.throughput(mean_iters, cs.n_seq_short)
    rho = mu_short_fullmix / mu_homo
    alpha = sum(1 for r in reqs if r.true_total <= b_short) / len(reqs)
    savings_eq7 = alpha * (1.0 - 1.0 / rho)
    nodes_dual_eq7 = math.ceil(PAPER_HOMO_NODES * (1.0 - savings_eq7))
    dollars = (
        annual_cost(PAPER_HOMO_NODES, MI300X, GPUS_PER_NODE)
        - annual_cost(nodes_dual_eq7, MI300X, GPUS_PER_NODE)
    )
    emit(
        "table5/fleet_eq7_paper",
        us,
        f"nodes_homo={PAPER_HOMO_NODES};nodes_dual={nodes_dual_eq7};"
        f"gpus_homo={PAPER_HOMO_NODES*GPUS_PER_NODE};"
        f"gpus_dual={nodes_dual_eq7*GPUS_PER_NODE};"
        f"savings={savings_eq7:.3f};annual_usd={dollars/1e6:.1f}M;"
        f"rho={rho:.2f};alpha={alpha:.3f}",
    )

    # --- corrected Eq. 8 with routed-traffic throughputs (our addition) ---
    short_reqs, long_reqs = split_by_budget(reqs, b_short)
    mu_short = timing.throughput(
        mean_iterations(short_reqs, probe), cs.n_seq_short
    )
    mu_long = timing.throughput(
        mean_iterations(long_reqs, probe), cs.n_seq_long
    )
    nodes_dual_eq8 = math.ceil(alpha * rate / mu_short) + math.ceil(
        (1 - alpha) * rate / mu_long
    )
    savings_eq8 = (PAPER_HOMO_NODES - nodes_dual_eq8) / PAPER_HOMO_NODES
    emit(
        "table5/fleet_eq8_corrected",
        us,
        f"nodes_dual={nodes_dual_eq8};savings={savings_eq8:.3f};"
        f"mu_short={mu_short:.1f};mu_long={mu_long:.2f};"
        f"note=eq7-is-upper-bound-per-paper-s4.7",
    )
    return {
        "case_study": cs,
        "nodes_dual_eq7": nodes_dual_eq7,
        "savings_eq7": savings_eq7,
        "nodes_dual_eq8": nodes_dual_eq8,
        "savings_eq8": savings_eq8,
    }


if __name__ == "__main__":
    run()
