"""Simulator throughput: reference vs vectorized backend (this repo's DES).

Measures *simulated requests per second of wall-clock* for the scalar
reference engine (`repro.sim.engine`) and the struct-of-arrays vectorized
engine (`repro.sim.vector_engine`) on identically-seeded Azure traces at the
paper's operating point (rate scaled with trace size so the fleet shape
stays representative). The headline `derived` column reports the speedup —
the repo's acceptance bar is ≥10× at the 100k-request scale (measured:
reference 1896 s vs vectorized 33 s ≈ 57× on a 2-core container, with
matching ttft_p99 between the backends).

CLI::

    python -m benchmarks.sim_throughput                   # 10k + 100k
    python -m benchmarks.sim_throughput --requests 1000   # CI smoke
    python -m benchmarks.sim_throughput --requests 1000000 \
        --backends vectorized                             # 1M, vector only

The 1M scale is practical only for the vectorized backend (the reference
engine needs ~1.5 h); pass ``--backends reference,vectorized`` explicitly if
you really want the scalar number.
"""

from __future__ import annotations

import argparse
import time

from benchmarks.common import emit
from repro.core.pools import PoolConfig, n_seq_for_cmax
from repro.sim import A100_LLAMA3_70B, plan_fleet, run_fleet
from repro.traces import TraceSpec, generate_trace

#: Arrival rate per 10k trace requests — keeps sim duration ≈ 100 s and the
#: planned fleet shape constant across scales.
RATE_PER_10K = 100.0


def bench_scale(
    num_requests: int,
    backends: tuple[str, ...] = ("reference", "vectorized"),
    *,
    seed: int = 42,
    warmup: bool = True,
) -> dict[str, float]:
    """Run one trace size through each backend; returns wall seconds each."""
    rate = max(50.0, RATE_PER_10K * num_requests / 10_000)
    trace = generate_trace(
        TraceSpec(trace="azure", num_requests=num_requests, rate=rate, seed=seed)
    )
    plan = plan_fleet("azure", trace, A100_LLAMA3_70B, rate)
    pools = {
        "short": (
            PoolConfig("short", 8192, n_seq_for_cmax(8192), headroom=1.05),
            plan.short.instances,
        ),
        "long": (
            PoolConfig("long", 65_536, 16, headroom=1.02),
            plan.long.instances,
        ),
    }

    if warmup and "vectorized" in backends:
        # JIT-compile the routing/calibration kernels outside the timing.
        # The ramped epoch schedule (64, 128, …, 2048) needs 4032 requests
        # to reach the full 2048-wide padded route-kernel shape; 4096
        # covers every shape the timed run will use.
        run_fleet(
            trace[: min(len(trace), 4096)],
            pools,
            A100_LLAMA3_70B,
            backend="vectorized",
        )

    walls: dict[str, float] = {}
    for backend in backends:
        t0 = time.perf_counter()
        res = run_fleet(trace, pools, A100_LLAMA3_70B, backend=backend)
        wall = time.perf_counter() - t0
        walls[backend] = wall
        emit(
            f"sim_throughput/{backend}/n={num_requests}",
            wall * 1e6,
            f"req_per_s={num_requests / wall:.0f};completed={res.summary.completed};"
            f"rejected={res.summary.rejected};preempt={res.preemptions};"
            f"ttft_p99={res.summary.ttft_p99:.3f}",
        )
    if "reference" in walls and "vectorized" in walls:
        emit(
            f"sim_throughput/speedup/n={num_requests}",
            0.0,
            f"x{walls['reference'] / walls['vectorized']:.1f}",
        )
    return walls


def run() -> None:
    """Aggregate-suite entry (`python -m benchmarks.run`).

    Both backends at 10k; vectorized-only at 100k (the reference backend
    needs ~30 min there — run it explicitly via the CLI when you want the
    full-scale speedup number).
    """
    bench_scale(10_000)
    bench_scale(100_000, ("vectorized",))


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--requests",
        type=int,
        nargs="+",
        default=[10_000, 100_000],
        help="trace sizes to benchmark",
    )
    parser.add_argument(
        "--backends",
        type=str,
        default=None,
        help="comma-separated subset of reference,vectorized "
        "(default: both, vectorized-only at ≥1M)",
    )
    parser.add_argument("--seed", type=int, default=42)
    args = parser.parse_args()
    for n in args.requests:
        if args.backends:
            backends = tuple(args.backends.split(","))
        else:
            backends = (
                ("vectorized",) if n >= 1_000_000 else ("reference", "vectorized")
            )
        bench_scale(n, backends, seed=args.seed)


if __name__ == "__main__":
    main()
