"""Simulator throughput: reference vs vectorized vs jax backends (this DES).

Measures *simulated requests per second of wall-clock* for the scalar
reference engine (`repro.sim.engine`), the struct-of-arrays vectorized
engine (`repro.sim.vector_engine`), and the fully compiled jax engine
(`repro.sim.jax_engine`) on identically-seeded Azure traces at the
paper's operating point (rate scaled with trace size so the fleet shape
stays representative). The headline `derived` column reports the speedup —
the repo's acceptance bar is ≥10× at the 100k-request scale (measured:
reference 1896 s vs vectorized 33 s ≈ 57× on a 2-core container, with
matching ttft_p99 between the backends).

The vectorized and jax backends are fed the trace in its native columnar
form (:class:`~repro.traces.generator.TraceColumns`, straight from
``generate_trace_columns``); the reference backend gets the materialized
``Request`` objects. ``--pools 3`` swaps the classic short/long pair for
the 4K/16K/64K three-pool topology, exercising the N-way routing path.
When ``jax`` is among the backends all backends run with spillover off
(the jax tier simulates static N-way routing only), and the one-off XLA
compile is reported as a separate ``jax_compile`` row so the steady-state
``us_per_call`` stays comparable.

``--grid G`` benchmarks the batched sensitivity-sweep API
(:func:`repro.sim.run_fleet_grid`): one vmapped G-lane threshold sweep
against the serial vectorized loop over the same G thresholds. The repo's
acceptance bar is ≥5× steady-state at G=16 (measured: serial 20.7 s vs
grid 3.6 s ≈ 5.7× on a 1-core container).

CLI::

    python -m benchmarks.sim_throughput                   # 10k + 100k
    python -m benchmarks.sim_throughput --requests 1000   # CI smoke
    python -m benchmarks.sim_throughput --requests 1000 --pools 3 \
        --backends vectorized                             # N-pool smoke
    python -m benchmarks.sim_throughput --requests 1000 \
        --backends vectorized,jax --grid 16               # jax tier + sweep
    python -m benchmarks.sim_throughput --requests 1000000 \
        --backends vectorized                             # 1M, vector only

The 1M scale is practical only for the vectorized backend (the reference
engine needs ~1.5 h); pass ``--backends reference,vectorized`` explicitly if
you really want the scalar number.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.beyond_paper_threepool import (
    analytic_profiles,
    pool_configs,
    thresholds_for,
)
from benchmarks.common import emit, write_json
from repro.core.pools import PoolConfig, n_seq_for_cmax
from repro.obs import TelemetryConfig
from repro.sim import A100_LLAMA3_70B, plan_fleet, run_fleet, run_fleet_grid
from repro.traces import TraceSpec, generate_trace_columns

#: Arrival rate per 10k trace requests — keeps sim duration ≈ 100 s and the
#: planned fleet shape constant across scales.
RATE_PER_10K = 100.0


def build_pools(cols, rate: float, n_pools: int):
    """Pool topology + routing thresholds for the benchmark fleet."""
    if n_pools == 2:
        plan = plan_fleet("azure", cols.to_requests(), A100_LLAMA3_70B, rate)
        return {
            "short": (
                PoolConfig("short", 8192, n_seq_for_cmax(8192), headroom=1.05),
                plan.short.instances,
            ),
            "long": (
                PoolConfig("long", 65_536, 16, headroom=1.02),
                plan.long.instances,
            ),
        }, None
    profiles = analytic_profiles(cols, n_pools, rate, cols.true_total)
    pools = {
        p.pool: (cfg, max(1, p.instances))
        for cfg, p in zip(pool_configs(n_pools), profiles)
    }
    return pools, list(thresholds_for(n_pools))


def bench_scale(
    num_requests: int,
    backends: tuple[str, ...] = ("reference", "vectorized"),
    *,
    seed: int = 42,
    warmup: bool = True,
    n_pools: int = 2,
) -> dict[str, float]:
    """Run one trace size through each backend; returns wall seconds each.

    The jax backend is AOT-compiled first via
    :func:`repro.sim.jax_engine.aot_compile` — the ``jax_compile`` row
    reports the ``.lower()``/``.compile()`` walls alone, with no run
    attached — and a ``jax_carry`` row records the while-loop carry
    footprint from :func:`repro.sim.jax_engine.carry_report`. The timed
    call then hits the executable cache, and its row carries the
    ``jax_iters``/``jax_rounds`` loop counters from
    :func:`repro.sim.jax_engine.last_run_stats`. When jax participates,
    every backend runs with spillover off so the rows stay like-for-like
    (the compiled engine simulates static N-way routing).
    """
    rate = max(50.0, RATE_PER_10K * num_requests / 10_000)
    cols = generate_trace_columns(
        TraceSpec(trace="azure", num_requests=num_requests, rate=rate, seed=seed)
    )
    pools, thresholds = build_pools(cols, rate, n_pools)
    spillover = "jax" not in backends
    # Materialize objects once, outside the timing, for the reference
    # backend; the vectorized and jax backends consume the columns natively.
    reqs = cols.to_requests() if "reference" in backends else None

    if warmup and "vectorized" in backends:
        # JIT-compile the routing/calibration kernels outside the timing.
        # The ramped epoch schedule (64, 128, …, 2048) needs 4032 requests
        # to reach the full 2048-wide padded route-kernel shape; 4096
        # covers every shape the timed run will use.
        run_fleet(
            cols.head(min(len(cols), 4096)),
            pools,
            A100_LLAMA3_70B,
            backend="vectorized",
            thresholds=thresholds,
            spillover=spillover,
        )

    tag = "" if n_pools == 2 else f"/pools={n_pools}"
    walls: dict[str, float] = {}
    for backend in backends:
        trace = reqs if backend == "reference" else cols
        if backend == "jax":
            from repro.sim import FleetSim, jax_engine

            # Compile ahead of time so the jax_compile row is the
            # lower+compile wall alone and the timed run below is a pure
            # executable-cache hit.
            probe = FleetSim(
                pools,
                A100_LLAMA3_70B,
                backend="jax",
                thresholds=thresholds,
                spillover=spillover,
            )
            stats = jax_engine.aot_compile(probe, cols)
            emit(
                f"sim_throughput/jax_compile/n={num_requests}{tag}",
                (stats["lower_s"] + stats["compile_s"]) * 1e6,
                f"aot=1;lower_s={stats['lower_s']:.3f};"
                f"compile_s={stats['compile_s']:.3f}",
            )
            carry = jax_engine.carry_report(probe, cols)
            emit(
                f"sim_throughput/jax_carry/n={num_requests}{tag}",
                0.0,
                f"carry_bytes={carry['carry_bytes']};"
                f"drain_carry_bytes={carry['drain_carry_bytes']};"
                f"sweep_carry_bytes={carry['sweep_carry_bytes']};"
                f"record_bytes={carry['record_bytes']}",
            )
            # Warm the host-side path (budget precompute kernels, array
            # staging) so the timed call measures steady state.
            run_fleet(
                trace,
                pools,
                A100_LLAMA3_70B,
                backend=backend,
                thresholds=thresholds,
                spillover=spillover,
            )
        t0 = time.perf_counter()
        res = run_fleet(
            trace,
            pools,
            A100_LLAMA3_70B,
            backend=backend,
            thresholds=thresholds,
            spillover=spillover,
        )
        wall = time.perf_counter() - t0
        walls[backend] = wall
        extra = ""
        if backend == "jax":
            rs = jax_engine.last_run_stats()
            extra = f";jax_iters={rs['iters']};jax_rounds={rs['rounds']}"
        emit(
            f"sim_throughput/{backend}/n={num_requests}{tag}",
            wall * 1e6,
            f"req_per_s={num_requests / wall:.0f};completed={res.summary.completed};"
            f"rejected={res.summary.rejected};preempt={res.preemptions};"
            f"ttft_p99={res.summary.ttft_p99:.3f}{extra}",
        )
    if "reference" in walls and "vectorized" in walls:
        emit(
            f"sim_throughput/speedup/n={num_requests}{tag}",
            0.0,
            f"x{walls['reference'] / walls['vectorized']:.1f}",
        )
    if "vectorized" in walls and "jax" in walls:
        emit(
            f"sim_throughput/jax_speedup/n={num_requests}{tag}",
            0.0,
            f"x{walls['vectorized'] / walls['jax']:.1f}",
        )
    return walls


def bench_grid_speedup(
    grid_points: int = 16, num_requests: int = 800, *, seed: int = 42
) -> dict[str, float]:
    """Vmapped threshold sweep (`run_fleet_grid`) vs the serial vectorized loop.

    One short/long fleet with the long pool overcommitted vLLM-style
    (``n_seq × blocks_for(c_max) > total_blocks``), swept over
    ``grid_points`` routing thresholds between 512 and 8192 tokens — the
    fig6 sensitivity shape. The serial baseline runs the vectorized
    backend once per threshold (spillover off, matching the grid
    semantics); the grid runs all lanes as one vmapped device
    computation. Compile wall (first call) is emitted separately; the
    ``grid_speedup`` row is serial over steady-state and the acceptance
    bar is ≥5× at G=16 (measured 5.7× on a 1-core container: serial
    20.7 s vs grid 3.6 s at the 800-request default).
    """
    rate = 40.0 * num_requests / 1000
    cols = generate_trace_columns(
        TraceSpec(trace="azure", num_requests=num_requests, rate=rate, seed=seed)
    )
    pools = {
        "short": (PoolConfig("short", 8192, 24, headroom=1.05), 1),
        "long": (PoolConfig("long", 65_536, 20, headroom=1.02), 1),
    }
    thresholds = [[int(b)] for b in np.linspace(512, 8192, grid_points)]

    # Warm the routing/calibration kernels outside the serial timing.
    run_fleet(
        cols,
        pools,
        A100_LLAMA3_70B,
        backend="vectorized",
        thresholds=thresholds[0],
        spillover=False,
    )
    t0 = time.perf_counter()
    serial = [
        run_fleet(
            cols,
            pools,
            A100_LLAMA3_70B,
            backend="vectorized",
            thresholds=th,
            spillover=False,
        )
        for th in thresholds
    ]
    serial_wall = time.perf_counter() - t0

    from repro.sim import jax_engine

    t0 = time.perf_counter()
    run_fleet_grid(cols, pools, A100_LLAMA3_70B, thresholds=thresholds)
    first_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    grid = run_fleet_grid(cols, pools, A100_LLAMA3_70B, thresholds=thresholds)
    steady_wall = time.perf_counter() - t0
    rs = jax_engine.last_run_stats()

    g = grid_points
    emit(
        f"sim_throughput/grid/serial_vectorized/g={g}",
        serial_wall * 1e6,
        f"n={num_requests};per_lane_s={serial_wall / g:.2f};"
        f"completed={sum(r.summary.completed for r in serial)}",
    )
    # The first call above paid lower+compile+run; report the AOT
    # lower/compile walls (recorded inside the executable cache) so the
    # compile row measures compilation alone.
    gstats = [s for s in jax_engine.compile_stats() if s["grid"]][-1]
    compile_wall = gstats["lower_s"] + gstats["compile_s"]
    emit(
        f"sim_throughput/grid/jax_compile/g={g}",
        compile_wall * 1e6,
        f"aot=1;lower_s={gstats['lower_s']:.3f};"
        f"compile_s={gstats['compile_s']:.3f};first_call_s={first_wall:.3f}",
    )
    emit(
        f"sim_throughput/grid/jax_steady/g={g}",
        steady_wall * 1e6,
        f"n={num_requests};per_lane_s={steady_wall / g:.2f};"
        f"completed={int(grid.completed.sum())};"
        f"jax_iters={rs['iters']};jax_rounds={rs['rounds']}",
    )
    emit(
        f"sim_throughput/grid_speedup/g={g}",
        0.0,
        f"x{serial_wall / steady_wall:.1f};"
        f"incl_compile_x{serial_wall / first_wall:.1f}",
    )
    return {
        "serial": serial_wall,
        "compile": compile_wall,
        "first": first_wall,
        "steady": steady_wall,
    }


def bench_telemetry_overhead(
    num_requests: int = 10_000, *, seed: int = 42, window: int = 200
) -> dict[str, float]:
    """Telemetry cost on the vectorized hot path: off vs sampling vs tracing.

    Three identically-seeded runs of the same fleet: telemetry fully off
    (the default — only ``tracer is None`` guards on the hot path), windowed
    sampling only, and sampling + event tracing. The *off* run is the
    configuration CI's throughput gate sees, so its overhead relative to the
    other rows is what the <3% acceptance bar constrains; the ``overhead``
    row reports both enabled modes relative to off. Best-of-3 wall times to
    suppress scheduler noise at CI scale.
    """
    rate = max(50.0, RATE_PER_10K * num_requests / 10_000)
    cols = generate_trace_columns(
        TraceSpec(trace="azure", num_requests=num_requests, rate=rate, seed=seed)
    )
    pools, thresholds = build_pools(cols, rate, 2)
    modes = {
        "off": None,
        "sampling": TelemetryConfig(window=window),
        "tracing": TelemetryConfig(window=window, events=True),
    }
    # JIT warmup (see bench_scale).
    run_fleet(
        cols.head(min(len(cols), 4096)),
        pools,
        A100_LLAMA3_70B,
        backend="vectorized",
        thresholds=thresholds,
    )
    walls: dict[str, float] = {}
    for mode, telemetry in modes.items():
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            run_fleet(
                cols,
                pools,
                A100_LLAMA3_70B,
                backend="vectorized",
                thresholds=thresholds,
                telemetry=telemetry,
            )
            best = min(best, time.perf_counter() - t0)
        walls[mode] = best
        emit(
            f"sim_throughput/telemetry/{mode}/n={num_requests}",
            best * 1e6,
            f"req_per_s={num_requests / best:.0f}",
        )
    emit(
        f"sim_throughput/telemetry/overhead/n={num_requests}",
        0.0,
        f"sampling_pct={100 * (walls['sampling'] / walls['off'] - 1):.1f};"
        f"tracing_pct={100 * (walls['tracing'] / walls['off'] - 1):.1f}",
    )
    return walls


def run() -> None:
    """Aggregate-suite entry (`python -m benchmarks.run`).

    Both host backends at 10k; vectorized-only at 100k (the reference
    backend needs ~30 min there — run it explicitly via the CLI when you
    want the full-scale speedup number); a 10k three-pool vectorized run
    covers the N-way routing path, a telemetry on/off comparison
    quantifies the observability overhead, vectorized-vs-jax pairs at 1k
    and 10k track the compiled single-fleet tier (AOT compile time and
    carry footprint as separate rows, loop counters on the jax rows),
    and the 16-point grid sweep tracks the vmapped-sensitivity speedup
    bar.
    """
    bench_scale(10_000)
    bench_scale(10_000, ("vectorized",), n_pools=3)
    bench_scale(100_000, ("vectorized",))
    bench_telemetry_overhead(10_000)
    bench_scale(1_000, ("vectorized", "jax"))
    bench_scale(10_000, ("vectorized", "jax"))
    bench_grid_speedup(16)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--requests",
        type=int,
        nargs="+",
        default=[10_000, 100_000],
        help="trace sizes to benchmark",
    )
    parser.add_argument(
        "--backends",
        type=str,
        default=None,
        help="comma-separated subset of reference,vectorized,jax "
        "(default: reference,vectorized; vectorized-only at ≥1M)",
    )
    parser.add_argument(
        "--pools",
        type=int,
        default=2,
        choices=(1, 2, 3),
        help="pool topology: 2 = short/long (default), 3 = 4K/16K/64K",
    )
    parser.add_argument("--seed", type=int, default=42)
    parser.add_argument(
        "--telemetry-overhead",
        action="store_true",
        help="also benchmark telemetry off/sampling/tracing at each size",
    )
    parser.add_argument(
        "--grid",
        type=int,
        default=0,
        metavar="G",
        help="also benchmark a G-point run_fleet_grid threshold sweep "
        "against the serial vectorized loop (acceptance bar: ≥5× at G=16)",
    )
    parser.add_argument(
        "--json",
        metavar="PATH",
        default=None,
        help="write the emitted rows as a JSON artifact (see benchmarks.common)",
    )
    args = parser.parse_args()
    for n in args.requests:
        if args.backends:
            backends = tuple(args.backends.split(","))
        else:
            backends = (
                ("vectorized",) if n >= 1_000_000 else ("reference", "vectorized")
            )
        bench_scale(n, backends, seed=args.seed, n_pools=args.pools)
        if args.telemetry_overhead:
            bench_telemetry_overhead(n, seed=args.seed)
    if args.grid:
        bench_grid_speedup(args.grid, seed=args.seed)
    if args.json:
        # fold the simlint static-pass cost into the same artifact so the
        # CI gate's price shows up next to the engine rows in BENCH_sim.json
        from benchmarks.analysis_throughput import bench_simlint

        bench_simlint()
        write_json(args.json)


if __name__ == "__main__":
    main()
