"""Beyond-paper: closed-loop adaptive control under nonstationary traffic.

The paper's §7 proposes error-driven threshold discovery and §8 prescribes
monitoring preemption pressure. This benchmark drives the first-class
:class:`~repro.core.adaptive.AdaptiveController` — plugged into
``FleetSim(controller=..., control_window=...)``, no monkeypatching — over
three nonstationary scenarios, each static-vs-adaptive, all through the
vectorized backend:

* ``incident`` — the short pool is undersized to 60% of its designed fleet
  (a realistic capacity incident) under stationary arrivals. With a static
  B_short the short queue grows without bound while long-pool slots idle;
  the controller shifts the boundary down and off-loads borderline traffic
  into the long pool's slack.
* ``surge`` — a burst window at 3× the provisioned arrival rate
  (``TraceSpec(rate_profile="burst")``). The controller tightens during the
  burst and relaxes back once pressure clears.
* ``drift`` — content drift: the category mix slides from Azure's
  prose/code-heavy mix toward LMSYS's CJK-heavy mix while the true
  bytes/token ratio shrinks 50% across the trace
  (``mix_drift`` + ``bytes_drift``), on a short pool provisioned at 70%
  for the pre-drift content. The lagging EMA under-estimates token
  budgets, mis-routing heavy requests into the short pool; the controller
  reacts to the resulting preemption/truncation pressure.

Reported per scenario: P99 TTFT and the composite error rate
(preemptions+rejections+truncations — the controller's §8 contract) for
static vs adaptive, plus the boundary trajectory and pressure peaks —
rendered from the run's windowed telemetry (``FleetResult.telemetry``),
the same series the controller acted on, rather than ad-hoc trajectory
lists.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from collections import Counter
from typing import Optional

from benchmarks.common import emit
from repro.core.adaptive import AdaptiveController
from repro.core.pools import PoolConfig, n_seq_for_cmax
from repro.obs import TelemetryConfig
from repro.sim import A100_LLAMA3_70B, FleetSim, plan_fleet
from repro.traces import TraceSpec, generate_trace_columns


#: Valid scenario names, in run order.
SCENARIO_NAMES = ("incident", "surge", "drift")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One nonstationary traffic scenario for the static-vs-adaptive A/B."""

    name: str
    spec: TraceSpec
    short_scale: float = 1.0  # capacity incident: fraction of designed fleet


def scenarios(num_requests: int, rate: float, seed: int) -> list[Scenario]:
    duration = num_requests / rate  # nominal stationary trace length, s
    base = TraceSpec(
        trace="azure", num_requests=num_requests, rate=rate, seed=seed
    )
    return [
        Scenario("incident", base, short_scale=0.6),
        Scenario(
            "surge",
            dataclasses.replace(
                base,
                rate_profile="burst",
                rate_amplitude=2.0,
                rate_period=0.2 * duration,
            ),
        ),
        # Content drift on a fleet provisioned for the pre-drift content:
        # the short pool runs at 70% of its designed size, so the lagging
        # EMA's mis-routes tip it into visible pressure.
        Scenario(
            "drift",
            dataclasses.replace(base, mix_drift=1.0, bytes_drift=-0.5),
            short_scale=0.7,
        ),
    ]


def build_pools(
    trace_cols, rate: float, short_scale: float
) -> dict[str, tuple[PoolConfig, int]]:
    """The paper's short/long pair, analytically sized for the base rate."""
    plan = plan_fleet("azure", trace_cols.to_requests(), A100_LLAMA3_70B, rate)
    short_cfg = PoolConfig(
        "short", 8192, n_seq_for_cmax(8192), batch_token_budget=16_384,
        headroom=1.05, queue_limit=64,
    )
    long_cfg = PoolConfig("long", 65_536, 16, headroom=1.02, queue_limit=64)
    return {
        "short": (short_cfg, max(1, int(plan.short.instances * short_scale))),
        "long": (long_cfg, plan.long.instances),
    }


def run_scenario(
    sc: Scenario,
    *,
    backend: str = "vectorized",
    control_window: int = 200,
) -> dict:
    cols = generate_trace_columns(sc.spec)
    pools = build_pools(cols, sc.spec.rate, sc.short_scale)

    out = {}
    for label in ("static", "adaptive"):
        controller: Optional[AdaptiveController] = (
            AdaptiveController(b_min=512) if label == "adaptive" else None
        )
        sim = FleetSim(
            dict(pools),
            A100_LLAMA3_70B,
            b_short=8192,
            backend=backend,
            controller=controller,
            control_window=control_window,
            telemetry=TelemetryConfig(window=control_window),
        )
        t0 = time.perf_counter()
        res = sim.run(cols)
        wall = (time.perf_counter() - t0) * 1e6
        s = res.summary
        extra = ""
        if controller is not None:
            reasons = Counter(m.reason for m in controller.history)
            extra = (
                f";moves={len(controller.history)}"
                f";final_b={controller.thresholds[0]}"
                f";reasons={'/'.join(f'{r}x{c}' for r, c in sorted(reasons.items()))}"
            )
        emit(
            f"beyond/adaptive/{sc.name}/{label}",
            wall,
            f"ttft_p99={s.ttft_p99:.2f};err_rate={s.error_rate:.4f};"
            f"spills={s.spills};success={s.success_rate:.4f}{extra}",
        )
        _emit_telemetry_rows(sc.name, label, res, adaptive=controller is not None)
        out[label] = res
        out[f"{label}_controller"] = controller
    return out


def _emit_telemetry_rows(
    scenario: str, label: str, res, *, adaptive: bool
) -> None:
    """Render the scenario's story from the run's windowed telemetry.

    The boundary trajectory is read off the sampled ``threshold.0`` series
    (change points only, as ``t_req:value`` pairs — the exact post-move
    vector each window's requests were routed with), and the pressure peaks
    come from the same per-window queue/error series the controller saw.
    """
    tel = res.telemetry
    if tel is None or tel.num_samples == 0:
        return
    if adaptive:
        t_req = tel.columns["t_req"]
        th = tel.columns["threshold.0"]
        points = [f"{t_req[0]}:{th[0]}"]
        for t, b, prev in zip(t_req[1:], th[1:], th[:-1]):
            if b != prev:
                points.append(f"{t}:{b}")
        emit(
            f"beyond/adaptive/{scenario}/trajectory",
            0.0,
            "|".join(points[:24]),
        )
    short = tel.pool_names[0]
    queue = tel.columns[f"queue_depth.{short}"]
    errs = [
        p + r + t
        for p, r, t in zip(
            tel.columns[f"preemptions.{short}"],
            tel.columns[f"rejections.{short}"],
            tel.columns[f"truncations.{short}"],
        )
    ]
    kv = tel.columns[f"kv_frac.{short}"]
    emit(
        f"beyond/adaptive/{scenario}/{label}/pressure",
        0.0,
        f"peak_queue={max(queue)};peak_win_errs={max(errs)};"
        f"peak_kv={max(kv):.3f};windows={tel.num_samples}",
    )


def run_scenarios(
    num_requests: int,
    rate: float,
    seed: int,
    *,
    backend: str = "vectorized",
    only: Optional[list[str]] = None,
) -> dict:
    """Run the selected scenarios; unknown names are an error, never a
    silent no-op (the CI smoke depends on actually exercising the loop)."""
    names = list(only) if only else list(SCENARIO_NAMES)
    unknown = sorted(set(names) - set(SCENARIO_NAMES))
    if unknown:
        raise ValueError(
            f"unknown scenarios {unknown}; expected a subset of {SCENARIO_NAMES}"
        )
    return {
        sc.name: run_scenario(sc, backend=backend)
        for sc in scenarios(num_requests, rate, seed)
        if sc.name in names
    }


def run(
    scale: float = 0.5,
    seed: int = 42,
    *,
    backend: str = "vectorized",
    only: Optional[list[str]] = None,
) -> dict:
    return run_scenarios(
        int(10_000 * scale), 1000.0 * scale, seed, backend=backend, only=only
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=5000)
    ap.add_argument("--rate", type=float, default=None,
                    help="arrival rate (default: requests/10 → 10 s trace)")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--backend", default="vectorized",
                    choices=("reference", "vectorized"))
    ap.add_argument("--scenarios", nargs="+", default=None,
                    choices=SCENARIO_NAMES,
                    help="subset of scenarios to run (default: all)")
    args = ap.parse_args()
    rate = args.rate if args.rate is not None else args.requests / 10.0
    run_scenarios(
        args.requests, rate, args.seed,
        backend=args.backend, only=args.scenarios,
    )


if __name__ == "__main__":
    main()
