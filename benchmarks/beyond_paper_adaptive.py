"""Beyond-paper: closed-loop adaptive control under nonstationary traffic.

The paper's §7 proposes error-driven threshold discovery and §8 prescribes
monitoring preemption pressure. This benchmark drives the first-class
:class:`~repro.core.adaptive.AdaptiveController` — plugged into
``FleetSim(controller=..., control_window=...)``, no monkeypatching — over
three nonstationary scenarios, each static-vs-adaptive, through the
vectorized backend by default (``--backend jax`` runs the compiled tier's
in-step controller mirror instead). ``--tune-gains`` additionally sweeps
the controller's AIMD gains per scenario as one vmapped
:func:`repro.sim.run_fleet_grid` call (see :func:`tune_gains`):

* ``incident`` — the short pool is undersized to 60% of its designed fleet
  (a realistic capacity incident) under stationary arrivals. With a static
  B_short the short queue grows without bound while long-pool slots idle;
  the controller shifts the boundary down and off-loads borderline traffic
  into the long pool's slack.
* ``surge`` — a burst window at 3× the provisioned arrival rate
  (``TraceSpec(rate_profile="burst")``). The controller tightens during the
  burst and relaxes back once pressure clears.
* ``drift`` — content drift: the category mix slides from Azure's
  prose/code-heavy mix toward LMSYS's CJK-heavy mix while the true
  bytes/token ratio shrinks 50% across the trace
  (``mix_drift`` + ``bytes_drift``), on a short pool provisioned at 70%
  for the pre-drift content. The lagging EMA under-estimates token
  budgets, mis-routing heavy requests into the short pool; the controller
  reacts to the resulting preemption/truncation pressure.

Reported per scenario: P99 TTFT and the composite error rate
(preemptions+rejections+truncations — the controller's §8 contract) for
static vs adaptive, plus the boundary trajectory and pressure peaks —
rendered from the run's windowed telemetry (``FleetResult.telemetry``),
the same series the controller acted on, rather than ad-hoc trajectory
lists.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from collections import Counter
from typing import Optional

from benchmarks.common import emit
from repro.core.adaptive import AdaptiveController
from repro.core.pools import PoolConfig, n_seq_for_cmax
from repro.obs import TelemetryConfig
from repro.sim import A100_LLAMA3_70B, FleetSim, plan_fleet, run_fleet_grid
from repro.traces import TraceSpec, generate_trace_columns


#: Valid scenario names, in run order.
SCENARIO_NAMES = ("incident", "surge", "drift")

#: AIMD gain grid for ``--tune-gains``: decrease factor × increase step,
#: every combination one vmapped lane (plus an uncontrolled baseline).
GAIN_GRID: tuple[Optional[dict], ...] = (None,) + tuple(
    {"decrease_factor": f, "increase_step": s}
    for f in (0.5, 0.625, 0.75, 0.875)
    for s in (256, 512, 1024)
)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One nonstationary traffic scenario for the static-vs-adaptive A/B."""

    name: str
    spec: TraceSpec
    short_scale: float = 1.0  # capacity incident: fraction of designed fleet


def scenarios(num_requests: int, rate: float, seed: int) -> list[Scenario]:
    duration = num_requests / rate  # nominal stationary trace length, s
    base = TraceSpec(
        trace="azure", num_requests=num_requests, rate=rate, seed=seed
    )
    return [
        Scenario("incident", base, short_scale=0.6),
        Scenario(
            "surge",
            dataclasses.replace(
                base,
                rate_profile="burst",
                rate_amplitude=2.0,
                rate_period=0.2 * duration,
            ),
        ),
        # Content drift on a fleet provisioned for the pre-drift content:
        # the short pool runs at 70% of its designed size, so the lagging
        # EMA's mis-routes tip it into visible pressure.
        Scenario(
            "drift",
            dataclasses.replace(base, mix_drift=1.0, bytes_drift=-0.5),
            short_scale=0.7,
        ),
    ]


def build_pools(
    trace_cols, rate: float, short_scale: float
) -> dict[str, tuple[PoolConfig, int]]:
    """The paper's short/long pair, analytically sized for the base rate."""
    plan = plan_fleet("azure", trace_cols.to_requests(), A100_LLAMA3_70B, rate)
    short_cfg = PoolConfig(
        "short", 8192, n_seq_for_cmax(8192), batch_token_budget=16_384,
        headroom=1.05, queue_limit=64,
    )
    long_cfg = PoolConfig("long", 65_536, 16, headroom=1.02, queue_limit=64)
    return {
        "short": (short_cfg, max(1, int(plan.short.instances * short_scale))),
        "long": (long_cfg, plan.long.instances),
    }


def run_scenario(
    sc: Scenario,
    *,
    backend: str = "vectorized",
    control_window: int = 200,
) -> dict:
    cols = generate_trace_columns(sc.spec)
    pools = build_pools(cols, sc.spec.rate, sc.short_scale)

    out = {}
    for label in ("static", "adaptive"):
        controller: Optional[AdaptiveController] = (
            AdaptiveController(b_min=512) if label == "adaptive" else None
        )
        sim = FleetSim(
            dict(pools),
            A100_LLAMA3_70B,
            b_short=8192,
            backend=backend,
            controller=controller,
            control_window=control_window,
            telemetry=TelemetryConfig(window=control_window),
        )
        t0 = time.perf_counter()
        res = sim.run(cols)
        wall = (time.perf_counter() - t0) * 1e6
        s = res.summary
        extra = ""
        if controller is not None:
            reasons = Counter(m.reason for m in controller.history)
            extra = (
                f";moves={len(controller.history)}"
                f";final_b={controller.thresholds[0]}"
                f";reasons={'/'.join(f'{r}x{c}' for r, c in sorted(reasons.items()))}"
            )
        emit(
            f"beyond/adaptive/{sc.name}/{label}",
            wall,
            f"ttft_p99={s.ttft_p99:.2f};err_rate={s.error_rate:.4f};"
            f"spills={s.spills};success={s.success_rate:.4f}{extra}",
        )
        _emit_telemetry_rows(sc.name, label, res, adaptive=controller is not None)
        out[label] = res
        out[f"{label}_controller"] = controller
    return out


def _emit_telemetry_rows(
    scenario: str, label: str, res, *, adaptive: bool
) -> None:
    """Render the scenario's story from the run's windowed telemetry.

    The boundary trajectory is read off the sampled ``threshold.0`` series
    (change points only, as ``t_req:value`` pairs — the exact post-move
    vector each window's requests were routed with), and the pressure peaks
    come from the same per-window queue/error series the controller saw.
    """
    tel = res.telemetry
    if tel is None or tel.num_samples == 0:
        return
    if adaptive:
        t_req = tel.columns["t_req"]
        th = tel.columns["threshold.0"]
        points = [f"{t_req[0]}:{th[0]}"]
        for t, b, prev in zip(t_req[1:], th[1:], th[:-1]):
            if b != prev:
                points.append(f"{t}:{b}")
        emit(
            f"beyond/adaptive/{scenario}/trajectory",
            0.0,
            "|".join(points[:24]),
        )
    short = tel.pool_names[0]
    queue = tel.columns[f"queue_depth.{short}"]
    errs = [
        p + r + t
        for p, r, t in zip(
            tel.columns[f"preemptions.{short}"],
            tel.columns[f"rejections.{short}"],
            tel.columns[f"truncations.{short}"],
        )
    ]
    kv = tel.columns[f"kv_frac.{short}"]
    emit(
        f"beyond/adaptive/{scenario}/{label}/pressure",
        0.0,
        f"peak_queue={max(queue)};peak_win_errs={max(errs)};"
        f"peak_kv={max(kv):.3f};windows={tel.num_samples}",
    )


def tune_gains(
    sc: Scenario,
    *,
    grid: tuple = GAIN_GRID,
    control_window: int = 200,
) -> dict:
    """Sweep AIMD controller gains for one scenario as a single vmapped grid.

    Every gain combination (and an uncontrolled baseline lane) runs as one
    :func:`repro.sim.run_fleet_grid` call on the compiled jax tier — the
    in-step controller mirror makes gains an honest vmap axis. Lanes are
    scored by composite error count (rejections + truncations +
    preemptions, the §8 contract) with P99 TTFT as the tiebreaker; the
    winner and the baseline are emitted for comparison.
    """
    cols = generate_trace_columns(sc.spec)
    pools = build_pools(cols, sc.spec.rate, sc.short_scale)
    t0 = time.perf_counter()
    res = run_fleet_grid(
        cols,
        pools,
        A100_LLAMA3_70B,
        gains=list(grid),
        control_window=control_window,
    )
    wall = (time.perf_counter() - t0) * 1e6
    errs = res.rejected + res.truncated + res.preemptions
    controlled = [i for i, gn in enumerate(grid) if gn is not None]
    best = min(controlled, key=lambda i: (errs[i], res.ttft_p99[i]))
    base = next(i for i, gn in enumerate(grid) if gn is None)
    gn = grid[best]
    emit(
        f"beyond/adaptive/{sc.name}/gain_tuning",
        wall,
        f"lanes={len(grid)};best_factor={gn['decrease_factor']};"
        f"best_step={gn['increase_step']};best_errs={errs[best]};"
        f"best_ttft_p99={res.ttft_p99[best]:.2f};"
        f"best_final_b={res.final_thresholds[best][0]};"
        f"best_moves={res.controller_moves[best]};"
        f"baseline_errs={errs[base]};"
        f"baseline_ttft_p99={res.ttft_p99[base]:.2f}",
    )
    return {
        "grid": res,
        "best": gn,
        "best_errors": int(errs[best]),
        "baseline_errors": int(errs[base]),
    }


def run_scenarios(
    num_requests: int,
    rate: float,
    seed: int,
    *,
    backend: str = "vectorized",
    only: Optional[list[str]] = None,
    tune: bool = False,
) -> dict:
    """Run the selected scenarios; unknown names are an error, never a
    silent no-op (the CI smoke depends on actually exercising the loop)."""
    names = list(only) if only else list(SCENARIO_NAMES)
    unknown = sorted(set(names) - set(SCENARIO_NAMES))
    if unknown:
        raise ValueError(
            f"unknown scenarios {unknown}; expected a subset of {SCENARIO_NAMES}"
        )
    out = {}
    for sc in scenarios(num_requests, rate, seed):
        if sc.name not in names:
            continue
        out[sc.name] = run_scenario(sc, backend=backend)
        if tune:
            out[sc.name]["tuning"] = tune_gains(sc)
    return out


def run(
    scale: float = 0.5,
    seed: int = 42,
    *,
    backend: str = "vectorized",
    only: Optional[list[str]] = None,
) -> dict:
    return run_scenarios(
        int(10_000 * scale), 1000.0 * scale, seed, backend=backend, only=only
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=5000)
    ap.add_argument("--rate", type=float, default=None,
                    help="arrival rate (default: requests/10 → 10 s trace)")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--backend", default="vectorized",
                    choices=("reference", "vectorized", "jax"))
    ap.add_argument("--scenarios", nargs="+", default=None,
                    choices=SCENARIO_NAMES,
                    help="subset of scenarios to run (default: all)")
    ap.add_argument("--tune-gains", action="store_true",
                    help="also sweep AIMD controller gains per scenario as "
                    "one vmapped run_fleet_grid call")
    args = ap.parse_args()
    rate = args.rate if args.rate is not None else args.requests / 10.0
    run_scenarios(
        args.requests, rate, args.seed,
        backend=args.backend, only=args.scenarios,
        tune=args.tune_gains,
    )


if __name__ == "__main__":
    main()
