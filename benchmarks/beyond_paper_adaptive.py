"""Beyond-paper: error-driven threshold discovery (paper §7, implemented).

Scenario: the short pool is deliberately undersized to 60% of its designed
fleet (a realistic capacity incident). With a *static* B_short the short
pool's queue grows without bound while long-pool slots idle; the AIMD
controller (repro/core/adaptive.py) detects the pressure and shifts the
boundary down, off-loading borderline traffic to the long pool's slack.

Reported: P99 TTFT static vs adaptive, plus the controller's trajectory.
"""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.adaptive import AdaptiveThreshold
from repro.core.pools import PoolConfig, n_seq_for_cmax
from repro.sim import A100_LLAMA3_70B, FleetSim, plan_fleet
from repro.traces import TraceSpec, generate_trace


def _run(trace, pools, adaptive: bool):
    sim = FleetSim(pools, A100_LLAMA3_70B, b_short=8192)
    controller = AdaptiveThreshold(b_short=8192, b_min=512) if adaptive else None
    window, errors_at_window = 200, [0]

    if controller is not None:
        orig_route = sim._route

        def route_with_control(request):
            n = sim.router.routed["short"] + sim.router.routed["long"]
            if n and n % window == 0:
                short = sim.pools["short"]
                long_ = sim.pools["long"]
                short.refresh_state()
                long_.refresh_state()
                errs = sum(i.preemption_count + i.rejection_count
                           for i in short.instances)
                new_b = controller.update(
                    window_requests=window,
                    short_errors=errs - errors_at_window[0],
                    short_queue=short.state.queue_depth,
                    short_instances=short.state.num_instances,
                    long_queue=long_.state.queue_depth,
                    long_instances=long_.state.num_instances,
                )
                errors_at_window[0] = errs
                sim.router.b_short = new_b
            return orig_route(request)

        sim._route = route_with_control
    return sim.run(trace), controller


def run(scale: float = 0.2, seed: int = 42) -> dict:
    rate = 1000.0 * scale
    trace = generate_trace(
        TraceSpec(trace="azure", num_requests=int(10_000 * scale), rate=rate,
                  seed=seed)
    )
    plan = plan_fleet("azure", trace, A100_LLAMA3_70B, rate)
    short_cfg = PoolConfig(
        "short", 8192, n_seq_for_cmax(8192), batch_token_budget=16_384,
        headroom=1.05, queue_limit=64,
    )
    long_cfg = PoolConfig("long", 65_536, 16, headroom=1.02, queue_limit=64)
    # capacity incident: short pool at 60% of designed size
    pools = {
        "short": (short_cfg, max(1, int(plan.short.instances * 0.6))),
        "long": (long_cfg, plan.long.instances),
    }

    out = {}
    for label, adaptive in (("static", False), ("adaptive", True)):
        t0 = time.perf_counter()
        res, controller = _run(trace, dict(pools), adaptive)
        wall = (time.perf_counter() - t0) * 1e6
        s = res.summary
        short = res.per_pool["short"]
        extra = ""
        if controller is not None:
            extra = (
                f";final_b={controller.b_short}"
                f";moves={len(controller.history)}"
            )
        emit(
            f"beyond/adaptive/{label}",
            wall,
            f"ttft_p99={s.ttft_p99:.2f};short_ttft_p99={short.ttft_p99:.2f};"
            f"spills={s.spills};success={s.success_rate:.4f}{extra}",
        )
        out[label] = res
    return out


if __name__ == "__main__":
    run()
