"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run`` runs everything and prints
``name,us_per_call,derived`` CSV rows (plus a header). ``--json PATH``
additionally writes the whole session as a machine-readable artifact —
per-row ``us_per_call`` + parsed derived metrics + git SHA — so CI can
archive a perf trajectory across commits (see ``benchmarks.common``).

Modules:
  table1_pools        — Table 1 pool configs + μ
  table2_cost         — Table 2 fleet sizes + savings + $/yr
  table3_latency      — Table 3 TTFT/TPOT via fleet DES
  table4_calibration  — Table 4 EMA convergence + mis-route rates
  table5_mi300x       — Table 5 / §4.7 MI300X case study
  fig6_sensitivity    — Fig. 6 threshold sweep
  cost_model_gap      — §4.2 Eq. 7 vs Eq. 8 vs realized
  reliability         — §4.3 preemptions/rejections + fault isolation
  chaos               — §4.3 isolation under injected instance faults
  dispatch_overhead   — §2.2 O(1) sub-microsecond dispatch
  roofline            — §Roofline table from dry-run records
  sim_throughput      — reference/vectorized/jax DES backend speedups
                        + vmapped run_fleet_grid sweep vs serial loop
  telemetry_smoke     — repro.obs telemetry schema + zero-overhead checks
  analysis_throughput — simlint static-pass cost over src/repro

Exits non-zero when any module fails (CI gates on this).
"""

from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks.common import write_json


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--json",
        metavar="PATH",
        default=None,
        help="also write the emitted rows as a JSON artifact "
        "(us_per_call + parsed derived metrics + git SHA)",
    )
    args = ap.parse_args()

    from benchmarks import (
        analysis_throughput,
        beyond_paper_adaptive,
        beyond_paper_int8kv,
        beyond_paper_threepool,
        chaos,
        cost_model_gap,
        dispatch_overhead,
        fig6_sensitivity,
        reliability,
        roofline,
        sim_throughput,
        table1_pools,
        table2_cost,
        table3_latency,
        table4_calibration,
        table5_mi300x,
        telemetry_smoke,
    )

    print("name,us_per_call,derived")
    modules = [
        table1_pools,
        table2_cost,
        table3_latency,
        table4_calibration,
        table5_mi300x,
        fig6_sensitivity,
        cost_model_gap,
        reliability,
        chaos,
        dispatch_overhead,
        beyond_paper_int8kv,
        beyond_paper_threepool,
        beyond_paper_adaptive,
        roofline,
        sim_throughput,
        telemetry_smoke,
        analysis_throughput,
    ]
    failed = 0
    errors: list[str] = []
    for mod in modules:
        try:
            mod.run()
        except Exception as e:
            failed += 1
            errors.append(f"{mod.__name__}: {type(e).__name__}: {e}")
            print(f"{mod.__name__},0,ERROR:{type(e).__name__}:{e}", file=sys.stderr)
            traceback.print_exc()
    if args.json:
        write_json(args.json, extra={"failed_modules": errors})
    if failed:
        raise SystemExit(f"{failed} benchmark modules failed")


if __name__ == "__main__":
    main()
