"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run`` runs everything and prints
``name,us_per_call,derived`` CSV rows (plus a header).

Modules:
  table1_pools        — Table 1 pool configs + μ
  table2_cost         — Table 2 fleet sizes + savings + $/yr
  table3_latency      — Table 3 TTFT/TPOT via fleet DES
  table4_calibration  — Table 4 EMA convergence + mis-route rates
  table5_mi300x       — Table 5 / §4.7 MI300X case study
  fig6_sensitivity    — Fig. 6 threshold sweep
  cost_model_gap      — §4.2 Eq. 7 vs Eq. 8 vs realized
  reliability         — §4.3 preemptions/rejections + fault isolation
  dispatch_overhead   — §2.2 O(1) sub-microsecond dispatch
  roofline            — §Roofline table from dry-run records
  sim_throughput      — reference vs vectorized DES backend speedup

Exits non-zero when any module fails (CI gates on this).
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        beyond_paper_adaptive,
        beyond_paper_int8kv,
        beyond_paper_threepool,
        cost_model_gap,
        dispatch_overhead,
        fig6_sensitivity,
        reliability,
        roofline,
        sim_throughput,
        table1_pools,
        table2_cost,
        table3_latency,
        table4_calibration,
        table5_mi300x,
    )

    print("name,us_per_call,derived")
    modules = [
        table1_pools,
        table2_cost,
        table3_latency,
        table4_calibration,
        table5_mi300x,
        fig6_sensitivity,
        cost_model_gap,
        reliability,
        dispatch_overhead,
        beyond_paper_int8kv,
        beyond_paper_threepool,
        beyond_paper_adaptive,
        roofline,
        sim_throughput,
    ]
    failed = 0
    for mod in modules:
        try:
            mod.run()
        except Exception as e:
            failed += 1
            print(f"{mod.__name__},0,ERROR:{type(e).__name__}:{e}", file=sys.stderr)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"{failed} benchmark modules failed")


if __name__ == "__main__":
    main()
