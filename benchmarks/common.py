"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` CSV rows: `us_per_call`
times the benchmark's own computation (the algorithm under test — e.g. one
routing decision, one DES run), `derived` carries the headline quantity the
paper's table reports (savings %, fleet size, μ, ...).
"""

from __future__ import annotations

import time
from typing import Callable


def time_us(fn: Callable, *, repeats: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
