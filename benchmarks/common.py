"""Shared benchmark utilities: timing + CSV emission + JSON artifacts.

Every benchmark prints ``name,us_per_call,derived`` CSV rows: `us_per_call`
times the benchmark's own computation (the algorithm under test — e.g. one
routing decision, one DES run), `derived` carries the headline quantity the
paper's table reports (savings %, fleet size, μ, ...).

Rows also accumulate in-process so a runner can dump the whole session as a
machine-readable artifact (:func:`write_json`) — per-row ``us_per_call``
plus the derived metrics parsed into key/value pairs, stamped with the git
SHA, for perf-trajectory tracking across commits.
"""

from __future__ import annotations

import json
import subprocess
import time
from typing import Callable

#: Rows emitted this process: (name, us_per_call, derived-string).
_ROWS: list[tuple[str, float, str]] = []


def time_us(fn: Callable, *, repeats: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def emit(name: str, us_per_call: float, derived) -> None:
    _ROWS.append((name, float(us_per_call), str(derived)))
    print(f"{name},{us_per_call:.3f},{derived}")


def reset_rows() -> None:
    _ROWS.clear()


def _git_sha() -> str:
    try:
        return (
            subprocess.run(
                ["git", "rev-parse", "HEAD"],
                capture_output=True,
                text=True,
                timeout=10,
            ).stdout.strip()
            or "unknown"
        )
    except OSError:
        return "unknown"


def _parse_derived(derived: str) -> dict:
    """Split a ``k=v;k=v`` derived string into typed key/values; strings
    that don't follow the convention come back under ``{"value": ...}``."""
    out: dict = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    if not out and derived:
        out["value"] = derived
    return out


def rows_as_json(extra: dict | None = None) -> dict:
    """The session's emitted rows as one artifact dict."""
    doc = {
        "schema": "repro.bench/rows-v1",
        "git_sha": _git_sha(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "rows": [
            {
                "name": name,
                "us_per_call": us,
                "derived": _parse_derived(derived),
                "derived_raw": derived,
            }
            for name, us, derived in _ROWS
        ],
    }
    if extra:
        doc.update(extra)
    return doc


def write_json(path: str, extra: dict | None = None) -> None:
    """Dump every row emitted so far to ``path`` (see ``rows_as_json``)."""
    with open(path, "w") as f:
        json.dump(rows_as_json(extra), f, indent=2)
        f.write("\n")
