"""Table 1: pool configurations and per-instance throughput μ.

Paper values (Azure trace, B_short=8192): homogeneous μ=3.0 / LMSYS 4.1;
short pool μ=13.5 / 6.8; long pool μ=0.4 (Azure). N_seq: 16 / 128 / 16.
"""

from __future__ import annotations

from benchmarks.common import emit, time_us
from repro.sim import A100_LLAMA3_70B, plan_fleet
from repro.traces import TraceSpec, generate_trace


def run(num_requests: int = 10_000, rate: float = 1000.0) -> dict:
    out = {}
    for trace in ("azure", "lmsys"):
        reqs = generate_trace(
            TraceSpec(trace=trace, num_requests=num_requests, rate=rate, seed=42)
        )
        us = time_us(
            lambda: plan_fleet(trace, reqs, A100_LLAMA3_70B, rate), repeats=3
        )
        plan = plan_fleet(trace, reqs, A100_LLAMA3_70B, rate)
        for prof in (plan.homogeneous, plan.short, plan.long):
            emit(
                f"table1/{trace}/{prof.pool}",
                us,
                f"n_seq={prof.n_seq};mu={prof.mu:.2f};iters={prof.mean_iters:.0f}",
            )
            out[f"{trace}/{prof.pool}"] = prof
    return out


if __name__ == "__main__":
    run()
