"""Table 3: TTFT/TPOT at 1,000 req/s (Azure trace), fleet-level DES.

Paper: homogeneous P50/P99 TTFT 0.02/0.91 s, TPOT 12/13 ms;
token-budget 0.09/1.60 s, 25/29 ms; both meet SLO (TTFT≤2s, TPOT≤80ms);
zero preemptions/rejections at designed sizes (§4.3).

Scale note: the DES is exact but Python; by default this benchmark runs a
1/5-scale fleet (200 req/s, 2,000 requests) whose per-instance load matches
the paper's operating point. Pass full=True for the full 1,000 req/s run.
"""

from __future__ import annotations

from benchmarks.common import emit, time_us
from repro.core.pools import PoolConfig, n_seq_for_cmax
from repro.sim import A100_LLAMA3_70B, plan_fleet, run_fleet
from repro.traces import TraceSpec, generate_trace


def run(trace: str = "azure", *, full: bool = False, seed: int = 42) -> dict:
    scale = 1.0 if full else 0.2
    rate = 1000.0 * scale
    n_req = int(10_000 * scale)
    reqs = generate_trace(
        TraceSpec(trace=trace, num_requests=n_req, rate=rate, seed=seed)
    )
    plan = plan_fleet(trace, reqs, A100_LLAMA3_70B, rate)

    homo_cfg = PoolConfig("homogeneous", 65_536, 16, headroom=1.08)
    short_cfg = PoolConfig(
        "short", 8192, n_seq_for_cmax(8192), batch_token_budget=16_384,
        headroom=1.05,
    )
    long_cfg = PoolConfig("long", 65_536, 16, headroom=1.02)

    import time

    t0 = time.perf_counter()
    res_h = run_fleet(
        reqs, {"homogeneous": (homo_cfg, plan.homogeneous.instances)},
        A100_LLAMA3_70B,
    )
    t_h = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_d = run_fleet(
        reqs,
        {
            "short": (short_cfg, plan.short.instances),
            "long": (long_cfg, plan.long.instances),
        },
        A100_LLAMA3_70B,
    )
    t_d = time.perf_counter() - t0

    for name, res, wall in (
        ("homogeneous", res_h, t_h),
        ("token-budget", res_d, t_d),
    ):
        s = res.summary
        emit(
            f"table3/{trace}/{name}",
            wall * 1e6,
            f"ttft_p50={s.ttft_p50:.3f};ttft_p99={s.ttft_p99:.3f};"
            f"tpot_p50={s.tpot_p50*1e3:.1f}ms;tpot_p99={s.tpot_p99*1e3:.1f}ms;"
            f"preemptions={res.preemptions};rejections={res.rejections};"
            f"success={s.success_rate:.4f};meets_slo={s.meets_slo()}",
        )
    return {"homogeneous": res_h, "token_budget": res_d, "plan": plan}


if __name__ == "__main__":
    run()
