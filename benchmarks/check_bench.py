"""Blocking assertions over a BENCH_sim.json produced by sim_throughput.

CI runs the throughput bench on every PR; this gate turns the two PR-10
acceptance bars into exit codes instead of log lines someone has to read:

* **Coalesced event jumps** — the compiled engine's outer while-loop
  iteration count on the routed 1k-request class must stay ≤ n + 1 (one
  drain + round step per arrival epoch plus the final drain). A
  regression to per-token or per-round outer stepping shows up here as
  thousands of iterations.
* **Single-lane throughput** — the jax backend's steady-state wall on
  the same class must be at most ``--ratio`` (default 1.1×) of the
  vectorized backend's: the compiled tier is required to beat NumPy at
  every scale, with 10% slack for shared-runner noise.

Usage::

    python -m benchmarks.check_bench BENCH_sim.json --requests 1000
"""

from __future__ import annotations

import argparse
import json
import sys


def _row(rows: list[dict], name: str) -> dict:
    for r in rows:
        if r.get("name") == name:
            return r
    raise SystemExit(f"check_bench: row `{name}` missing from bench output")


def _derived(row: dict) -> dict:
    d = row.get("derived")
    if isinstance(d, dict):
        return d
    out: dict = {}
    for part in str(row.get("derived_raw", "")).split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            try:
                out[k] = float(v) if "." in v else int(v)
            except ValueError:
                out[k] = v
    return out


def check(payload: dict, *, requests: int, ratio: float) -> list[str]:
    rows = payload.get("rows", payload if isinstance(payload, list) else [])
    failures: list[str] = []

    jax = _row(rows, f"sim_throughput/jax/n={requests}")
    vec = _row(rows, f"sim_throughput/vectorized/n={requests}")

    iters = _derived(jax).get("jax_iters")
    if iters is None:
        failures.append("jax row carries no jax_iters derived metric")
    elif not 0 < int(iters) <= requests + 1:
        failures.append(
            f"coalesced-jump regression: jax_iters={int(iters)} exceeds "
            f"n+1={requests + 1} on the n={requests} routed class"
        )

    jw, vw = float(jax["us_per_call"]), float(vec["us_per_call"])
    if jw > ratio * vw:
        failures.append(
            f"single-lane regression: jax wall {jw / 1e6:.2f}s > "
            f"{ratio:.2f}x vectorized {vw / 1e6:.2f}s on n={requests}"
        )
    return failures


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("bench_json", help="BENCH_sim.json path")
    parser.add_argument("--requests", type=int, default=1000)
    parser.add_argument(
        "--ratio",
        type=float,
        default=1.1,
        help="max allowed jax/vectorized single-lane wall ratio",
    )
    args = parser.parse_args()

    with open(args.bench_json) as fh:
        payload = json.load(fh)
    failures = check(payload, requests=args.requests, ratio=args.ratio)
    for f in failures:
        print(f"check_bench: FAIL — {f}", file=sys.stderr)
    if failures:
        raise SystemExit(1)
    print(
        f"check_bench: OK — jax_iters within n+1 and single-lane jax within "
        f"{args.ratio:.2f}x vectorized on n={args.requests}"
    )


if __name__ == "__main__":
    main()
