"""§4.2 "The long-pool bottleneck": Eq. 7 vs Eq. 8 vs simulation.

Paper: on Azure, Eq. 7 predicts α(1−1/ρ) = 0.92×0.75 ≈ 69% but realized
savings are 16.6% — a ~4× over-prediction, driven by μ_Pl ≈ 0.37 ≪ μ_homo.
On LMSYS (α≈1.00) the closed form is accurate (40% vs 38.5% realized).
"""

from __future__ import annotations

from benchmarks.common import emit, time_us
from repro.core import closed_form_savings, corrected_savings
from repro.sim import A100_LLAMA3_70B, plan_fleet
from repro.traces import TraceSpec, generate_trace


def run(num_requests: int = 10_000, rate: float = 1000.0) -> dict:
    out = {}
    for trace in ("azure", "lmsys"):
        reqs = generate_trace(
            TraceSpec(trace=trace, num_requests=num_requests, rate=rate, seed=42)
        )
        plan = plan_fleet(trace, reqs, A100_LLAMA3_70B, rate)
        us = time_us(
            lambda: closed_form_savings(plan.alpha, plan.rho), repeats=100
        )
        eq7 = closed_form_savings(plan.alpha, plan.rho)
        eq8, g_homo, g_dual = corrected_savings(
            rate,
            plan.alpha,
            plan.short.mu,
            plan.long.mu if plan.long.mu > 0 else plan.homogeneous.mu,
            plan.homogeneous.mu,
            headroom_homo=1.08,
            headroom_short=1.05,
            headroom_long=1.02,
        )
        gap = eq7 / max(plan.savings, 1e-9)
        emit(
            f"cost_gap/{trace}",
            us,
            f"eq7={eq7:.3f};eq8={eq8:.3f};realized={plan.savings:.3f};"
            f"overprediction={gap:.2f}x;mu_long={plan.long.mu:.2f};"
            f"mu_homo={plan.homogeneous.mu:.2f}",
        )
        out[trace] = {"eq7": eq7, "eq8": eq8, "realized": plan.savings}
    return out


if __name__ == "__main__":
    run()
