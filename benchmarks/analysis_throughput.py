"""Analyzer throughput: wall-clock cost of the simlint CI gate.

The static pass (`python -m repro.analysis src/`) runs as a blocking CI
job, so its cost is part of the repo's iteration loop and gets tracked
like any other perf row.  Emits one row::

    simlint/src_repro,<us_per_pass>,files=<N>;findings=<K>;files_per_s=<F>

Registered in ``benchmarks.run`` and folded into the CI `BENCH_sim.json`
artifact by ``benchmarks.sim_throughput --json``.

CLI::

    python -m benchmarks.analysis_throughput
    python -m benchmarks.analysis_throughput --json BENCH_simlint.json
"""

from __future__ import annotations

import argparse
from pathlib import Path

from benchmarks.common import emit, time_us, write_json

SRC = Path(__file__).resolve().parents[1] / "src"


def bench_simlint(repeats: int = 3) -> None:
    from repro.analysis import analyze_paths, default_rules
    from repro.analysis.core import analyze_files, iter_python_files, SourceFile

    target = SRC / "repro"
    findings = analyze_paths([target])
    n_files = len(iter_python_files([target]))

    def one_pass() -> None:
        files = [SourceFile.load(p) for p in iter_python_files([target])]
        analyze_files(files, default_rules())

    us = time_us(one_pass, repeats=repeats, warmup=1)
    files_per_s = n_files / (us / 1e6) if us > 0 else 0.0
    emit(
        "simlint/src_repro",
        us,
        f"files={n_files};findings={len(findings)};"
        f"files_per_s={files_per_s:.0f}",
    )


def run() -> None:
    """Aggregate-suite entry (`python -m benchmarks.run`)."""
    bench_simlint()


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--repeats", type=int, default=3)
    parser.add_argument(
        "--json",
        metavar="PATH",
        default=None,
        help="write the emitted rows as a JSON artifact (see benchmarks.common)",
    )
    args = parser.parse_args()
    bench_simlint(repeats=args.repeats)
    if args.json:
        write_json(args.json)


if __name__ == "__main__":
    main()
