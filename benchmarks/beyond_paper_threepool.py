"""Beyond-paper: quantify the §8 "start with two pools" guideline.

The paper argues a third pool (4K/16K/64K) adds operational complexity for
diminishing returns but gives no numbers. Two layers, per trace:

* **analytic** — fleet sizes for the 1/2/3-pool configurations, with the
  pool groups formed two ways: by oracle ``true_total`` (the paper's
  Table-2 convention — ground truth the router never sees) and by the
  converged calibrator's Eq. 3/5 estimates (what dispatch actually acts
  on). Emitting both makes the oracle gap visible instead of silently
  flattering the added pools.
* **simulated** — the same topologies run end-to-end through
  ``FleetSim(backend="vectorized")`` with calibrated routing over columnar
  traces (no oracle anywhere in dispatch). For each topology a bisection
  over a uniform fleet-scaling factor finds the smallest
  analytically-proportioned fleet that still completes every request and
  meets the SLO, so the marginal savings of each added pool come out of
  the DES rather than arithmetic. ``--grid`` swaps the serial bisection
  for :func:`minimal_sim_fleet_grid`, which probes the whole multiplier
  ladder as ONE vmapped ``run_fleet_grid`` call on the compiled jax tier
  (rows under ``sim_grid/`` — spillover off, full-run metrics).
"""

from __future__ import annotations

import math
import time

import numpy as np

from benchmarks.common import emit
from repro.core.calibration import EmaCalibrator
from repro.core.pools import PoolConfig, n_seq_for_cmax
from repro.sim import (
    A100_LLAMA3_70B,
    PAPER_SLO,
    FleetSim,
    PoolProfile,
    SLOTarget,
    profile_pool,
    run_fleet_grid,
)
from repro.sim.profiler import HEADROOM
from repro.traces import TraceColumns, TraceSpec, generate_trace_columns

#: 4K/16K boundaries (B_1, B_2) of the three-pool ablation; B_3 is open.
THREE_POOL_THRESHOLDS = (4096, 16_384)


def pool_configs(n_pools: int) -> tuple[PoolConfig, ...]:
    """Budget-ordered pool family for the 1/2/3-pool configurations."""
    if n_pools == 1:
        return (
            PoolConfig(
                "homogeneous", 65_536, 16, headroom=HEADROOM["homogeneous"]
            ),
        )
    if n_pools == 2:
        return (
            PoolConfig(
                "short", 8192, n_seq_for_cmax(8192), headroom=HEADROOM["short"]
            ),
            PoolConfig("long", 65_536, 16, headroom=HEADROOM["long"]),
        )
    if n_pools == 3:
        b1, b2 = THREE_POOL_THRESHOLDS
        return (
            PoolConfig("p4k", b1, n_seq_for_cmax(b1), headroom=HEADROOM["short"]),
            PoolConfig(
                "p16k", b2, n_seq_for_cmax(b2), headroom=HEADROOM["short"]
            ),
            PoolConfig("p64k", 65_536, 16, headroom=HEADROOM["long"]),
        )
    raise ValueError(f"unsupported pool count {n_pools}")


def thresholds_for(n_pools: int) -> tuple[int, ...]:
    """Routing boundaries matching :func:`pool_configs` (B_1 … B_{P-1})."""
    if n_pools == 1:
        return ()
    if n_pools == 2:
        return (8192,)
    return THREE_POOL_THRESHOLDS


def calibrated_budgets(cols: TraceColumns) -> np.ndarray:
    """Per-request L_total as the *converged* calibrator estimates it.

    Folds the trace's (byte_len, prompt_tokens) stream through the EMA —
    the steady state a production router reaches — then applies Eq. 3/5.
    Unlike the oracle grouping, no ground-truth token counts enter the
    per-request decision.
    """
    calib = EmaCalibrator()
    calib.observe_batch(cols.byte_len, cols.true_input_tokens, cols.category)
    ratio = np.array(
        [calib.conservative_ratio(k) for k in range(calib.num_categories)]
    )
    l_in = np.ceil(cols.byte_len / ratio[cols.category]).astype(np.int64)
    return l_in + cols.max_output_tokens


def analytic_profiles(
    cols: TraceColumns, n_pools: int, rate: float, budgets: np.ndarray
) -> list[PoolProfile]:
    """Size each pool for the request group its threshold band captures."""
    cfgs = pool_configs(n_pools)
    th = np.asarray(thresholds_for(n_pools), dtype=np.int64)
    group = np.searchsorted(th, budgets, side="left")
    reqs = cols.to_requests()
    return [
        profile_pool(
            cfg.name,
            reqs,
            [r for r, g in zip(reqs, group) if g == k],
            cfg,
            A100_LLAMA3_70B,
            rate,
        )
        for k, cfg in enumerate(cfgs)
    ]


def analytic_fleet(
    cols: TraceColumns, n_pools: int, rate: float, budgets: np.ndarray
) -> int:
    return sum(p.instances for p in analytic_profiles(cols, n_pools, rate, budgets))


def _passes(res) -> bool:
    """SLO gate against the run's own target (``FleetResult.slo``)."""
    return res.summary.success_rate == 1.0 and res.meets_slo()


def _run_scaled(
    cols: TraceColumns,
    n_pools: int,
    base: list[int],
    m: float,
    slo: SLOTarget = PAPER_SLO,
):
    """One vectorized DES run with every pool scaled by multiplier ``m``."""
    cfgs = pool_configs(n_pools)
    pools = {
        cfg.name: (cfg, max(1, math.ceil(b * m)))
        for cfg, b in zip(cfgs, base)
    }
    th = thresholds_for(n_pools)
    sim = FleetSim(
        pools,
        A100_LLAMA3_70B,
        thresholds=list(th) if th else None,
        backend="vectorized",
        slo=slo,
    )
    return sim, sim.run(cols)


def minimal_sim_fleet(
    cols: TraceColumns,
    n_pools: int,
    rate: float,
    *,
    iters: int = 3,
    slo: SLOTarget = PAPER_SLO,
) -> tuple[int, int, "object", bool]:
    """Smallest SLO-meeting fleet the DES will accept for this topology.

    Bisects a uniform scaling factor over the analytically-proportioned
    fleet (oracle sizing fixes the pool *ratio*; the DES with calibrated
    routing decides how much total capacity is really needed). Returns
    (sim_instances, analytic_instances, FleetResult, slo_met); ``slo_met``
    is False when even the largest probed fleet (1.6× analytic) failed —
    the sizes are then an unmet lower bound, not a verified fleet.
    """
    profiles = analytic_profiles(cols, n_pools, rate, cols.true_total)
    base = [max(1, p.instances) for p in profiles]
    analytic_total = sum(p.instances for p in profiles)

    lo, hi = 0.5, 1.0
    _, res = _run_scaled(cols, n_pools, base, hi, slo)
    while not _passes(res) and hi < 1.6:
        lo = hi  # this multiplier failed — bisect above it, not below
        hi *= 1.2
        _, res = _run_scaled(cols, n_pools, base, hi, slo)
    best_m, best_res = hi, res
    if _passes(res):
        for _ in range(iters):
            mid = (lo + hi) / 2.0
            _, res = _run_scaled(cols, n_pools, base, mid, slo)
            if _passes(res):
                hi, best_m, best_res = mid, mid, res
            else:
                lo = mid
    total = sum(max(1, math.ceil(b * best_m)) for b in base)
    return total, analytic_total, best_res, _passes(best_res)


#: Uniform fleet-scaling multipliers probed by the grid fast path — the
#: serial bisection's 0.5–1.6 search interval at its terminal resolution,
#: evaluated all at once instead of one DES run per probe.
GRID_MULTIPLIERS = (0.5, 0.625, 0.75, 0.875, 1.0, 1.2, 1.44, 1.6)


def minimal_sim_fleet_grid(
    cols: TraceColumns,
    n_pools: int,
    rate: float,
    *,
    slo: SLOTarget = PAPER_SLO,
    multipliers: tuple[float, ...] = GRID_MULTIPLIERS,
) -> tuple[int, int, dict, bool]:
    """Grid fast path for :func:`minimal_sim_fleet`: one vmapped ladder.

    Evaluates the whole multiplier ladder as a single
    :func:`repro.sim.run_fleet_grid` call (``instances`` axis, dead-lane
    padding) and picks the smallest lane that completes every request and
    meets the latency SLO. Semantics differ from the serial bisection in
    the jax tier's documented ways — spillover off, full-run metrics with
    no warmup discard — so its rows are emitted under ``sim_grid/`` rather
    than replacing the ``sim/`` series. Returns
    ``(sim_instances, analytic_instances, lane_metrics, slo_met)``.
    """
    profiles = analytic_profiles(cols, n_pools, rate, cols.true_total)
    base = [max(1, p.instances) for p in profiles]
    analytic_total = sum(p.instances for p in profiles)
    cfgs = pool_configs(n_pools)
    pools = {cfg.name: (cfg, b) for cfg, b in zip(cfgs, base)}
    inst_axis = [
        [max(1, math.ceil(b * m)) for b in base] for m in multipliers
    ]
    th = thresholds_for(n_pools)
    grid = run_fleet_grid(
        cols,
        pools,
        A100_LLAMA3_70B,
        thresholds=[list(th)] if th else None,
        instances=inst_axis,
    )
    n = len(cols)
    passes = (
        (grid.completed == n)
        & (grid.truncated == 0)
        & (grid.ttft_p99 <= slo.ttft_p99)
        & (grid.tpot_p99 <= slo.tpot_p99)
    )
    totals = grid.instances.sum(axis=1)
    if passes.any():
        # Smallest passing fleet (the ladder is capacity-ordered).
        k = int(np.flatnonzero(passes)[0])
        slo_met = True
    else:
        k = len(multipliers) - 1  # unmet lower bound, like the serial path
        slo_met = False
    lane = {
        "completed": int(grid.completed[k]),
        "rejected": int(grid.rejected[k]),
        "ttft_p99": float(grid.ttft_p99[k]),
        "tpot_p99": float(grid.tpot_p99[k]),
        "preemptions": int(grid.preemptions[k]),
        "routed": {
            name: int(v) for name, v in zip(grid.pool_names, grid.routed[k])
        },
    }
    return int(totals[k]), analytic_total, lane, slo_met


def run(
    num_requests: int = 4000,
    rate: float = 40.0,
    seed: int = 42,
    slo: SLOTarget = PAPER_SLO,
    *,
    use_grid: bool = False,
) -> dict:
    """Measure the 1/2/3-pool comparison at a ~100 s arrival span.

    The arrival span must dwarf the longest per-request service time or
    queueing never bites and the SLO bisection degenerates (any topology
    with more slots than requests passes): keep ``num_requests/rate`` ≈
    100 s, the convention of ``benchmarks/sim_throughput.py``. Scale both
    together for paper-scale fleets (e.g. 100k requests at rate 1000).
    """
    out = {}
    for trace in ("azure", "lmsys"):
        cols = generate_trace_columns(
            TraceSpec(trace=trace, num_requests=num_requests, rate=rate, seed=seed)
        )

        # -- analytic layer: oracle vs calibrated-estimate grouping ----------
        t0 = time.perf_counter()
        oracle = [analytic_fleet(cols, n, rate, cols.true_total) for n in (1, 2, 3)]
        us_oracle = (time.perf_counter() - t0) / 3 * 1e6
        t0 = time.perf_counter()
        est_budgets = calibrated_budgets(cols)
        estimate = [
            analytic_fleet(cols, n, rate, est_budgets) for n in (1, 2, 3)
        ]
        us_estimate = (time.perf_counter() - t0) / 3 * 1e6
        for label, us, (g1, g2, g3) in (
            ("oracle", us_oracle, oracle),
            ("estimate", us_estimate, estimate),
        ):
            emit(
                f"beyond/threepool/{trace}/analytic_{label}",
                us,
                f"one_pool={g1};two_pools={g2};three_pools={g3};"
                f"second_pool_saves={(g1 - g2) / g1:.3f};"
                f"third_pool_adds={(g2 - g3) / g1:.3f}",
            )

        # -- simulated layer: the fleets actually run --------------------------
        sim_fleet = {}
        all_met = True
        for n_pools in (1, 2, 3):
            t0 = time.perf_counter()
            if use_grid:
                g_sim, g_analytic, lane, slo_met = minimal_sim_fleet_grid(
                    cols, n_pools, rate, slo=slo
                )
                wall = (time.perf_counter() - t0) * 1e6
                sim_fleet[n_pools] = g_sim
                all_met &= slo_met
                routed = ";".join(
                    f"{k}={v}" for k, v in lane["routed"].items()
                )
                emit(
                    f"beyond/threepool/{trace}/sim_grid/{n_pools}pool",
                    wall,
                    f"sim_instances={g_sim};analytic_instances={g_analytic};"
                    f"completed={lane['completed']};"
                    f"ttft_p99={lane['ttft_p99']:.3f};"
                    f"slo_met={slo_met};preempt={lane['preemptions']};{routed}",
                )
                continue
            g_sim, g_analytic, res, slo_met = minimal_sim_fleet(
                cols, n_pools, rate, slo=slo
            )
            wall = (time.perf_counter() - t0) * 1e6
            sim_fleet[n_pools] = g_sim
            all_met &= slo_met
            s = res.summary
            routed = ";".join(
                f"{k}={v}" for k, v in res.router_stats.get("routed", {}).items()
            )
            emit(
                f"beyond/threepool/{trace}/sim/{n_pools}pool",
                wall,
                f"sim_instances={g_sim};analytic_instances={g_analytic};"
                f"success={s.success_rate:.4f};ttft_p99={s.ttft_p99:.3f};"
                f"slo_met={slo_met};preempt={res.preemptions};{routed}",
            )
        f1, f2, f3 = (sim_fleet[n] for n in (1, 2, 3))
        emit(
            f"beyond/threepool/{trace}/sim_marginal",
            0.0,
            f"second_pool_saves={(f1 - f2) / f1:.3f};"
            f"third_pool_adds={(f2 - f3) / f1:.3f};"
            f"all_slo_met={all_met}",  # False → sizes are unmet lower bounds
        )
        out[trace] = {
            "analytic_oracle": tuple(oracle),
            "analytic_estimate": tuple(estimate),
            "sim_fleet": (f1, f2, f3),
        }
    return out


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=4000)
    ap.add_argument("--rate", type=float, default=40.0)
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument(
        "--grid",
        action="store_true",
        help="use the vmapped run_fleet_grid multiplier ladder instead of "
        "the serial DES bisection (jax-tier semantics; rows under sim_grid/)",
    )
    args = ap.parse_args()
    run(args.requests, args.rate, args.seed, use_grid=args.grid)


if __name__ == "__main__":
    main()
