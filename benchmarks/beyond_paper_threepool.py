"""Beyond-paper: quantify the §8 "start with two pools" guideline.

The paper argues a third pool (4K/16K/64K) adds operational complexity for
diminishing returns but gives no numbers. We compute the analytical fleet
for 1/2/3-pool configurations on both traces and report the marginal
savings of each added pool.
"""

from __future__ import annotations

from benchmarks.common import emit, time_us
from repro.core.pools import PoolConfig, n_seq_for_cmax
from repro.sim import A100_LLAMA3_70B, plan_fleet
from repro.sim.profiler import HEADROOM, profile_pool
from repro.traces import TraceSpec, generate_trace


def three_pool_fleet(reqs, rate, thresholds=(4096, 16_384)) -> int:
    """Pools: ≤4K (N=256 if block budget allowed... capped 128), ≤16K, ≤64K."""
    b1, b2 = thresholds
    groups = (
        [r for r in reqs if r.true_total <= b1],
        [r for r in reqs if b1 < r.true_total <= b2],
        [r for r in reqs if r.true_total > b2],
    )
    cfgs = (
        PoolConfig("p4k", b1, n_seq_for_cmax(b1), headroom=HEADROOM["short"]),
        PoolConfig("p16k", b2, n_seq_for_cmax(b2), headroom=HEADROOM["short"]),
        PoolConfig("p64k", 65_536, 16, headroom=HEADROOM["long"]),
    )
    total = 0
    for cfg, grp in zip(cfgs, groups):
        prof = profile_pool(cfg.name, reqs, grp, cfg, A100_LLAMA3_70B, rate)
        total += prof.instances
    return total


def run(rate: float = 1000.0) -> dict:
    out = {}
    for trace in ("azure", "lmsys"):
        reqs = generate_trace(
            TraceSpec(trace=trace, num_requests=10_000, rate=rate, seed=42)
        )
        us = time_us(lambda: three_pool_fleet(reqs, rate), repeats=2)
        plan = plan_fleet(trace, reqs, A100_LLAMA3_70B, rate)
        g1 = plan.g_homo
        g2 = plan.g_dual
        g3 = three_pool_fleet(reqs, rate)
        emit(
            f"beyond/threepool/{trace}",
            us,
            f"one_pool={g1};two_pools={g2};three_pools={g3};"
            f"second_pool_saves={(g1-g2)/g1:.3f};"
            f"third_pool_adds={(g2-g3)/g1:.3f}",
        )
        out[trace] = (g1, g2, g3)
    return out


if __name__ == "__main__":
    run()
