"""Figure 6: savings vs B_short threshold sweep.

Paper: Azure increases monotonically (→ ~20% at 32K); LMSYS peaks at 8K
(38.5%) then declines as N_seq drops with higher C_max. Any B_short in
8K–16K delivers >80% of peak savings on both workloads (§8).

Two layers:

* :func:`run` — the paper's analytic sweep (planner arithmetic, resizes
  the short pool's ``C_max`` with each threshold).
* :func:`run_des` (``--des``) — DES validation of the routing-threshold
  axis at fixed capacity: one :func:`repro.sim.run_fleet_grid` call vmaps
  every threshold lane through the compiled fleet engine and reports
  goodput / P99 TTFT / routed fraction per lane. Pool shapes are static
  under vmap, so this sweeps the *routing boundary* at a fixed short-pool
  ``C_max`` (the max threshold) rather than re-deriving fleet sizes —
  the dynamic-behaviour complement to the analytic savings curve.
"""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, time_us
from repro.core.pools import PoolConfig, n_seq_for_cmax
from repro.sim import A100_LLAMA3_70B, run_fleet_grid, sensitivity_sweep
from repro.traces import TraceSpec, generate_trace, generate_trace_columns

THRESHOLDS = (2048, 4096, 8192, 16_384, 32_768)


def run(num_requests: int = 10_000, rate: float = 1000.0) -> dict:
    out = {}
    for trace in ("azure", "lmsys"):
        reqs = generate_trace(
            TraceSpec(trace=trace, num_requests=num_requests, rate=rate, seed=42)
        )
        us = time_us(
            lambda: sensitivity_sweep(
                trace, reqs, A100_LLAMA3_70B, rate, THRESHOLDS
            ),
            repeats=2,
        )
        plans = sensitivity_sweep(trace, reqs, A100_LLAMA3_70B, rate, THRESHOLDS)
        curve = {p.b_short: p.savings for p in plans}
        peak = max(curve.values())
        for p in plans:
            emit(
                f"fig6/{trace}/b{p.b_short}",
                us,
                f"savings={p.savings:.3f};alpha={p.alpha:.4f};"
                f"n_seq={p.short.n_seq};frac_of_peak="
                f"{p.savings/peak if peak > 0 else 0:.2f}",
            )
        out[trace] = curve
    return out


def run_des(
    num_requests: int = 2000,
    rate: float = 20.0,
    seed: int = 42,
    thresholds: tuple[int, ...] = THRESHOLDS,
) -> dict:
    """Threshold sensitivity at DES fidelity: one vmapped grid per trace.

    A short/long pair with the short pool at ``C_max = max(thresholds)``
    (so every lane's boundary fits) and a small fixed fleet; all
    threshold lanes run as a single compiled device computation. Grid
    metrics are full-run (no warmup discard), spillover off — the jax
    tier's documented semantics.
    """
    out = {}
    ths = [[int(b)] for b in thresholds]
    c_short = max(thresholds)
    pools = {
        "short": (
            PoolConfig("short", c_short, n_seq_for_cmax(c_short), headroom=1.05),
            2,
        ),
        "long": (PoolConfig("long", 65_536, 16, headroom=1.02), 1),
    }
    for trace in ("azure", "lmsys"):
        cols = generate_trace_columns(
            TraceSpec(trace=trace, num_requests=num_requests, rate=rate, seed=seed)
        )
        us = time_us(
            lambda: run_fleet_grid(
                cols, pools, A100_LLAMA3_70B, thresholds=ths
            ),
            repeats=2,
        )
        grid = run_fleet_grid(cols, pools, A100_LLAMA3_70B, thresholds=ths)
        goodput = grid.goodput()
        short_frac = grid.routed[:, 0] / np.maximum(grid.routed.sum(axis=1), 1)
        for i, b in enumerate(thresholds):
            emit(
                f"fig6/des/{trace}/b{b}",
                us,
                f"goodput={goodput[i]:.1f};ttft_p99={grid.ttft_p99[i]:.3f};"
                f"short_frac={short_frac[i]:.3f};completed={grid.completed[i]};"
                f"preempt={grid.preemptions[i]}",
            )
        out[trace] = {
            int(b): float(g) for b, g in zip(thresholds, goodput)
        }
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--des", action="store_true",
                    help="also run the DES-fidelity vmapped threshold grid")
    ap.add_argument("--requests", type=int, default=2000,
                    help="trace size for the DES grid (analytic sweep uses 10k)")
    ap.add_argument("--rate", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=42)
    args = ap.parse_args()
    run()
    if args.des:
        run_des(args.requests, args.rate, args.seed)


if __name__ == "__main__":
    main()
