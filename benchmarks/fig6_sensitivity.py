"""Figure 6: savings vs B_short threshold sweep.

Paper: Azure increases monotonically (→ ~20% at 32K); LMSYS peaks at 8K
(38.5%) then declines as N_seq drops with higher C_max. Any B_short in
8K–16K delivers >80% of peak savings on both workloads (§8).
"""

from __future__ import annotations

from benchmarks.common import emit, time_us
from repro.sim import A100_LLAMA3_70B, sensitivity_sweep
from repro.traces import TraceSpec, generate_trace

THRESHOLDS = (2048, 4096, 8192, 16_384, 32_768)


def run(num_requests: int = 10_000, rate: float = 1000.0) -> dict:
    out = {}
    for trace in ("azure", "lmsys"):
        reqs = generate_trace(
            TraceSpec(trace=trace, num_requests=num_requests, rate=rate, seed=42)
        )
        us = time_us(
            lambda: sensitivity_sweep(
                trace, reqs, A100_LLAMA3_70B, rate, THRESHOLDS
            ),
            repeats=2,
        )
        plans = sensitivity_sweep(trace, reqs, A100_LLAMA3_70B, rate, THRESHOLDS)
        curve = {p.b_short: p.savings for p in plans}
        peak = max(curve.values())
        for p in plans:
            emit(
                f"fig6/{trace}/b{p.b_short}",
                us,
                f"savings={p.savings:.3f};alpha={p.alpha:.4f};"
                f"n_seq={p.short.n_seq};frac_of_peak="
                f"{p.savings/peak if peak > 0 else 0:.2f}",
            )
        out[trace] = curve
    return out


if __name__ == "__main__":
    run()
