"""Telemetry smoke: schema-valid exports + zero-perturbation guarantee.

Drives a surge scenario (burst arrivals at 2× the provisioned rate) through
the vectorized backend with full telemetry — windowed sampling plus event
tracing — and checks the three properties CI gates on:

1. the exported telemetry JSON, events JSONL, and Chrome trace all validate
   against the ``repro.obs`` schemas (so a run always opens in Perfetto);
2. the telemetry series is internally consistent (windowed deltas sum to
   the run's end-of-run counters);
3. installing telemetry does not perturb the simulation: the ``SimSummary``
   of a run with the registry + tracer installed is bit-identical to a run
   without any telemetry objects at all.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

from benchmarks.common import emit
from repro.core.pools import PoolConfig, n_seq_for_cmax
from repro.obs import (
    TelemetryConfig,
    validate_chrome_trace,
    validate_events_jsonl,
    validate_telemetry,
)
from repro.sim import A100_LLAMA3_70B, FleetSim
from repro.traces import TraceSpec, generate_trace_columns


def _surge_fleet(num_requests: int, rate: float, seed: int):
    cols = generate_trace_columns(
        TraceSpec(
            trace="azure",
            num_requests=num_requests,
            rate=rate,
            seed=seed,
            rate_profile="burst",
            rate_amplitude=2.0,
            rate_period=0.2 * num_requests / rate,
        )
    )
    pools = {
        "short": (
            PoolConfig(
                "short", 8192, n_seq_for_cmax(8192), queue_limit=64
            ),
            4,
        ),
        "long": (PoolConfig("long", 65_536, 16, queue_limit=64), 2),
    }
    return cols, pools


def _run(cols, pools, telemetry, *, window: int = 100):
    sim = FleetSim(
        dict(pools),
        A100_LLAMA3_70B,
        b_short=8192,
        backend="vectorized",
        telemetry=telemetry,
        control_window=window,
    )
    return sim.run(cols)


def run(num_requests: int = 1000, rate: float = 100.0, seed: int = 42) -> dict:
    cols, pools = _surge_fleet(num_requests, rate, seed)

    t0 = time.perf_counter()
    res = _run(cols, pools, TelemetryConfig(window=100, events=True))
    wall = (time.perf_counter() - t0) * 1e6
    tel = res.telemetry

    # 1) Every export validates against its schema.
    validate_telemetry(tel.to_json())
    events = validate_events_jsonl(tel.events.to_jsonl())
    trace_doc = validate_chrome_trace(tel.events.to_chrome_trace())

    # 2) Windowed deltas reconcile with the end-of-run counters.
    for fam, total in (
        ("preemptions", res.preemptions),
        ("rejections", res.rejections),
        ("truncations", res.truncations),
    ):
        sampled = sum(
            sum(tel.columns[f"{fam}.{p}"]) for p in tel.pool_names
        )
        if sampled != total:
            raise AssertionError(
                f"telemetry {fam} deltas sum to {sampled}, run counter is {total}"
            )
    spills = sum(tel.columns["spills"])
    if spills != res.summary.spills:
        raise AssertionError(
            f"telemetry spills sum to {spills}, run counter is {res.summary.spills}"
        )

    # 3) Zero perturbation: with telemetry fully off, the SimSummary is
    # bit-identical to a run that never constructed a registry or tracer.
    plain = _run(cols, pools, None)
    with_tel = _run(cols, pools, TelemetryConfig(window=100, events=True))
    a = dataclasses.asdict(plain.summary)
    b = dataclasses.asdict(with_tel.summary)
    if a != b:
        diff = {k: (a[k], b[k]) for k in a if a[k] != b[k]}
        raise AssertionError(f"telemetry perturbed the simulation: {diff}")

    emit(
        "obs/telemetry_smoke/surge",
        wall,
        f"samples={tel.num_samples};events={len(events)};"
        f"trace_events={len(trace_doc['traceEvents'])};"
        f"dropped={tel.events.dropped};success={res.summary.success_rate:.4f}",
    )
    emit(
        "obs/telemetry_smoke/bit_identity",
        0.0,
        "summary_identical=1",
    )
    return {"result": res, "telemetry": tel}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=1000)
    ap.add_argument("--rate", type=float, default=None,
                    help="arrival rate (default: requests/10 → 10 s trace)")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--jsonl-out", default=None,
                    help="also write the events JSONL export to this path")
    ap.add_argument("--trace-out", default=None,
                    help="also write the Chrome trace export to this path")
    args = ap.parse_args()
    rate = args.rate if args.rate is not None else args.requests / 10.0
    out = run(args.requests, rate, args.seed)
    tel = out["telemetry"]
    if args.jsonl_out:
        with open(args.jsonl_out, "w") as f:
            f.write(tel.events.to_jsonl())
    if args.trace_out:
        with open(args.trace_out, "w") as f:
            f.write(tel.events.to_chrome_trace())


if __name__ == "__main__":
    main()
