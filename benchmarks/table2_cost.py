"""Table 2: GPU instances and savings at 1,000 req/s (B_short=8192).

Paper: Azure homogeneous 361 → token-budget 301 (16.6%);
LMSYS 265 → 163 (38.5%). Also reports the closed-form (Eq. 7) prediction
and the corrected (Eq. 8) fleet to reproduce the §4.2 "cost model gap".
"""

from __future__ import annotations

from benchmarks.common import emit, time_us
from repro.core import A100_80G, annual_savings, closed_form_savings
from repro.sim import A100_LLAMA3_70B, plan_fleet
from repro.traces import TraceSpec, generate_trace

TP = 2  # paper §4.1: tensor parallel = 2 → 2 GPUs per instance


def run(num_requests: int = 10_000, rate: float = 1000.0) -> dict:
    out = {}
    for trace in ("azure", "lmsys"):
        reqs = generate_trace(
            TraceSpec(trace=trace, num_requests=num_requests, rate=rate, seed=42)
        )
        us = time_us(
            lambda: plan_fleet(trace, reqs, A100_LLAMA3_70B, rate), repeats=3
        )
        plan = plan_fleet(trace, reqs, A100_LLAMA3_70B, rate)
        naive = closed_form_savings(plan.alpha, plan.rho)
        dollars = annual_savings(plan.g_homo, plan.g_dual, A100_80G, TP)
        emit(
            f"table2/{trace}",
            us,
            f"G_homo={plan.g_homo};G_short={plan.short.instances};"
            f"G_long={plan.long.instances};G_dual={plan.g_dual};"
            f"savings={plan.savings:.3f};eq7_predicts={naive:.3f};"
            f"annual_usd={dollars/1e6:.2f}M",
        )
        out[trace] = plan
    return out


if __name__ == "__main__":
    run()
