"""§4.3 reliability: zero preemptions at designed sizes; fault isolation
under a long-request surge.

Two experiments:

1. **designed** — Table-2-sized fleets on the nominal trace → expect 0
   preemptions, 0 rejections, 100% success on both configurations.
2. **long-surge** — the same fleets, but the trace gains a burst of extra
   long requests (+150% of the long-tail mass injected over a 20% window).
   In the homogeneous fleet the burst lands on the shared pool and inflates
   everyone's tail latency; with token-budget routing only the long pool
   queues — the short pool (>90% of traffic) keeps its TTFT. This is the
   paper's "graceful degradation / fault isolation" claim, measured.
"""

from __future__ import annotations

import dataclasses
import time

from benchmarks.common import emit
from repro.core.pools import PoolConfig, n_seq_for_cmax
from repro.core.router import Request
from repro.sim import A100_LLAMA3_70B, plan_fleet, run_fleet
from repro.traces import TraceSpec, generate_trace


def _with_long_surge(reqs, *, factor: float = 1.5, seed: int = 7):
    """Clone a fraction of long requests into a mid-trace burst window."""
    import numpy as np

    rng = np.random.default_rng(seed)
    t_lo = reqs[int(len(reqs) * 0.4)].arrival_time
    t_hi = reqs[int(len(reqs) * 0.6)].arrival_time
    long_reqs = [r for r in reqs if r.true_total > 8192]
    n_extra = int(len(long_reqs) * factor)
    extra = []
    base_id = max(r.request_id for r in reqs) + 1
    for i in range(n_extra):
        src = long_reqs[int(rng.integers(0, len(long_reqs)))]
        extra.append(
            dataclasses.replace(
                src,
                request_id=base_id + i,
                arrival_time=float(rng.uniform(t_lo, t_hi)),
            )
        )
    return sorted(reqs + extra, key=lambda r: r.arrival_time)


def run(scale: float = 0.2, seed: int = 42) -> dict:
    rate = 1000.0 * scale
    reqs = generate_trace(
        TraceSpec(
            trace="azure", num_requests=int(10_000 * scale), rate=rate, seed=seed
        )
    )
    plan = plan_fleet("azure", reqs, A100_LLAMA3_70B, rate)
    homo_cfg = PoolConfig("homogeneous", 65_536, 16, headroom=1.08)
    short_cfg = PoolConfig(
        "short", 8192, n_seq_for_cmax(8192), batch_token_budget=16_384,
        headroom=1.05,
    )
    long_cfg = PoolConfig("long", 65_536, 16, headroom=1.02)
    homo_pools = {"homogeneous": (homo_cfg, plan.homogeneous.instances)}
    dual_pools = {
        "short": (short_cfg, plan.short.instances),
        "long": (long_cfg, plan.long.instances),
    }

    out = {}
    for label, trace in (
        ("designed", reqs),
        ("long_surge", _with_long_surge(reqs)),
    ):
        t0 = time.perf_counter()
        res_h = run_fleet(trace, homo_pools, A100_LLAMA3_70B)
        res_d = run_fleet(trace, dual_pools, A100_LLAMA3_70B)
        wall = (time.perf_counter() - t0) * 1e6
        short_stats = res_d.per_pool["short"]
        emit(
            f"reliability/{label}/homogeneous",
            wall,
            f"preempt={res_h.preemptions};reject={res_h.rejections};"
            f"success={res_h.summary.success_rate:.4f};"
            f"ttft_p99={res_h.summary.ttft_p99:.2f}",
        )
        emit(
            f"reliability/{label}/token-budget",
            wall,
            f"preempt={res_d.preemptions};reject={res_d.rejections};"
            f"success={res_d.summary.success_rate:.4f};"
            f"fleet_ttft_p99={res_d.summary.ttft_p99:.2f};"
            f"short_pool_ttft_p99={short_stats.ttft_p99:.2f};"
            f"spills={res_d.summary.spills}",
        )
        out[label] = {"homo": res_h, "dual": res_d}
    return out


if __name__ == "__main__":
    run()
