"""§4.3 reliability: zero preemptions at designed sizes; fault isolation
under a long-request surge.

Two experiments, both through the columnar trace pipeline
(:func:`~repro.traces.generate_trace_columns`) and the vectorized DES
backend:

1. **designed** — Table-2-sized fleets on the nominal trace → expect 0
   preemptions, 0 rejections, 100% success on both configurations.
2. **long-surge** — the same fleets, but the trace gains a burst of extra
   long requests (+150% of the long-tail mass injected over a 20% window).
   In the homogeneous fleet the burst lands on the shared pool and inflates
   everyone's tail latency; with token-budget routing only the long pool
   queues — the short pool (>90% of traffic) keeps its TTFT. This is the
   paper's "graceful degradation / fault isolation" claim, measured.

``benchmarks/chaos.py`` reuses :func:`long_surge_columns` to combine the
same surge with *actual* instance faults.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from benchmarks.common import emit, write_json
from repro.core.pools import PoolConfig, n_seq_for_cmax
from repro.sim import A100_LLAMA3_70B, plan_fleet, run_fleet
from repro.traces import TraceSpec, generate_trace_columns
from repro.traces.generator import TraceColumns


def long_surge_columns(
    cols: TraceColumns, *, factor: float = 1.5, seed: int = 7
) -> TraceColumns:
    """Clone a fraction of long requests into a mid-trace burst window.

    Columnar equivalent of the old per-request ``dataclasses.replace``
    loop: sample ``factor ×`` the >8192-token rows with replacement, give
    them fresh ids and uniform arrivals in the [40%, 60%] window, and
    re-sort by arrival.
    """
    rng = np.random.default_rng(seed)
    t_lo = float(cols.arrival_time[int(len(cols) * 0.4)])
    t_hi = float(cols.arrival_time[int(len(cols) * 0.6)])
    long_idx = np.flatnonzero(cols.true_total > 8192)
    n_extra = int(len(long_idx) * factor)
    src = long_idx[rng.integers(0, len(long_idx), n_extra)]
    base_id = int(cols.request_id.max()) + 1
    extra = {
        "request_id": np.arange(base_id, base_id + n_extra, dtype=np.int64),
        "arrival_time": rng.uniform(t_lo, t_hi, n_extra),
    }
    merged = TraceColumns(
        **{
            f.name: np.concatenate(
                [getattr(cols, f.name), extra.get(f.name, getattr(cols, f.name)[src])]
            )
            for f in dataclasses.fields(TraceColumns)
        }
    )
    return merged.sorted_by_arrival()


def run(scale: float = 0.2, seed: int = 42, *, backend: str = "vectorized") -> dict:
    rate = 1000.0 * scale
    cols = generate_trace_columns(
        TraceSpec(
            trace="azure", num_requests=int(10_000 * scale), rate=rate, seed=seed
        )
    )
    plan = plan_fleet("azure", cols.to_requests(), A100_LLAMA3_70B, rate)
    homo_cfg = PoolConfig("homogeneous", 65_536, 16, headroom=1.08)
    short_cfg = PoolConfig(
        "short", 8192, n_seq_for_cmax(8192), batch_token_budget=16_384,
        headroom=1.05,
    )
    long_cfg = PoolConfig("long", 65_536, 16, headroom=1.02)
    homo_pools = {"homogeneous": (homo_cfg, plan.homogeneous.instances)}
    dual_pools = {
        "short": (short_cfg, plan.short.instances),
        "long": (long_cfg, plan.long.instances),
    }

    out = {}
    for label, trace in (
        ("designed", cols),
        ("long_surge", long_surge_columns(cols)),
    ):
        t0 = time.perf_counter()
        res_h = run_fleet(trace, homo_pools, A100_LLAMA3_70B, backend=backend)
        res_d = run_fleet(trace, dual_pools, A100_LLAMA3_70B, backend=backend)
        wall = (time.perf_counter() - t0) * 1e6
        short_stats = res_d.per_pool["short"]
        emit(
            f"reliability/{label}/homogeneous",
            wall,
            f"preempt={res_h.preemptions};reject={res_h.rejections};"
            f"success={res_h.summary.success_rate:.4f};"
            f"ttft_p99={res_h.summary.ttft_p99:.2f}",
        )
        emit(
            f"reliability/{label}/token-budget",
            wall,
            f"preempt={res_d.preemptions};reject={res_d.rejections};"
            f"success={res_d.summary.success_rate:.4f};"
            f"fleet_ttft_p99={res_d.summary.ttft_p99:.2f};"
            f"short_pool_ttft_p99={short_stats.ttft_p99:.2f};"
            f"spills={res_d.summary.spills}",
        )
        out[label] = {"homo": res_h, "dual": res_d}
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scale", type=float, default=0.2)
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--backend", default="vectorized",
                    choices=("reference", "vectorized"))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write emitted rows as a JSON artifact")
    args = ap.parse_args()
    run(args.scale, args.seed, backend=args.backend)
    if args.json:
        write_json(args.json)


if __name__ == "__main__":
    main()
