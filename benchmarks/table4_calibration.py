"""Table 4: per-category EMA calibration convergence (Monte Carlo).

Setup mirrors the paper's: synthetic per-category request streams with known
bytes-per-token ratios (uniform category mix), Azure-shaped total-token
distribution. After n=50 observations per category:

paper: rel. error ≤3.5%; calibrated mis-route <1% per category; global
static c=4 baseline 4.1% (CJK worst: the 2× ratio error systematically
under-counts tokens and false-routes to the short pool).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_us
from repro.core import (
    CATEGORY_NAMES,
    TRUE_BYTES_PER_TOKEN,
    Category,
    EmaCalibrator,
)
from repro.core.categories import BYTES_PER_TOKEN_STD
from repro.traces.cdf import AZURE


def _stream(cat: Category, n: int, rng: np.random.Generator):
    """Synthetic per-category stream: (byte_len, true_in, max_out) tuples."""
    totals = AZURE.sample_totals(rng, n)
    l_in, l_out = AZURE.sample_split(rng, totals)
    c = rng.normal(
        TRUE_BYTES_PER_TOKEN[cat], BYTES_PER_TOKEN_STD[cat], size=n
    ).clip(0.5)
    bytes_ = np.maximum(1, np.round(l_in * c)).astype(np.int64)
    return bytes_, l_in, l_out


def run(n_obs: int = 50, n_eval: int = 2500, b_short: int = 8192, seed: int = 42):
    rng = np.random.default_rng(seed)
    out = {}
    static = EmaCalibrator()  # never observes → global static c0 = 4.0
    static_miss, static_total = 0, 0

    for cat in Category:
        wb, wi, wo = _stream(cat, n_obs, rng)
        eb, ei, eo = _stream(cat, n_eval, rng)

        def calibrate():
            c = EmaCalibrator()
            for b, i in zip(wb, wi):
                c.observe(int(b), int(i), int(cat))
            return c

        us = time_us(calibrate, repeats=3)
        cal = calibrate()
        true_c = TRUE_BYTES_PER_TOKEN[cat]
        est_c = cal.ratio[int(cat)]
        rel_err = abs(est_c - true_c) / true_c

        def misroute(c: EmaCalibrator) -> float:
            miss = 0
            for b, i, o in zip(eb, ei, eo):
                est = c.estimate_total_budget(int(b), int(o), int(cat))
                if (est <= b_short) != (int(i + o) <= b_short):
                    miss += 1
            return miss / n_eval

        m_cal = misroute(cal)
        m_static = misroute(static)
        static_miss += int(m_static * n_eval)
        static_total += n_eval
        emit(
            f"table4/{CATEGORY_NAMES[cat].replace(' ', '_')}",
            us,
            f"true_c={true_c:.2f};est_c={est_c:.2f};rel_err={rel_err:.3f};"
            f"misroute={m_cal:.4f};static_misroute={m_static:.4f}",
        )
        out[CATEGORY_NAMES[cat]] = {
            "true": true_c, "est": est_c, "rel_err": rel_err,
            "misroute": m_cal, "static": m_static,
        }
    emit(
        "table4/global_static_c4",
        0.0,
        f"misroute={static_miss/static_total:.4f}",
    )
    out["static"] = static_miss / static_total
    return out


if __name__ == "__main__":
    run()
