"""Beyond-paper: int8 KV cache × pool routing — fleet-level effect.

The §Perf hillclimb shows int8 KV halves the decode memory term (the
dominant roofline term for every decode cell). Folded into the paper's own
fleet model it compounds with pool routing:

* KV bytes/token halve → the KV-block *byte* budget holds 2× the tokens →
  N_seq doubles at every C_max (Eq. 1–2);
* the per-sequence iteration overhead H = H_fixed + H_kv·(bytes/token)
  drops: we split the paper's calibrated H=0.65 ms into 40% fixed
  (sampling/bookkeeping) and 60% KV-read at bf16, so int8 gives
  H' = 0.26 + 0.39×0.51 ≈ 0.46 ms (assumption documented here; the Pallas
  paged-attention kernel reads int8 pages natively).

Applied to BOTH fleets (honest baseline): the dual-pool fleet shrinks
~35–40% further, and the paper's relative savings are preserved on top.
"""

from __future__ import annotations

import math

from benchmarks.common import emit
from repro.sim import TimingModel, plan_fleet
from repro.traces import TraceSpec, generate_trace

H_FIXED_FRAC = 0.40
INT8_KV_BYTES_FRAC = 0.51  # 1 byte + per-head fp16 scale ≈ 1.02/2.0


def int8_timing(base: TimingModel) -> TimingModel:
    h_fixed = H_FIXED_FRAC * base.h_per_seq
    h_kv = (1 - H_FIXED_FRAC) * base.h_per_seq
    return TimingModel(
        name=f"{base.name}+int8kv",
        w_base=base.w_base,
        h_per_seq=h_fixed + h_kv * INT8_KV_BYTES_FRAC,
        prefill_chunk=base.prefill_chunk,
    )


def run(rate: float = 1000.0) -> dict:
    from repro.sim.timing import A100_LLAMA3_70B

    reqs = generate_trace(
        TraceSpec(trace="azure", num_requests=10_000, rate=rate, seed=42)
    )
    out = {}
    for label, timing, slot_mult in (
        ("bf16", A100_LLAMA3_70B, 1),
        ("int8kv", int8_timing(A100_LLAMA3_70B), 2),
    ):
        plan = plan_fleet(
            "azure", reqs, timing, rate,
            homo_slots=16 * slot_mult,
            short_max_slots=128 * slot_mult,
            kv_block_budget_mult=float(slot_mult),
        )
        emit(
            f"beyond/int8kv/{label}",
            0.0,
            f"G_homo={plan.g_homo};G_dual={plan.g_dual};"
            f"savings={plan.savings:.3f};mu_short={plan.short.mu:.1f};"
            f"n_seq_short={plan.short.n_seq}",
        )
        out[label] = plan
    dual_cut = 1 - out["int8kv"].g_dual / out["bf16"].g_dual
    emit(
        "beyond/int8kv/fleet_reduction",
        0.0,
        f"dual_fleet_cut={dual_cut:.3f};"
        f"combined_vs_bf16_homogeneous="
        f"{1 - out['int8kv'].g_dual / out['bf16'].g_homo:.3f}",
    )
    return out


if __name__ == "__main__":
    run()
