"""§Roofline: aggregate the dry-run records into the per-cell table.

Reads results/dryrun/*.json (produced by ``python -m repro.launch.dryrun``)
and emits one row per (arch × shape × mesh): the three roofline terms,
the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs useful fraction, and
per-device memory. This is the table EXPERIMENTS.md §Roofline embeds.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(__file__)), "results", "dryrun")


def run(mesh: str = "pod16x16") -> list[dict]:
    rows = []
    paths = sorted(glob.glob(os.path.join(RESULTS_DIR, f"*__{mesh}.json")))
    if not paths:
        emit("roofline/no_records", 0.0, f"run repro.launch.dryrun first ({RESULTS_DIR})")
        return rows
    for path in paths:
        with open(path) as f:
            rec = json.load(f)
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec.get("status") == "skipped":
            emit(name, 0.0, "skipped=sub-quadratic-only")
            continue
        if rec.get("status") != "ok":
            emit(name, 0.0, f"status={rec.get('status')}")
            continue
        r = rec["roofline"]
        mem = rec.get("memory_analysis", {})
        per_dev_gb = mem.get("total_per_device", 0) / 1e9
        emit(
            name,
            rec.get("compile_s", 0.0) * 1e6,
            f"compute_ms={r['compute_s']*1e3:.2f};"
            f"memory_ms={r['memory_s']*1e3:.2f};"
            f"collective_ms={r['collective_s']*1e3:.2f};"
            f"dominant={r['dominant']};"
            f"useful_frac={rec.get('useful_flops_fraction', 0):.2f};"
            f"mem_gb_per_dev={per_dev_gb:.2f}",
        )
        rows.append(rec)
    return rows


if __name__ == "__main__":
    run()
    run("pod2x16x16")
