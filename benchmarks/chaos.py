"""Chaos benchmark: the paper's fault-isolation claim under *actual* faults.

§4.3 argues pool isolation gives graceful degradation — pressure on the
long pool never touches the short pool's latency. Earlier benchmarks only
created *pressure* (surges); this one *breaks things*, driving the
:mod:`repro.sim.faults` subsystem end to end through the vectorized
backend, static-vs-adaptive:

* ``crash_surge`` — a long-request surge (the §4.3 long-tail burst from
  ``benchmarks/reliability.py``) and, in the middle of it, a hard crash
  of a long-pool instance with its in-flight work lost. Retries
  re-route with backoff; the measurement is the paper's isolation claim
  under a *real* incident: the short pool holds its TTFT SLO while the
  long pool absorbs the crash.
* ``rolling_restart`` — every instance of both pools restarted in
  sequence (in-flight work re-queued, post-restart warm-up at degraded
  speed), the standard deploy-time reliability drill.
* ``straggler`` — one instance per pool runs 3× slow for the middle
  third of the run (the classic gray failure: alive, admitting, slow).

Each scenario validates its telemetry-v2 / events-v1 exports in-line, so
running this in CI is also an export-schema smoke. ``--determinism-check``
replays a seeded stochastic schedule twice and demands identical
counters; ``--check-isolation`` turns the crash_surge isolation claim
into a hard exit code for CI.
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Optional

from benchmarks.common import emit, write_json
from benchmarks.reliability import long_surge_columns
from repro.core.adaptive import AdaptiveController
from repro.core.pools import PoolConfig, n_seq_for_cmax
from repro.obs import TelemetryConfig, validate_events_jsonl, validate_telemetry
from repro.sim import (
    A100_LLAMA3_70B,
    PAPER_SLO,
    FaultInjector,
    FaultSpec,
    FleetSim,
    RetryPolicy,
    plan_fleet,
)
from repro.traces import TraceSpec, generate_trace_columns

#: Valid scenario names, in run order.
SCENARIO_NAMES = ("crash_surge", "rolling_restart", "straggler")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One fault scenario: a trace spec plus the fault schedule builder."""

    name: str
    spec: TraceSpec
    #: (pools: name → instances, duration) → FaultInjector
    faults: object
    retry: Optional[RetryPolicy] = None
    #: inject the §4.3 long-request burst into the [40%, 60%] window
    long_surge: bool = False


def _crash_surge_faults(pools: dict[str, int], duration: float) -> FaultInjector:
    """One long-pool instance dies mid-surge, in-flight work lost."""
    return FaultInjector(
        (
            FaultSpec(
                "crash",
                "long",
                instance=0,
                t=0.45 * duration,
                duration=0.20 * duration,
                requeue=False,
                warmup=0.05 * duration,
                warmup_factor=1.5,
            ),
        )
    )


def _rolling_restart_faults(pools: dict[str, int], duration: float) -> FaultInjector:
    """Restart every instance of every pool in sequence, re-queueing work."""
    specs = []
    slots = sum(pools.values())
    window = 0.8 * duration / max(1, slots)
    t = 0.1 * duration
    for name, count in pools.items():
        for inst in range(count):
            specs.append(
                FaultSpec(
                    "crash",
                    name,
                    instance=inst,
                    t=t,
                    duration=0.5 * window,
                    requeue=True,
                    warmup=0.25 * window,
                    warmup_factor=2.0,
                )
            )
            t += window
    return FaultInjector(specs)


def _straggler_faults(pools: dict[str, int], duration: float) -> FaultInjector:
    """Gray failure: one instance per pool at 3× iteration time mid-run."""
    return FaultInjector(
        tuple(
            FaultSpec(
                "slowdown",
                name,
                instance=0,
                t=0.33 * duration,
                duration=0.33 * duration,
                factor=3.0,
            )
            for name in pools
        )
    )


def scenarios(num_requests: int, rate: float, seed: int) -> list[Scenario]:
    duration = num_requests / rate  # nominal trace length, s
    base = TraceSpec(trace="azure", num_requests=num_requests, rate=rate, seed=seed)
    retry = RetryPolicy(
        max_retries=3,
        base_backoff=0.005 * duration,
        max_backoff=0.05 * duration,
        jitter=0.25,
        seed=seed,
    )
    return [
        Scenario(
            "crash_surge", base, _crash_surge_faults, retry=retry, long_surge=True
        ),
        Scenario("rolling_restart", base, _rolling_restart_faults, retry=retry),
        Scenario("straggler", base, _straggler_faults),
    ]


def build_pools(trace_cols, rate: float) -> dict[str, tuple[PoolConfig, int]]:
    """The paper's short/long pair, analytically sized for the base rate."""
    plan = plan_fleet("azure", trace_cols.to_requests(), A100_LLAMA3_70B, rate)
    short_cfg = PoolConfig(
        "short", 8192, n_seq_for_cmax(8192), batch_token_budget=16_384,
        headroom=1.05, queue_limit=64,
    )
    long_cfg = PoolConfig("long", 65_536, 16, headroom=1.02, queue_limit=64)
    return {
        "short": (short_cfg, plan.short.instances),
        "long": (long_cfg, plan.long.instances),
    }


def run_scenario(
    sc: Scenario,
    *,
    backend: str = "vectorized",
    control_window: int = 200,
) -> dict:
    cols = generate_trace_columns(sc.spec)
    pools = build_pools(cols, sc.spec.rate)  # sized for the NOMINAL trace
    if sc.long_surge:
        cols = long_surge_columns(cols, seed=sc.spec.seed)
    duration = float(cols.arrival_time[-1])
    injector = sc.faults({name: n for name, (_, n) in pools.items()}, duration)

    out = {}
    for label in ("static", "adaptive"):
        controller: Optional[AdaptiveController] = (
            AdaptiveController(b_min=512) if label == "adaptive" else None
        )
        sim = FleetSim(
            dict(pools),
            A100_LLAMA3_70B,
            b_short=8192,
            backend=backend,
            controller=controller,
            control_window=control_window,
            telemetry=TelemetryConfig(window=control_window, events=True),
            injector=injector,
            retry_policy=sc.retry,
        )
        t0 = time.perf_counter()
        res = sim.run(cols)
        wall = (time.perf_counter() - t0) * 1e6
        # every chaos run doubles as an export-schema smoke
        doc = validate_telemetry(res.telemetry.to_dict())
        assert doc["schema"] == "repro.obs/telemetry-v2", doc["schema"]
        validate_events_jsonl(res.telemetry.events.to_jsonl())
        s = res.summary
        short, long_ = res.per_pool["short"], res.per_pool["long"]
        emit(
            f"chaos/{sc.name}/{label}",
            wall,
            f"short_ttft_p99={short.ttft_p99:.3f};"
            f"long_ttft_p99={long_.ttft_p99:.3f};"
            f"goodput={res.goodput():.1f};avail={res.availability:.4f};"
            f"retries={res.retries};timeouts={res.timeouts};shed={res.shed};"
            f"fails={res.instance_failures};success={s.success_rate:.4f}",
        )
        out[label] = res
    return out


def run_scenarios(
    num_requests: int,
    rate: float,
    seed: int,
    *,
    backend: str = "vectorized",
    only: Optional[list[str]] = None,
) -> dict:
    names = list(only) if only else list(SCENARIO_NAMES)
    unknown = sorted(set(names) - set(SCENARIO_NAMES))
    if unknown:
        raise ValueError(
            f"unknown scenarios {unknown}; expected a subset of {SCENARIO_NAMES}"
        )
    return {
        sc.name: run_scenario(sc, backend=backend)
        for sc in scenarios(num_requests, rate, seed)
        if sc.name in names
    }


def check_isolation(results: dict) -> None:
    """The §4.3 claim as a hard assertion: under crash-during-surge the
    short pool holds its TTFT SLO while the long pool absorbs the hit."""
    res = results["crash_surge"]["static"]
    short = res.per_pool["short"]
    if res.instance_failures == 0:
        raise AssertionError("crash_surge injected no faults — scenario broken")
    if short.ttft_p99 > PAPER_SLO.ttft_p99:
        raise AssertionError(
            f"short pool lost its TTFT SLO under crash_surge: "
            f"p99={short.ttft_p99:.3f}s > {PAPER_SLO.ttft_p99}s"
        )
    emit(
        "chaos/crash_surge/isolation",
        0.0,
        f"short_ttft_p99={short.ttft_p99:.3f};slo={PAPER_SLO.ttft_p99};held=1",
    )


def check_determinism(num_requests: int, rate: float, seed: int, *, backend: str) -> None:
    """Same seeded stochastic fault schedule twice → identical counters."""
    spec = TraceSpec(trace="azure", num_requests=num_requests, rate=rate, seed=seed)
    cols = generate_trace_columns(spec)
    pools = build_pools(cols, rate)
    duration = float(cols.arrival_time[-1])
    retry = RetryPolicy(max_retries=3, base_backoff=0.01, max_backoff=0.1, seed=seed)

    def one():
        injector = FaultInjector.stochastic(
            {name: n for name, (_, n) in pools.items()},
            horizon=duration,
            rate=2.0 / duration,
            seed=seed,
            requeue=True,
        )
        res = FleetSim(
            dict(pools),
            A100_LLAMA3_70B,
            b_short=8192,
            backend=backend,
            injector=injector,
            retry_policy=retry,
        ).run(cols)
        return (
            res.summary.completed,
            res.summary.rejected,
            res.summary.truncated,
            res.retries,
            res.timeouts,
            res.shed,
            res.instance_failures,
            res.availability,
            res.summary.ttft_p99,
            res.summary.makespan,
        )

    a, b = one(), one()
    if a != b:
        raise AssertionError(f"seeded fault replay diverged:\n  {a}\n  {b}")
    emit("chaos/determinism", 0.0, f"fails={a[6]};retries={a[3]};identical=1")


def run(
    scale: float = 0.2,
    seed: int = 42,
    *,
    backend: str = "vectorized",
    only: Optional[list[str]] = None,
) -> dict:
    return run_scenarios(
        int(10_000 * scale), 1000.0 * scale, seed, backend=backend, only=only
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--rate", type=float, default=None,
                    help="arrival rate (default: requests/10 → 10 s trace)")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--backend", default="vectorized",
                    choices=("reference", "vectorized"))
    ap.add_argument("--scenarios", nargs="+", default=None,
                    choices=SCENARIO_NAMES,
                    help="subset of scenarios to run (default: all)")
    ap.add_argument("--check-isolation", action="store_true",
                    help="assert the short pool holds its TTFT SLO in crash_surge")
    ap.add_argument("--determinism-check", action="store_true",
                    help="replay a seeded stochastic schedule twice, demand "
                         "identical FleetResult counters")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write emitted rows as a JSON artifact")
    args = ap.parse_args()
    rate = args.rate if args.rate is not None else args.requests / 10.0

    names = list(args.scenarios) if args.scenarios else list(SCENARIO_NAMES)
    if args.check_isolation and "crash_surge" not in names:
        ap.error("--check-isolation requires the crash_surge scenario")
    try:
        results = run_scenarios(
            args.requests, rate, args.seed, backend=args.backend, only=names
        )
        if args.check_isolation:
            check_isolation(results)
        if args.determinism_check:
            check_determinism(args.requests, rate, args.seed, backend=args.backend)
    except AssertionError as e:
        print(f"chaos: FAILED: {e}", file=sys.stderr)
        if args.json:
            write_json(args.json, extra={"failed": str(e)})
        raise SystemExit(1)
    if args.json:
        write_json(args.json)


if __name__ == "__main__":
    main()
