"""Generate the EXPERIMENTS.md markdown tables from dry-run records.

    PYTHONPATH=src python -m benchmarks.make_tables > results/tables.md
"""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(os.path.dirname(__file__)), "results", "dryrun")

ARCH_ORDER = [
    "gemma-2b", "granite-3-8b", "yi-6b", "granite-34b",
    "llama4-scout-17b-a16e", "llama4-maverick-400b-a17b",
    "qwen2-vl-7b", "musicgen-medium", "zamba2-2.7b", "xlstm-350m",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, tag: str = "") -> dict:
    out = {}
    suffix = f"_{tag}" if tag else ""
    for p in glob.glob(os.path.join(RESULTS, f"*__{mesh}{suffix}.json")):
        name = os.path.basename(p)
        if not tag and name.count("_", name.rfind("__")) > 0:
            # exclude tagged variants when loading baselines
            stem = name[: -len(".json")]
            if not stem.endswith(mesh):
                continue
        r = json.load(open(p))
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_ms(s: float) -> str:
    return f"{s*1e3:.2f}"


def roofline_table(mesh: str) -> str:
    recs = load(mesh)
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | 6·N·D / HLO | mem/chip (GB) | compile (s) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None:
                continue
            if r["status"] == "skipped":
                lines.append(
                    f"| {arch} | {shape} | — | — | — | *skipped: "
                    f"full-attention arch at 524k* | — | — | — |"
                )
                continue
            roof = r["roofline"]
            mem = r.get("memory_analysis", {}).get("total_per_device", 0) / 1e9
            lines.append(
                f"| {arch} | {shape} | {fmt_ms(roof['compute_s'])} | "
                f"{fmt_ms(roof['memory_s'])} | {fmt_ms(roof['collective_s'])} | "
                f"**{roof['dominant']}** | "
                f"{r.get('useful_flops_fraction', 0):.2f} | {mem:.1f} | "
                f"{r.get('compile_s', 0):.1f} |"
            )
    return "\n".join(lines)


def variant_rows(arch: str, shape: str, mesh: str, tags: list[str]) -> str:
    rows = []
    base = load(mesh).get((arch, shape))
    entries = [("baseline", base)]
    for t in tags:
        v = load(mesh, t).get((arch, shape))
        entries.append((t, v))
    lines = [
        "| variant | compute (ms) | memory (ms) | collective (ms) | dominant | bound (ms) |",
        "|---|---|---|---|---|---|",
    ]
    for name, r in entries:
        if r is None or r.get("status") != "ok":
            lines.append(f"| {name} | (missing) | | | | |")
            continue
        roof = r["roofline"]
        bound = max(roof["compute_s"], roof["memory_s"], roof["collective_s"])
        lines.append(
            f"| {name} | {fmt_ms(roof['compute_s'])} | {fmt_ms(roof['memory_s'])} "
            f"| {fmt_ms(roof['collective_s'])} | {roof['dominant']} | "
            f"{fmt_ms(bound)} |"
        )
    return "\n".join(lines)


def main() -> None:
    print("## Roofline — single pod (16×16 = 256 chips)\n")
    print(roofline_table("pod16x16"))
    print("\n## Roofline — multi-pod (2×16×16 = 512 chips)\n")
    print(roofline_table("pod2x16x16"))
    print("\n## Hillclimb variants\n")
    print("### yi-6b × decode_32k (int8 KV)\n")
    print(variant_rows("yi-6b", "decode_32k", "pod16x16", ["int8kv"]))
    print("\n### xlstm-350m × train_4k (pure DP)\n")
    print(variant_rows("xlstm-350m", "train_4k", "pod16x16", ["puredp"]))
    print("\n### granite-34b × prefill_32k (triangle causal)\n")
    print(variant_rows("granite-34b", "prefill_32k", "pod16x16", ["triangle"]))
    print("\n### granite-3-8b × train_4k (triangle, +dots remat)\n")
    print(
        variant_rows(
            "granite-3-8b", "train_4k", "pod16x16",
            ["triangle", "triangle_dots"],
        )
    )


if __name__ == "__main__":
    main()
