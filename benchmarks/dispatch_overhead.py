"""§2.2 claim: dispatch is three comparisons + a queue-depth lookup — O(1)
with sub-microsecond overhead. Measures the host-side route() hot path and
the vectorized JAX batch-routing throughput.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import (
    PoolState,
    Request,
    TokenBudgetRouter,
    init_state,
    jax_route_batch,
    long_pool,
    short_pool,
)


def run(n: int = 100_000) -> dict:
    router = TokenBudgetRouter(
        PoolState(config=short_pool()), PoolState(config=long_pool())
    )
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            request_id=i,
            byte_len=int(rng.integers(64, 64_000)),
            max_output_tokens=int(rng.integers(16, 4096)),
            category=int(rng.integers(0, 4)),
        )
        for i in range(n)
    ]
    t0 = time.perf_counter()
    for r in reqs:
        router.route(r)
    dt = time.perf_counter() - t0
    us = dt / n * 1e6
    emit("dispatch/host_route", us, f"sub_microsecond={us < 1.0}")

    # calibration feedback path
    t0 = time.perf_counter()
    for r in reqs[:10_000]:
        router.on_response(r, max(1, r.byte_len // 4))
    us_fb = (time.perf_counter() - t0) / 10_000 * 1e6
    emit("dispatch/on_response", us_fb, f"sub_microsecond={us_fb < 1.0}")

    # vectorized batch path
    st = init_state()
    bl = jnp.asarray([r.byte_len for r in reqs], jnp.int32)
    mo = jnp.asarray([r.max_output_tokens for r in reqs], jnp.int32)
    ct = jnp.asarray([r.category for r in reqs], jnp.int32)
    jax_route_batch(st, bl, mo, ct)  # compile
    t0 = time.perf_counter()
    pools, _ = jax_route_batch(st, bl, mo, ct)
    pools.block_until_ready()
    us_batch = (time.perf_counter() - t0) / n * 1e6
    emit("dispatch/jax_batch_per_req", us_batch, f"n={n}")
    return {"host_us": us, "batch_us": us_batch}


if __name__ == "__main__":
    run()
