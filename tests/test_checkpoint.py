"""Checkpointing: atomicity, versioning, dtype round-trip, elastic restore."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def tree():
    return {
        "w": jnp.asarray(np.random.default_rng(0).normal(size=(8, 16)), jnp.float32),
        "b16": jnp.asarray([1.5, -2.25, 0.125], jnp.bfloat16),
        "nested": {"count": jnp.int32(7), "m": jnp.ones((4,), jnp.float32)},
    }


class TestRoundTrip:
    def test_exact_bits_including_bf16(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        t = tree()
        ck.save(5, t)
        restored, meta = ck.restore(t, step=5)
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(t)):
            assert a.dtype == b.dtype
            assert jnp.array_equal(
                a.astype(jnp.float32), b.astype(jnp.float32)
            )

    def test_metadata_round_trip(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, tree(), {"loss": 3.25, "step_time": 0.1})
        _, meta = ck.restore(tree())
        assert meta == {"loss": 3.25, "step_time": 0.1}

    def test_async_save(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_save=True)
        ck.save(2, tree())
        ck.wait()
        assert ck.latest_step() == 2


class TestVersioning:
    def test_latest_and_gc(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, tree())
        assert ck.latest_step() == 4
        assert ck.completed_steps() == [3, 4]  # GC kept last 2

    def test_partial_checkpoint_invisible(self, tmp_path):
        """A tmp dir (simulated crash mid-write) is never listed."""
        ck = Checkpointer(str(tmp_path))
        ck.save(1, tree())
        fake = os.path.join(str(tmp_path), "step_00000009.tmp-123")
        os.makedirs(fake)
        with open(os.path.join(fake, "arr_00000.p0.npy"), "wb") as f:
            f.write(b"partial")
        assert ck.latest_step() == 1

    def test_restore_missing_raises(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        with pytest.raises(FileNotFoundError):
            ck.restore(tree())

    def test_leaf_count_mismatch_detected(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, tree())
        with pytest.raises(ValueError):
            ck.restore({"only": jnp.zeros((2,))})


class TestElasticRestore:
    def test_restore_with_new_shardings(self, tmp_path):
        """Restore places leaves via the provided shardings (re-mesh path)."""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

        ck = Checkpointer(str(tmp_path))
        t = tree()
        ck.save(3, t)
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
        restored, _ = ck.restore(t, shardings=sh)
        for leaf in jax.tree.leaves(restored):
            assert leaf.sharding == NamedSharding(mesh, P())

    def test_training_resume_continuity(self, tmp_path):
        """Save mid-run, restore, continue → identical to uninterrupted run."""
        from repro.configs import get_config
        from repro.models import Model
        from repro.training import (
            DataConfig, SyntheticLM, TrainConfig, init_train_state,
            make_train_step,
        )

        cfg = get_config("yi-6b").reduced()
        model = Model(cfg)
        tcfg = TrainConfig(total_steps=10, warmup_steps=1)
        step_fn, _ = make_train_step(model, tcfg)
        jstep = jax.jit(step_fn)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2))

        params, opt = init_train_state(model, tcfg, jax.random.key(0))
        losses_a = []
        for i in range(6):
            b = jax.tree.map(jnp.asarray, data.batch(i))
            params, opt, m = jstep(params, opt, b, jnp.int32(i))
            losses_a.append(float(m["loss"]))
            if i == 2:
                ck = Checkpointer(str(tmp_path))
                ck.save(i + 1, {"p": params, "o": opt})

        # crash + restore at step 3, replay 3..5 (seekable data pipeline)
        state, _ = ck.restore({"p": params, "o": opt}, step=3)
        p2, o2 = state["p"], state["o"]
        losses_b = []
        for i in range(3, 6):
            b = jax.tree.map(jnp.asarray, data.batch(i))
            p2, o2, m = jstep(p2, o2, b, jnp.int32(i))
            losses_b.append(float(m["loss"]))
        np.testing.assert_allclose(losses_a[3:], losses_b, rtol=1e-5)
