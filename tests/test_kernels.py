"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, paged_attention, ref, ssd_scan

F32 = jnp.float32
BF16 = jnp.bfloat16


def rand(rng, shape, dtype, scale=1.0):
    return (jnp.asarray(rng.normal(size=shape)) * scale).astype(dtype)


FLASH_CASES = [
    # (B, L, H, K, D, dtype, tol)
    (2, 256, 8, 2, 64, F32, 2e-5),
    (1, 512, 4, 1, 128, F32, 2e-5),  # MQA
    (2, 128, 4, 4, 32, F32, 2e-5),  # MHA
    (1, 256, 8, 8, 256, F32, 2e-5),  # gemma-style head_dim
    (2, 256, 8, 2, 64, BF16, 2e-2),
    (1, 384, 6, 2, 64, F32, 2e-5),  # non-pow2 length (divides 128)
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_oracle(case, causal):
    B, L, H, K, D, dtype, tol = case
    rng = np.random.default_rng(0)
    q = rand(rng, (B, L, H, D), dtype)
    k = rand(rng, (B, L, K, D), dtype)
    v = rand(rng, (B, L, K, D), dtype)
    out = flash_attention(
        q, k, v, causal=causal, block_q=128, block_k=128, interpret=True
    )
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=tol
    )


PAGED_CASES = [
    # (B, H, K, D, page, pages_per_seq, dtype, tol)
    (4, 8, 2, 64, 16, 8, F32, 2e-5),
    (2, 8, 1, 128, 16, 4, F32, 2e-5),  # MQA
    (3, 4, 4, 32, 32, 4, F32, 2e-5),
    (4, 8, 2, 64, 16, 8, BF16, 2e-2),
]


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_attention_matches_oracle(case):
    B, H, K, D, page, pps, dtype, tol = case
    rng = np.random.default_rng(1)
    total_pages = B * pps * 2
    q = rand(rng, (B, H, D), dtype)
    kp = rand(rng, (total_pages, page, K, D), dtype)
    vp = rand(rng, (total_pages, page, K, D), dtype)
    perm = rng.permutation(total_pages)[: B * pps]
    bt = jnp.asarray(perm.reshape(B, pps), jnp.int32)
    lengths = jnp.asarray(
        rng.integers(1, pps * page + 1, size=(B,)), jnp.int32
    )
    out = paged_attention(q, kp, vp, bt, lengths, interpret=True)
    expect = ref.paged_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=tol
    )


def test_paged_attention_ignores_unmapped_pages():
    """Pages past `lengths` must not affect the output (poison test)."""
    rng = np.random.default_rng(2)
    B, H, K, D, page, pps = 2, 4, 2, 64, 16, 4
    q = rand(rng, (B, H, D), F32)
    kp = rand(rng, (16, page, K, D), F32)
    vp = rand(rng, (16, page, K, D), F32)
    bt = jnp.asarray(rng.permutation(16)[: B * pps].reshape(B, pps), jnp.int32)
    lengths = jnp.asarray([20, 35], jnp.int32)
    base = paged_attention(q, kp, vp, bt, lengths, interpret=True)
    # poison every page beyond each sequence's length
    kp2, vp2 = np.asarray(kp).copy(), np.asarray(vp).copy()
    for b in range(B):
        first_dead = int(np.ceil(lengths[b] / page))
        for j in range(first_dead, pps):
            kp2[int(bt[b, j])] = 1e9
            vp2[int(bt[b, j])] = 1e9
    out = paged_attention(
        q, jnp.asarray(kp2), jnp.asarray(vp2), bt, lengths, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(base), atol=1e-4
    )


SSD_CASES = [
    # (B, L, H, P, N, chunk, dtype, tol)
    (2, 128, 4, 32, 16, 32, F32, 5e-5),
    (1, 256, 2, 64, 64, 128, F32, 1e-4),
    (2, 64, 8, 16, 32, 64, F32, 5e-5),
    (2, 128, 4, 32, 16, 32, BF16, 6e-2),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_matches_oracle(case):
    B, L, H, P, N, chunk, dtype, tol = case
    rng = np.random.default_rng(3)
    x = rand(rng, (B, L, H, P), dtype)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, L, H))).astype(F32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, (H,))).astype(F32)
    bm = rand(rng, (B, L, N), dtype)
    cm = rand(rng, (B, L, N), dtype)
    y, s = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    y_ref, s_ref = ref.ssd_scan_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32), atol=tol
    )
    np.testing.assert_allclose(
        np.asarray(s), np.asarray(s_ref), atol=max(tol, 1e-4)
    )


def test_ssd_scan_state_streams_across_chunks():
    """Final state equals the sequential recurrence regardless of chunking."""
    rng = np.random.default_rng(4)
    B, L, H, P, N = 1, 96, 2, 16, 8
    x = rand(rng, (B, L, H, P), F32)
    dt = jnp.asarray(rng.uniform(0.05, 0.1, (B, L, H))).astype(F32)
    a = -jnp.ones((H,), F32)
    bm = rand(rng, (B, L, N), F32)
    cm = rand(rng, (B, L, N), F32)
    states = []
    for chunk in (32, 48, 96):
        _, s = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
        states.append(np.asarray(s))
    np.testing.assert_allclose(states[0], states[1], atol=1e-4)
    np.testing.assert_allclose(states[0], states[2], atol=1e-4)


def test_paged_attention_int8_pages():
    """int8 KV pages + per-(pos,head) scales ≈ the fp32 oracle (§Perf A1)."""
    rng = np.random.default_rng(5)
    B, H, K, D, page, pps = 3, 8, 2, 64, 16, 4
    total = 16
    q = rand(rng, (B, H, D), F32)
    kp = rand(rng, (total, page, K, D), F32)
    vp = rand(rng, (total, page, K, D), F32)
    bt = jnp.asarray(rng.permutation(total)[: B * pps].reshape(B, pps), jnp.int32)
    lengths = jnp.asarray([64, 40, 13], jnp.int32)

    def quant(t):
        amax = jnp.max(jnp.abs(t), axis=-1, keepdims=True)
        s = jnp.maximum(amax, 1e-6) / 127.0
        qv = jnp.clip(jnp.round(t / s), -127, 127).astype(jnp.int8)
        return qv, s.astype(jnp.float32)

    kq, ks = quant(kp)
    vq, vs = quant(vp)
    out = paged_attention(q, kq, vq, bt, lengths, ks, vs, interpret=True)
    expect = ref.paged_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), atol=5e-2
    )
    # and well inside the quantization-noise envelope
    assert float(jnp.abs(out - expect).max()) < 0.05


# ---------------------------------------------------------------------------
# sim_decode: the DES decode-advance kernel vs its jnp twin
# ---------------------------------------------------------------------------


def _decode_state(seed, I=3, S=8):
    """Random but invariant-respecting slot state (float64 exact class)."""
    rng = np.random.default_rng(seed)
    occ = rng.random((I, S)) < 0.7
    pre = np.where(
        occ & (rng.random((I, S)) < 0.3),
        rng.integers(1, 600, (I, S)),
        0,
    ).astype(np.int32)
    inp = np.where(occ, rng.integers(16, 1200, (I, S)), 0).astype(np.int32)
    gen = np.where(occ & (pre == 0), rng.integers(0, 48, (I, S)), 0).astype(
        np.int32
    )
    rem = np.where(occ, rng.integers(1, 120, (I, S)), 0).astype(np.int32)
    blk = np.where(occ, (inp + gen) // 16 + 1, 0).astype(np.int32)
    sq = rng.permutation(I * S).reshape(I, S).astype(np.int32)
    nact = occ.sum(axis=1, dtype=np.int32)
    busy = nact > 0
    now = np.where(busy, rng.uniform(0.5, 2.0, I), 0.0)
    free = rng.integers(0, 64, I).astype(np.int32)
    ft = np.where(
        occ & (gen > 0), rng.uniform(0.1, 1.0, (I, S)), np.nan
    )
    tr = np.zeros((I, S), bool)
    t_limit = float(now.max() + 0.75)
    return dict(
        t_limit=t_limit, busy=busy, now=now, nact=nact, free=free,
        occ=occ, pre=pre, sq=sq, inp=inp, gen=gen, rem=rem, blk=blk,
        ft=ft, tr=tr,
    )


@pytest.mark.parametrize("seed", [0, 1, 7])
def test_sim_decode_pallas_matches_jnp(seed):
    from repro.kernels.sim_decode import (
        decode_advance_jnp,
        decode_advance_pallas,
    )

    s = _decode_state(seed)
    kw = dict(w=2**-10, h=2**-13, chunk=512, c_max=2048)
    with jax.experimental.enable_x64():
        args = (
            s["t_limit"], s["busy"], s["now"], s["nact"], s["free"],
            s["occ"], s["pre"], s["sq"], s["inp"], s["gen"], s["rem"],
            s["blk"], s["ft"], s["tr"],
        )
        out_j = decode_advance_jnp(*args, **kw)
        out_p = decode_advance_pallas(*args, **kw)
    assert set(out_j) == set(out_p)
    for k in out_j:
        a, b = np.asarray(out_j[k]), np.asarray(out_p[k])
        assert a.dtype == b.dtype, k
        assert np.array_equal(a, b, equal_nan=True), k


def test_sim_decode_idle_instances_are_inert():
    """Idle (not busy) instances complete and truncate nothing — the
    busy-gated outputs the engine consumes unmasked must stay silent
    (raw ``gen``/``rem`` are busy-masked by the engine itself)."""
    from repro.kernels.sim_decode import decode_advance_jnp

    s = _decode_state(3)
    s["busy"] = np.zeros_like(s["busy"])
    s["now"] = np.zeros_like(s["now"])
    with jax.experimental.enable_x64():
        out = decode_advance_jnp(
            s["t_limit"], s["busy"], s["now"], s["nact"], s["free"],
            s["occ"], s["pre"], s["sq"], s["inp"], s["gen"], s["rem"],
            s["blk"], s["ft"], s["tr"],
            w=2**-10, h=2**-13, chunk=512, c_max=2048,
        )
    assert not np.asarray(out["comp"]).any()
    assert not np.asarray(out["trunc_new"]).any()
