"""N-pool routing parity: scalar route() ≡ batched route_batch().

The static decision of Algorithm 1 has two implementations — the host-side
threshold search in :meth:`TokenBudgetRouter.route` and the vectorized
``searchsorted`` kernel behind :meth:`TokenBudgetRouter.route_batch`. This
suite pins them together for P ∈ {2, 3, 4} pools across every traffic
category and the exact threshold boundaries (``B_k``, ``B_k ± 1``, and
budgets beyond the largest ``C_max``), plus the shape-padding behaviour of
ragged final epochs.
"""

import numpy as np
import pytest

from repro.core import (
    EmaCalibrator,
    PoolConfig,
    PoolSet,
    PoolState,
    Request,
    TokenBudgetRouter,
    n_seq_for_cmax,
)

#: Budget-ordered topologies: (c_maxs, thresholds B_1 < … < B_{P-1}).
TOPOLOGIES = {
    2: ((8192, 65_536), (8192,)),
    3: ((4096, 16_384, 65_536), (4096, 16_384)),
    4: ((2048, 8192, 16_384, 65_536), (2048, 8192, 16_384)),
}

NUM_CATEGORIES = 4


def make_pool_set(n_pools: int) -> PoolSet:
    c_maxs, thresholds = TOPOLOGIES[n_pools]
    states = [
        PoolState(
            config=PoolConfig(
                f"pool{k}", c, n_seq_for_cmax(c, max_slots=64)
            )
        )
        for k, c in enumerate(c_maxs)
    ]
    return PoolSet(states, thresholds)


def make_router(n_pools: int, calibrator=None) -> TokenBudgetRouter:
    return TokenBudgetRouter(
        pools=make_pool_set(n_pools), calibrator=calibrator, spillover=False
    )


def boundary_requests(router: TokenBudgetRouter) -> list[Request]:
    """Requests whose *estimated* budgets land exactly on every boundary.

    Inverts Eq. 3 through the output-cap term: with ``byte_len=1`` the
    input estimate is ``ceil(1/ĉ) = 1`` token for any sane ratio, so
    ``max_output_tokens = target - 1`` pins the estimated total to
    ``target`` regardless of calibration state.
    """
    largest_cmax = router.pools.configs[-1].c_max
    targets = sorted(
        {
            t
            for b in router.pools.thresholds
            for t in (int(b) - 1, int(b), int(b) + 1)
        }
        | {2, largest_cmax, largest_cmax + 1, 4 * largest_cmax}
    )
    return [
        Request(
            request_id=i,
            byte_len=1,
            max_output_tokens=t - 1,
            category=cat,
        )
        for i, (t, cat) in enumerate(
            (t, cat) for t in targets for cat in range(NUM_CATEGORIES)
        )
    ]


def warmed_calibrator(seed: int = 0) -> EmaCalibrator:
    """A calibrator with distinct per-category ratios and spreads."""
    calib = EmaCalibrator()
    rng = np.random.default_rng(seed)
    true_ratio = {0: 4.4, 1: 3.1, 2: 2.0, 3: 3.6}
    for _ in range(80):
        cat = int(rng.integers(0, NUM_CATEGORIES))
        tokens = int(rng.integers(100, 4000))
        noisy = tokens * (true_ratio[cat] + rng.normal(0, 0.3))
        calib.observe(max(1, int(noisy)), tokens, cat)
    return calib


@pytest.mark.parametrize("n_pools", [2, 3, 4])
class TestStaticParity:
    def assert_parity(
        self, router: TokenBudgetRouter, requests, *, exact: bool = True
    ) -> None:
        """Scalar and batched static decisions must agree.

        ``exact=False`` admits the one known divergence: the host path
        computes ``ceil(|r|/ĉ)`` in float64, the JAX kernel in float32, so
        budgets may differ by 1 ulp-of-ceil on ~100k-token estimates —
        decisions then may only differ when that ±1 straddles a threshold.
        """
        pool_ids, budgets = router.route_batch(
            [r.byte_len for r in requests],
            [r.max_output_tokens for r in requests],
            [r.category for r in requests],
        )
        thresholds = router.pools.thresholds
        for i, r in enumerate(requests):
            d = router.route(r)
            batch_idx, batch_budget = int(pool_ids[i]), int(budgets[i])
            assert d.pool == router.pools.names[d.pool_index]
            if exact:
                assert d.estimated_total == batch_budget, f"req {i}"
            else:
                assert abs(d.estimated_total - batch_budget) <= 1, f"req {i}"
            lo = min(d.estimated_total, batch_budget)
            hi = max(d.estimated_total, batch_budget)
            straddles = bool(np.any((thresholds >= lo) & (thresholds < hi)))
            if not straddles:
                assert d.pool_index == batch_idx, (
                    f"req {i}: scalar → {d.pool_index}, batch → {batch_idx} "
                    f"(budget {d.estimated_total} vs {batch_budget})"
                )

    def test_boundary_budgets_cold(self, n_pools):
        """Exactly B_k / B_k ± 1 / beyond-largest-C_max, cold calibrator."""
        router = make_router(n_pools)
        self.assert_parity(router, boundary_requests(router))

    def test_boundary_budgets_warmed(self, n_pools):
        """Same boundaries with converged per-category calibration."""
        router = make_router(n_pools, calibrator=warmed_calibrator())
        self.assert_parity(router, boundary_requests(router))

    def test_random_requests_warmed(self, n_pools):
        """Randomized byte/cap/category sweep, per-category ratios live."""
        router = make_router(n_pools, calibrator=warmed_calibrator(7))
        rng = np.random.default_rng(n_pools)
        requests = [
            Request(
                request_id=i,
                byte_len=int(rng.integers(1, 400_000)),
                max_output_tokens=int(rng.integers(1, 40_000)),
                category=int(rng.integers(0, NUM_CATEGORIES)),
            )
            for i in range(300)
        ]
        self.assert_parity(router, requests, exact=False)

    def test_beyond_largest_cmax_goes_last_pool(self, n_pools):
        """The hard-constraint tail: an infeasible-everywhere budget still
        routes (to the largest pool) identically in both paths."""
        router = make_router(n_pools)
        big = 4 * router.pools.configs[-1].c_max
        d = router.route(Request(0, byte_len=1, max_output_tokens=big, category=0))
        pool_ids, _ = router.route_batch([1], [big], [0])
        assert d.pool_index == int(pool_ids[0]) == n_pools - 1


class TestRaggedEpochPadding:
    """route_batch pads inputs to a power of two for JIT shape reuse; the
    pad rows must never escape into decisions, counters, or feedback."""

    def test_output_sliced_to_input_length(self):
        router = make_router(3)
        for n in (1, 5, 37, 100, 1000):
            pool_ids, budgets = router.route_batch(
                [100] * n, [64] * n, [0] * n
            )
            assert len(pool_ids) == len(budgets) == n

    def test_ragged_tail_matches_full_batch_prefix(self):
        """Same calibrator state → a ragged final epoch routes exactly like
        the corresponding prefix of a larger (differently-padded) batch."""
        router = make_router(3, calibrator=warmed_calibrator(3))
        rng = np.random.default_rng(11)
        byte_lens = rng.integers(1, 200_000, size=256)
        caps = rng.integers(1, 30_000, size=256)
        cats = rng.integers(0, NUM_CATEGORIES, size=256)
        full_ids, full_budgets = router.route_batch(byte_lens, caps, cats)
        for n in (37, 100, 255):  # three different pad widths
            ids, budgets = router.route_batch(
                byte_lens[:n], caps[:n], cats[:n]
            )
            np.testing.assert_array_equal(ids, full_ids[:n])
            np.testing.assert_array_equal(budgets, full_budgets[:n])

    def test_counters_unaffected_by_padding(self):
        """Dispatching every batched decision counts exactly n requests —
        pad rows never reach the routed counters."""
        router = make_router(3)
        n = 37  # pads to 64
        pool_ids, budgets = router.route_batch([100] * n, [64] * n, [0] * n)
        for pid, budget in zip(pool_ids, budgets):
            router.route_decided(int(pid), int(budget))
        assert sum(router.routed.values()) == n

    def test_fleet_ragged_final_epoch_counts_exact(self):
        """End-to-end regression: a vectorized fleet whose trace does not
        fill its final routing epoch routes exactly len(trace) requests."""
        from repro.sim.fleet import FleetSim
        from repro.sim.timing import TimingModel
        from repro.traces import TraceSpec, generate_trace_columns

        cols = generate_trace_columns(
            TraceSpec(trace="azure", num_requests=100, rate=200.0, seed=5)
        )  # first epoch 64, final epoch a ragged 36 → padded to 64
        cfgs = {
            "short": (PoolConfig("short", 8192, 32), 2),
            "long": (PoolConfig("long", 65_536, 8), 2),
        }
        timing = TimingModel("fast", w_base=1e-3, h_per_seq=1e-4, prefill_chunk=512)
        sim = FleetSim(cfgs, timing, backend="vectorized")
        res = sim.run(cols)
        assert sum(sim.router.routed.values()) == len(cols)
        assert res.summary.num_requests == len(cols) - int(len(cols) * 0.2)
        # EMA feedback saw at most one observation per completed request.
        assert sum(sim.router.calibrator.count) <= len(cols)
