"""Cost model (Eq. 1–2, 6–8) unit + property tests, incl. paper numbers."""

import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core import (
    LLAMA3_70B_KV,
    MI300X,
    QWEN3_235B_KV,
    closed_form_savings,
    corrected_savings,
    dual_fleet_naive,
    homogeneous_fleet,
    mi300x_case_study,
    n_seq_for_cmax,
)

settings.register_profile("fast", max_examples=40, deadline=None)
settings.load_profile("fast")


class TestEq7:
    def test_paper_examples(self):
        """§3: α=0.80, ρ=4 → 60%; α=0.70, ρ=2 → 35%."""
        assert closed_form_savings(0.80, 4.0) == pytest.approx(0.60)
        assert closed_form_savings(0.70, 2.0) == pytest.approx(0.35)

    @given(alpha=st.floats(0, 1), rho=st.floats(1.0, 64.0))
    def test_bounds(self, alpha, rho):
        s = closed_form_savings(alpha, rho)
        assert 0.0 <= s < 1.0

    @given(
        alpha=st.floats(0.01, 1),
        rho1=st.floats(1.0, 32.0),
        rho2=st.floats(1.0, 32.0),
    )
    def test_monotone_in_rho(self, alpha, rho1, rho2):
        lo, hi = sorted((rho1, rho2))
        assert closed_form_savings(alpha, lo) <= closed_form_savings(
            alpha, hi
        ) + 1e-12

    @given(rho=st.floats(1.0, 32.0), a1=st.floats(0, 1), a2=st.floats(0, 1))
    def test_monotone_in_alpha(self, rho, a1, a2):
        lo, hi = sorted((a1, a2))
        assert closed_form_savings(lo, rho) <= closed_form_savings(hi, rho) + 1e-12

    def test_rejects_bad_inputs(self):
        with pytest.raises(ValueError):
            closed_form_savings(1.5, 2.0)
        with pytest.raises(ValueError):
            closed_form_savings(0.5, 0.0)


class TestEq8:
    @given(
        rate=st.floats(10, 10_000),
        alpha=st.floats(0.05, 0.95),
        mu_s=st.floats(1.0, 100.0),
        mu_h=st.floats(0.5, 50.0),
    )
    def test_corrected_never_beats_naive_when_long_is_slower(
        self, rate, alpha, mu_s, mu_h
    ):
        """μ_Pl ≤ μ_homo ⇒ Eq. 8 fleet ≥ Eq. 6 fleet (the §4.2 gap)."""
        mu_l = mu_h * 0.5
        s8, g_homo, g8 = corrected_savings(rate, alpha, mu_s, mu_l, mu_h)
        g6 = dual_fleet_naive(rate, alpha, mu_s, mu_h)
        assert g8 >= g6

    def test_homogeneous_fleet_rounds_up(self):
        assert homogeneous_fleet(1000, 3.0, 1.08) == 360
        assert homogeneous_fleet(1.0, 100.0) == 1


class TestKVMath:
    def test_block_budget_matches_paper_table1(self):
        """Appendix A: N_seq 128 @ 8K, 64 @ 16K, 32 @ 32K, 16 @ 64K."""
        assert n_seq_for_cmax(8192) == 128
        assert n_seq_for_cmax(16_384) == 64
        assert n_seq_for_cmax(32_768) == 32
        assert n_seq_for_cmax(65_536, max_slots=16) == 16

    def test_mi300x_case_study_exact(self):
        """§4.7: 23.5 KB/token/GPU, 133.4 GB, 676 vs 169 (4×)."""
        cs = mi300x_case_study()
        assert cs.kv_kb_per_token_per_gpu == pytest.approx(23.5, abs=0.05)
        assert cs.kv_budget_gb_per_gpu == pytest.approx(133.4, abs=0.1)
        assert cs.n_seq_short == 676
        assert cs.n_seq_long == 169
        assert cs.concurrency_ratio == pytest.approx(4.0, abs=0.01)

    def test_qwen3_kv_per_token(self):
        """Eq. 1: 2·94·4·128·2 = 192.5 KB/token whole model."""
        assert QWEN3_235B_KV.kv_bytes_per_token() == 2 * 94 * 4 * 128 * 2

    @given(c1=st.integers(1024, 65_536), c2=st.integers(1024, 65_536))
    def test_n_seq_monotone_decreasing_in_cmax(self, c1, c2):
        lo, hi = sorted((c1, c2))
        assert n_seq_for_cmax(lo) >= n_seq_for_cmax(hi)

    @given(cmax=st.integers(256, 65_536))
    def test_eq2_memory_nonnegative(self, cmax):
        n = LLAMA3_70B_KV.n_seq_memory(MI300X, cmax)
        assert n >= 0
