"""Discrete-event simulator: conservation, reliability, paper anchors."""

import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.core.pools import PoolConfig, n_seq_for_cmax
from repro.core.router import Request
from repro.sim import (
    A100_LLAMA3_70B,
    InstanceSim,
    TimingModel,
    plan_fleet,
    run_fleet,
)
from repro.traces import TraceSpec, generate_trace

settings.register_profile("fast", max_examples=20, deadline=None)
settings.load_profile("fast")

FAST = TimingModel("fast", w_base=1e-3, h_per_seq=1e-4, prefill_chunk=512)


def mk_request(i, t, l_in, l_out):
    return Request(
        request_id=i,
        byte_len=l_in * 4,
        max_output_tokens=l_out,
        category=0,
        arrival_time=t,
        true_input_tokens=l_in,
        true_output_tokens=l_out,
    )


class TestInstanceSim:
    def test_single_request_completes(self):
        pool = PoolConfig("p", 4096, 4)
        inst = InstanceSim(pool, FAST)
        inst.submit(mk_request(0, 0.0, 600, 5), 0.0)
        t = 0.0
        for _ in range(100):
            dt, done = inst.step(t)
            t += max(dt, 1e-9)
            if done:
                break
        assert len(inst.records) == 1
        rec = inst.records[0]
        assert rec.output_tokens == 5
        # 600 tokens → 2 prefill chunks; first token in chunk-2's iteration
        assert rec.first_token > 0
        assert rec.finish >= rec.first_token

    def test_reject_oversized_prompt(self):
        pool = PoolConfig("p", 1024, 4)
        inst = InstanceSim(pool, FAST)
        ok = inst.submit(mk_request(0, 0.0, 2000, 5), 0.0)
        assert not ok
        assert inst.rejection_count == 1
        assert inst.records[0].rejected

    def test_truncation_at_cmax(self):
        pool = PoolConfig("p", 128, 2)
        inst = InstanceSim(pool, FAST)
        inst.submit(mk_request(0, 0.0, 100, 1000), 0.0)
        t = 0.0
        for _ in range(2000):
            dt, done = inst.step(t)
            t += max(dt, 1e-9)
            if done:
                break
        rec = inst.records[0]
        assert rec.truncated
        assert 100 + rec.output_tokens <= 128

    def test_block_accounting_never_negative(self):
        pool = PoolConfig("p", 2048, 8)
        inst = InstanceSim(pool, FAST)
        for i in range(20):
            inst.submit(mk_request(i, 0.0, 500, 50), 0.0)
        t = 0.0
        for _ in range(3000):
            assert 0 <= inst.blocks_free <= inst.total_blocks
            dt, _ = inst.step(t)
            if inst.idle:
                break
            t += max(dt, 1e-9)
        assert len([r for r in inst.records if not r.rejected]) == 20
        assert inst.blocks_free == inst.total_blocks  # all freed

    def test_preemption_under_block_pressure(self):
        """Tiny block budget + growing decodes → vLLM-style preemption."""
        pool = PoolConfig("p", 4096, 8)
        inst = InstanceSim(pool, FAST, total_blocks=80)
        for i in range(8):
            inst.submit(mk_request(i, 0.0, 64, 400), 0.0)
        t = 0.0
        for _ in range(20_000):
            dt, _ = inst.step(t)
            if inst.idle:
                break
            t += max(dt, 1e-9)
        done = [r for r in inst.records if not r.rejected]
        assert len(done) == 8  # everyone eventually finishes
        assert inst.preemption_count > 0  # but some were preempted

    @given(
        n=st.integers(1, 25),
        seed=st.integers(0, 100),
    )
    def test_conservation(self, n, seed):
        """Every submitted request is exactly once completed or rejected."""
        import numpy as np

        r = np.random.default_rng(seed)
        pool = PoolConfig("p", 2048, 4)
        inst = InstanceSim(pool, FAST)
        for i in range(n):
            inst.submit(
                mk_request(
                    i,
                    float(r.uniform(0, 0.1)),
                    int(r.integers(1, 3000)),
                    int(r.integers(1, 50)),
                ),
                0.0,
            )
        t = 0.0
        for _ in range(50_000):
            dt, _ = inst.step(t)
            if inst.idle:
                break
            t += max(dt, 1e-9)
        ids = sorted(rec.request_id for rec in inst.records)
        assert ids == list(range(n))
        for rec in inst.records:
            if not rec.rejected:
                assert rec.finish >= rec.first_token >= 0


class TestFleet:
    def test_designed_fleet_zero_preemptions(self):
        """§4.3: zero preemptions / rejections at designed sizes."""
        reqs = generate_trace(
            TraceSpec(trace="azure", num_requests=600, rate=50, seed=42)
        )
        plan = plan_fleet("azure", reqs, A100_LLAMA3_70B, 50.0)
        short_cfg = PoolConfig(
            "short", 8192, n_seq_for_cmax(8192), headroom=1.05
        )
        long_cfg = PoolConfig("long", 65_536, 16, headroom=1.02)
        res = run_fleet(
            reqs,
            {
                "short": (short_cfg, plan.short.instances),
                "long": (long_cfg, plan.long.instances),
            },
            A100_LLAMA3_70B,
        )
        assert res.preemptions == 0
        assert res.summary.success_rate == 1.0
        assert res.summary.meets_slo()

    def test_router_feedback_calibrates(self):
        reqs = generate_trace(
            TraceSpec(trace="azure", num_requests=400, rate=50, seed=1)
        )
        plan = plan_fleet("azure", reqs, A100_LLAMA3_70B, 50.0)
        short_cfg = PoolConfig("short", 8192, 128, headroom=1.05)
        long_cfg = PoolConfig("long", 65_536, 16, headroom=1.02)
        res = run_fleet(
            reqs,
            {
                "short": (short_cfg, plan.short.instances),
                "long": (long_cfg, plan.long.instances),
            },
            A100_LLAMA3_70B,
        )
        calib = res.router_stats["calibration"]
        assert all(c > 0 for c in calib["count"])  # every category observed

    def test_paper_table1_throughputs(self):
        """μ within 5% of Table 1: 3.0 / 13.5 / 0.4 (Azure)."""
        reqs = generate_trace(
            TraceSpec(trace="azure", num_requests=10_000, rate=1000, seed=42)
        )
        plan = plan_fleet("azure", reqs, A100_LLAMA3_70B, 1000.0)
        assert plan.homogeneous.mu == pytest.approx(3.0, rel=0.05)
        assert plan.short.mu == pytest.approx(13.5, rel=0.05)
        assert plan.long.mu == pytest.approx(0.385, rel=0.1)

    def test_paper_table2_savings(self):
        """Savings within 1pp of Table 2: 16.6% Azure / 38.5% LMSYS."""
        for trace, expected in (("azure", 0.166), ("lmsys", 0.385)):
            reqs = generate_trace(
                TraceSpec(trace=trace, num_requests=10_000, rate=1000, seed=42)
            )
            plan = plan_fleet(trace, reqs, A100_LLAMA3_70B, 1000.0)
            assert plan.savings == pytest.approx(expected, abs=0.01)


class TestSummarizeParity:
    """``summarize`` (record objects) and ``summarize_columns`` (arrays)
    must agree on degenerate inputs, where warm-up cuts, empty percentile
    sets, and zero makespans are easiest to get wrong in one path only."""

    @staticmethod
    def _to_columns(records):
        import numpy as np

        return {
            "request_id": np.array([r.request_id for r in records], dtype=np.int64),
            "arrival": np.array([r.arrival for r in records]),
            "first_token": np.array([r.first_token for r in records]),
            "finish": np.array([r.finish for r in records]),
            "output_tokens": np.array(
                [r.output_tokens for r in records], dtype=np.int64
            ),
            "preemptions": np.array(
                [r.preemptions for r in records], dtype=np.int64
            ),
            "truncated": np.array([r.truncated for r in records], dtype=bool),
            "rejected": np.array([r.rejected for r in records], dtype=bool),
        }

    def _assert_parity(self, records, **kw):
        from repro.sim.metrics import summarize, summarize_columns

        a = summarize("x", records, **kw)
        b = summarize_columns("x", self._to_columns(records), **kw)
        assert a == b

    def test_empty_trace(self):
        self._assert_parity([])

    def test_all_rejected(self):
        from repro.sim.metrics import RequestRecord

        records = [
            RequestRecord(
                request_id=i,
                pool="p",
                arrival=float(i),
                first_token=float(i),
                finish=float(i),
                output_tokens=0,
                rejected=True,
            )
            for i in range(10)
        ]
        self._assert_parity(records)
        from repro.sim.metrics import summarize

        s = summarize("x", records)
        assert s.completed == 0 and s.rejected == 8  # post 20% warm-up cut
        assert s.makespan == 0.0 and s.throughput == 0.0

    def test_all_truncated(self):
        from repro.sim.metrics import RequestRecord

        records = [
            RequestRecord(
                request_id=i,
                pool="p",
                arrival=float(i),
                first_token=float(i) + 0.5,
                finish=float(i) + 1.0,
                output_tokens=1,  # truncated after the first token: no TPOT
                truncated=True,
            )
            for i in range(10)
        ]
        self._assert_parity(records)
        from repro.sim.metrics import summarize

        s = summarize("x", records)
        assert s.truncated == s.completed == 8
        assert s.tpot_p50 == s.tpot_p99 == 0.0  # no multi-token requests
        assert s.ttft_p50 == 0.5

    def test_single_record(self):
        from repro.sim.metrics import RequestRecord

        self._assert_parity(
            [
                RequestRecord(
                    request_id=0,
                    pool="p",
                    arrival=0.0,
                    first_token=0.25,
                    finish=1.0,
                    output_tokens=4,
                )
            ]
        )

    def test_mixed_with_spills_and_warmup(self):
        from repro.sim.metrics import RequestRecord

        records = [
            RequestRecord(
                request_id=i,
                pool="p",
                arrival=float(i),
                first_token=float(i) + 0.1 * (i + 1),
                finish=float(i) + 1.0 + 0.05 * i,
                output_tokens=i % 5,
                preemptions=i % 3,
                truncated=(i % 4 == 0),
                rejected=(i % 7 == 0),
            )
            for i in range(23)
        ]
        self._assert_parity(records, warmup_frac=0.20, total_spills=6)
        self._assert_parity(records, warmup_frac=0.0, total_spills=0)
