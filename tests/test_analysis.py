"""simlint analyzer tests: per-rule fixtures + repo-wide clean smoke.

Each shipped rule gets (at least) one passing fixture, one violating
fixture, and one suppressed fixture, per the analyzer contract.  The
fixtures are tiny synthetic trees under tmp_path shaped like the real
repo (``repro/sim/...``) so the manifest's path matching engages.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from repro.analysis import DEFAULT_MANIFEST, analyze_paths, manifest_dict
from repro.analysis.core import SourceFile, analyze_files, default_rules
from repro.analysis.dtype import DtypeDisciplineRule
from repro.analysis.guards import GuardDisciplineRule
from repro.analysis.parity import EngineParityRule
from repro.analysis.purity import JitPurityRule
from repro.analysis.schema import EventSchemaRule

SRC = Path(__file__).resolve().parents[1] / "src"


def _write(tmp_path: Path, rel: str, code: str) -> Path:
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(code)
    return p


def _lint_one(tmp_path, rel, code, rule):
    p = _write(tmp_path, rel, code)
    return analyze_files([SourceFile.load(p)], [rule])


# ---------------------------------------------------------------------------
# guard-discipline
# ---------------------------------------------------------------------------


class TestGuardDiscipline:
    def test_guarded_emit_passes(self, tmp_path):
        code = (
            "class S:\n"
            "    def step(self):\n"
            "        if self.tracer is not None:\n"
            "            self.tracer.emit(ADMIT, 1)\n"
        )
        assert _lint_one(tmp_path, "m.py", code, GuardDisciplineRule()) == []

    def test_and_conjunction_guard_passes(self, tmp_path):
        code = (
            "class S:\n"
            "    def step(self, mask):\n"
            "        if self.tracer is not None and mask.any():\n"
            "            self.tracer.emit(TRUNCATE, 2)\n"
        )
        assert _lint_one(tmp_path, "m.py", code, GuardDisciplineRule()) == []

    def test_early_return_guard_passes(self, tmp_path):
        code = (
            "class S:\n"
            "    def step(self):\n"
            "        if self.tracer is None:\n"
            "            return 0\n"
            "        self.tracer.emit(ARRIVAL, 3)\n"
            "        return 1\n"
        )
        assert _lint_one(tmp_path, "m.py", code, GuardDisciplineRule()) == []

    def test_conditional_expression_guard_passes(self, tmp_path):
        code = (
            "class S:\n"
            "    def tick(self, t):\n"
            "        return self.telemetry.sample(t) "
            "if self.telemetry is not None else None\n"
        )
        assert _lint_one(tmp_path, "m.py", code, GuardDisciplineRule()) == []

    def test_unguarded_emit_flagged(self, tmp_path):
        code = (
            "class S:\n"
            "    def step(self):\n"
            "        self.tracer.emit(ADMIT, 1)\n"
        )
        fs = _lint_one(tmp_path, "m.py", code, GuardDisciplineRule())
        assert len(fs) == 1
        assert fs[0].rule == "guard-discipline"
        assert fs[0].line == 3

    def test_wrong_receiver_guard_flagged(self, tmp_path):
        code = (
            "class S:\n"
            "    def step(self):\n"
            "        if self.telemetry is not None:\n"
            "            self.tracer.emit(ADMIT, 1)\n"
        )
        fs = _lint_one(tmp_path, "m.py", code, GuardDisciplineRule())
        assert len(fs) == 1

    def test_nested_function_must_reguard(self, tmp_path):
        code = (
            "class S:\n"
            "    def step(self):\n"
            "        if self.tracer is not None:\n"
            "            def inner():\n"
            "                self.tracer.emit(ADMIT, 1)\n"
        )
        fs = _lint_one(tmp_path, "m.py", code, GuardDisciplineRule())
        assert len(fs) == 1

    def test_fault_runtime_any_method_watched(self, tmp_path):
        code = (
            "class S:\n"
            "    def route(self, t):\n"
            "        return self._fault_rt.blocked(t)\n"
        )
        fs = _lint_one(tmp_path, "m.py", code, GuardDisciplineRule())
        assert len(fs) == 1

    def test_suppression_honored(self, tmp_path):
        code = (
            "class S:\n"
            "    def step(self):\n"
            "        self.tracer.emit(ADMIT, 1)"
            "  # simlint: disable=guard-discipline\n"
        )
        assert _lint_one(tmp_path, "m.py", code, GuardDisciplineRule()) == []


# ---------------------------------------------------------------------------
# dtype-discipline
# ---------------------------------------------------------------------------

JAX_ENGINE = "repro/sim/jax_engine.py"


class TestDtypeDiscipline:
    def test_explicit_f64_passes(self, tmp_path):
        code = (
            "import jax.numpy as jnp\n"
            "f64 = jnp.float64\n"
            "x = jnp.zeros((4,), f64)\n"
            "y = jnp.asarray(0, jnp.int32)\n"
        )
        assert _lint_one(tmp_path, JAX_ENGINE, code, DtypeDisciplineRule()) == []

    def test_float32_reference_flagged(self, tmp_path):
        code = "import jax.numpy as jnp\nx = q.astype(jnp.float32)\n"
        fs = _lint_one(tmp_path, JAX_ENGINE, code, DtypeDisciplineRule())
        assert len(fs) == 1 and "float32" in fs[0].message

    def test_float32_outside_critical_file_ignored(self, tmp_path):
        code = "import jax.numpy as jnp\nx = q.astype(jnp.float32)\n"
        assert (
            _lint_one(tmp_path, "repro/other.py", code, DtypeDisciplineRule())
            == []
        )

    def test_manifest_scope_allowance(self, tmp_path):
        code = (
            "import jax.numpy as jnp\n"
            "def window_step(c):\n"
            "    return c.astype(jnp.float32)\n"
        )
        assert _lint_one(tmp_path, JAX_ENGINE, code, DtypeDisciplineRule()) == []

    def test_bare_float_literal_constructor_flagged(self, tmp_path):
        code = "import jax.numpy as jnp\nx = jnp.asarray(1e-9)\n"
        fs = _lint_one(tmp_path, JAX_ENGINE, code, DtypeDisciplineRule())
        assert len(fs) == 1 and "float literal" in fs[0].message

    def test_implicit_dtype_zeros_flagged(self, tmp_path):
        code = "import jax.numpy as jnp\nx = jnp.zeros((4,))\n"
        fs = _lint_one(tmp_path, JAX_ENGINE, code, DtypeDisciplineRule())
        assert len(fs) == 1

    def test_unwrapped_roofline_constant_flagged(self, tmp_path):
        code = "def f(timing):\n    return timing.w_base * 2\n"
        fs = _lint_one(tmp_path, JAX_ENGINE, code, DtypeDisciplineRule())
        assert len(fs) == 1 and "w_base" in fs[0].message

    def test_wrapped_roofline_constant_passes(self, tmp_path):
        code = "def f(timing):\n    return float(timing.w_base) * 2\n"
        assert _lint_one(tmp_path, JAX_ENGINE, code, DtypeDisciplineRule()) == []

    def test_x64_entry_outside_context_flagged(self, tmp_path):
        code = "def go(spec):\n    return _runner(spec)\n"
        fs = _lint_one(tmp_path, JAX_ENGINE, code, DtypeDisciplineRule())
        assert len(fs) == 1 and "enable_x64" in fs[0].message

    def test_x64_entry_inside_context_passes(self, tmp_path):
        code = (
            "from jax.experimental import enable_x64\n"
            "def go(spec):\n"
            "    with enable_x64():\n"
            "        return _runner(spec)\n"
        )
        assert _lint_one(tmp_path, JAX_ENGINE, code, DtypeDisciplineRule()) == []

    def test_suppression_honored(self, tmp_path):
        code = (
            "import jax.numpy as jnp\n"
            "x = q.astype(jnp.float32)"
            "  # simlint: disable=dtype-discipline\n"
        )
        assert _lint_one(tmp_path, JAX_ENGINE, code, DtypeDisciplineRule()) == []


# ---------------------------------------------------------------------------
# jit-purity
# ---------------------------------------------------------------------------


class TestJitPurity:
    def test_clean_jit_body_passes(self, tmp_path):
        code = (
            "import jax\n"
            "def core(c):\n"
            "    return c + 1\n"
            "fn = jax.jit(core)\n"
        )
        assert _lint_one(tmp_path, "m.py", code, JitPurityRule()) == []

    def test_clock_in_jit_body_flagged(self, tmp_path):
        code = (
            "import jax, time\n"
            "def core(c):\n"
            "    t = time.time()\n"
            "    return c + t\n"
            "fn = jax.jit(core)\n"
        )
        fs = _lint_one(tmp_path, "m.py", code, JitPurityRule())
        assert len(fs) == 1 and "time.time" in fs[0].message

    def test_print_in_while_loop_body_flagged(self, tmp_path):
        code = (
            "from jax import lax\n"
            "def body(c):\n"
            "    print(c)\n"
            "    return c\n"
            "out = lax.while_loop(lambda c: c < 3, body, 0)\n"
        )
        fs = _lint_one(tmp_path, "m.py", code, JitPurityRule())
        assert len(fs) == 1 and "print" in fs[0].message

    def test_transitive_callee_checked(self, tmp_path):
        code = (
            "import jax\n"
            "def helper(x):\n"
            "    print(x)\n"
            "    return x\n"
            "def core(c):\n"
            "    return helper(c)\n"
            "fn = jax.jit(core)\n"
        )
        fs = _lint_one(tmp_path, "m.py", code, JitPurityRule())
        assert len(fs) == 1

    def test_decorated_partial_jit_detected(self, tmp_path):
        code = (
            "import functools, jax\n"
            "@functools.partial(jax.jit, static_argnames=('n',))\n"
            "def core(c, n):\n"
            "    global COUNT\n"
            "    return c\n"
        )
        fs = _lint_one(tmp_path, "m.py", code, JitPurityRule())
        assert len(fs) == 1 and "global" in fs[0].message

    def test_while_body_arity_flagged(self, tmp_path):
        code = (
            "from jax import lax\n"
            "def body(a, b):\n"
            "    return a\n"
            "out = lax.while_loop(lambda c: True, body, 0)\n"
        )
        fs = _lint_one(tmp_path, "m.py", code, JitPurityRule())
        assert any("one carry parameter" in f.message for f in fs)

    def test_bare_return_in_while_body_flagged(self, tmp_path):
        code = (
            "from jax import lax\n"
            "def body(c):\n"
            "    if c:\n"
            "        return\n"
            "    return c\n"
            "out = lax.while_loop(lambda c: True, body, 0)\n"
        )
        fs = _lint_one(tmp_path, "m.py", code, JitPurityRule())
        assert any("bare `return`" in f.message for f in fs)

    def test_legacy_global_rng_flagged_anywhere(self, tmp_path):
        code = "import numpy as np\nx = np.random.rand(4)\n"
        fs = _lint_one(tmp_path, "m.py", code, JitPurityRule())
        assert len(fs) == 1 and "np.random.rand" in fs[0].message

    def test_seeded_generator_passes(self, tmp_path):
        code = "import numpy as np\nrng = np.random.default_rng(0)\n"
        assert _lint_one(tmp_path, "m.py", code, JitPurityRule()) == []

    def test_suppression_honored(self, tmp_path):
        code = (
            "import numpy as np\n"
            "x = np.random.rand(4)  # simlint: disable=jit-purity\n"
        )
        assert _lint_one(tmp_path, "m.py", code, JitPurityRule()) == []


# ---------------------------------------------------------------------------
# engine-parity (project rule, fixture engine trio + fleet)
# ---------------------------------------------------------------------------

REF_ENGINE_OK = """
class PoolSim:
    def step(self):
        self.preemption_count += 1
        self.rejection_count += 1
        self.truncation_count += 1
        if self.tracer is not None:
            self.tracer.emit(ADMIT, 1)
            self.tracer.emit(PREEMPT, 1)
            self.tracer.emit(TRUNCATE, 1)
            self.tracer.emit(REJECT, 1)
"""

JAX_ENGINE_OK = """
def init_pool():
    return {"npre": 0, "nrej": 0, "ntr": 0}

def update(st):
    return {"npre": st["npre"] + 1, "nrej": st["nrej"] + 1,
            "ntr": st["ntr"] + 1}

def run_fleet_jax(fleet):
    return FleetResult(
        summary=1, per_pool=2, router_stats=3, preemptions=4,
        rejections=5, truncations=6, telemetry=None, slo=None,
    )
"""

FLEET_OK = """
def _run_reference(self):
    return FleetResult(
        summary=1, per_pool=2, router_stats=3, preemptions=4,
        rejections=5, truncations=6, retries=0, timeouts=0, shed=0,
        instance_failures=0, availability=1.0, records=[],
        fail_records=[], telemetry=None, slo=None,
    )

def _run_vectorized(self):
    return FleetResult(
        summary=1, per_pool=2, router_stats=3, preemptions=4,
        rejections=5, truncations=6, retries=0, timeouts=0, shed=0,
        instance_failures=0, availability=1.0,
        fail_records=[], telemetry=None, slo=None,
    )
"""


def _parity_tree(tmp_path, vec_engine=REF_ENGINE_OK, fleet=FLEET_OK):
    files = [
        _write(tmp_path, "repro/sim/engine.py", REF_ENGINE_OK),
        _write(tmp_path, "repro/sim/vector_engine.py", vec_engine),
        _write(tmp_path, "repro/sim/jax_engine.py", JAX_ENGINE_OK),
        _write(tmp_path, "repro/sim/fleet.py", fleet),
    ]
    return [SourceFile.load(p) for p in files]


class TestEngineParity:
    def test_aligned_trio_passes(self, tmp_path):
        files = _parity_tree(tmp_path)
        assert analyze_files(files, [EngineParityRule()]) == []

    def test_missing_counter_flagged(self, tmp_path):
        vec = REF_ENGINE_OK.replace("self.truncation_count += 1\n        ", "")
        files = _parity_tree(tmp_path, vec_engine=vec)
        fs = analyze_files(files, [EngineParityRule()])
        assert any(
            "truncation_count" in f.message
            and f.path.endswith("vector_engine.py")
            for f in fs
        )

    def test_unknown_counter_flagged(self, tmp_path):
        vec = REF_ENGINE_OK.replace(
            "self.truncation_count += 1",
            "self.truncation_count += 1\n        self.mystery_count += 1",
        )
        files = _parity_tree(tmp_path, vec_engine=vec)
        fs = analyze_files(files, [EngineParityRule()])
        assert any("mystery_count" in f.message for f in fs)

    def test_missing_event_kind_flagged(self, tmp_path):
        vec = REF_ENGINE_OK.replace("self.tracer.emit(PREEMPT, 1)\n            ", "")
        files = _parity_tree(tmp_path, vec_engine=vec)
        fs = analyze_files(files, [EngineParityRule()])
        assert any("preempt" in f.message for f in fs)

    def test_fleet_result_drift_flagged(self, tmp_path):
        fleet = FLEET_OK.replace("availability=1.0,\n        fail_records=[], ", "")
        files = _parity_tree(tmp_path, fleet=fleet)
        fs = analyze_files(files, [EngineParityRule()])
        missing = {m for f in fs for m in ("availability", "fail_records")
                   if m in f.message}
        assert missing == {"availability", "fail_records"}

    def test_manifest_tolerates_jax_omissions(self, tmp_path):
        # the jax fixture omits retries/timeouts/records/... — all of it
        # declared in fleet_result.missing_ok, so the aligned tree is clean
        files = _parity_tree(tmp_path)
        assert analyze_files(files, [EngineParityRule()]) == []

    def test_suppression_honored(self, tmp_path):
        vec = REF_ENGINE_OK.replace(
            "self.truncation_count += 1",
            "self.truncation_count += 1\n        "
            "self.mystery_count += 1  # simlint: disable=engine-parity",
        )
        files = _parity_tree(tmp_path, vec_engine=vec)
        assert analyze_files(files, [EngineParityRule()]) == []

    def test_partial_tree_skips(self, tmp_path):
        p = _write(tmp_path, "repro/sim/engine.py", REF_ENGINE_OK)
        assert analyze_files([SourceFile.load(p)], [EngineParityRule()]) == []


# ---------------------------------------------------------------------------
# event-schema (project rule, fixture obs trio)
# ---------------------------------------------------------------------------

EVENTS_OK = """
ARRIVAL, ADMIT, REJECT, CALIB_SYNC = range(4)
EVENT_NAMES = ("arrival", "admit", "reject", "calib_sync")
"""

EMITTER_OK = """
class S:
    def step(self):
        if self.tracer is not None:
            self.tracer.emit(ARRIVAL, 1)
            self.tracer.emit(ADMIT, 1)
            self.tracer.emit(REJECT, 1)
            self.tracer.emit(CALIB_SYNC, 1)
"""

VALIDATE_OK = """
REQUIRED_COLUMNS = ("t_sim",)
POOL_COLUMNS = ("queue_depth", "active")
REQUIRED_COLUMNS_V2 = ("retries",)
POOL_COLUMNS_V2 = ("down",)
"""

TIMESERIES_OK = """
class T:
    def sample(self, name):
        self.columns["t_sim"].append(0)
        self.columns["retries"].append(0)
        self.columns[f"queue_depth.{name}"].append(0)
        self.columns[f"active.{name}"].append(0)
        self.columns[f"down.{name}"].append(0)
"""


def _schema_manifest():
    m = manifest_dict()
    m["telemetry"]["emitter_files"] = ["repro/sim/engine.py"]
    m["telemetry"]["unvalidated_families_ok"] = {}
    return m


def _schema_tree(tmp_path, events=EVENTS_OK, emitter=EMITTER_OK,
                 validate=VALIDATE_OK, timeseries=TIMESERIES_OK):
    files = [
        _write(tmp_path, "repro/obs/events.py", events),
        _write(tmp_path, "repro/sim/engine.py", emitter),
        _write(tmp_path, "repro/obs/validate.py", validate),
        _write(tmp_path, "repro/obs/timeseries.py", timeseries),
    ]
    return [SourceFile.load(p) for p in files]


class TestEventSchema:
    def test_wired_tree_passes(self, tmp_path):
        files = _schema_tree(tmp_path)
        assert analyze_files(files, [EventSchemaRule(_schema_manifest())]) == []

    def test_name_order_mismatch_flagged(self, tmp_path):
        ev = EVENTS_OK.replace('"admit", "reject"', '"reject", "admit"')
        files = _schema_tree(tmp_path, events=ev)
        fs = analyze_files(files, [EventSchemaRule(_schema_manifest())])
        assert any("mismatch" in f.message for f in fs)

    def test_arity_mismatch_flagged(self, tmp_path):
        ev = EVENTS_OK.replace(', "calib_sync"', "")
        files = _schema_tree(tmp_path, events=ev)
        fs = analyze_files(files, [EventSchemaRule(_schema_manifest())])
        assert any("EVENT_NAMES" in f.message for f in fs)

    def test_dead_kind_flagged(self, tmp_path):
        em = EMITTER_OK.replace("self.tracer.emit(CALIB_SYNC, 1)\n", "pass\n")
        files = _schema_tree(tmp_path, emitter=em)
        fs = analyze_files(files, [EventSchemaRule(_schema_manifest())])
        assert any("CALIB_SYNC" in f.message and "declared but" in f.message
                   for f in fs)

    def test_undeclared_kind_flagged(self, tmp_path):
        em = EMITTER_OK.replace(
            "self.tracer.emit(CALIB_SYNC, 1)",
            "self.tracer.emit(CALIB_SYNC, 1)\n"
            "            self.tracer.emit(MYSTERY, 1)",
        )
        files = _schema_tree(tmp_path, emitter=em)
        fs = analyze_files(files, [EventSchemaRule(_schema_manifest())])
        assert any("MYSTERY" in f.message for f in fs)

    def test_validator_only_column_flagged(self, tmp_path):
        va = VALIDATE_OK.replace('"queue_depth", "active"',
                                 '"queue_depth", "active", "bogus"')
        files = _schema_tree(tmp_path, validate=va)
        fs = analyze_files(files, [EventSchemaRule(_schema_manifest())])
        assert any('"bogus"' in f.message for f in fs)

    def test_unvalidated_family_flagged_then_tolerated(self, tmp_path):
        ts = TIMESERIES_OK.replace(
            'self.columns[f"down.{name}"].append(0)',
            'self.columns[f"down.{name}"].append(0)\n'
            '        self.columns[f"mystery.{name}"].append(0)',
        )
        files = _schema_tree(tmp_path, timeseries=ts)
        fs = analyze_files(files, [EventSchemaRule(_schema_manifest())])
        assert any('"mystery.*"' in f.message for f in fs)
        m = _schema_manifest()
        m["telemetry"]["unvalidated_families_ok"] = {"mystery": "fixture"}
        assert analyze_files(files, [EventSchemaRule(m)]) == []

    def test_suppression_honored(self, tmp_path):
        # dead-kind finding anchors at the constants line in events.py
        ev = EVENTS_OK.replace(
            "ARRIVAL, ADMIT, REJECT, CALIB_SYNC = range(4)",
            "ARRIVAL, ADMIT, REJECT, CALIB_SYNC = range(4)"
            "  # simlint: disable=event-schema",
        )
        em = EMITTER_OK.replace("self.tracer.emit(CALIB_SYNC, 1)\n", "pass\n")
        files = _schema_tree(tmp_path, events=ev, emitter=em)
        assert analyze_files(files, [EventSchemaRule(_schema_manifest())]) == []


# ---------------------------------------------------------------------------
# repo-wide smoke + CLI + manifest
# ---------------------------------------------------------------------------


class TestRepoClean:
    def test_simlint_clean_on_repo(self):
        findings = analyze_paths([SRC / "repro"])
        assert findings == [], "\n".join(f.format() for f in findings)

    def test_default_rules_cover_contract(self):
        names = {r.name for r in default_rules()}
        assert names == {
            "engine-parity",
            "guard-discipline",
            "dtype-discipline",
            "jit-purity",
            "event-schema",
        }

    def test_manifest_reasons_present(self):
        # every tolerance is a documented statement: reasons are non-empty
        ev = DEFAULT_MANIFEST["events"]["missing_ok"]
        fr = DEFAULT_MANIFEST["fleet_result"]["missing_ok"]
        dt = DEFAULT_MANIFEST["dtype"]["float32_scope_ok"]
        tl = DEFAULT_MANIFEST["telemetry"]["unvalidated_families_ok"]
        for table in (*ev.values(), *fr.values(), *dt.values(), tl):
            for reason in table.values():
                assert isinstance(reason, str) and reason.strip()


class TestCli:
    def _run(self, args, cwd):
        env = dict(os.environ)
        env["PYTHONPATH"] = str(SRC)
        return subprocess.run(
            [sys.executable, "-m", "repro.analysis", *args],
            capture_output=True,
            text=True,
            env=env,
            cwd=cwd,
        )

    def test_clean_dir_exit_zero_with_json(self, tmp_path):
        _write(tmp_path, "pkg/ok.py", "x = 1\n")
        out = tmp_path / "report.json"
        res = self._run([str(tmp_path / "pkg"), "--json", str(out)], tmp_path)
        assert res.returncode == 0, res.stderr
        report = json.loads(out.read_text())
        assert report["schema"] == "repro.simlint/report-v1"
        assert report["findings"] == []
        assert report["manifest"]["schema"] == "repro.simlint/manifest-v1"
        assert {r["name"] for r in report["rules"]} >= {"engine-parity"}

    def test_violating_dir_exit_one(self, tmp_path):
        _write(
            tmp_path,
            "pkg/bad.py",
            "class S:\n    def f(self):\n        self.tracer.emit(A, 1)\n",
        )
        out = tmp_path / "report.json"
        res = self._run([str(tmp_path / "pkg"), "--json", str(out)], tmp_path)
        assert res.returncode == 1
        report = json.loads(out.read_text())
        assert len(report["findings"]) == 1
        assert report["findings"][0]["rule"] == "guard-discipline"
        assert "hint" in report["findings"][0]

    def test_list_rules(self, tmp_path):
        res = self._run(["--list-rules"], tmp_path)
        assert res.returncode == 0
        assert "guard-discipline" in res.stdout
        assert "event-schema" in res.stdout

    def test_manifest_dump(self, tmp_path):
        res = self._run(["--manifest"], tmp_path)
        assert res.returncode == 0
        blob = json.loads(res.stdout)
        assert blob["schema"] == "repro.simlint/manifest-v1"
        assert set(blob["counters"]) == {
            "preemption_count",
            "rejection_count",
            "truncation_count",
        }
