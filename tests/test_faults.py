"""Fault injection + failure recovery (:mod:`repro.sim.faults`).

Unit tests for the spec/schedule/policy layer plus fleet-level behavior:
crash/OOM/slowdown disposition, retry/timeout/shed accounting, circuit
breakers and health-gated routing, availability, fault-off bit-identity,
and the telemetry-v2 health columns. Cross-backend equivalence of the
fault semantics lives in ``tests/test_vector_engine.py``
(``TestFaultEquivalence``); this file pins the *semantics* on the
reference backend and the guard discipline on both.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.pools import PoolConfig
from repro.core.router import Request
from repro.obs import TelemetryConfig, validate_telemetry
from repro.sim import FaultInjector, FaultSpec, FleetSim, RetryPolicy, run_fleet
from repro.sim.faults import _unit_hash
from repro.sim.timing import TimingModel

#: Dyadic constants (as in test_vector_engine): every event time is an
#: exact binary float, so cross-run comparisons can demand equality.
DYADIC = TimingModel("dyadic", w_base=2**-10, h_per_seq=2**-13, prefill_chunk=512)


def poisson_trace(n, rate, seed, *, l_in=(16, 3000), l_out=(1, 400)):
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n))
    return [
        Request(
            request_id=i,
            byte_len=int(rng.integers(4, 12_000)),
            max_output_tokens=int(rng.integers(*l_out)),
            category=int(rng.integers(0, 4)),
            arrival_time=float(arrivals[i]),
            true_input_tokens=int(rng.integers(*l_in)),
            true_output_tokens=int(rng.integers(*l_out)),
        )
        for i in range(n)
    ]


CFG = PoolConfig("p", 4096, 16)


def run_pool(trace, *, backend="reference", instances=4, injector=None,
             policy=None, telemetry=None):
    sim = FleetSim(
        {CFG.name: (CFG, instances)},
        DYADIC,
        backend=backend,
        coalesce_dt=0.0,
        injector=injector,
        retry_policy=policy,
        telemetry=telemetry,
    )
    return sim, sim.run(trace)


def all_tuples(sim, res):
    pool = sorted(
        (r.request_id, r.arrival, r.first_token, r.finish,
         r.output_tokens, r.preemptions, r.truncated, r.rejected)
        for p in sim.pools.values() for r in p.records
    )
    fails = sorted((r.request_id, r.arrival, r.finish) for r in res.fail_records)
    return pool, fails


class TestFaultSpec:
    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultSpec("powercut", "p")

    def test_negative_times_rejected(self):
        with pytest.raises(ValueError):
            FaultSpec("crash", "p", t=-1.0)
        with pytest.raises(ValueError):
            FaultSpec("crash", "p", duration=-0.1)

    def test_slowdown_needs_positive_factor(self):
        with pytest.raises(ValueError):
            FaultSpec("slowdown", "p", factor=0.0)

    def test_evict_frac_bounds(self):
        with pytest.raises(ValueError):
            FaultSpec("oom", "p", evict_frac=0.0)
        with pytest.raises(ValueError):
            FaultSpec("oom", "p", evict_frac=1.5)
        FaultSpec("oom", "p", evict_frac=1.0)  # inclusive upper bound


class TestInjectorCompile:
    def test_transitions_time_ordered(self):
        inj = FaultInjector(
            (
                FaultSpec("slowdown", "p", 0, t=2.0, duration=1.0, factor=2.0),
                FaultSpec("crash", "p", 1, t=0.5, duration=1.0, warmup=0.5),
                FaultSpec("oom", "p", 2, t=1.0),
            )
        )
        trs = inj.compile(["p"], [4])
        assert [t.t for t in trs] == sorted(t.t for t in trs)
        actions = [(t.t, t.action, t.instance) for t in trs]
        # crash at 0.5 → recover (warm) at 1.5 → warm end at 2.0
        assert (0.5, "crash", 1) in actions
        assert (1.5, "recover", 1) in actions
        assert (2.0, "slow_end", 1) in actions
        assert (1.0, "oom", 2) in actions
        assert (2.0, "slow", 0) in actions and (3.0, "slow_end", 0) in actions

    def test_unknown_pool_rejected(self):
        with pytest.raises(ValueError, match="unknown pool"):
            FaultInjector((FaultSpec("crash", "nope"),)).compile(["p"], [4])

    def test_instance_out_of_range_rejected(self):
        with pytest.raises(ValueError, match="instance"):
            FaultInjector((FaultSpec("crash", "p", instance=4),)).compile(["p"], [4])

    def test_stochastic_seed_determinism(self):
        kw = dict(horizon=10.0, rate=1.0)
        a = FaultInjector.stochastic({"p": 4, "q": 2}, seed=5, **kw)
        b = FaultInjector.stochastic({"p": 4, "q": 2}, seed=5, **kw)
        c = FaultInjector.stochastic({"p": 4, "q": 2}, seed=6, **kw)
        assert a.specs == b.specs
        assert a.specs != c.specs
        for s in a.specs:
            assert s.pool in ("p", "q")
            assert 0.0 <= s.t <= 10.0
        # schedules compile against the target fleet without error
        a.compile(["p", "q"], [4, 2])


class TestRetryPolicy:
    def test_backoff_doubles_then_caps(self):
        pol = RetryPolicy(base_backoff=0.1, max_backoff=0.4, jitter=0.0)
        assert [pol.backoff(7, a) for a in (1, 2, 3, 4)] == [0.1, 0.2, 0.4, 0.4]

    def test_jitter_bounded_and_deterministic(self):
        pol = RetryPolicy(base_backoff=0.1, max_backoff=10.0, jitter=0.5, seed=3)
        for rid in range(20):
            b = pol.backoff(rid, 1)
            assert 0.1 <= b < 0.1 * 1.5
            assert b == pol.backoff(rid, 1)  # pure function of (seed, rid, attempt)
        # distinct requests get distinct jitter (hash actually mixes)
        assert len({pol.backoff(rid, 1) for rid in range(20)}) > 10

    def test_unit_hash_range(self):
        us = [_unit_hash(0, i, 1) for i in range(100)]
        assert all(0.0 <= u < 1.0 for u in us)

    def test_invalid_policy_rejected(self):
        with pytest.raises(ValueError):
            RetryPolicy(max_retries=-1)
        with pytest.raises(ValueError):
            RetryPolicy(base_backoff=0.5, max_backoff=0.1)


class TestFleetFaults:
    def test_crash_requeue_completes_everything(self):
        """Re-queued in-flight work finishes: no losses, no failure records."""
        trace = poisson_trace(300, rate=150.0, seed=1)
        inj = FaultInjector(
            (FaultSpec("crash", "p", instance=0, t=0.5, duration=0.25, requeue=True),)
        )
        sim, res = run_pool(trace, injector=inj)
        assert res.instance_failures == 1
        assert res.retries == res.timeouts == res.shed == 0
        assert res.fail_records == []
        pool, _ = all_tuples(sim, res)
        assert len(pool) == len(trace)
        assert not any(rejected for *_, rejected in pool)
        assert res.availability < 1.0

    def test_crash_lost_retries_recover(self):
        trace = poisson_trace(300, rate=150.0, seed=1)
        inj = FaultInjector(
            (FaultSpec("crash", "p", instance=0, t=0.5, duration=0.25),)
        )
        pol = RetryPolicy(max_retries=3, base_backoff=2**-6, max_backoff=2**-3, jitter=0.0)
        sim, res = run_pool(trace, injector=inj, policy=pol)
        assert res.retries > 0
        assert res.shed == 0 and res.timeouts == 0
        pool, fails = all_tuples(sim, res)
        assert len(pool) == len(trace) and fails == []
        # retried requests keep their original arrival, so their TTFT spans
        # the backoff — some first_token must land after the crash instant
        retried_ttfts = [ft - arr for _, arr, ft, *_ in pool if ft > 0.5]
        assert retried_ttfts and max(retried_ttfts) > 2**-6

    def test_no_policy_sheds_lost_requests(self):
        trace = poisson_trace(300, rate=150.0, seed=1)
        inj = FaultInjector(
            (FaultSpec("crash", "p", instance=0, t=0.5, duration=0.25),)
        )
        sim, res = run_pool(trace, injector=inj)
        assert res.shed > 0
        assert len(res.fail_records) == res.shed
        assert all(r.pool == "fleet" and r.rejected for r in res.fail_records)
        # every submitted request is accounted for exactly once
        pool, fails = all_tuples(sim, res)
        assert len(pool) + len(fails) == len(trace)

    def test_retry_budget_exhaustion_sheds(self):
        """Repeated crashes keep destroying the same requests' in-flight
        work until their retry budgets run out (single pool — nowhere else
        to go)."""
        trace = poisson_trace(200, rate=400.0, seed=2)
        inj = FaultInjector(
            tuple(
                FaultSpec("crash", "p", instance=0, t=0.25 + 0.25 * k, duration=0.125)
                for k in range(8)
            )
        )
        pol = RetryPolicy(max_retries=1, base_backoff=2**-8, max_backoff=2**-8, jitter=0.0)
        _, res = run_pool(trace, instances=1, injector=inj, policy=pol)
        assert res.retries > 0
        assert res.shed > 0
        assert len(res.fail_records) == res.shed

    def test_timeout_deadline_drops(self):
        trace = poisson_trace(300, rate=150.0, seed=1)
        inj = FaultInjector(
            (FaultSpec("crash", "p", instance=0, t=0.5, duration=0.5),)
        )
        pol = RetryPolicy(
            max_retries=5, base_backoff=2**-2, max_backoff=2.0, jitter=0.0,
            timeout=0.25,
        )
        _, res = run_pool(trace, injector=inj, policy=pol)
        assert res.timeouts > 0
        assert len(res.fail_records) == res.timeouts + res.shed

    def test_oom_evicts_youngest_fraction(self):
        trace = poisson_trace(300, rate=300.0, seed=4)
        inj = FaultInjector(
            (FaultSpec("oom", "p", instance=1, t=0.5, evict_frac=0.5, requeue=True),)
        )
        sim, res = run_pool(trace, injector=inj)
        assert res.instance_failures == 1
        pool, fails = all_tuples(sim, res)
        assert len(pool) == len(trace) and fails == []
        assert res.availability == 1.0  # instance survives an OOM kill

    def test_slowdown_inflates_latency_only(self):
        trace = poisson_trace(300, rate=150.0, seed=1)
        _, base = run_pool(trace)
        inj = FaultInjector(
            (FaultSpec("slowdown", "p", instance=0, t=0.25, duration=1.0, factor=4.0),)
        )
        _, slow = run_pool(trace, injector=inj)
        assert slow.summary.completed == base.summary.completed
        assert slow.availability == 1.0
        assert slow.summary.makespan > base.summary.makespan

    def test_goodput(self):
        trace = poisson_trace(200, rate=100.0, seed=6)
        _, res = run_pool(trace)
        s = res.summary
        assert res.goodput() == pytest.approx((s.completed - s.truncated) / s.makespan)

    def test_retry_policy_requires_injector(self):
        with pytest.raises(ValueError, match="retry_policy"):
            FleetSim({CFG.name: (CFG, 1)}, DYADIC, retry_policy=RetryPolicy())

    @pytest.mark.parametrize("backend", ["reference", "vectorized"])
    def test_fault_off_bit_identical(self, backend):
        """`injector=None` and an empty injector take identical paths —
        the ISSUE's guard-discipline acceptance criterion."""
        trace = poisson_trace(400, rate=200.0, seed=7)
        s0, r0 = run_pool(trace, backend=backend)
        s1, r1 = run_pool(trace, backend=backend, injector=FaultInjector(()))
        assert dataclasses.asdict(r0.summary) == dataclasses.asdict(r1.summary)
        assert all_tuples(s0, r0) == all_tuples(s1, r1)
        assert (r1.retries, r1.timeouts, r1.shed, r1.instance_failures) == (0, 0, 0, 0)
        assert r1.availability == 1.0


class TestHealthGatedRouting:
    POOLS = {
        "short": (PoolConfig("short", 4096, 16, queue_limit=64), 2),
        "long": (PoolConfig("long", 16384, 8, queue_limit=64), 2),
    }

    def run_fleet_faults(self, trace, specs, policy=None, backend="reference", **kw):
        sim = FleetSim(
            dict(self.POOLS),
            DYADIC,
            b_short=2048,
            backend=backend,
            coalesce_dt=0.0,
            injector=FaultInjector(specs, **kw),
            retry_policy=policy,
        )
        return sim, sim.run(trace)

    def test_all_down_pool_is_skipped(self):
        """With every long-pool instance down, long-routed arrivals divert
        to the short pool (nearest feasible) instead of queueing on a dead
        pool — and return once the pool recovers."""
        trace = poisson_trace(400, rate=200.0, seed=9)
        specs = tuple(
            FaultSpec("crash", "long", instance=i, t=0.25, duration=0.5, requeue=True)
            for i in range(2)
        )
        sim, res = self.run_fleet_faults(trace, specs)
        n_records = sum(len(p.records) for p in sim.pools.values())
        assert n_records == len(trace) and res.fail_records == []
        # diverted traffic shows up as spills off the dead pool
        assert sim.router.spill_count > 0

    def test_breaker_trips_and_recovers(self):
        """Enough lost in-flight work inside the window trips the pool's
        breaker; routing avoids it during cooldown (spills), then resumes."""
        trace = poisson_trace(600, rate=300.0, seed=10)
        specs = (
            FaultSpec("crash", "long", instance=0, t=0.25, duration=0.125),
            FaultSpec("crash", "long", instance=1, t=0.3125, duration=0.125),
        )
        pol = RetryPolicy(max_retries=3, base_backoff=2**-6, max_backoff=2**-4, jitter=0.0)
        sim, res = self.run_fleet_faults(
            trace, specs, policy=pol,
            breaker_threshold=3, breaker_window=1.0, breaker_cooldown=0.25,
        )
        rt = sim._fault_rt
        assert max(rt.failures) >= 3  # breaker had cause to trip
        assert rt.is_open(1, 0.375)  # long pool open right after the losses
        assert not rt.is_open(1, 10.0)  # half-open well past cooldown
        n_records = sum(len(p.records) for p in sim.pools.values())
        assert n_records + len(res.fail_records) == len(trace)

    def test_blocked_frozenset_fast_path(self):
        trace = poisson_trace(100, rate=100.0, seed=11)
        sim, _ = self.run_fleet_faults(trace, ())
        # no faults ever fired: blocked() must stay on the None fast path
        assert sim._fault_rt.blocked(1e9) is None


class TestTelemetryV2:
    def test_v2_schema_with_health_columns(self):
        trace = poisson_trace(300, rate=150.0, seed=1)
        inj = FaultInjector(
            (FaultSpec("crash", "p", instance=0, t=0.5, duration=1.0, requeue=True),)
        )
        _, res = run_pool(
            trace, injector=inj, telemetry=TelemetryConfig(window=16)
        )
        doc = validate_telemetry(res.telemetry.to_dict())
        assert doc["schema"] == "repro.obs/telemetry-v2"
        cols = doc["columns"]
        for name in ("retries", "timeouts", "down.p", "failures.p", "breaker_open.p"):
            assert name in cols
        # the crash window is visible in the down gauge
        assert max(cols["down.p"]) == 1

    def test_v1_schema_without_injector(self):
        trace = poisson_trace(200, rate=150.0, seed=1)
        _, res = run_pool(trace, telemetry=TelemetryConfig(window=64))
        doc = validate_telemetry(res.telemetry.to_dict())
        assert doc["schema"] == "repro.obs/telemetry-v1"
        assert "retries" not in doc["columns"]

    def test_run_fleet_wrapper_passes_faults(self):
        trace = poisson_trace(200, rate=150.0, seed=1)
        res = run_fleet(
            trace,
            {CFG.name: (CFG, 4)},
            DYADIC,
            injector=FaultInjector(
                (FaultSpec("crash", "p", instance=0, t=0.5, duration=0.25, requeue=True),)
            ),
            retry_policy=RetryPolicy(),
        )
        assert res.instance_failures == 1
