"""Router + calibration: unit and property tests (Algorithm 1, §2)."""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import (
    CalibState,
    EmaCalibrator,
    PoolConfig,
    PoolSet,
    PoolState,
    Request,
    TokenBudgetRouter,
    init_state,
    jax_estimate_budget,
    jax_route_batch,
    jax_update_stream,
    long_pool,
    n_seq_for_cmax,
    short_pool,
)

settings.register_profile("fast", max_examples=30, deadline=None)
settings.load_profile("fast")


def make_router(b_short=8192, spillover=True, queue_limit=4):
    import dataclasses

    s_cfg = dataclasses.replace(short_pool(), queue_limit=queue_limit)
    return TokenBudgetRouter(
        PoolState(config=s_cfg),
        PoolState(config=long_pool()),
        b_short=b_short,
        spillover=spillover,
    )


class TestDispatch:
    def test_short_request_goes_short(self):
        r = make_router()
        d = r.route(Request(0, byte_len=400, max_output_tokens=64, category=0))
        assert d.pool == "short"

    def test_long_output_cap_goes_long(self):
        """'Short-prompt, long-generation' must go long (§2.1 'why total')."""
        r = make_router()
        d = r.route(Request(0, byte_len=800, max_output_tokens=8192, category=0))
        assert d.pool == "long"

    def test_hard_constraint_exceeds_short_cmax(self):
        r = make_router()
        d = r.route(
            Request(0, byte_len=10_000_000, max_output_tokens=16, category=0)
        )
        assert d.pool == "long" and not d.spilled

    def test_b_short_cannot_exceed_short_cmax(self):
        with pytest.raises(ValueError):
            TokenBudgetRouter(
                PoolState(config=short_pool()),
                PoolState(config=long_pool()),
                b_short=100_000,
            )

    def test_spillover_redirects_on_overload(self):
        r = make_router(queue_limit=2)
        r.short.queue_depth = 100  # overloaded
        d = r.route(Request(0, byte_len=400, max_output_tokens=16, category=0))
        assert d.pool == "long" and d.spilled

    def test_no_spillover_when_disabled(self):
        r = make_router(queue_limit=2, spillover=False)
        r.short.queue_depth = 100
        d = r.route(Request(0, byte_len=400, max_output_tokens=16, category=0))
        assert d.pool == "short"

    def test_spillover_respects_hard_constraint(self):
        """A long-pool request can never spill into a too-small short pool."""
        r = make_router(queue_limit=2)
        r.long.queue_depth = 10_000
        d = r.route(
            Request(0, byte_len=200_000, max_output_tokens=8192, category=0)
        )
        assert d.pool == "long"

    @given(
        byte_len=st.integers(1, 500_000),
        max_out=st.integers(1, 32_768),
        category=st.integers(0, 3),
    )
    def test_routing_invariant_no_spill(self, byte_len, max_out, category):
        """Without load, pool == short iff estimate ≤ B_short (Algorithm 1)."""
        r = make_router(spillover=False)
        est = r.calibrator.estimate_total_budget(byte_len, max_out, category)
        d = r.route(Request(0, byte_len, max_out, category))
        if est > r.short.config.c_max or est > r.b_short:
            assert d.pool == "long"
        else:
            assert d.pool == "short"
        assert d.estimated_total == est


class TestCalibration:
    def test_cold_start_ratio(self):
        c = EmaCalibrator()
        assert c.conservative_ratio(0) == 4.0

    def test_first_observation_replaces_prior(self):
        c = EmaCalibrator()
        c.observe(2000, 1000, 2)  # c_obs = 2.0
        assert c.ratio[2] == pytest.approx(2.0)

    @given(
        true_c=st.floats(1.0, 8.0),
        n=st.integers(30, 120),
    )
    def test_converges_to_true_ratio(self, true_c, n):
        c = EmaCalibrator()
        rng = np.random.default_rng(1)
        for _ in range(n):
            tokens = int(rng.integers(100, 4000))
            c.observe(int(round(tokens * true_c)), tokens, 0)
        assert abs(c.ratio[0] - true_c) / true_c < 0.05

    def test_conservative_bias_direction(self):
        """γσ>0 shifts the ratio down → token estimate up → safer pool."""
        c = EmaCalibrator()
        rng = np.random.default_rng(2)
        for _ in range(100):
            tokens = int(rng.integers(100, 4000))
            noisy = tokens * (4.0 + rng.normal(0, 0.8))
            c.observe(max(1, int(noisy)), tokens, 0)
        assert c.sigma[0] > 0
        assert c.conservative_ratio(0) < c.ratio[0]
        est_cons = c.estimate_input_tokens(10_000, 0)
        plain = int(np.ceil(10_000 / c.ratio[0]))
        assert est_cons >= plain

    def test_zero_prompt_tokens_ignored(self):
        c = EmaCalibrator()
        before = c.snapshot()
        c.observe(1000, 0, 0)
        assert c.snapshot() == before

    @given(
        obs=st.lists(
            st.tuples(
                st.integers(10, 100_000),  # bytes
                st.integers(1, 20_000),  # prompt tokens
                st.integers(0, 3),  # category
            ),
            min_size=1,
            max_size=60,
        )
    )
    def test_jax_matches_python(self, obs):
        """The vectorized JAX EMA is bit-for-bit the host-side algorithm."""
        py = EmaCalibrator()
        for b, p, k in obs:
            py.observe(b, p, k)
        st_ = jax_update_stream(
            init_state(),
            jnp.array([o[0] for o in obs], jnp.float32),
            jnp.array([o[1] for o in obs], jnp.float32),
            jnp.array([o[2] for o in obs], jnp.int32),
        )
        np.testing.assert_allclose(
            np.asarray(st_.ratio), np.asarray(py.ratio, np.float32), rtol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(st_.sigma), np.asarray(py.sigma, np.float32),
            rtol=1e-3, atol=1e-4,
        )

    @given(
        byte_lens=st.lists(st.integers(1, 300_000), min_size=1, max_size=40),
    )
    def test_jax_batch_routing_matches_host(self, byte_lens):
        n = len(byte_lens)
        max_out = [64] * n
        cats = [0] * n
        router = make_router(spillover=False)
        host = [
            router.route(Request(i, b, 64, 0)).pool == "long"
            for i, b in enumerate(byte_lens)
        ]
        pools, _ = jax_route_batch(
            init_state(),
            jnp.array(byte_lens, jnp.int32),
            jnp.array(max_out, jnp.int32),
            jnp.array(cats, jnp.int32),
        )
        np.testing.assert_array_equal(np.asarray(pools) == 1, host)


def _state(name, c_max, *, queue_limit=4):
    return PoolState(
        config=PoolConfig(
            name, c_max, n_seq_for_cmax(c_max, max_slots=64),
            queue_limit=queue_limit,
        )
    )


def make_three_pool_router(spillover=True, queue_limit=4):
    ps = PoolSet(
        [
            _state("p4k", 4096, queue_limit=queue_limit),
            _state("p16k", 16_384, queue_limit=queue_limit),
            _state("p64k", 65_536, queue_limit=queue_limit),
        ],
        [4096, 16_384],
    )
    return TokenBudgetRouter(pools=ps, spillover=spillover)


class TestPoolSet:
    def test_sorts_by_cmax(self):
        ps = PoolSet(
            [_state("big", 65_536), _state("small", 4096)], [4096]
        )
        assert ps.names == ["small", "big"]

    def test_threshold_count_must_match(self):
        with pytest.raises(ValueError):
            PoolSet([_state("a", 4096), _state("b", 65_536)], [1024, 2048])

    def test_thresholds_strictly_increasing(self):
        states = [_state("a", 4096), _state("b", 16_384), _state("c", 65_536)]
        with pytest.raises(ValueError):
            PoolSet(states, [4096, 4096])

    def test_threshold_bounded_by_pool_cmax(self):
        with pytest.raises(ValueError):
            PoolSet([_state("a", 4096), _state("b", 65_536)], [8192])

    def test_static_pool_boundaries(self):
        ps = PoolSet(
            [_state("a", 4096), _state("b", 16_384), _state("c", 65_536)],
            [4096, 16_384],
        )
        assert ps.static_pool(4096) == 0
        assert ps.static_pool(4097) == 1
        assert ps.static_pool(16_384) == 1
        assert ps.static_pool(16_385) == 2
        assert ps.static_pool(10**9) == 2

    def test_first_feasible_escalates(self):
        ps = PoolSet(
            [_state("a", 4096), _state("b", 16_384), _state("c", 65_536)],
            [4096, 16_384],
        )
        assert ps.first_feasible(0, 8000) == 1
        assert ps.first_feasible(0, 20_000) == 2
        assert ps.first_feasible(0, 10**9) == 2  # last pool catches all

    def test_spill_order_prefers_near_then_larger(self):
        states = [_state(f"p{k}", 2**12 << k) for k in range(4)]
        ps = PoolSet(states, [2**12, 2**13, 2**14])
        assert ps.spill_order(1) == [2, 0, 3]
        assert ps.spill_order(0) == [1, 2, 3]
        assert ps.spill_order(3) == [2, 1, 0]

    def test_set_threshold_reverts_on_invalid(self):
        ps = PoolSet(
            [_state("a", 4096), _state("b", 16_384), _state("c", 65_536)],
            [4096, 16_384],
        )
        with pytest.raises(ValueError):
            ps.set_threshold(0, 20_000)  # would cross B_2
        assert ps.thresholds.tolist() == [4096, 16_384]


class TestNPoolDispatch:
    def test_middle_pool_spills_to_larger_neighbour(self):
        r = make_three_pool_router()
        r.pools.states[1].queue_depth = 10_000  # p16k overloaded
        d = r.route(Request(0, byte_len=4, max_output_tokens=8000, category=0))
        assert d.pool == "p64k" and d.spilled

    def test_smallest_pool_spills_up(self):
        r = make_three_pool_router()
        r.pools.states[0].queue_depth = 10_000  # p4k overloaded
        d = r.route(Request(0, byte_len=4, max_output_tokens=100, category=0))
        assert d.pool == "p16k" and d.spilled

    def test_spill_skips_infeasible_smaller_pool(self):
        """A budget above p4k's window can only spill upward."""
        r = make_three_pool_router()
        r.pools.states[1].queue_depth = 10_000
        r.pools.states[2].queue_depth = 10_000  # p16k AND p64k overloaded
        d = r.route(Request(0, byte_len=4, max_output_tokens=8000, category=0))
        assert d.pool == "p16k" and not d.spilled  # nowhere feasible to go

    def test_no_spill_when_disabled(self):
        r = make_three_pool_router(spillover=False)
        r.pools.states[1].queue_depth = 10_000
        d = r.route(Request(0, byte_len=4, max_output_tokens=8000, category=0))
        assert d.pool == "p16k" and not d.spilled

    def test_route_decided_matches_route_counters(self):
        r = make_three_pool_router()
        reqs = [
            Request(i, byte_len=4, max_output_tokens=m, category=0)
            for i, m in enumerate((100, 5000, 20_000, 100, 8000))
        ]
        for req in reqs:
            r.route(req)
        r2 = make_three_pool_router()
        ids, budgets = r2.route_batch(
            [q.byte_len for q in reqs],
            [q.max_output_tokens for q in reqs],
            [q.category for q in reqs],
        )
        for pid, b in zip(ids, budgets):
            r2.route_decided(int(pid), int(b))
        assert r.routed == r2.routed

    def test_stats_shape_for_three_pools(self):
        r = make_three_pool_router()
        r.route(Request(0, byte_len=4, max_output_tokens=100, category=0))
        s = r.stats()
        assert set(s["routed"]) == {"p4k", "p16k", "p64k"}
        assert "short_fraction" not in s  # two-pool compat keys only at P=2
        assert sum(s["fractions"].values()) == pytest.approx(1.0)


class TestAdaptiveThreshold:
    """Error-driven threshold discovery (paper §7, beyond-paper feature)."""

    def _c(self, **kw):
        from repro.core.adaptive import AdaptiveThreshold

        return AdaptiveThreshold(b_short=8192, b_min=512, **kw)

    def test_errors_tighten_threshold(self):
        c = self._c()
        b = c.update(
            window_requests=100, short_errors=5, short_queue=0,
            short_instances=10, long_queue=0, long_instances=10,
        )
        assert b < 8192

    def test_short_overload_tightens(self):
        c = self._c()
        b = c.update(
            window_requests=100, short_errors=0, short_queue=500,
            short_instances=10, long_queue=2, long_instances=10,
        )
        assert b < 8192

    def test_quiet_window_relaxes_up_to_cmax(self):
        c = self._c()
        c.b_short = 4096
        for _ in range(20):
            c.update(
                window_requests=100, short_errors=0, short_queue=0,
                short_instances=10, long_queue=0, long_instances=10,
            )
        assert c.b_short == 8192  # clamped at short-pool C_max

    def test_never_below_floor(self):
        c = self._c()
        for _ in range(50):
            c.update(
                window_requests=100, short_errors=50, short_queue=1000,
                short_instances=1, long_queue=0, long_instances=10,
            )
        assert c.b_short >= 512


class TestAdaptiveController:
    """N-boundary AIMD over a PoolSet: clamp + ordering invariants."""

    def _two_pool(self, b=8192):
        from repro.core.adaptive import AdaptiveController

        ps = PoolSet([_state("short", 8192), _state("long", 65_536)], [b])
        return AdaptiveController(ps, b_min=512), ps

    def _three_pool(self, th=(4096, 16_384)):
        from repro.core.adaptive import AdaptiveController

        ps = PoolSet(
            [_state("p4k", 4096), _state("p16k", 16_384), _state("p64k", 65_536)],
            list(th),
        )
        return AdaptiveController(ps, b_min=512), ps

    @staticmethod
    def _quiet(p):
        return dict(errors=[0] * p, queues=[0] * p, instances=[10] * p)

    def test_errors_tighten_first_boundary(self):
        c, ps = self._two_pool()
        new = c.update(
            window_requests=100, errors=[5, 0], queues=[0, 0],
            instances=[10, 10],
        )
        assert new[0] < 8192
        assert list(ps.thresholds) == new  # applied to the live PoolSet
        assert len(c.history) == 1 and c.history[0].reason == "decrease"

    def test_quiet_window_relaxes_to_cmax(self):
        c, ps = self._two_pool(b=4096)
        for _ in range(20):
            c.update(window_requests=100, **self._quiet(2))
        assert int(ps.thresholds[0]) == 8192  # clamped at short C_max

    def test_floor_holds_under_sustained_errors(self):
        c, ps = self._two_pool()
        for _ in range(50):
            c.update(
                window_requests=100, errors=[50, 0], queues=[1000, 0],
                instances=[1, 10],
            )
        assert int(ps.thresholds[0]) >= 512

    @pytest.mark.parametrize("rounds", [1, 30])
    def test_three_pool_ordering_invariant(self, rounds):
        """Adversarial per-boundary pressure can never break
        B_1 < B_2 ≤ C_max,k (PoolSet would reject the vector)."""
        c, ps = self._three_pool()
        rng = np.random.default_rng(3)
        for _ in range(rounds):
            c.update(
                window_requests=100,
                errors=[int(rng.integers(0, 20)) for _ in range(3)],
                queues=[int(rng.integers(0, 2000)) for _ in range(3)],
                instances=[1 + int(rng.integers(0, 10)) for _ in range(3)],
            )
            th = list(ps.thresholds)
            assert th[0] < th[1]
            assert th[0] <= ps.configs[0].c_max
            assert th[1] <= ps.configs[1].c_max
            assert th[0] >= 512

    def test_three_pool_boundaries_move_independently(self):
        """Errors in the middle pool tighten B_2 without touching B_1."""
        c, ps = self._three_pool()
        new = c.update(
            window_requests=100, errors=[0, 10, 0], queues=[0, 0, 500],
            instances=[10, 10, 10],
        )
        assert new[0] == 4096
        assert new[1] < 16_384

    def test_decrease_cannot_cross_lower_boundary(self):
        """B_2 collapsing under sustained pressure stops strictly above
        B_1, preserving the middle pool's slice."""
        c, ps = self._three_pool(th=(4096, 5000))
        for _ in range(40):
            c.update(
                window_requests=100, errors=[0, 50, 0], queues=[0, 2000, 0],
                instances=[10, 1, 10],
            )
        th = list(ps.thresholds)
        assert th[0] == 4096
        assert th[1] == 4097  # pinned one above B_1

    def test_increase_cannot_cross_upper_boundary(self):
        """B_1 relaxing under quiet traffic stops strictly below B_2."""
        c, ps = self._three_pool(th=(3000, 3500))
        for _ in range(20):
            c.update(
                window_requests=100, errors=[0, 10, 0], queues=[0, 800, 0],
                instances=[10, 1, 10],
            )
        th = list(ps.thresholds)
        assert th[0] < th[1] <= 3500

    def test_empty_window_holds(self):
        c, ps = self._two_pool()
        before = list(ps.thresholds)
        c.update(window_requests=0, errors=[99, 0], queues=[999, 0],
                 instances=[1, 1])
        assert list(ps.thresholds) == before
        assert c.history == []

    def test_signal_length_mismatch_raises(self):
        c, _ = self._two_pool()
        with pytest.raises(ValueError):
            c.update(window_requests=100, errors=[1], queues=[0, 0],
                     instances=[1, 1])

    def test_unbound_controller_raises(self):
        from repro.core.adaptive import AdaptiveController

        c = AdaptiveController()
        with pytest.raises(RuntimeError):
            c.update(window_requests=100, errors=[0, 0], queues=[0, 0],
                     instances=[1, 1])

    def test_single_pool_bind_rejected(self):
        from repro.core.adaptive import AdaptiveController

        ps = PoolSet([_state("only", 8192)], [])
        with pytest.raises(ValueError):
            AdaptiveController(ps)

    def test_router_hot_path_sees_moves(self):
        """The router's inlined threshold alias tracks controller moves."""
        c, ps = self._two_pool()
        r = TokenBudgetRouter(pools=ps, spillover=False)
        d = r.route(Request(0, byte_len=4, max_output_tokens=5000, category=0))
        assert d.pool == "short"
        for _ in range(2):  # 8192 → 6144 → 4608
            c.update(window_requests=100, errors=[10, 0], queues=[0, 0],
                     instances=[10, 10])
        assert int(ps.thresholds[0]) < 5000
        d = r.route(Request(1, byte_len=4, max_output_tokens=5000, category=0))
        assert d.pool == "long"


class TestPoolSetSetThresholds:
    def test_atomic_replace(self):
        ps = PoolSet(
            [_state("a", 4096), _state("b", 16_384), _state("c", 65_536)],
            [2048, 8192],
        )
        ps.set_thresholds([1024, 4096])
        assert list(ps.thresholds) == [1024, 4096]

    def test_invalid_vector_restores_previous(self):
        ps = PoolSet([_state("a", 4096), _state("b", 65_536)], [2048])
        with pytest.raises(ValueError):
            ps.set_thresholds([100_000])  # exceeds pool-a C_max
        assert list(ps.thresholds) == [2048]

    def test_length_mismatch_rejected(self):
        ps = PoolSet([_state("a", 4096), _state("b", 65_536)], [2048])
        with pytest.raises(ValueError):
            ps.set_thresholds([1024, 2048])

    def test_mutates_in_place_for_aliases(self):
        ps = PoolSet([_state("a", 4096), _state("b", 65_536)], [2048])
        alias = ps._thresholds  # the router's hot-path view
        ps.set_thresholds([1500])
        assert alias == [1500]


class TestSaturatedSpillover:
    """Nearest-feasible spillover when every pool is saturated, and the
    exact-threshold boundary semantics the spill path must preserve."""

    def _boundary_request(self, rid, budget, max_out=16):
        # Cold-start conservative ratio is 4.0, so est = byte_len/4 + max_out
        # exactly when byte_len is a multiple of 4.
        return Request(
            rid, byte_len=4 * (budget - max_out), max_output_tokens=max_out,
            category=0,
        )

    def test_exact_threshold_boundary(self):
        """est == B_short routes short (bisect_left); est == B_short + 1
        routes long — locked on both sides of the boundary."""
        r = make_router(b_short=8192)
        at = r.route(self._boundary_request(0, 8192))
        above = r.route(self._boundary_request(1, 8193))
        assert at.estimated_total == 8192 and at.pool == "short"
        assert above.estimated_total == 8193 and above.pool == "long"

    def test_boundary_request_spills_when_short_saturated(self):
        r = make_router(b_short=8192, queue_limit=2)
        r.short.queue_depth = 100
        d = r.route(self._boundary_request(0, 8192))
        assert d.pool == "long" and d.spilled

    def test_all_pools_saturated_stays_on_target(self):
        """Degrade, don't drop: with every pool overloaded the request
        stays on its static target and no spill is counted."""
        r = make_router(queue_limit=2)
        r.short.queue_depth = 100
        r.long.queue_depth = 100_000
        d = r.route(self._boundary_request(0, 4096))
        assert d.pool == "short" and not d.spilled
        assert r.spill_count == 0

    def test_saturated_long_pool_never_spills_down_infeasible(self):
        """A saturated long pool can't dump an over-budget request into the
        short pool even when the short pool is idle."""
        r = make_router(b_short=8192, queue_limit=2)
        r.long.queue_depth = 100_000
        d = r.route(self._boundary_request(0, 50_000))
        assert d.pool == "long" and not d.spilled

    def test_blocked_pool_with_saturated_alternative(self):
        """Health-gating composes with saturation: a blocked short pool
        evacuates to long even when long is overloaded-but-feasible is
        false — nowhere healthy to go means stay on the original target."""
        r = make_router(queue_limit=2)
        req = self._boundary_request(0, 4096)
        # blocked short, healthy long → evacuate
        d = r.route(req, blocked=frozenset((0,)))
        assert d.pool == "long" and d.spilled
        # blocked short AND saturated long → degrade on the blocked target
        r2 = make_router(queue_limit=2)
        r2.long.queue_depth = 100_000
        d2 = r2.route(req, blocked=frozenset((0,)))
        assert d2.pool == "short" and not d2.spilled

    def test_blocked_evacuates_even_without_spillover(self):
        r = make_router(spillover=False)
        d = r.route(self._boundary_request(0, 4096), blocked=frozenset((0,)))
        assert d.pool == "long"


class TestSaturatedSpilloverFleet:
    """The saturation semantics above, end-to-end in BOTH DES backends."""

    @pytest.mark.parametrize("backend", ["reference", "vectorized"])
    def test_saturated_fleet_spills_and_degrades(self, backend):
        """An undersized short pool under sustained pressure: spillover
        fires, and once the long pool saturates too, requests degrade on
        the short pool instead of being dropped."""
        from repro.sim.fleet import FleetSim
        from repro.sim.timing import TimingModel

        dyadic = TimingModel(
            "dyadic", w_base=2**-10, h_per_seq=2**-13, prefill_chunk=512
        )
        rng = np.random.default_rng(31)
        arrivals = np.cumsum(rng.exponential(1.0 / 2000.0, 600))
        trace = [
            Request(
                request_id=i,
                byte_len=int(rng.integers(4, 8000)),
                max_output_tokens=int(rng.integers(32, 256)),
                category=0,
                arrival_time=float(arrivals[i]),
                true_input_tokens=int(rng.integers(16, 2000)),
                true_output_tokens=int(rng.integers(32, 256)),
            )
            for i in range(600)
        ]
        pools = {
            "short": (PoolConfig("short", 4096, 16, queue_limit=1), 1),
            "long": (PoolConfig("long", 16384, 8, queue_limit=1), 1),
        }
        sim = FleetSim(
            dict(pools), dyadic, b_short=2048, backend=backend, coalesce_dt=0.0
        )
        res = sim.run(trace)
        assert sim.router.spill_count > 0  # spillover actually fired
        n_records = sum(len(p.records) for p in sim.pools.values())
        assert n_records == len(trace)  # degrade path drops nothing
        assert sum(sim.router.routed.values()) == len(trace)

    @pytest.mark.parametrize("backend", ["reference", "vectorized"])
    def test_exact_boundary_routing_in_fleet(self, backend):
        """Budgets exactly at / one past B_short land on opposite sides of
        the boundary in both backends (cold-start calibrator: all requests
        arrive before any completion can update the EMA)."""
        from repro.sim.fleet import FleetSim
        from repro.sim.timing import TimingModel

        dyadic = TimingModel(
            "dyadic", w_base=2**-10, h_per_seq=2**-13, prefill_chunk=512
        )
        b = 2048
        trace = []
        for i in range(8):
            budget = b if i % 2 == 0 else b + 1
            trace.append(
                Request(
                    request_id=i,
                    byte_len=4 * (budget - 16),
                    max_output_tokens=16,
                    category=0,
                    arrival_time=i * 2**-10,  # all before the first completion
                    true_input_tokens=64,
                    true_output_tokens=8,
                )
            )
        pools = {
            "short": (PoolConfig("short", 4096, 16, queue_limit=64), 2),
            "long": (PoolConfig("long", 16384, 8, queue_limit=64), 2),
        }
        sim = FleetSim(
            dict(pools), dyadic, b_short=b, backend=backend, coalesce_dt=0.0
        )
        sim.run(trace)
        assert sim.router.routed == {"short": 4, "long": 4}
