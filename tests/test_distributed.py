"""Distribution utilities: axis rules, compressed collectives, fault logic."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import (
    DEFAULT_RULES,
    HealthMonitor,
    StepTimer,
    elastic_mesh,
    largest_mesh_shape,
    quantize_int8,
    dequantize_int8,
    make_compressed_grad_sync,
)
from repro.distributed.sharding import AxisRules


def one_device_mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


class TestAxisRules:
    def test_spec_basic(self):
        mesh = one_device_mesh()
        spec = DEFAULT_RULES.spec(("vocab", "embed"), mesh)
        assert spec == P("model", None)

    def test_missing_mesh_axis_drops(self):
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("model",))
        spec = DEFAULT_RULES.spec(("batch", "embed"), mesh)
        assert spec == P(None, None)  # ("pod","data") absent → replicated

    def test_duplicate_mesh_axis_degrades_to_replication(self):
        mesh = one_device_mesh()
        rules = AxisRules(rules=(("a", "model"), ("b", "model")))
        spec = rules.spec(("a", "b"), mesh)
        assert spec == P("model", None)  # second use dropped

    def test_unknown_logical_axis_replicates(self):
        mesh = one_device_mesh()
        assert DEFAULT_RULES.spec(("nonexistent",), mesh) == P(None)


class TestCompression:
    def test_quantize_roundtrip_error_bounded(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(128,)), jnp.float32)
        q, s = quantize_int8(x)
        err = jnp.abs(dequantize_int8(q, s) - x)
        assert float(err.max()) <= float(s) * 0.5 + 1e-7

    def test_error_feedback_is_unbiased_over_rounds(self):
        """Σ compressed ≈ Σ true when the residual is carried (EF-SGD)."""
        mesh = one_device_mesh()
        sync = make_compressed_grad_sync(mesh, ("data",))
        rng = np.random.default_rng(1)
        err = {"w": jnp.zeros((64,), jnp.float32)}
        total_true = np.zeros((64,))
        total_comp = np.zeros((64,))
        for _ in range(50):
            g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
            mean, err = sync(g, err)
            total_true += np.asarray(g["w"])
            total_comp += np.asarray(mean["w"])
        # residual is bounded by one quantization step, so the running sums
        # track each other tightly
        drift = np.abs(total_comp - total_true).max()
        assert drift < 0.1

    def test_wire_bytes_reduction(self):
        x = jnp.asarray(np.random.default_rng(2).normal(size=(1024,)), jnp.float32)
        q, s = quantize_int8(x)
        assert q.dtype == jnp.int8  # 4× smaller than fp32 on the wire


class TestFault:
    def test_largest_mesh_shape(self):
        assert largest_mesh_shape(512, model_parallel=16) == (32, 16)
        assert largest_mesh_shape(496, model_parallel=16) == (31, 16)
        with pytest.raises(ValueError):
            largest_mesh_shape(8, model_parallel=16)

    def test_elastic_mesh_single_device(self):
        mesh = elastic_mesh(model_parallel=1)
        assert mesh.devices.size == jax.device_count()

    def test_health_monitor(self):
        hm = HealthMonitor(timeout_s=10)
        hm.heartbeat(0, now=100.0)
        hm.heartbeat(1, now=100.0)
        hm.heartbeat(2, now=95.0)
        assert sorted(hm.alive_hosts(now=104.0)) == [0, 1, 2]
        assert sorted(hm.alive_hosts(now=107.0)) == [0, 1]
        hm.mark_dead(1)
        assert sorted(hm.alive_hosts(now=104.0)) == [0, 2]

    def test_health_monitor_injectable_clock(self):
        """Sim-time replay: a injected clock makes alive_hosts deterministic
        with no ``now=`` arguments (the FaultRuntime drives it this way)."""
        t = [0.0]
        hm = HealthMonitor(timeout_s=10, clock=lambda: t[0])
        hm.heartbeat("a")
        t[0] = 9.0
        assert hm.alive_hosts() == ["a"]
        t[0] = 11.0
        assert hm.alive_hosts() == []

    def test_mark_dead_without_heartbeat(self):
        """A host declared dead before ever heartbeating must stay dead —
        and reappear in alive_hosts only after an explicit revive."""
        hm = HealthMonitor(timeout_s=10, clock=lambda: 0.0)
        hm.mark_dead("ghost")
        assert hm.alive_hosts() == []
        assert hm.dead_hosts() == ["ghost"]
        hm.revive("ghost")
        assert hm.alive_hosts() == ["ghost"]
        assert hm.dead_hosts() == []

    def test_revive_refreshes_heartbeat(self):
        hm = HealthMonitor(timeout_s=10, clock=lambda: 100.0)
        hm.heartbeat("a", now=0.0)  # stale
        hm.mark_dead("a")
        hm.revive("a", now=99.0)
        assert hm.alive_hosts() == ["a"]

    def test_step_timer_flags_stragglers(self):
        st = StepTimer(window=16, multiplier=2.0)
        for _ in range(16):
            assert not st.record(1.0)
        assert st.record(5.0)  # 5× median
        assert not st.record(1.1)
        assert st.straggler_rate > 0


class TestElasticResumeEndToEnd:
    def test_shrink_mesh_resume(self, tmp_path):
        """Train → checkpoint → 'lose' devices → rebuild mesh → resume.

        Single-host container: the re-mesh is 1→1 device, but the entire
        code path (checkpoint → elastic_mesh → restore with new shardings →
        continue training) is the production restart sequence.
        """
        from jax.sharding import NamedSharding
        from repro.checkpoint import Checkpointer
        from repro.configs import get_config
        from repro.distributed.sharding import tree_shardings
        from repro.models import Model
        from repro.training import (
            DataConfig, SyntheticLM, TrainConfig, init_train_state,
            make_train_step, opt_state_axes,
        )

        cfg = get_config("granite-3-8b").reduced()
        model = Model(cfg)
        tcfg = TrainConfig(total_steps=8, warmup_steps=1)
        step_fn, _ = make_train_step(model, tcfg)
        jstep = jax.jit(step_fn)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2))
        params, opt = init_train_state(model, tcfg, jax.random.key(1))
        for i in range(3):
            b = jax.tree.map(jnp.asarray, data.batch(i))
            params, opt, _ = jstep(params, opt, b, jnp.int32(i))
        ck = Checkpointer(str(tmp_path))
        ck.save(3, {"p": params, "o": opt})

        # simulated failure → new (smaller) mesh → restore with its shardings
        new_mesh = elastic_mesh(jax.devices(), model_parallel=1)
        p_sh = tree_shardings(model.axes(), new_mesh)
        o_sh = tree_shardings(opt_state_axes(model, tcfg), new_mesh)
        state, _ = ck.restore(
            {"p": params, "o": opt}, shardings={"p": p_sh, "o": o_sh}
        )
        p2, o2 = state["p"], state["o"]
        b = jax.tree.map(jnp.asarray, data.batch(3))
        p2, o2, m = jstep(p2, o2, b, jnp.int32(3))
        assert np.isfinite(float(m["loss"]))
