"""Scalar-vs-vectorized simulator equivalence (tentpole acceptance suite).

The vectorized struct-of-arrays backend must reproduce the scalar reference
engine's behaviour. For routerless (single-pool) fleets with ``coalesce_dt=0``
the two are *bit-identical* — completion/preemption/rejection totals, every
per-request record, and all latency percentiles — provided the timing
constants are dyadic (powers of two) so float accumulation is exact in both
engines. Two-pool routed fleets relax routing to per-epoch batches, so those
compare within tolerance.
"""

import numpy as np
import pytest

from repro.core.pools import PoolConfig, n_seq_for_cmax
from repro.core.router import Request
from repro.sim import A100_LLAMA3_70B, plan_fleet, profile_pool
from repro.sim.fleet import FleetSim, run_fleet
from repro.sim.timing import TimingModel
from repro.traces import TraceSpec, generate_trace, generate_trace_columns

#: Dyadic constants: W, H, and every accumulated event time are exact
#: binary floats, so `now + k*t_iter` (vector) == repeated addition (scalar).
DYADIC = TimingModel("dyadic", w_base=2**-10, h_per_seq=2**-13, prefill_chunk=512)

SUMMARY_FIELDS = (
    "num_requests",
    "completed",
    "rejected",
    "truncated",
    "preemptions",
    "ttft_p50",
    "ttft_p99",
    "tpot_p50",
    "tpot_p99",
    "makespan",
)


def poisson_trace(n, rate, seed, *, l_in=(16, 3000), l_out=(1, 400)):
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n))
    return [
        Request(
            request_id=i,
            byte_len=int(rng.integers(4, 12_000)),
            max_output_tokens=int(rng.integers(*l_out)),
            category=int(rng.integers(0, 4)),
            arrival_time=float(arrivals[i]),
            true_input_tokens=int(rng.integers(*l_in)),
            true_output_tokens=int(rng.integers(*l_out)),
        )
        for i in range(n)
    ]


def run_single_pool(trace, config, instances, backend, *, total_blocks=None):
    sim = FleetSim(
        {config.name: (config, instances)},
        DYADIC,
        backend=backend,
        coalesce_dt=0.0,  # exact event ordering
    )
    if total_blocks is not None:
        pool = sim.pools[config.name]
        if backend == "reference":
            for inst in pool.instances:
                inst.total_blocks = total_blocks
                inst.blocks_free = total_blocks
        else:
            pool.total_blocks = total_blocks
            pool.blocks_free[:] = total_blocks
    return sim, sim.run(trace)


def record_tuples(result, sim):
    if result.records is not None:
        recs = result.records
    else:
        recs = [r for p in sim.pools.values() for r in p.records]
    return sorted(
        (
            r.request_id,
            r.arrival,
            r.first_token,
            r.finish,
            r.output_tokens,
            r.preemptions,
            r.truncated,
            r.rejected,
        )
        for r in recs
    )


class TestExactEquivalence:
    def test_seeded_trace_identical(self):
        """Same seeded trace → identical totals, percentiles, and records."""
        trace = poisson_trace(1500, rate=250.0, seed=11)
        cfg = PoolConfig("p", 4096, 16)
        ref_sim, ref = run_single_pool(trace, cfg, 4, "reference")
        vec_sim, vec = run_single_pool(trace, cfg, 4, "vectorized")
        for f in SUMMARY_FIELDS:
            assert getattr(ref.summary, f) == getattr(vec.summary, f), f
        assert ref.preemptions == vec.preemptions
        assert ref.rejections == vec.rejections
        assert record_tuples(ref, ref_sim) == record_tuples(vec, vec_sim)

    def test_adversarial_kv_pressure_trace(self):
        """Tiny block budget: constant preemption + mid-generation truncation
        must match the reference engine decision-for-decision."""
        trace = poisson_trace(
            600, rate=400.0, seed=3, l_in=(16, 900), l_out=(50, 800)
        )
        cfg = PoolConfig("p", 1024, 8)
        ref_sim, ref = run_single_pool(
            trace, cfg, 3, "reference", total_blocks=90
        )
        vec_sim, vec = run_single_pool(
            trace, cfg, 3, "vectorized", total_blocks=90
        )
        # the trace actually exercises the adversarial paths
        assert ref.preemptions > 100
        assert ref.summary.truncated > 50
        for f in SUMMARY_FIELDS:
            assert getattr(ref.summary, f) == getattr(vec.summary, f), f
        assert ref.preemptions == vec.preemptions
        assert ref.rejections == vec.rejections
        # incremental truncation counters (the controller's error signal)
        # match each other and the canonical per-request records
        assert ref.truncations == vec.truncations > 0
        truncated_records = sum(
            1 for r in (ref.records or []) if r.truncated
        )
        assert ref.truncations == truncated_records
        assert record_tuples(ref, ref_sim) == record_tuples(vec, vec_sim)

    def test_rejections_identical(self):
        """Oversized prompts reject identically in both backends."""
        trace = poisson_trace(300, rate=100.0, seed=5, l_in=(16, 3000))
        cfg = PoolConfig("p", 1024, 8)  # prompts ≥ 1024 → submit-time reject
        ref_sim, ref = run_single_pool(trace, cfg, 2, "reference")
        vec_sim, vec = run_single_pool(trace, cfg, 2, "vectorized")
        assert ref.rejections == vec.rejections > 0
        assert record_tuples(ref, ref_sim) == record_tuples(vec, vec_sim)


def three_pool_topology(trace, rate):
    """4K/16K/64K pools sized analytically for this trace (oracle split)."""
    cfgs = (
        PoolConfig("p4k", 4096, n_seq_for_cmax(4096), headroom=1.05),
        PoolConfig("p16k", 16_384, n_seq_for_cmax(16_384), headroom=1.05),
        PoolConfig("p64k", 65_536, 16, headroom=1.02),
    )
    thresholds = [4096, 16_384]
    group = np.searchsorted(thresholds, [r.true_total for r in trace])
    pools = {}
    for k, cfg in enumerate(cfgs):
        members = [r for r, g in zip(trace, group) if g == k]
        prof = profile_pool(cfg.name, trace, members, cfg, A100_LLAMA3_70B, rate)
        pools[cfg.name] = (cfg, max(1, prof.instances))
    return pools, thresholds


class TestRoutedTolerance:
    """Routed fleets batch routing per epoch (calibration lags ≤ one
    epoch), so aggregate metrics agree within tolerance, not bit-for-bit —
    checked for both the classic short/long pair and the 4K/16K/64K
    three-pool topology."""

    @pytest.fixture(scope="class", params=["two_pool", "three_pool"])
    def results(self, request):
        n, rate = 4000, 400.0
        trace = generate_trace(
            TraceSpec(trace="azure", num_requests=n, rate=rate, seed=42)
        )
        if request.param == "two_pool":
            plan = plan_fleet("azure", trace, A100_LLAMA3_70B, rate)
            pools = {
                "short": (
                    PoolConfig("short", 8192, n_seq_for_cmax(8192), headroom=1.05),
                    plan.short.instances,
                ),
                "long": (
                    PoolConfig("long", 65_536, 16, headroom=1.02),
                    plan.long.instances,
                ),
            }
            thresholds = None
        else:
            pools, thresholds = three_pool_topology(trace, rate)
        ref = run_fleet(
            trace, pools, A100_LLAMA3_70B, backend="reference", thresholds=thresholds
        )
        vec = run_fleet(
            trace, pools, A100_LLAMA3_70B, backend="vectorized", thresholds=thresholds
        )
        return ref, vec

    def test_completion_totals_close(self, results):
        ref, vec = results
        assert ref.summary.num_requests == vec.summary.num_requests
        assert vec.summary.completed == pytest.approx(
            ref.summary.completed, rel=0.01
        )

    def test_latency_percentiles_close(self, results):
        ref, vec = results
        assert vec.summary.ttft_p99 == pytest.approx(
            ref.summary.ttft_p99, rel=0.15
        )
        assert vec.summary.tpot_p99 == pytest.approx(
            ref.summary.tpot_p99, rel=0.15
        )

    def test_routing_fractions_close(self, results):
        ref, vec = results
        for name, frac in ref.router_stats["fractions"].items():
            assert vec.router_stats["fractions"][name] == pytest.approx(
                frac, abs=0.02
            ), name

    def test_calibration_converges_both(self, results):
        for res in results:
            assert all(c > 0 for c in res.router_stats["calibration"]["count"])


class TestColumnarInput:
    """TraceColumns is the vectorized backend's native input; feeding the
    columns directly must be indistinguishable from feeding the
    materialized Request objects — on both backends."""

    @pytest.fixture(scope="class")
    def setup(self):
        cols = generate_trace_columns(
            TraceSpec(trace="azure", num_requests=1200, rate=120.0, seed=21)
        )
        plan = plan_fleet("azure", cols.to_requests(), A100_LLAMA3_70B, 120.0)
        pools = {
            "short": (
                PoolConfig("short", 8192, n_seq_for_cmax(8192), headroom=1.05),
                plan.short.instances,
            ),
            "long": (
                PoolConfig("long", 65_536, 16, headroom=1.02),
                plan.long.instances,
            ),
        }
        return cols, pools

    @pytest.mark.parametrize("backend", ["vectorized", "reference"])
    def test_columns_equal_objects(self, setup, backend):
        cols, pools = setup
        res_c = run_fleet(cols, pools, A100_LLAMA3_70B, backend=backend)
        res_o = run_fleet(
            cols.to_requests(), pools, A100_LLAMA3_70B, backend=backend
        )
        for f in SUMMARY_FIELDS:
            assert getattr(res_c.summary, f) == getattr(res_o.summary, f), f
        assert res_c.router_stats["routed"] == res_o.router_stats["routed"]


class TestControllerInTheLoop:
    """Closed-loop adaptive control must behave equivalently through both
    backends: same windows (request counts), same error contract
    (preemptions + rejections + truncations), boundary moves applied to
    the live PoolSet. The feedback loop amplifies the backends' epoch
    staleness, so aggregates compare within loose tolerance while the
    functional claims (controller fires, boundary tightens, thresholds
    stay valid) are exact."""

    @pytest.fixture(scope="class")
    def incident(self):
        """Undersized short pool (capacity incident) + controller."""
        from repro.core.adaptive import AdaptiveController

        n, rate = 2500, 250.0
        cols = generate_trace_columns(
            TraceSpec(trace="azure", num_requests=n, rate=rate, seed=42)
        )
        plan = plan_fleet("azure", cols.to_requests(), A100_LLAMA3_70B, rate)
        pools = {
            "short": (
                PoolConfig(
                    "short", 8192, n_seq_for_cmax(8192),
                    headroom=1.05, queue_limit=64,
                ),
                max(1, int(plan.short.instances * 0.6)),
            ),
            "long": (
                PoolConfig("long", 65_536, 16, headroom=1.02, queue_limit=64),
                plan.long.instances,
            ),
        }
        out = {}
        for backend in ("reference", "vectorized"):
            ctrl = AdaptiveController(b_min=512)
            sim = FleetSim(
                dict(pools), A100_LLAMA3_70B, b_short=8192, backend=backend,
                controller=ctrl, control_window=200,
            )
            trace = cols if backend == "vectorized" else cols.to_requests()
            out[backend] = (sim.run(trace), ctrl)
        return out

    def test_controller_fires_on_both_backends(self, incident):
        for backend, (_, ctrl) in incident.items():
            assert ctrl.history, backend
            assert ctrl.thresholds[0] < 8192, backend

    def test_thresholds_stay_valid_on_both_backends(self, incident):
        for backend, (_, ctrl) in incident.items():
            assert 512 <= ctrl.thresholds[0] <= 8192, backend

    def test_aggregates_close_across_backends(self, incident):
        ref, _ = incident["reference"]
        vec, _ = incident["vectorized"]
        assert ref.summary.num_requests == vec.summary.num_requests
        assert vec.summary.completed == pytest.approx(
            ref.summary.completed, rel=0.02
        )
        # the control loop compounds routing-epoch staleness: compare the
        # operating point loosely, direction is pinned by the tests above
        assert vec.summary.ttft_p99 == pytest.approx(
            ref.summary.ttft_p99, rel=0.5
        )

    def test_router_stats_report_moved_thresholds(self, incident):
        for backend, (res, ctrl) in incident.items():
            assert res.router_stats["thresholds"] == ctrl.thresholds, backend

    def test_controller_requires_multi_pool(self):
        from repro.core.adaptive import AdaptiveController

        with pytest.raises(ValueError):
            FleetSim(
                {"p": (PoolConfig("p", 4096, 16), 1)},
                A100_LLAMA3_70B,
                controller=AdaptiveController(),
            )


class TestCanonicalRecords:
    def test_no_double_counting(self):
        """Every submitted request appears exactly once in the canonical
        record list — completions and rejections never double-count."""
        trace = poisson_trace(500, rate=300.0, seed=9, l_in=(16, 2000))
        cfg = PoolConfig("p", 1024, 8)
        for backend in ("reference", "vectorized"):
            sim, res = run_single_pool(trace, cfg, 2, backend, total_blocks=120)
            recs = record_tuples(res, sim)
            ids = [r[0] for r in recs]
            assert len(ids) == len(set(ids)) == len(trace)
            assert res.summary.completed + res.summary.rejected == (
                res.summary.num_requests
            )

    def test_summary_built_from_canonical_records(self):
        trace = poisson_trace(400, rate=200.0, seed=13)
        cfg = PoolConfig("p", 4096, 8)
        sim, res = run_single_pool(trace, cfg, 2, "reference")
        assert res.records is not None
        from repro.sim.metrics import summarize

        rebuilt = summarize("fleet", res.records, total_spills=0)
        assert rebuilt == res.summary


class TestIncrementalPoolState:
    def test_counters_match_recompute_mid_run(self):
        """PoolState.queue_depth/active stay consistent with a full O(N)
        recompute at every step of a preemption-heavy run (O(1) dispatch)."""
        trace = poisson_trace(200, rate=500.0, seed=7, l_in=(16, 900), l_out=(50, 400))
        cfg = PoolConfig("p", 1024, 4)
        sim = FleetSim({"p": (cfg, 2)}, DYADIC, coalesce_dt=0.0)
        pool = sim.pools["p"]
        for inst in pool.instances:
            inst.total_blocks = 80
            inst.blocks_free = 80

        t = 0.0
        ti = iter(sorted(trace, key=lambda r: r.arrival_time))
        nxt = next(ti, None)
        for _ in range(5000):
            while nxt is not None and nxt.arrival_time <= t:
                pool.least_loaded().submit(nxt, nxt.arrival_time)
                nxt = next(ti, None)
            for inst in pool.instances:
                inst.step(t)
            assert pool.state.queue_depth == sum(
                len(i.queue) for i in pool.instances
            )
            assert pool.state.active == sum(
                len(i.active) for i in pool.instances
            )
            if nxt is None and all(i.idle for i in pool.instances):
                break
            t += DYADIC.iter_time(1)
        assert pool.preemptions > 0  # the run exercised preemption paths


def _sorted_events(telemetry):
    """Time-sorted event multiset — the cross-backend comparison key.

    Within one coalesced round the two backends walk instances in different
    orders (heap order vs row order), so raw emission order differs while
    the event *set* is identical; sorting by (t, kind, request_id, pool,
    value) makes the comparison order-insensitive without losing anything.
    """
    tr = telemetry.events
    idx = tr._order()
    return sorted(
        zip(
            tr.t[idx].tolist(),
            tr.kind[idx].tolist(),
            tr.request_id[idx].tolist(),
            tr.pool[idx].tolist(),
            tr.value[idx].tolist(),
        )
    )


class TestTelemetryEquivalence:
    """The observability layer inherits the backend-equivalence contract:
    exact-class runs (single pool, dyadic timing, ``coalesce_dt=0``) must
    produce *identical* telemetry columns and event multisets from both
    engines; routed fleets compare structurally (same windows, deltas that
    reconcile with the run counters) since routing itself is only
    tolerance-equivalent. Installing telemetry must never perturb the
    simulation."""

    WINDOW = 100

    def _run_single(self, trace, backend, telemetry):
        from repro.obs import TelemetryConfig

        cfg = PoolConfig("p", 4096, 16)
        sim = FleetSim(
            {"p": (cfg, 4)},
            DYADIC,
            backend=backend,
            coalesce_dt=0.0,
            telemetry=telemetry,
            control_window=self.WINDOW,
        )
        return sim.run(trace)

    @pytest.fixture(scope="class")
    def exact(self):
        from repro.obs import TelemetryConfig

        trace = poisson_trace(1500, rate=250.0, seed=11)
        tel = TelemetryConfig(window=self.WINDOW, events=True)
        ref = self._run_single(trace, "reference", tel)
        vec = self._run_single(trace, "vectorized", tel)
        return ref, vec

    def test_exact_class_columns_identical(self, exact):
        ref, vec = exact
        assert ref.telemetry.num_samples == vec.telemetry.num_samples > 0
        assert set(ref.telemetry.columns) == set(vec.telemetry.columns)
        for name in ref.telemetry.columns:
            assert np.array_equal(
                ref.telemetry.column(name),
                vec.telemetry.column(name),
                equal_nan=True,
            ), name

    def test_exact_class_event_multisets_identical(self, exact):
        ref, vec = exact
        a = _sorted_events(ref.telemetry)
        b = _sorted_events(vec.telemetry)
        assert len(a) == len(b) > 0
        assert a == b

    def test_telemetry_off_is_bit_identical(self):
        trace = poisson_trace(800, rate=250.0, seed=17)
        from repro.obs import TelemetryConfig

        for backend in ("reference", "vectorized"):
            plain = self._run_single(trace, backend, None)
            tele = self._run_single(
                trace, backend, TelemetryConfig(window=self.WINDOW, events=True)
            )
            for f in SUMMARY_FIELDS:
                assert getattr(plain.summary, f) == getattr(tele.summary, f), (
                    backend,
                    f,
                )
            assert plain.telemetry is None
            assert tele.telemetry is not None

    @pytest.fixture(scope="class", params=["two_pool", "three_pool"])
    def routed(self, request):
        from repro.obs import TelemetryConfig

        n, rate = 3000, 300.0
        trace = generate_trace(
            TraceSpec(trace="azure", num_requests=n, rate=rate, seed=42)
        )
        if request.param == "two_pool":
            plan = plan_fleet("azure", trace, A100_LLAMA3_70B, rate)
            pools = {
                "short": (
                    PoolConfig("short", 8192, n_seq_for_cmax(8192), headroom=1.05),
                    plan.short.instances,
                ),
                "long": (
                    PoolConfig("long", 65_536, 16, headroom=1.02),
                    plan.long.instances,
                ),
            }
            thresholds = None
        else:
            pools, thresholds = three_pool_topology(trace, rate)
        tel = TelemetryConfig(window=self.WINDOW, events=True)
        out = {}
        for backend in ("reference", "vectorized"):
            out[backend] = run_fleet(
                trace,
                pools,
                A100_LLAMA3_70B,
                backend=backend,
                thresholds=thresholds,
                telemetry=tel,
            )
        return out

    def test_routed_windows_align(self, routed):
        """Windows are counted in dispatched requests on both backends; the
        vectorized engine may overshoot a boundary by at most one dispatch
        chunk (documented in ``repro.obs``), so sample counts agree within
        the merge slack while the request axis itself is identical: both
        series are non-decreasing and end at the full dispatched count."""
        ref, vec = routed["reference"], routed["vectorized"]
        assert ref.telemetry.pool_names == vec.telemetry.pool_names
        for tel in (ref.telemetry, vec.telemetry):
            assert tel.num_samples > 0
            t_req = tel.column("t_req")
            assert np.all(np.diff(t_req) >= 0)
        assert (
            ref.telemetry.column("t_req")[-1]
            == vec.telemetry.column("t_req")[-1]
        )
        assert abs(ref.telemetry.num_samples - vec.telemetry.num_samples) <= 2

    def test_routed_deltas_reconcile_with_counters(self, routed):
        """Per-window deltas must sum to the run's end-of-run counters on
        each backend independently — no events lost between windows."""
        for backend, res in routed.items():
            tel = res.telemetry
            for fam, total in (
                ("preemptions", res.preemptions),
                ("rejections", res.rejections),
                ("truncations", res.truncations),
            ):
                sampled = sum(
                    tel.column(f"{fam}.{p}").sum() for p in tel.pool_names
                )
                assert sampled == total, (backend, fam)
            assert tel.column("spills").sum() == res.summary.spills, backend

    def test_routed_series_close(self, routed):
        """Cross-backend: the sampled error mass agrees within the routed
        tolerance (routing staleness shifts individual windows)."""
        ref, vec = routed["reference"], routed["vectorized"]
        for fam in ("preemptions", "truncations"):
            a = sum(
                ref.telemetry.column(f"{fam}.{p}").sum()
                for p in ref.telemetry.pool_names
            )
            b = sum(
                vec.telemetry.column(f"{fam}.{p}").sum()
                for p in vec.telemetry.pool_names
            )
            assert b == pytest.approx(a, rel=0.25, abs=20), fam

    def test_routed_exports_validate(self, routed):
        from repro.obs import (
            validate_chrome_trace,
            validate_events_jsonl,
            validate_telemetry,
        )

        for res in routed.values():
            validate_telemetry(res.telemetry.to_json())
            validate_events_jsonl(res.telemetry.events.to_jsonl())
            validate_chrome_trace(res.telemetry.events.to_chrome_trace())

    def test_threshold_column_tracks_controller(self):
        """The sampled ``threshold.0`` series replays the controller's
        move history exactly (post-move vector at each window)."""
        from repro.core.adaptive import AdaptiveController
        from repro.obs import TelemetryConfig

        n, rate = 2500, 250.0
        cols = generate_trace_columns(
            TraceSpec(trace="azure", num_requests=n, rate=rate, seed=42)
        )
        plan = plan_fleet("azure", cols.to_requests(), A100_LLAMA3_70B, rate)
        pools = {
            "short": (
                PoolConfig(
                    "short", 8192, n_seq_for_cmax(8192),
                    headroom=1.05, queue_limit=64,
                ),
                max(1, int(plan.short.instances * 0.6)),
            ),
            "long": (
                PoolConfig("long", 65_536, 16, headroom=1.02, queue_limit=64),
                plan.long.instances,
            ),
        }
        ctrl = AdaptiveController(b_min=512)
        sim = FleetSim(
            dict(pools), A100_LLAMA3_70B, b_short=8192, backend="vectorized",
            controller=ctrl, control_window=200,
            telemetry=TelemetryConfig(window=200, events=True),
        )
        res = sim.run(cols)
        assert ctrl.history  # the incident actually fired the controller
        tel = res.telemetry
        t_req = tel.column("t_req")
        th = tel.column("threshold.0")
        # replay: threshold at window [.., hi) is the vector after every
        # move with boundary index <= hi
        moves = {m.t: m.value for m in ctrl.history}
        expect, cur = [], 8192
        for hi in t_req:
            cur = moves.get(int(hi), cur)
            expect.append(cur)
        assert th.tolist() == expect
        # every move also landed in the event trace on the router track
        ev = [e for e in tel.events.events() if e["kind"] == "threshold_move"]
        assert len(ev) == len(ctrl.history)
        assert all(e["pool"] == "router" for e in ev)


class TestFaultEquivalence:
    """Fault semantics are backend-invariant (PR 7 acceptance classes).

    Single pool + dyadic timing + ``coalesce_dt=0`` keeps fault application
    bit-exact: identical SimSummary fields, fault counters, availability,
    per-request pool records, and fleet-level failure records for every
    fault kind and recovery path.
    """

    def _run(self, trace, backend, specs, policy=None, instances=4):
        from repro.sim.faults import FaultInjector

        cfg = PoolConfig("p", 4096, 16)
        sim = FleetSim(
            {cfg.name: (cfg, instances)},
            DYADIC,
            backend=backend,
            coalesce_dt=0.0,
            injector=FaultInjector(specs),
            retry_policy=policy,
        )
        return sim, sim.run(trace)

    def _assert_equal(self, trace, specs, policy=None, instances=4):
        ref_sim, ref = self._run(trace, "reference", specs, policy, instances)
        vec_sim, vec = self._run(trace, "vectorized", specs, policy, instances)
        for f in SUMMARY_FIELDS:
            assert getattr(ref.summary, f) == getattr(vec.summary, f), f
        for f in ("retries", "timeouts", "shed", "instance_failures"):
            assert getattr(ref, f) == getattr(vec, f), f
        assert ref.availability == vec.availability
        ref_pool = sorted(
            (r.request_id, r.arrival, r.first_token, r.finish,
             r.output_tokens, r.preemptions, r.truncated, r.rejected)
            for p in ref_sim.pools.values() for r in p.records
        )
        vec_pool = sorted(
            (r.request_id, r.arrival, r.first_token, r.finish,
             r.output_tokens, r.preemptions, r.truncated, r.rejected)
            for p in vec_sim.pools.values() for r in p.records
        )
        assert ref_pool == vec_pool
        ref_fail = sorted((r.request_id, r.arrival, r.finish) for r in ref.fail_records)
        vec_fail = sorted((r.request_id, r.arrival, r.finish) for r in vec.fail_records)
        assert ref_fail == vec_fail
        return ref, vec

    def test_crash_requeue(self):
        from repro.sim.faults import FaultSpec

        trace = poisson_trace(500, rate=250.0, seed=21)
        ref, _ = self._assert_equal(
            trace,
            (FaultSpec("crash", "p", instance=1, t=0.5, duration=0.25, requeue=True),),
        )
        assert ref.instance_failures == 1 and ref.availability < 1.0

    def test_crash_lost_with_retries(self):
        from repro.sim.faults import FaultSpec, RetryPolicy

        trace = poisson_trace(500, rate=250.0, seed=22)
        pol = RetryPolicy(
            max_retries=3, base_backoff=2**-6, max_backoff=2**-3, jitter=0.25, seed=1
        )
        ref, _ = self._assert_equal(
            trace,
            (FaultSpec("crash", "p", instance=0, t=0.5, duration=0.25),),
            policy=pol,
        )
        assert ref.retries > 0

    def test_crash_with_warmup_degradation(self):
        from repro.sim.faults import FaultSpec

        trace = poisson_trace(500, rate=250.0, seed=23)
        self._assert_equal(
            trace,
            (
                FaultSpec(
                    "crash", "p", instance=2, t=0.5, duration=0.25,
                    requeue=True, warmup=0.25, warmup_factor=2.0,
                ),
            ),
        )

    def test_oom_kill_both_dispositions(self):
        from repro.sim.faults import FaultSpec, RetryPolicy

        trace = poisson_trace(500, rate=300.0, seed=24)
        self._assert_equal(
            trace,
            (FaultSpec("oom", "p", instance=1, t=0.5, evict_frac=0.5, requeue=True),),
        )
        pol = RetryPolicy(max_retries=2, base_backoff=2**-6, max_backoff=2**-4, jitter=0.0)
        ref, _ = self._assert_equal(
            trace,
            (FaultSpec("oom", "p", instance=1, t=0.5, evict_frac=0.75),),
            policy=pol,
        )
        assert ref.retries > 0

    def test_slowdown_dyadic_factor(self):
        from repro.sim.faults import FaultSpec

        trace = poisson_trace(500, rate=250.0, seed=25)
        # dyadic factors keep t_iter * factor an exact binary float in both
        # the scalar multiply and the masked vector multiply
        for factor in (2.0, 1.5):
            self._assert_equal(
                trace,
                (FaultSpec("slowdown", "p", instance=0, t=0.25, duration=0.5,
                           factor=factor),),
            )

    def test_timeout_drops(self):
        from repro.sim.faults import FaultSpec, RetryPolicy

        trace = poisson_trace(400, rate=200.0, seed=26)
        pol = RetryPolicy(
            max_retries=5, base_backoff=2**-2, max_backoff=2.0, jitter=0.0,
            timeout=0.25,
        )
        ref, _ = self._assert_equal(
            trace,
            (FaultSpec("crash", "p", instance=0, t=0.5, duration=0.5),),
            policy=pol,
        )
        assert ref.timeouts > 0 and len(ref.fail_records) == ref.timeouts

    def test_overlapping_fault_storm(self):
        """Several faults on several instances, interleaved in time."""
        from repro.sim.faults import FaultSpec, RetryPolicy

        trace = poisson_trace(600, rate=300.0, seed=27)
        specs = (
            FaultSpec("crash", "p", instance=0, t=0.25, duration=0.25),
            FaultSpec("slowdown", "p", instance=1, t=0.375, duration=0.25, factor=2.0),
            FaultSpec("oom", "p", instance=2, t=0.5, evict_frac=0.5, requeue=True),
            FaultSpec("crash", "p", instance=3, t=0.625, duration=0.125, requeue=True),
        )
        pol = RetryPolicy(max_retries=2, base_backoff=2**-6, max_backoff=2**-4, jitter=0.5, seed=9)
        ref, _ = self._assert_equal(trace, specs, policy=pol)
        assert ref.instance_failures == 3


# ---------------------------------------------------------------------------
# Third backend: jitted jax event loop + vmapped grids
# ---------------------------------------------------------------------------


class TestJaxBackendEquivalence:
    """``backend="jax"`` joins the backend-equivalence contract: the
    compiled event loop must be *bit-identical* to both host engines on the
    exact class (routerless single pool, dyadic timing, ``coalesce_dt=0``)
    — including the adversarial KV-pressure trace that drives the shared
    order-free preemption rule hard."""

    def _triple(self, trace, cfg, instances, *, total_blocks=None):
        out = {}
        for backend in ("reference", "vectorized", "jax"):
            sim, res = run_single_pool(
                trace, cfg, instances, backend, total_blocks=total_blocks
            )
            out[backend] = (sim, res)
        return out

    def test_basic_three_way_identical(self):
        cfg = PoolConfig("p", 4096, 16)
        trace = poisson_trace(600, 220.0, 7, l_in=(16, 1200), l_out=(1, 200))
        runs = self._triple(trace, cfg, 3)
        ref_tuples = record_tuples(*reversed(runs["reference"]))
        for backend in ("vectorized", "jax"):
            sim, res = runs[backend]
            assert record_tuples(res, sim) == ref_tuples, backend
            for f in SUMMARY_FIELDS:
                assert getattr(res.summary, f) == getattr(
                    runs["reference"][1].summary, f
                ), (backend, f)

    def test_kv_pressure_three_way_identical(self):
        """Preemption/truncation heavy: tiny block pool forces constant
        victim selection; all three backends must agree bit-for-bit."""
        cfg = PoolConfig("p", 1024, 8)
        trace = poisson_trace(500, 400.0, 3, l_in=(16, 900), l_out=(1, 400))
        runs = self._triple(trace, cfg, 3, total_blocks=90)
        ref_sim, ref = runs["reference"]
        assert ref.preemptions > 100  # the trace exercises the hard path
        assert ref.summary.truncated > 50
        ref_tuples = record_tuples(ref, ref_sim)
        for backend in ("vectorized", "jax"):
            sim, res = runs[backend]
            assert record_tuples(res, sim) == ref_tuples, backend
            assert res.preemptions == ref.preemptions
            assert res.truncations == ref.truncations

    def test_submit_rejects_identical(self):
        cfg = PoolConfig("p", 1024, 8)  # prompts ≥ 1024 → submit-time reject
        trace = poisson_trace(300, 200.0, 5, l_in=(16, 2000), l_out=(1, 100))
        runs = self._triple(trace, cfg, 2)
        ref_tuples = record_tuples(*reversed(runs["reference"]))
        assert runs["reference"][1].rejections > 0
        for backend in ("vectorized", "jax"):
            sim, res = runs[backend]
            assert record_tuples(res, sim) == ref_tuples, backend
            assert res.rejections == runs["reference"][1].rejections

    def test_telemetry_windows_identical(self):
        """Replayed device window snapshots must reproduce the host
        backend's windowed time series exactly on the exact class."""
        from repro.obs import TelemetryConfig

        cfg = PoolConfig("p", 4096, 16)
        trace = poisson_trace(1500, 250.0, 11)
        tel = TelemetryConfig(window=100, events=False)
        res = {}
        for backend in ("vectorized", "jax"):
            sim = FleetSim(
                {"p": (cfg, 4)},
                DYADIC,
                backend=backend,
                coalesce_dt=0.0,
                telemetry=tel,
                control_window=100,
            )
            res[backend] = sim.run(trace)
        v, j = res["vectorized"].telemetry, res["jax"].telemetry
        assert v.num_samples == j.num_samples > 0
        assert set(v.columns) == set(j.columns)
        for name in v.columns:
            assert np.array_equal(
                v.column(name), j.column(name), equal_nan=True
            ), name

    def test_jax_rejects_fault_injection(self):
        from repro.sim.faults import FaultInjector, FaultSpec

        cfg = PoolConfig("p", 4096, 16)
        inj = FaultInjector((FaultSpec("crash", "p", instance=0, t=0.5),))
        with pytest.raises(ValueError, match="fault injection"):
            FleetSim({"p": (cfg, 2)}, DYADIC, backend="jax", injector=inj)

    def test_jax_rejects_event_tracing(self):
        from repro.obs import TelemetryConfig

        cfg = PoolConfig("p", 4096, 16)
        with pytest.raises(ValueError, match="event tracing"):
            FleetSim(
                {"p": (cfg, 2)},
                DYADIC,
                backend="jax",
                telemetry=TelemetryConfig(window=64, events=True),
            )


class TestJaxRoutedTolerance:
    """Routed fleets on the jax backend precompute EMA budgets host-side in
    arrival order (the device loop only does a searchsorted per dispatch),
    so routing is tolerance-equivalent to the host backends — same contract
    the vectorized backend has vs the reference engine. Spillover is not
    modeled on-device, so the host comparator runs with spillover off."""

    @pytest.fixture(scope="class")
    def results(self):
        n, rate = 4000, 400.0
        trace = generate_trace(
            TraceSpec(trace="azure", num_requests=n, rate=rate, seed=42)
        )
        plan = plan_fleet("azure", trace, A100_LLAMA3_70B, rate)
        pools = {
            "short": (
                PoolConfig("short", 8192, n_seq_for_cmax(8192), headroom=1.05),
                plan.short.instances,
            ),
            "long": (
                PoolConfig("long", 65_536, 16, headroom=1.02),
                plan.long.instances,
            ),
        }
        vec = run_fleet(
            trace, pools, A100_LLAMA3_70B, backend="vectorized", spillover=False
        )
        jx = run_fleet(
            trace, pools, A100_LLAMA3_70B, backend="jax", spillover=False
        )
        return vec, jx

    def test_completion_totals_close(self, results):
        vec, jx = results
        assert jx.summary.num_requests == vec.summary.num_requests
        assert jx.summary.completed == pytest.approx(
            vec.summary.completed, rel=0.01
        )

    def test_latency_percentiles_close(self, results):
        vec, jx = results
        assert jx.summary.ttft_p99 == pytest.approx(
            vec.summary.ttft_p99, rel=0.15
        )
        assert jx.summary.tpot_p99 == pytest.approx(
            vec.summary.tpot_p99, rel=0.15
        )

    def test_routing_fractions_close(self, results):
        vec, jx = results
        for name, frac in vec.router_stats["fractions"].items():
            assert jx.router_stats["fractions"][name] == pytest.approx(
                frac, abs=0.02
            ), name

    def test_every_request_accounted(self, results):
        vec, jx = results
        # every submitted request got exactly one routing decision, on both
        # backends (the summaries themselves discard the 20% warm-up)
        assert sum(jx.router_stats["routed"].values()) == 4000
        assert sum(vec.router_stats["routed"].values()) == 4000


class TestFleetGrid:
    """``run_fleet_grid`` vmaps whole fleet runs across threshold /
    instance-count / controller-gain axes. A grid lane must be bit-identical
    to the same configuration run through ``FleetSim(backend="jax")`` — the
    vmap axis cannot perturb the simulation."""

    @pytest.fixture(scope="class")
    def setup(self):
        from repro.sim.jax_engine import run_fleet_grid

        n, rate = 2000, 400.0
        trace = generate_trace(
            TraceSpec(trace="azure", num_requests=n, rate=rate, seed=42)
        )
        plan = plan_fleet("azure", trace, A100_LLAMA3_70B, rate)
        pools = {
            "short": (
                PoolConfig("short", 8192, n_seq_for_cmax(8192), headroom=1.05),
                plan.short.instances,
            ),
            "long": (
                PoolConfig("long", 65_536, 16, headroom=1.02),
                plan.long.instances,
            ),
        }
        grid = run_fleet_grid(
            trace,
            pools,
            A100_LLAMA3_70B,
            thresholds=[[2048], [4096], [8192]],
            return_records=True,
        )
        return trace, pools, grid

    def test_grid_lane_matches_single_run(self, setup):
        trace, pools, grid = setup
        sim = FleetSim(
            dict(pools), A100_LLAMA3_70B, backend="jax", spillover=False
        )
        res = sim.run(trace)
        k = 2  # thresholds [8192] == FleetSim's default b_short boundary
        single = {}
        for p in sim.pools.values():
            a = p.record_arrays()
            for j in range(len(a["request_id"])):
                single[int(a["request_id"][j])] = (
                    a["first_token"][j],
                    a["finish"][j],
                    int(a["output_tokens"][j]),
                    int(a["preemptions"][j]),
                    bool(a["truncated"][j]),
                    bool(a["rejected"][j]),
                )
        order = np.argsort([r.arrival_time for r in trace], kind="stable")
        ids = np.array([r.request_id for r in trace])[order]
        rec = grid.records
        for j, rid in enumerate(ids):
            got = (
                rec["first"][k, j],
                rec["finish"][k, j],
                int(rec["out"][k, j]),
                int(rec["pre"][k, j]),
                bool(rec["trunc"][k, j]),
                bool(rec["rej"][k, j]),
            )
            assert got == single[int(rid)], rid
        assert int(grid.routed[k, 0]) == res.router_stats["routed"]["short"]

    def test_threshold_axis_is_monotone_in_routing(self, setup):
        _, _, grid = setup
        # raising the boundary can only move requests short-ward
        short = grid.routed[:, 0]
        assert (np.diff(short) >= 0).all()
        assert (grid.routed.sum(axis=1) == len(grid.records["rej"][0])).all()

    def test_instance_and_gain_axes(self, setup):
        from repro.sim.jax_engine import run_fleet_grid

        trace, pools, _ = setup
        base = [ni for _, (_, ni) in sorted(
            pools.items(), key=lambda kv: kv[1][0].c_max
        )]
        shrunk = [max(1, base[0] - 2), base[1]]
        grid = run_fleet_grid(
            trace,
            pools,
            A100_LLAMA3_70B,
            thresholds=[[4096]],
            instances=[base, shrunk],
            gains=[None, {"decrease_factor": 0.5}],
        )
        assert len(grid) == 2
        # fewer instances → no more completions than the full fleet
        assert grid.completed[1] <= grid.completed[0]
        # uncontrolled lane never moves; controlled lane stays clamped
        assert grid.controller_moves[0] == 0
        assert (grid.final_thresholds[0] == 4096).all()
        b_min, c_max_short = 512, 8192
        assert b_min <= int(grid.final_thresholds[1][0]) <= c_max_short

    def test_bad_axis_length_raises(self, setup):
        from repro.sim.jax_engine import run_fleet_grid

        trace, pools, _ = setup
        with pytest.raises(ValueError, match="grid axis"):
            run_fleet_grid(
                trace,
                pools,
                A100_LLAMA3_70B,
                thresholds=[[2048], [4096]],
                gains=[None, None, None],
            )


class TestKernelCaching:
    """Routing/observe kernel specializations are cached by ``(name, …)``
    keys; a second run with the same shapes must not retrace anything."""

    def test_no_retrace_on_second_run(self):
        from repro.core.calibration import kernel_trace_counts

        cfg_s = PoolConfig("short", 8192, n_seq_for_cmax(8192), headroom=1.05)
        cfg_l = PoolConfig("long", 65_536, 16, headroom=1.02)
        trace = generate_trace(
            TraceSpec(trace="azure", num_requests=800, rate=200.0, seed=9)
        )
        pools = {"short": (cfg_s, 2), "long": (cfg_l, 2)}

        def one_run():
            return run_fleet(
                trace, pools, A100_LLAMA3_70B, backend="vectorized"
            )

        one_run()
        before = kernel_trace_counts()
        one_run()
        after = kernel_trace_counts()
        assert before  # kernels were exercised at all
        assert after == before  # …and never retraced


class TestDonatedBufferParity:
    """The compiled entries donate their record buffers
    (``donate_argnums``): every call allocates a fresh set via
    ``_fresh_records`` and the in-loop scatters write into them, so
    results must never depend on buffer history. Repeated runs and
    interleaved records/summary grid calls have to stay bit-identical —
    a stale or reused donated buffer would leak one run's completions
    into the next."""

    @pytest.fixture(scope="class")
    def fixture(self):
        cfg = PoolConfig("p", 4096, 16)
        trace = poisson_trace(400, 220.0, 13, l_in=(16, 1200), l_out=(1, 200))
        return cfg, trace

    def test_repeated_runs_bit_identical(self, fixture):
        cfg, trace = fixture
        base = None
        for _ in range(3):
            sim, res = run_single_pool(trace, cfg, 3, "jax")
            tuples = record_tuples(res, sim)
            if base is None:
                base = tuples
            assert tuples == base

    def test_interleaved_grid_record_modes(self, fixture):
        from repro.sim.jax_engine import run_fleet_grid

        _, trace = fixture
        pools = {
            "short": (PoolConfig("short", 2048, 8), 2),
            "long": (PoolConfig("long", 8192, 8), 2),
        }
        thresholds = [[512], [1536]]

        def grid(return_records):
            return run_fleet_grid(
                trace,
                pools,
                DYADIC,
                thresholds=thresholds,
                return_records=return_records,
            )

        with_rec = grid(True)
        summary_only = grid(False)
        again = grid(True)
        assert summary_only.records is None
        assert (with_rec.completed == summary_only.completed).all()
        assert (with_rec.completed == again.completed).all()
        for k, v in with_rec.records.items():
            assert np.array_equal(v, again.records[k], equal_nan=True), k


class TestCoalescedJumpEquivalence:
    """Event-coalesced k-jumps inside the compiled loop: the outer
    while iterates once per arrival epoch (fleet mode), so the surfaced
    iteration counter is bounded by n + 1 while rounds stay far below
    the token count a step-per-token loop would need — and coalescing
    must not perturb exact-class equivalence with either host engine."""

    def test_iters_bounded_and_exact(self):
        from repro.sim import jax_engine

        cfg = PoolConfig("p", 4096, 16)
        trace = poisson_trace(600, 220.0, 7, l_in=(16, 1200), l_out=(1, 200))
        runs = {}
        for backend in ("reference", "vectorized", "jax"):
            sim, res = run_single_pool(trace, cfg, 3, backend)
            runs[backend] = record_tuples(res, sim)
        assert runs["jax"] == runs["reference"] == runs["vectorized"]

        stats = jax_engine.last_run_stats()
        assert stats["mode"] == "fleet"
        n = len(trace)
        assert 0 < stats["iters"] <= n + 1
        total_tokens = sum(t[4] for t in runs["jax"])  # output_tokens
        assert stats["rounds"] >= stats["iters"]
        # coalesced jumps: rounds ≪ one-round-per-generated-token
        assert stats["rounds"] < total_tokens / 5

    def test_grid_iters_bounded(self):
        from repro.sim import jax_engine
        from repro.sim.jax_engine import run_fleet_grid

        trace = poisson_trace(300, 220.0, 3, l_in=(16, 1200), l_out=(1, 150))
        pools = {
            "short": (PoolConfig("short", 2048, 8), 2),
            "long": (PoolConfig("long", 8192, 8), 2),
        }
        run_fleet_grid(trace, pools, DYADIC, thresholds=[[512], [1536]])
        stats = jax_engine.last_run_stats()
        assert stats["mode"] == "grid"
        # grid lanes run one unconditional round per outer iteration, so
        # the iteration counter equals the slowest lane's round count and
        # the totals surface per-lane sums for benchmarking.
        assert stats["rounds"] == stats["iters"]
        assert stats["rounds_total"] <= stats["rounds"] * 2


class TestPallasEngineParity:
    """The Pallas decode-advance path (forced via ``_PALLAS_FORCE``)
    must be bit-identical to the vmapped jnp twin through a full engine
    run — same records, interpreter mode on CPU."""

    def test_forced_pallas_matches_jnp_engine(self):
        from repro.sim import jax_engine

        cfg = PoolConfig("p", 2048, 8)
        trace = poisson_trace(120, 150.0, 17, l_in=(16, 900), l_out=(1, 60))
        sim_j, res_j = run_single_pool(trace, cfg, 2, "jax")
        base = record_tuples(res_j, sim_j)
        jax_engine._PALLAS_FORCE = True
        try:
            sim_p, res_p = run_single_pool(trace, cfg, 2, "jax")
        finally:
            jax_engine._PALLAS_FORCE = None
        assert record_tuples(res_p, sim_p) == base
