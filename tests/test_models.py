"""Per-architecture smoke tests (reduced configs) + serving-path parity.

Every assigned arch: instantiate the REDUCED config, run one forward and
one train step on CPU, assert output shapes and no NaNs. Then check
prefill→decode parity (exact for non-MoE; decode==prefill for MoE, whose
capacity semantics legitimately differ from train mode).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, PAPER_MODELS, get_config
from repro.models import Model
from repro.training import TrainConfig, init_train_state, make_train_step

ARCH_IDS = [c.name for c in ASSIGNED]


def make_batch(cfg, B=2, L=32, *, train=True, seed=0):
    key = jax.random.key(seed)
    batch = {}
    if cfg.frontend == "tokens":
        batch["tokens"] = jax.random.randint(key, (B, L), 0, cfg.vocab)
    else:
        batch["embeds"] = (
            jax.random.normal(key, (B, L, cfg.d_model), jnp.float32) * 0.1
        ).astype(jnp.bfloat16)
    if cfg.pos_type == "mrope":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(L)[None, None], (3, B, L)
        ).astype(jnp.int32)
    if cfg.cross_attention:
        batch["memory"] = (
            jax.random.normal(key, (B, cfg.cross_mem_len, cfg.d_model)) * 0.1
        ).astype(jnp.bfloat16)
    if train:
        if cfg.n_codebooks > 0:
            batch["labels"] = jnp.zeros((B, L, cfg.n_codebooks), jnp.int32)
        else:
            batch["labels"] = jnp.zeros((B, L), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg, remat="full")
    B, L = 2, 32
    batch = make_batch(cfg, B, L)

    logits, aux = model.forward(model.init(jax.random.key(0)), batch)
    if cfg.n_codebooks > 0:
        assert logits.shape == (B, L, cfg.n_codebooks, cfg.padded_vocab)
    else:
        assert logits.shape == (B, L, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    tcfg = TrainConfig(total_steps=3, warmup_steps=1)
    train_step, _ = make_train_step(model, tcfg)
    params, opt_state = init_train_state(model, tcfg, jax.random.key(1))
    new_params, _, metrics = jax.jit(train_step)(
        params, opt_state, batch, jnp.int32(0)
    )
    assert np.isfinite(float(metrics["loss"]))
    # parameters actually moved
    moved = any(
        not jnp.array_equal(a, b)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_matches_forward_last_position(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, 2, 32, train=False)
    logits_full, _ = model.forward(params, batch)
    logits_pre, cache = model.prefill(params, batch)
    if cfg.is_moe:
        # capacity factors differ between train fwd and serving prefill;
        # parity is checked decode-vs-prefill below instead.
        return
    np.testing.assert_allclose(
        np.asarray(logits_pre, np.float32),
        np.asarray(logits_full[:, -1], np.float32),
        atol=1e-3,
    )
    assert cache is not None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_consistent_with_prefill(arch):
    """prefill(x[:L]) then decode == prefill(x[:L+1]) last logits."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    B, L = 2, 24
    full = make_batch(cfg, B, L, train=False, seed=2)

    # prefill over the full L tokens → reference last logits
    ref_logits, _ = model.prefill(params, full)

    # prefill L-1, pad caches to L, decode token L-1
    part = dict(full)
    if cfg.frontend == "tokens":
        part["tokens"] = full["tokens"][:, : L - 1]
    else:
        part["embeds"] = full["embeds"][:, : L - 1]
    if cfg.pos_type == "mrope":
        part["positions"] = full["positions"][:, :, : L - 1]
    _, cache = model.prefill(params, part)

    def pad(leaf):
        if (
            leaf.ndim == 5
            and leaf.shape[-1] == cfg.head_dim
            and leaf.shape[-2] == cfg.n_kv_heads
            and leaf.shape[-3] == L - 1
        ):
            pads = [(0, 0)] * leaf.ndim
            pads[-3] = (0, 1)
            return jnp.pad(leaf, pads)
        return leaf

    cache = jax.tree.map(pad, cache)
    dec = {"index": jnp.int32(L - 1)}
    if cfg.frontend == "tokens":
        dec["tokens"] = full["tokens"][:, L - 1 :]
    else:
        dec["embeds"] = full["embeds"][:, L - 1 :]
    if cfg.pos_type == "mrope":
        dec["positions"] = full["positions"][:, :, L - 1 :]
    dec_logits, _ = model.decode_step(params, cache, dec)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(ref_logits, np.float32),
        atol=2e-2,
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_specs_structure(arch):
    """cache_specs / cache_axes / init_cache agree structurally."""
    from repro.configs.base import ShapeCell

    cfg = get_config(arch).reduced()
    model = Model(cfg)
    cell = ShapeCell("t", "decode", 64, 2)
    specs = model.cache_specs(cell)
    axes = model.cache_axes(cell)
    assert jax.tree.structure(specs) == jax.tree.structure(
        axes,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(a, (str, type(None))) for a in x),
    )
    for leaf, ax in zip(
        jax.tree.leaves(specs),
        jax.tree.leaves(
            axes,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(a, (str, type(None))) for a in x),
        ),
    ):
        assert len(leaf.shape) == len(ax)


@pytest.mark.parametrize("arch", [c.name for c in PAPER_MODELS])
def test_paper_model_configs_instantiate(arch):
    cfg = get_config(arch)
    model = Model(cfg.reduced())
    batch = make_batch(cfg.reduced(), 1, 16)
    loss, metrics = model.loss(model.init(jax.random.key(0)), batch)
    assert np.isfinite(float(loss))


def test_full_config_param_counts():
    """Full (unreduced) parameter counts are in the published ballpark."""
    expected = {
        "gemma-2b": (2.0e9, 3.5e9),
        "granite-3-8b": (7.5e9, 9.0e9),
        "yi-6b": (5.5e9, 6.5e9),
        "granite-34b": (30e9, 36e9),
        "llama4-scout-17b-a16e": (90e9, 115e9),
        "llama4-maverick-400b-a17b": (380e9, 420e9),
        "qwen2-vl-7b": (6.5e9, 8.5e9),
        "musicgen-medium": (1.3e9, 2.3e9),
        "zamba2-2.7b": (2.3e9, 3.2e9),
        "xlstm-350m": (0.3e9, 0.5e9),
    }
    for name, (lo, hi) in expected.items():
        model = Model(get_config(name))
        n = model.param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params():
    m = Model(get_config("llama4-maverick-400b-a17b"))
    assert m.active_param_count() < 0.1 * m.param_count()
