"""Training substrate: optimizers, schedules, accumulation, data pipeline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.training import (
    AdamW,
    Adafactor,
    DataConfig,
    SyntheticLM,
    TrainConfig,
    clip_by_global_norm,
    cosine_schedule,
    init_train_state,
    make_train_step,
)


class TestOptimizers:
    def test_adamw_first_step_is_signed_lr(self):
        """With b1=b2 bias correction, step-1 update ≈ lr·sign(g) + wd."""
        opt = AdamW(weight_decay=0.0)
        p = {"w": jnp.array([1.0, -2.0])}
        g = {"w": jnp.array([0.5, -0.1])}
        state = opt.init(p)
        new_p, _ = opt.update(g, state, p, jnp.float32(0.1))
        np.testing.assert_allclose(
            np.asarray(new_p["w"]),
            np.asarray(p["w"]) - 0.1 * np.sign([0.5, -0.1]),
            rtol=1e-4,
        )

    def test_adamw_weight_decay_shrinks(self):
        opt = AdamW(weight_decay=0.1)
        p = {"w": jnp.array([10.0])}
        g = {"w": jnp.array([0.0])}
        s = opt.init(p)
        new_p, _ = opt.update(g, s, p, jnp.float32(0.1))
        assert float(new_p["w"][0]) < 10.0

    def test_adafactor_factored_shapes(self):
        opt = Adafactor()
        p = {"m": jnp.zeros((8, 16)), "v": jnp.zeros((4,))}
        s = opt.init(p)
        assert s.vr["m"].shape == (8,)
        assert s.vc["m"].shape == (16,)
        assert s.vr["v"].shape == (4,)

    def test_adafactor_reduces_loss_direction(self):
        opt = Adafactor()
        p = {"w": jnp.array([[2.0, -3.0]])}
        s = opt.init(p)
        for _ in range(5):
            g = {"w": p["w"]}  # grad of 0.5||w||²
            p, s = opt.update(g, s, p, jnp.float32(0.1))
        assert float(jnp.abs(p["w"]).sum()) < 5.0


class TestSchedule:
    def test_cosine_shape(self):
        lr0 = cosine_schedule(jnp.int32(0), peak_lr=1.0, warmup_steps=10, total_steps=100)
        lr_peak = cosine_schedule(jnp.int32(9), peak_lr=1.0, warmup_steps=10, total_steps=100)
        lr_end = cosine_schedule(jnp.int32(100), peak_lr=1.0, warmup_steps=10, total_steps=100)
        assert float(lr0) == pytest.approx(0.1)  # (0+1)/10 warmup
        assert float(lr_peak) == pytest.approx(1.0)
        assert float(lr_end) == pytest.approx(0.1, abs=1e-6)

    def test_clip_by_global_norm(self):
        tree = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert float(norm) == pytest.approx(5.0)
        total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
        assert float(total) == pytest.approx(1.0, rel=1e-5)


class TestTrainStep:
    def test_loss_decreases(self):
        cfg = get_config("granite-3-8b").reduced()
        model = Model(cfg, remat="full")
        tcfg = TrainConfig(total_steps=60, warmup_steps=5, peak_lr=3e-3)
        step_fn, _ = make_train_step(model, tcfg)
        params, opt_state = init_train_state(model, tcfg, jax.random.key(0))
        data = SyntheticLM(
            DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
        )
        jstep = jax.jit(step_fn, donate_argnums=(0, 1))
        losses = []
        for i in range(40):
            b = jax.tree.map(jnp.asarray, data.batch(i))
            params, opt_state, m = jstep(params, opt_state, b, jnp.int32(i))
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5

    def test_grad_accumulation_matches_full_batch(self):
        """microbatches=2 must equal the single-batch gradient step."""
        cfg = get_config("yi-6b").reduced()
        model = Model(cfg)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
        batch = jax.tree.map(jnp.asarray, data.batch(0))

        outs = {}
        for mb in (1, 2):
            tcfg = TrainConfig(total_steps=5, warmup_steps=0, microbatches=mb)
            step_fn, _ = make_train_step(model, tcfg)
            params, opt_state = init_train_state(model, tcfg, jax.random.key(3))
            p2, _, m = jax.jit(step_fn)(params, opt_state, batch, jnp.int32(1))
            outs[mb] = (p2, float(m["loss"]))
        for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[2][0])):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                atol=5e-3,
            )


class TestData:
    def test_deterministic_and_seekable(self):
        d = SyntheticLM(DataConfig(vocab=1000, seq_len=64, global_batch=4))
        a = d.batch(17)
        b = d.batch(17)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_labels_shifted(self):
        d = SyntheticLM(DataConfig(vocab=1000, seq_len=64, global_batch=2))
        b = d.batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_sharding_partitions(self):
        d = SyntheticLM(DataConfig(vocab=1000, seq_len=32, global_batch=8))
        s0 = d.batch(3, process_index=0, process_count=2)
        s1 = d.batch(3, process_index=1, process_count=2)
        assert s0["tokens"].shape == (4, 32)
        assert not np.array_equal(s0["tokens"], s1["tokens"])
