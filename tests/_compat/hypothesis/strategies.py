"""Strategy objects for the fallback ``hypothesis`` shim.

Each strategy exposes two methods used by ``given``:

* ``boundary_examples()`` — small list of deterministic edge values;
* ``example(rng)`` — one seeded-random draw.

Only the strategies our test-suite uses are implemented.
"""

from __future__ import annotations

import math
import random
from typing import Any, Sequence


class SearchStrategy:
    def boundary_examples(self) -> list:
        return [self.example(random.Random(0))]

    def example(self, rng: random.Random):  # pragma: no cover - interface
        raise NotImplementedError

    def map(self, fn) -> "SearchStrategy":
        return _Mapped(self, fn)

    def filter(self, pred) -> "SearchStrategy":
        return _Filtered(self, pred)


class _Mapped(SearchStrategy):
    def __init__(self, base: SearchStrategy, fn) -> None:
        self.base, self.fn = base, fn

    def boundary_examples(self) -> list:
        return [self.fn(x) for x in self.base.boundary_examples()]

    def example(self, rng: random.Random):
        return self.fn(self.base.example(rng))


class _Filtered(SearchStrategy):
    def __init__(self, base: SearchStrategy, pred) -> None:
        self.base, self.pred = base, pred

    def boundary_examples(self) -> list:
        return [x for x in self.base.boundary_examples() if self.pred(x)] or [
            self.example(random.Random(0))
        ]

    def example(self, rng: random.Random):
        for _ in range(1000):
            x = self.base.example(rng)
            if self.pred(x):
                return x
        raise ValueError("filter predicate rejected 1000 draws")


class _Integers(SearchStrategy):
    def __init__(self, min_value: int, max_value: int) -> None:
        self.lo, self.hi = int(min_value), int(max_value)

    def boundary_examples(self) -> list[int]:
        vals = {self.lo, self.hi, (self.lo + self.hi) // 2}
        return sorted(vals)

    def example(self, rng: random.Random) -> int:
        return rng.randint(self.lo, self.hi)


class _Floats(SearchStrategy):
    def __init__(self, min_value: float, max_value: float) -> None:
        self.lo, self.hi = float(min_value), float(max_value)

    def boundary_examples(self) -> list[float]:
        mid = 0.5 * (self.lo + self.hi)
        vals = []
        for v in (self.lo, mid, self.hi):
            if math.isfinite(v) and v not in vals:
                vals.append(v)
        return vals

    def example(self, rng: random.Random) -> float:
        return rng.uniform(self.lo, self.hi)


class _Booleans(SearchStrategy):
    def boundary_examples(self) -> list[bool]:
        return [False, True]

    def example(self, rng: random.Random) -> bool:
        return rng.random() < 0.5


class _Lists(SearchStrategy):
    def __init__(
        self,
        elements: SearchStrategy,
        *,
        min_size: int = 0,
        max_size: int = 10,
    ) -> None:
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10

    def boundary_examples(self) -> list[list]:
        rng = random.Random(1)
        out = [[self.elements.example(rng) for _ in range(self.min_size)]]
        if self.max_size > self.min_size:
            out.append(
                [self.elements.example(rng) for _ in range(self.max_size)]
            )
        return out

    def example(self, rng: random.Random) -> list:
        size = rng.randint(self.min_size, self.max_size)
        return [self.elements.example(rng) for _ in range(size)]


class _Tuples(SearchStrategy):
    def __init__(self, *parts: SearchStrategy) -> None:
        self.parts = parts

    def boundary_examples(self) -> list[tuple]:
        rng = random.Random(2)
        return [tuple(p.example(rng) for p in self.parts)]

    def example(self, rng: random.Random) -> tuple:
        return tuple(p.example(rng) for p in self.parts)


class _SampledFrom(SearchStrategy):
    def __init__(self, options: Sequence[Any]) -> None:
        self.options = list(options)

    def boundary_examples(self) -> list:
        return [self.options[0], self.options[-1]]

    def example(self, rng: random.Random):
        return rng.choice(self.options)


def integers(min_value: int = 0, max_value: int = 2**31 - 1) -> _Integers:
    return _Integers(min_value, max_value)


def floats(
    min_value: float = 0.0,
    max_value: float = 1.0,
    *,
    allow_nan: bool = False,
    allow_infinity: bool = False,
) -> _Floats:
    return _Floats(min_value, max_value)


def booleans() -> _Booleans:
    return _Booleans()


def lists(
    elements: SearchStrategy,
    *,
    min_size: int = 0,
    max_size: int = 10,
) -> _Lists:
    return _Lists(elements, min_size=min_size, max_size=max_size)


def tuples(*parts: SearchStrategy) -> _Tuples:
    return _Tuples(*parts)


def sampled_from(options: Sequence[Any]) -> _SampledFrom:
    return _SampledFrom(options)
