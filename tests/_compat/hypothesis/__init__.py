"""Minimal deterministic fallback for the ``hypothesis`` library.

Loaded by ``tests/conftest.py`` ONLY when the real ``hypothesis`` package is
not importable (the CI container does not ship it and the repo policy forbids
installing new dependencies). It implements exactly the surface our tests
use — ``given``, ``settings`` profiles, and the strategies in
:mod:`hypothesis.strategies` — by enumerating boundary values plus a
seeded-random sample instead of doing real property-based shrinking.

If the genuine library is installed it always wins; delete this package the
day ``hypothesis`` lands in the image.
"""

from __future__ import annotations

import functools
import inspect
import itertools
import random
import types
from typing import Any, Callable

from . import strategies  # noqa: F401  (re-export: hypothesis.strategies)

__all__ = ["given", "settings", "assume", "HealthCheck", "strategies"]

_IS_FALLBACK = True  # marker so conftest/tests can detect the shim


class HealthCheck:
    """No-op placeholder mirroring hypothesis.HealthCheck members."""

    all = staticmethod(lambda: [])
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"


class _Profile(dict):
    pass


class settings:  # noqa: N801 — mirrors hypothesis' lowercase class
    """Profile registry + decorator, mirroring ``hypothesis.settings``."""

    _profiles: dict[str, _Profile] = {"default": _Profile(max_examples=20)}
    _current: _Profile = _profiles["default"]

    def __init__(self, **kwargs: Any) -> None:
        self.kwargs = kwargs

    def __call__(self, fn: Callable) -> Callable:
        fn._hypo_settings = self.kwargs  # noqa: SLF001
        return fn

    @classmethod
    def register_profile(cls, name: str, **kwargs: Any) -> None:
        cls._profiles[name] = _Profile(**kwargs)

    @classmethod
    def load_profile(cls, name: str) -> None:
        cls._current = cls._profiles[name]

    @classmethod
    def max_examples(cls) -> int:
        return int(cls._current.get("max_examples", 20))


class _Assumption(Exception):
    pass


def assume(condition: bool) -> bool:
    """Skip the current example when its precondition does not hold."""
    if not condition:
        raise _Assumption()
    return True


def given(*arg_strategies: Any, **kw_strategies: Any) -> Callable:
    """Deterministic stand-in for ``hypothesis.given``.

    Runs the test with every combination of each strategy's boundary
    examples first, then pads to the active profile's ``max_examples`` with
    seeded-random draws, so failures reproduce across runs.
    """

    if arg_strategies:
        raise NotImplementedError(
            "hypothesis fallback shim supports keyword strategies only; "
            "write @given(x=st.integers(...)) instead of @given(st.integers(...))"
        )

    def deco(fn: Callable) -> Callable:
        @functools.wraps(fn)
        def wrapper(*args: Any, **kwargs: Any) -> None:
            # @settings may sit above @given (stamping the wrapper) or
            # below it (stamping fn) — honour both stacking orders.
            overrides = getattr(
                wrapper, "_hypo_settings", getattr(fn, "_hypo_settings", {})
            )
            n = int(overrides.get("max_examples", settings.max_examples()))
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            names = list(kw_strategies)
            strats = [kw_strategies[k] for k in names]

            examples: list[tuple] = []
            boundary_sets = [s.boundary_examples() for s in strats]
            for combo in itertools.islice(itertools.product(*boundary_sets), n):
                examples.append(combo)
            while len(examples) < n:
                examples.append(tuple(s.example(rng) for s in strats))

            for combo in examples[:n]:
                try:
                    fn(*args, **dict(kwargs, **dict(zip(names, combo))))
                except _Assumption:
                    continue

        # Parity with the real library: pytest plugins (e.g. anyio) probe
        # `fn.hypothesis.inner_test` to find the undecorated test.
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        # Hide the strategy-supplied parameters from pytest's fixture
        # resolution (the real library rewrites the signature the same way).
        wrapper.__dict__.pop("__wrapped__", None)
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(
            parameters=[
                p
                for p in sig.parameters.values()
                if p.name not in kw_strategies
            ]
        )
        return wrapper

    return deco
