"""Model building blocks: jnp flash attention, RoPE/M-RoPE, SSD, xLSTM."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import (
    apply_rope,
    decode_attention,
    flash_attention,
    mrope_angles,
    rms_norm,
    rope_angles,
)
from repro.models.ssm import ssd_chunked, ssd_step
from repro.models.xlstm import mlstm_chunked, mlstm_step, slstm_scan


def naive_attention(q, k, v, causal=True):
    B, L, H, D = q.shape
    K = k.shape[2]
    g = H // K
    qg = q.reshape(B, L, K, g, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(D))
    if causal:
        mask = jnp.tril(jnp.ones((L, L), bool))
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, L, H, D)


@pytest.mark.parametrize("mode", ["triangle", "masked"])
@pytest.mark.parametrize("H,K", [(8, 2), (4, 1), (4, 4)])
def test_flash_attention_value_and_grad(mode, H, K):
    rng = np.random.default_rng(0)
    B, L, D = 2, 192, 32
    q = jnp.asarray(rng.normal(size=(B, L, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, L, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, L, K, D)), jnp.float32)
    out = flash_attention(q, k, v, q_chunk=64, kv_chunk=64, causal_mode=mode)
    expect = naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)

    g = jax.grad(
        lambda q: flash_attention(
            q, k, v, q_chunk=64, kv_chunk=64, causal_mode=mode
        ).sum()
    )(q)
    g_ref = jax.grad(lambda q: naive_attention(q, k, v).sum())(q)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=2e-5)


def test_decode_attention_per_batch_lengths():
    rng = np.random.default_rng(1)
    B, S, H, K, D = 3, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
    lens = jnp.asarray([10, 33, 64])
    out = decode_attention(q, kc, vc, lens)
    for b in range(B):
        n = int(lens[b])
        exp = naive_attention(
            q[b : b + 1], kc[b : b + 1, :n], vc[b : b + 1, :n], causal=False
        )
        np.testing.assert_allclose(
            np.asarray(out[b]), np.asarray(exp[0]), atol=1e-5
        )


def test_rope_rotation_preserves_norm():
    pos = jnp.arange(16)[None]
    cos, sin = rope_angles(pos, 64, 10_000.0)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1, 16, 2, 64)), jnp.float32)
    y = apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m−n."""
    D = 32
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(D,)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(D,)), jnp.float32)

    def dot_at(m, n):
        cos_m, sin_m = rope_angles(jnp.array([[m]]), D, 10_000.0)
        cos_n, sin_n = rope_angles(jnp.array([[n]]), D, 10_000.0)
        qm = apply_rope(q[None, None, None], cos_m, sin_m)[0, 0, 0]
        kn = apply_rope(k[None, None, None], cos_n, sin_n)[0, 0, 0]
        return float(jnp.dot(qm, kn))

    assert dot_at(5, 3) == pytest.approx(dot_at(105, 103), abs=1e-4)
    assert dot_at(7, 0) == pytest.approx(dot_at(57, 50), abs=1e-4)


def test_mrope_equals_rope_on_equal_streams():
    pos3 = jnp.broadcast_to(jnp.arange(16)[None, None], (3, 2, 16))
    c3, s3 = mrope_angles(pos3, 64, 10_000.0, (8, 12, 12))
    c1, s1 = rope_angles(jnp.broadcast_to(jnp.arange(16)[None], (2, 16)), 64, 10_000.0)
    np.testing.assert_allclose(np.asarray(c3), np.asarray(c1))
    np.testing.assert_allclose(np.asarray(s3), np.asarray(s1))


def test_mrope_sections_validate():
    with pytest.raises(ValueError):
        mrope_angles(jnp.zeros((3, 1, 4)), 64, 1e4, (8, 8, 8))


def test_rms_norm_basic():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 8, 16)), jnp.float32)
    w = jnp.zeros((16,), jnp.float32)
    y = rms_norm(x, w)
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-4)


@pytest.mark.parametrize("chunk", [1, 16, 64])
def test_ssd_chunked_equals_stepwise(chunk):
    rng = np.random.default_rng(3)
    B, L, H, P, G, N = 2, 64, 4, 8, 1, 16
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, L, H)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(B, L, G, N)), jnp.float32)
    y, s = ssd_chunked(x, dt, a, bm, cm, chunk=chunk)
    st = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(L):
        yy, st = ssd_step(x[:, t], dt[:, t], a, bm[:, t], cm[:, t], st)
        ys.append(yy)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(jnp.stack(ys, 1)), atol=1e-4
    )
    np.testing.assert_allclose(np.asarray(s), np.asarray(st), atol=1e-4)


@pytest.mark.parametrize("chunk", [8, 32, 64])
def test_mlstm_chunked_equals_stepwise(chunk):
    rng = np.random.default_rng(4)
    B, L, H, Dk, Dv = 2, 64, 4, 16, 16
    q = jnp.asarray(rng.normal(size=(B, L, H, Dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, L, H, Dk)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, L, H, Dv)), jnp.float32)
    ip = jnp.asarray(rng.normal(size=(B, L, H)), jnp.float32)
    fp = jnp.asarray(rng.normal(size=(B, L, H)) + 2.0, jnp.float32)
    h, (cf, nf) = mlstm_chunked(q, k, v, ip, fp, chunk=chunk)
    c = jnp.zeros((B, H, Dv, Dk))
    n = jnp.zeros((B, H, Dk))
    hs = []
    for t in range(L):
        ht, (c, n) = mlstm_step(q[:, t], k[:, t], v[:, t], ip[:, t], fp[:, t], (c, n))
        hs.append(ht)
    np.testing.assert_allclose(
        np.asarray(h), np.asarray(jnp.stack(hs, 1)), atol=2e-5
    )
    np.testing.assert_allclose(np.asarray(cf), np.asarray(c), atol=2e-5)


def test_slstm_stability_extreme_gates():
    """Stabilizer keeps sLSTM finite under extreme gate preactivations."""
    B, L, H, D = 1, 32, 2, 4
    big = jnp.full((B, L, H, D), 30.0)
    r = jnp.zeros((H, D, D))
    h, state = slstm_scan(big, big, -big, big, r, r, r, r)
    assert np.isfinite(np.asarray(h)).all()
    h2, _ = slstm_scan(-big, -big, big, -big, r, r, r, r)
    assert np.isfinite(np.asarray(h2)).all()
