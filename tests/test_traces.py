"""Trace CDFs and generation: properties + paper-anchored stats."""

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.traces import (
    AZURE,
    LMSYS,
    TraceColumns,
    TraceSpec,
    generate_trace,
    generate_trace_columns,
    short_fraction,
)

settings.register_profile("fast", max_examples=40, deadline=None)
settings.load_profile("fast")


class TestBucketCDF:
    @given(u=st.floats(0.0, 1.0))
    def test_inverse_cdf_roundtrip(self, u):
        for cdf in (AZURE, LMSYS):
            x = cdf.inverse(u)
            assert 0 <= x <= cdf.max_total
            assert cdf.cdf(x) == pytest.approx(u, abs=1e-6)

    @given(x1=st.floats(0, 70_000), x2=st.floats(0, 70_000))
    def test_cdf_monotone(self, x1, x2):
        lo, hi = sorted((x1, x2))
        for cdf in (AZURE, LMSYS):
            assert cdf.cdf(lo) <= cdf.cdf(hi) + 1e-12

    def test_azure_paper_anchors(self):
        """§1.1/§4.1: ~80% below 2K, ~92% below 8K, tail to 64K."""
        assert AZURE.cdf(2048) == pytest.approx(0.80, abs=0.01)
        assert AZURE.cdf(8192) == pytest.approx(0.92, abs=0.01)
        assert AZURE.max_total == 65_536

    def test_lmsys_paper_anchors(self):
        """§4.1: mean total ≈ 69.5 + 214.5 = 284; virtually all below 8K."""
        assert LMSYS.mean_total() == pytest.approx(284, rel=0.05)
        assert LMSYS.cdf(8192) > 0.999

    def test_conditional_mean_bounds(self):
        m = AZURE.mean_total_conditional(0, 8192)
        assert 0 < m <= 8192
        m2 = AZURE.mean_total_conditional(8192, 65_536)
        assert 8192 < m2 <= 65_536


class TestGenerator:
    def test_deterministic(self):
        a = generate_trace(TraceSpec(num_requests=100, seed=7))
        b = generate_trace(TraceSpec(num_requests=100, seed=7))
        assert [r.byte_len for r in a] == [r.byte_len for r in b]
        assert [r.arrival_time for r in a] == [r.arrival_time for r in b]

    def test_arrivals_sorted_and_rate(self):
        reqs = generate_trace(TraceSpec(num_requests=5000, rate=500, seed=0))
        times = [r.arrival_time for r in reqs]
        assert times == sorted(times)
        measured = len(reqs) / times[-1]
        assert measured == pytest.approx(500, rel=0.1)

    @given(seed=st.integers(0, 50))
    def test_fields_valid(self, seed):
        reqs = generate_trace(TraceSpec(num_requests=50, seed=seed))
        for r in reqs:
            assert r.byte_len >= 1
            assert r.true_input_tokens >= 1
            assert r.true_output_tokens >= 1
            assert r.max_output_tokens >= 1
            assert 0 <= r.category <= 3
            assert r.true_total <= 65_536 + 1

    def test_lmsys_mean_lengths(self):
        """Paper §4.1: mean L_in=69.5, L_out=214.5 (±15%)."""
        reqs = generate_trace(
            TraceSpec(trace="lmsys", num_requests=20_000, seed=3)
        )
        mean_in = np.mean([r.true_input_tokens for r in reqs])
        mean_out = np.mean([r.true_output_tokens for r in reqs])
        assert mean_in == pytest.approx(69.5, rel=0.2)
        assert mean_out == pytest.approx(214.5, rel=0.15)

    def test_azure_alpha(self):
        """§4.2: α ≈ 0.92 at B_short=8192."""
        reqs = generate_trace(TraceSpec(trace="azure", num_requests=20_000, seed=3))
        assert short_fraction(reqs, 8192) == pytest.approx(0.917, abs=0.01)

    def test_short_fraction_accepts_columns(self):
        cols = generate_trace_columns(
            TraceSpec(trace="azure", num_requests=5000, seed=3)
        )
        assert short_fraction(cols, 8192) == pytest.approx(
            short_fraction(cols.to_requests(), 8192)
        )

    def test_cap_styles(self):
        for style in ("exact", "padded", "bucket"):
            reqs = generate_trace(
                TraceSpec(num_requests=200, seed=1, cap_style=style)
            )
            for r in reqs:
                assert r.max_output_tokens >= min(r.true_output_tokens, 128) or (
                    style == "exact"
                )
        exact = generate_trace(TraceSpec(num_requests=200, seed=1))
        assert all(r.max_output_tokens == r.true_output_tokens for r in exact)


class TestNonstationary:
    """Scenario axes of TraceSpec: arrival-rate modulation, category-mix
    drift, bytes/token drift — stationary defaults stay bit-identical to
    the paper's recipe."""

    @staticmethod
    def _rate_in(arr, lo, hi):
        return ((arr >= lo) & (arr < hi)).sum() / (hi - lo)

    def test_stationary_knobs_are_inert(self):
        base = generate_trace_columns(TraceSpec(num_requests=1500, seed=7))
        explicit = generate_trace_columns(
            TraceSpec(
                num_requests=1500, seed=7,
                rate_profile="stationary", rate_amplitude=0.0,
                mix_drift=0.0, bytes_drift=0.0,
            )
        )
        for f in ("arrival_time", "byte_len", "category"):
            np.testing.assert_array_equal(
                getattr(base, f), getattr(explicit, f), err_msg=f
            )

    def test_burst_profile_rate(self):
        """Inside the burst window the measured rate is ~(1+A)·λ; the
        surrounding plateau stays at λ."""
        n, rate = 60_000, 1000.0  # nominal 60 s trace; burst at t≈24 s
        cols = generate_trace_columns(
            TraceSpec(
                num_requests=n, rate=rate, seed=1,
                rate_profile="burst", rate_amplitude=2.0, rate_period=10.0,
            )
        )
        arr = cols.arrival_time
        assert self._rate_in(arr, 5, 20) == pytest.approx(rate, rel=0.1)
        assert self._rate_in(arr, 25, 33) == pytest.approx(3 * rate, rel=0.1)

    def test_step_profile_rate(self):
        n, rate = 60_000, 1000.0
        cols = generate_trace_columns(
            TraceSpec(
                num_requests=n, rate=rate, seed=1,
                rate_profile="step", rate_amplitude=1.0, rate_period=20.0,
            )
        )
        arr = cols.arrival_time
        assert self._rate_in(arr, 5, 18) == pytest.approx(rate, rel=0.1)
        assert self._rate_in(arr, 22, 40) == pytest.approx(2 * rate, rel=0.1)

    def test_diurnal_profile_rate(self):
        n, rate = 60_000, 1000.0
        cols = generate_trace_columns(
            TraceSpec(
                num_requests=n, rate=rate, seed=1,
                rate_profile="diurnal", rate_amplitude=0.8, rate_period=20.0,
            )
        )
        arr = cols.arrival_time
        peak = self._rate_in(arr, 3, 7)  # sin peak at t = T/4 = 5 s
        trough = self._rate_in(arr, 13, 17)  # sin trough at 3T/4 = 15 s
        assert peak > 1.5 * rate
        assert trough < 0.5 * rate

    @pytest.mark.parametrize("profile,amplitude", [
        ("burst", 2.0), ("diurnal", 0.8), ("step", 1.0),
    ])
    def test_warped_arrivals_sorted_positive(self, profile, amplitude):
        cols = generate_trace_columns(
            TraceSpec(
                num_requests=3000, rate=300.0, seed=5,
                rate_profile=profile, rate_amplitude=amplitude,
                rate_period=3.0,
            )
        )
        arr = cols.arrival_time
        assert (arr[1:] >= arr[:-1]).all()
        assert (arr > 0).all()

    def test_mix_drift_moves_toward_target(self):
        """Full drift toward LMSYS: the tail of the trace matches the
        LMSYS category mix (CJK-heavy), the head keeps Azure's."""
        cols = generate_trace_columns(
            TraceSpec(
                trace="azure", num_requests=40_000, seed=1,
                mix_drift=1.0, drift_trace="lmsys",
            )
        )
        head, tail = cols.category[:5000], cols.category[-5000:]
        from repro.core.categories import Category

        cjk = int(Category.CJK_TEXT)
        assert (head == cjk).mean() == pytest.approx(0.08, abs=0.02)
        assert (tail == cjk).mean() == pytest.approx(0.22, abs=0.03)

    def test_bytes_drift_scales_ratio(self):
        """bytes_drift=-0.5 halves bytes/token by the end of the trace."""
        spec = TraceSpec(num_requests=40_000, seed=1, bytes_drift=-0.5)
        drifted = generate_trace_columns(spec)
        base = generate_trace_columns(TraceSpec(num_requests=40_000, seed=1))
        head = (drifted.byte_len[:4000] / base.byte_len[:4000]).mean()
        tail = (drifted.byte_len[-4000:] / base.byte_len[-4000:]).mean()
        assert head == pytest.approx(1.0, abs=0.05)
        assert tail == pytest.approx(0.5, abs=0.06)

    def test_nonstationary_columns_match_objects(self):
        """The scenario axes are implemented once: object and columnar
        entry points stay bit-identical for a fully nonstationary spec."""
        spec = TraceSpec(
            trace="azure", num_requests=2000, rate=200.0, seed=13,
            rate_profile="diurnal", rate_amplitude=0.6, rate_period=2.0,
            mix_drift=0.8, bytes_drift=0.3,
        )
        native = generate_trace_columns(spec)
        via_objects = TraceColumns.from_requests(generate_trace(spec))
        import dataclasses

        for f in dataclasses.fields(TraceColumns):
            np.testing.assert_array_equal(
                getattr(native, f.name), getattr(via_objects, f.name),
                err_msg=f.name,
            )

    @pytest.mark.parametrize("bad", [
        dict(rate_profile="tsunami"),
        dict(rate_profile="diurnal", rate_amplitude=1.5),
        dict(rate_profile="burst", rate_amplitude=-1.0),
        dict(rate_profile="burst", rate_period=0.0),
        dict(mix_drift=1.5),
        dict(mix_drift=0.5, drift_trace="nope"),
        dict(bytes_drift=-1.0),
    ])
    def test_invalid_scenarios_rejected(self, bad):
        with pytest.raises(ValueError):
            generate_trace_columns(TraceSpec(num_requests=10, **bad))


class TestTraceColumns:
    @pytest.mark.parametrize("trace", ["azure", "lmsys"])
    def test_bit_identical_to_object_path(self, trace):
        """generate_trace_columns(spec) must equal columnarizing
        generate_trace(spec) exactly — same seed, same RNG draw order."""
        spec = TraceSpec(trace=trace, num_requests=3000, rate=300.0, seed=17)
        native = generate_trace_columns(spec)
        via_objects = TraceColumns.from_requests(generate_trace(spec))
        for field in (
            "request_id",
            "byte_len",
            "max_output_tokens",
            "category",
            "arrival_time",
            "true_input_tokens",
            "true_output_tokens",
        ):
            np.testing.assert_array_equal(
                getattr(native, field), getattr(via_objects, field), err_msg=field
            )

    def test_roundtrip_through_requests(self):
        cols = generate_trace_columns(TraceSpec(num_requests=500, seed=9))
        back = TraceColumns.from_requests(cols.to_requests())
        np.testing.assert_array_equal(cols.byte_len, back.byte_len)
        np.testing.assert_array_equal(cols.arrival_time, back.arrival_time)
        assert len(cols) == 500
        assert len(cols.head(10)) == 10

    def test_sorted_by_arrival(self):
        cols = generate_trace_columns(TraceSpec(num_requests=100, seed=2))
        assert cols.sorted_by_arrival() is cols  # generator output is sorted
        import dataclasses

        shuffled = TraceColumns(
            **{
                f.name: getattr(cols, f.name)[::-1]
                for f in dataclasses.fields(cols)
            }
        )
        resorted = shuffled.sorted_by_arrival()
        np.testing.assert_array_equal(resorted.arrival_time, cols.arrival_time)
        np.testing.assert_array_equal(resorted.request_id, cols.request_id)
