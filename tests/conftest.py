"""Test configuration.

Smoke tests and benches must see the host's real (single) device — the
512-device XLA flag belongs to the dry-run process only, never here.
"""

import os

# Guard: if a stray environment leaked the dry-run flag, drop it so tests
# exercise the single-device paths they're written for.
if "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", ""):
    del os.environ["XLA_FLAGS"]

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
