"""Test configuration.

Smoke tests and benches must see the host's real (single) device — the
512-device XLA flag belongs to the dry-run process only, never here.
"""

import os
import sys

# Guard: if a stray environment leaked the dry-run flag, drop it so tests
# exercise the single-device paths they're written for.
if "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", ""):
    del os.environ["XLA_FLAGS"]

# The CI container does not ship `hypothesis` and the repo forbids adding
# dependencies; fall back to the deterministic shim in tests/_compat so the
# property tests still run. The real library wins whenever it is installed.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_compat"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
