"""Unit tests for the ``repro.obs`` observability layer.

Covers the three building blocks in isolation — the O(1) metrics registry,
the bounded event ring, and the export validators — plus the
:class:`~repro.sim.metrics.SLOTarget` satellite. End-to-end telemetry
equivalence between the two DES backends lives in
``tests/test_vector_engine.py`` (TestTelemetryEquivalence).
"""

import json

import numpy as np
import pytest

from repro.obs import (
    ADMIT,
    ARRIVAL,
    CALIB_SYNC,
    DISPATCH,
    EVENT_NAMES,
    PREEMPT,
    REJECT,
    ROUTER_TRACK,
    EventTrace,
    MetricsRegistry,
    validate_chrome_trace,
    validate_events_jsonl,
    validate_telemetry,
)
from repro.sim.metrics import PAPER_SLO, SimSummary, SLOTarget


class TestMetricsRegistry:
    def test_counter_and_gauge_roundtrip(self):
        reg = MetricsRegistry()
        c = reg.counter("preemptions")
        g = reg.gauge("queue_depth")
        c.add()
        c.add(3.0)
        g.set(17.0)
        assert c.value == 4.0
        assert g.value == 17.0
        assert reg.value("preemptions") == 4.0
        assert reg.values() == {"preemptions": 4.0, "queue_depth": 17.0}

    def test_duplicate_name_rejected(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(ValueError):
            reg.gauge("x")

    def test_handles_survive_slab_doubling(self):
        """Counter/Gauge handles index into the registry, not a captured
        array — growing the slab past its capacity must not orphan them."""
        reg = MetricsRegistry(capacity=2)
        first = reg.counter("m0")
        first.add(5.0)
        handles = [reg.counter(f"m{i}") for i in range(1, 100)]
        for h in handles:
            h.add(1.0)
        first.add(1.0)  # mutates the *current* slab, not the original
        assert first.value == 6.0
        assert all(h.value == 1.0 for h in handles)

    def test_histogram_observe_matches_observe_many(self):
        reg = MetricsRegistry()
        edges = (1.0, 10.0, 100.0)
        h1 = reg.histogram("a", edges)
        h2 = reg.histogram("b", edges)
        values = [0.5, 1.0, 5.0, 10.0, 99.0, 100.0, 1e6]
        for v in values:
            h1.observe(v)
        h2.observe_many(np.array(values))
        assert h1.counts.tolist() == h2.counts.tolist()
        assert h1.total == len(values)
        # len(edges)+1 buckets: underflow of first edge … overflow of last.
        assert len(h1.counts) == len(edges) + 1

    def test_histogram_requires_increasing_edges(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError):
            reg.histogram("bad", (1.0, 1.0, 2.0))

    def test_snapshot_includes_histograms(self):
        reg = MetricsRegistry()
        reg.counter("c").add(2.0)
        reg.histogram("h", (1.0, 2.0)).observe(1.5)
        snap = reg.snapshot()
        assert snap["values"]["c"] == 2.0
        assert snap["kinds"]["h"] == "histogram"
        assert sum(snap["histograms"]["h"]["counts"]) == 1


class TestEventTrace:
    def test_emit_and_events_roundtrip(self):
        tr = EventTrace(capacity=8, pool_names=("short", "long"))
        tr.emit(ARRIVAL, 0.5, ROUTER_TRACK, 7)
        tr.emit(DISPATCH, 0.5, 1, 7, value=4096.0)
        tr.emit(ADMIT, 0.75, 1, 7)
        evs = tr.events()
        assert [e["kind"] for e in evs] == ["arrival", "dispatch", "admit"]
        assert evs[0]["pool"] == "router"
        assert evs[1] == {
            "kind": "dispatch",
            "t": 0.5,
            "pool": "long",
            "request_id": 7,
            "value": 4096.0,
        }

    def test_ring_wraparound_keeps_newest(self):
        tr = EventTrace(capacity=4, pool_names=("p",))
        for i in range(10):
            tr.emit(PREEMPT, float(i), 0, i)
        assert tr.emitted == 10
        assert tr.dropped == 6
        assert len(tr) == 4
        assert [e["request_id"] for e in tr.events()] == [6, 7, 8, 9]

    def test_capacity_rounds_to_power_of_two(self):
        assert EventTrace(capacity=5).capacity == 8
        assert EventTrace(capacity=8).capacity == 8
        with pytest.raises(ValueError):
            EventTrace(capacity=0)

    def test_jsonl_export_validates(self):
        tr = EventTrace(capacity=16, pool_names=("short",))
        tr.emit(REJECT, 1.0, 0, 3)
        tr.emit(CALIB_SYNC, 2.0, ROUTER_TRACK, -1, value=12.0)
        text = tr.to_jsonl()
        events = validate_events_jsonl(text)
        assert [e["kind"] for e in events] == ["reject", "calib_sync"]
        header = json.loads(text.splitlines()[0])
        assert header["emitted"] == 2 and header["dropped"] == 0

    def test_chrome_trace_validates_and_maps_tracks(self):
        tr = EventTrace(capacity=16, pool_names=("short", "long"))
        tr.emit(ARRIVAL, 0.25, ROUTER_TRACK, 1)
        tr.emit(ADMIT, 0.5, 1, 1)
        doc = validate_chrome_trace(tr.to_chrome_trace())
        instants = [e for e in doc["traceEvents"] if e["ph"] == "i"]
        # Router events land on the tid *after* the pool tracks; ts is µs.
        assert [e["tid"] for e in instants] == [2, 1]
        assert instants[0]["ts"] == pytest.approx(0.25e6)
        thread_names = {
            e["tid"]: e["args"]["name"]
            for e in doc["traceEvents"]
            if e.get("name") == "thread_name"
        }
        assert thread_names == {0: "short", 1: "long", 2: "router"}

    def test_event_names_cover_all_kinds(self):
        assert len(EVENT_NAMES) == 14
        assert len(set(EVENT_NAMES)) == 14
        # Fault/recovery kinds appended in PR 7 — the prefix is append-only.
        assert EVENT_NAMES[9:] == ("fail", "recover", "retry", "timeout", "shed")


class TestValidators:
    def _telemetry_doc(self):
        pools = ["short"]
        cols = {name: [0.0, 1.0] for name in ("t_req", "t_sim", "spills")}
        for fam in (
            "queue_depth",
            "active",
            "slot_frac",
            "kv_frac",
            "preemptions",
            "rejections",
            "truncations",
        ):
            cols[f"{fam}.short"] = [0.0, 0.0]
        return {
            "schema": "repro.obs/telemetry-v1",
            "pools": pools,
            "num_samples": 2,
            "columns": cols,
        }

    def test_telemetry_doc_accepted(self):
        assert validate_telemetry(self._telemetry_doc())

    def test_telemetry_rejects_bad_schema(self):
        doc = self._telemetry_doc()
        doc["schema"] = "nope"
        with pytest.raises(ValueError, match="schema"):
            validate_telemetry(doc)

    def test_telemetry_rejects_ragged_columns(self):
        doc = self._telemetry_doc()
        doc["columns"]["t_sim"] = [0.0]
        with pytest.raises(ValueError, match="t_sim"):
            validate_telemetry(doc)

    def test_telemetry_rejects_missing_pool_column(self):
        doc = self._telemetry_doc()
        del doc["columns"]["kv_frac.short"]
        with pytest.raises(ValueError, match="kv_frac"):
            validate_telemetry(doc)

    def test_telemetry_rejects_nonmonotonic_t_req(self):
        doc = self._telemetry_doc()
        doc["columns"]["t_req"] = [1.0, 0.0]
        with pytest.raises(ValueError, match="non-decreasing"):
            validate_telemetry(doc)

    def test_events_jsonl_rejects_unknown_kind(self):
        tr = EventTrace(capacity=4, pool_names=("p",))
        tr.emit(ADMIT, 1.0, 0, 1)
        lines = tr.to_jsonl().splitlines()
        bad = json.loads(lines[1])
        bad["kind"] = "meltdown"
        with pytest.raises(ValueError, match="kind"):
            validate_events_jsonl("\n".join([lines[0], json.dumps(bad)]))

    def test_chrome_trace_rejects_unnamed_track(self):
        tr = EventTrace(capacity=4, pool_names=("p",))
        tr.emit(ADMIT, 1.0, 0, 1)
        doc = json.loads(tr.to_chrome_trace())
        for e in doc["traceEvents"]:
            if e["ph"] == "i":
                e["tid"] = 99
        with pytest.raises(ValueError, match="unnamed track"):
            validate_chrome_trace(json.dumps(doc))


class TestSLOTarget:
    def _summary(self, ttft_p99, tpot_p99):
        return SimSummary(
            name="t",
            num_requests=100,
            completed=100,
            rejected=0,
            truncated=0,
            preemptions=0,
            spills=0,
            ttft_p50=0.1,
            ttft_p99=ttft_p99,
            tpot_p50=0.01,
            tpot_p99=tpot_p99,
            makespan=10.0,
            throughput=10.0,
        )

    def test_paper_defaults(self):
        assert PAPER_SLO.ttft_p99 == 2.0
        assert PAPER_SLO.tpot_p99 == 0.080

    def test_met_at_exact_boundary(self):
        assert self._summary(2.0, 0.080).meets_slo()

    def test_each_axis_gates_independently(self):
        assert not self._summary(2.1, 0.01).meets_slo()
        assert not self._summary(0.1, 0.081).meets_slo()

    def test_custom_target_threads_through(self):
        s = self._summary(4.0, 0.1)
        assert not s.meets_slo()
        assert s.meets_slo(SLOTarget(ttft_p99=5.0, tpot_p99=0.2))
