"""Serving engine + two-pool server integration tests."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs import get_config
from repro.models import Model
from repro.serving import (
    SamplingParams,
    ServeRequest,
    ServingEngine,
    SlotAllocator,
    TwoPoolServer,
    bucket_length,
)

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("granite-3-8b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


class TestSlotAllocator:
    @given(ops=st.lists(st.booleans(), max_size=40))
    def test_alloc_release_invariants(self, ops):
        alloc = SlotAllocator(4)
        held = []
        for do_alloc in ops:
            if do_alloc:
                s = alloc.alloc()
                if len(held) < 4:
                    assert s is not None and s not in held
                    held.append(s)
                else:
                    assert s is None
            elif held:
                alloc.release(held.pop())
            assert alloc.num_free == 4 - len(held)

    def test_double_release_raises(self):
        a = SlotAllocator(2)
        s = a.alloc()
        a.release(s)
        with pytest.raises(ValueError):
            a.release(s)


class TestBucketing:
    @given(n=st.integers(1, 100_000))
    def test_bucket_covers_and_is_aligned(self, n):
        b = bucket_length(n, multiple=128, max_len=1 << 17)
        assert b % 128 == 0 or b == 1 << 17
        assert b >= min(n, 1 << 17)


class TestEngine:
    def test_greedy_matches_full_forward(self, small_model):
        cfg, model, params = small_model
        prompt = list(np.random.default_rng(1).integers(0, cfg.vocab, 12))
        eng = ServingEngine(model, params, c_max=64, n_slots=2, prompt_bucket=16)
        eng.submit(ServeRequest(0, prompt, max_new_tokens=6))
        comp = eng.run_to_completion()[0]
        toks = list(prompt)
        for _ in range(6):
            logits, _ = model.forward(params, {"tokens": jnp.asarray(toks)[None]})
            toks.append(int(jnp.argmax(logits[0, -1])))
        assert comp.output_tokens == toks[len(prompt):]

    def test_concurrent_slots_isolated(self, small_model):
        """Requests served together produce the same tokens as served alone."""
        cfg, model, params = small_model
        rng = np.random.default_rng(2)
        prompts = [list(rng.integers(0, cfg.vocab, int(n))) for n in (8, 13, 21)]

        solo = {}
        for i, p in enumerate(prompts):
            eng = ServingEngine(model, params, c_max=64, n_slots=1, prompt_bucket=16)
            eng.submit(ServeRequest(i, p, max_new_tokens=4))
            solo[i] = eng.run_to_completion()[0].output_tokens

        eng = ServingEngine(model, params, c_max=64, n_slots=3, prompt_bucket=16)
        for i, p in enumerate(prompts):
            eng.submit(ServeRequest(i, p, max_new_tokens=4))
        together = {
            c.request_id: c.output_tokens for c in eng.run_to_completion()
        }
        assert together == solo

    def test_queueing_beyond_slots(self, small_model):
        cfg, model, params = small_model
        eng = ServingEngine(model, params, c_max=64, n_slots=2, prompt_bucket=16)
        rng = np.random.default_rng(3)
        for i in range(7):
            eng.submit(
                ServeRequest(
                    i, list(rng.integers(0, cfg.vocab, 10)), max_new_tokens=3
                )
            )
        comps = eng.run_to_completion()
        assert sorted(c.request_id for c in comps) == list(range(7))
        assert all(len(c.output_tokens) == 3 for c in comps)

    def test_prompt_over_cmax_rejected(self, small_model):
        cfg, model, params = small_model
        eng = ServingEngine(model, params, c_max=32, n_slots=2)
        ok = eng.submit(ServeRequest(0, list(range(40)), max_new_tokens=3))
        assert not ok and eng.rejections == 1

    def test_usage_prompt_tokens_reported(self, small_model):
        cfg, model, params = small_model
        eng = ServingEngine(model, params, c_max=64, n_slots=2, prompt_bucket=16)
        eng.submit(ServeRequest(0, list(range(1, 18)), max_new_tokens=2))
        comp = eng.run_to_completion()[0]
        assert comp.prompt_tokens == 17  # exact, independent of bucketing


class TestTwoPoolServer:
    def test_routing_and_feedback(self, small_model):
        cfg, model, params = small_model
        srv = TwoPoolServer(
            model, params,
            short_cmax=64, long_cmax=256, short_slots=4, long_slots=2,
        )
        rng = np.random.default_rng(4)
        pools = {}
        for i in range(10):
            n = int(rng.integers(4, 30))
            toks = list(rng.integers(0, cfg.vocab, n))
            mx = 100 if i % 5 == 0 else int(rng.integers(2, 6))
            pools[i] = srv.submit(i, toks, int(n * 4.4), mx)
        resps = srv.run_to_completion()
        assert len(resps) == 10
        # long-output requests must be in the long pool (total-budget rule)
        for i, pool in pools.items():
            if i % 5 == 0:
                assert pool == "long"
        # calibration learned from usage feedback
        stats = srv.stats()["router"]
        assert stats["calibration"]["count"][0] > 0
        ratio = stats["calibration"]["ratio"][0]
        assert 3.5 < ratio < 5.5  # learned ≈ 4.4 bytes/token

    def test_hard_miss_bounces_to_long(self, small_model):
        """Estimate says short, prompt actually exceeds short c_max."""
        cfg, model, params = small_model
        srv = TwoPoolServer(
            model, params,
            short_cmax=32, long_cmax=256, short_slots=2, long_slots=2,
            bytes_per_token_hint=40.0,  # wildly wrong → underestimates tokens
        )
        toks = list(range(1, 41))  # 40 tokens > short c_max 32
        srv.submit(0, toks, prompt_bytes=160, max_output_tokens=2)
        resps = srv.run_to_completion()
        assert resps[0].pool == "long"
        assert len(resps[0].output_tokens) == 2
