"""Launch layer: sharding policy, HLO parsing, analytic cost, dry-run records."""

import glob
import json
import os

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs import (
    ALL_SHAPES,
    ASSIGNED,
    SHAPES_BY_NAME,
    get_config,
    shape_applicable,
)
from repro.launch.analytic_cost import cell_cost, forward_flops
from repro.launch.hlo_parse import (
    computation_multipliers,
    parse_collectives,
    shape_bytes,
    split_computations,
)

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")

RESULTS = os.path.join(os.path.dirname(os.path.dirname(__file__)), "results", "dryrun")


class TestPolicy:
    """build_policy needs a mesh; construct lightweight stand-ins."""

    def _policy(self, arch, shape, dp=16, mp=16, pod=1):
        from unittest import mock
        from repro.launch.policy import build_policy

        cfg = get_config(arch)
        cell = SHAPES_BY_NAME[shape]
        mesh = mock.MagicMock()
        shape_map = {"data": dp, "model": mp}
        if pod > 1:
            shape_map["pod"] = pod
        mesh.shape = shape_map
        mesh.axis_names = tuple(shape_map)
        return cfg, cell, build_policy(cfg, cell, mesh)

    @pytest.mark.parametrize("arch", [c.name for c in ASSIGNED])
    @pytest.mark.parametrize("shape", list(SHAPES_BY_NAME))
    def test_every_mapped_axis_divides(self, arch, shape):
        """The policy never maps a logical axis a dim can't divide."""
        cfg, cell, pol = self._policy(arch, shape)
        rules = dict(pol.rules.rules)
        msize = 16
        if rules["heads"] == "model":
            assert cfg.n_heads % msize == 0
        if rules["kv_heads"] == "model":
            assert cfg.n_kv_heads % msize == 0
        if rules["experts"] == "model":
            assert cfg.n_experts % msize == 0
        if rules["vocab"] == "model":
            assert cfg.padded_vocab % msize == 0
        if rules["batch"] is not None:
            assert cell.global_batch % 16 == 0

    def test_long500k_replicates_batch_shards_seq(self):
        _, _, pol = self._policy("zamba2-2.7b", "long_500k")
        rules = dict(pol.rules.rules)
        assert rules["serve_batch"] is None
        assert rules["kv_seq"] == ("data", "model")
        assert not pol.batch_sharded

    def test_mqa_arch_seq_shards_cache(self):
        _, _, pol = self._policy("gemma-2b", "decode_32k")
        rules = dict(pol.rules.rules)
        assert rules["kv_heads"] is None  # 1 kv head can't shard over 16
        assert rules["kv_seq"] == "model"
        assert not pol.kv_heads_sharded

    def test_multi_pod_batch_uses_both_axes(self):
        _, _, pol = self._policy("yi-6b", "train_4k", pod=2)
        rules = dict(pol.rules.rules)
        assert rules["batch"] == ("pod", "data")


class TestHloParse:
    HLO = """
HloModule test

%scan_body (x: f32[8,128]) -> f32[8,128] {
  %p = f32[8,128]{1,0} parameter(0)
  %ar = f32[8,128]{1,0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %r = f32[8,128]{1,0} add(%ar, %ar)
}

%scan_cond (s: s32[]) -> pred[] {
  %iv = s32[] parameter(0)
  %limit = s32[] constant(32)
  ROOT %lt = pred[] compare(%iv, %limit), direction=LT
}

ENTRY %main (a: f32[8,128]) -> f32[8,128] {
  %a = f32[8,128]{1,0} parameter(0)
  %ag = f32[32,128]{1,0} all-gather(%a), replica_groups={{0,1,2,3}}, dimensions={0}
  %w = f32[8,128]{1,0} while(%a), condition=%scan_cond, body=%scan_body
  ROOT %out = f32[8,128]{1,0} add(%w, %a)
}
"""

    def test_shape_bytes(self):
        assert shape_bytes("f32[8,128]") == 8 * 128 * 4
        assert shape_bytes("bf16[2,4]") == 16
        assert shape_bytes("pred[10]") == 10

    def test_split_and_multipliers(self):
        comps = split_computations(self.HLO)
        assert {"scan_body", "scan_cond", "main"} <= set(comps)
        mult = computation_multipliers(comps)
        assert mult["main"] == 1
        assert mult["scan_body"] == 32  # trip count from constant(32)

    def test_collective_scaling(self):
        stats = parse_collectives(self.HLO)
        assert stats.counts["all-reduce"] == 1
        assert stats.executed["all-reduce"] == 32  # inside the while
        assert stats.counts["all-gather"] == 1
        assert stats.executed["all-gather"] == 1
        # ring model: AR = 2·(3/4)·bytes × 32 execs; AG = (3/4)·out_bytes
        ar = 2 * 0.75 * 8 * 128 * 4 * 32
        ag = 0.75 * 32 * 128 * 4
        assert stats.wire_bytes_per_chip == pytest.approx(ar + ag)


class TestAnalyticCost:
    @pytest.mark.parametrize("arch", [c.name for c in ASSIGNED])
    def test_flops_positive_all_applicable_cells(self, arch):
        cfg = get_config(arch)
        from repro.models import Model

        n = Model(cfg).param_count()
        for cell in ALL_SHAPES:
            if not shape_applicable(cfg, cell):
                continue
            c = cell_cost(cfg, cell, n)
            assert c.flops_total > 0
            assert c.hbm_bytes > 0

    def test_train_flops_close_to_6nd(self):
        """Dense train ≈ 6·N·D × remat factor (4/3) + attention overhead."""
        from repro.models import Model

        cfg = get_config("yi-6b")
        cell = SHAPES_BY_NAME["train_4k"]
        n = Model(cfg).param_count()
        c = cell_cost(cfg, cell, n, causal_mode="triangle")
        model_flops = 6.0 * n * cell.global_batch * cell.seq_len
        ratio = c.flops_total / model_flops
        assert 1.2 < ratio < 2.2  # remat 4/3 + attention + head

    def test_decode_memory_dominated_by_kv(self):
        cfg = get_config("yi-6b")
        cell = SHAPES_BY_NAME["decode_32k"]
        from repro.models import Model

        n = Model(cfg).param_count()
        c = cell_cost(cfg, cell, n)
        kv = c.detail["bytes"]["kv_cache_read"]
        assert kv > 0.3 * c.hbm_bytes


@pytest.mark.skipif(
    not glob.glob(os.path.join(RESULTS, "*.json")),
    reason="dry-run records not generated (run repro.launch.dryrun --all)",
)
class TestDryRunMatrix:
    """Deliverable (e): every (arch × shape × mesh) compiled or was a
    documented sub-quadratic skip — on BOTH production meshes."""

    def _records(self):
        return [json.load(open(p)) for p in glob.glob(os.path.join(RESULTS, "*.json"))]

    def test_all_cells_present_both_meshes(self):
        recs = self._records()
        seen = {(r["arch"], r["shape"], r["mesh"]) for r in recs}
        for cfg in ASSIGNED:
            for shape in SHAPES_BY_NAME:
                for mesh in ("pod16x16", "pod2x16x16"):
                    assert (cfg.name, shape, mesh) in seen

    def test_no_errors(self):
        for r in self._records():
            assert r["status"] in ("ok", "skipped"), (
                r["arch"], r["shape"], r["mesh"], r.get("error"),
            )

    def test_skips_are_exactly_the_subquadratic_rule(self):
        for r in self._records():
            cfg = get_config(r["arch"])
            cell = SHAPES_BY_NAME[r["shape"]]
            if r["status"] == "skipped":
                assert not shape_applicable(cfg, cell)
            else:
                assert shape_applicable(cfg, cell)

    def test_ok_cells_have_roofline_terms(self):
        for r in self._records():
            if r["status"] != "ok":
                continue
            roof = r["roofline"]
            assert roof["compute_s"] > 0
            assert roof["memory_s"] > 0
            assert roof["dominant"] in ("compute", "memory", "collective")
            assert r["collectives"]["wire_bytes_per_chip"] >= 0
