"""Cold-start calibration parity: scalar vs JAX Eq. 4 paths.

The vectorized fleet backend syncs its EMA through ``jax_update_stream``
(``EmaCalibrator.observe_batch``) while the reference backend calls
``EmaCalibrator.observe`` per response. The two implementations must agree
from a cold start to float32 tolerance — in particular the *first*
observation per category, where both the ratio AND the sigma EMA replace
the prior outright (the same blend factor ``b`` drives both; a
beta-weighted sigma would diverge whenever the prior sigma is nonzero at
count=0).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.calibration import (
    CalibState,
    EmaCalibrator,
    init_state,
    jax_update,
    jax_update_stream,
)
from repro.core.categories import NUM_CATEGORIES

F32_RTOL = 1e-5
F32_ATOL = 1e-6


def stream_state(obs):
    """Fold (bytes, tokens, category) observations through the JAX path."""
    return jax_update_stream(
        init_state(),
        jnp.array([o[0] for o in obs], jnp.float32),
        jnp.array([o[1] for o in obs], jnp.float32),
        jnp.array([o[2] for o in obs], jnp.int32),
    )


def scalar_state(obs):
    cal = EmaCalibrator()
    for b, p, k in obs:
        cal.observe(b, p, k)
    return cal


def assert_parity(cal: EmaCalibrator, state: CalibState):
    np.testing.assert_allclose(
        np.asarray(state.ratio), np.asarray(cal.ratio, np.float32),
        rtol=F32_RTOL, atol=F32_ATOL,
    )
    np.testing.assert_allclose(
        np.asarray(state.sigma), np.asarray(cal.sigma, np.float32),
        rtol=F32_RTOL, atol=F32_ATOL,
    )
    np.testing.assert_array_equal(
        np.asarray(state.count), np.asarray(cal.count)
    )


class TestColdStartParity:
    @pytest.mark.parametrize("category", range(NUM_CATEGORIES))
    def test_first_sample_per_category(self, category):
        """First observation: ratio ← c_obs, sigma ← 0, in BOTH paths."""
        obs = [(3000, 1000, category)]  # c_obs = 3.0
        cal = scalar_state(obs)
        state = stream_state(obs)
        assert cal.ratio[category] == pytest.approx(3.0)
        assert cal.sigma[category] == 0.0
        assert float(state.sigma[category]) == 0.0
        assert_parity(cal, state)

    @pytest.mark.parametrize("category", range(NUM_CATEGORIES))
    def test_second_sample_per_category(self, category):
        """Second observation: sigma ← (1−β)·dev, identically in both."""
        obs = [(3000, 1000, category), (5000, 1000, category)]
        cal = scalar_state(obs)
        state = stream_state(obs)
        assert cal.sigma[category] > 0.0
        assert_parity(cal, state)

    def test_interleaved_categories_from_cold(self):
        rng = np.random.default_rng(7)
        obs = [
            (int(rng.integers(100, 50_000)), int(rng.integers(1, 10_000)),
             int(rng.integers(0, NUM_CATEGORIES)))
            for _ in range(200)
        ]
        cal = scalar_state(obs)
        state = stream_state(obs)
        np.testing.assert_allclose(
            np.asarray(state.ratio), np.asarray(cal.ratio, np.float32),
            rtol=1e-4,
        )
        np.testing.assert_allclose(
            np.asarray(state.sigma), np.asarray(cal.sigma, np.float32),
            rtol=1e-3, atol=1e-5,
        )

    def test_sigma_prior_replaced_at_count_zero(self):
        """Regression for the sigma-EMA cold-start bug: with a nonzero
        sigma prior at count=0 the first observation must *replace* the
        prior (b=0), not beta-blend it — in both implementations."""
        cal = EmaCalibrator()
        cal.sigma[1] = 5.0  # stale prior, count still 0
        cal.observe(3000, 1000, 1)
        assert cal.sigma[1] == 0.0  # dev of the first sample is 0

        state = CalibState(
            ratio=init_state().ratio,
            sigma=init_state().sigma.at[1].set(5.0),
            count=init_state().count,
        )
        state = jax_update(
            state,
            jnp.float32(3000.0),
            jnp.float32(1000.0),
            jnp.int32(1),
        )
        assert float(state.sigma[1]) == 0.0

    def test_observe_batch_syncs_scalar_state(self):
        """observe_batch (the vectorized backend's epoch sync) lands on the
        same scalar state as per-response observe calls."""
        rng = np.random.default_rng(11)
        obs = [
            (int(rng.integers(100, 50_000)), int(rng.integers(1, 10_000)),
             int(rng.integers(0, NUM_CATEGORIES)))
            for _ in range(300)
        ]
        loop = scalar_state(obs)
        batched = EmaCalibrator()
        batched.observe_batch(
            [o[0] for o in obs], [o[1] for o in obs], [o[2] for o in obs]
        )
        np.testing.assert_allclose(
            batched.ratio, np.asarray(loop.ratio, np.float32), rtol=1e-4
        )
        np.testing.assert_allclose(
            batched.sigma, np.asarray(loop.sigma, np.float32),
            rtol=1e-3, atol=1e-5,
        )
        assert batched.count == loop.count

    def test_padding_rows_are_inert(self):
        """prompt_tokens=0 rows (observe_batch shape padding) never touch
        the state in either path."""
        cal = EmaCalibrator()
        cal.observe(1000, 0, 0)
        assert cal.count[0] == 0
        state = jax_update(
            init_state(), jnp.float32(1000.0), jnp.float32(0.0), jnp.int32(0)
        )
        assert int(state.count[0]) == 0
        np.testing.assert_array_equal(
            np.asarray(state.ratio), np.asarray(init_state().ratio)
        )
        np.testing.assert_array_equal(
            np.asarray(state.sigma), np.asarray(init_state().sigma)
        )
