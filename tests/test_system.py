"""End-to-end behaviour tests: the paper's system working as a whole."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import EmaCalibrator
from repro.models import Model
from repro.serving import TwoPoolServer
from repro.sim import A100_LLAMA3_70B, plan_fleet
from repro.traces import TraceSpec, generate_trace


def test_paper_headline_claim():
    """17–39% GPU reduction across the two traces (abstract)."""
    savings = {}
    for trace in ("azure", "lmsys"):
        reqs = generate_trace(
            TraceSpec(trace=trace, num_requests=10_000, rate=1000, seed=42)
        )
        savings[trace] = plan_fleet(trace, reqs, A100_LLAMA3_70B, 1000.0).savings
    assert 0.16 <= savings["azure"] <= 0.20
    assert 0.35 <= savings["lmsys"] <= 0.40


def test_end_to_end_two_pool_serving_with_calibration():
    """Real JAX engines + Algorithm-1 router + usage feedback, end to end.

    The short-prompt/long-generation request must land in the long pool
    (the paper's 'route on L_total' design rule), and every response's
    usage.prompt_tokens must have fed the EMA.
    """
    cfg = get_config("yi-6b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    srv = TwoPoolServer(
        model, params,
        short_cmax=64, long_cmax=256, short_slots=4, long_slots=2,
        bytes_per_token_hint=4.0,
    )
    rng = np.random.default_rng(5)
    n_req = 12
    long_ids = set()
    for i in range(n_req):
        n = int(rng.integers(4, 30))
        toks = list(rng.integers(0, cfg.vocab, n))
        if i in (3, 7):  # short prompt, huge output cap
            mx = 150
            long_ids.add(i)
        else:
            mx = int(rng.integers(2, 6))
        pool = srv.submit(i, toks, int(n * 4.4), mx)
        if i in long_ids:
            assert pool == "long"
    resps = srv.run_to_completion()
    assert len(resps) == n_req
    assert all(len(r.output_tokens) >= 1 for r in resps)
    counts = srv.stats()["router"]["calibration"]["count"]
    assert sum(counts) == n_req


def test_calibration_cross_category_isolation():
    """CJK feedback must not disturb the prose ratio (per-category EMA)."""
    cal = EmaCalibrator()
    for _ in range(50):
        cal.observe(4480, 1000, 0)  # prose: 4.48 B/tok
        cal.observe(2010, 1000, 2)  # CJK: 2.01 B/tok
    assert cal.ratio[0] == pytest.approx(4.48, rel=0.01)
    assert cal.ratio[2] == pytest.approx(2.01, rel=0.01)
    assert cal.ratio[1] == 4.0  # untouched category keeps the prior
