"""dtype-discipline: float64 op-order contract in the compiled engine.

Event times in the jax DES tier must be IEEE-754 identical to the host
engines, which means every constant entering time arithmetic is float64
and roofline constants flow through ``timing.constants_f64()`` (or an
explicit ``float``/``np.float64`` wrap).  Within the manifest's
f64-critical files this rule flags:

* references to reduced-precision dtypes (``float32``/``float16``/
  ``bfloat16``) outside manifest-allowed scopes — the allowed scopes are
  the documented jax-tier divergences (the float32 AIMD controller
  mirror), recorded with reasons in the tolerance manifest;
* jnp array constructors whose fill value is a bare float literal with
  no explicit dtype (``jnp.asarray(1e-9)``) — weak-typed constants
  silently degrade to float32 when x64 is not enabled;
* ``jnp.zeros/ones/empty/full`` with no dtype argument at all;
* unwrapped reads of the roofline constants (``.w_base``/``.h_per_seq``)
  — they must pass through ``float()``/``np.float64()`` or
  ``timing.constants_f64()``;
* calls to the manifest's x64 entry points (``_runner``) outside a
  ``with enable_x64():`` block.

Device kernels (``repro/kernels/*``) are deliberately outside this
rule's file set — see the manifest's ``kernels_note``.
"""

from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.core import (
    Finding,
    Rule,
    SourceFile,
    enclosing_map,
    register,
    scope_chain,
    unparse,
)

_LOW_PRECISION = {"float32", "float16", "bfloat16"}
_CTORS_DTYPE_POS = {  # constructor -> index of the positional dtype arg
    "zeros": 1,
    "ones": 1,
    "empty": 1,
    "full": 2,
    "array": 1,
    "asarray": 1,
}
_FILL_POS = {"full": 1, "array": 0, "asarray": 0}


def _is_float_literal(node: ast.expr) -> bool:
    if isinstance(node, ast.Constant) and type(node.value) is float:
        return True
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, (ast.USub, ast.UAdd)):
        return _is_float_literal(node.operand)
    return False


@register
class DtypeDisciplineRule(Rule):
    name = "dtype-discipline"
    description = (
        "f64-critical files: no float32-family constants or implicit-"
        "dtype jnp constructors; roofline constants wrapped in f64; "
        "jit entries under enable_x64()"
    )

    def check(self, sf: SourceFile) -> Iterable[Finding]:
        cfg = self.manifest.get("dtype", {})
        if not any(sf.matches(p) for p in cfg.get("files", [])):
            return ()
        findings: List[Finding] = []
        enclosing = enclosing_map(sf.tree)
        allowed_scopes: set = set()
        for path, scopes in cfg.get("float32_scope_ok", {}).items():
            if sf.matches(path):
                allowed_scopes |= set(scopes)
        const_attrs = set(cfg.get("const_attrs", []))
        wrappers = set(cfg.get("const_wrappers", ["float", "np.float64"]))
        x64_entries: set = set()
        for path, names in cfg.get("x64_entries", {}).items():
            if sf.matches(path):
                x64_entries |= set(names)

        # parent map for the const-wrap and x64 checks
        parents = {}
        for node in ast.walk(sf.tree):
            for child in ast.iter_child_nodes(node):
                parents[child] = node

        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Attribute) and node.attr in _LOW_PRECISION:
                if not (set(scope_chain(node, enclosing)) & allowed_scopes):
                    findings.append(
                        Finding(
                            rule=self.name,
                            path=sf.ident,
                            line=node.lineno,
                            message=(
                                f"reduced-precision dtype `{unparse(node)}` in "
                                f"an f64-critical file"
                            ),
                            hint=(
                                "event-time math must stay float64 "
                                "(timing.constants_f64()); if this scope is an "
                                "intentional jax-tier divergence, record it "
                                "under dtype.float32_scope_ok in the tolerance "
                                "manifest with a reason"
                            ),
                        )
                    )
            elif isinstance(node, ast.Call):
                findings.extend(self._check_ctor(node, sf))
                findings.extend(self._check_x64(node, sf, x64_entries, parents))
            elif isinstance(node, ast.keyword) and node.arg == "dtype":
                v = node.value
                if isinstance(v, ast.Constant) and v.value in _LOW_PRECISION:
                    if not (set(scope_chain(node.value, enclosing)) & allowed_scopes):
                        findings.append(
                            Finding(
                                rule=self.name,
                                path=sf.ident,
                                line=v.lineno,
                                message=(
                                    f'reduced-precision dtype string '
                                    f'"{v.value}" in an f64-critical file'
                                ),
                                hint="use an explicit x64 dtype",
                            )
                        )
            elif isinstance(node, ast.Attribute) and node.attr in const_attrs:
                par = parents.get(node)
                wrapped = (
                    isinstance(par, ast.Call)
                    and node in par.args
                    and unparse(par.func) in wrappers
                )
                if not wrapped:
                    findings.append(
                        Finding(
                            rule=self.name,
                            path=sf.ident,
                            line=node.lineno,
                            message=(
                                f"roofline constant `{unparse(node)}` used "
                                f"without an explicit f64 wrap"
                            ),
                            hint=(
                                "read it via timing.constants_f64() or wrap "
                                "in float()/np.float64() so device and host "
                                "accumulate identical event times"
                            ),
                        )
                    )
        return findings

    def _check_ctor(self, call: ast.Call, sf: SourceFile) -> Iterable[Finding]:
        if not isinstance(call.func, ast.Attribute):
            return ()
        if not (
            isinstance(call.func.value, ast.Name) and call.func.value.id == "jnp"
        ):
            return ()
        name = call.func.attr
        if name not in _CTORS_DTYPE_POS:
            return ()
        has_dtype = any(k.arg == "dtype" for k in call.keywords) or len(
            call.args
        ) > _CTORS_DTYPE_POS[name]
        if has_dtype:
            return ()
        fill_idx = _FILL_POS.get(name)
        fill_is_float = (
            fill_idx is not None
            and fill_idx < len(call.args)
            and _is_float_literal(call.args[fill_idx])
        )
        if name in ("array", "asarray") and not fill_is_float:
            return ()  # int/bool literals and array args keep their dtype
        if name == "full" and not fill_is_float:
            # non-literal fill inherits its operand dtype; still covered
            # by the zeros/ones/empty explicitness rule below only when
            # the fill is a literal, so let it pass here.
            return ()
        what = (
            f"bare float literal in `jnp.{name}(...)`"
            if fill_is_float
            else f"`jnp.{name}(...)` without an explicit dtype"
        )
        return (
            Finding(
                rule=self.name,
                path=sf.ident,
                line=call.lineno,
                message=f"{what} — weak-typed constant may degrade to float32",
                hint="pass an explicit x64 dtype (e.g. jnp.float64/i32)",
            ),
        )

    def _check_x64(
        self, call: ast.Call, sf: SourceFile, entries: set, parents: dict
    ) -> Iterable[Finding]:
        fn = call.func
        name = fn.id if isinstance(fn, ast.Name) else (
            fn.attr if isinstance(fn, ast.Attribute) else None
        )
        if name not in entries:
            return ()
        node: ast.AST = call
        while node in parents:
            node = parents[node]
            if isinstance(node, (ast.With, ast.AsyncWith)) and any(
                "enable_x64" in unparse(item.context_expr) for item in node.items
            ):
                return ()
        return (
            Finding(
                rule=self.name,
                path=sf.ident,
                line=call.lineno,
                message=(
                    f"jit entry `{name}(...)` called outside a "
                    f"`with enable_x64():` block"
                ),
                hint=(
                    "event times are float64 accumulations; run compiled "
                    "entries under jax.experimental.enable_x64"
                ),
            ),
        )
