"""jit-purity/determinism: no side effects inside traced bodies.

Functions handed to ``jax.jit``/``jax.vmap``/``lax.while_loop``/
``lax.scan``/``lax.cond``/``lax.fori_loop`` trace once and replay; any
wall-clock read, RNG draw from global state, ``print``, or ``global``
mutation inside them is at best dead and at worst nondeterminism that
breaks the seeded-replay guarantees the chaos/determinism CI checks
rely on.  Jit scopes are discovered syntactically:

* decorators: ``@jax.jit``, ``@jit``, ``@(functools.)partial(jax.jit, ...)``;
* function names passed to the jit entry points above (``jax.jit(core)``,
  ``lax.while_loop(cond_fn, body_fn, init)``) — including Pallas kernel
  bodies handed to ``pl.pallas_call``, also when wrapped in a
  ``(functools.)partial(kernel, ...)`` call for static parameters;
* transitive closure: local functions *called from* a jit scope in the
  same module, and manifest-declared extra roots.

Inside a jit scope this rule flags calls to ``time.*`` clocks,
``np.random.*`` (module-level global RNG — ``default_rng``/``Generator``
construction is allowed), ``random.*``, ``print``, ``input``, ``open``,
and any ``global`` statement.  Additionally, every ``lax.while_loop``
body must take exactly one carry parameter and return a value on every
return path (shape-stable carry discipline).

Determinism also applies outside jit: legacy global-state NumPy RNG
calls (``np.random.seed``, ``np.random.rand``, ...) are flagged in any
analyzed file — seeded ``np.random.default_rng`` generators are the
repo-wide convention.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Set

from repro.analysis.core import (
    Finding,
    Rule,
    SourceFile,
    register,
    unparse,
)

_JIT_ENTRY_ATTRS = {"jit", "vmap", "pmap", "while_loop", "scan", "cond", "fori_loop"}
_RNG_OK = {"default_rng", "Generator", "SeedSequence", "PCG64", "Philox"}
_CLOCKS = {
    "time.time",
    "time.perf_counter",
    "time.perf_counter_ns",
    "time.monotonic",
    "time.process_time",
    "datetime.datetime.now",
    "datetime.datetime.utcnow",
}
_IMPURE_NAMES = {"print", "input", "open"}


def _callee_name(fn: ast.expr) -> str:
    return unparse(fn)


def _is_jit_entry(fn: ast.expr) -> bool:
    """True for jax.jit / lax.while_loop / pl.pallas_call style callees."""
    if isinstance(fn, ast.Attribute) and fn.attr in _JIT_ENTRY_ATTRS:
        root = unparse(fn.value)
        return root in ("jax", "lax", "jax.lax")
    if isinstance(fn, ast.Attribute) and fn.attr == "pallas_call":
        return unparse(fn.value) in ("pl", "pallas", "jax.experimental.pallas")
    if isinstance(fn, ast.Name) and fn.id in ("jit", "vmap", "pallas_call"):
        return True
    return False


def _jit_decorated(fn_def: ast.AST) -> bool:
    for dec in getattr(fn_def, "decorator_list", []):
        if _is_jit_entry(dec):
            return True
        if isinstance(dec, ast.Call):
            if _is_jit_entry(dec.func):
                return True
            # @functools.partial(jax.jit, ...) / @partial(jit, ...)
            name = _callee_name(dec.func)
            if name.endswith("partial") and dec.args and _is_jit_entry(dec.args[0]):
                return True
    return False


@register
class JitPurityRule(Rule):
    name = "jit-purity"
    description = (
        "no clocks, global-state RNG, print, or global mutation inside "
        "jitted bodies; while_loop carries take one parameter and always "
        "return a value"
    )

    def check(self, sf: SourceFile) -> Iterable[Finding]:
        findings: List[Finding] = []
        defs: Dict[str, List[ast.AST]] = {}
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, []).append(node)

        jit_names: Set[str] = set()
        for path, names in self.manifest.get("jit", {}).get("extra_roots", {}).items():
            if sf.matches(path):
                jit_names |= set(names)

        while_bodies: List[ast.expr] = []
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call) and _is_jit_entry(node.func):
                for arg in node.args:
                    if isinstance(arg, ast.Name):
                        jit_names.add(arg.id)
                    elif (
                        isinstance(arg, ast.Call)
                        and _callee_name(arg.func).endswith("partial")
                        and arg.args
                        and isinstance(arg.args[0], ast.Name)
                    ):
                        # pl.pallas_call(partial(kernel, c_max=...), ...)
                        jit_names.add(arg.args[0].id)
                if (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr == "while_loop"
                    and len(node.args) >= 2
                ):
                    while_bodies.append(node.args[1])

        jit_defs: List[ast.AST] = [d for d in ast.walk(sf.tree)
                                   if isinstance(d, (ast.FunctionDef, ast.AsyncFunctionDef))
                                   and (_jit_decorated(d) or d.name in jit_names)]

        # transitive closure over same-module calls from jit scopes
        seen = {id(d) for d in jit_defs}
        frontier = list(jit_defs)
        while frontier:
            cur = frontier.pop()
            for node in ast.walk(cur):
                if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                    for d in defs.get(node.func.id, []):
                        if id(d) not in seen:
                            seen.add(id(d))
                            jit_defs.append(d)
                            frontier.append(d)

        for d in jit_defs:
            findings.extend(self._check_jit_body(d, sf))

        for body in while_bodies:
            findings.extend(self._check_while_body(body, defs, sf))

        findings.extend(self._check_global_rng(sf))
        return findings

    def _check_jit_body(self, fn_def: ast.AST, sf: SourceFile) -> Iterable[Finding]:
        out: List[Finding] = []
        for node in ast.walk(fn_def):
            if isinstance(node, ast.Global):
                out.append(
                    Finding(
                        rule=self.name,
                        path=sf.ident,
                        line=node.lineno,
                        message=(
                            f"`global` mutation inside jitted body "
                            f"`{fn_def.name}`"
                        ),
                        hint="thread state through the carry instead",
                    )
                )
            elif isinstance(node, ast.Call):
                name = _callee_name(node.func)
                bad = None
                if name in _CLOCKS:
                    bad = f"wall-clock read `{name}()`"
                elif name in _IMPURE_NAMES:
                    bad = f"side-effecting call `{name}(...)`"
                elif name.startswith("random."):
                    # np.random.* is covered module-wide by _check_global_rng
                    bad = f"global-state RNG call `{name}(...)`"
                if bad is not None:
                    out.append(
                        Finding(
                            rule=self.name,
                            path=sf.ident,
                            line=node.lineno,
                            message=(
                                f"{bad} inside jitted body `{fn_def.name}` "
                                f"— traces once, replays stale/nondeterministic"
                            ),
                            hint=(
                                "hoist out of the traced scope; use "
                                "jax.random with an explicit key for "
                                "in-graph randomness"
                            ),
                        )
                    )
        return out

    def _check_while_body(
        self, body_ref: ast.expr, defs: Dict[str, List[ast.AST]], sf: SourceFile
    ) -> Iterable[Finding]:
        targets: List[ast.AST] = []
        if isinstance(body_ref, ast.Name):
            targets = defs.get(body_ref.id, [])
        elif isinstance(body_ref, ast.Lambda):
            nargs = len(body_ref.args.args)
            if nargs != 1:
                return (
                    Finding(
                        rule=self.name,
                        path=sf.ident,
                        line=body_ref.lineno,
                        message=(
                            f"lax.while_loop body takes {nargs} parameters; "
                            f"the carry is a single pytree"
                        ),
                        hint="pack state into one carry tuple/dict",
                    ),
                )
            return ()
        out: List[Finding] = []
        for d in targets:
            args = d.args
            nargs = len(args.args) + len(args.posonlyargs)
            if nargs != 1 or args.vararg or args.kwonlyargs:
                out.append(
                    Finding(
                        rule=self.name,
                        path=sf.ident,
                        line=d.lineno,
                        message=(
                            f"lax.while_loop body `{d.name}` must take exactly "
                            f"one carry parameter (got {nargs})"
                        ),
                        hint="pack state into one carry tuple/dict",
                    )
                )
            for node in ast.walk(d):
                if isinstance(node, ast.Return) and node.value is None:
                    out.append(
                        Finding(
                            rule=self.name,
                            path=sf.ident,
                            line=node.lineno,
                            message=(
                                f"bare `return` in while_loop body `{d.name}` "
                                f"— the carry must be returned on every path "
                                f"with a stable shape"
                            ),
                            hint="return the updated carry",
                        )
                    )
        return out

    def _check_global_rng(self, sf: SourceFile) -> Iterable[Finding]:
        out: List[Finding] = []
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _callee_name(node.func)
            if name.startswith("np.random.") or name.startswith("numpy.random."):
                tail = name.rsplit(".", 1)[1]
                if tail not in _RNG_OK:
                    out.append(
                        Finding(
                            rule=self.name,
                            path=sf.ident,
                            line=node.lineno,
                            message=(
                                f"legacy global-state RNG `{name}(...)` — "
                                f"seeded determinism requires explicit "
                                f"generators"
                            ),
                            hint="use np.random.default_rng(seed)",
                        )
                    )
        return out
