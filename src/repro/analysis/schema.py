"""event-schema: obs event kinds and telemetry columns stay wired.

``repro/obs/events.py`` declares the event-kind id space,
``repro/obs/timeseries.py`` produces the telemetry column families, and
``repro/obs/validate.py`` is the schema the exporters are validated
against.  Drift between the three (a kind declared but never emitted, a
validator column no producer writes, a produced family the validator
has never heard of) silently weakens the export contract.  This
project-scoped rule checks:

* the positional constant tuple in events.py (``ARRIVAL, DISPATCH, ...
  = range(N)``) lines up one-for-one with ``EVENT_NAMES`` (lower-cased
  constant name == name string, same arity);
* every declared kind is emitted by at least one manifest-listed
  emitter file, and every all-caps kind passed to ``.emit(...)``
  anywhere is declared (the dead/unknown-kind sweep only runs when the
  full emitter set is in the analyzed tree, so subtree runs don't
  false-positive);
* every column in validate.py's ``REQUIRED_COLUMNS``/``POOL_COLUMNS``
  (v1 + v2) is produced somewhere in timeseries.py, and every per-pool
  family timeseries.py emits (``f"<family>.{name}"``) is either
  validated or declared optional in the manifest's
  ``unvalidated_families_ok`` (with a reason).
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import Finding, Rule, SourceFile, register

_FAMILY_RE = re.compile(r"^[a-z_]+\.(cat)?$")


def _tuple_assign(
    tree: ast.AST, target_name: str
) -> Optional[Tuple[int, List[str]]]:
    """(line, [string elements]) of ``TARGET = ("a", "b", ...)``."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        t = node.targets[0]
        if not (isinstance(t, ast.Name) and t.id == target_name):
            continue
        if isinstance(node.value, (ast.Tuple, ast.List)):
            vals = [
                e.value
                for e in node.value.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)
            ]
            return node.lineno, vals
    return None


def _kind_constants(tree: ast.AST) -> Optional[Tuple[int, List[str]]]:
    """(line, names) of the ``A, B, ... = range(N)`` unpack in events.py."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        t = node.targets[0]
        if not (
            isinstance(t, ast.Tuple)
            and len(t.elts) >= 4
            and all(isinstance(e, ast.Name) for e in t.elts)
        ):
            continue
        v = node.value
        if (
            isinstance(v, ast.Call)
            and isinstance(v.func, ast.Name)
            and v.func.id == "range"
        ):
            return node.lineno, [e.id for e in t.elts]
    return None


def _emit_kind_sites(sf: SourceFile) -> List[Tuple[str, int]]:
    """(ALL_CAPS first-arg name, line) for every ``*.emit(KIND, ...)``."""
    out: List[Tuple[str, int]] = []
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        if not (
            isinstance(node.func, ast.Attribute) and node.func.attr == "emit"
        ):
            continue
        if node.args and isinstance(node.args[0], ast.Name):
            name = node.args[0].id
            if name.isupper():
                out.append((name, node.lineno))
    return out


def _produced_tokens(sf: SourceFile) -> Tuple[Set[str], Set[str]]:
    """(plain string constants, per-entity family prefixes) in a module.

    A family prefix is the leading constant of an f-string shaped like
    ``f"queue_depth.{p}"`` / ``f"calib_err.cat{k}"``.
    """
    plain: Set[str] = set()
    families: Set[str] = set()
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            plain.add(node.value)
        elif isinstance(node, ast.JoinedStr) and node.values:
            head = node.values[0]
            if isinstance(head, ast.Constant) and isinstance(head.value, str):
                if _FAMILY_RE.match(head.value):
                    families.add(head.value.split(".", 1)[0])
    return plain, families


@register
class EventSchemaRule(Rule):
    name = "event-schema"
    description = (
        "obs event kinds and telemetry v1/v2 columns must stay wired "
        "between events.py, timeseries.py, and validate.py"
    )
    project = True

    def check_project(self, files: Sequence[SourceFile]) -> Iterable[Finding]:
        cfg = self.manifest.get("telemetry", {})
        events_sf = self._find(files, cfg.get("events_file", ""))
        findings: List[Finding] = []
        if events_sf is not None:
            findings.extend(self._check_constant_names(events_sf))
            findings.extend(self._check_kind_usage(events_sf, files, cfg))
        validate_sf = self._find(files, cfg.get("validate_file", ""))
        ts_sf = self._find(files, cfg.get("timeseries_file", ""))
        if validate_sf is not None and ts_sf is not None:
            findings.extend(self._check_columns(validate_sf, ts_sf, cfg))
        return findings

    @staticmethod
    def _find(files: Sequence[SourceFile], path: str) -> Optional[SourceFile]:
        if not path:
            return None
        for sf in files:
            if sf.matches(path):
                return sf
        return None

    def _check_constant_names(self, events_sf: SourceFile) -> Iterable[Finding]:
        consts = _kind_constants(events_sf.tree)
        names = _tuple_assign(events_sf.tree, "EVENT_NAMES")
        if consts is None or names is None:
            return ()
        cline, cnames = consts
        nline, nvals = names
        out: List[Finding] = []
        if len(cnames) != len(nvals):
            out.append(
                Finding(
                    rule=self.name,
                    path=events_sf.ident,
                    line=nline,
                    message=(
                        f"{len(cnames)} event-kind constants but "
                        f"{len(nvals)} entries in EVENT_NAMES"
                    ),
                    hint="keep the unpack tuple and EVENT_NAMES in lockstep",
                )
            )
        for i, (c, n) in enumerate(zip(cnames, nvals)):
            if c.lower() != n:
                out.append(
                    Finding(
                        rule=self.name,
                        path=events_sf.ident,
                        line=nline,
                        message=(
                            f"EVENT_NAMES[{i}] is \"{n}\" but constant #{i} "
                            f"is {c} — positional id/name mismatch"
                        ),
                        hint=(
                            "EVENT_NAMES must be the lower-cased constants "
                            "in declaration order (ids index into it)"
                        ),
                    )
                )
        return out

    def _check_kind_usage(
        self, events_sf: SourceFile, files: Sequence[SourceFile], cfg: dict
    ) -> Iterable[Finding]:
        consts = _kind_constants(events_sf.tree)
        if consts is None:
            return ()
        cline, declared = consts
        emitters = cfg.get("emitter_files", [])
        located = [self._find(files, p) for p in emitters]
        if any(sf is None for sf in located) or not located:
            return ()  # partial tree: skip the dead-kind sweep
        out: List[Finding] = []
        used: Set[str] = set()
        for sf in located:
            for name, line in _emit_kind_sites(sf):
                used.add(name)
                if name not in declared:
                    out.append(
                        Finding(
                            rule=self.name,
                            path=sf.ident,
                            line=line,
                            message=(
                                f"emit() of `{name}`, which events.py does "
                                f"not declare"
                            ),
                            hint="add the kind to events.py (+ EVENT_NAMES)",
                        )
                    )
        for name in declared:
            if name not in used:
                out.append(
                    Finding(
                        rule=self.name,
                        path=events_sf.ident,
                        line=cline,
                        message=(
                            f"event kind `{name}` is declared but no emitter "
                            f"file ever emits it"
                        ),
                        hint=(
                            "emit it somewhere or drop the kind (and its "
                            "EVENT_NAMES entry)"
                        ),
                    )
                )
        return out

    def _check_columns(
        self, validate_sf: SourceFile, ts_sf: SourceFile, cfg: dict
    ) -> Iterable[Finding]:
        plain, families = _produced_tokens(ts_sf)
        unvalidated_ok = set(cfg.get("unvalidated_families_ok", {}))
        out: List[Finding] = []
        pool_known: Set[str] = set()
        for var in (
            "REQUIRED_COLUMNS",
            "REQUIRED_COLUMNS_V2",
            "POOL_COLUMNS",
            "POOL_COLUMNS_V2",
        ):
            got = _tuple_assign(validate_sf.tree, var)
            if got is None:
                continue
            line, cols = got
            per_pool = var.startswith("POOL")
            if per_pool:
                pool_known |= set(cols)
            for col in cols:
                produced = col in plain or (per_pool and col in families)
                if not produced:
                    out.append(
                        Finding(
                            rule=self.name,
                            path=validate_sf.ident,
                            line=line,
                            message=(
                                f"validator column \"{col}\" ({var}) is "
                                f"never produced by the telemetry writer"
                            ),
                            hint=(
                                f"produce it in {ts_sf.ident} or drop it "
                                f"from {var}"
                            ),
                        )
                    )
        if pool_known:
            for fam in sorted(families - pool_known - unvalidated_ok):
                out.append(
                    Finding(
                        rule=self.name,
                        path=ts_sf.ident,
                        line=1,
                        message=(
                            f"telemetry emits per-pool family "
                            f"\"{fam}.*\" the validator does not know"
                        ),
                        hint=(
                            "add it to POOL_COLUMNS(_V2) or declare it "
                            "under telemetry.unvalidated_families_ok with "
                            "a reason"
                        ),
                    )
                )
        return out
