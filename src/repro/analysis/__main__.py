"""CLI for simlint: ``python -m repro.analysis [paths] [--json PATH]``.

Exit status is the CI contract: 0 when the tree is clean, 1 when any
finding survives suppressions and the tolerance manifest, 2 on usage
errors.  ``--json`` writes (or prints, with ``-``) a machine-readable
report: schema id, rule inventory, the tolerance manifest, and the
findings — CI archives it as an artifact.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from repro.analysis.core import (
    SourceFile,
    analyze_files,
    default_rules,
    iter_python_files,
    registered_rules,
)
from repro.analysis.manifest import DEFAULT_MANIFEST

REPORT_SCHEMA = "repro.simlint/report-v1"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="simlint: static invariant checks for the DES three-tier "
        "contract (engine parity, guard discipline, dtype discipline, jit "
        "purity, obs schema wiring).",
    )
    ap.add_argument(
        "paths",
        nargs="*",
        default=[],
        help="files or directories to analyze (default: src/repro or repro "
        "under the current directory)",
    )
    ap.add_argument(
        "--json",
        metavar="PATH",
        default=None,
        help="write a JSON report ('-' for stdout)",
    )
    ap.add_argument(
        "--rules",
        metavar="NAMES",
        default=None,
        help="comma-separated subset of rules to run",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="list registered rules and exit"
    )
    ap.add_argument(
        "--manifest",
        action="store_true",
        help="dump the tolerance manifest as JSON and exit",
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for name, cls in sorted(registered_rules().items()):
            scope = "project" if getattr(cls, "project", False) else "file"
            print(f"{name:18s} [{scope}]  {cls.description}")
        return 0
    if args.manifest:
        print(json.dumps(DEFAULT_MANIFEST, indent=2))
        return 0

    paths = args.paths
    if not paths:
        for cand in ("src/repro", "repro", "src"):
            if Path(cand).is_dir():
                paths = [cand]
                break
        else:
            print("simlint: no paths given and no src/repro found", file=sys.stderr)
            return 2

    rules = default_rules()
    if args.rules:
        want = {r.strip() for r in args.rules.split(",") if r.strip()}
        unknown = want - {r.name for r in rules}
        if unknown:
            print(f"simlint: unknown rules {sorted(unknown)}", file=sys.stderr)
            return 2
        rules = [r for r in rules if r.name in want]

    t0 = time.perf_counter()
    files = [SourceFile.load(p) for p in iter_python_files(paths)]
    findings = analyze_files(files, rules)
    elapsed = time.perf_counter() - t0

    for f in findings:
        print(f.format())
    status = "clean" if not findings else f"{len(findings)} finding(s)"
    print(
        f"simlint: {status} — {len(files)} files, "
        f"{len(rules)} rules, {elapsed * 1e3:.0f} ms"
    )

    if args.json is not None:
        report = {
            "schema": REPORT_SCHEMA,
            "paths": [str(p) for p in paths],
            "files_scanned": len(files),
            "elapsed_s": elapsed,
            "rules": [
                {
                    "name": r.name,
                    "description": r.description,
                    "scope": "project" if r.project else "file",
                }
                for r in rules
            ],
            "manifest": DEFAULT_MANIFEST,
            "findings": [f.to_dict() for f in findings],
        }
        blob = json.dumps(report, indent=2)
        if args.json == "-":
            print(blob)
        else:
            Path(args.json).write_text(blob + "\n")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
