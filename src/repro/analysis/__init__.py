"""simlint: AST-based invariant checker for the three-tier DES contract.

The simulator's core guarantees — bit-identical semantics across the
``reference``/``vectorized``/``jax`` backends, float64 op-order
discipline inside the jitted ``lax.while_loop``, and zero-cost-when-off
telemetry/fault hooks — live in runtime equivalence suites that only
catch drift when a test happens to exercise it.  ``repro.analysis``
enforces the same contracts *statically*, at CI time, from source alone
(stdlib-only: no numpy/jax import needed to run the pass).

Usage::

    python -m repro.analysis src/            # human-readable findings
    python -m repro.analysis src/ --json report.json
    python -m repro.analysis --list-rules
    python -m repro.analysis --manifest      # dump the tolerance manifest

Shipped rules (see each module's docstring for the precise semantics):

``engine-parity``   counters, event kinds, and FleetResult fields match
                    across the three engines, modulo the manifest.
``guard-discipline``  tracer/telemetry/fault emissions dominated by
                    ``is None`` guards (zero-cost-when-off).
``dtype-discipline``  no float32-family constants / implicit-dtype jnp
                    constructors / unwrapped roofline constants in
                    f64-critical files; jit entries under enable_x64.
``jit-purity``      no clocks, global RNG, print, or global mutation in
                    jitted bodies; while_loop carry discipline.
``event-schema``    obs event kinds and telemetry v1/v2 columns wired
                    between events.py / timeseries.py / validate.py.

Suppressions: append ``# simlint: disable=<rule>[,<rule>]`` to the
flagged line (or the line above it); ``disable=all`` mutes every rule
for that line.  Intentional jax-tier divergences belong in the
*tolerance manifest* (`repro.analysis.manifest`) with a reason string,
not in inline suppressions — the manifest is the machine-readable
documentation of the tier contract (``--manifest`` dumps it).

Adding a rule
-------------
1. Create ``src/repro/analysis/<rule>.py`` with a ``Rule`` subclass:
   set ``name`` (kebab-case, used in suppressions) and ``description``;
   implement ``check(self, sf)`` yielding ``Finding``s for one parsed
   ``SourceFile``, or set ``project = True`` and implement
   ``check_project(self, files)`` for cross-file checks.  Decorate the
   class with ``@register``.  Findings should carry a ``hint`` that
   tells the reader how to fix the violation (or where to declare the
   tolerance).
2. Import the module in ``core._ensure_builtin_rules`` so the registry
   sees it.
3. If the rule needs declared tolerances, give it a section in
   ``manifest.DEFAULT_MANIFEST`` — every allowance with a reason
   string — and read it via ``self.manifest`` so tests can inject
   fixture manifests.
4. Add fixture tests in ``tests/test_analysis.py``: one passing, one
   violating, one suppressed — plus keep the repo-wide "simlint is
   clean" smoke green (fix the repo or declare the tolerance).
5. Document the rule in ROADMAP.md's simlint section.
"""

from repro.analysis.core import (
    Finding,
    Rule,
    SourceFile,
    analyze_files,
    analyze_paths,
    default_rules,
    register,
    registered_rules,
)
from repro.analysis.manifest import DEFAULT_MANIFEST, manifest_dict, manifest_json

__all__ = [
    "Finding",
    "Rule",
    "SourceFile",
    "analyze_files",
    "analyze_paths",
    "default_rules",
    "register",
    "registered_rules",
    "DEFAULT_MANIFEST",
    "manifest_dict",
    "manifest_json",
]
