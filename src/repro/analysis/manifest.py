"""simlint tolerance manifest: the three-tier equivalence contract.

This module is the machine-readable form of the contract the runtime
equivalence suites (``tests/test_vector_engine.py``) check empirically:
which counters/events/result fields every DES backend must produce, and
which divergences of the compiled ``jax`` tier are *intentional* and
bounded by their own tests rather than bugs.

Every allowance carries a reason string.  Adding an entry here is a
reviewed statement "this divergence is by design"; prefer it over inline
``# simlint: disable=`` comments for anything that is part of the tier
contract (inline suppressions are for one-off local exceptions).

``python -m repro.analysis --manifest`` dumps this as JSON.
"""

from __future__ import annotations

import copy
import json

SCHEMA = "repro.simlint/manifest-v1"

DEFAULT_MANIFEST: dict = {
    "schema": SCHEMA,
    # ------------------------------------------------------------------
    # The three interchangeable DES backends (suffix-matched on path).
    # ------------------------------------------------------------------
    "engines": {
        "reference": "repro/sim/engine.py",
        "vectorized": "repro/sim/vector_engine.py",
        "jax": "repro/sim/jax_engine.py",
    },
    # ------------------------------------------------------------------
    # engine-parity: counters.  Canonical counter -> the symbol each
    # engine must write.  Host engines increment `self.<symbol>`; the
    # jax tier carries them as dict keys inside the jitted while_loop.
    # ------------------------------------------------------------------
    "counters": {
        "preemption_count": {
            "reference": "preemption_count",
            "vectorized": "preemption_count",
            "jax": "npre",
        },
        "rejection_count": {
            "reference": "rejection_count",
            "vectorized": "rejection_count",
            "jax": "nrej",
        },
        "truncation_count": {
            "reference": "truncation_count",
            "vectorized": "truncation_count",
            "jax": "ntr",
        },
    },
    # ------------------------------------------------------------------
    # engine-parity: event kinds each engine emits on its hot path.
    # The jax tier cannot emit discrete events from inside a jitted
    # lax.while_loop; FleetSim(backend="jax") rejects event tracing up
    # front, so the whole canonical set is declared missing-by-design.
    # ------------------------------------------------------------------
    "events": {
        "canonical": ["admit", "preempt", "truncate", "reject"],
        "missing_ok": {
            "jax": {
                "admit": "no per-event callbacks inside jit; "
                "FleetSim raises if events are requested on the jax tier",
                "preempt": "counted in the carried npre counter instead",
                "truncate": "counted in the carried ntr counter instead",
                "reject": "counted in the carried nrej counter instead",
            }
        },
    },
    # ------------------------------------------------------------------
    # engine-parity: FleetResult construction.  The reference
    # constructor is the canonical field set; other tiers may omit only
    # what is declared here.
    # ------------------------------------------------------------------
    "fleet_result": {
        "constructors": {
            "reference": {"file": "repro/sim/fleet.py", "function": "_run_reference"},
            "vectorized": {"file": "repro/sim/fleet.py", "function": "_run_vectorized"},
            "jax": {"file": "repro/sim/jax_engine.py", "function": "run_fleet_jax"},
        },
        "missing_ok": {
            "vectorized": {
                "records": "outcomes stay columnar (summarize_columns); "
                "per-request Record objects are a reference-tier feature",
            },
            "jax": {
                "retries": "fault injection unsupported inside the jitted loop",
                "timeouts": "fault injection unsupported inside the jitted loop",
                "shed": "fault injection unsupported inside the jitted loop",
                "instance_failures": "fault injection unsupported inside "
                "the jitted loop",
                "availability": "defaults to 1.0; no fault runtime on this tier",
                "records": "fixed-shape slot arrays, no Record objects",
                "fail_records": "no fault runtime on this tier",
            },
        },
    },
    # ------------------------------------------------------------------
    # dtype-discipline: float64 op-order contract for DES time math.
    # Scoped to the compiled engine plus the one device kernel that IS
    # event-time math (repro/kernels/sim_decode.py — its jnp twin and
    # Pallas body must accumulate bit-identical float64 event times).
    # Other device kernels pick compute precision explicitly per
    # accelerator (f32/bf16 accumulators) and stay outside the contract.
    # ------------------------------------------------------------------
    "dtype": {
        "files": [
            "repro/sim/jax_engine.py",
            "repro/kernels/sim_decode.py",
        ],
        "float32_scope_ok": {
            "repro/sim/jax_engine.py": {
                "window_step": "in-step AIMD controller mirror keeps gains "
                "and pressure ratios in float32 for vmappable lane axes; "
                "decisions are threshold comparisons, bounded by the "
                "gain-grid parity tests",
                "_ctrl_params": "controller gain pack mirrors window_step's "
                "float32 lanes",
                "run_fleet_grid": "gain-grid rows feed the float32 "
                "controller mirror",
                "precompute_budget_trajectory": "EMA calibration state is "
                "float32 by the CalibState contract (core/calibration.py); "
                "the output is int32 budgets, never event-time math — "
                "cold-start parity tests bound it",
                "_abstract_inputs": "abstract avals for AOT lowering mirror "
                "window_step's float32 controller-gain lanes; no runtime "
                "values flow through them",
            }
        },
        "const_attrs": ["w_base", "h_per_seq"],
        "const_wrappers": ["float", "np.float64", "jnp.float64"],
        "x64_entries": {
            "repro/sim/jax_engine.py": ["_runner", "_aot"],
        },
        "kernels_note": "repro/kernels/* excluded except sim_decode.py: "
        "pallas compute kernels (attention, scan) choose their own "
        "compute precision, but sim_decode advances DES event times and "
        "must hold the same float64 op-order contract as the engines; "
        "event-time constants flow through timing.constants_f64()",
    },
    # ------------------------------------------------------------------
    # jit-purity: extra jit roots not discoverable syntactically
    # (none today — jax.jit/vmap/lax.* call sites are found by name).
    # ------------------------------------------------------------------
    "jit": {"extra_roots": {}},
    # ------------------------------------------------------------------
    # event-schema: obs wiring.  Telemetry column families the producer
    # emits that the validator intentionally does not require.
    # ------------------------------------------------------------------
    "telemetry": {
        "events_file": "repro/obs/events.py",
        "validate_file": "repro/obs/validate.py",
        "timeseries_file": "repro/obs/timeseries.py",
        "emitter_files": [
            "repro/sim/engine.py",
            "repro/sim/vector_engine.py",
            "repro/sim/fleet.py",
            "repro/sim/faults.py",
            "repro/obs/timeseries.py",
        ],
        "unvalidated_families_ok": {
            "threshold": "per-boundary count varies with pool count P; "
            "optional trajectory family",
            "calib_err": "per-category diagnostics; category count is "
            "config-dependent",
            "ema_ratio": "per-category diagnostics; category count is "
            "config-dependent",
        },
    },
}


def manifest_dict() -> dict:
    """Deep copy of the default tolerance manifest."""
    return copy.deepcopy(DEFAULT_MANIFEST)


def manifest_json(indent: int = 2) -> str:
    return json.dumps(DEFAULT_MANIFEST, indent=indent, sort_keys=False)
