"""simlint core: source loading, suppressions, rule registry, runner.

Everything here is stdlib-only (``ast`` + ``re`` + ``pathlib``) so the CI
gate can run without installing the numeric stack.

Vocabulary
----------
``SourceFile``
    One parsed ``.py`` file: raw text, AST, and the per-line suppression
    table built from ``# simlint: disable=<rule>[,<rule>...]`` comments.
``Rule``
    A named check.  File-scoped rules implement :meth:`Rule.check` and
    see one file at a time; project-scoped rules (``project = True``)
    implement :meth:`Rule.check_project` and see the whole parsed file
    set at once (used for cross-engine parity and schema wiring).
``Finding``
    One violation: rule name, file, line, message, and a fix hint.

Suppression semantics: a finding at line *L* is dropped when line *L* or
line *L-1* carries a ``# simlint: disable=`` comment naming the rule (or
``all``).  Project rules anchor cross-file findings to a concrete line in
the offending file, so the same mechanism covers them.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence

_SUPPRESS_RE = re.compile(r"#\s*simlint:\s*disable(?:=([A-Za-z0-9_,\- ]+))?")


@dataclass(frozen=True)
class Finding:
    """One rule violation with enough context to jump to and fix it."""

    rule: str
    path: str
    line: int
    message: str
    hint: str = ""

    def format(self) -> str:
        out = f"{self.path}:{self.line}: [{self.rule}] {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "message": self.message,
            "hint": self.hint,
        }


@dataclass
class SourceFile:
    """A parsed source file plus its simlint suppression table."""

    path: Path
    text: str
    tree: ast.AST
    # line number -> set of suppressed rule names (or {"all"})
    suppressions: Dict[int, set] = field(default_factory=dict)

    @property
    def ident(self) -> str:
        """Stable repo-relative identity, e.g. ``repro/sim/engine.py``.

        Starts at the last ``repro`` path component when present so the
        tolerance manifest can name files independently of where the
        checkout (or a test fixture tree) lives on disk.
        """
        parts = self.path.as_posix().split("/")
        if "repro" in parts:
            i = len(parts) - 1 - parts[::-1].index("repro")
            return "/".join(parts[i:])
        return self.path.name

    @classmethod
    def load(cls, path: Path) -> "SourceFile":
        text = path.read_text()
        tree = ast.parse(text, filename=str(path))
        sup: Dict[int, set] = {}
        for i, line in enumerate(text.splitlines(), start=1):
            m = _SUPPRESS_RE.search(line)
            if m is None:
                continue
            names = m.group(1)
            if names is None:
                sup[i] = {"all"}
            else:
                sup[i] = {n.strip() for n in names.split(",") if n.strip()}
        return cls(path=path, text=text, tree=tree, suppressions=sup)

    def matches(self, manifest_path: str) -> bool:
        """True when this file is the one a manifest entry names."""
        ident = self.ident
        return ident == manifest_path or ident.endswith("/" + manifest_path)

    def suppressed(self, line: int, rule: str) -> bool:
        for ln in (line, line - 1):
            names = self.suppressions.get(ln)
            if names and (rule in names or "all" in names):
                return True
        return False


class Rule:
    """Base class for simlint rules.  Subclass + :func:`register`."""

    name: str = ""
    description: str = ""
    project: bool = False  # project rules see all files at once

    def __init__(self, manifest: Optional[dict] = None):
        if manifest is None:
            from repro.analysis.manifest import DEFAULT_MANIFEST

            manifest = DEFAULT_MANIFEST
        self.manifest = manifest

    # file-scoped entry point
    def check(self, sf: SourceFile) -> Iterable[Finding]:
        return ()

    # project-scoped entry point
    def check_project(self, files: Sequence[SourceFile]) -> Iterable[Finding]:
        return ()


_REGISTRY: Dict[str, type] = {}


def register(cls: type) -> type:
    """Class decorator adding a Rule subclass to the global registry."""
    if not cls.name:
        raise ValueError(f"rule class {cls.__name__} has no name")
    _REGISTRY[cls.name] = cls
    return cls


def registered_rules() -> Dict[str, type]:
    _ensure_builtin_rules()
    return dict(_REGISTRY)


def default_rules(manifest: Optional[dict] = None) -> List[Rule]:
    """Fresh instances of every registered rule (optionally with a
    fixture manifest — tests use this to seed tolerances)."""
    _ensure_builtin_rules()
    return [cls(manifest) for cls in _REGISTRY.values()]


def _ensure_builtin_rules() -> None:
    # Importing the rule modules registers them; idempotent.
    from repro.analysis import dtype, guards, parity, purity, schema  # noqa: F401


def iter_python_files(paths: Sequence) -> List[Path]:
    out: List[Path] = []
    seen = set()
    for p in paths:
        p = Path(p)
        if p.is_dir():
            cands = sorted(q for q in p.rglob("*.py") if "__pycache__" not in q.parts)
        elif p.suffix == ".py":
            cands = [p]
        else:
            continue
        for q in cands:
            r = q.resolve()
            if r not in seen:
                seen.add(r)
                out.append(q)
    return out


def analyze_files(
    files: Sequence[SourceFile], rules: Optional[Sequence[Rule]] = None
) -> List[Finding]:
    """Run rules over already-parsed files, honoring suppressions."""
    if rules is None:
        rules = default_rules()
    by_ident = {sf.ident: sf for sf in files}
    findings: List[Finding] = []

    def keep(f: Finding) -> bool:
        sf = by_ident.get(f.path) or next(
            (s for s in files if str(s.path) == f.path), None
        )
        return sf is None or not sf.suppressed(f.line, f.rule)

    for rule in rules:
        if rule.project:
            findings.extend(f for f in rule.check_project(files) if keep(f))
        else:
            for sf in files:
                findings.extend(f for f in rule.check(sf) if keep(f))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def analyze_paths(
    paths: Sequence, rules: Optional[Sequence[Rule]] = None
) -> List[Finding]:
    """Parse ``paths`` (files or directories) and run the rule set."""
    files = [SourceFile.load(p) for p in iter_python_files(paths)]
    return analyze_files(files, rules)


# ---------------------------------------------------------------------------
# Shared AST helpers used by several rules.
# ---------------------------------------------------------------------------


def unparse(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:  # pragma: no cover - malformed synthetic nodes only
        return "<expr>"


def receiver_of(call: ast.Call) -> Optional[ast.expr]:
    """For ``a.b.meth(...)`` return the ``a.b`` expression, else None."""
    if isinstance(call.func, ast.Attribute):
        return call.func.value
    return None


def final_attr(expr: ast.expr) -> Optional[str]:
    """Trailing attribute name of a receiver: ``self.tracer`` -> ``tracer``."""
    if isinstance(expr, ast.Attribute):
        return expr.attr
    if isinstance(expr, ast.Name):
        return expr.id
    return None


def walk_functions(tree: ast.AST) -> Iterator[ast.AST]:
    """Yield every function/async-function definition in the tree."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def enclosing_map(tree: ast.AST) -> Dict[ast.AST, ast.AST]:
    """node -> nearest enclosing function/class def (parent scope map)."""
    out: Dict[ast.AST, ast.AST] = {}

    def visit(node: ast.AST, scope: Optional[ast.AST]) -> None:
        for child in ast.iter_child_nodes(node):
            if scope is not None:
                out[child] = scope
            nxt = scope
            if isinstance(
                child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
            ):
                nxt = child
            visit(child, nxt)

    visit(tree, None)
    return out


def scope_chain(node: ast.AST, enclosing: Dict[ast.AST, ast.AST]) -> List[str]:
    """Names of the function/class scopes containing ``node``, inner-first."""
    chain: List[str] = []
    cur = enclosing.get(node)
    while cur is not None:
        chain.append(getattr(cur, "name", "<scope>"))
        cur = enclosing.get(cur)
    return chain
