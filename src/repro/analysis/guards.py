"""guard-discipline: zero-cost-when-off hooks must be None-guarded.

The DES hot paths promise "telemetry/tracing/faults off" runs are
bit-identical to runs of a build with the hooks deleted.  That only
holds if every emission site is *dominated* by an ``is None`` guard on
its receiver.  This rule checks, intra-procedurally, that each watched
call is reachable only where the receiver is proven non-None:

* ``if self.tracer is not None: ...`` (including ``and``-conjunctions:
  ``if self.tracer is not None and mask.any(): ...``),
* early-return style: ``if self.tracer is None: return ...`` followed by
  unguarded use in the remainder of the block,
* conditional expressions: ``x.m() if x is not None else d``,
* short-circuits: ``x is not None and x.emit(...)``,
* ``assert x is not None``.

Watched receivers are *attribute* expressions only (``self.tracer``);
bare local names are assumed to be aliases hoisted inside an already
guarded region (the common ``rt = self._fault_rt`` pattern — a local
alias's None-ness is not re-derivable syntactically).  Nested function
definitions start from an empty guard set: a closure may be called from
anywhere, so it must re-guard (or stay off the watched set).
"""

from __future__ import annotations

import ast
from typing import FrozenSet, Iterable, List, Set, Tuple

from repro.analysis.core import (
    Finding,
    Rule,
    SourceFile,
    final_attr,
    receiver_of,
    register,
    unparse,
)

# (receiver trailing attribute names, watched method names or None=any)
WATCHED: Tuple[Tuple[FrozenSet[str], FrozenSet[str]], ...] = (
    (frozenset({"tracer", "events"}), frozenset({"emit"})),
    (frozenset({"telemetry"}), frozenset({"sample", "set_trace"})),
    (frozenset({"_fault_rt"}), frozenset()),  # empty set = any method
)


def _is_none(node: ast.expr) -> bool:
    return isinstance(node, ast.Constant) and node.value is None


def _guard_sets(test: ast.expr) -> Tuple[Set[str], Set[str]]:
    """(non-None-if-true, non-None-if-false) receiver keys for a test."""
    if isinstance(test, ast.Compare) and len(test.ops) == 1:
        left, right = test.left, test.comparators[0]
        if _is_none(right):
            expr = left
        elif _is_none(left):
            expr = right
        else:
            return set(), set()
        key = unparse(expr)
        if isinstance(test.ops[0], ast.IsNot):
            return {key}, set()
        if isinstance(test.ops[0], ast.Is):
            return set(), {key}
        return set(), set()
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        pos, neg = _guard_sets(test.operand)
        return neg, pos
    if isinstance(test, ast.BoolOp):
        if isinstance(test.op, ast.And):
            pos: Set[str] = set()
            for v in test.values:
                pos |= _guard_sets(v)[0]
            return pos, set()
        neg: Set[str] = set()
        for v in test.values:
            neg |= _guard_sets(v)[1]
        return set(), neg
    return set(), set()


def _terminates(stmts: List[ast.stmt]) -> bool:
    """True when the block always leaves the enclosing suite."""
    if not stmts:
        return False
    last = stmts[-1]
    if isinstance(last, (ast.Return, ast.Raise, ast.Continue, ast.Break)):
        return True
    if isinstance(last, ast.If):
        return _terminates(last.body) and _terminates(last.orelse)
    return False


@register
class GuardDisciplineRule(Rule):
    name = "guard-discipline"
    description = (
        "tracer/telemetry/fault-runtime emission sites must be dominated "
        "by an `is None` guard so off-mode stays bit-identical"
    )

    def check(self, sf: SourceFile) -> Iterable[Finding]:
        findings: List[Finding] = []
        self._walk_stmts(getattr(sf.tree, "body", []), frozenset(), sf, findings)
        return findings

    # -- statement-level domination walk ---------------------------------

    def _walk_stmts(self, stmts, guarded, sf, findings) -> None:
        g: Set[str] = set(guarded)
        for st in stmts:
            if isinstance(st, ast.If):
                self._scan_expr(st.test, g, sf, findings)
                pos, neg = _guard_sets(st.test)
                self._walk_stmts(st.body, frozenset(g | pos), sf, findings)
                self._walk_stmts(st.orelse, frozenset(g | neg), sf, findings)
                if neg and _terminates(st.body):
                    g |= neg  # `if x is None: return` dominates the rest
                if pos and st.orelse and _terminates(st.orelse):
                    g |= pos
            elif isinstance(st, ast.Assert):
                self._scan_expr(st.test, g, sf, findings)
                g |= _guard_sets(st.test)[0]
            elif isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for d in st.decorator_list:
                    self._scan_expr(d, g, sf, findings)
                self._walk_stmts(st.body, frozenset(), sf, findings)
            elif isinstance(st, ast.ClassDef):
                for d in st.decorator_list:
                    self._scan_expr(d, g, sf, findings)
                self._walk_stmts(st.body, frozenset(), sf, findings)
            elif isinstance(st, (ast.For, ast.AsyncFor)):
                self._scan_expr(st.iter, g, sf, findings)
                self._walk_stmts(st.body, frozenset(g), sf, findings)
                self._walk_stmts(st.orelse, frozenset(g), sf, findings)
            elif isinstance(st, ast.While):
                self._scan_expr(st.test, g, sf, findings)
                pos, _ = _guard_sets(st.test)
                self._walk_stmts(st.body, frozenset(g | pos), sf, findings)
                self._walk_stmts(st.orelse, frozenset(g), sf, findings)
            elif isinstance(st, (ast.With, ast.AsyncWith)):
                for item in st.items:
                    self._scan_expr(item.context_expr, g, sf, findings)
                self._walk_stmts(st.body, frozenset(g), sf, findings)
            elif isinstance(st, ast.Try):
                self._walk_stmts(st.body, frozenset(g), sf, findings)
                for h in st.handlers:
                    self._walk_stmts(h.body, frozenset(g), sf, findings)
                self._walk_stmts(st.orelse, frozenset(g), sf, findings)
                self._walk_stmts(st.finalbody, frozenset(g), sf, findings)
            else:
                for child in ast.iter_child_nodes(st):
                    if isinstance(child, ast.expr):
                        self._scan_expr(child, g, sf, findings)

    # -- expression-level walk with short-circuit guard tracking ---------

    def _scan_expr(self, expr, guarded, sf, findings) -> None:
        g: Set[str] = set(guarded)
        if isinstance(expr, ast.BoolOp):
            for v in expr.values:
                self._scan_expr(v, g, sf, findings)
                pos, neg = _guard_sets(v)
                g |= pos if isinstance(expr.op, ast.And) else neg
            return
        if isinstance(expr, ast.IfExp):
            self._scan_expr(expr.test, g, sf, findings)
            pos, neg = _guard_sets(expr.test)
            self._scan_expr(expr.body, g | pos, sf, findings)
            self._scan_expr(expr.orelse, g | neg, sf, findings)
            return
        if isinstance(expr, ast.Lambda):
            self._scan_expr(expr.body, frozenset(), sf, findings)
            return
        if isinstance(expr, ast.Call):
            self._check_call(expr, g, sf, findings)
        for child in ast.iter_child_nodes(expr):
            if isinstance(child, ast.expr):
                self._scan_expr(child, g, sf, findings)

    def _check_call(self, call: ast.Call, guarded, sf, findings) -> None:
        recv = receiver_of(call)
        if recv is None or not isinstance(recv, ast.Attribute):
            return  # bare-name receivers are hoisted aliases; see docstring
        meth = call.func.attr  # type: ignore[union-attr]
        attr = final_attr(recv)
        watched = any(
            attr in attrs and (not meths or meth in meths)
            for attrs, meths in WATCHED
        )
        if not watched:
            return
        key = unparse(recv)
        if key in guarded:
            return
        findings.append(
            Finding(
                rule=self.name,
                path=sf.ident,
                line=call.lineno,
                message=(
                    f"`{key}.{meth}(...)` is not dominated by a "
                    f"`{key} is None` guard"
                ),
                hint=(
                    f"wrap the call in `if {key} is not None:` (or early-"
                    f"return when it is None) so hooks-off runs stay "
                    f"bit-identical and zero-cost"
                ),
            )
        )
