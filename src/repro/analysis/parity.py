"""engine-parity: the three DES backends must write the same contract.

The repo's core guarantee is that ``FleetSim(backend="reference" |
"vectorized" | "jax")`` are interchangeable.  This project-scoped rule
statically checks the written surface of that contract against the
tolerance manifest:

* **counters** — every canonical counter (``preemption_count``,
  ``rejection_count``, ``truncation_count``) is incremented by each
  engine under its manifest-declared symbol (host engines bump
  ``self.<name>``; the jax tier carries dict keys like ``"npre"``
  through the jitted while_loop).  A ``self.*_count`` counter that one
  host engine writes but the manifest doesn't know is flagged: add it
  to all three engines *and* the manifest.
* **event kinds** — the hot-path event sets emitted by the host engines
  must match the canonical set exactly; jax-tier omissions must be
  declared (with reasons) under ``events.missing_ok``.
* **FleetResult fields** — each backend's ``FleetResult(...)``
  constructor call passes the reference tier's canonical keyword set,
  minus only the fields declared missing-by-design for that tier.

The rule only fires when the analyzed file set contains the engine
files the manifest names, so running simlint on a subtree (or a test
fixture tree) skips it silently unless the fixtures provide them.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set

from repro.analysis.core import (
    Finding,
    Rule,
    SourceFile,
    final_attr,
    receiver_of,
    register,
)


def _host_counters(sf: SourceFile) -> Dict[str, int]:
    """``self.<x>_count += ...`` target names -> first line seen."""
    out: Dict[str, int] = {}
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.AugAssign) or not isinstance(
            node.op, ast.Add
        ):
            continue
        t = node.target
        if (
            isinstance(t, ast.Attribute)
            and isinstance(t.value, ast.Name)
            and t.value.id == "self"
            and t.attr.endswith("_count")
        ):
            out.setdefault(t.attr, node.lineno)
    return out


def _string_constant_count(sf: SourceFile, value: str) -> int:
    return sum(
        1
        for n in ast.walk(sf.tree)
        if isinstance(n, ast.Constant) and n.value == value
    )


def _emitted_kinds(sf: SourceFile) -> Set[str]:
    """Lower-cased event constant names passed to tracer/events .emit()."""
    kinds: Set[str] = set()
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        if not (
            isinstance(node.func, ast.Attribute) and node.func.attr == "emit"
        ):
            continue
        recv = receiver_of(node)
        if recv is None or final_attr(recv) not in ("tracer", "events"):
            continue
        if node.args and isinstance(node.args[0], ast.Name):
            kinds.add(node.args[0].id.lower())
    return kinds


def _fleet_result_calls(sf: SourceFile, function: str) -> List[ast.Call]:
    """FleetResult(...) call sites lexically inside ``function``."""
    out: List[ast.Call] = []
    for fn in ast.walk(sf.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if fn.name != function:
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                callee = node.func
                name = (
                    callee.id
                    if isinstance(callee, ast.Name)
                    else callee.attr if isinstance(callee, ast.Attribute) else ""
                )
                if name == "FleetResult":
                    out.append(node)
    return out


@register
class EngineParityRule(Rule):
    name = "engine-parity"
    description = (
        "counter fields, event kinds, and FleetResult fields must match "
        "across the reference/vectorized/jax engines, modulo the "
        "tolerance manifest"
    )
    project = True

    def check_project(self, files: Sequence[SourceFile]) -> Iterable[Finding]:
        engines: Dict[str, str] = self.manifest.get("engines", {})
        located: Dict[str, SourceFile] = {}
        for eng, path in engines.items():
            sf = self._find(files, path)
            if sf is not None:
                located[eng] = sf
        if len(located) < 2:
            return ()  # partial tree: nothing to compare
        findings: List[Finding] = []
        findings.extend(self._check_counters(located))
        findings.extend(self._check_events(located))
        findings.extend(self._check_fleet_result(files, located))
        return findings

    @staticmethod
    def _find(files: Sequence[SourceFile], path: str) -> Optional[SourceFile]:
        for sf in files:
            if sf.matches(path):
                return sf
        return None

    # -- counters --------------------------------------------------------

    def _check_counters(self, located: Dict[str, SourceFile]) -> Iterable[Finding]:
        out: List[Finding] = []
        counters: Dict[str, Dict[str, str]] = self.manifest.get("counters", {})
        known_symbols: Dict[str, Set[str]] = {}
        for canonical, per_engine in counters.items():
            for eng, sym in per_engine.items():
                known_symbols.setdefault(eng, set()).add(sym)

        for eng, sf in located.items():
            if eng == "jax":
                for canonical, per_engine in counters.items():
                    sym = per_engine.get(eng)
                    if sym is None:
                        continue
                    # carried counters appear at least twice: the init
                    # dict literal and the accumulation update.
                    if _string_constant_count(sf, sym) < 2:
                        out.append(
                            Finding(
                                rule=self.name,
                                path=sf.ident,
                                line=1,
                                message=(
                                    f"jax engine never carries counter key "
                                    f'"{sym}" (canonical `{canonical}`)'
                                ),
                                hint=(
                                    "add the key to the while_loop carry "
                                    "init and accumulate it, or update the "
                                    "manifest counters table"
                                ),
                            )
                        )
                continue
            written = _host_counters(sf)
            for canonical, per_engine in counters.items():
                sym = per_engine.get(eng)
                if sym is not None and sym not in written:
                    out.append(
                        Finding(
                            rule=self.name,
                            path=sf.ident,
                            line=1,
                            message=(
                                f"{eng} engine never increments "
                                f"`self.{sym}` (canonical `{canonical}`)"
                            ),
                            hint=(
                                "all three engines must write the same "
                                "counter set; see the manifest counters table"
                            ),
                        )
                    )
            for sym, line in written.items():
                if sym not in known_symbols.get(eng, set()):
                    out.append(
                        Finding(
                            rule=self.name,
                            path=sf.ident,
                            line=line,
                            message=(
                                f"counter `self.{sym}` is written by the "
                                f"{eng} engine but missing from the parity "
                                f"manifest"
                            ),
                            hint=(
                                "add it to every engine and to the manifest "
                                "counters table (with per-engine symbols)"
                            ),
                        )
                    )
        return out

    # -- event kinds -----------------------------------------------------

    def _check_events(self, located: Dict[str, SourceFile]) -> Iterable[Finding]:
        out: List[Finding] = []
        cfg = self.manifest.get("events", {})
        canonical = set(cfg.get("canonical", []))
        missing_ok: Dict[str, Dict[str, str]] = cfg.get("missing_ok", {})
        for eng, sf in located.items():
            emitted = _emitted_kinds(sf)
            allowed_missing = set(missing_ok.get(eng, {}))
            for kind in sorted(canonical - emitted - allowed_missing):
                out.append(
                    Finding(
                        rule=self.name,
                        path=sf.ident,
                        line=1,
                        message=(
                            f"{eng} engine never emits canonical event kind "
                            f"`{kind}`"
                        ),
                        hint=(
                            "emit it on the hot path (guarded) or declare "
                            "the omission with a reason under "
                            "events.missing_ok in the manifest"
                        ),
                    )
                )
            for kind in sorted(emitted - canonical):
                out.append(
                    Finding(
                        rule=self.name,
                        path=sf.ident,
                        line=1,
                        message=(
                            f"{eng} engine emits event kind `{kind}` that is "
                            f"not in the canonical engine event set"
                        ),
                        hint=(
                            "add the kind to events.canonical and to the "
                            "other engines (or their missing_ok entries)"
                        ),
                    )
                )
        return out

    # -- FleetResult construction ---------------------------------------

    def _check_fleet_result(
        self, files: Sequence[SourceFile], located: Dict[str, SourceFile]
    ) -> Iterable[Finding]:
        out: List[Finding] = []
        cfg = self.manifest.get("fleet_result", {})
        ctors: Dict[str, Dict[str, str]] = cfg.get("constructors", {})
        missing_ok: Dict[str, Dict[str, str]] = cfg.get("missing_ok", {})
        ref = ctors.get("reference")
        if ref is None:
            return ()
        ref_sf = self._find(files, ref["file"])
        if ref_sf is None:
            return ()
        ref_calls = _fleet_result_calls(ref_sf, ref["function"])
        if not ref_calls:
            return ()
        baseline = {k.arg for k in ref_calls[0].keywords if k.arg}
        for eng, loc in ctors.items():
            if eng == "reference":
                continue
            sf = self._find(files, loc["file"])
            if sf is None:
                continue
            allowed = set(missing_ok.get(eng, {}))
            for call in _fleet_result_calls(sf, loc["function"]):
                kwargs = {k.arg for k in call.keywords if k.arg}
                for fld in sorted(baseline - kwargs - allowed):
                    out.append(
                        Finding(
                            rule=self.name,
                            path=sf.ident,
                            line=call.lineno,
                            message=(
                                f"{eng} FleetResult omits field `{fld}` the "
                                f"reference tier populates"
                            ),
                            hint=(
                                "populate it or declare it under "
                                "fleet_result.missing_ok with a reason"
                            ),
                        )
                    )
                for fld in sorted(kwargs - baseline):
                    out.append(
                        Finding(
                            rule=self.name,
                            path=sf.ident,
                            line=call.lineno,
                            message=(
                                f"{eng} FleetResult passes field `{fld}` the "
                                f"reference tier does not"
                            ),
                            hint="add it to the reference constructor too",
                        )
                    )
        return out
