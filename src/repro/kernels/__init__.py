"""Pallas TPU kernels for the serving hot spots, with jnp oracles.

* ``flash_attention`` — prefill causal attention (GQA via index-map folding)
* ``paged_attention`` — decode over block-table KV pages (vLLM→TPU port)
* ``ssd_scan``        — Mamba-2 chunked state-space scan

Validated with ``interpret=True`` on CPU against :mod:`repro.kernels.ref`;
compiled by Mosaic on real TPU backends.
"""

from repro.kernels.ops import flash_attention, paged_attention, ssd_scan
from repro.kernels import ref

__all__ = ["flash_attention", "paged_attention", "ssd_scan", "ref"]
