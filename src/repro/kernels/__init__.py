"""Pallas TPU kernels for the serving hot spots, with jnp oracles.

* ``flash_attention``      — prefill causal attention (GQA via index-map
  folding)
* ``paged_attention``      — decode over block-table KV pages (vLLM→TPU
  port)
* ``ssd_scan``             — Mamba-2 chunked state-space scan
* ``decode_advance_pallas`` — the jax DES backend's fused decode-advance
  round (one program per instance row), with ``decode_advance_jnp`` as
  its bit-identical jnp twin/oracle

Validated with ``interpret=True`` on CPU against :mod:`repro.kernels.ref`
(attention/scan, numeric tolerance) and the jnp twin (sim_decode,
bit-identity); compiled by Mosaic on real TPU backends. Off-TPU the
kernels default to interpreter mode so CPU CI still executes the kernel
bodies — ``sim_decode`` additionally keeps the jnp twin as the engine's
default off-TPU path because its float64 event-time contract has no
native TPU execution yet (``REPRO_SIM_PALLAS=1`` forces the kernel).
"""

from jax.experimental.pallas import tpu as _pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; alias the
# old spelling once here (package __init__ runs before any kernel submodule)
# so every kernel can use the new name unconditionally.
if not hasattr(_pltpu, "CompilerParams"):  # pragma: no cover - version shim
    _pltpu.CompilerParams = _pltpu.TPUCompilerParams

from repro.kernels.ops import flash_attention, paged_attention, ssd_scan
from repro.kernels.sim_decode import decode_advance_jnp, decode_advance_pallas
from repro.kernels import ref

__all__ = [
    "flash_attention",
    "paged_attention",
    "ssd_scan",
    "decode_advance_jnp",
    "decode_advance_pallas",
    "ref",
]
