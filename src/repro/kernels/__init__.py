"""Pallas TPU kernels for the serving hot spots, with jnp oracles.

* ``flash_attention`` — prefill causal attention (GQA via index-map folding)
* ``paged_attention`` — decode over block-table KV pages (vLLM→TPU port)
* ``ssd_scan``        — Mamba-2 chunked state-space scan

Validated with ``interpret=True`` on CPU against :mod:`repro.kernels.ref`;
compiled by Mosaic on real TPU backends.
"""

from jax.experimental.pallas import tpu as _pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; alias the
# old spelling once here (package __init__ runs before any kernel submodule)
# so every kernel can use the new name unconditionally.
if not hasattr(_pltpu, "CompilerParams"):  # pragma: no cover - version shim
    _pltpu.CompilerParams = _pltpu.TPUCompilerParams

from repro.kernels.ops import flash_attention, paged_attention, ssd_scan
from repro.kernels import ref

__all__ = ["flash_attention", "paged_attention", "ssd_scan", "ref"]
