"""Fused DES decode-advance pass: the compiled tier's hot inner kernel.

One pool round of the jax DES backend (:mod:`repro.sim.jax_engine`)
spends most of its time in a dense per-instance pass over the
``(instances, n_seq)`` slot arrays: pick the oldest prefilling sequence
and feed it one chunk, compute the event-distance k-jump (completion /
truncation / time-limit, with the KV-growth over-check), advance decode
state, and stage the completion/truncation records for the scatter that
follows. This module implements that pass twice, with identical op
order:

* :func:`decode_advance_jnp` — the reference implementation, pure
  ``jnp`` over the full ``(I, S)`` arrays. This is the oracle and the
  default path on CPU/GPU hosts; it is bit-identical to the NumPy
  engine's ``VectorPoolSim._round`` by construction (same formulas,
  same IEEE-754 op order, float64 event times).
* :func:`decode_advance_pallas` — a Pallas kernel, grid ``(I,)`` with
  one program per instance row, each block a ``(1, S)`` slot row in
  VMEM. On non-TPU backends it runs in **interpreter mode**
  (``interpret=True``, the :mod:`repro.kernels` convention) so CPU CI
  exercises the kernel body; on TPU it compiles via Mosaic. Note the
  event-time contract is float64, which TPUs do not execute natively —
  the compiled-TPU path is a forward-looking port target, and the
  engine selects the jnp twin by default off-TPU
  (``REPRO_SIM_PALLAS=1`` forces the kernel, used by the parity tests).

Both paths return the same dict of advanced arrays and staging masks;
``tests/test_kernels.py`` asserts they are bit-identical in interpreter
mode and ``tests/test_vector_engine.py`` runs a whole fleet through the
forced-Pallas engine against the scalar reference engine.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.pools import KV_BLOCK_TOKENS

#: Sentinels for "no constraint" in masked min-reductions (int32-safe).
_BIG_I = 1 << 30
_BIG_F = 1.0e18


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _blocks_for(tok):
    return jnp.maximum(1, (tok + (KV_BLOCK_TOKENS - 1)) // KV_BLOCK_TOKENS)


def decode_advance_jnp(
    t_limit,  # scalar f64 — sweep boundary (next arrival / inf)
    busy,  # (I,) bool — due instances with active sequences
    now,  # (I,) f64 — per-instance wake time (0 where not busy)
    nact,  # (I,) i32 — active sequences per instance
    free,  # (I,) i32 — free KV blocks per instance
    occ,  # (I, S) bool — slot occupied
    pre,  # (I, S) i32 — prefill tokens remaining
    sq,  # (I, S) i32 — admission sequence number (age tie-break)
    inp,  # (I, S) i32 — input tokens
    gen,  # (I, S) i32 — generated tokens
    rem,  # (I, S) i32 — output tokens remaining
    blk,  # (I, S) i32 — KV blocks held
    ft,  # (I, S) f64 — first-token time (nan = not yet)
    tr,  # (I, S) bool — truncated flag
    *,
    w: float,
    h: float,
    chunk: int,
    c_max: int,
):
    """One fused decode-advance over the full slot arrays (the oracle).

    Identical formulas and op order to ``VectorPoolSim._round``'s
    k-jump/advance section; every float op is float64. Returns a dict:
    ``pre`` (post-chunk prefill), ``dec`` (decoding mask), ``k``/``end``
    (jump length and end-of-round time per instance), advanced
    ``gen``/``rem``/``ft``/``tr``, ``trunc_new`` (this-round truncation
    mask) and ``comp`` (completion mask) for the record scatter.
    """
    f64 = jnp.float64
    i32 = jnp.int32
    I, _ = occ.shape
    t_it = w + h * nact.astype(f64)
    bb = busy[:, None]

    # one prefill chunk to the oldest prefilling sequence
    pmask = occ & (pre > 0)
    has_pre = pmask.any(axis=1) & busy
    oldest = jnp.argmin(jnp.where(pmask, sq, _BIG_I), axis=1)
    # One-hot select/subtract instead of a row gather + scatter:
    # XLA:CPU expands even a one-update-per-row scatter into a serial
    # while loop; the masked eltwise form fuses away (identical integer
    # arithmetic — the one-hot row sum selects exactly one slot).
    oh = jnp.arange(occ.shape[1])[None, :] == oldest[:, None]
    take = jnp.minimum(
        jnp.sum(jnp.where(oh, pre, 0), axis=1, dtype=i32), chunk
    )
    pre_arr = pre - jnp.where(oh & has_pre[:, None], take[:, None], 0)

    # event-distance k-jump (identical formulas to the host round)
    dec = occ & (pre_arr == 0) & (rem > 0)
    ctx0 = inp + gen
    k_complete = jnp.min(jnp.where(dec, rem, _BIG_I), axis=1)
    k_trunc = jnp.min(jnp.where(dec, c_max - ctx0, _BIG_I), axis=1)
    q = (t_limit - now) / t_it
    k_time = jnp.where(jnp.isfinite(q), jnp.ceil(q - 1e-9), _BIG_F)
    k = jnp.minimum(jnp.minimum(k_complete, k_trunc).astype(f64), k_time)
    k = jnp.where(has_pre, 1.0, jnp.maximum(k, 1.0))
    k = jnp.minimum(k, float(_BIG_I)).astype(i32)

    def growth(kk):
        ng = gen + jnp.where(dec, kk[:, None], 0)
        nd = jnp.where(occ, _blocks_for(inp + ng), 0)
        return jnp.maximum(nd - blk, 0).sum(axis=1, dtype=i32)

    over = busy & (growth(k) > free)
    k = jnp.where(over, 1, k)
    end = now + k.astype(f64) * t_it

    # advance + stage completion/truncation for the record scatter
    kcol = jnp.where(dec, k[:, None], 0)
    gen_a = gen + kcol
    rem_a = rem - kcol
    ft_a = jnp.where(dec & jnp.isnan(ft), (now + t_it)[:, None], ft)
    trunc_n = dec & (inp + gen_a >= c_max) & (rem_a > 0) & bb
    rem_a = jnp.where(trunc_n, 0, rem_a)
    tr_a = tr | trunc_n
    comp = dec & (rem_a == 0) & bb
    return {
        "pre": pre_arr,
        "dec": dec,
        "k": k,
        "end": end,
        "gen": gen_a,
        "rem": rem_a,
        "ft": ft_a,
        "trunc_new": trunc_n,
        "tr": tr_a,
        "comp": comp,
    }


def _decode_kernel(
    tlim_ref,  # (1, 1) f64
    busy_ref,  # (1, 1) bool
    now_ref,  # (1, 1) f64
    nact_ref,  # (1, 1) i32
    free_ref,  # (1, 1) i32
    occ_ref,  # (1, S) bool
    pre_ref,  # (1, S) i32
    sq_ref,  # (1, S) i32
    inp_ref,  # (1, S) i32
    gen_ref,  # (1, S) i32
    rem_ref,  # (1, S) i32
    blk_ref,  # (1, S) i32
    ft_ref,  # (1, S) f64
    tr_ref,  # (1, S) bool
    pre_out,  # (1, S) i32
    dec_out,  # (1, S) bool
    k_out,  # (1, 1) i32
    end_out,  # (1, 1) f64
    gen_out,  # (1, S) i32
    rem_out,  # (1, S) i32
    ft_out,  # (1, S) f64
    trn_out,  # (1, S) bool
    tra_out,  # (1, S) bool
    comp_out,  # (1, S) bool
    *,
    w: float,
    h: float,
    chunk: int,
    c_max: int,
):
    """Per-instance program: the same pass, one (1, S) slot row at a time."""
    f64 = jnp.float64
    i32 = jnp.int32
    t_limit = tlim_ref[0, 0]
    busy = busy_ref[0, 0]
    now = now_ref[0, 0]
    nact = nact_ref[0, 0]
    free = free_ref[0, 0]
    occ = occ_ref[...]
    pre = pre_ref[...]
    sq = sq_ref[...]
    inp = inp_ref[...]
    gen = gen_ref[...]
    rem = rem_ref[...]
    blk = blk_ref[...]
    ft = ft_ref[...]
    tr = tr_ref[...]

    t_it = w + h * nact.astype(f64)
    pmask = occ & (pre > 0)
    has_pre = jnp.any(pmask) & busy
    oldest = jnp.argmin(jnp.where(pmask, sq, _BIG_I))
    take = jnp.minimum(pre[0, oldest], chunk)
    pre_arr = pre.at[0, oldest].add(jnp.where(has_pre, -take, 0))

    dec = occ & (pre_arr == 0) & (rem > 0)
    ctx0 = inp + gen
    k_complete = jnp.min(jnp.where(dec, rem, _BIG_I))
    k_trunc = jnp.min(jnp.where(dec, c_max - ctx0, _BIG_I))
    q = (t_limit - now) / t_it
    k_time = jnp.where(jnp.isfinite(q), jnp.ceil(q - 1e-9), _BIG_F)
    k = jnp.minimum(jnp.minimum(k_complete, k_trunc).astype(f64), k_time)
    k = jnp.where(has_pre, 1.0, jnp.maximum(k, 1.0))
    k = jnp.minimum(k, float(_BIG_I)).astype(i32)

    ng = gen + jnp.where(dec, k, 0)
    nd = jnp.where(occ, _blocks_for(inp + ng), 0)
    over = busy & (jnp.maximum(nd - blk, 0).sum(dtype=i32) > free)
    k = jnp.where(over, 1, k)
    end = now + k.astype(f64) * t_it

    kcol = jnp.where(dec, k, 0)
    gen_a = gen + kcol
    rem_a = rem - kcol
    ft_a = jnp.where(dec & jnp.isnan(ft), now + t_it, ft)
    trunc_n = dec & (inp + gen_a >= c_max) & (rem_a > 0) & busy
    rem_a = jnp.where(trunc_n, 0, rem_a)
    tr_a = tr | trunc_n
    comp = dec & (rem_a == 0) & busy

    pre_out[...] = pre_arr
    dec_out[...] = dec
    k_out[0, 0] = k
    end_out[0, 0] = end
    gen_out[...] = gen_a
    rem_out[...] = rem_a
    ft_out[...] = ft_a
    trn_out[...] = trunc_n
    tra_out[...] = tr_a
    comp_out[...] = comp


def decode_advance_pallas(
    t_limit,
    busy,
    now,
    nact,
    free,
    occ,
    pre,
    sq,
    inp,
    gen,
    rem,
    blk,
    ft,
    tr,
    *,
    w: float,
    h: float,
    chunk: int,
    c_max: int,
    interpret: bool | None = None,
):
    """Pallas twin of :func:`decode_advance_jnp` (same signature + dict).

    Grid ``(I,)``; each program owns one instance's ``(1, S)`` slot row.
    ``interpret`` defaults to True off-TPU so CPU CI runs the kernel
    body through the Pallas interpreter.
    """
    if interpret is None:
        interpret = _default_interpret()
    I, S = occ.shape
    f64 = jnp.float64
    i32 = jnp.int32

    col = lambda v, dt: jnp.asarray(v, dt).reshape(I, 1)  # noqa: E731
    tlim2 = jnp.asarray(t_limit, f64).reshape(1, 1)
    row_spec = pl.BlockSpec((1, S), lambda i: (i, 0))
    col_spec = pl.BlockSpec((1, 1), lambda i: (i, 0))
    scl_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))

    kernel = functools.partial(
        _decode_kernel, w=w, h=h, chunk=chunk, c_max=c_max
    )
    outs = pl.pallas_call(
        kernel,
        grid=(I,),
        in_specs=[
            scl_spec,  # t_limit
            col_spec,  # busy
            col_spec,  # now
            col_spec,  # nact
            col_spec,  # free
            row_spec,  # occ
            row_spec,  # pre
            row_spec,  # sq
            row_spec,  # inp
            row_spec,  # gen
            row_spec,  # rem
            row_spec,  # blk
            row_spec,  # ft
            row_spec,  # tr
        ],
        out_specs=[
            row_spec,  # pre
            row_spec,  # dec
            col_spec,  # k
            col_spec,  # end
            row_spec,  # gen
            row_spec,  # rem
            row_spec,  # ft
            row_spec,  # trunc_new
            row_spec,  # tr
            row_spec,  # comp
        ],
        out_shape=[
            jax.ShapeDtypeStruct((I, S), i32),
            jax.ShapeDtypeStruct((I, S), jnp.bool_),
            jax.ShapeDtypeStruct((I, 1), i32),
            jax.ShapeDtypeStruct((I, 1), f64),
            jax.ShapeDtypeStruct((I, S), i32),
            jax.ShapeDtypeStruct((I, S), i32),
            jax.ShapeDtypeStruct((I, S), f64),
            jax.ShapeDtypeStruct((I, S), jnp.bool_),
            jax.ShapeDtypeStruct((I, S), jnp.bool_),
            jax.ShapeDtypeStruct((I, S), jnp.bool_),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(
        tlim2,
        col(busy, jnp.bool_),
        col(now, f64),
        col(nact, i32),
        col(free, i32),
        occ,
        pre,
        sq,
        inp,
        gen,
        rem,
        blk,
        ft,
        tr,
    )
    pre_a, dec, k, end, gen_a, rem_a, ft_a, trn, tra, comp = outs
    return {
        "pre": pre_a,
        "dec": dec,
        "k": k.reshape(I),
        "end": end.reshape(I),
        "gen": gen_a,
        "rem": rem_a,
        "ft": ft_a,
        "trunc_new": trn,
        "tr": tra,
        "comp": comp,
    }
