"""Jit'd public wrappers around the Pallas kernels.

Layout adaptation between the model code's (B, L, H, D) convention and the
kernels' head-major tiling, plus automatic ``interpret=True`` on non-TPU
backends (this container is CPU-only; TPU is the compile target).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,  # (B, L, H, D) — model layout
    k: jax.Array,  # (B, L, K, D)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = _default_interpret()
    qh = jnp.swapaxes(q, 1, 2)  # (B, H, L, D)
    kh = jnp.swapaxes(k, 1, 2)
    vh = jnp.swapaxes(v, 1, 2)
    out = flash_attention_pallas(
        qh, kh, vh, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )
    return jnp.swapaxes(out, 1, 2)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(
    q: jax.Array,  # (B, H, D)
    k_pages: jax.Array,  # (P, page, K, D) — bf16/f32, or int8 (+scales)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, pages_per_seq) int32
    lengths: jax.Array,  # (B,) int32
    k_scales: jax.Array | None = None,  # (P, page, K, 1) for int8 pages
    v_scales: jax.Array | None = None,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = _default_interpret()
    return paged_attention_pallas(
        q, k_pages, v_pages, block_tables, lengths,
        k_scales=k_scales, v_scales=v_scales, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,  # (B, L, H, P) — model layout
    dt: jax.Array,  # (B, L, H) positive
    a_neg: jax.Array,  # (H,) negative decay
    b_mat: jax.Array,  # (B, L, N)
    c_mat: jax.Array,  # (B, L, N)
    *,
    chunk: int = 128,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    if interpret is None:
        interpret = _default_interpret()
    # fold dt into x and decay (kernel is a pure gated scan)
    xh = jnp.swapaxes(x * dt[..., None].astype(x.dtype), 1, 2)  # (B,H,L,P)
    log_a = jnp.swapaxes(
        a_neg[None, None, :].astype(jnp.float32) * dt.astype(jnp.float32), 1, 2
    )  # (B, H, L)
    y, s_final = ssd_scan_pallas(
        xh, log_a, b_mat, c_mat, chunk=chunk, interpret=interpret
    )
    return jnp.swapaxes(y, 1, 2), s_final
