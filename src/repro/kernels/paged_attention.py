"""Pallas TPU paged attention (decode): one query token per sequence against
a block-table-indirected KV page pool.

This is the TPU adaptation of vLLM's PagedAttention (DESIGN.md §3): pages
are 16-token KV blocks in a global HBM pool; the per-sequence block table is
a *scalar-prefetch* operand, so the page id feeds the BlockSpec index map and
Mosaic can schedule the HBM→VMEM page streams ahead of compute. Pages past a
sequence's length are skipped with ``pl.when`` — the cost of a decode step
scales with the *actual* context, which is exactly the short-pool advantage
the paper's cost model banks on (Eq. 1–2).

Grid: (batch, kv_heads, pages_per_seq); the page dimension is sequential and
carries online-softmax accumulators in VMEM scratch. All G = H/K query heads
of one KV head are processed together as a (G, D) tile.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# repro.kernels.__init__ (always initialized first) aliases the old
# pltpu.TPUCompilerParams spelling to CompilerParams on legacy jax.

NEG_INF = -1e30


def _paged_kernel(
    # scalar-prefetch operands
    block_tables_ref,  # (B, pages_per_seq) int32 (SMEM)
    lengths_ref,  # (B,) int32 (SMEM)
    # array operands
    q_ref,  # (1, 1, G, D)
    k_ref,  # (1, page, 1, D) — bf16/f32, or int8 with scale refs below
    v_ref,  # (1, page, 1, D)
    *rest,  # [k_scale_ref, v_scale_ref,] o_ref, m_ref, l_ref, acc_ref
    page_size: int,
    pages_per_seq: int,
    scale: float,
    quantized: bool = False,
):
    if quantized:
        k_scale_ref, v_scale_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]
    base = j * page_size

    @pl.when(base < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quantized:
            # int8 pages: dequantize in VMEM after the (half-sized) HBM read
            k = k * k_scale_ref[0, :, 0].astype(jnp.float32)
            v = v * v_scale_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (G, page)
        pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_ref[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == pages_per_seq - 1)
    def _finish():
        o_ref[0, 0, :, :] = (
            acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30)
        ).astype(o_ref.dtype)


def paged_attention_pallas(
    q: jax.Array,  # (B, H, D) — single decode token per sequence
    k_pages: jax.Array,  # (P, page, K, D) global page pool (bf16/f32/int8)
    v_pages: jax.Array,  # (P, page, K, D)
    block_tables: jax.Array,  # (B, pages_per_seq) int32
    lengths: jax.Array,  # (B,) int32
    *,
    k_scales: jax.Array | None = None,  # (P, page, K, 1) for int8 pages
    v_scales: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    b, h, d = q.shape
    _, page, n_kv, _ = k_pages.shape
    pages_per_seq = block_tables.shape[1]
    g = h // n_kv
    scale = 1.0 / math.sqrt(d)
    quantized = k_pages.dtype == jnp.int8
    if quantized and (k_scales is None or v_scales is None):
        raise ValueError("int8 pages require k_scales/v_scales")

    # (B, H, D) → (B, K, G, D): all query heads of one KV head together.
    q4 = q.reshape(b, n_kv, g, d)

    kernel = functools.partial(
        _paged_kernel,
        page_size=page,
        pages_per_seq=pages_per_seq,
        scale=scale,
        quantized=quantized,
    )

    page_spec = pl.BlockSpec(
        (1, page, 1, d), lambda b_, kv, j, bt, ln: (bt[b_, j], 0, kv, 0)
    )
    scale_spec = pl.BlockSpec(
        (1, page, 1, 1), lambda b_, kv, j, bt, ln: (bt[b_, j], 0, kv, 0)
    )
    in_specs = [
        pl.BlockSpec((1, 1, g, d), lambda b_, kv, j, bt, ln: (b_, kv, 0, 0)),
        page_spec,
        page_spec,
    ]
    operands = [block_tables, lengths, q4, k_pages, v_pages]
    if quantized:
        in_specs += [scale_spec, scale_spec]
        operands += [k_scales, v_scales]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_kv, pages_per_seq),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, g, d), lambda b_, kv, j, bt, ln: (b_, kv, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )

    out_dtype = q.dtype
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, n_kv, g, d), out_dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out.reshape(b, h, d)
