"""Pallas TPU Mamba-2 SSD chunk scan.

The sub-quadratic sequence mixer of the hybrid/ssm architectures (zamba2,
and the same dual form as xLSTM's mLSTM). Each (batch, head) pair scans its
chunks sequentially, carrying the (P, N) state in VMEM scratch; within a
chunk the recurrence is the dual quadratic form — two MXU matmuls over a
(Q, Q) decay-masked Gram matrix.

Inputs are pre-projected at the ops layer: the kernel receives per-step
``log_a = A·dt`` (decay, already multiplied) and ``dt·x`` folding so the
kernel is a pure scan — this keeps it reusable for any gated-linear-
recurrence model (DESIGN.md §3 hardware-adaptation note).

Grid: (batch, heads, chunks) with chunks sequential.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# repro.kernels.__init__ (always initialized first) aliases the old
# pltpu.TPUCompilerParams spelling to CompilerParams on legacy jax.


def _ssd_kernel(
    x_ref,  # (1, 1, Q, P) — dt·x already folded
    loga_ref,  # (1, 1, Q, 128) — log decay per step (broadcast on lanes)
    b_ref,  # (1, Q, N)
    c_ref,  # (1, Q, N)
    y_ref,  # (1, 1, Q, P) out
    s_out_ref,  # (1, 1, P, N) out — final state
    state_ref,  # VMEM (P, N) f32 scratch
    *,
    chunk: int,
    num_chunks: int,
):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)  # (Q, P)
    log_a = loga_ref[0, 0, :, :1].astype(jnp.float32)  # (Q, 1)
    bmat = b_ref[0].astype(jnp.float32)  # (Q, N)
    cmat = c_ref[0].astype(jnp.float32)  # (Q, N)

    cum = jnp.cumsum(log_a, axis=0)  # (Q, 1) inclusive
    # intra-chunk: y[i] = Σ_{j≤i} (C_i·B_j) exp(cum_i − cum_j) x_j
    cb = jax.lax.dot_general(
        cmat, bmat, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q)
    seg = cum - cum.T  # (Q, Q) cum_i - cum_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    y = jax.lax.dot_general(
        cb * decay, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (Q, P)

    # cross-chunk read: y[i] += (C_i · S_prev^T) exp(cum_i)
    s_prev = state_ref[...]  # (P, N)
    y_cross = jax.lax.dot_general(
        cmat, s_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (Q, P)
    y = y + y_cross * jnp.exp(cum)

    # state update: S = exp(total) S_prev + Σ_j exp(total − cum_j) x_j B_j^T
    total = cum[-1:, :]  # (1, 1)
    w = jnp.exp(total - cum)  # (Q, 1)
    s_add = jax.lax.dot_general(
        x * w, bmat, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (P, N)
    state_ref[...] = jnp.exp(total) * s_prev + s_add

    y_ref[0, 0, :, :] = y.astype(y_ref.dtype)

    @pl.when(c_idx == num_chunks - 1)
    def _emit_state():
        s_out_ref[0, 0, :, :] = state_ref[...].astype(s_out_ref.dtype)


def ssd_scan_pallas(
    x: jax.Array,  # (B, H, L, P) — pre-multiplied by dt
    log_a: jax.Array,  # (B, H, L) — A·dt per step
    b_mat: jax.Array,  # (B, L, N)
    c_mat: jax.Array,  # (B, L, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,H,L,P), final_state (B,H,P,N))."""
    bsz, h, l, p = x.shape
    n = b_mat.shape[-1]
    chunk = min(chunk, l)
    if l % chunk:
        raise ValueError(f"L={l} must divide chunk={chunk}")
    nck = l // chunk

    # lanes-broadcast the decay so the block keeps a 128 minor dimension
    loga4 = jnp.broadcast_to(log_a[..., None], (bsz, h, l, 128))

    kernel = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=nck)
    y, s_final = pl.pallas_call(
        kernel,
        grid=(bsz, h, nck),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, chunk, 128), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c: (b_, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c: (b_, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, c: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, l, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, loga4, b_mat, c_mat)
    return y, s_final
