"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is a direct, unchunked implementation — deliberately simple
and memory-hungry, used only at test sizes. Kernel tests sweep shapes and
dtypes and ``assert_allclose`` against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,  # (B, Lq, H, D)
    k: jax.Array,  # (B, Lk, K, D)
    v: jax.Array,  # (B, Lk, K, D)
    *,
    causal: bool = True,
) -> jax.Array:
    """Dense softmax attention with GQA head grouping (fp32 softmax)."""
    b, lq, h, d = q.shape
    _, lk, n_kv, _ = k.shape
    g = h // n_kv
    qg = q.reshape(b, lq, n_kv, g, d).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(d))
    if causal:
        mask = jnp.tril(jnp.ones((lq, lk), bool), k=lk - lq)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, lq, h, d).astype(q.dtype)


def paged_attention_ref(
    q: jax.Array,  # (B, H, D) — one query token per sequence
    k_pages: jax.Array,  # (P, page, K, D) — global KV page pool
    v_pages: jax.Array,  # (P, page, K, D)
    block_tables: jax.Array,  # (B, pages_per_seq) int32 page ids
    lengths: jax.Array,  # (B,) int32 valid context lengths
) -> jax.Array:
    """Gathers each sequence's pages and runs dense masked attention."""
    b, h, d = q.shape
    _, page, n_kv, _ = k_pages.shape
    pages_per_seq = block_tables.shape[1]
    g = h // n_kv

    # gather (B, S, K, D) with S = pages_per_seq * page
    kg = k_pages[block_tables].reshape(b, pages_per_seq * page, n_kv, d)
    vg = v_pages[block_tables].reshape(b, pages_per_seq * page, n_kv, d)

    qg = q.reshape(b, n_kv, g, d).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, kg.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(d))
    pos = jnp.arange(pages_per_seq * page)
    valid = pos[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, vg.astype(jnp.float32))
    return o.reshape(b, h, d).astype(q.dtype)


def ssd_scan_ref(
    x: jax.Array,  # (B, L, H, P)
    dt: jax.Array,  # (B, L, H) positive
    a_neg: jax.Array,  # (H,) negative decay
    b_mat: jax.Array,  # (B, L, N)  (single group)
    c_mat: jax.Array,  # (B, L, N)
    *,
    initial_state: jax.Array | None = None,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Sequential Mamba-2 recurrence (fp32): the ground truth for ssd_scan."""
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    s0 = (
        jnp.zeros((bsz, h, p, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(s, inputs):
        x_t, dt_t, b_t, c_t = inputs  # (B,H,P), (B,H), (B,N), (B,N)
        a = jnp.exp(a_neg[None] * dt_t)  # (B, H)
        s_new = (
            a[..., None, None] * s
            + dt_t[..., None, None] * x_t[..., None] * b_t[:, None, None, :]
        )
        y = jnp.einsum("bn,bhpn->bhp", c_t, s_new)
        return s_new, y

    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(b_mat.astype(jnp.float32), 1, 0),
        jnp.moveaxis(c_mat.astype(jnp.float32), 1, 0),
    )
    s_final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), s_final
