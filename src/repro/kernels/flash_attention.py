"""Pallas TPU flash attention (prefill): causal, GQA/MQA via index-map
head folding — no KV replication in HBM or VMEM.

Grid: (batch, q_heads, q_blocks, kv_blocks); the kv_blocks dimension is the
sequential ("arbitrary") one, carrying the online-softmax accumulators in
VMEM scratch. BlockSpecs tile HBM→VMEM in (block, head_dim) tiles aligned to
the MXU (head_dim is 64/80/128/256 for our archs; q/kv blocks default 512).
Causal blocks above the diagonal are skipped with ``pl.when`` (no FLOPs, no
HBM reads for masked-out tiles beyond the stream), halving causal work vs a
masked dense scan.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# repro.kernels.__init__ (always initialized first) aliases the old
# pltpu.TPUCompilerParams spelling to CompilerParams on legacy jax.

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, 1, bq, D)
    k_ref,  # (1, 1, bk, D)
    v_ref,  # (1, 1, bk, D)
    o_ref,  # (1, 1, bq, D)
    m_ref,  # VMEM (bq, 128) f32
    l_ref,  # VMEM (bq, 128) f32
    acc_ref,  # VMEM (bq, D) f32
    *,
    causal: bool,
    block_q: int,
    block_k: int,
    scale: float,
    num_kv_blocks: int,
):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)

        if causal:
            q_pos = i * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            k_pos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)

        m_prev = m_ref[:, :1]  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = l_ref[:, :1] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    # causal: with block_q == block_k, block (i, j) contributes iff j <= i —
    # blocks above the diagonal are skipped entirely.
    if causal:
        pl.when(j * block_k <= i * block_q)(_compute)
    else:
        _compute()

    @pl.when(j == num_kv_blocks - 1)
    def _finish():
        l = l_ref[:, :1]
        o_ref[0, 0, :, :] = (
            acc_ref[...] / jnp.maximum(l, 1e-30)
        ).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (B, H, Lq, D)
    k: jax.Array,  # (B, K, Lk, D)
    v: jax.Array,  # (B, K, Lk, D)
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Head-major flash attention; q heads fold onto kv heads via index map."""
    b, h, lq, d = q.shape
    _, n_kv, lk, _ = k.shape
    g = h // n_kv
    block_q = min(block_q, lq)
    block_k = min(block_k, lk)
    if lq % block_q or lk % block_k:
        raise ValueError("sequence lengths must divide block sizes")
    if causal and block_q != block_k:
        raise ValueError("causal path requires block_q == block_k")
    nq, nk = lq // block_q, lk // block_k
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        scale=scale,
        num_kv_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda b_, h_, i, j: (b_, h_ // g, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda b_, h_, i, j: (b_, h_ // g, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda b_, h_, i, j: (b_, h_, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
