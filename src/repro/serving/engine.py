"""Iteration-based continuous-batching serving engine (one pool instance).

JAX counterpart of the DES instance model (Appendix A layer 1): ``n_seq``
slots, one decode token per active slot per iteration, prompt prefill on
admission. Static shapes throughout: the decode step is one compiled
program per pool configuration — the short pool and the long pool are
*different compiled programs* with different ``c_max``, which is the paper's
configuration–traffic-matching idea expressed at the XLA level.

Decode parallelism across slots is ``jax.vmap`` over the slot axis with
per-leaf in_axes derived from the model's logical cache axes, so every slot
writes its KV at its own position in one fused step.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model_zoo import Model
from repro.serving.kv_cache import SlotAllocator, SlotKVCache, bucket_length
from repro.serving.sampler import SamplingParams, sample


@dataclasses.dataclass
class ServeRequest:
    request_id: int
    tokens: list[int]  # prompt token ids
    max_new_tokens: int
    eos_id: int = -1  # -1 → never stops early


@dataclasses.dataclass
class Completion:
    request_id: int
    prompt_tokens: int  # usage.prompt_tokens — the router's feedback signal
    output_tokens: list[int]
    iterations: int


@dataclasses.dataclass
class _SlotState:
    request: ServeRequest
    length: int  # current context length (prompt + generated)
    remaining: int
    generated: list[int]
    iterations: int = 0


class ServingEngine:
    """One pool instance: admission queue + slot cache + decode loop."""

    def __init__(
        self,
        model: Model,
        params: Any,
        *,
        c_max: int,
        n_slots: int,
        sampling: SamplingParams = SamplingParams(),
        prompt_bucket: int = 64,
    ) -> None:
        if model.cfg.frontend != "tokens":
            raise ValueError("serving engine requires a token-frontend arch")
        self.model = model
        self.params = params
        self.c_max = c_max
        self.n_slots = n_slots
        self.sampling = sampling
        self.prompt_bucket = prompt_bucket
        self.cache = SlotKVCache(model, c_max, n_slots)
        self.alloc = SlotAllocator(n_slots)
        self.queue: deque[ServeRequest] = deque()
        self.slots: dict[int, _SlotState] = {}
        self.rejections = 0
        self.iterations = 0

        self._prefill = jax.jit(model.prefill)
        self._decode = self._build_decode()
        self._token_buf = np.zeros((n_slots,), np.int32)
        self._index_buf = np.zeros((n_slots,), np.int32)

    # -- compiled decode over all slots ---------------------------------------
    def _build_decode(self):
        model = self.model
        batch_axes = self.cache.batch_axes

        def single(params, state_slice, token, index):
            state = jax.tree.map(
                lambda x, ax: jnp.expand_dims(x, ax),
                state_slice,
                batch_axes,
            )
            batch = {"tokens": token[None, None], "index": index}
            logits, new_state = model.decode_step(params, state, batch)
            new_state = jax.tree.map(
                lambda x, ax: jnp.squeeze(x, ax), new_state, batch_axes
            )
            return logits[0], new_state

        vm = jax.vmap(
            single,
            in_axes=(None, batch_axes, 0, 0),
            out_axes=(0, batch_axes),
        )
        return jax.jit(vm, donate_argnums=(1,))

    # -- queue ------------------------------------------------------------------
    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    @property
    def active(self) -> int:
        return len(self.slots)

    def submit(self, request: ServeRequest) -> bool:
        """Reject requests whose prompt alone exceeds c_max (paper §1.3)."""
        if len(request.tokens) >= self.c_max:
            self.rejections += 1
            return False
        self.queue.append(request)
        return True

    # -- admission ----------------------------------------------------------------
    def _admit(self) -> None:
        while self.queue and self.alloc.num_free > 0:
            req = self.queue.popleft()
            slot = self.alloc.alloc()
            assert slot is not None
            prompt = np.asarray(req.tokens, np.int32)
            n = len(prompt)
            if self.model.cfg.family in ("dense", "moe", "vlm", "audio"):
                pad = bucket_length(
                    n, multiple=self.prompt_bucket, max_len=self.c_max
                )
                padded = np.zeros((pad,), np.int32)
                padded[:n] = prompt
                batch = {
                    "tokens": jnp.asarray(padded)[None],
                    "last_pos": jnp.asarray([n - 1], jnp.int32),
                }
            else:
                batch = {"tokens": jnp.asarray(prompt)[None]}
            logits, prefill_state = self._prefill(self.params, batch)
            self.cache.insert_prefill(slot, prefill_state)
            first = int(
                sample(logits, jax.random.key(req.request_id), self.sampling)[0]
            )
            self.slots[slot] = _SlotState(
                request=req,
                length=n + 1,
                remaining=req.max_new_tokens - 1,
                generated=[first],
            )
            self._token_buf[slot] = first
            self._index_buf[slot] = n

    # -- one iteration ---------------------------------------------------------
    def step(self, rng: Optional[jax.Array] = None) -> list[Completion]:
        """Admit + decode one token per active slot. Returns completions."""
        self._admit()
        completions: list[Completion] = []
        done_now = [
            s
            for s, st in self.slots.items()
            if st.remaining <= 0 or st.length >= self.c_max
        ]
        for s in done_now:
            completions.append(self._finish(s))
        if not self.slots:
            return completions

        tokens = jnp.asarray(self._token_buf)
        index = jnp.asarray(self._index_buf)
        logits, new_state = self._decode(
            self.params, self.cache.state, tokens, index
        )
        self.cache.update(new_state)
        if rng is None:
            rng = jax.random.key(self.iterations)
        next_tokens = np.asarray(sample(logits, rng, self.sampling))
        self.iterations += 1

        for slot, st in list(self.slots.items()):
            tok = int(next_tokens[slot])
            st.generated.append(tok)
            st.length += 1
            st.remaining -= 1
            st.iterations += 1
            self._token_buf[slot] = tok
            self._index_buf[slot] = st.length - 1
            if (
                st.remaining <= 0
                or st.length >= self.c_max
                or tok == st.request.eos_id
            ):
                completions.append(self._finish(slot))
        return completions

    def _finish(self, slot: int) -> Completion:
        st = self.slots.pop(slot)
        self.alloc.release(slot)
        return Completion(
            request_id=st.request.request_id,
            prompt_tokens=len(st.request.tokens),
            output_tokens=st.generated,
            iterations=st.iterations,
        )

    def run_to_completion(self, max_iters: int = 100_000) -> list[Completion]:
        """Drain queue + slots (examples / tests)."""
        out: list[Completion] = []
        for _ in range(max_iters):
            out.extend(self.step())
            if not self.queue and not self.slots:
                break
        return out
