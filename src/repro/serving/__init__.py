"""JAX serving engine: slot KV cache, continuous batching, two-pool server."""

from repro.serving.engine import Completion, ServeRequest, ServingEngine
from repro.serving.kv_cache import SlotAllocator, SlotKVCache, bucket_length
from repro.serving.pool_server import ServedResponse, TwoPoolServer
from repro.serving.sampler import SamplingParams, sample

__all__ = [
    "Completion",
    "ServeRequest",
    "ServingEngine",
    "SlotAllocator",
    "SlotKVCache",
    "bucket_length",
    "ServedResponse",
    "TwoPoolServer",
    "SamplingParams",
    "sample",
]
