"""Slot-based KV cache management for the JAX serving engine.

Each pool instance reserves ``n_seq`` slots of ``c_max`` tokens — precisely
the provisioning rule of paper Eq. 1–2 (the quantity the short pool
right-sizes). Model decode states live in a single batched pytree whose
batch axis is the slot index; prefill results are inserted into a slot with
``dynamic_update_slice`` along the per-leaf batch/seq axes derived from the
model's logical cache axes.

The block-table paged pool (``repro.kernels.paged_attention``) is the
TPU-kernel-level counterpart; the slot layout here is its static-shape
engine-level wrapper (DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell
from repro.models.model_zoo import Model


@dataclasses.dataclass
class SlotAllocator:
    """Host-side free-list of sequence slots."""

    n_slots: int

    def __post_init__(self) -> None:
        self.free: list[int] = list(range(self.n_slots))[::-1]
        self.used: set[int] = set()

    def alloc(self) -> Optional[int]:
        if not self.free:
            return None
        slot = self.free.pop()
        self.used.add(slot)
        return slot

    def release(self, slot: int) -> None:
        if slot not in self.used:
            raise ValueError(f"slot {slot} not allocated")
        self.used.discard(slot)
        self.free.append(slot)

    @property
    def num_free(self) -> int:
        return len(self.free)


class SlotKVCache:
    """Batched decode-state tree with slot-indexed insertion."""

    def __init__(self, model: Model, c_max: int, n_slots: int) -> None:
        self.model = model
        self.c_max = c_max
        self.n_slots = n_slots
        cell = ShapeCell(
            name="serving", kind="decode", seq_len=c_max, global_batch=n_slots
        )
        self.cell = cell
        self.state = model.init_cache(cell)
        self.axes = model.cache_axes(cell)
        # per-leaf batch axis = position of "serve_batch" in the logical axes
        self.batch_axes = jax.tree.map(
            lambda ax: ax.index("serve_batch") if "serve_batch" in ax else None,
            self.axes,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(a, (str, type(None))) for a in x),
        )
        # per-leaf seq axis (KV caches only): position of the c_max dim
        self.vmap_axes = self.batch_axes

    def insert_prefill(self, slot: int, prefill_state: Any) -> None:
        """Write a single-sequence prefill state (batch dim 1) into a slot."""

        def write(target, src, batch_axis):
            if batch_axis is None:
                return target
            start = [0] * target.ndim
            start[batch_axis] = slot
            # pad the seq axis difference implicitly: dynamic_update_slice
            # accepts a smaller update block.
            return jax.lax.dynamic_update_slice(
                target, src.astype(target.dtype), tuple(start)
            )

        self.state = jax.tree.map(
            write, self.state, prefill_state, self.batch_axes
        )

    def update(self, new_state: Any) -> None:
        self.state = new_state


def bucket_length(n: int, *, multiple: int = 128, max_len: int = 1 << 20) -> int:
    """Round a prompt length up to the next bucket (limits recompiles)."""
    b = ((max(1, n) + multiple - 1) // multiple) * multiple
    return min(b, max_len)
