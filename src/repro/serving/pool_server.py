"""Two-pool server: the paper's system, end to end, on real JAX engines.

Wires Algorithm 1 (token-budget dispatch + EMA calibration + spillover) to
two :class:`ServingEngine` instances — a short pool with small ``c_max``
and high slot count, and a long pool with the full context window. The
router sees only bytes + ``max_output_tokens``; exact prompt token counts
flow back through ``Completion.prompt_tokens`` (= ``usage.prompt_tokens``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.core.calibration import EmaCalibrator
from repro.core.pools import PoolConfig, PoolState
from repro.core.router import Request, TokenBudgetRouter
from repro.models.model_zoo import Model
from repro.serving.engine import Completion, ServeRequest, ServingEngine
from repro.serving.sampler import SamplingParams


@dataclasses.dataclass
class ServedResponse:
    request_id: int
    pool: str
    prompt_tokens: int
    output_tokens: list[int]
    estimated_budget: int
    spilled: bool


class TwoPoolServer:
    """Production topology of the paper, scaled to in-process engines."""

    def __init__(
        self,
        model: Model,
        params: Any,
        *,
        short_cmax: int,
        long_cmax: int,
        short_slots: int,
        long_slots: int,
        b_short: Optional[int] = None,
        bytes_per_token_hint: float = 4.0,
        sampling: SamplingParams = SamplingParams(),
        spillover: bool = True,
        queue_limit: int = 64,
    ) -> None:
        self.short_engine = ServingEngine(
            model, params, c_max=short_cmax, n_slots=short_slots,
            sampling=sampling,
        )
        self.long_engine = ServingEngine(
            model, params, c_max=long_cmax, n_slots=long_slots,
            sampling=sampling,
        )
        short_cfg = PoolConfig(
            "short", short_cmax, short_slots, queue_limit=queue_limit
        )
        long_cfg = PoolConfig(
            "long", long_cmax, long_slots, queue_limit=queue_limit
        )
        self._short_state = PoolState(config=short_cfg, num_instances=1)
        self._long_state = PoolState(config=long_cfg, num_instances=1)
        self.router = TokenBudgetRouter(
            self._short_state,
            self._long_state,
            b_short=b_short or short_cmax,
            calibrator=EmaCalibrator(c0=bytes_per_token_hint),
            spillover=spillover,
        )
        self._inflight: dict[int, tuple[Request, str]] = {}
        self.responses: list[ServedResponse] = []

    # -- request path -----------------------------------------------------------
    def submit(
        self,
        request_id: int,
        prompt_tokens: list[int],
        prompt_bytes: int,
        max_output_tokens: int,
        category: int = 0,
    ) -> str:
        """Route and enqueue. Returns the pool name chosen."""
        req = Request(
            request_id=request_id,
            byte_len=prompt_bytes,
            max_output_tokens=max_output_tokens,
            category=category,
        )
        self._refresh_states()
        decision = self.router.route(req)
        engine = (
            self.short_engine if decision.pool == "short" else self.long_engine
        )
        ok = engine.submit(
            ServeRequest(
                request_id=request_id,
                tokens=prompt_tokens,
                max_new_tokens=max_output_tokens,
            )
        )
        if not ok and decision.pool == "short":
            # hard-constraint miss (estimate was wrong): bounce to long pool
            self.long_engine.submit(
                ServeRequest(
                    request_id=request_id,
                    tokens=prompt_tokens,
                    max_new_tokens=max_output_tokens,
                )
            )
            decision = dataclasses.replace(decision, pool="long")
        self._inflight[request_id] = (req, decision.pool)
        self.responses_meta = decision
        return decision.pool

    def _refresh_states(self) -> None:
        self._short_state.queue_depth = self.short_engine.queue_depth
        self._short_state.active = self.short_engine.active
        self._long_state.queue_depth = self.long_engine.queue_depth
        self._long_state.active = self.long_engine.active

    # -- engine loop --------------------------------------------------------------
    def step(self) -> list[ServedResponse]:
        """One iteration on both pools; feeds usage back to the calibrator."""
        out: list[ServedResponse] = []
        for name, engine in (
            ("short", self.short_engine),
            ("long", self.long_engine),
        ):
            for comp in engine.step():
                out.append(self._complete(name, comp))
        self.responses.extend(out)
        return out

    def _complete(self, pool: str, comp: Completion) -> ServedResponse:
        req, routed_pool = self._inflight.pop(comp.request_id)
        # usage.prompt_tokens feedback → EMA calibration (Algorithm 1 l.15–19)
        self.router.on_response(req, comp.prompt_tokens)
        est = self.router.calibrator.estimate_total_budget(
            req.byte_len, req.max_output_tokens, req.category
        )
        return ServedResponse(
            request_id=comp.request_id,
            pool=pool,
            prompt_tokens=comp.prompt_tokens,
            output_tokens=comp.output_tokens,
            estimated_budget=est,
            spilled=routed_pool != pool,
        )

    def run_to_completion(self, max_iters: int = 100_000) -> list[ServedResponse]:
        out: list[ServedResponse] = []
        for _ in range(max_iters):
            out.extend(self.step())
            if not self._inflight:
                break
        return out

    def stats(self) -> dict:
        return {
            "router": self.router.stats(),
            "short_iterations": self.short_engine.iterations,
            "long_iterations": self.long_engine.iterations,
            "short_rejections": self.short_engine.rejections,
            "long_rejections": self.long_engine.rejections,
        }
