"""Token sampling for the serving engine."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0  # 0 → greedy
    top_k: int = 0  # 0 → no top-k filter


def sample(
    logits: jax.Array,  # (B, V)
    rng: jax.Array,
    params: SamplingParams = SamplingParams(),
) -> jax.Array:
    """Returns (B,) int32 token ids."""
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / params.temperature
    if params.top_k > 0:
        kth = jax.lax.top_k(lf, params.top_k)[0][..., -1:]
        lf = jnp.where(lf < kth, -jnp.inf, lf)
    return jax.random.categorical(rng, lf, axis=-1).astype(jnp.int32)
