"""Output heads and losses shared across the model zoo."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain


def _mask_padded(logits: jax.Array, valid_vocab: Optional[int]) -> jax.Array:
    """Megatron-style vocab padding: padded tail logits → -inf."""
    v = logits.shape[-1]
    if valid_vocab is None or valid_vocab >= v:
        return logits
    idx = jnp.arange(v)
    return jnp.where(idx < valid_vocab, logits, jnp.finfo(jnp.float32).min)


def lm_logits(
    hidden: jax.Array,  # (B, L, D)
    head: jax.Array,  # (D, V) — or embed table (V, D) when tied
    *,
    tied: bool = False,
    valid_vocab: Optional[int] = None,
) -> jax.Array:
    if tied:
        logits = jnp.einsum("bld,vd->blv", hidden, head)
    else:
        logits = jnp.einsum("bld,dv->blv", hidden, head)
    logits = _mask_padded(logits, valid_vocab)
    return constrain(logits, ("batch", None, "vocab"))


def codebook_logits(
    hidden: jax.Array, heads: jax.Array, *, valid_vocab: Optional[int] = None
) -> jax.Array:
    """MusicGen multi-codebook heads: (B,L,D) x (K,D,V) → (B,L,K,V)."""
    logits = jnp.einsum("bld,kdv->blkv", hidden, heads)
    logits = _mask_padded(logits, valid_vocab)
    return constrain(logits, ("batch", None, None, "vocab"))


def softmax_xent(
    logits: jax.Array,  # (..., V)
    labels: jax.Array,  # (...) int32
    *,
    z_loss: float = 0.0,
    mask: Optional[jax.Array] = None,
) -> tuple[jax.Array, dict]:
    """Mean cross-entropy in fp32, with optional z-loss regularizer."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss > 0.0:
        nll = nll + z_loss * jnp.square(lse)
    if mask is not None:
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum(nll * mask) / denom
        acc = jnp.sum((jnp.argmax(lf, -1) == labels) * mask) / denom
    else:
        loss = jnp.mean(nll)
        acc = jnp.mean(jnp.argmax(lf, -1) == labels)
    return loss, {"loss": loss, "accuracy": acc}
