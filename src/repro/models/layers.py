"""Shared neural building blocks (pure JAX, bf16 compute / fp32 accumulate).

Includes the O(L)-memory chunked flash attention used for 32k prefill and
4k training (the pure-jnp counterpart of ``repro.kernels.flash_attention``)
and the cache-reading decode attention (counterpart of
``repro.kernels.paged_attention``).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

# ---------------------------------------------------------------------------
# Norms / MLP
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def mlp(x: jax.Array, params: dict, activation: str) -> jax.Array:
    """Gated (swiglu/geglu) or plain (gelu) feed-forward."""
    if activation in ("swiglu", "geglu"):
        gate = jnp.einsum("...d,df->...f", x, params["w_gate"])
        up = jnp.einsum("...d,df->...f", x, params["w_up"])
        act = jax.nn.silu(gate) if activation == "swiglu" else jax.nn.gelu(gate)
        hidden = act * up
    elif activation == "gelu":
        hidden = jax.nn.gelu(jnp.einsum("...d,df->...f", x, params["w_up"]))
    else:
        raise ValueError(f"unknown activation {activation!r}")
    hidden = constrain(hidden, (None, None, "ffn"))
    return jnp.einsum("...f,fd->...d", hidden, params["w_down"])


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE and multimodal M-RoPE)
# ---------------------------------------------------------------------------


def rope_angles(
    positions: jax.Array, head_dim: int, theta: float
) -> tuple[jax.Array, jax.Array]:
    """positions (..., L) → cos/sin (..., L, head_dim/2) in fp32."""
    half = head_dim // 2
    freqs = 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(
    positions: jax.Array,  # (3, B, L) — temporal / height / width streams
    head_dim: int,
    theta: float,
    sections: tuple[int, ...],
) -> tuple[jax.Array, jax.Array]:
    """M-RoPE (Qwen2-VL): rotary pairs are split into sections, each driven
    by its own positional stream. Returns cos/sin (B, L, head_dim/2)."""
    half = head_dim // 2
    if sum(sections) != half:
        raise ValueError(f"mrope sections {sections} must sum to {half}")
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    # effective position per pair: stream index for each frequency slot
    stream_idx = jnp.concatenate(
        [jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)]
    )  # (half,)
    # positions: (3, B, L) → per-pair positions (B, L, half)
    pos_eff = jnp.take(positions, stream_idx, axis=0)  # (half, B, L)
    pos_eff = jnp.moveaxis(pos_eff, 0, -1).astype(jnp.float32)  # (B, L, half)
    ang = pos_eff * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., L, H, D); cos/sin broadcastable to (..., L, 1, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :] if cos.ndim == x.ndim - 1 else cos
    s = sin[..., None, :] if sin.ndim == x.ndim - 1 else sin
    out1 = x1 * c - x2 * s
    out2 = x2 * c + x1 * s
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention — O(L) memory, GQA/MQA aware
# ---------------------------------------------------------------------------


def _gqa_expand(q: jax.Array, n_kv: int) -> jax.Array:
    """(B, L, H, D) → (B, L, K, G, D) grouping query heads per KV head."""
    b, l, h, d = q.shape
    return q.reshape(b, l, n_kv, h // n_kv, d)


def flash_attention(
    q: jax.Array,  # (B, Lq, H, D)
    k: jax.Array,  # (B, Lk, K, D)
    v: jax.Array,  # (B, Lk, K, D)
    *,
    causal: bool = True,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    causal_mode: str = "triangle",  # triangle | masked
    bias: Optional[jax.Array] = None,
) -> jax.Array:
    """Online-softmax chunked attention in pure jnp.

    ``triangle`` mode iterates only the lower-triangular chunk pairs (a
    static python loop over q chunks with per-chunk-length kv scans), which
    halves causal FLOPs vs ``masked`` mode (full kv scan + mask). Both are
    reverse-mode differentiable. Non-causal attention always scans all kv
    chunks.
    """
    b, lq, h, d = q.shape
    _, lk, n_kv, _ = k.shape
    g = h // n_kv
    scale = 1.0 / jnp.sqrt(jnp.array(d, jnp.float32))

    q_chunk = min(q_chunk, lq)
    kv_chunk = min(kv_chunk, lk)
    if lq % q_chunk or lk % kv_chunk:
        raise ValueError(
            f"seq lengths ({lq},{lk}) must divide chunks ({q_chunk},{kv_chunk})"
        )
    nq, nk = lq // q_chunk, lk // kv_chunk

    qg = _gqa_expand(q, n_kv)  # (B, Lq, K, G, D)

    def attend_block(qc, kc, vc, qpos0, kpos0, need_mask):
        """One (q_chunk x kv_chunk) block of scores; qc is (B, K, G, q, D)."""
        s = jnp.einsum(
            "bkgqd,bskd->bkgqs", qc.astype(jnp.float32), kc.astype(jnp.float32)
        ) * scale  # (B, K, G, q, s)
        if need_mask:
            qpos = qpos0 + jnp.arange(qc.shape[-2])
            kpos = kpos0 + jnp.arange(kc.shape[1])
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
        return s

    def scan_kv(qc, k_all, v_all, qpos0, n_kv_chunks, diag_mask_last):
        """Online softmax over the given kv chunks (lax.scan)."""
        kr = k_all[:, : n_kv_chunks * kv_chunk].reshape(
            b, n_kv_chunks, kv_chunk, n_kv, d
        )
        vr = v_all[:, : n_kv_chunks * kv_chunk].reshape(
            b, n_kv_chunks, kv_chunk, n_kv, d
        )
        kr = jnp.moveaxis(kr, 1, 0)  # (n, B, s, K, D)
        vr = jnp.moveaxis(vr, 1, 0)

        q_len = qc.shape[-2]
        m0 = jnp.full((b, n_kv, g, q_len), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, n_kv, g, q_len), jnp.float32)
        acc0 = jnp.zeros((b, n_kv, g, q_len, d), jnp.float32)

        def body(carry, inputs):
            m, l, acc = carry
            idx, kc, vc = inputs
            kpos0 = idx * kv_chunk
            need_mask = causal and (
                diag_mask_last or causal_mode == "masked"
            )
            s = attend_block(qc, kc, vc, qpos0, kpos0, need_mask)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vc.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        idxs = jnp.arange(n_kv_chunks)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (idxs, kr, vr))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out  # (B, K, G, q, D)

    if not causal or causal_mode == "masked" or nq == 1:
        outs = []
        for i in range(nq):
            # (B, q, K, G, D) → (B, K, G, q, D)
            qc = jnp.moveaxis(qg[:, i * q_chunk : (i + 1) * q_chunk], 1, -2)
            out = scan_kv(qc, k, v, i * q_chunk, nk, diag_mask_last=True)
            outs.append(out)
        o = jnp.concatenate([jnp.moveaxis(x, -2, 1) for x in outs], axis=1)
        return o.reshape(b, lq, h, d).astype(q.dtype)

    # triangle mode: q chunk i attends kv chunks 0..i; only the diagonal
    # block needs the causal mask (assumes q_chunk == kv_chunk alignment).
    if q_chunk != kv_chunk:
        raise ValueError("triangle mode requires q_chunk == kv_chunk")
    outs = []
    for i in range(nq):
        qc = jnp.moveaxis(qg[:, i * q_chunk : (i + 1) * q_chunk], 1, -2)
        if i == 0:
            s = attend_block(
                qc, k[:, :kv_chunk], v[:, :kv_chunk], 0, 0, True
            )
            m = jnp.max(s, axis=-1)
            p = jnp.where(jnp.isfinite(s), jnp.exp(s - m[..., None]), 0.0)
            l = jnp.sum(p, axis=-1)
            acc = jnp.einsum(
                "bkgqs,bskd->bkgqd", p, v[:, :kv_chunk].astype(jnp.float32)
            )
            out = acc / jnp.maximum(l[..., None], 1e-30)
        else:
            # off-diagonal chunks 0..i-1 (no mask) via scan, then diagonal.
            out_nodiag_m_l = _scan_with_final_diag(
                qc, k, v, i, kv_chunk, b, n_kv, g, d, scale
            )
            out = out_nodiag_m_l
        outs.append(out)
    o = jnp.concatenate([jnp.moveaxis(x, -2, 1) for x in outs], axis=1)
    return o.reshape(b, lq, h, d).astype(q.dtype)


def _scan_with_final_diag(qc, k, v, i, chunk, b, n_kv, g, d, scale):
    """Triangle-mode inner loop: chunks 0..i-1 unmasked + masked diagonal."""
    kr = k[:, : i * chunk].reshape(b, i, chunk, n_kv, d)
    vr = v[:, : i * chunk].reshape(b, i, chunk, n_kv, d)
    kr = jnp.moveaxis(kr, 1, 0)
    vr = jnp.moveaxis(vr, 1, 0)
    q_len = qc.shape[-2]

    m0 = jnp.full((b, n_kv, g, q_len), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, n_kv, g, q_len), jnp.float32)
    acc0 = jnp.zeros((b, n_kv, g, q_len, d), jnp.float32)

    def body(carry, inputs):
        m, l, acc = carry
        kc, vc = inputs
        s = (
            jnp.einsum(
                "bkgqd,bskd->bkgqs",
                qc.astype(jnp.float32),
                kc.astype(jnp.float32),
            )
            * scale
        )
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc * corr[..., None] + pv), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kr, vr))

    # masked diagonal block
    kc = k[:, i * chunk : (i + 1) * chunk]
    vc = v[:, i * chunk : (i + 1) * chunk]
    s = (
        jnp.einsum(
            "bkgqd,bskd->bkgqs", qc.astype(jnp.float32), kc.astype(jnp.float32)
        )
        * scale
    )
    qpos = i * chunk + jnp.arange(q_len)
    kpos = i * chunk + jnp.arange(chunk)
    mask = qpos[:, None] >= kpos[None, :]
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_new[..., None]), 0.0)
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    acc = acc * corr[..., None] + jnp.einsum(
        "bkgqs,bskd->bkgqd", p, vc.astype(jnp.float32)
    )
    return acc / jnp.maximum(l_new[..., None], 1e-30)


# ---------------------------------------------------------------------------
# Decode attention (single new token against a KV cache)
# ---------------------------------------------------------------------------


def decode_attention(
    q: jax.Array,  # (B, 1, H, D)
    k_cache: jax.Array,  # (B, S, K, D)
    v_cache: jax.Array,  # (B, S, K, D)
    cur_len: jax.Array | int,  # valid cache length (scalar or (B,))
) -> jax.Array:
    """One-step attention over the cache; positions ≥ cur_len are masked."""
    b, s, n_kv, d = k_cache.shape
    h = q.shape[2]
    g = h // n_kv
    scale = 1.0 / jnp.sqrt(jnp.array(d, jnp.float32))

    qg = q.reshape(b, 1, n_kv, g, d)
    scores = (
        jnp.einsum(
            "bqkgd,bskd->bkgqs",
            qg.astype(jnp.float32),
            k_cache.astype(jnp.float32),
        )
        * scale
    )  # (B, K, G, 1, S)
    pos = jnp.arange(s)
    cur = jnp.asarray(cur_len)
    if cur.ndim == 0:
        valid = pos < cur
        scores = jnp.where(valid[None, None, None, None, :], scores, -jnp.inf)
    else:
        valid = pos[None, :] < cur[:, None]  # (B, S)
        scores = jnp.where(valid[:, None, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)
