"""Zamba2-style hybrid: Mamba-2 backbone + shared attention blocks.

Structure (zamba2-2.7b): 54 Mamba-2 blocks; after every ``attn_every``=6
blocks one of ``n_shared_attn_blocks``=2 *shared* attention+MLP blocks is
applied (round-robin), with per-invocation LoRA adapters on its q/k/v and
MLP-up projections (9 invocations). Outer ``lax.scan`` over groups, inner
scan over the Mamba blocks of each group.

Long-context: Mamba state is O(1); only the 9 shared-attention invocations
hold KV — sharded over "model" (kv heads) for the 500k decode cell.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models import heads as heads_lib
from repro.models.layers import (
    apply_rope,
    decode_attention,
    flash_attention,
    mlp,
    rms_norm,
    rope_angles,
)
from repro.models.params import ParamDef, stack_tree
from repro.models.ssm import mamba2_block, mamba2_param_defs

# ---------------------------------------------------------------------------
# Parameter declarations
# ---------------------------------------------------------------------------


def _shared_block_defs(cfg: ArchConfig) -> dict:
    h, k, dh, d, f = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model, cfg.d_ff
    return {
        "attn_norm": ParamDef((d,), ("embed",), init="zeros", dtype=jnp.float32),
        "w_q": ParamDef((d, h, dh), ("embed", "heads", "head_dim"), init="scaled"),
        "w_k": ParamDef((d, k, dh), ("embed", "kv_heads", "head_dim"), init="scaled"),
        "w_v": ParamDef((d, k, dh), ("embed", "kv_heads", "head_dim"), init="scaled"),
        "w_o": ParamDef((h, dh, d), ("heads", "head_dim", "embed"), init="scaled"),
        "mlp_norm": ParamDef((d,), ("embed",), init="zeros", dtype=jnp.float32),
        "w_up": ParamDef((d, f), ("embed", "ffn"), init="scaled"),
        "w_down": ParamDef((f, d), ("ffn", "embed"), init="scaled"),
    }


def _lora_defs(cfg: ArchConfig) -> dict:
    d, r = cfg.d_model, cfg.shared_lora_rank
    h, k, dh, f = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_ff
    return {
        "a_q": ParamDef((d, r), ("embed", None), init="scaled"),
        "b_q": ParamDef((r, h, dh), (None, "heads", "head_dim"), init="zeros"),
        "a_k": ParamDef((d, r), ("embed", None), init="scaled"),
        "b_k": ParamDef((r, k, dh), (None, "kv_heads", "head_dim"), init="zeros"),
        "a_v": ParamDef((d, r), ("embed", None), init="scaled"),
        "b_v": ParamDef((r, k, dh), (None, "kv_heads", "head_dim"), init="zeros"),
        "a_up": ParamDef((d, r), ("embed", None), init="scaled"),
        "b_up": ParamDef((r, f), (None, "ffn"), init="zeros"),
    }


def _mamba_block_defs(cfg: ArchConfig) -> dict:
    defs = mamba2_param_defs(
        cfg.d_model, cfg.d_inner, cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_conv
    )
    defs["in_norm"] = ParamDef(
        (cfg.d_model,), ("embed",), init="zeros", dtype=jnp.float32
    )
    return defs


def hybrid_defs(cfg: ArchConfig) -> dict:
    per_group = cfg.attn_every
    if cfg.n_layers % per_group:
        raise ValueError("n_layers must divide attn_every")
    n_groups = cfg.n_layers // per_group
    return {
        "embed": ParamDef((cfg.padded_vocab, cfg.d_model), ("vocab", "embed")),
        "mamba": stack_tree(
            stack_tree(_mamba_block_defs(cfg), per_group, "sub"), n_groups
        ),
        "shared": stack_tree(
            _shared_block_defs(cfg), cfg.n_shared_attn_blocks, "layers"
        ),
        "lora": stack_tree(_lora_defs(cfg), n_groups),
        "final_norm": ParamDef(
            (cfg.d_model,), ("embed",), init="zeros", dtype=jnp.float32
        ),
        "lm_head": ParamDef(
            (cfg.d_model, cfg.padded_vocab), ("embed", "vocab"), init="scaled"
        ),
    }


# ---------------------------------------------------------------------------
# Application
# ---------------------------------------------------------------------------


def _lora_proj(x, w, a, b, eqn: str, scale: float = 1.0):
    base = jnp.einsum(eqn, x, w)
    low = jnp.einsum("bld,dr->blr", x, a)
    return base + scale * jnp.einsum(
        eqn.replace("d,d", "r,r"), low, b
    )


def _shared_attn_apply(
    x,
    base: dict,
    lora: dict,
    cfg: ArchConfig,
    cos,
    sin,
    *,
    mode: str,
    cache=None,
    index=None,
):
    xn = rms_norm(x, base["attn_norm"], cfg.norm_eps)
    q = _lora_proj(xn, base["w_q"], lora["a_q"], lora["b_q"], "bld,dhk->blhk")
    k = _lora_proj(xn, base["w_k"], lora["a_k"], lora["b_k"], "bld,dhk->blhk")
    v = _lora_proj(xn, base["w_v"], lora["a_v"], lora["b_v"], "bld,dhk->blhk")
    if cos is not None:
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    if mode == "full":
        o = flash_attention(
            q, k, v, causal=True,
            q_chunk=min(512, q.shape[1]), kv_chunk=min(512, k.shape[1]),
        )
        new_cache = (k, v)
    else:
        k_cache, v_cache = cache
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, index, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, index, 0, 0)
        )
        o = decode_attention(q, k_cache, v_cache, index + 1)
        new_cache = (k_cache, v_cache)
    x = x + jnp.einsum("blhk,hkd->bld", o, base["w_o"])

    xn = rms_norm(x, base["mlp_norm"], cfg.norm_eps)
    up = _lora_proj(xn, base["w_up"], lora["a_up"], lora["b_up"], "bld,df->blf")
    hidden = jax.nn.gelu(up)
    x = x + jnp.einsum("blf,fd->bld", hidden, base["w_down"])
    return x, new_cache


def _group_scan(
    params,
    cfg: ArchConfig,
    x,
    cos,
    sin,
    *,
    mode: str,
    states: Optional[Any] = None,
    index=None,
    remat: str = "none",
):
    per_group = cfg.attn_every
    n_groups = cfg.n_layers // per_group
    n_shared = cfg.n_shared_attn_blocks

    def group_step(carry, xs):
        h = carry
        p_mamba, p_lora, inv_idx, st = xs
        mamba_st = None if st is None else st["mamba"]
        attn_cache = None if st is None else st["attn"]

        def run(h):
            def mamba_step(hh, xs2):
                p_blk, st_blk = xs2
                xn = rms_norm(hh, p_blk["in_norm"], cfg.norm_eps)
                out, new_st = mamba2_block(
                    xn,
                    p_blk,
                    n_heads=cfg.n_ssm_heads,
                    head_dim=cfg.ssm_head_dim,
                    d_state=cfg.ssm_state,
                    initial_state=st_blk,
                )
                return hh + out, new_st

            h2, new_mamba_st = jax.lax.scan(mamba_step, h, (p_mamba, mamba_st))
            base = jax.tree.map(lambda p: p[inv_idx % n_shared], params["shared"])
            h2, new_cache = _shared_attn_apply(
                h2, base, p_lora, cfg, cos, sin,
                mode=mode, cache=attn_cache, index=index,
            )
            return h2, {"mamba": new_mamba_st, "attn": new_cache}

        if remat == "full":
            run = jax.checkpoint(
                run, policy=jax.checkpoint_policies.nothing_saveable
            )
        h2, new_state = run(h)
        return h2, new_state

    inv_ids = jnp.arange(n_groups)
    x, new_states = jax.lax.scan(
        group_step, x, (params["mamba"], params["lora"], inv_ids, states)
    )
    return x, new_states


def _finish(params, cfg: ArchConfig, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    vv = cfg.vocab if cfg.padded_vocab != cfg.vocab else None
    return heads_lib.lm_logits(x, params["lm_head"], valid_vocab=vv)


def forward(params, cfg: ArchConfig, batch: dict, *, remat: str = "none", **_):
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = constrain(x, ("batch", None, "embed"))
    bsz, length = batch["tokens"].shape
    pos = jnp.broadcast_to(jnp.arange(length)[None], (bsz, length))
    cos, sin = rope_angles(pos, cfg.head_dim, cfg.rope_theta)
    x, _ = _group_scan(params, cfg, x, cos, sin, mode="full", remat=remat)
    logits = _finish(params, cfg, x)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: ArchConfig, batch: dict, *, remat: str = "none", **kw):
    logits, _ = forward(params, cfg, batch, remat=remat)
    loss, metrics = heads_lib.softmax_xent(logits, batch["labels"])
    metrics["total_loss"] = loss
    return loss, metrics


def prefill(params, cfg: ArchConfig, batch: dict, **_):
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    bsz, length = batch["tokens"].shape
    pos = jnp.broadcast_to(jnp.arange(length)[None], (bsz, length))
    cos, sin = rope_angles(pos, cfg.head_dim, cfg.rope_theta)
    x, states = _group_scan(params, cfg, x, cos, sin, mode="full")
    logits = _finish(params, cfg, x[:, -1:])
    return logits[:, 0], states


def decode_step(params, cfg: ArchConfig, states: Any, batch: dict, **_):
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    index = batch["index"]
    bsz = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(index)[None, None], (bsz, 1))
    cos, sin = rope_angles(pos, cfg.head_dim, cfg.rope_theta)
    x, new_states = _group_scan(
        params, cfg, x, cos, sin, mode="decode", states=states, index=index
    )
    logits = _finish(params, cfg, x)
    return logits[:, 0], new_states
