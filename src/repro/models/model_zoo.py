"""Model facade: one API over all architecture families.

``Model(cfg)`` exposes:

* ``init(rng)`` / ``abstract()`` / ``axes()`` — parameter tree, its dry-run
  stand-ins, and its logical sharding axes (always structurally aligned).
* ``loss / forward / prefill / decode_step`` — family-dispatched apply fns.
* ``input_specs(cell)`` — ShapeDtypeStruct stand-ins + logical axes for every
  model input of a dry-run shape cell.
* ``cache_specs(cell)`` / ``cache_axes(cell)`` — decode-state stand-ins via
  ``jax.eval_shape`` over prefill (zero allocation) and their sharding axes.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell
from repro.models import hybrid, transformer, xlstm_model
from repro.models.params import (
    abstract_params,
    init_params,
    param_axes,
    param_bytes,
    param_count,
)

_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "audio": transformer,
    "hybrid": hybrid,
    "ssm": xlstm_model,
}


def _defs_for(cfg: ArchConfig):
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        return transformer.transformer_defs(cfg)
    if cfg.family == "hybrid":
        return hybrid.hybrid_defs(cfg)
    if cfg.family == "ssm":
        return xlstm_model.xlstm_defs(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    remat: str = "none"
    causal_mode: str = "triangle"
    moe_group: int = 512
    kv_dtype: str = "bf16"  # "int8" → quantized KV cache (§Perf iteration)

    def __post_init__(self) -> None:
        self._mod = _FAMILY_MODULES[self.cfg.family]
        self.defs = _defs_for(self.cfg)

    # -- parameters ----------------------------------------------------------
    def init(self, rng: jax.Array):
        return init_params(self.defs, rng)

    def abstract(self):
        return abstract_params(self.defs)

    def axes(self):
        return param_axes(self.defs)

    def param_count(self) -> int:
        return param_count(self.defs)

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top-k experts, not all).

        Used for the MODEL_FLOPS = 6·N_active·D roofline numerator.
        """
        total = param_count(self.defs)
        cfg = self.cfg
        if not cfg.is_moe:
            return total
        n_moe_layers = cfg.n_layers // cfg.moe_every
        f = cfg.moe_d_ff or cfg.d_ff
        mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
        per_expert = mats * cfg.d_model * f
        inactive = n_moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
        return total - inactive

    def param_bytes(self) -> int:
        return param_bytes(self.defs)

    # -- apply ----------------------------------------------------------------
    def forward(self, params, batch):
        return self._mod.forward(
            params, self.cfg, batch, remat="none",
            causal_mode=self.causal_mode, moe_group=self.moe_group,
        )

    def loss(self, params, batch):
        return self._mod.loss_fn(
            params, self.cfg, batch, remat=self.remat,
            causal_mode=self.causal_mode, moe_group=self.moe_group,
        )

    def prefill(self, params, batch):
        return self._mod.prefill(
            params, self.cfg, batch,
            causal_mode=self.causal_mode, moe_group=self.moe_group,
            kv_dtype=self.kv_dtype,
        )

    def decode_step(self, params, caches, batch):
        return self._mod.decode_step(
            params, self.cfg, caches, batch, moe_group=self.moe_group,
            kv_dtype=self.kv_dtype,
        )

    # -- dry-run specs ----------------------------------------------------------
    def input_specs(self, cell: ShapeCell) -> tuple[dict, dict]:
        """(ShapeDtypeStruct dict, logical-axes dict) for one shape cell."""
        cfg = self.cfg
        b = cell.global_batch
        l = 1 if cell.kind == "decode" else cell.seq_len
        specs: dict[str, Any] = {}
        axes: dict[str, Any] = {}

        if cfg.frontend == "tokens":
            specs["tokens"] = jax.ShapeDtypeStruct((b, l), jnp.int32)
            axes["tokens"] = ("batch", None)
        else:
            specs["embeds"] = jax.ShapeDtypeStruct((b, l, cfg.d_model), jnp.bfloat16)
            axes["embeds"] = ("batch", None, "embed")
        if cfg.pos_type == "mrope":
            specs["positions"] = jax.ShapeDtypeStruct((3, b, l), jnp.int32)
            axes["positions"] = (None, "batch", None)
        if cfg.cross_attention:
            specs["memory"] = jax.ShapeDtypeStruct(
                (b, cfg.cross_mem_len, cfg.d_model), jnp.bfloat16
            )
            axes["memory"] = ("batch", None, "embed")

        if cell.kind == "train":
            if cfg.n_codebooks > 0:
                specs["labels"] = jax.ShapeDtypeStruct(
                    (b, l, cfg.n_codebooks), jnp.int32
                )
                axes["labels"] = ("batch", None, None)
            else:
                specs["labels"] = jax.ShapeDtypeStruct((b, l), jnp.int32)
                axes["labels"] = ("batch", None)
        elif cell.kind == "decode":
            specs["index"] = jax.ShapeDtypeStruct((), jnp.int32)
            axes["index"] = ()
        return specs, axes

    def _prefill_specs_for_cache(self, cell: ShapeCell) -> dict:
        """Prefill input stand-ins whose cache matches the decode cell."""
        prefill_cell = ShapeCell(
            name=f"_cache_{cell.name}",
            kind="prefill",
            seq_len=cell.seq_len,
            global_batch=cell.global_batch,
        )
        specs, _ = self.input_specs(prefill_cell)
        return specs

    def cache_specs(self, cell: ShapeCell):
        """Abstract decode-state tree (full cache of cell.seq_len tokens)."""
        specs = self._prefill_specs_for_cache(cell)
        params_abs = self.abstract()
        out = jax.eval_shape(
            lambda p, b: self.prefill(p, b)[1], params_abs, specs
        )
        return out

    def cache_axes(self, cell: ShapeCell, *, kv_shardable: bool = True):
        """Logical axes tree matching cache_specs' structure.

        kv_shardable=False (MQA archs on a wide model axis) switches the KV
        cache layout from head-sharded to sequence-sharded ("kv_seq").
        """
        structure = jax.tree.structure(self.cache_specs(cell))
        leaves = jax.tree.leaves(self.cache_specs(cell))
        axes = [
            _cache_leaf_axes(leaf, self.cfg, kv_shardable) for leaf in leaves
        ]
        return jax.tree.unflatten(structure, axes)

    def init_cache(self, cell: ShapeCell, rng=None):
        """Concrete zero-initialized decode state (smoke tests/examples)."""
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.cache_specs(cell)
        )


def _cache_leaf_axes(leaf, cfg: ArchConfig, kv_shardable: bool):
    """Assign logical axes to one decode-state leaf by shape pattern."""
    shape = leaf.shape
    nd = len(shape)
    # KV caches: (steps, B, S, K, Dh) — attention families;
    #            (groups, B, S, K, Dh) — hybrid shared attn;
    #            (steps, B, S, K, 1)  — int8 KV scales.
    if nd == 5 and shape[-1] in (cfg.head_dim, 1) and shape[-2] == cfg.n_kv_heads:
        if kv_shardable and cfg.n_kv_heads > 1:
            return ("layers", "serve_batch", None, "kv_heads", None)
        return ("layers", "serve_batch", "kv_seq", None, None)
    # Mamba ssd state: (groups, sub, B, H, P, N)
    if (
        cfg.family == "hybrid"
        and nd == 6
        and cfg.ssm_state
        and shape[-1] == cfg.ssm_state
    ):
        return ("layers", None, "serve_batch", "ssm_heads", None, None)
    # Mamba conv state: (groups, sub, B, K-1, conv_dim)
    if cfg.family == "hybrid" and nd == 5 and shape[-2] == cfg.ssm_conv - 1:
        return ("layers", None, "serve_batch", None, None)
    if cfg.family == "ssm":
        # mLSTM C: (groups, sub, B, H, Dv, Dk) / n: (groups, sub, B, H, Dk):
        # batch at axis 2. sLSTM c/n/h/m: (groups, B, H, D): batch at axis 1.
        if nd >= 5:
            return tuple(["layers", None, "serve_batch"] + [None] * (nd - 3))
        return tuple(["layers", "serve_batch"] + [None] * (nd - 2))
    # Fallback: replicate.
    return tuple([None] * nd)


@functools.lru_cache(maxsize=None)
def get_model(name: str, remat: str = "none", causal_mode: str = "triangle") -> Model:
    from repro.configs import get_config

    return Model(get_config(name), remat=remat, causal_mode=causal_mode)
