"""Mamba-2 (SSD) blocks in pure JAX (chunked scan; per-step decode).

The chunked state-space-duality algorithm (Mamba-2): sequence is processed
in chunks of Q tokens; within a chunk the recurrence is materialized as a
(Q×Q) decay-masked attention-like product, between chunks a (H,P,N) state is
carried by ``lax.scan``. This is also the pure-jnp oracle for
``repro.kernels.ssd_scan``.

Dimensions: B batch, L seq, H ssm heads, P head dim, G groups, N state.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef


# ---------------------------------------------------------------------------
# Core SSD scan
# ---------------------------------------------------------------------------


def ssd_chunked(
    x: jax.Array,  # (B, L, H, P)
    dt: jax.Array,  # (B, L, H) — positive (post-softplus)
    a_neg: jax.Array,  # (H,) — negative continuous-time decay A
    b_mat: jax.Array,  # (B, L, G, N)
    c_mat: jax.Array,  # (B, L, G, N)
    *,
    chunk: int = 128,
    initial_state: Optional[jax.Array] = None,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,L,H,P), final_state (B,H,P,N)). fp32 internally."""
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    heads_per_group = h // g
    chunk = min(chunk, l)
    if l % chunk:
        raise ValueError(f"seq len {l} must divide chunk {chunk}")
    nck = l // chunk

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b_mat.astype(jnp.float32)
    cf = c_mat.astype(jnp.float32)

    # log decay per step: log a_t = A * dt_t  (A negative)
    log_a = a_neg.astype(jnp.float32)[None, None, :] * dtf  # (B, L, H)

    # reshape to chunks
    xc = xf.reshape(bsz, nck, chunk, h, p)
    dtc = dtf.reshape(bsz, nck, chunk, h)
    lac = log_a.reshape(bsz, nck, chunk, h)
    bc = bf.reshape(bsz, nck, chunk, g, n)
    cc = cf.reshape(bsz, nck, chunk, g, n)

    # expand B,C to heads: head h belongs to group h // heads_per_group
    def expand_groups(t):  # (B, nck, Q, G, N) -> (B, nck, Q, H, N)
        return jnp.repeat(t, heads_per_group, axis=3)

    bh = expand_groups(bc)
    ch = expand_groups(cc)

    cum = jnp.cumsum(lac, axis=2)  # (B, nck, Q, H) inclusive cumsum

    if initial_state is None:
        s0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    else:
        s0 = initial_state.astype(jnp.float32)

    # Pre-compute per-chunk quantities independent of the carried state.
    # intra-chunk:  y_intra[i] = Σ_{j≤i} (C_i·B_j) exp(cum_i − cum_j) dt_j x_j
    cb = jnp.einsum("bkihn,bkjhn->bkhij", ch, bh)  # (B,nck,H,Q,Q)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # cum_i - cum_j: (B,nck,Q,Q,H)
    seg = jnp.moveaxis(seg, -1, 2)  # (B,nck,H,Q,Q)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(causal[None, None, None], jnp.exp(seg), 0.0)
    dtx = xc * dtc[..., None]  # (B,nck,Q,H,P)
    y_intra = jnp.einsum("bkhij,bkjhp->bkihp", cb * decay, dtx)

    # chunk-level aggregates for the inter-chunk recurrence
    total = cum[:, :, -1, :]  # (B,nck,H) — log decay over the whole chunk
    # state contribution of chunk k: Σ_j exp(total − cum_j) dt_j B_j x_j^T
    w = jnp.exp(total[:, :, None, :] - cum)  # (B,nck,Q,H)
    state_in = jnp.einsum("bkjhn,bkjhp,bkjh->bkhpn", bh, xc * dtc[..., None], w)
    # cross-chunk read: y_cross[i] = (C_i · S_prev) exp(cum_i)
    read_w = jnp.exp(cum)  # (B,nck,Q,H)

    def body(s_prev, inputs):
        y_in, s_add, tot, c_blk, r_w = inputs
        y_cross = (
            jnp.einsum("bihn,bhpn->bihp", c_blk, s_prev) * r_w[..., None]
        )
        s_new = jnp.exp(tot)[:, :, None, None] * s_prev + s_add
        return s_new, y_in + y_cross

    xs = (
        jnp.moveaxis(y_intra, 1, 0),
        jnp.moveaxis(state_in, 1, 0),
        jnp.moveaxis(total, 1, 0),
        jnp.moveaxis(ch, 1, 0),
        jnp.moveaxis(read_w, 1, 0),
    )
    s_final, ys = jax.lax.scan(body, s0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, l, h, p)
    return y.astype(x.dtype), s_final


def ssd_step(
    x_t: jax.Array,  # (B, H, P)
    dt_t: jax.Array,  # (B, H)
    a_neg: jax.Array,  # (H,)
    b_t: jax.Array,  # (B, G, N)
    c_t: jax.Array,  # (B, G, N)
    state: jax.Array,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Single-token recurrence: S ← a S + dt B x;  y = C·S."""
    bsz, h, p = x_t.shape
    g, n = b_t.shape[1], b_t.shape[2]
    rep = h // g
    bh = jnp.repeat(b_t, rep, axis=1)  # (B, H, N)
    chh = jnp.repeat(c_t, rep, axis=1)
    a = jnp.exp(a_neg.astype(jnp.float32)[None] * dt_t.astype(jnp.float32))
    s_new = (
        a[..., None, None] * state.astype(jnp.float32)
        + (dt_t.astype(jnp.float32) * 1.0)[..., None, None]
        * x_t.astype(jnp.float32)[..., None]
        * bh.astype(jnp.float32)[:, :, None, :]
    )
    y = jnp.einsum("bhn,bhpn->bhp", chh.astype(jnp.float32), s_new)
    return y.astype(x_t.dtype), s_new


# ---------------------------------------------------------------------------
# Mamba-2 block (projections + conv + SSD + gate)
# ---------------------------------------------------------------------------


def mamba2_param_defs(
    d_model: int, d_inner: int, n_heads: int, d_state: int, d_conv: int
) -> dict:
    di_ax = ("embed", "ssm_heads")
    return {
        "w_z": ParamDef((d_model, d_inner), di_ax, init="scaled"),
        "w_x": ParamDef((d_model, d_inner), di_ax, init="scaled"),
        "w_b": ParamDef((d_model, d_state), ("embed", None), init="scaled"),
        "w_c": ParamDef((d_model, d_state), ("embed", None), init="scaled"),
        "w_dt": ParamDef((d_model, n_heads), ("embed", "ssm_heads"), init="scaled"),
        "conv_x": ParamDef((d_conv, d_inner), (None, "ssm_heads"), init="scaled"),
        "conv_b": ParamDef((d_conv, d_state), (None, None), init="scaled"),
        "conv_c": ParamDef((d_conv, d_state), (None, None), init="scaled"),
        "a_log": ParamDef((n_heads,), ("ssm_heads",), init="zeros", dtype=jnp.float32),
        "d_skip": ParamDef((n_heads,), ("ssm_heads",), init="ones", dtype=jnp.float32),
        "dt_bias": ParamDef((n_heads,), ("ssm_heads",), init="zeros", dtype=jnp.float32),
        "norm": ParamDef((d_inner,), ("ssm_heads",), init="zeros", dtype=jnp.float32),
        "w_out": ParamDef((d_inner, d_model), ("ssm_heads", "embed"), init="scaled"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, cache: Optional[jax.Array] = None):
    """Depthwise causal conv along L. x (B,L,C), w (K,C).

    Returns (y, new_cache) where cache holds the last K-1 inputs.
    """
    k = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = cache.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(k)
    )
    new_cache = xp[:, -(k - 1) :] if k > 1 else jnp.zeros_like(pad)
    return y, new_cache


def _ssm_gated_norm(y: jax.Array, z: jax.Array, w: jax.Array, eps: float = 1e-6):
    """RMSNorm(y * silu(z)) — the Mamba-2 gated output norm."""
    h = y * jax.nn.silu(z)
    hf = h.astype(jnp.float32)
    var = jnp.mean(jnp.square(hf), axis=-1, keepdims=True)
    return ((hf * jax.lax.rsqrt(var + eps)) * (1.0 + w)).astype(y.dtype)


def mamba2_block(
    x: jax.Array,  # (B, L, d_model)
    params: dict,
    *,
    n_heads: int,
    head_dim: int,
    d_state: int,
    chunk: int = 128,
    initial_state: Optional[dict] = None,
) -> tuple[jax.Array, dict]:
    """Full Mamba-2 mixer. Returns (out, {"conv": ..., "ssd": ...} state)."""
    z = jnp.einsum("bld,de->ble", x, params["w_z"])
    xs = jnp.einsum("bld,de->ble", x, params["w_x"])
    bproj = jnp.einsum("bld,dn->bln", x, params["w_b"])
    cproj = jnp.einsum("bld,dn->bln", x, params["w_c"])
    dt = jnp.einsum("bld,dh->blh", x, params["w_dt"])

    conv_state = (initial_state or {}).get("conv")
    cx0 = conv_state[..., : xs.shape[-1]] if conv_state is not None else None
    cb0 = (
        conv_state[..., xs.shape[-1] : xs.shape[-1] + d_state]
        if conv_state is not None
        else None
    )
    cc0 = conv_state[..., xs.shape[-1] + d_state :] if conv_state is not None else None
    xs, cx = _causal_conv(xs, params["conv_x"], cx0)
    bproj, cb = _causal_conv(bproj, params["conv_b"], cb0)
    cproj, cc = _causal_conv(cproj, params["conv_c"], cc0)
    xs, bproj, cproj = jax.nn.silu(xs), jax.nn.silu(bproj), jax.nn.silu(cproj)

    bsz, l, _ = x.shape
    xh = xs.reshape(bsz, l, n_heads, head_dim)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a_neg = -jnp.exp(params["a_log"])

    y, s_final = ssd_chunked(
        xh,
        dtp,
        a_neg,
        bproj[:, :, None, :],  # G = 1
        cproj[:, :, None, :],
        chunk=chunk,
        initial_state=(initial_state or {}).get("ssd"),
    )
    y = y + xh * params["d_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(bsz, l, -1)
    y = _ssm_gated_norm(y, z, params["norm"])
    out = jnp.einsum("ble,ed->bld", y, params["w_out"])
    new_state = {"conv": jnp.concatenate([cx, cb, cc], axis=-1), "ssd": s_final}
    return out, new_state


def mamba2_decode_step(
    x_t: jax.Array,  # (B, 1, d_model)
    params: dict,
    state: dict,  # {"conv": (B, K-1, conv_dim), "ssd": (B, H, P, N)}
    *,
    n_heads: int,
    head_dim: int,
    d_state: int,
) -> tuple[jax.Array, dict]:
    """O(1) per-token recurrence for serving decode."""
    out, new_state = mamba2_block(
        x_t,
        params,
        n_heads=n_heads,
        head_dim=head_dim,
        d_state=d_state,
        chunk=1,
        initial_state=state,
    )
    return out, new_state
