"""Spec-first parameter trees.

Models declare parameters as a nested dict of :class:`ParamDef` (shape +
logical sharding axes + init). From one declaration we derive:

* ``init_params``     — materialized arrays (smoke tests, examples, training)
* ``abstract_params`` — ShapeDtypeStruct tree (dry-run: no allocation)
* ``param_axes``      — logical-axis tree → PartitionSpecs via AxisRules

This keeps the model definition, its sharding, and its dry-run stand-ins in
lockstep by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declaration of one parameter tensor."""

    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | scaled (fan-in)
    scale: float = 0.02
    dtype: Any = jnp.bfloat16

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.axes):
            raise ValueError(
                f"shape {self.shape} and axes {self.axes} rank mismatch"
            )


def is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def _init_leaf(rng: jax.Array, d: ParamDef) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "scaled":
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        std = 1.0 / np.sqrt(max(1, fan_in))
        return (jax.random.normal(rng, d.shape, jnp.float32) * std).astype(d.dtype)
    if d.init == "normal":
        return (jax.random.normal(rng, d.shape, jnp.float32) * d.scale).astype(
            d.dtype
        )
    raise ValueError(f"unknown init {d.init!r}")


def init_params(defs: Any, rng: jax.Array) -> Any:
    """Materialize a ParamDef tree into arrays (deterministic per-path keys)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(rng, len(leaves))
    arrays = [_init_leaf(k, d) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrays)


def abstract_params(defs: Any) -> Any:
    """ShapeDtypeStruct stand-ins (dry-run: zero allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=is_def
    )


def param_axes(defs: Any) -> Any:
    """Tree of logical-axis tuples, aligned with the param tree."""
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=is_def)


def param_count(defs: Any) -> int:
    total = 0
    for d in jax.tree.leaves(defs, is_leaf=is_def):
        n = 1
        for s in d.shape:
            n *= s
        total += n
    return total


def param_bytes(defs: Any) -> int:
    total = 0
    for d in jax.tree.leaves(defs, is_leaf=is_def):
        n = 1
        for s in d.shape:
            n *= s
        total += n * jnp.dtype(d.dtype).itemsize
    return total


def stack_defs(d: ParamDef, n: int, axis_name: Optional[str] = "layers") -> ParamDef:
    """Prepend a stacking dimension (for lax.scan'd layer stacks)."""
    return dataclasses.replace(
        d, shape=(n, *d.shape), axes=(axis_name, *d.axes)
    )


def stack_tree(defs: Any, n: int, axis_name: Optional[str] = "layers") -> Any:
    return jax.tree.map(
        lambda d: stack_defs(d, n, axis_name), defs, is_leaf=is_def
    )
