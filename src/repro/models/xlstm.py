"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, recurrent) [arXiv:2405.04517].

mLSTM recurrence per head (state C ∈ R^{dv×dk}, normalizer n ∈ R^{dk}):

    C_t = f_t C_{t-1} + i_t v_t k_t^T
    n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t q_t) / max(|n_t · q_t|, 1)

We use sigmoid forget gates and soft-capped exponential input gates
(|ĩ| ≤ 5 via tanh cap) instead of the paper's running-max stabilizer —
recorded in DESIGN.md §5; the fp32 normalizer keeps the chunkwise form
numerically stable. The chunkwise algorithm mirrors
:func:`repro.models.ssm.ssd_chunked` (same dual form).

sLSTM keeps per-head-channel scalar state with recurrent gate connections
(block-diagonal R), which forces a sequential ``lax.scan`` — the price of
the sLSTM's state-tracking abilities, as the paper notes.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef

GATE_CAP = 5.0


def _capped_exp_gate(pre: jax.Array) -> jax.Array:
    """exp with tanh-capped preactivation (stability without running max)."""
    return jnp.exp(GATE_CAP * jnp.tanh(pre.astype(jnp.float32) / GATE_CAP))


# ---------------------------------------------------------------------------
# mLSTM cell — chunkwise parallel + single step
# ---------------------------------------------------------------------------


def mlstm_chunked(
    q: jax.Array,  # (B, L, H, Dk)
    k: jax.Array,  # (B, L, H, Dk)
    v: jax.Array,  # (B, L, H, Dv)
    i_pre: jax.Array,  # (B, L, H) input-gate preactivation
    f_pre: jax.Array,  # (B, L, H) forget-gate preactivation
    *,
    chunk: int = 128,
    initial_state: Optional[tuple[jax.Array, jax.Array]] = None,  # (C, n)
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Returns (h (B,L,H,Dv), (C (B,H,Dv,Dk), n (B,H,Dk)))."""
    bsz, l, h, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, l)
    if l % chunk:
        raise ValueError(f"seq len {l} must divide chunk {chunk}")
    nck = l // chunk

    qf = q.astype(jnp.float32) / jnp.sqrt(jnp.float32(dk))
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    ig = _capped_exp_gate(i_pre)  # (B, L, H)
    log_f = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))

    qc = qf.reshape(bsz, nck, chunk, h, dk)
    kc = kf.reshape(bsz, nck, chunk, h, dk)
    vc = vf.reshape(bsz, nck, chunk, h, dv)
    ic = ig.reshape(bsz, nck, chunk, h)
    lfc = log_f.reshape(bsz, nck, chunk, h)

    cum = jnp.cumsum(lfc, axis=2)  # inclusive cumsum of log f

    # intra-chunk: h_intra[t] = Σ_{j≤t} (q_t·k_j) exp(cum_t − cum_j) i_j v_j
    qk = jnp.einsum("bkthd,bkjhd->bkhtj", qc, kc)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nck,t,j,H)
    seg = jnp.moveaxis(seg, -1, 2)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(causal[None, None, None], jnp.exp(seg), 0.0)
    w = qk * decay * jnp.moveaxis(ic, -1, 2)[:, :, :, None, :]  # i_j on axis j
    h_intra = jnp.einsum("bkhtj,bkjhv->bkthv", w, vc)
    # intra normalizer: n_t·q_t = Σ_{j≤t} decay·i_j·(k_j·q_t) = Σ_j w[t,j]
    norm_intra = jnp.einsum("bkhtj->bkth", w)

    total = cum[:, :, -1, :]  # (B,nck,H)
    state_w = jnp.exp(total[:, :, None, :] - cum) * ic  # (B,nck,Q,H)
    c_in = jnp.einsum("bkjhv,bkjhd,bkjh->bkhvd", vc, kc, state_w)
    n_in = jnp.einsum("bkjhd,bkjh->bkhd", kc, state_w)
    read_w = jnp.exp(cum)  # (B,nck,Q,H)

    if initial_state is None:
        c0 = jnp.zeros((bsz, h, dv, dk), jnp.float32)
        n0 = jnp.zeros((bsz, h, dk), jnp.float32)
    else:
        c0 = initial_state[0].astype(jnp.float32)
        n0 = initial_state[1].astype(jnp.float32)

    def body(carry, inputs):
        c_prev, n_prev = carry
        h_in, nm_in, c_add, n_add, tot, q_blk, r_w = inputs
        h_cross = (
            jnp.einsum("bthd,bhvd->bthv", q_blk, c_prev) * r_w[..., None]
        )
        nm_cross = jnp.einsum("bthd,bhd->bth", q_blk, n_prev) * r_w
        dec = jnp.exp(tot)
        c_new = dec[:, :, None, None] * c_prev + c_add
        n_new = dec[:, :, None] * n_prev + n_add
        h_num = h_in + h_cross
        nm = nm_in + nm_cross
        h_out = h_num / jnp.maximum(jnp.abs(nm), 1.0)[..., None]
        return (c_new, n_new), h_out

    xs = tuple(
        jnp.moveaxis(t, 1, 0)
        for t in (h_intra, norm_intra, c_in, n_in, total, qc, read_w)
    )
    (c_f, n_f), hs = jax.lax.scan(body, (c0, n0), xs)
    h_out = jnp.moveaxis(hs, 0, 1).reshape(bsz, l, h, dv)
    return h_out.astype(v.dtype), (c_f, n_f)


def mlstm_step(
    q: jax.Array,  # (B, H, Dk)
    k: jax.Array,
    v: jax.Array,  # (B, H, Dv)
    i_pre: jax.Array,  # (B, H)
    f_pre: jax.Array,
    state: tuple[jax.Array, jax.Array],
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    c_prev, n_prev = state
    dk = q.shape[-1]
    qf = q.astype(jnp.float32) / jnp.sqrt(jnp.float32(dk))
    ig = _capped_exp_gate(i_pre)
    fg = jax.nn.sigmoid(f_pre.astype(jnp.float32))
    c_new = (
        fg[..., None, None] * c_prev
        + ig[..., None, None]
        * v.astype(jnp.float32)[..., :, None]
        * k.astype(jnp.float32)[..., None, :]
    )
    n_new = fg[..., None] * n_prev + ig[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhvd->bhv", qf, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n_new)), 1.0)
    return (num / den[..., None]).astype(v.dtype), (c_new, n_new)


# ---------------------------------------------------------------------------
# sLSTM cell — sequential scan
# ---------------------------------------------------------------------------


def slstm_scan(
    z_pre: jax.Array,  # (B, L, H, D) cell-input preactivation
    i_pre: jax.Array,  # (B, L, H, D)
    f_pre: jax.Array,
    o_pre: jax.Array,
    r_z: jax.Array,  # (H, D, D) block-diagonal recurrent weights
    r_i: jax.Array,
    r_f: jax.Array,
    r_o: jax.Array,
    *,
    initial_state: Optional[tuple] = None,  # (c, n, h, m)
) -> tuple[jax.Array, tuple]:
    """Stabilized exponential-gated scalar LSTM (per head-channel state)."""
    bsz, l, h, d = z_pre.shape
    if initial_state is None:
        zeros = jnp.zeros((bsz, h, d), jnp.float32)
        state0 = (zeros, zeros + 1e-6, zeros, zeros - 10.0)
    else:
        state0 = tuple(s.astype(jnp.float32) for s in initial_state)

    def body(carry, x_t):
        c, n, h_prev, m = carry
        zp, ip, fp, op = x_t  # each (B, H, D)
        # recurrent contributions (block-diagonal per head)
        zr = jnp.einsum("bhd,hde->bhe", h_prev, r_z)
        ir = jnp.einsum("bhd,hde->bhe", h_prev, r_i)
        fr = jnp.einsum("bhd,hde->bhe", h_prev, r_f)
        orr = jnp.einsum("bhd,hde->bhe", h_prev, r_o)
        zt = jnp.tanh(zp + zr)
        it_pre = ip + ir
        ft_pre = fp + fr
        # stabilizer: m_t = max(log f + m, log i)
        log_f = jax.nn.log_sigmoid(ft_pre)
        m_new = jnp.maximum(log_f + m, it_pre)
        i_g = jnp.exp(it_pre - m_new)
        f_g = jnp.exp(log_f + m - m_new)
        c_new = f_g * c + i_g * zt
        n_new = f_g * n + i_g
        h_new = jax.nn.sigmoid(op + orr) * (c_new / jnp.maximum(n_new, 1e-6))
        return (c_new, n_new, h_new, m_new), h_new

    seq = tuple(
        jnp.moveaxis(t.astype(jnp.float32), 1, 0)
        for t in (z_pre, i_pre, f_pre, o_pre)
    )
    final, hs = jax.lax.scan(body, state0, seq)
    return jnp.moveaxis(hs, 0, 1).astype(z_pre.dtype), final


# ---------------------------------------------------------------------------
# Block-level param defs (pre-up-projection mLSTM / post-up sLSTM)
# ---------------------------------------------------------------------------


def mlstm_block_defs(d_model: int, n_heads: int) -> dict:
    d_in = 2 * d_model  # pf = 2 up-projection
    hd = d_in // n_heads
    return {
        "norm": ParamDef((d_model,), ("embed",), init="zeros", dtype=jnp.float32),
        "w_up": ParamDef((d_model, 2 * d_in), ("embed", "ffn"), init="scaled"),
        # block-diagonal per-head q/k/v (xLSTM repo's qkv_proj_blocksize)
        "w_q": ParamDef((n_heads, hd, hd), ("heads", None, None), init="scaled"),
        "w_k": ParamDef((n_heads, hd, hd), ("heads", None, None), init="scaled"),
        "w_v": ParamDef((n_heads, hd, hd), ("heads", None, None), init="scaled"),
        "w_i": ParamDef((d_in, n_heads), (None, "heads"), init="scaled"),
        "w_f": ParamDef((d_in, n_heads), (None, "heads"), init="scaled"),
        "f_bias": ParamDef((n_heads,), ("heads",), init="ones", dtype=jnp.float32),
        "skip": ParamDef((d_in,), ("ffn",), init="ones", dtype=jnp.float32),
        "w_down": ParamDef((d_in, d_model), ("ffn", "embed"), init="scaled"),
    }


def slstm_block_defs(d_model: int, n_heads: int) -> dict:
    hd = d_model // n_heads
    # pf = 4/3 post-up MLP, rounded to a 128 multiple (TP-friendly)
    d_up = (((4 * d_model) // 3 + 127) // 128) * 128
    gates = {
        f"w_{g}": ParamDef(
            (d_model, n_heads, hd), (None, "heads", "head_dim"), init="scaled"
        )
        for g in ("z", "i", "f", "o")
    }
    recs = {
        f"r_{g}": ParamDef((n_heads, hd, hd), ("heads", None, None), init="scaled")
        for g in ("z", "i", "f", "o")
    }
    return {
        "norm": ParamDef((d_model,), ("embed",), init="zeros", dtype=jnp.float32),
        **gates,
        **recs,
        "w_o_proj": ParamDef((d_model, d_model), (None, "embed"), init="scaled"),
        "mlp_norm": ParamDef((d_model,), ("embed",), init="zeros", dtype=jnp.float32),
        "w_mlp_up": ParamDef((d_model, d_up), ("embed", "ffn"), init="scaled"),
        "w_mlp_down": ParamDef((d_up, d_model), (("ffn"), "embed"), init="scaled"),
    }


def mlstm_block(
    x: jax.Array,
    params: dict,
    *,
    n_heads: int,
    chunk: int = 128,
    initial_state=None,
    step: bool = False,
):
    """Pre-up-projection mLSTM block. Returns (out, state)."""
    from repro.models.layers import rms_norm

    bsz, l, d = x.shape
    d_in = params["skip"].shape[0]
    hd = d_in // n_heads
    xn = rms_norm(x, params["norm"])
    up = jnp.einsum("bld,de->ble", xn, params["w_up"])
    u, zgate = jnp.split(up, 2, axis=-1)
    uh = u.reshape(bsz, l, n_heads, hd)
    q = jnp.einsum("blhe,hed->blhd", uh, params["w_q"])
    k = jnp.einsum("blhe,hed->blhd", uh, params["w_k"])
    v = jnp.einsum("blhe,hed->blhd", uh, params["w_v"])
    ip = jnp.einsum("ble,eh->blh", u, params["w_i"])
    fp = jnp.einsum("ble,eh->blh", u, params["w_f"]) + params["f_bias"]

    if step:
        h, state = mlstm_step(
            q[:, 0], k[:, 0], v[:, 0], ip[:, 0], fp[:, 0], initial_state
        )
        h = h[:, None]
    else:
        h, state = mlstm_chunked(
            q, k, v, ip, fp, chunk=chunk, initial_state=initial_state
        )
    h = h.reshape(bsz, l, d_in)
    h = h + u * params["skip"].astype(h.dtype)
    h = h * jax.nn.silu(zgate)
    return x + jnp.einsum("ble,ed->bld", h, params["w_down"]), state


def slstm_block(
    x: jax.Array,
    params: dict,
    *,
    n_heads: int,
    initial_state=None,
):
    """Post-up-projection sLSTM block. Returns (out, state)."""
    from repro.models.layers import rms_norm

    bsz, l, d = x.shape
    xn = rms_norm(x, params["norm"])
    pre = {
        g: jnp.einsum("bld,dhe->blhe", xn, params[f"w_{g}"])
        for g in ("z", "i", "f", "o")
    }
    h, state = slstm_scan(
        pre["z"],
        pre["i"],
        pre["f"],
        pre["o"],
        params["r_z"],
        params["r_i"],
        params["r_f"],
        params["r_o"],
        initial_state=initial_state,
    )
    h = h.reshape(bsz, l, d)
    y = x + jnp.einsum("bld,de->ble", h, params["w_o_proj"])
    # pf-4/3 MLP
    yn = rms_norm(y, params["mlp_norm"])
    hidden = jax.nn.gelu(jnp.einsum("bld,df->blf", yn, params["w_mlp_up"]))
    return y + jnp.einsum("blf,fd->bld", hidden, params["w_mlp_down"]), state
