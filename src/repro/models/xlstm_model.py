"""xLSTM model assembly: groups of (slstm_every−1) mLSTM blocks + 1 sLSTM.

xlstm-350m: 24 blocks, sLSTM at every 8th position → 3 groups of
(7 mLSTM + 1 sLSTM). Outer scan over groups, inner scan over mLSTM blocks.
Decode state is O(1): per-layer (C, n) matrices for mLSTM and (c, n, h, m)
scalars for sLSTM — no KV cache at any context length (the long_500k cell).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models import heads as heads_lib
from repro.models.params import ParamDef, stack_tree
from repro.models.xlstm import (
    mlstm_block,
    mlstm_block_defs,
    slstm_block,
    slstm_block_defs,
)


def xlstm_defs(cfg: ArchConfig) -> dict:
    k = cfg.slstm_every
    if k < 2 or cfg.n_layers % k:
        raise ValueError("n_layers must divide slstm_every (>=2)")
    n_groups = cfg.n_layers // k
    per_group_m = k - 1
    return {
        "embed": ParamDef((cfg.padded_vocab, cfg.d_model), ("vocab", "embed")),
        "mlstm": stack_tree(
            stack_tree(mlstm_block_defs(cfg.d_model, cfg.n_heads), per_group_m, "sub"),
            n_groups,
        ),
        "slstm": stack_tree(
            slstm_block_defs(cfg.d_model, cfg.n_heads), n_groups
        ),
        "final_norm": ParamDef(
            (cfg.d_model,), ("embed",), init="zeros", dtype=jnp.float32
        ),
        "lm_head": ParamDef(
            (cfg.d_model, cfg.padded_vocab), ("embed", "vocab"), init="scaled"
        ),
    }


def _group_scan(
    params,
    cfg: ArchConfig,
    x,
    *,
    step: bool = False,
    states: Optional[Any] = None,
    remat: str = "none",
):
    def group_step(carry, xs):
        h = carry
        p_m, p_s, st = xs
        m_states = None if st is None else st["mlstm"]
        s_state = None if st is None else st["slstm"]

        def run(h):
            def mlstm_step_fn(hh, xs2):
                p_blk, st_blk = xs2
                out, new_st = mlstm_block(
                    hh, p_blk, n_heads=cfg.n_heads,
                    initial_state=st_blk, step=step,
                )
                return out, new_st

            h2, new_m = jax.lax.scan(mlstm_step_fn, h, (p_m, m_states))
            h2, new_s = slstm_block(
                h2, p_s, n_heads=cfg.n_heads, initial_state=s_state
            )
            return h2, {"mlstm": new_m, "slstm": new_s}

        if remat == "full":
            run = jax.checkpoint(
                run, policy=jax.checkpoint_policies.nothing_saveable
            )
        h2, new_state = run(h)
        return h2, new_state

    x, new_states = jax.lax.scan(
        group_step, x, (params["mlstm"], params["slstm"], states)
    )
    return x, new_states


def _embed(params, cfg, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    return constrain(x, ("batch", None, "embed"))


def _finish(params, cfg: ArchConfig, x):
    from repro.models.layers import rms_norm

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    vv = cfg.vocab if cfg.padded_vocab != cfg.vocab else None
    return heads_lib.lm_logits(x, params["lm_head"], valid_vocab=vv)


def forward(params, cfg: ArchConfig, batch: dict, *, remat: str = "none", **_):
    x = _embed(params, cfg, batch["tokens"])
    x, _ = _group_scan(params, cfg, x, remat=remat)
    return _finish(params, cfg, x), jnp.zeros((), jnp.float32)


def loss_fn(params, cfg: ArchConfig, batch: dict, *, remat: str = "none", **kw):
    logits, _ = forward(params, cfg, batch, remat=remat)
    loss, metrics = heads_lib.softmax_xent(logits, batch["labels"])
    metrics["total_loss"] = loss
    return loss, metrics


def prefill(params, cfg: ArchConfig, batch: dict, **_):
    x = _embed(params, cfg, batch["tokens"])
    x, states = _group_scan(params, cfg, x)
    return _finish(params, cfg, x[:, -1:])[:, 0], states


def decode_step(params, cfg: ArchConfig, states: Any, batch: dict, **_):
    x = _embed(params, cfg, batch["tokens"])
    x, new_states = _group_scan(params, cfg, x, step=True, states=states)
    return _finish(params, cfg, x)[:, 0], new_states
