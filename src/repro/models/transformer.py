"""Unified attention-transformer assembly (dense / moe / vlm / audio).

One definition covers gemma-2b, granite-3-8b, yi-6b, granite-34b,
llama4-scout, llama4-maverick (alternating dense/MoE), qwen2-vl (M-RoPE,
embedding frontend), musicgen (cross-attention + codebook heads),
llama3-70b and qwen3-235b.

Layer stacks are ``lax.scan``'d over stacked parameters (one scan step =
``moe_every`` consecutive layers so alternating patterns stay scannable),
with optional activation rematerialization in train mode.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models import heads as heads_lib
from repro.models.layers import (
    apply_rope,
    decode_attention,
    flash_attention,
    mlp,
    mrope_angles,
    rms_norm,
    rope_angles,
)
from repro.models.moe import (
    DECODE_CAPACITY_FACTOR,
    PREFILL_CAPACITY_FACTOR,
    TRAIN_CAPACITY_FACTOR,
    moe_layer,
    moe_param_defs,
)
from repro.models.params import ParamDef, stack_tree

# ---------------------------------------------------------------------------
# Parameter declarations
# ---------------------------------------------------------------------------


def attention_defs(cfg: ArchConfig, *, cross: bool = False) -> dict:
    h, k, dh, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    prefix = "cross_" if cross else ""
    return {
        f"{prefix}attn_norm": ParamDef(
            (d,), ("embed",), init="zeros", dtype=jnp.float32
        ),
        f"{prefix}w_q": ParamDef(
            (d, h, dh), ("embed", "heads", "head_dim"), init="scaled"
        ),
        f"{prefix}w_k": ParamDef(
            (d, k, dh), ("embed", "kv_heads", "head_dim"), init="scaled"
        ),
        f"{prefix}w_v": ParamDef(
            (d, k, dh), ("embed", "kv_heads", "head_dim"), init="scaled"
        ),
        f"{prefix}w_o": ParamDef(
            (h, dh, d), ("heads", "head_dim", "embed"), init="scaled"
        ),
    }


def mlp_defs(cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    defs = {
        "mlp_norm": ParamDef((d,), ("embed",), init="zeros", dtype=jnp.float32),
        "w_up": ParamDef((d, f), ("embed", "ffn"), init="scaled"),
        "w_down": ParamDef((f, d), ("ffn", "embed"), init="scaled"),
    }
    if cfg.activation in ("swiglu", "geglu"):
        defs["w_gate"] = ParamDef((d, f), ("embed", "ffn"), init="scaled")
    return defs


def dense_layer_defs(cfg: ArchConfig) -> dict:
    defs = {**attention_defs(cfg), **mlp_defs(cfg)}
    if cfg.cross_attention:
        defs.update(attention_defs(cfg, cross=True))
    return defs


def moe_layer_defs(cfg: ArchConfig) -> dict:
    defs = {
        **attention_defs(cfg),
        "mlp_norm": ParamDef(
            (cfg.d_model,), ("embed",), init="zeros", dtype=jnp.float32
        ),
        "moe": moe_param_defs(
            cfg.d_model,
            cfg.moe_d_ff or cfg.d_ff,
            cfg.n_experts,
            cfg.n_shared_experts,
            cfg.activation,
        ),
    }
    if cfg.cross_attention:
        defs.update(attention_defs(cfg, cross=True))
    return defs


def transformer_defs(cfg: ArchConfig) -> dict:
    """Full parameter tree for an attention-family architecture."""
    d, v = cfg.d_model, cfg.padded_vocab
    defs: dict[str, Any] = {}
    if cfg.frontend == "tokens":
        defs["embed"] = ParamDef((v, d), ("vocab", "embed"), init="normal")
    if cfg.is_moe:
        if cfg.moe_every not in (1, 2):
            raise ValueError("moe_every must be 1 or 2")
        n_steps = cfg.n_layers // cfg.moe_every
        step: dict[str, Any] = {"moe_block": moe_layer_defs(cfg)}
        if cfg.moe_every == 2:
            step["dense_block"] = dense_layer_defs(cfg)
        defs["blocks"] = stack_tree(step, n_steps)
    else:
        defs["blocks"] = stack_tree(dense_layer_defs(cfg), cfg.n_layers)
    defs["final_norm"] = ParamDef((d,), ("embed",), init="zeros", dtype=jnp.float32)
    if cfg.n_codebooks > 0:
        defs["codebook_heads"] = ParamDef(
            (cfg.n_codebooks, d, v), ("codebooks", "embed", "vocab"), init="scaled"
        )
    elif not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, v), ("embed", "vocab"), init="scaled")
    return defs


# ---------------------------------------------------------------------------
# Sublayer application
# ---------------------------------------------------------------------------


def _project_qkv(x, p, prefix=""):
    q = jnp.einsum("bld,dhk->blhk", x, p[f"{prefix}w_q"])
    k = jnp.einsum("bld,dhk->blhk", x, p[f"{prefix}w_k"])
    v = jnp.einsum("bld,dhk->blhk", x, p[f"{prefix}w_v"])
    return q, k, v


def quantize_kv(t: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-(position, head) symmetric int8 KV quantization.

    Halves decode HBM traffic and doubles slot concurrency (beyond-paper
    §Perf iteration; composes with the paper's pool right-sizing by raising
    ρ — see EXPERIMENTS.md). Scale shape (B, S, K, 1) fp16.
    """
    amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(
        jnp.round(t.astype(jnp.float32) / scale), -127, 127
    ).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def dequantize_kv(q: jax.Array, scale: jax.Array) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(
        jnp.bfloat16
    )


def _self_attention_full(
    x, p, cos, sin, cfg: ArchConfig, causal_mode: str, kv_dtype: str = "bf16"
):
    """Train/prefill self-attention over the whole sequence."""
    xn = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    q, k, v = _project_qkv(xn, p)
    if cos is not None:
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    o = flash_attention(
        q, k, v, causal=True, causal_mode=causal_mode,
        q_chunk=min(512, q.shape[1]), kv_chunk=min(512, k.shape[1]),
    )
    out = jnp.einsum("blhk,hkd->bld", o, p["w_o"])
    if kv_dtype == "int8":
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        return x + out, (kq, vq, ks, vs)
    return x + out, (k, v)


def _self_attention_decode(
    x, p, cos, sin, cfg: ArchConfig, cache, index, kv_dtype: str = "bf16"
):
    """Single-token decode; cache (k, v[, k_scale, v_scale])."""
    xn = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    q, k, v = _project_qkv(xn, p)
    if cos is not None:
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    if kv_dtype == "int8":
        k_cache, v_cache, k_scale, v_scale = cache
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        k_cache = jax.lax.dynamic_update_slice(k_cache, kq, (0, index, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, vq, (0, index, 0, 0))
        k_scale = jax.lax.dynamic_update_slice(
            k_scale, ks.astype(k_scale.dtype), (0, index, 0, 0)
        )
        v_scale = jax.lax.dynamic_update_slice(
            v_scale, vs.astype(v_scale.dtype), (0, index, 0, 0)
        )
        o = decode_attention(
            q,
            dequantize_kv(k_cache, k_scale),
            dequantize_kv(v_cache, v_scale),
            index + 1,
        )
        new_cache = (k_cache, v_cache, k_scale, v_scale)
    else:
        k_cache, v_cache = cache
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, index, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, index, 0, 0)
        )
        o = decode_attention(q, k_cache, v_cache, index + 1)
        new_cache = (k_cache, v_cache)
    out = jnp.einsum("blhk,hkd->bld", o, p["w_o"])
    return x + out, new_cache


def _cross_attention(x, p, memory_kv, cfg: ArchConfig):
    """Encoder-memory cross attention (musicgen text conditioning)."""
    mk, mv = memory_kv
    xn = rms_norm(x, p["cross_attn_norm"], cfg.norm_eps)
    q = jnp.einsum("bld,dhk->blhk", xn, p["cross_w_q"])
    o = flash_attention(
        q, mk, mv, causal=False,
        q_chunk=min(512, q.shape[1]), kv_chunk=min(512, mk.shape[1]),
    )
    return x + jnp.einsum("blhk,hkd->bld", o, p["cross_w_o"])


def _memory_kv(p, memory):
    mk = jnp.einsum("bmd,dhk->bmhk", memory, p["cross_w_k"])
    mv = jnp.einsum("bmd,dhk->bmhk", memory, p["cross_w_v"])
    return mk, mv


def _mlp_sublayer(x, p, cfg: ArchConfig):
    xn = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    return x + mlp(xn, p, cfg.activation)


def _moe_sublayer(x, p, cfg: ArchConfig, group_size: int, capacity_factor: float):
    xn = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
    out, aux = moe_layer(
        xn,
        p["moe"],
        n_experts=cfg.n_experts,
        top_k=cfg.top_k,
        activation=cfg.activation,
        group_size=group_size,
        capacity_factor=capacity_factor,
    )
    return x + out, aux


def _block_apply(
    x,
    p,
    cfg: ArchConfig,
    cos,
    sin,
    *,
    mode: str,  # full | decode
    is_moe_block: bool,
    memory=None,
    cache=None,
    index=None,
    causal_mode: str = "triangle",
    moe_group: int = 512,
    moe_cf: float = TRAIN_CAPACITY_FACTOR,
    kv_dtype: str = "bf16",
):
    """One (sub-)layer: self-attn [+cross] + (mlp | moe). Returns
    (x, new_cache, aux_loss)."""
    n_self = 4 if kv_dtype == "int8" else 2
    if mode == "full":
        x, kv = _self_attention_full(
            x, p, cos, sin, cfg, causal_mode, kv_dtype
        )
        new_cache = kv
    else:
        x, new_cache = _self_attention_decode(
            x, p, cos, sin, cfg, cache[:n_self], index, kv_dtype
        )
    if cfg.cross_attention:
        if mode == "full":
            mkv = _memory_kv(p, memory)
            new_cache = (*new_cache, *mkv)
        else:
            mkv = cache[n_self:]
            new_cache = (*new_cache, *mkv)
        x = _cross_attention(x, p, mkv, cfg)
    aux = jnp.zeros((), jnp.float32)
    if is_moe_block:
        x, aux = _moe_sublayer(x, p, cfg, moe_group, moe_cf)
    else:
        x = _mlp_sublayer(x, p, cfg)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Whole-model forward passes
# ---------------------------------------------------------------------------


def _positions_full(batch, cfg: ArchConfig, length: int):
    if cfg.pos_type == "none":
        return None, None
    if cfg.pos_type == "mrope":
        pos = batch["positions"]  # (3, B, L)
        return mrope_angles(pos, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections)
    bsz = (
        batch["tokens"].shape[0]
        if "tokens" in batch
        else batch["embeds"].shape[0]
    )
    pos = jnp.broadcast_to(jnp.arange(length)[None], (bsz, length))
    return rope_angles(pos, cfg.head_dim, cfg.rope_theta)


def _embed_input(params, cfg: ArchConfig, batch) -> jax.Array:
    if cfg.frontend == "tokens":
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        if cfg.tie_embeddings:  # gemma-style sqrt(d) scaling
            x = x * jnp.sqrt(jnp.array(cfg.d_model, x.dtype))
    else:
        x = batch["embeds"]
    return constrain(x, ("batch", None, "embed"))


def _scan_blocks(
    params,
    cfg: ArchConfig,
    x,
    cos,
    sin,
    *,
    mode: str,
    memory=None,
    caches=None,
    index=None,
    remat: str = "none",
    causal_mode: str = "triangle",
    moe_group: int = 512,
    moe_cf: float = TRAIN_CAPACITY_FACTOR,
    kv_dtype: str = "bf16",
):
    """Scan over the stacked layer blocks. Returns (x, new_caches, aux)."""

    def step(carry, xs):
        h, aux_acc = carry
        p_step, cache_step = xs

        def run(h):
            aux_step = jnp.zeros((), jnp.float32)
            new_caches = {}
            if cfg.is_moe:
                if cfg.moe_every == 2:
                    h2, nc, a = _block_apply(
                        h, p_step["dense_block"], cfg, cos, sin, mode=mode,
                        is_moe_block=False, memory=memory,
                        cache=None if cache_step is None else cache_step["dense_block"],
                        index=index, causal_mode=causal_mode, moe_group=moe_group,
                        moe_cf=moe_cf, kv_dtype=kv_dtype,
                    )
                    new_caches["dense_block"] = nc
                    aux_step = aux_step + a
                else:
                    h2 = h
                h2, nc, a = _block_apply(
                    h2, p_step["moe_block"], cfg, cos, sin, mode=mode,
                    is_moe_block=True, memory=memory,
                    cache=None if cache_step is None else cache_step["moe_block"],
                    index=index, causal_mode=causal_mode, moe_group=moe_group,
                    moe_cf=moe_cf, kv_dtype=kv_dtype,
                )
                new_caches["moe_block"] = nc
                aux_step = aux_step + a
            else:
                h2, nc, a = _block_apply(
                    h, p_step, cfg, cos, sin, mode=mode,
                    is_moe_block=False, memory=memory, cache=cache_step,
                    index=index, causal_mode=causal_mode, moe_group=moe_group,
                    moe_cf=moe_cf, kv_dtype=kv_dtype,
                )
                new_caches = nc
                aux_step = aux_step + a
            return h2, new_caches, aux_step

        if remat == "full":
            run = jax.checkpoint(
                run, policy=jax.checkpoint_policies.nothing_saveable
            )
        elif remat == "dots":
            run = jax.checkpoint(
                run,
                policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            )
        h2, new_caches, aux_step = run(h)
        return (h2, aux_acc + aux_step), new_caches

    xs = (params["blocks"], caches)
    (x, aux), new_caches = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)), xs)
    return x, new_caches, aux


def _head(params, cfg: ArchConfig, x):
    vv = cfg.vocab if cfg.padded_vocab != cfg.vocab else None
    if cfg.n_codebooks > 0:
        return heads_lib.codebook_logits(
            x, params["codebook_heads"], valid_vocab=vv
        )
    if cfg.tie_embeddings:
        return heads_lib.lm_logits(x, params["embed"], tied=True, valid_vocab=vv)
    return heads_lib.lm_logits(x, params["lm_head"], valid_vocab=vv)


def forward(
    params,
    cfg: ArchConfig,
    batch: dict,
    *,
    remat: str = "none",
    causal_mode: str = "triangle",
    moe_group: int = 512,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward → (logits, aux_loss). Train/eval mode."""
    x = _embed_input(params, cfg, batch)
    length = x.shape[1]
    cos, sin = _positions_full(batch, cfg, length)
    memory = batch.get("memory")
    x, _, aux = _scan_blocks(
        params, cfg, x, cos, sin, mode="full", memory=memory,
        remat=remat, causal_mode=causal_mode, moe_group=moe_group,
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _head(params, cfg, x)
    return logits, aux


def loss_fn(
    params,
    cfg: ArchConfig,
    batch: dict,
    *,
    remat: str = "none",
    aux_coeff: float = 0.01,
    causal_mode: str = "triangle",
    moe_group: int = 512,
) -> tuple[jax.Array, dict]:
    logits, aux = forward(
        params, cfg, batch, remat=remat, causal_mode=causal_mode,
        moe_group=moe_group,
    )
    loss, metrics = heads_lib.softmax_xent(logits, batch["labels"])
    total = loss + aux_coeff * aux
    metrics["aux_loss"] = aux
    metrics["total_loss"] = total
    return total, metrics


def prefill(
    params,
    cfg: ArchConfig,
    batch: dict,
    *,
    causal_mode: str = "triangle",
    moe_group: int = 512,
    kv_dtype: str = "bf16",
) -> tuple[jax.Array, Any]:
    """Prefill pass → (last-position logits, kv caches)."""
    x = _embed_input(params, cfg, batch)
    length = x.shape[1]
    cos, sin = _positions_full(batch, cfg, length)
    memory = batch.get("memory")
    x, caches, _ = _scan_blocks(
        params, cfg, x, cos, sin, mode="full", memory=memory,
        causal_mode=causal_mode, moe_group=moe_group,
        moe_cf=PREFILL_CAPACITY_FACTOR, kv_dtype=kv_dtype,
    )
    # "last_pos" supports right-padded prompts (serving buckets): logits are
    # taken at the true last prompt token, not the padded end.
    if "last_pos" in batch:
        x = jax.vmap(
            lambda h, p: jax.lax.dynamic_slice_in_dim(h, p, 1, axis=0)
        )(x, batch["last_pos"])
    else:
        x = x[:, -1:]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _head(params, cfg, x)
    return logits[:, 0], caches


def decode_step(
    params,
    cfg: ArchConfig,
    caches: Any,
    batch: dict,
    *,
    moe_group: int = 512,
    kv_dtype: str = "bf16",
) -> tuple[jax.Array, Any]:
    """One decode iteration. ``batch["index"]`` is the write position;
    caches are (k, v[, cross_k, cross_v]) stacked over scan steps."""
    x = _embed_input(params, cfg, batch)
    index = batch["index"]
    if cfg.pos_type == "none":
        cos = sin = None
    elif cfg.pos_type == "mrope":
        cos, sin = mrope_angles(
            batch["positions"], cfg.head_dim, cfg.rope_theta, cfg.mrope_sections
        )
    else:
        bsz = x.shape[0]
        pos = jnp.broadcast_to(
            jnp.asarray(index)[None, None], (bsz, 1)
        )
        cos, sin = rope_angles(pos, cfg.head_dim, cfg.rope_theta)
    x, new_caches, _ = _scan_blocks(
        params, cfg, x, cos, sin, mode="decode", caches=caches, index=index,
        moe_group=moe_group, moe_cf=DECODE_CAPACITY_FACTOR, kv_dtype=kv_dtype,
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _head(params, cfg, x)
    return logits[:, 0], new_caches
