"""Model zoo: all assigned architectures in pure JAX."""

from repro.models.model_zoo import Model, get_model
from repro.models.params import (
    ParamDef,
    abstract_params,
    init_params,
    param_axes,
    param_bytes,
    param_count,
)

__all__ = [
    "Model",
    "get_model",
    "ParamDef",
    "abstract_params",
    "init_params",
    "param_axes",
    "param_bytes",
    "param_count",
]
