"""Mixture-of-Experts layer (GShard-style grouped dispatch, EP over "model").

Top-k routing with capacity-bounded dispatch/combine einsums. Tokens are
processed in groups of ``group_size`` so the dispatch tensor
(G, g, E, C) stays ~O(g²·cf) elements per group regardless of expert count
(C ∝ g·k/E). Experts are sharded over the "model" mesh axis (expert
parallelism as a sub-case of the tensor axis — DESIGN.md §6).

Used by llama4-scout (16e top-1 + shared), llama4-maverick (128e top-1 +
shared, every other layer) and qwen3-235b (128e top-8).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.params import ParamDef


def moe_param_defs(
    d_model: int,
    d_ff: int,
    n_experts: int,
    n_shared: int,
    activation: str,
) -> dict:
    """Parameter declarations for one MoE layer."""
    e_axes3 = ("experts", "embed", "ffn")
    e_axes3_t = ("experts", "ffn", "embed")
    defs: dict = {
        "router": ParamDef(
            (d_model, n_experts), ("embed", None), init="scaled", dtype=jnp.float32
        ),
        "w_up": ParamDef((n_experts, d_model, d_ff), e_axes3, init="scaled"),
        "w_down": ParamDef((n_experts, d_ff, d_model), e_axes3_t, init="scaled"),
    }
    if activation in ("swiglu", "geglu"):
        defs["w_gate"] = ParamDef((n_experts, d_model, d_ff), e_axes3, init="scaled")
    if n_shared > 0:
        sh = {
            "w_up": ParamDef((d_model, n_shared * d_ff), ("embed", "ffn"), init="scaled"),
            "w_down": ParamDef((n_shared * d_ff, d_model), ("ffn", "embed"), init="scaled"),
        }
        if activation in ("swiglu", "geglu"):
            sh["w_gate"] = ParamDef(
                (d_model, n_shared * d_ff), ("embed", "ffn"), init="scaled"
            )
        defs["shared"] = sh
    return defs


def _expert_ffn(x: jax.Array, params: dict, activation: str) -> jax.Array:
    """x: (E, C', d_model) per expert → (E, C', d_model)."""
    up = jnp.einsum("ecd,edf->ecf", x, params["w_up"])
    if activation in ("swiglu", "geglu"):
        gate = jnp.einsum("ecd,edf->ecf", x, params["w_gate"])
        act = jax.nn.silu(gate) if activation == "swiglu" else jax.nn.gelu(gate)
        hidden = act * up
    else:
        hidden = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", hidden, params["w_down"])


#: Capacity factors per mode. Training uses the GShard standard (drops are a
#: regularizer); serving paths use a large factor so drops are effectively
#: impossible (vLLM MoE semantics). A ragged group-matmul kernel would make
#: serving exactly dropless without the capacity padding — noted in
#: EXPERIMENTS.md §Perf as future work.
TRAIN_CAPACITY_FACTOR = 1.25
PREFILL_CAPACITY_FACTOR = 2.0
DECODE_CAPACITY_FACTOR = 4.0


def moe_layer(
    x: jax.Array,  # (B, L, d_model)
    params: dict,
    *,
    n_experts: int,
    top_k: int,
    activation: str,
    group_size: int = 512,
    capacity_factor: float = TRAIN_CAPACITY_FACTOR,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output, aux_load_balance_loss)."""
    b, l, d = x.shape
    dtype = x.dtype
    tokens = x.reshape(-1, d)
    n_tok = tokens.shape[0]
    g = min(group_size, n_tok)
    if n_tok % g:
        raise ValueError(f"tokens {n_tok} must divide group size {g}")
    n_groups = n_tok // g
    capacity = max(
        top_k, min(g, int(g * top_k * capacity_factor / n_experts))
    )

    xg = tokens.reshape(n_groups, g, d)  # (G, g, d)
    logits = jnp.einsum(
        "Ggd,de->Gge", xg.astype(jnp.float32), params["router"]
    )  # (G, g, E)
    probs = jax.nn.softmax(logits, axis=-1)

    # Load-balancing auxiliary loss (Switch §2.2): E * Σ_e f_e · p_e.
    me = jnp.mean(probs, axis=1)  # (G, E) mean router prob
    top1 = jnp.argmax(probs, axis=-1)
    ce = jnp.mean(
        jax.nn.one_hot(top1, n_experts, dtype=jnp.float32), axis=1
    )  # (G, E) fraction dispatched
    aux_loss = n_experts * jnp.mean(jnp.sum(me * ce, axis=-1))

    # Top-k expert choice per token.
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)  # (G, g, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    combine = jnp.zeros((n_groups, g, n_experts, capacity), jnp.float32)
    for slot in range(top_k):
        e_idx = expert_idx[..., slot]  # (G, g)
        e_oh = jax.nn.one_hot(e_idx, n_experts, dtype=jnp.float32)
        # position of each token within its expert's capacity buffer
        pos = jnp.cumsum(e_oh, axis=1) - 1.0  # (G, g, E)
        pos_tok = jnp.sum(pos * e_oh, axis=-1)  # (G, g)
        in_cap = pos_tok < capacity
        gate = gate_vals[..., slot] * in_cap  # dropped tokens → 0 gate
        pos_oh = jax.nn.one_hot(
            jnp.where(in_cap, pos_tok, capacity).astype(jnp.int32),
            capacity,
            dtype=jnp.float32,
        )  # (G, g, C)
        combine = combine + jnp.einsum(
            "Gg,Gge,Ggc->Ggec", gate, e_oh, pos_oh
        )

    dispatch = (combine > 0).astype(dtype)  # (G, g, E, C)
    expert_in = jnp.einsum("Ggec,Ggd->Gecd", dispatch, xg)  # (G, E, C, d)
    expert_in = constrain(expert_in, (None, "experts", None, None))

    eo = jax.vmap(lambda xi: _expert_ffn(xi, params, activation))(expert_in)
    eo = constrain(eo, (None, "experts", None, None))

    out = jnp.einsum("Ggec,Gecd->Ggd", combine.astype(dtype), eo)

    if "shared" in params:
        sh = params["shared"]
        if activation in ("swiglu", "geglu"):
            gate = jnp.einsum("Ggd,df->Ggf", xg, sh["w_gate"])
            up = jnp.einsum("Ggd,df->Ggf", xg, sh["w_up"])
            a = jax.nn.silu(gate) if activation == "swiglu" else jax.nn.gelu(gate)
            hidden = a * up
        else:
            hidden = jax.nn.gelu(jnp.einsum("Ggd,df->Ggf", xg, sh["w_up"]))
        out = out + jnp.einsum("Ggf,fd->Ggd", hidden, sh["w_down"])

    return out.reshape(b, l, d).astype(dtype), aux_loss.astype(jnp.float32)
