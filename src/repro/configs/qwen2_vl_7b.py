"""qwen2-vl-7b — VLM backbone with M-RoPE and GQA kv=4 [arXiv:2409.12191].

The vision frontend (dynamic-resolution ViT) is a STUB per the assignment:
``input_specs()`` provides precomputed patch/text embeddings plus the 3D
M-RoPE position ids (temporal, height, width)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18_944,
    vocab=152_064,
    activation="swiglu",
    pos_type="mrope",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),  # head_dim/2 = 64 rotary pairs: t/h/w
    frontend="embeddings",
    max_context=65_536,
    source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-7B-Instruct",
)
