"""llama3-70b — the paper's own evaluation model (§4.1) [arXiv:2407.21783]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-70b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    vocab=128_256,
    activation="swiglu",
    pos_type="rope",
    rope_theta=500_000.0,
    max_context=65_536,
    source="arXiv:2407.21783; hf:meta-llama/Meta-Llama-3-70B-Instruct",
)
