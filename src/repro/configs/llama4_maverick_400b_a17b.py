"""llama4-maverick-400b-a17b — MoE 128 experts top-1 + shared expert,
alternating dense/MoE layers, GQA kv=8 [hf:meta-llama/Llama-4-Maverick;
unverified]. Text backbone only."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202_048,
    activation="swiglu",
    pos_type="rope",
    rope_theta=500_000.0,
    n_experts=128,
    top_k=1,
    n_shared_experts=1,
    moe_every=2,  # Maverick: MoE every other layer
    moe_d_ff=8192,
    max_context=65_536,
    source="hf:meta-llama/Llama-4-Maverick-17B-128E (unverified)",
)
