"""llama4-scout-17b-a16e — MoE 16 experts top-1 + shared expert, GQA kv=8
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]. Text backbone only
(early-fusion multimodality out of scope per LM-family shape assignment)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202_048,
    activation="swiglu",
    pos_type="rope",
    rope_theta=500_000.0,
    n_experts=16,
    top_k=1,
    n_shared_experts=1,
    moe_every=1,  # Scout: MoE on every layer
    moe_d_ff=8192,
    max_context=65_536,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (unverified)",
)
