"""qwen3-235b-a22b — the paper's §4.7 case-study model (MoE 128e top-8,
GQA 16:1) [Qwen3 Technical Report]. Used by the Table-5 benchmark's cost
model and available as a full model config."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=12_288,
    vocab=151_936,
    activation="swiglu",
    pos_type="rope",
    rope_theta=1_000_000.0,
    n_experts=128,
    top_k=8,
    n_shared_experts=0,
    moe_every=1,
    moe_d_ff=1536,
    max_context=65_536,
    source="Qwen3 Technical Report; hf:Qwen/Qwen3-235B-A22B",
)
