"""granite-34b — llama-arch code model, MQA (kv=1), 88L [arXiv:2405.04324]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24_576,
    vocab=49_152,
    activation="gelu",  # GPTBigCode-style plain MLP (hf config)
    pos_type="rope",
    rope_theta=10_000.0,
    max_context=65_536,
    source="arXiv:2405.04324; hf:ibm-granite/granite-34b-code-base",
)
