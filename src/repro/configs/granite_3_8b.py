"""granite-3-8b — dense GQA (kv=8) [hf:ibm-granite/granite-3.0-8b-base]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12_800,
    vocab=49_155,
    activation="swiglu",
    pos_type="rope",
    rope_theta=10_000.0,
    max_context=65_536,
    source="hf:ibm-granite/granite-3.0-8b-base",
)
