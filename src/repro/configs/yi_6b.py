"""yi-6b — llama-arch dense GQA (kv=4) [arXiv:2403.04652; hf:01-ai/Yi-6B]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11_008,
    vocab=64_000,
    activation="swiglu",
    pos_type="rope",
    rope_theta=5_000_000.0,
    max_context=65_536,
    source="arXiv:2403.04652; hf:01-ai/Yi-6B",
)
