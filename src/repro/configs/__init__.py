"""Architecture registry: ``--arch <id>`` resolves here.

One module per assigned architecture (exact published dims) plus the paper's
own evaluation model (llama3-70b) and the §4.7 case-study model
(qwen3-235b-a22b).
"""

from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES_BY_NAME,
    SUB_QUADRATIC_FAMILIES,
    TRAIN_4K,
    ArchConfig,
    ShapeCell,
    shape_applicable,
)
from repro.configs.gemma_2b import CONFIG as GEMMA_2B
from repro.configs.granite_3_8b import CONFIG as GRANITE_3_8B
from repro.configs.granite_34b import CONFIG as GRANITE_34B
from repro.configs.llama3_70b import CONFIG as LLAMA3_70B
from repro.configs.llama4_maverick_400b_a17b import CONFIG as LLAMA4_MAVERICK
from repro.configs.llama4_scout_17b_a16e import CONFIG as LLAMA4_SCOUT
from repro.configs.musicgen_medium import CONFIG as MUSICGEN_MEDIUM
from repro.configs.qwen2_vl_7b import CONFIG as QWEN2_VL_7B
from repro.configs.qwen3_235b_a22b import CONFIG as QWEN3_235B
from repro.configs.xlstm_350m import CONFIG as XLSTM_350M
from repro.configs.yi_6b import CONFIG as YI_6B
from repro.configs.zamba2_2_7b import CONFIG as ZAMBA2_2_7B

#: The ten assigned architectures (dry-run matrix rows), in assignment order.
ASSIGNED: tuple[ArchConfig, ...] = (
    GEMMA_2B,
    GRANITE_3_8B,
    YI_6B,
    GRANITE_34B,
    LLAMA4_SCOUT,
    LLAMA4_MAVERICK,
    QWEN2_VL_7B,
    MUSICGEN_MEDIUM,
    ZAMBA2_2_7B,
    XLSTM_350M,
)

#: Paper-specific models (evaluation + case study).
PAPER_MODELS: tuple[ArchConfig, ...] = (LLAMA3_70B, QWEN3_235B)

REGISTRY: dict[str, ArchConfig] = {
    cfg.name: cfg for cfg in (*ASSIGNED, *PAPER_MODELS)
}


def get_config(name: str) -> ArchConfig:
    try:
        return REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(REGISTRY))
        raise KeyError(f"unknown arch {name!r}; known: {known}") from None


__all__ = [
    "ArchConfig",
    "ShapeCell",
    "ALL_SHAPES",
    "SHAPES_BY_NAME",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "SUB_QUADRATIC_FAMILIES",
    "shape_applicable",
    "ASSIGNED",
    "PAPER_MODELS",
    "REGISTRY",
    "get_config",
]
