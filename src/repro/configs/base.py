"""Architecture config schema + input-shape cells for the dry-run matrix."""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One selectable ``--arch`` configuration (exact published dims)."""

    name: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 → d_model // n_heads
    activation: str = "swiglu"  # swiglu | geglu
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # positional encoding
    pos_type: str = "rope"  # rope | mrope | none
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, ...] = ()  # head_dim/2 split for M-RoPE

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_every: int = 1  # MoE on every k-th layer (others dense)
    moe_d_ff: int = 0  # expert hidden size (0 → d_ff)

    # SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    attn_every: int = 0  # zamba2: shared attn after every k ssm blocks
    n_shared_attn_blocks: int = 0  # zamba2: number of distinct shared blocks
    shared_lora_rank: int = 0  # zamba2: per-invocation LoRA rank
    slstm_every: int = 0  # xlstm: sLSTM at every k-th block

    # modality frontend
    frontend: str = "tokens"  # tokens | embeddings (vlm/audio stubs)
    cross_attention: bool = False  # musicgen text conditioning
    cross_mem_len: int = 256
    n_codebooks: int = 0  # musicgen multi-codebook output heads

    # serving / provenance
    max_context: int = 65_536
    source: str = ""

    def __post_init__(self) -> None:
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads % max(1, self.n_kv_heads):
            raise ValueError(
                f"{self.name}: n_heads={self.n_heads} not divisible by "
                f"n_kv_heads={self.n_kv_heads}"
            )

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def padded_vocab(self) -> int:
        """Megatron-style vocab padding to a TP-friendly multiple of 256.

        Embedding tables and logits use the padded size internally; the loss
        masks the padded tail, labels never reference it. Only granite-3-8b
        (49155) actually pads among the assigned archs.
        """
        if self.vocab % 256 == 0 or self.vocab % 16 == 0:
            return self.vocab
        return ((self.vocab + 255) // 256) * 256

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    def reduced(self) -> "ArchConfig":
        """Smoke-test config: same family/wiring, tiny dims."""
        return dataclasses.replace(
            self,
            name=f"{self.name}-reduced",
            n_layers=max(2, min(4, self.n_layers)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads)),
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            moe_d_ff=128 if self.is_moe else 0,
            vocab=512,
            n_experts=min(4, self.n_experts) if self.is_moe else 0,
            top_k=min(self.top_k, 2) if self.is_moe else 0,
            ssm_state=min(16, self.ssm_state) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            attn_every=2 if self.attn_every else 0,
            n_shared_attn_blocks=min(2, self.n_shared_attn_blocks),
            shared_lora_rank=4 if self.shared_lora_rank else 0,
            slstm_every=2 if self.slstm_every else 0,
            cross_mem_len=16 if self.cross_attention else 256,
            mrope_sections=(4, 6, 6) if self.mrope_sections else (),
            max_context=512,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One input-shape cell of the dry-run matrix."""

    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def sub_quadratic_only(self) -> bool:
        return self.seq_len >= 262_144


TRAIN_4K = ShapeCell("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeCell("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeCell("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeCell("long_500k", "decode", 524_288, 1)

ALL_SHAPES: tuple[ShapeCell, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}

#: Families with sub-quadratic sequence mixing (run long_500k).
SUB_QUADRATIC_FAMILIES = {"hybrid", "ssm"}


def shape_applicable(cfg: ArchConfig, shape: ShapeCell) -> bool:
    """Is this (arch x shape) cell live, per the assignment's skip rule?"""
    if shape.sub_quadratic_only and cfg.family not in SUB_QUADRATIC_FAMILIES:
        return False
    return True
