"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

d_ff=0 per the assignment: blocks carry their own up/down projections
(mLSTM pre-up-projection pf=2; sLSTM post-up-projection MLP pf=4/3),
matching the xLSTM paper's block designs. sLSTM every 8th block (3 of 24)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab=50_304,
    activation="gelu",
    pos_type="none",
    slstm_every=8,
    max_context=1_048_576,  # recurrent: O(1) state, unbounded context
    source="arXiv:2405.04517 (unverified)",
)
