"""gemma-2b — dense, GeGLU, MQA (kv=1), head_dim 256 [arXiv:2403.08295; hf]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab=256_000,
    activation="geglu",
    pos_type="rope",
    rope_theta=10_000.0,
    tie_embeddings=True,
    max_context=65_536,
    source="arXiv:2403.08295; hf:google/gemma-2b",
)
