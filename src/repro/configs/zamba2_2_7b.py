"""zamba2-2.7b — Mamba2 backbone + 2 shared attention blocks applied
round-robin every 6 SSM blocks, with per-invocation LoRA [arXiv:2411.15242].

Simplification recorded in DESIGN.md §5: the shared block attends over the
hidden state only (the published model concatenates the original embedding)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,  # shared attn block is full MHA
    head_dim=80,
    d_ff=10_240,
    vocab=32_000,
    activation="gelu",
    pos_type="rope",
    ssm_state=64,
    ssm_conv=4,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,  # shared attn after every 6 mamba blocks (9 applications)
    n_shared_attn_blocks=2,
    shared_lora_rank=128,
    max_context=1_048_576,  # sub-quadratic: long-context capable
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-2.7B",
)
