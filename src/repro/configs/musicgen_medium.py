"""musicgen-medium — decoder-only over EnCodec tokens with text-conditioning
cross-attention and 4 codebook heads [arXiv:2306.05284].

The EnCodec frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (codebook embeddings already summed) and the
text-conditioning memory embeddings. The delay-pattern interleaving lives in
the (stubbed) frontend."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,  # full MHA
    head_dim=64,
    d_ff=6144,
    vocab=2048,  # EnCodec codebook size
    activation="gelu",  # plain (non-gated) GELU MLP
    pos_type="rope",
    frontend="embeddings",
    cross_attention=True,
    cross_mem_len=256,  # T5 text-conditioning sequence (stub embeddings)
    n_codebooks=4,
    max_context=65_536,
    source="arXiv:2306.05284; hf:facebook/musicgen-medium",
)
