"""Sharded, atomic, versioned checkpointing with elastic restore.

Layout (one directory per step)::

    <root>/step_000010.tmp-<nonce>/   # written first
        manifest.json                  # treedef, shapes, dtypes, metadata
        arr_00000.npy ...              # one file per leaf (process shard)
    <root>/step_000010/                # atomic rename on completion

Guarantees:

* **atomicity** — readers never see a partial checkpoint (tmp dir + rename);
* **versioning** — ``latest_step`` scans completed directories only;
* **elastic restore** — leaves are stored layout-free; ``restore`` places
  them onto whatever mesh/shardings the *new* job topology wants
  (``jax.device_put`` reshards), so a 512-chip checkpoint restarts on 256;
* **async save** — a background thread does device→host transfer + IO;
  callers overlap the next step's compute with checkpoint IO and call
  ``wait()`` before exiting.

Multi-host note: each process writes only its addressable shards (file
names carry ``process_index``); this container is single-process, so shard
0 holds everything — the format and code paths are the multi-host ones.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

#: numpy can't round-trip these through .npy — store a bit-identical integer
#: view and record the logical dtype in the manifest.
_EXOTIC_DTYPES = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _flatten_with_paths(tree: Any):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)
    return leaves_with_paths


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


class Checkpointer:
    def __init__(
        self,
        root: str,
        *,
        keep: int = 3,
        async_save: bool = False,
    ) -> None:
        self.root = root
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(root, exist_ok=True)

    # -- save ------------------------------------------------------------------
    def save(self, step: int, tree: Any, metadata: Optional[dict] = None) -> str:
        """Save a pytree at `step`. Returns the final directory path."""
        self.wait()
        # device→host happens on the caller thread (device buffers may be
        # donated right after); IO can go async.
        (flat, treedef) = jax.tree_util.tree_flatten_with_path(tree)
        host_leaves = [
            (np.asarray(jax.device_get(leaf)), _path_str(path))
            for path, leaf in flat
        ]
        manifest = {
            "step": step,
            "time": time.time(),
            "process_index": jax.process_index(),
            "process_count": jax.process_count(),
            "metadata": metadata or {},
            "leaves": [
                {
                    "index": i,
                    "path": p,
                    "shape": list(a.shape),
                    "dtype": str(a.dtype),
                    "file": f"arr_{i:05d}.p{jax.process_index()}.npy",
                }
                for i, (a, p) in enumerate(host_leaves)
            ],
            "treedef": jax.tree_util.tree_structure(tree).__repr__(),
        }

        final = self._step_dir(step)

        def write() -> None:
            tmp = f"{final}.tmp-{os.getpid()}-{threading.get_ident()}"
            try:
                os.makedirs(tmp, exist_ok=True)
                for i, (arr, _) in enumerate(host_leaves):
                    stored = arr
                    view = _EXOTIC_DTYPES.get(str(arr.dtype))
                    if view is not None:
                        stored = arr.view(view)
                    np.save(
                        os.path.join(
                            tmp, f"arr_{i:05d}.p{jax.process_index()}.npy"
                        ),
                        stored,
                    )
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f, indent=1)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:  # surfaced at next wait()
                self._error = e
                raise

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
        return final

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint save failed") from err

    # -- restore ---------------------------------------------------------------
    def restore(
        self,
        like: Any,
        *,
        step: Optional[int] = None,
        shardings: Optional[Any] = None,
    ) -> tuple[Any, dict]:
        """Restore into the structure of `like` (a pytree or abstract tree).

        ``shardings`` (optional pytree of NamedSharding, same structure)
        re-shards onto the current mesh — the elastic-restart path.
        Returns (tree, metadata).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = []
        for leaf in manifest["leaves"]:
            arr = np.load(os.path.join(d, leaf["file"]))
            if str(arr.dtype) != leaf["dtype"]:
                arr = arr.view(np.dtype(getattr(ml_dtypes, leaf["dtype"])))
            arrays.append(arr)
        treedef = jax.tree_util.tree_structure(like)
        if treedef.num_leaves != len(arrays):
            raise ValueError(
                f"checkpoint has {len(arrays)} leaves, expected "
                f"{treedef.num_leaves}"
            )
        tree = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings
            )
        else:
            like_leaves = jax.tree.leaves(like)
            if like_leaves and isinstance(like_leaves[0], jax.Array):
                tree = jax.tree.map(jax.device_put, tree)
        return tree, manifest["metadata"]

    # -- bookkeeping -------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def completed_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and ".tmp" not in name:
                if os.path.exists(os.path.join(self.root, name, "manifest.json")):
                    steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.completed_steps()
        return steps[-1] if steps else None

    def _gc(self) -> None:
        steps = self.completed_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
