"""Trace CDFs and synthetic request generation (paper Appendix A)."""

from repro.traces.cdf import AZURE, LMSYS, TRACES, BucketCDF, describe, get_trace_cdf
from repro.traces.generator import (
    CATEGORY_MIX,
    RATE_PROFILES,
    TraceColumns,
    TraceSpec,
    generate_trace,
    generate_trace_columns,
    short_fraction,
)

__all__ = [
    "AZURE",
    "LMSYS",
    "TRACES",
    "BucketCDF",
    "describe",
    "get_trace_cdf",
    "CATEGORY_MIX",
    "RATE_PROFILES",
    "TraceColumns",
    "TraceSpec",
    "generate_trace",
    "generate_trace_columns",
    "short_fraction",
]
