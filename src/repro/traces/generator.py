"""Synthetic trace generation (Appendix A "Trace generation").

Requests arrive as a Poisson process at rate λ. Total-token counts come from
the bucketed CDFs in :mod:`repro.traces.cdf`; the input/output split is a
clipped normal. On top of the paper's recipe we synthesize the *routing
observables*: a traffic category and a prompt byte length
``|r| ≈ L_in · c_k`` with per-request noise, so the router's calibration
loop (which never sees token counts, only bytes and usage feedback) can be
evaluated end-to-end.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.categories import (
    BYTES_PER_TOKEN_STD,
    TRUE_BYTES_PER_TOKEN,
    Category,
)
from repro.core.router import Request
from repro.traces.cdf import BucketCDF, get_trace_cdf

#: Category mix per trace. Azure (enterprise API) is prose/code heavy;
#: LMSYS (chat arena) has a large non-English share.
CATEGORY_MIX: dict[str, dict[Category, float]] = {
    "azure": {
        Category.ENGLISH_PROSE: 0.55,
        Category.SOURCE_CODE: 0.25,
        Category.CJK_TEXT: 0.08,
        Category.MIXED_OTHER: 0.12,
    },
    "lmsys": {
        Category.ENGLISH_PROSE: 0.50,
        Category.SOURCE_CODE: 0.12,
        Category.CJK_TEXT: 0.22,
        Category.MIXED_OTHER: 0.16,
    },
}


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Everything needed to regenerate a trace deterministically."""

    trace: str = "azure"
    num_requests: int = 10_000
    rate: float = 1000.0  # req/s Poisson arrival rate
    seed: int = 42
    cap_style: str = "exact"  # max_output_tokens: exact | padded | bucket


def _sample_categories(
    rng: np.random.Generator, trace: str, n: int
) -> np.ndarray:
    mix = CATEGORY_MIX[trace]
    cats = np.array([int(k) for k in mix], dtype=np.int64)
    probs = np.array([mix[k] for k in mix])
    probs = probs / probs.sum()
    return rng.choice(cats, size=n, p=probs)


def _synth_bytes(
    rng: np.random.Generator, l_in: np.ndarray, cats: np.ndarray
) -> np.ndarray:
    """|r| = L_in · c_true, with per-request ratio noise per category."""
    c_mu = np.array([TRUE_BYTES_PER_TOKEN[Category(int(c))] for c in cats])
    c_sd = np.array([BYTES_PER_TOKEN_STD[Category(int(c))] for c in cats])
    c_req = np.maximum(0.5, rng.normal(c_mu, c_sd))
    return np.maximum(1, np.round(l_in * c_req)).astype(np.int64)


def _output_caps(
    rng: np.random.Generator, l_out: np.ndarray, style: str
) -> np.ndarray:
    """The API-level max_output_tokens cap the router sees.

    exact  — cap equals the realized output (paper's Table 2 setting);
    padded — users over-ask by 1–2× (robustness studies);
    bucket — round up to the next power of two ≥128 (UI presets).
    """
    if style == "exact":
        return l_out
    if style == "padded":
        return np.maximum(1, np.round(l_out * rng.uniform(1.0, 2.0, len(l_out)))).astype(
            np.int64
        )
    if style == "bucket":
        caps = 2 ** np.ceil(np.log2(np.maximum(l_out, 128)))
        return caps.astype(np.int64)
    raise ValueError(f"unknown cap style {style!r}")


def generate_trace(spec: TraceSpec) -> list[Request]:
    """Deterministic synthetic trace of routing-layer requests."""
    cdf: BucketCDF = get_trace_cdf(spec.trace)
    rng = np.random.default_rng(spec.seed)
    n = spec.num_requests

    gaps = rng.exponential(1.0 / spec.rate, size=n)
    arrivals = np.cumsum(gaps)
    totals = cdf.sample_totals(rng, n)
    l_in, l_out = cdf.sample_split(rng, totals)
    cats = _sample_categories(rng, spec.trace, n)
    byte_lens = _synth_bytes(rng, l_in, cats)
    caps = _output_caps(rng, l_out, spec.cap_style)

    return [
        Request(
            request_id=i,
            byte_len=int(byte_lens[i]),
            max_output_tokens=int(caps[i]),
            category=int(cats[i]),
            arrival_time=float(arrivals[i]),
            true_input_tokens=int(l_in[i]),
            true_output_tokens=int(l_out[i]),
        )
        for i in range(n)
    ]


def short_fraction(requests: Sequence[Request], b_short: int) -> float:
    """Empirical α = fraction of requests with true total ≤ B_short."""
    if not requests:
        return 0.0
    hits = sum(1 for r in requests if r.true_total <= b_short)
    return hits / len(requests)
