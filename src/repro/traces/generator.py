"""Synthetic trace generation (Appendix A "Trace generation").

Requests arrive as a Poisson process at rate λ. Total-token counts come from
the bucketed CDFs in :mod:`repro.traces.cdf`; the input/output split is a
clipped normal. On top of the paper's recipe we synthesize the *routing
observables*: a traffic category and a prompt byte length
``|r| ≈ L_in · c_k`` with per-request noise, so the router's calibration
loop (which never sees token counts, only bytes and usage feedback) can be
evaluated end-to-end.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.categories import (
    BYTES_PER_TOKEN_STD,
    TRUE_BYTES_PER_TOKEN,
    Category,
)
from repro.core.router import Request
from repro.traces.cdf import BucketCDF, get_trace_cdf

#: Category mix per trace. Azure (enterprise API) is prose/code heavy;
#: LMSYS (chat arena) has a large non-English share.
CATEGORY_MIX: dict[str, dict[Category, float]] = {
    "azure": {
        Category.ENGLISH_PROSE: 0.55,
        Category.SOURCE_CODE: 0.25,
        Category.CJK_TEXT: 0.08,
        Category.MIXED_OTHER: 0.12,
    },
    "lmsys": {
        Category.ENGLISH_PROSE: 0.50,
        Category.SOURCE_CODE: 0.12,
        Category.CJK_TEXT: 0.22,
        Category.MIXED_OTHER: 0.16,
    },
}


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Everything needed to regenerate a trace deterministically."""

    trace: str = "azure"
    num_requests: int = 10_000
    rate: float = 1000.0  # req/s Poisson arrival rate
    seed: int = 42
    cap_style: str = "exact"  # max_output_tokens: exact | padded | bucket


def _sample_categories(
    rng: np.random.Generator, trace: str, n: int
) -> np.ndarray:
    mix = CATEGORY_MIX[trace]
    cats = np.array([int(k) for k in mix], dtype=np.int64)
    probs = np.array([mix[k] for k in mix])
    probs = probs / probs.sum()
    return rng.choice(cats, size=n, p=probs)


def _synth_bytes(
    rng: np.random.Generator, l_in: np.ndarray, cats: np.ndarray
) -> np.ndarray:
    """|r| = L_in · c_true, with per-request ratio noise per category."""
    c_mu = np.array([TRUE_BYTES_PER_TOKEN[Category(int(c))] for c in cats])
    c_sd = np.array([BYTES_PER_TOKEN_STD[Category(int(c))] for c in cats])
    c_req = np.maximum(0.5, rng.normal(c_mu, c_sd))
    return np.maximum(1, np.round(l_in * c_req)).astype(np.int64)


def _output_caps(
    rng: np.random.Generator, l_out: np.ndarray, style: str
) -> np.ndarray:
    """The API-level max_output_tokens cap the router sees.

    exact  — cap equals the realized output (paper's Table 2 setting);
    padded — users over-ask by 1–2× (robustness studies);
    bucket — round up to the next power of two ≥128 (UI presets).
    """
    if style == "exact":
        return l_out
    if style == "padded":
        return np.maximum(1, np.round(l_out * rng.uniform(1.0, 2.0, len(l_out)))).astype(
            np.int64
        )
    if style == "bucket":
        caps = 2 ** np.ceil(np.log2(np.maximum(l_out, 128)))
        return caps.astype(np.int64)
    raise ValueError(f"unknown cap style {style!r}")


@dataclasses.dataclass
class TraceColumns:
    """Struct-of-arrays trace: one NumPy array per :class:`Request` field.

    The native product of :func:`generate_trace_columns` and the native
    input of the vectorized fleet backend — a million-request trace is
    seven arrays, not a million Python objects. ``to_requests()`` /
    ``from_requests()`` adapt to the reference backend's object form.
    """

    request_id: np.ndarray  # (N,) int64
    byte_len: np.ndarray  # (N,) int64
    max_output_tokens: np.ndarray  # (N,) int64
    category: np.ndarray  # (N,) int64
    arrival_time: np.ndarray  # (N,) float64
    true_input_tokens: np.ndarray  # (N,) int64
    true_output_tokens: np.ndarray  # (N,) int64

    def __len__(self) -> int:
        return len(self.request_id)

    @property
    def true_total(self) -> np.ndarray:
        return self.true_input_tokens + self.true_output_tokens

    def head(self, n: int) -> "TraceColumns":
        """First ``n`` requests (views, no copy)."""
        return TraceColumns(
            **{
                f.name: getattr(self, f.name)[:n]
                for f in dataclasses.fields(self)
            }
        )

    def sorted_by_arrival(self) -> "TraceColumns":
        """Arrival-ordered view (no copy when already sorted, the normal
        case for generator output — arrivals are a cumulative sum)."""
        arr = self.arrival_time
        if len(arr) < 2 or bool((arr[1:] >= arr[:-1]).all()):
            return self
        order = np.argsort(arr, kind="stable")
        return TraceColumns(
            **{
                f.name: getattr(self, f.name)[order]
                for f in dataclasses.fields(self)
            }
        )

    def to_requests(self) -> list[Request]:
        """Materialize :class:`Request` objects (reference backend)."""
        return [
            Request(
                request_id=int(self.request_id[i]),
                byte_len=int(self.byte_len[i]),
                max_output_tokens=int(self.max_output_tokens[i]),
                category=int(self.category[i]),
                arrival_time=float(self.arrival_time[i]),
                true_input_tokens=int(self.true_input_tokens[i]),
                true_output_tokens=int(self.true_output_tokens[i]),
            )
            for i in range(len(self))
        ]

    @classmethod
    def from_requests(cls, requests: Sequence[Request]) -> "TraceColumns":
        """Columnarize an object-form trace (adapter, not the hot path)."""
        return cls(
            request_id=np.fromiter(
                (r.request_id for r in requests), np.int64, len(requests)
            ),
            byte_len=np.fromiter(
                (r.byte_len for r in requests), np.int64, len(requests)
            ),
            max_output_tokens=np.fromiter(
                (r.max_output_tokens for r in requests), np.int64, len(requests)
            ),
            category=np.fromiter(
                (r.category for r in requests), np.int64, len(requests)
            ),
            arrival_time=np.fromiter(
                (r.arrival_time for r in requests), np.float64, len(requests)
            ),
            true_input_tokens=np.fromiter(
                (r.true_input_tokens for r in requests), np.int64, len(requests)
            ),
            true_output_tokens=np.fromiter(
                (r.true_output_tokens for r in requests), np.int64, len(requests)
            ),
        )


def generate_trace_columns(spec: TraceSpec) -> TraceColumns:
    """Deterministic synthetic trace, columnar form (no Request objects).

    Draws from the RNG in exactly the order :func:`generate_trace` always
    has (arrival gaps, totals, split, categories, bytes, caps), so the two
    paths are bit-identical for the same spec.
    """
    cdf: BucketCDF = get_trace_cdf(spec.trace)
    rng = np.random.default_rng(spec.seed)
    n = spec.num_requests

    gaps = rng.exponential(1.0 / spec.rate, size=n)
    arrivals = np.cumsum(gaps)
    totals = cdf.sample_totals(rng, n)
    l_in, l_out = cdf.sample_split(rng, totals)
    cats = _sample_categories(rng, spec.trace, n)
    byte_lens = _synth_bytes(rng, l_in, cats)
    caps = _output_caps(rng, l_out, spec.cap_style)

    return TraceColumns(
        request_id=np.arange(n, dtype=np.int64),
        byte_len=byte_lens.astype(np.int64),
        max_output_tokens=caps.astype(np.int64),
        category=cats.astype(np.int64),
        arrival_time=arrivals.astype(np.float64),
        true_input_tokens=l_in.astype(np.int64),
        true_output_tokens=l_out.astype(np.int64),
    )


def generate_trace(spec: TraceSpec) -> list[Request]:
    """Deterministic synthetic trace of routing-layer requests (object form;
    :func:`generate_trace_columns` is the columnar native path)."""
    return generate_trace_columns(spec).to_requests()


def short_fraction(requests, b_short: int) -> float:
    """Empirical α = fraction of requests with true total ≤ B_short.

    Accepts either a Request sequence or a :class:`TraceColumns`.
    """
    if isinstance(requests, TraceColumns):
        if not len(requests):
            return 0.0
        return float((requests.true_total <= b_short).mean())
    if not requests:
        return 0.0
    hits = sum(1 for r in requests if r.true_total <= b_short)
    return hits / len(requests)
