"""Synthetic trace generation (Appendix A "Trace generation").

Requests arrive as a Poisson process at rate λ. Total-token counts come from
the bucketed CDFs in :mod:`repro.traces.cdf`; the input/output split is a
clipped normal. On top of the paper's recipe we synthesize the *routing
observables*: a traffic category and a prompt byte length
``|r| ≈ L_in · c_k`` with per-request noise, so the router's calibration
loop (which never sees token counts, only bytes and usage feedback) can be
evaluated end-to-end.

Nonstationary scenarios
-----------------------
Real fleets are not stationary Poisson (FleetOpt / inference-fleet-sim both
validate provisioning under bursts, diurnal cycles, and content drift), so
:class:`TraceSpec` carries three orthogonal scenario axes, all defaulting to
the paper's stationary recipe:

* **arrival-rate modulation** (``rate_profile``) — the trace becomes an
  inhomogeneous Poisson process with intensity ``λ·m(t)`` via the
  time-rescaling theorem: the stationary draw supplies unit-rate arrival
  times, which are mapped through the inverse cumulative intensity
  ``Λ⁻¹``. Profiles: ``"burst"`` (a ``rate_period``-second window at
  ``(1+A)·λ`` starting 40% into the nominal trace), ``"diurnal"``
  (sinusoidal ``1 + A·sin(2πt/period)``), and ``"step"`` (a permanent
  shift to ``(1+A)·λ`` at ``t = rate_period``).
* **category-mix drift** (``mix_drift``) — the per-request category
  distribution interpolates from the source trace's mix toward
  ``drift_trace``'s mix over the trace (0 = none, 1 = fully drifted by the
  final request).
* **bytes-per-token drift** (``bytes_drift``) — the true per-request
  bytes/token ratio scales by ``1 + bytes_drift·(i/n)``, modelling content
  drift *within* categories (the calibrator's EMA must chase it).

All three are implemented once, in :func:`generate_trace_columns`;
:func:`generate_trace` materializes the identical columns, so the two
entry points stay bit-identical for every scenario.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.categories import (
    BYTES_PER_TOKEN_STD,
    TRUE_BYTES_PER_TOKEN,
    Category,
)
from repro.core.router import Request
from repro.traces.cdf import BucketCDF, get_trace_cdf

#: Category mix per trace. Azure (enterprise API) is prose/code heavy;
#: LMSYS (chat arena) has a large non-English share.
CATEGORY_MIX: dict[str, dict[Category, float]] = {
    "azure": {
        Category.ENGLISH_PROSE: 0.55,
        Category.SOURCE_CODE: 0.25,
        Category.CJK_TEXT: 0.08,
        Category.MIXED_OTHER: 0.12,
    },
    "lmsys": {
        Category.ENGLISH_PROSE: 0.50,
        Category.SOURCE_CODE: 0.12,
        Category.CJK_TEXT: 0.22,
        Category.MIXED_OTHER: 0.16,
    },
}


#: Valid arrival-rate modulation profiles.
RATE_PROFILES = ("stationary", "burst", "diurnal", "step")

#: Burst window start, as a fraction of the nominal trace duration n/λ.
_BURST_START_FRAC = 0.4

#: Intensity floor for the diurnal trough (keeps Λ strictly increasing).
_RATE_FLOOR = 0.05


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Everything needed to regenerate a trace deterministically.

    The scenario fields (``rate_profile`` onward) default to the paper's
    strictly-stationary recipe; see the module docstring for the burst /
    diurnal / step arrival profiles and the two content-drift axes.
    """

    trace: str = "azure"
    num_requests: int = 10_000
    rate: float = 1000.0  # req/s Poisson arrival rate
    seed: int = 42
    cap_style: str = "exact"  # max_output_tokens: exact | padded | bucket
    # -- nonstationary scenario axes (defaults = stationary) ----------------
    rate_profile: str = "stationary"  # stationary | burst | diurnal | step
    rate_amplitude: float = 0.0  # A: modulation depth, ×rate
    rate_period: float = 60.0  # s: burst length / sine period / step time
    mix_drift: float = 0.0  # 0..1: category-mix drift toward drift_trace
    drift_trace: str = "lmsys"  # mix drifted toward over the trace
    bytes_drift: float = 0.0  # fractional bytes/token drift over the trace

    def validate(self) -> None:
        if self.rate_profile not in RATE_PROFILES:
            raise ValueError(
                f"unknown rate_profile {self.rate_profile!r}; "
                f"expected one of {RATE_PROFILES}"
            )
        if self.rate_profile == "diurnal":
            if not abs(self.rate_amplitude) < 1.0:
                raise ValueError(
                    f"diurnal amplitude must satisfy |A| < 1: {self.rate_amplitude}"
                )
        elif self.rate_profile != "stationary":
            if self.rate_amplitude <= -1.0:
                raise ValueError(
                    f"{self.rate_profile} amplitude must exceed -1: "
                    f"{self.rate_amplitude}"
                )
        if self.rate_profile != "stationary" and self.rate_period <= 0:
            raise ValueError(f"rate_period must be positive: {self.rate_period}")
        if not 0.0 <= self.mix_drift <= 1.0:
            raise ValueError(f"mix_drift must be in [0, 1]: {self.mix_drift}")
        if self.mix_drift > 0.0 and self.drift_trace not in CATEGORY_MIX:
            raise ValueError(f"unknown drift_trace {self.drift_trace!r}")
        if self.bytes_drift <= -1.0:
            raise ValueError(f"bytes_drift must exceed -1: {self.bytes_drift}")


def _warp_arrivals(spec: TraceSpec, stationary: np.ndarray) -> np.ndarray:
    """Inhomogeneous-Poisson arrivals by time rescaling.

    ``stationary`` are the constant-rate arrival times; ``v = stationary``
    is exactly the cumulative unit-rate operational time divided by λ, so
    the warped arrivals are ``t_i = Λ⁻¹(λ·v_i)`` with
    ``Λ(t) = λ·∫₀ᵗ m``. Burst and step invert Λ in closed form; diurnal
    interpolates the analytic Λ on a dense grid.
    """
    a = spec.rate_amplitude
    if spec.rate_profile == "stationary" or a == 0.0:
        return stationary
    v = stationary  # Λ(t_i)/λ in operational time
    if spec.rate_profile == "step":
        # m(t) = 1 + A for t ≥ t_s: Λ/λ = t + A·max(0, t−t_s)
        t_s = spec.rate_period
        return np.where(v <= t_s, v, t_s + (v - t_s) / (1.0 + a))
    if spec.rate_profile == "burst":
        # m(t) = 1 + A inside [t_b, t_b+L): Λ/λ = t + A·clip(t−t_b, 0, L)
        t_b = _BURST_START_FRAC * spec.num_requests / spec.rate
        length = spec.rate_period
        hi = t_b + (1.0 + a) * length  # Λ/λ at the burst's end
        return np.where(
            v <= t_b,
            v,
            np.where(v <= hi, t_b + (v - t_b) / (1.0 + a), v - a * length),
        )
    # diurnal: m(t) = max(1 + A·sin(2πt/T), floor); invert the analytic Λ
    # numerically (the floor only binds for |A| → 1).
    omega = 2.0 * np.pi / spec.rate_period
    m_min = max(1.0 - abs(a), _RATE_FLOOR)
    t_max = float(v[-1]) / m_min + spec.rate_period
    cells_per_period = 1024
    grid_n = int(
        min(2_000_000, max(4096, np.ceil(t_max / spec.rate_period) * cells_per_period))
    )
    ts = np.linspace(0.0, t_max, grid_n)
    lam_over_rate = ts + (a / omega) * (1.0 - np.cos(omega * ts))
    # Guard the floor case: enforce monotonicity before inverting.
    lam_over_rate = np.maximum.accumulate(lam_over_rate)
    return np.interp(v, lam_over_rate, ts)


def _mix_probs(trace: str, cats: np.ndarray) -> np.ndarray:
    """Category probabilities aligned to the ``cats`` id order."""
    mix = CATEGORY_MIX[trace]
    p = np.array([mix.get(Category(int(c)), 0.0) for c in cats], dtype=np.float64)
    return p / p.sum()


def _sample_categories(
    rng: np.random.Generator,
    trace: str,
    n: int,
    *,
    mix_drift: float = 0.0,
    drift_trace: str = "lmsys",
) -> np.ndarray:
    cats = np.array([int(k) for k in CATEGORY_MIX[trace]], dtype=np.int64)
    probs = _mix_probs(trace, cats)
    if mix_drift == 0.0:
        return rng.choice(cats, size=n, p=probs)
    # Per-request mix p_i = (1−w_i)·p_src + w_i·p_dst with w ramping from 0
    # to mix_drift across the trace: inverse-CDF sampling row-wise.
    dst = _mix_probs(drift_trace, cats)
    w = mix_drift * np.arange(n, dtype=np.float64) / max(1, n - 1)
    p_t = (1.0 - w[:, None]) * probs[None, :] + w[:, None] * dst[None, :]
    cum = np.cumsum(p_t, axis=1)
    u = rng.random(n)
    idx = np.minimum((u[:, None] > cum).sum(axis=1), len(cats) - 1)
    return cats[idx]


def _synth_bytes(
    rng: np.random.Generator,
    l_in: np.ndarray,
    cats: np.ndarray,
    *,
    bytes_drift: float = 0.0,
) -> np.ndarray:
    """|r| = L_in · c_true, with per-request ratio noise per category and an
    optional content-drift ramp of the true ratio across the trace."""
    c_mu = np.array([TRUE_BYTES_PER_TOKEN[Category(int(c))] for c in cats])
    c_sd = np.array([BYTES_PER_TOKEN_STD[Category(int(c))] for c in cats])
    c_req = np.maximum(0.5, rng.normal(c_mu, c_sd))
    if bytes_drift != 0.0:
        n = len(l_in)
        ramp = 1.0 + bytes_drift * np.arange(n, dtype=np.float64) / max(1, n - 1)
        c_req = np.maximum(0.5, c_req * ramp)
    return np.maximum(1, np.round(l_in * c_req)).astype(np.int64)


def _output_caps(
    rng: np.random.Generator, l_out: np.ndarray, style: str
) -> np.ndarray:
    """The API-level max_output_tokens cap the router sees.

    exact  — cap equals the realized output (paper's Table 2 setting);
    padded — users over-ask by 1–2× (robustness studies);
    bucket — round up to the next power of two ≥128 (UI presets).
    """
    if style == "exact":
        return l_out
    if style == "padded":
        return np.maximum(1, np.round(l_out * rng.uniform(1.0, 2.0, len(l_out)))).astype(
            np.int64
        )
    if style == "bucket":
        caps = 2 ** np.ceil(np.log2(np.maximum(l_out, 128)))
        return caps.astype(np.int64)
    raise ValueError(f"unknown cap style {style!r}")


@dataclasses.dataclass
class TraceColumns:
    """Struct-of-arrays trace: one NumPy array per :class:`Request` field.

    The native product of :func:`generate_trace_columns` and the native
    input of the vectorized fleet backend — a million-request trace is
    seven arrays, not a million Python objects. ``to_requests()`` /
    ``from_requests()`` adapt to the reference backend's object form.
    """

    request_id: np.ndarray  # (N,) int64
    byte_len: np.ndarray  # (N,) int64
    max_output_tokens: np.ndarray  # (N,) int64
    category: np.ndarray  # (N,) int64
    arrival_time: np.ndarray  # (N,) float64
    true_input_tokens: np.ndarray  # (N,) int64
    true_output_tokens: np.ndarray  # (N,) int64

    def __len__(self) -> int:
        return len(self.request_id)

    @property
    def true_total(self) -> np.ndarray:
        return self.true_input_tokens + self.true_output_tokens

    def head(self, n: int) -> "TraceColumns":
        """First ``n`` requests (views, no copy)."""
        return TraceColumns(
            **{
                f.name: getattr(self, f.name)[:n]
                for f in dataclasses.fields(self)
            }
        )

    def sorted_by_arrival(self) -> "TraceColumns":
        """Arrival-ordered view (no copy when already sorted, the normal
        case for generator output — arrivals are a cumulative sum)."""
        arr = self.arrival_time
        if len(arr) < 2 or bool((arr[1:] >= arr[:-1]).all()):
            return self
        order = np.argsort(arr, kind="stable")
        return TraceColumns(
            **{
                f.name: getattr(self, f.name)[order]
                for f in dataclasses.fields(self)
            }
        )

    def to_requests(self) -> list[Request]:
        """Materialize :class:`Request` objects (reference backend)."""
        return [
            Request(
                request_id=int(self.request_id[i]),
                byte_len=int(self.byte_len[i]),
                max_output_tokens=int(self.max_output_tokens[i]),
                category=int(self.category[i]),
                arrival_time=float(self.arrival_time[i]),
                true_input_tokens=int(self.true_input_tokens[i]),
                true_output_tokens=int(self.true_output_tokens[i]),
            )
            for i in range(len(self))
        ]

    @classmethod
    def from_requests(cls, requests: Sequence[Request]) -> "TraceColumns":
        """Columnarize an object-form trace (adapter, not the hot path)."""
        return cls(
            request_id=np.fromiter(
                (r.request_id for r in requests), np.int64, len(requests)
            ),
            byte_len=np.fromiter(
                (r.byte_len for r in requests), np.int64, len(requests)
            ),
            max_output_tokens=np.fromiter(
                (r.max_output_tokens for r in requests), np.int64, len(requests)
            ),
            category=np.fromiter(
                (r.category for r in requests), np.int64, len(requests)
            ),
            arrival_time=np.fromiter(
                (r.arrival_time for r in requests), np.float64, len(requests)
            ),
            true_input_tokens=np.fromiter(
                (r.true_input_tokens for r in requests), np.int64, len(requests)
            ),
            true_output_tokens=np.fromiter(
                (r.true_output_tokens for r in requests), np.int64, len(requests)
            ),
        )


def generate_trace_columns(spec: TraceSpec) -> TraceColumns:
    """Deterministic synthetic trace, columnar form (no Request objects).

    Draws from the RNG in exactly the order :func:`generate_trace` always
    has (arrival gaps, totals, split, categories, bytes, caps), so the two
    paths are bit-identical for the same spec.
    """
    spec.validate()
    cdf: BucketCDF = get_trace_cdf(spec.trace)
    rng = np.random.default_rng(spec.seed)
    n = spec.num_requests

    gaps = rng.exponential(1.0 / spec.rate, size=n)
    arrivals = _warp_arrivals(spec, np.cumsum(gaps))
    totals = cdf.sample_totals(rng, n)
    l_in, l_out = cdf.sample_split(rng, totals)
    cats = _sample_categories(
        rng, spec.trace, n,
        mix_drift=spec.mix_drift, drift_trace=spec.drift_trace,
    )
    byte_lens = _synth_bytes(rng, l_in, cats, bytes_drift=spec.bytes_drift)
    caps = _output_caps(rng, l_out, spec.cap_style)

    return TraceColumns(
        request_id=np.arange(n, dtype=np.int64),
        byte_len=byte_lens.astype(np.int64),
        max_output_tokens=caps.astype(np.int64),
        category=cats.astype(np.int64),
        arrival_time=arrivals.astype(np.float64),
        true_input_tokens=l_in.astype(np.int64),
        true_output_tokens=l_out.astype(np.int64),
    )


def generate_trace(spec: TraceSpec) -> list[Request]:
    """Deterministic synthetic trace of routing-layer requests (object form;
    :func:`generate_trace_columns` is the columnar native path)."""
    return generate_trace_columns(spec).to_requests()


def short_fraction(requests, b_short: int) -> float:
    """Empirical α = fraction of requests with true total ≤ B_short.

    Accepts either a Request sequence or a :class:`TraceColumns`.
    """
    if isinstance(requests, TraceColumns):
        if not len(requests):
            return 0.0
        return float((requests.true_total <= b_short).mean())
    if not requests:
        return 0.0
    hits = sum(1 for r in requests if r.true_total <= b_short)
    return hits / len(requests)
