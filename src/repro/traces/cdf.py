"""Empirical total-token CDFs for the two evaluation traces (Appendix A).

The paper derives simplified bucketed CDFs from published summary statistics
(it does not ship raw logs):

* **Azure-Derived** [11]: 80% of requests below 2K total tokens, 92% below
  8K, long tail to 64K; output fraction ~N(0.10, 0.05).
* **LMSYS-Derived** [12]: mean L_in = 69.5, mean L_out = 214.5 (mean total
  ~284); output fraction ~N(0.75, 0.10); virtually nothing above 8K.

Sampling is inverse-CDF with *uniform interpolation inside each bucket*,
which (as the paper's Limitations section notes) produces slightly heavier
tails than the true distributions — we reproduce that artefact on purpose,
since the paper's Table 1/2 numbers depend on it.

Bucket masses below were tuned so the analytically-derived quantities match
the paper's reported values (Table 1):
  Azure:  E[iters]≈290 → μ_homo≈3.0; E[iters | ≤8K]≈104 → μ_short≈13.5;
          E[iters | >8K] → μ_long≈0.37; F(2048)=0.80; F(8192)≈0.92.
  LMSYS:  E[total]≈284 → μ_homo≈4.1, μ_short≈6.8; F(8192)=0.9993 (the tiny
          tail that makes Table 2's 8 long-pool instances).
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class BucketCDF:
    """Piecewise-uniform CDF over total token counts."""

    name: str
    edges: tuple[int, ...]  # bucket upper edges, ascending
    cum: tuple[float, ...]  # cumulative probability at each edge
    # Output-fraction split L_out/L_total ~ N(mu, sigma) clipped (Appendix A)
    out_frac_mu: float = 0.10
    out_frac_sigma: float = 0.05
    out_frac_clip: tuple[float, float] = (0.02, 0.95)

    def __post_init__(self) -> None:
        if len(self.edges) != len(self.cum):
            raise ValueError("edges and cum must align")
        if any(b <= a for a, b in zip(self.edges, self.edges[1:])):
            raise ValueError("edges must be strictly ascending")
        if any(b < a for a, b in zip(self.cum, self.cum[1:])):
            raise ValueError("cum must be non-decreasing")
        if abs(self.cum[-1] - 1.0) > 1e-9:
            raise ValueError("cum must end at 1.0")

    # -- CDF / inverse-CDF ---------------------------------------------------
    def cdf(self, x: float) -> float:
        """F(x) with uniform interpolation inside buckets."""
        if x <= 0:
            return 0.0
        lo_edge, lo_cum = 0, 0.0
        for edge, c in zip(self.edges, self.cum):
            if x <= edge:
                frac = (x - lo_edge) / (edge - lo_edge)
                return lo_cum + frac * (c - lo_cum)
            lo_edge, lo_cum = edge, c
        return 1.0

    def inverse(self, u: float) -> float:
        """F^{-1}(u) with uniform interpolation (Appendix A sampling)."""
        u = min(max(u, 0.0), 1.0)
        idx = bisect.bisect_left(self.cum, u)
        idx = min(idx, len(self.cum) - 1)
        lo_edge = 0 if idx == 0 else self.edges[idx - 1]
        lo_cum = 0.0 if idx == 0 else self.cum[idx - 1]
        hi_edge, hi_cum = self.edges[idx], self.cum[idx]
        if hi_cum <= lo_cum:
            return float(hi_edge)
        frac = (u - lo_cum) / (hi_cum - lo_cum)
        return lo_edge + frac * (hi_edge - lo_edge)

    def sample_totals(self, rng: np.random.Generator, n: int) -> np.ndarray:
        u = rng.uniform(size=n)
        totals = np.array([self.inverse(v) for v in u])
        return np.maximum(2, np.round(totals)).astype(np.int64)

    def sample_split(
        self, rng: np.random.Generator, totals: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        """Split totals into (L_in, L_out) via the clipped-normal fraction."""
        frac = rng.normal(self.out_frac_mu, self.out_frac_sigma, size=len(totals))
        frac = np.clip(frac, *self.out_frac_clip)
        l_out = np.maximum(1, np.round(totals * frac)).astype(np.int64)
        l_in = np.maximum(1, totals - l_out)
        return l_in, l_out

    # -- analytics (used by the profiler and Fig. 6) --------------------------
    def mean_total(self) -> float:
        m, lo_edge, lo_cum = 0.0, 0, 0.0
        for edge, c in zip(self.edges, self.cum):
            m += (c - lo_cum) * (lo_edge + edge) / 2.0
            lo_edge, lo_cum = edge, c
        return m

    def mean_total_conditional(self, lo: float, hi: float) -> float:
        """E[T | lo < T <= hi] under the piecewise-uniform density."""
        mass, acc = 0.0, 0.0
        prev_edge, prev_cum = 0, 0.0
        for edge, c in zip(self.edges, self.cum):
            a, b = max(prev_edge, lo), min(edge, hi)
            if b > a and edge > prev_edge:
                dens = (c - prev_cum) / (edge - prev_edge)
                mass += dens * (b - a)
                acc += dens * (b - a) * (a + b) / 2.0
            prev_edge, prev_cum = edge, c
        if mass <= 0:
            return 0.0
        return acc / mass

    def tail_mass(self, threshold: float) -> float:
        return 1.0 - self.cdf(threshold)

    @property
    def max_total(self) -> int:
        return self.edges[-1]


AZURE = BucketCDF(
    name="azure",
    edges=(64, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768, 65536),
    cum=(0.06, 0.2815, 0.4815, 0.6815, 0.8015, 0.8815, 0.917, 0.960, 0.987, 1.0),
    out_frac_mu=0.10,
    out_frac_sigma=0.05,
)

LMSYS = BucketCDF(
    name="lmsys",
    edges=(32, 64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384),
    cum=(0.10, 0.30, 0.586, 0.786, 0.885, 0.952, 0.9860, 0.9970, 0.99935, 1.0),
    out_frac_mu=0.75,
    out_frac_sigma=0.10,
)

TRACES: dict[str, BucketCDF] = {"azure": AZURE, "lmsys": LMSYS}


def get_trace_cdf(name: str) -> BucketCDF:
    try:
        return TRACES[name]
    except KeyError:
        raise KeyError(f"unknown trace {name!r}; have {sorted(TRACES)}") from None


def describe(cdf: BucketCDF, thresholds: Sequence[int] = (2048, 8192)) -> dict:
    out = {
        "name": cdf.name,
        "mean_total": cdf.mean_total(),
        "max_total": cdf.max_total,
    }
    for t in thresholds:
        out[f"F({t})"] = cdf.cdf(t)
    return out
