import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# The 512 placeholder host devices exist ONLY for this dry-run process.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver builds the production mesh (16×16 single-pod /
2×16×16 multi-pod), derives the sharding policy, lowers the appropriate
step function over ShapeDtypeStruct stand-ins (zero allocation), compiles
it, and records:

* ``memory_analysis()``  — per-device bytes (proves the config fits),
* ``cost_analysis()``    — HLO FLOPs / bytes for §Roofline,
* optimized-HLO collective stats — wire bytes for the collective term.

Results are printed and saved as JSON under results/dryrun/ for the
roofline benchmark and EXPERIMENTS.md.

Usage:
    python -m repro.launch.dryrun --arch yi-6b --shape decode_32k
    python -m repro.launch.dryrun --arch yi-6b --shape train_4k --multi-pod
    python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (
    ASSIGNED,
    SHAPES_BY_NAME,
    get_config,
    shape_applicable,
)
from repro.distributed.sharding import tree_shardings
from repro.launch.mesh import make_production_mesh
from repro.launch.policy import build_policy
from repro.launch.analytic_cost import cell_cost
from repro.launch.hlo_parse import parse_collectives
from repro.launch.roofline import Roofline, model_flops_estimate
from repro.models.model_zoo import Model
from repro.training.train_loop import TrainConfig, make_train_step, opt_state_axes

RESULTS_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(__file__)))),
    "results",
    "dryrun",
)

#: Use factored-second-moment optimizer above this size (AdamW state would
#: not fit the assigned mesh — see EXPERIMENTS.md §Dry-run).
ADAFACTOR_THRESHOLD = 4e10


def _batch_shardings(axes: dict, mesh, rules):
    from jax.sharding import NamedSharding

    return {
        k: NamedSharding(mesh, rules.spec(ax, mesh)) for k, ax in axes.items()
    }


def run_cell(
    arch: str,
    shape: str,
    *,
    multi_pod: bool = False,
    causal_mode: str = "masked",
    remat: str = "full",
    kv_dtype: str = "bf16",
    pure_dp: bool = False,
    donate: bool = True,
    extra_tag: str = "",
) -> dict:
    """Lower+compile one cell; returns the result record (also JSON-saved)."""
    cfg = get_config(arch)
    cell = SHAPES_BY_NAME[shape]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    record: dict = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "status": "error",
    }
    if not shape_applicable(cfg, cell):
        record["status"] = "skipped"
        record["reason"] = (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.family} family is full-attention (DESIGN.md §4)"
        )
        _save(record, extra_tag)
        return record

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    policy = build_policy(cfg, cell, mesh)
    if pure_dp:
        from repro.launch.policy import pure_dp_policy

        policy = pure_dp_policy(cfg, cell, mesh)
    model = Model(
        cfg, remat=remat, causal_mode=causal_mode, kv_dtype=kv_dtype
    )
    record["variant"] = {
        "causal_mode": causal_mode, "remat": remat, "kv_dtype": kv_dtype,
        "pure_dp": pure_dp,
    }

    specs, b_axes = model.input_specs(cell)
    rules = policy.rules
    b_sh = _batch_shardings(b_axes, mesh, rules)
    p_abs = model.abstract()
    p_sh = tree_shardings(model.axes(), mesh, rules)

    with mesh:
        if cell.kind == "train":
            opt_name = (
                "adafactor"
                if model.param_count() > ADAFACTOR_THRESHOLD
                else "adamw"
            )
            tcfg = TrainConfig(optimizer=opt_name)
            train_step, opt = make_train_step(model, tcfg)
            o_abs = jax.eval_shape(opt.init, p_abs)
            o_sh = tree_shardings(opt_state_axes(model, tcfg), mesh, rules)
            step_spec = jax.ShapeDtypeStruct((), jnp.int32)
            fn = jax.jit(
                train_step,
                in_shardings=(p_sh, o_sh, b_sh, None),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = fn.lower(p_abs, o_abs, specs, step_spec)
            record["optimizer"] = opt_name
            tokens = cell.global_batch * cell.seq_len
            record["model_flops"] = model_flops_estimate(
                model.active_param_count(), tokens, train=True
            )
        elif cell.kind == "prefill":
            fn = jax.jit(model.prefill, in_shardings=(p_sh, b_sh))
            lowered = fn.lower(p_abs, specs)
            tokens = cell.global_batch * cell.seq_len
            record["model_flops"] = model_flops_estimate(
                model.active_param_count(), tokens, train=False
            )
        else:  # decode
            c_abs = model.cache_specs(cell)
            c_ax = model.cache_axes(
                cell, kv_shardable=policy.kv_heads_sharded
            )
            c_sh = tree_shardings(c_ax, mesh, rules)
            fn = jax.jit(
                model.decode_step,
                in_shardings=(p_sh, c_sh, b_sh),
                donate_argnums=(1,) if donate else (),
            )
            lowered = fn.lower(p_abs, c_abs, specs)
            record["model_flops"] = model_flops_estimate(
                model.active_param_count(), cell.global_batch, train=False
            )

        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

    try:
        mem = compiled.memory_analysis()
        record["memory_analysis"] = _mem_dict(mem)
    except Exception as e:  # CPU backend may not support it
        record["memory_analysis"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
    except Exception:
        cost = {}
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)

    # Whole-program FLOPs/bytes: analytic reconstruction (XLA's aggregate
    # cost_analysis counts while bodies once — see analytic_cost docstring).
    acost = cell_cost(
        cfg,
        cell,
        model.param_count(),
        causal_mode=causal_mode,
        moe_cf=1.25 if cell.kind == "train" else 2.0,
        optimizer=record.get("optimizer", "adamw"),
        remat=remat,
        kv_dtype=kv_dtype,
    )
    roof = Roofline(
        flops_total=acost.flops_total,
        bytes_total=acost.hbm_bytes,
        collective_bytes_per_chip=colls.wire_bytes_per_chip,
        chips=chips,
    )

    record.update(
        status="ok",
        chips=chips,
        params=model.param_count(),
        policy=policy.describe(),
        lower_s=round(t_lower - t0, 2),
        compile_s=round(t_compile - t_lower, 2),
        xla_cost_analysis_body_once={
            "flops_per_device": float(cost.get("flops", 0.0)),
            "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        },
        analytic_cost=acost.as_dict(),
        collectives={
            "counts": colls.counts,
            "executed": colls.executed,
            "wire_bytes_per_chip": colls.wire_bytes_per_chip,
            "by_op": colls.by_op,
        },
        roofline=roof.as_dict(),
        useful_flops_fraction=roof.model_flops_fraction(
            record.get("model_flops", 0.0)
        ),
    )
    _save(record, extra_tag)
    return record


def _mem_dict(mem) -> dict:
    out = {}
    for key in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        if hasattr(mem, key):
            out[key] = int(getattr(mem, key))
    if out:
        out["total_per_device"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
    return out


def _save(record: dict, extra_tag: str = "") -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    tag = f"_{extra_tag}" if extra_tag else ""
    path = os.path.join(
        RESULTS_DIR,
        f"{record['arch']}__{record['shape']}__{record['mesh']}{tag}.json",
    )
    with open(path, "w") as f:
        json.dump(record, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="all assigned cells")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--causal-mode", default="masked",
                    choices=["masked", "triangle"])
    ap.add_argument("--remat", default="full", choices=["full", "dots", "none"])
    ap.add_argument("--kv-dtype", default="bf16", choices=["bf16", "int8"])
    ap.add_argument("--pure-dp", action="store_true",
                    help="fold the model axis into data parallelism")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        for cfg in ASSIGNED:
            for shape in SHAPES_BY_NAME:
                cells.append((cfg.name, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        cells.append((args.arch, args.shape))

    failures = 0
    for arch, shape in cells:
        mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
        out = os.path.join(
            RESULTS_DIR, f"{arch}__{shape}__{mesh_name}"
            + (f"_{args.tag}" if args.tag else "") + ".json"
        )
        if args.skip_existing and os.path.exists(out):
            with open(out) as f:
                prev = json.load(f)
            if prev.get("status") in ("ok", "skipped"):
                print(f"[skip] {arch} × {shape} × {mesh_name} (cached)")
                continue
        try:
            rec = run_cell(
                arch,
                shape,
                multi_pod=args.multi_pod,
                causal_mode=args.causal_mode,
                remat=args.remat,
                kv_dtype=args.kv_dtype,
                pure_dp=args.pure_dp,
                extra_tag=args.tag,
            )
            if rec["status"] == "ok":
                r = rec["roofline"]
                print(
                    f"[ok]   {arch} × {shape} × {mesh_name}: "
                    f"compile {rec['compile_s']}s  "
                    f"compute {r['compute_s']*1e3:.1f}ms  "
                    f"memory {r['memory_s']*1e3:.1f}ms  "
                    f"collective {r['collective_s']*1e3:.1f}ms  "
                    f"dominant={r['dominant']}"
                )
            else:
                print(f"[{rec['status']}] {arch} × {shape} × {mesh_name}")
        except Exception as e:
            failures += 1
            print(f"[FAIL] {arch} × {shape} × {mesh_name}: {type(e).__name__}: {e}")
            traceback.print_exc()
            _save(
                {
                    "arch": arch,
                    "shape": shape,
                    "mesh": mesh_name,
                    "status": "error",
                    "error": f"{type(e).__name__}: {e}",
                },
                args.tag,
            )
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
