"""Optimized-HLO parsing: per-computation collectives × while trip counts.

XLA's aggregate ``cost_analysis`` counts a ``while`` body once regardless of
trip count (verified empirically — see EXPERIMENTS.md §Roofline method), so
naive text scans undercount anything inside the layer scan. This parser

1. splits the module into computations,
2. finds every ``while`` op, resolves its body/condition computations and
   extracts the trip count from the condition's compare-against-constant,
3. propagates multipliers through nested whiles,
4. sums ring-model wire bytes for every collective, scaled by its
   computation's execution multiplier.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"=\s*\(?[^=]*?\)?\s*while\(.*?condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)"
)
_CONST_CMP_RE = re.compile(r"constant\((\d+)\)")


def shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dtype, dims = m.group(1), m.group(2)
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _output_bytes(line: str) -> int:
    """Bytes of the instruction's result shape (sum over tuple elements)."""
    eq = line.split(" = ", 1)
    if len(eq) != 2:
        return 0
    rhs = eq[1].strip()
    op_pos = rhs.find("(")
    head = rhs[: op_pos if op_pos > 0 else len(rhs)]
    # head is like "bf16[1,2,3]{...} all-gather" or "(f32[..], f32[..]) tuple"
    return sum(shape_bytes(m.group(0)) for m in _SHAPE_RE.finditer(head))


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return max(1, len([x for x in m.group(1).split(",") if x.strip()]))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return max(1, int(m.group(2)))
    return 1


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    lines: list[str]


def split_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        m = _COMP_HDR_RE.match(line.strip())
        if m and not line.startswith(" "):
            cur = Computation(
                name=m.group(2), is_entry=bool(m.group(1)), lines=[]
            )
            comps[cur.name] = cur
            continue
        if cur is not None and line.strip().startswith("}"):
            cur = None
            continue
        if cur is not None:
            cur.lines.append(line.strip())
    return comps


def _trip_count(cond: Computation) -> int:
    """Largest compare-constant in the condition computation (loop bound)."""
    best = 1
    for line in cond.lines:
        if "compare(" in line or "constant(" in line:
            for m in _CONST_CMP_RE.finditer(line):
                best = max(best, int(m.group(1)))
    return best


def computation_multipliers(comps: dict[str, Computation]) -> dict[str, int]:
    """Execution count of each computation (nested while products)."""
    mult: dict[str, int] = defaultdict(lambda: 1)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return dict(mult)

    # edges: computation -> [(child, factor)]
    edges: dict[str, list[tuple[str, int]]] = defaultdict(list)
    for comp in comps.values():
        for line in comp.lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond_name, body_name = wm.group(1), wm.group(2)
                trips = (
                    _trip_count(comps[cond_name])
                    if cond_name in comps
                    else 1
                )
                edges[comp.name].append((body_name, trips))
                edges[comp.name].append((cond_name, trips))
                continue
            # non-while references execute once per parent execution
            for m in re.finditer(
                r"(?:calls=|to_apply=|condition=|body=|branch_computations=\{)%?([\w\.\-]+)",
                line,
            ):
                edges[comp.name].append((m.group(1), 1))

    seen = set()
    stack = [(entry.name, 1)]
    while stack:
        name, factor = stack.pop()
        key = (name, factor)
        if key in seen:
            continue
        seen.add(key)
        mult[name] = max(mult[name], factor)
        for child, f in edges.get(name, ()):
            if child in comps:
                stack.append((child, factor * f))
    mult[entry.name] = 1
    return dict(mult)


@dataclasses.dataclass
class CollectiveStats:
    counts: dict  # static instruction counts per op kind
    executed: dict  # trip-count-scaled execution counts
    wire_bytes_per_chip: float
    by_op: dict

    def total_ops(self) -> int:
        return sum(self.counts.values())


def parse_collectives(hlo: str) -> CollectiveStats:
    comps = split_computations(hlo)
    mult = computation_multipliers(comps)
    counts = {c: 0 for c in COLLECTIVE_OPS}
    executed = {c: 0 for c in COLLECTIVE_OPS}
    wire = {c: 0.0 for c in COLLECTIVE_OPS}

    for comp in comps.values():
        m = mult.get(comp.name, 1)
        for line in comp.lines:
            if "-done(" in line:
                continue  # async pair: counted at -start
            for op in COLLECTIVE_OPS:
                if f" {op}(" in line or f" {op}-start(" in line:
                    out_b = _output_bytes(line)
                    n = _group_size(line)
                    if n <= 1:
                        break
                    frac = (n - 1) / n
                    if op == "all-gather":
                        b = out_b * frac
                    elif op == "reduce-scatter":
                        b = out_b * (n - 1)  # input = out × n
                    elif op == "all-reduce":
                        b = 2.0 * out_b * frac
                    elif op == "all-to-all":
                        b = out_b * frac
                    else:
                        b = out_b
                    counts[op] += 1
                    executed[op] += m
                    wire[op] += b * m
                    break
    return CollectiveStats(
        counts=counts,
        executed=executed,
        wire_bytes_per_chip=sum(wire.values()),
        by_op=wire,
    )
