"""Launchers: production meshes, multi-pod dry-run, train/serve drivers.

NOTE: ``repro.launch.dryrun`` sets ``XLA_FLAGS`` at import — import it only
in a dedicated process (``python -m repro.launch.dryrun``), never from
tests or benchmarks.
"""

from repro.launch.mesh import make_host_mesh, make_production_mesh

__all__ = ["make_production_mesh", "make_host_mesh"]
