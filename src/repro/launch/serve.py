"""Two-pool serving driver (the paper's system, runnable end to end).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 40

Builds a reduced model, a short pool and a long pool (right-sized per the
paper), routes a synthetic workload through Algorithm 1 with live EMA
calibration, and prints per-pool outcomes + router statistics.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.categories import TRUE_BYTES_PER_TOKEN, Category
from repro.models import Model
from repro.serving import SamplingParams, TwoPoolServer


def serve(
    arch: str = "yi-6b",
    *,
    requests: int = 40,
    short_cmax: int = 128,
    long_cmax: int = 512,
    short_slots: int = 8,
    long_slots: int = 2,
    seed: int = 0,
    temperature: float = 0.0,
) -> dict:
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    srv = TwoPoolServer(
        model,
        params,
        short_cmax=short_cmax,
        long_cmax=long_cmax,
        short_slots=short_slots,
        long_slots=long_slots,
        sampling=SamplingParams(temperature=temperature),
    )

    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    for i in range(requests):
        cat = Category(int(rng.integers(0, 4)))
        n = int(rng.integers(4, short_cmax // 2))
        toks = list(rng.integers(0, cfg.vocab, n))
        # ~10% are short-prompt/long-generation (the paper's hard case)
        mx = int(long_cmax * 0.6) if rng.random() < 0.1 else int(rng.integers(2, 12))
        nbytes = int(n * TRUE_BYTES_PER_TOKEN[cat] + rng.normal(0, 4))
        pool = srv.submit(i, toks, max(1, nbytes), mx, category=int(cat))
        # interleave arrival with service (continuous batching)
        if i % 4 == 3:
            srv.step()
    srv.run_to_completion()
    responses = srv.responses  # includes completions from interleaved steps
    wall = time.perf_counter() - t0

    stats = srv.stats()
    by_pool = {"short": 0, "long": 0}
    for r in responses:
        by_pool[r.pool] += 1
    print(f"[serve] {len(responses)} responses in {wall:.1f}s")
    print(f"[serve] pool split: {by_pool}")
    print(f"[serve] router: {stats['router']['routed_short']} short, "
          f"{stats['router']['routed_long']} long, "
          f"{stats['router']['spill_count']} spills")
    cal = stats["router"]["calibration"]
    for cat in Category:
        true_c = TRUE_BYTES_PER_TOKEN[cat]
        print(
            f"[serve] calib {cat.name}: learned "
            f"{cal['ratio'][int(cat)]:.2f} (true {true_c:.2f}, "
            f"n={cal['count'][int(cat)]})"
        )
    return {"responses": responses, "stats": stats}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--short-cmax", type=int, default=128)
    ap.add_argument("--long-cmax", type=int, default=512)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    serve(
        args.arch,
        requests=args.requests,
        short_cmax=args.short_cmax,
        long_cmax=args.long_cmax,
        temperature=args.temperature,
    )


if __name__ == "__main__":
    main()
