"""Production meshes.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis extends data parallelism across the pod boundary (DCN-ish links), the
inner two stay intra-pod (ICI).

Defined as functions, not module constants, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model_parallel: int = 1) -> Mesh:
    """Whatever this host has (tests/examples): (data, model)."""
    n = jax.device_count()
    mp = min(model_parallel, n)
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def mesh_axis_size(mesh: Mesh, name: str, default: int = 1) -> int:
    try:
        return mesh.shape[name]
    except KeyError:
        return default
