"""Per-(architecture × shape-cell × mesh) sharding policy.

JIT input shardings must divide array dims evenly, so each logical axis is
mapped to a mesh axis only when the corresponding model dimension divides
the mesh axis size; otherwise it degrades to replication (or, for KV
caches, to sequence sharding). The decisions:

* ``heads`` / ``kv_heads`` / ``ssm_heads`` / ``experts`` → "model" iff
  divisible (MQA archs like gemma-2b/granite-34b replicate the tiny KV
  projections and instead shard the decode cache along the *sequence*);
* ``batch`` / ``serve_batch`` → ("pod","data") iff the global batch divides
  the total DP size (long_500k's batch=1 replicates and gives its cache
  sequence both axes);
* ``kv_seq`` → "model" when KV heads can't shard; ("data","model") when the
  batch doesn't shard either (long-context decode = sequence parallelism
  over the whole mesh).
"""

from __future__ import annotations

import dataclasses

from jax.sharding import Mesh

from repro.configs.base import ArchConfig, ShapeCell
from repro.distributed.sharding import AxisRules
from repro.launch.mesh import mesh_axis_size


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    rules: AxisRules
    kv_heads_sharded: bool  # cache layout: heads-sharded vs seq-sharded
    batch_sharded: bool

    def describe(self) -> dict:
        return {
            "rules": {k: v for k, v in self.rules.rules},
            "kv_heads_sharded": self.kv_heads_sharded,
            "batch_sharded": self.batch_sharded,
        }


def build_policy(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh) -> ShardingPolicy:
    msize = mesh_axis_size(mesh, "model")
    dp_total = mesh_axis_size(mesh, "data") * mesh_axis_size(mesh, "pod")

    div = lambda n: n > 0 and n % msize == 0
    batch_ok = cell.global_batch % dp_total == 0

    heads_ok = div(cfg.n_heads * cfg.head_dim) and div(cfg.n_heads)
    kv_ok = div(cfg.n_kv_heads)
    ssm_ok = div(cfg.n_ssm_heads) if cfg.ssm_state else False
    experts_ok = cfg.is_moe and div(cfg.n_experts)
    vocab_ok = cfg.padded_vocab % msize == 0

    # sequence-shard the decode cache when KV heads can't shard; when the
    # batch is also unsharded (long_500k) give the sequence the data axis too
    kv_heads_sharded = kv_ok and batch_ok
    if not batch_ok:
        kv_seq_target: tuple[str, ...] | str | None = ("data", "model")
    elif not kv_ok:
        kv_seq_target = "model"
    else:
        kv_seq_target = None

    rules = AxisRules(
        rules=(
            ("batch", ("pod", "data") if batch_ok else None),
            ("serve_batch", ("pod", "data") if batch_ok else None),
            ("vocab", "model" if vocab_ok else None),
            ("heads", "model" if heads_ok else None),
            ("kv_heads", "model" if (kv_ok and kv_heads_sharded) else None),
            ("ffn", "model"),
            ("experts", "model" if experts_ok else None),
            ("ssm_heads", "model" if ssm_ok else None),
            ("kv_seq", kv_seq_target),
            ("seq_data", "data" if not batch_ok else None),
            ("layers", None),
            ("embed", None),
            ("seq", None),
            ("head_dim", None),
            ("state", None),
            ("conv", None),
            ("codebooks", None),
        )
    )
    return ShardingPolicy(
        rules=rules,
        kv_heads_sharded=kv_heads_sharded,
        batch_sharded=batch_ok,
    )


def pure_dp_policy(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh) -> ShardingPolicy:
    """Fold the model axis into data parallelism (small-model train cells).

    For models whose per-chip weight shard is tiny, TP's per-layer
    all-reduces dominate; running 256-way DP instead trades them for one
    gradient all-reduce per step (§Perf hillclimb B).
    """
    dp_total = mesh.devices.size
    batch_ok = cell.global_batch % dp_total == 0
    axes_all = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    rules = AxisRules(
        rules=(
            ("batch", axes_all if batch_ok else None),
            ("serve_batch", axes_all if batch_ok else None),
            ("vocab", None),
            ("heads", None),
            ("kv_heads", None),
            ("ffn", None),
            ("experts", None),
            ("ssm_heads", None),
            ("kv_seq", None),
            ("seq_data", None),
            ("layers", None),
            ("embed", None),
            ("seq", None),
            ("head_dim", None),
            ("state", None),
            ("conv", None),
            ("codebooks", None),
        )
    )
    return ShardingPolicy(rules=rules, kv_heads_sharded=False, batch_sharded=batch_ok)
