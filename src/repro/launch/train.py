"""Distributed training driver with fault-tolerant restart.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 100 \
        --seq-len 128 --global-batch 8 --reduced

Runs the jit'd train step over the host mesh (elastic: uses whatever
devices exist), checkpoints every ``--ckpt-every`` steps (async, atomic),
and — if interrupted or crashed — resumes from the latest checkpoint with
the data pipeline seeked to the right batch. ``--simulate-failure-at N``
exercises the restart path deliberately.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.distributed.fault import SimulatedFailure, StepTimer, elastic_mesh
from repro.distributed.sharding import tree_shardings
from repro.models import Model
from repro.training import (
    TrainConfig,
    init_train_state,
    make_batch_fn,
    make_train_step,
    opt_state_axes,
)


def train(
    arch: str,
    *,
    steps: int = 100,
    seq_len: int = 128,
    global_batch: int = 8,
    reduced: bool = True,
    peak_lr: float = 1e-3,
    microbatches: int = 1,
    remat: str = "full",
    ckpt_dir: str = "/tmp/repro_ckpt",
    ckpt_every: int = 25,
    model_parallel: int = 1,
    simulate_failure_at: int = -1,
    log_every: int = 10,
) -> dict:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = Model(cfg, remat=remat)
    tcfg = TrainConfig(
        peak_lr=peak_lr,
        warmup_steps=max(2, steps // 20),
        total_steps=steps,
        microbatches=microbatches,
    )
    step_fn, opt = make_train_step(model, tcfg)
    batch_fn = make_batch_fn(cfg, seq_len, global_batch)
    ck = Checkpointer(ckpt_dir, keep=3, async_save=True)
    timer = StepTimer()

    mesh = elastic_mesh(model_parallel=model_parallel)
    p_sh = tree_shardings(model.axes(), mesh)
    o_sh = tree_shardings(opt_state_axes(model, tcfg), mesh)
    jstep = jax.jit(
        step_fn,
        in_shardings=(p_sh, o_sh, None, None),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    )

    start = ck.latest_step() or 0
    if start:
        params, opt_state = init_train_state(model, tcfg, jax.random.key(0))
        state, meta = ck.restore(
            {"p": params, "o": opt_state},
            shardings={"p": p_sh, "o": o_sh},
        )
        params, opt_state = state["p"], state["o"]
        print(f"[train] resumed from step {start} (loss {meta.get('loss')})")
    else:
        params, opt_state = init_train_state(model, tcfg, jax.random.key(0))

    losses = []
    for i in range(start, steps):
        t0 = time.perf_counter()
        if i == simulate_failure_at:
            raise SimulatedFailure(f"injected failure at step {i}")
        batch = jax.tree.map(jnp.asarray, batch_fn(i))
        params, opt_state, metrics = jstep(params, opt_state, batch, jnp.int32(i))
        loss = float(metrics["loss"])
        losses.append(loss)
        if timer.record(time.perf_counter() - t0):
            print(f"[train] straggler step {i}")
        if i % log_every == 0:
            print(
                f"[train] step {i}: loss {loss:.4f} "
                f"lr {float(metrics['lr']):.2e} "
                f"gnorm {float(metrics['grad_norm']):.2f}"
            )
        if (i + 1) % ckpt_every == 0 or i + 1 == steps:
            ck.save(i + 1, {"p": params, "o": opt_state}, {"loss": loss})
    ck.wait()
    return {"final_loss": losses[-1] if losses else None, "losses": losses}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--full", action="store_true", help="full (unreduced) config")
    ap.add_argument("--peak-lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--simulate-failure-at", type=int, default=-1)
    args = ap.parse_args()
    out = train(
        args.arch,
        steps=args.steps,
        seq_len=args.seq_len,
        global_batch=args.global_batch,
        reduced=not args.full,
        peak_lr=args.peak_lr,
        microbatches=args.microbatches,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        model_parallel=args.model_parallel,
        simulate_failure_at=args.simulate_failure_at,
    )
    print(f"[train] done: final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
