"""Analytic whole-program cost model: exact FLOPs + HBM-traffic estimates.

Why this exists: XLA's ``compiled.cost_analysis()`` on this backend counts
``while`` bodies **once** (verified by probe — EXPERIMENTS.md §Roofline
method), so scanned layer stacks are undercounted by ~n_layers×. We control
every einsum in the model zoo, so the FLOP count here is exact (it is the
"HLO FLOPs" the partitioned program executes, reconstructed with correct
trip counts); HBM bytes follow a standard traffic model (weights read per
pass, residual-stream activations, flash-KV restreaming, cache reads,
optimizer state) — each term annotated below.

All numbers are GLOBAL (whole step, all chips); the roofline divides by
chip count. Collective bytes are NOT modeled here — they come from the
trip-count-corrected HLO parse (repro.launch.hlo_parse).
"""

from __future__ import annotations

import dataclasses
import math

from repro.configs.base import ArchConfig, ShapeCell


@dataclasses.dataclass
class CellCost:
    flops_fwd: float
    flops_total: float  # with train multiplier if applicable
    hbm_bytes: float
    detail: dict

    def as_dict(self) -> dict:
        return {
            "flops_fwd": self.flops_fwd,
            "flops_total": self.flops_total,
            "hbm_bytes": self.hbm_bytes,
            "detail": self.detail,
        }


def _attn_layer_flops(cfg, b, l, l_kv, *, causal_frac, decode=False):
    d, h, k, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    qkv = 2 * b * l * d * (h + 2 * k) * dh
    if decode:
        attn = 4 * b * h * dh * l_kv
    else:
        attn = 4 * b * h * dh * l * l_kv * causal_frac
    o = 2 * b * l * h * dh * d
    return qkv + attn + o


def _mlp_flops(cfg, b, l):
    mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
    return 2 * mats * b * l * cfg.d_model * cfg.d_ff


def _moe_flops(cfg, b, l, capacity_factor):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    tokens = b * l
    g = min(512, tokens)
    n_groups = tokens // g
    cap = max(cfg.top_k, min(g, int(g * cfg.top_k * capacity_factor / e)))
    mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
    router = 2 * tokens * d * e
    dispatch = 2 * n_groups * g * e * cap * d * 2  # dispatch + combine
    experts = 2 * mats * n_groups * e * cap * d * f
    shared = (
        2 * mats * tokens * d * f * cfg.n_shared_experts
        if cfg.n_shared_experts
        else 0
    )
    return router + dispatch + experts + shared


def _cross_attn_flops(cfg, b, l):
    d, h, k, dh, m = (
        cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
        cfg.cross_mem_len,
    )
    return (
        2 * b * l * d * h * dh  # q
        + 2 * b * m * d * 2 * k * dh  # k, v (per layer; no caching assumed)
        + 4 * b * h * dh * l * m  # scores + pv
        + 2 * b * l * h * dh * d  # o
    )


def _mamba_flops(cfg, b, l, decode=False):
    d, di, n, hs = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    p = cfg.ssm_head_dim
    proj = 2 * b * l * d * (2 * di + 2 * n + hs)
    conv = 2 * b * l * (di + 2 * n) * cfg.ssm_conv
    if decode:
        ssd = 4 * b * hs * p * n  # single-step state update + read
    else:
        q = min(128, l)
        ssd = (
            2 * b * hs * l * q * n  # C·B Gram
            + 2 * b * hs * l * q * p  # intra combine
            + 4 * b * hs * l * p * n  # state in + cross read
        )
    out = 2 * b * l * di * d
    return proj + conv + ssd + out


def _mlstm_flops(cfg, b, l, decode=False):
    d = cfg.d_model
    di = 2 * d
    h = cfg.n_heads
    hd = di // h
    up = 2 * b * l * d * 2 * di
    qkv = 3 * 2 * b * l * di * (di // h)  # block-diagonal per head
    gates = 2 * 2 * b * l * di * h
    if decode:
        cell = 4 * b * h * hd * hd
    else:
        q = min(128, l)
        cell = 4 * b * h * l * q * hd + 4 * b * h * l * hd * hd
    down = 2 * b * l * di * d
    return up + qkv + gates + cell + down


def _slstm_flops(cfg, b, l):
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    d_up = (((4 * d) // 3 + 127) // 128) * 128
    gates = 4 * 2 * b * l * d * d
    recur = 4 * 2 * b * l * h * hd * hd
    out = 2 * b * l * d * d
    mlp = 2 * 2 * b * l * d * d_up
    return gates + recur + out + mlp


def forward_flops(
    cfg: ArchConfig,
    cell: ShapeCell,
    *,
    causal_mode: str = "masked",
    moe_cf: float = 1.25,
) -> dict:
    """Global forward FLOPs, by component."""
    b = cell.global_batch
    decode = cell.kind == "decode"
    l = 1 if decode else cell.seq_len
    l_kv = cell.seq_len
    causal_frac = 0.5 if causal_mode == "triangle" else 1.0

    detail: dict[str, float] = {}
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        attn = cfg.n_layers * _attn_layer_flops(
            cfg, b, l, l_kv if decode else l,
            causal_frac=causal_frac, decode=decode,
        )
        detail["attention"] = attn
        if cfg.is_moe:
            n_moe = cfg.n_layers // cfg.moe_every
            n_dense = cfg.n_layers - n_moe
            detail["moe"] = n_moe * _moe_flops(cfg, b, l, moe_cf)
            detail["mlp"] = n_dense * _mlp_flops(cfg, b, l)
        else:
            detail["mlp"] = cfg.n_layers * _mlp_flops(cfg, b, l)
        if cfg.cross_attention:
            detail["cross_attention"] = cfg.n_layers * _cross_attn_flops(
                cfg, b, l
            )
    elif cfg.family == "hybrid":
        n_inv = cfg.n_layers // cfg.attn_every
        detail["mamba"] = cfg.n_layers * _mamba_flops(cfg, b, l, decode)
        detail["attention"] = n_inv * (
            _attn_layer_flops(
                cfg, b, l, l_kv if decode else l,
                causal_frac=causal_frac, decode=decode,
            )
            + _mlp_flops(cfg, b, l)
        )
    elif cfg.family == "ssm":
        n_groups = cfg.n_layers // cfg.slstm_every
        n_m = n_groups * (cfg.slstm_every - 1)
        detail["mlstm"] = n_m * _mlstm_flops(cfg, b, l, decode)
        detail["slstm"] = n_groups * _slstm_flops(cfg, b, l)
    else:
        raise ValueError(cfg.family)

    head_positions = b * (l if cell.kind == "train" else 1)
    heads = max(1, cfg.n_codebooks)
    detail["head"] = 2 * head_positions * cfg.d_model * cfg.padded_vocab * heads
    if cell.kind == "train":
        detail["xent"] = 3 * b * l * cfg.padded_vocab * heads
    return detail


def hbm_bytes(
    cfg: ArchConfig,
    cell: ShapeCell,
    param_count: int,
    *,
    optimizer: str = "adamw",
    kv_dtype: str = "bf16",
) -> dict:
    """Global HBM traffic model (bytes), by component.

    weights      — one full bf16 read per forward pass; train does fwd +
                   remat-fwd + bwd (3 reads) + grad write/read (2+2) and
                   optimizer traffic (AdamW: m,v fp32 read+write = 16 B/p +
                   param write 2; Adafactor ≈ 2 B/p).
    activations  — residual-stream traffic ≈ 8 reads/writes of (B,L,D)
                   per layer per pass (qkv/attn/mlp boundaries).
    flash_kv     — prefill/train attention restreams K,V once per q-chunk.
    kv_cache     — decode reads the whole cache once per step (+tiny write);
                   prefill writes it once.
    logits       — written + read by the loss (train), or last-position
                   only (serve).
    """
    b = cell.global_batch
    decode = cell.kind == "decode"
    l = 1 if decode else cell.seq_len
    s = cell.seq_len
    d = cfg.d_model
    bpe = 2  # bf16
    train = cell.kind == "train"

    detail: dict[str, float] = {}
    w_bytes = param_count * bpe
    if train:
        opt_traffic = 18.0 if optimizer == "adamw" else 4.0
        detail["weights"] = w_bytes * (3 + 2 + 2) + param_count * opt_traffic
    else:
        detail["weights"] = w_bytes

    act_passes = 3 if train else 1
    detail["activations"] = 8.0 * cfg.n_layers * b * l * d * bpe * act_passes

    if cfg.family in ("dense", "moe", "vlm", "audio", "hybrid") and not decode:
        n_attn = (
            cfg.n_layers
            if cfg.family != "hybrid"
            else cfg.n_layers // cfg.attn_every
        )
        nq = max(1, l // 512)
        kv_bytes_layer = 2 * b * l * cfg.n_kv_heads * cfg.head_dim * bpe
        detail["flash_kv"] = n_attn * nq * kv_bytes_layer * act_passes
        detail["kv_cache_write"] = (
            n_attn * kv_bytes_layer if cell.kind == "prefill" else 0.0
        )
    if decode:
        n_attn = (
            cfg.n_layers
            if cfg.family in ("dense", "moe", "vlm", "audio")
            else (cfg.n_layers // cfg.attn_every if cfg.family == "hybrid" else 0)
        )
        # int8 KV: 1 byte/element + fp16 scale per (pos, head) ≈ 1.02 B/elem
        kv_bpe = (1.0 + 2.0 / cfg.head_dim) if kv_dtype == "int8" else bpe
        detail["kv_cache_read"] = (
            n_attn * 2 * b * s * cfg.n_kv_heads * cfg.head_dim * kv_bpe
        )
        if cfg.family == "hybrid":
            detail["ssm_state"] = (
                2 * cfg.n_layers * b * cfg.n_ssm_heads * cfg.ssm_head_dim
                * cfg.ssm_state * 4
            )
        if cfg.family == "ssm":
            di = 2 * d
            hd = di // cfg.n_heads
            detail["mlstm_state"] = (
                2 * cfg.n_layers * b * cfg.n_heads * hd * hd * 4
            )

    heads = max(1, cfg.n_codebooks)
    logit_positions = b * (l if train else 1)
    detail["logits"] = 2.0 * logit_positions * cfg.padded_vocab * heads * bpe
    return detail


def cell_cost(
    cfg: ArchConfig,
    cell: ShapeCell,
    param_count: int,
    *,
    causal_mode: str = "masked",
    moe_cf: float = 1.25,
    optimizer: str = "adamw",
    remat: str = "full",
    kv_dtype: str = "bf16",
) -> CellCost:
    fwd = forward_flops(cfg, cell, causal_mode=causal_mode, moe_cf=moe_cf)
    fwd_total = sum(fwd.values())
    if cell.kind == "train":
        # fwd (1×) + bwd (2×) + remat recompute: full policy recomputes the
        # whole forward (1×); dots-saved policy recomputes only the cheap
        # non-matmul ops (~0.2×); no remat recomputes nothing.
        mult = {"full": 4.0, "dots": 3.2, "none": 3.0}.get(str(remat), 4.0)
        total = fwd_total * mult
    else:
        total = fwd_total
    mem = hbm_bytes(
        cfg, cell, param_count, optimizer=optimizer, kv_dtype=kv_dtype
    )
    return CellCost(
        flops_fwd=fwd_total,
        flops_total=total,
        hbm_bytes=sum(mem.values()),
        detail={"flops": fwd, "bytes": mem},
    )
