"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (EXPERIMENTS.md §Roofline):

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_wire_bytes_per_chip / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are
parsed from the *optimized* (post-SPMD) HLO: every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op contributes ring-model
bytes-on-wire per chip:

    all-gather       (N-1)/N × output_bytes
    reduce-scatter   (N-1)/N × input_bytes  (≈ output_bytes × (N-1))
    all-reduce       2 (N-1)/N × bytes
    all-to-all       (N-1)/N × bytes
    collective-permute   bytes

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.core.cost_model import TPU_V5E, HardwareSpec

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(shape_str: str) -> int:
    """bytes of one HLO shape literal like ``bf16[16,4096,128]``."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dtype, dims = m.group(1), m.group(2)
    if dtype == "token" or dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _line_output_bytes(line: str) -> int:
    """Total bytes of the op's output shape(s) (tuple → sum)."""
    eq = line.split("=", 1)
    if len(eq) != 2:
        return 0
    rhs = eq[1].strip()
    # output shape is the first shape literal(s) before the op name
    total = 0
    for m in _SHAPE_RE.finditer(rhs.split(")")[0] + ")"):
        total += shape_bytes(m.group(0))
    # simpler: first tuple or single shape
    first = re.match(r"\(?((?:\w+\[[\d,]*\](?:,\s*)?)+)\)?", rhs)
    if first:
        total = sum(
            shape_bytes(m.group(0)) for m in _SHAPE_RE.finditer(first.group(1))
        )
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(1, len(ids))
    m = _GROUPS_V2_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    wire_bytes_per_chip: float
    by_op: dict

    def total_ops(self) -> int:
        return sum(self.counts.values())


def parse_collectives(hlo_text: str, *, default_group: int = 1) -> CollectiveStats:
    """Scan optimized HLO for collectives → per-chip wire bytes (ring model)."""
    counts: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    wire: dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("%") or ls.startswith("ROOT"):
            body = ls
        else:
            continue
        for op in _COLLECTIVES:
            # match the op as an instruction, not a substring of a name
            if re.search(rf"\b{op}(?:-start|-done)?\(", body) or re.search(
                rf"= *\(?[\w\[\],\s]*\)? *{op}(?:-start)?\(", body
            ):
                if f"{op}-done" in body:
                    break  # counted at -start
                out_bytes = _line_output_bytes(body)
                n = _group_size(body, default_group)
                if n <= 1:
                    break
                frac = (n - 1) / n
                if op == "all-gather":
                    b = out_bytes * frac
                elif op == "reduce-scatter":
                    b = out_bytes * (n - 1)
                elif op == "all-reduce":
                    b = 2.0 * out_bytes * frac
                elif op == "all-to-all":
                    b = out_bytes * frac
                else:  # collective-permute
                    b = out_bytes
                counts[op] += 1
                wire[op] += b
                break
    return CollectiveStats(
        counts=counts,
        wire_bytes_per_chip=sum(wire.values()),
        by_op=wire,
    )


@dataclasses.dataclass
class Roofline:
    flops_total: float
    bytes_total: float
    collective_bytes_per_chip: float
    chips: int
    hw: HardwareSpec = TPU_V5E

    @property
    def compute_s(self) -> float:
        return self.flops_total / (self.chips * self.hw.peak_flops_bf16)

    @property
    def memory_s(self) -> float:
        return self.bytes_total / (self.chips * self.hw.hbm_bw)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_chip / self.hw.ici_bw

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def model_flops_fraction(self, model_flops: float) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        if self.flops_total <= 0:
            return 0.0
        return model_flops / self.flops_total

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops_total": self.flops_total,
            "bytes_total": self.bytes_total,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "chips": self.chips,
        }


def make_roofline(
    cost_analysis: Optional[dict],
    collectives: CollectiveStats,
    chips: int,
    hw: HardwareSpec = TPU_V5E,
) -> Roofline:
    cost = cost_analysis or {}
    return Roofline(
        flops_total=float(cost.get("flops", 0.0)),
        bytes_total=float(cost.get("bytes accessed", 0.0)),
        collective_bytes_per_chip=collectives.wire_bytes_per_chip,
        chips=chips,
        hw=hw,
    )


def model_flops_estimate(n_params: int, tokens: int, *, train: bool) -> float:
    """6·N·D for training; 2·N·D for a forward/decode pass."""
    return (6.0 if train else 2.0) * n_params * tokens
