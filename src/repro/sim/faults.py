"""Fault injection + failure recovery for the fleet DES.

The failure model is instance-level and fully deterministic: a
:class:`FaultInjector` holds an immutable set of :class:`FaultSpec`\\ s
(scheduled explicitly or generated stochastically from a seed) which
compile into a time-ordered list of state *transitions* — crash, KV-OOM
kill, slowdown onset, recovery, warm-up end. The fleet applies each
transition as a first-class simulation event at its exact timestamp, in
both DES backends, so a faulted run is reproducible bit-for-bit.

Fault kinds
-----------

``crash``     hard instance failure: all in-flight sequences are dropped
              (``requeue=True`` puts them back at the head of the local
              queue with their generated tokens folded into the prompt,
              vLLM recompute-style; ``requeue=False`` loses them — the
              fleet's :class:`RetryPolicy` decides their fate). The
              instance is down for ``duration`` seconds, then recovers;
              with ``warmup > 0`` it admits immediately on recovery but
              runs at ``warmup_factor``× iteration time until warm.
``oom``       KV-OOM kill: the youngest ``evict_frac`` of resident
              sequences are evicted (the instance survives). Same
              requeue-vs-lose disposition as ``crash``.
``slowdown``  transient straggler: iteration time is multiplied by
              ``factor`` for ``duration`` seconds.

Recovery side
-------------

:class:`RetryPolicy` gives lost requests capped exponential backoff with
deterministic (hash-based, order-independent) jitter, a per-request retry
budget, and an optional deadline measured from the original arrival. On
retry the router is asked to *avoid* the pool that failed the request.
Pool-level health is a windowed-error-rate circuit breaker: once a pool
accumulates ``breaker_threshold`` lost requests within
``breaker_window`` sim-seconds, the pool is skipped by nearest-feasible
spillover for ``breaker_cooldown`` seconds (half-open after that — new
failures re-trip it). Instance up/down bookkeeping reuses
:class:`repro.distributed.fault.HealthMonitor` on the sim clock.

Everything here is inert unless ``FleetSim(injector=...)`` is passed:
fault-off runs take exactly the pre-fault code paths (``injector is
None`` guards, same discipline as telemetry).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from collections import deque
from typing import Callable, Mapping, Optional, Sequence

import numpy as np

from repro.distributed.fault import HealthMonitor
from repro.obs.events import FAIL, RECOVER, ROUTER_TRACK, SHED, TIMEOUT

FAULT_KINDS = ("crash", "oom", "slowdown")

_MASK64 = (1 << 64) - 1


def _mix64(x: int) -> int:
    """splitmix64 finalizer — cheap, well-mixed 64-bit hash."""
    x = (x + 0x9E3779B97F4A7C15) & _MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK64
    return x ^ (x >> 31)


def _unit_hash(seed: int, request_id: int, attempt: int) -> float:
    """Deterministic uniform in [0, 1) from (seed, request, attempt).

    Order-independent by construction — both DES backends evaluate it at
    different points in their loops yet get identical jitter.
    """
    z = _mix64(_mix64(_mix64(seed & _MASK64) ^ (request_id & _MASK64)) ^ attempt)
    return (z >> 11) / float(1 << 53)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled instance-level fault."""

    kind: str
    pool: str
    instance: int = 0
    t: float = 0.0
    #: Downtime (crash) or straggler window (slowdown), seconds.
    duration: float = 0.0
    #: Iteration-time multiplier while a slowdown is active.
    factor: float = 1.0
    #: Fraction of resident sequences evicted by an ``oom`` fault.
    evict_frac: float = 0.5
    #: Re-queue dropped sequences locally instead of losing them.
    requeue: bool = False
    #: Post-recovery warm-up window (crash only), seconds.
    warmup: float = 0.0
    #: Iteration-time multiplier during warm-up.
    warmup_factor: float = 1.5

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; use one of {FAULT_KINDS}")
        if self.t < 0.0 or self.duration < 0.0 or self.warmup < 0.0:
            raise ValueError(f"fault times must be non-negative: {self}")
        if self.kind == "slowdown" and self.factor <= 0.0:
            raise ValueError(f"slowdown factor must be positive: {self.factor}")
        if self.kind == "oom" and not (0.0 < self.evict_frac <= 1.0):
            raise ValueError(f"evict_frac must be in (0, 1]: {self.evict_frac}")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff + deterministic jitter for lost requests."""

    max_retries: int = 3
    base_backoff: float = 0.05
    max_backoff: float = 1.0
    #: Relative jitter amplitude: backoff is scaled by 1 + jitter·U where
    #: U ~ hash(seed, request, attempt) in [0, 1).
    jitter: float = 0.25
    #: Deadline measured from the request's original arrival; a retry that
    #: would dispatch past it is dropped as a timeout. ``None`` = no deadline.
    timeout: Optional[float] = None
    seed: int = 0

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0: {self.max_retries}")
        if self.base_backoff < 0.0 or self.max_backoff < self.base_backoff:
            raise ValueError(
                f"need 0 <= base_backoff <= max_backoff: "
                f"{self.base_backoff}, {self.max_backoff}"
            )
        if self.jitter < 0.0:
            raise ValueError(f"jitter must be >= 0: {self.jitter}")

    def backoff(self, request_id: int, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based) of ``request_id``."""
        b = min(self.max_backoff, self.base_backoff * (2.0 ** (attempt - 1)))
        if self.jitter:
            b *= 1.0 + self.jitter * _unit_hash(self.seed, request_id, attempt)
        return b


@dataclasses.dataclass(frozen=True)
class _Transition:
    """One compiled instance state change, applied at exactly ``t``."""

    t: float
    order: int  # stable tie-break: compilation order
    pool_idx: int
    instance: int
    action: str  # crash | oom | slow | recover | slow_end
    requeue: bool = False
    frac: float = 0.0
    factor: float = 1.0
    until: float = 0.0  # crash: recovery time (down_until)


class FaultInjector:
    """Immutable fault schedule + circuit-breaker configuration.

    Per-run mutable state lives in :class:`FaultRuntime`, built by the
    fleet — one injector can drive many runs (e.g. static vs adaptive on
    the same incident).
    """

    def __init__(
        self,
        specs: Sequence[FaultSpec] = (),
        *,
        breaker_threshold: int = 5,
        breaker_window: float = 1.0,
        breaker_cooldown: float = 0.5,
    ) -> None:
        if breaker_threshold <= 0:
            raise ValueError(f"breaker_threshold must be positive: {breaker_threshold}")
        self.specs = tuple(sorted(specs, key=lambda s: (s.t, s.pool, s.instance)))
        self.breaker_threshold = breaker_threshold
        self.breaker_window = breaker_window
        self.breaker_cooldown = breaker_cooldown

    @classmethod
    def stochastic(
        cls,
        pools: Mapping[str, int],
        *,
        horizon: float,
        rate: float,
        seed: int = 0,
        kinds: Sequence[str] = FAULT_KINDS,
        mean_downtime: float = 0.25,
        mean_slow_window: float = 0.25,
        slow_factor: float = 3.0,
        evict_frac: float = 0.5,
        requeue: bool = False,
        warmup: float = 0.0,
        **breaker_kw,
    ) -> "FaultInjector":
        """Seeded Poisson fault schedule over ``pools`` (name → instances).

        Fault count ~ Poisson(rate·horizon); times are uniform on the
        horizon, targets weighted by instance count. Same seed → the
        identical schedule, independent of backend or run order.
        """
        names = list(pools)
        counts = np.asarray([pools[n] for n in names], dtype=np.float64)
        if len(names) == 0 or counts.sum() <= 0:
            raise ValueError("stochastic faults need at least one instance")
        rng = np.random.default_rng(seed)
        n = int(rng.poisson(rate * horizon))
        specs = []
        weights = counts / counts.sum()
        for _ in range(n):
            t = float(rng.uniform(0.0, horizon))
            p = int(rng.choice(len(names), p=weights))
            inst = int(rng.integers(int(counts[p])))
            kind = str(kinds[int(rng.integers(len(kinds)))])
            if kind == "crash":
                specs.append(
                    FaultSpec(
                        "crash",
                        names[p],
                        inst,
                        t,
                        duration=float(rng.exponential(mean_downtime)),
                        requeue=requeue,
                        warmup=warmup,
                    )
                )
            elif kind == "oom":
                specs.append(
                    FaultSpec("oom", names[p], inst, t, evict_frac=evict_frac, requeue=requeue)
                )
            else:
                specs.append(
                    FaultSpec(
                        "slowdown",
                        names[p],
                        inst,
                        t,
                        duration=float(rng.exponential(mean_slow_window)),
                        factor=slow_factor,
                    )
                )
        return cls(specs, **breaker_kw)

    def compile(
        self, pool_names: Sequence[str], num_instances: Sequence[int]
    ) -> list[_Transition]:
        """Resolve pool names → budget-order indices; expand to transitions."""
        index = {name: i for i, name in enumerate(pool_names)}
        out: list[_Transition] = []
        order = itertools.count()
        for s in self.specs:
            if s.pool not in index:
                raise ValueError(f"fault targets unknown pool {s.pool!r}; have {list(index)}")
            p = index[s.pool]
            if not 0 <= s.instance < num_instances[p]:
                raise ValueError(
                    f"fault targets instance {s.instance} of pool {s.pool!r} "
                    f"which has {num_instances[p]} instances"
                )
            if s.kind == "crash":
                up = s.t + s.duration
                out.append(
                    _Transition(s.t, next(order), p, s.instance, "crash", requeue=s.requeue, until=up)
                )
                warm = s.warmup_factor if s.warmup > 0.0 else 1.0
                out.append(_Transition(up, next(order), p, s.instance, "recover", factor=warm))
                if s.warmup > 0.0:
                    out.append(_Transition(up + s.warmup, next(order), p, s.instance, "slow_end"))
            elif s.kind == "oom":
                out.append(
                    _Transition(s.t, next(order), p, s.instance, "oom", requeue=s.requeue, frac=s.evict_frac)
                )
            else:  # slowdown
                out.append(_Transition(s.t, next(order), p, s.instance, "slow", factor=s.factor))
                out.append(_Transition(s.t + s.duration, next(order), p, s.instance, "slow_end"))
        out.sort(key=lambda tr: (tr.t, tr.order))
        return out


class FaultRuntime:
    """Per-run fault state shared by both DES backends.

    Owns the compiled transition schedule, the retry heap, per-pool
    circuit breakers, a sim-clock :class:`HealthMonitor` of instance
    up/down state, and the fault/retry counters surfaced on
    ``FleetResult`` and in the ``telemetry-v2`` health columns. The fleet
    drives it through :meth:`next_time`/:meth:`pop` (faults win ties
    against arrivals, engine iterations, and retries) and reports lost
    requests through :meth:`on_lost`.
    """

    def __init__(
        self,
        injector: FaultInjector,
        policy: Optional[RetryPolicy],
        pool_names: Sequence[str],
        pool_sims: Sequence,
    ) -> None:
        self.injector = injector
        self.policy = policy
        self.pool_names = list(pool_names)
        self.pool_sims = list(pool_sims)
        self.num_instances = [p.state.num_instances for p in self.pool_sims]
        self.transitions = injector.compile(self.pool_names, self.num_instances)
        self._ti = 0
        self._rheap: list[tuple[float, int, int, int, int]] = []
        self._rseq = itertools.count()
        self.attempts: dict[int, int] = {}
        # counters (FleetResult + telemetry deltas)
        self.retries = 0
        self.timeouts = 0
        self.shed = 0
        self.instance_failures = 0
        self.failures = [0] * len(self.pool_sims)  # lost in-flight, per pool
        # instance health: host id = global instance offset + local index
        self.monitor = HealthMonitor(timeout_s=math.inf, clock=lambda: self._now)
        self._now = 0.0
        self._offsets = [0] * len(self.pool_sims)
        off = 0
        for i, n in enumerate(self.num_instances):
            self._offsets[i] = off
            off += n
        self.total_instances = off
        for h in range(off):
            self.monitor.heartbeat(h, now=0.0)
        self.down_count = [0] * len(self.pool_sims)
        self._down_started: dict[int, float] = {}
        self._down_intervals: list[tuple[float, float]] = []
        # circuit breaker: windowed lost-request times per pool
        self._fail_times: list[deque[float]] = [deque() for _ in self.pool_sims]
        self._open_until = [-math.inf] * len(self.pool_sims)
        self.tracer = None
        self._arrival_of: Optional[Callable[[int], float]] = None

    # -- run wiring ----------------------------------------------------------
    def begin(self, arrival_of: Callable[[int], float]) -> None:
        self._arrival_of = arrival_of

    # -- event-queue interface ----------------------------------------------
    def pending(self) -> bool:
        return self._ti < len(self.transitions) or bool(self._rheap)

    def next_time(self) -> float:
        t = math.inf
        if self._ti < len(self.transitions):
            t = self.transitions[self._ti].t
        if self._rheap and self._rheap[0][0] < t:
            t = self._rheap[0][0]
        return t

    def pop(self):
        """Next due item: ``("fault", _Transition)`` or ``("retry", entry)``.

        Transitions win exact-time ties against retries so both backends
        agree on ordering.
        """
        t_tr = self.transitions[self._ti].t if self._ti < len(self.transitions) else math.inf
        if self._rheap and self._rheap[0][0] < t_tr:
            return "retry", heapq.heappop(self._rheap)
        tr = self.transitions[self._ti]
        self._ti += 1
        return "fault", tr

    # -- transition bookkeeping ---------------------------------------------
    def _host(self, pool_idx: int, instance: int) -> int:
        return self._offsets[pool_idx] + instance

    def on_instance_fault(self, tr: _Transition, n_lost: int, t: float) -> None:
        """A crash or OOM fired: health + counters + FAIL event."""
        self._now = t
        self.instance_failures += 1
        if tr.action == "crash":
            self.down_count[tr.pool_idx] += 1
            host = self._host(tr.pool_idx, tr.instance)
            self.monitor.mark_dead(host)
            self._down_started[host] = t
        if self.tracer is not None:
            self.tracer.emit(FAIL, t, tr.pool_idx, tr.instance, float(n_lost))

    def on_slow(self, tr: _Transition, t: float) -> None:
        self._now = t
        if self.tracer is not None:
            self.tracer.emit(FAIL, t, tr.pool_idx, tr.instance, tr.factor)

    def on_recover(self, tr: _Transition, t: float) -> None:
        self._now = t
        if tr.action == "recover":
            self.down_count[tr.pool_idx] -= 1
            host = self._host(tr.pool_idx, tr.instance)
            self.monitor.revive(host, now=t)
            start = self._down_started.pop(host, t)
            self._down_intervals.append((start, t))
        if self.tracer is not None:
            self.tracer.emit(RECOVER, t, tr.pool_idx, tr.instance)

    # -- lost-request disposition -------------------------------------------
    def on_lost(self, request_id: int, pool_idx: int, t: float) -> bool:
        """A request's in-flight state was destroyed on ``pool_idx``.

        Returns True if a retry was scheduled; False if the request is
        finally failed (shed or timed out) and the fleet must write its
        failure record.
        """
        self._now = t
        self.failures[pool_idx] += 1
        self._record_breaker(pool_idx, t)
        policy = self.policy
        if policy is None:
            self.shed += 1
            if self.tracer is not None:
                self.tracer.emit(SHED, t, ROUTER_TRACK, request_id)
            return False
        attempt = self.attempts.get(request_id, 0) + 1
        self.attempts[request_id] = attempt
        if attempt > policy.max_retries:
            self.shed += 1
            if self.tracer is not None:
                self.tracer.emit(SHED, t, ROUTER_TRACK, request_id, float(attempt - 1))
            return False
        t_retry = t + policy.backoff(request_id, attempt)
        if policy.timeout is not None:
            arrival = self._arrival_of(request_id) if self._arrival_of else 0.0
            if t_retry - arrival > policy.timeout:
                self.timeouts += 1
                if self.tracer is not None:
                    self.tracer.emit(TIMEOUT, t, ROUTER_TRACK, request_id, float(attempt))
                return False
        heapq.heappush(self._rheap, (t_retry, next(self._rseq), request_id, attempt, pool_idx))
        return True

    # -- circuit breaker -----------------------------------------------------
    def _record_breaker(self, pool_idx: int, t: float) -> None:
        dq = self._fail_times[pool_idx]
        dq.append(t)
        while dq and t - dq[0] > self.injector.breaker_window:
            dq.popleft()
        if len(dq) >= self.injector.breaker_threshold:
            self._open_until[pool_idx] = t + self.injector.breaker_cooldown

    def is_open(self, pool_idx: int, now: float) -> bool:
        return self._open_until[pool_idx] > now

    def blocked(self, now: float) -> Optional[frozenset]:
        """Pool indices to skip at dispatch: tripped breaker or all-down.

        ``None`` (the common case) keeps the router's fast path allocation-
        free.
        """
        b = None
        for k in range(len(self.pool_sims)):
            if self._open_until[k] > now or (
                0 < self.num_instances[k] == self.down_count[k]
            ):
                if b is None:
                    b = set()
                b.add(k)
        return frozenset(b) if b else None

    # -- end-of-run metrics ---------------------------------------------------
    def availability(self, t_end: float) -> float:
        """Up instance-seconds / total instance-seconds over [0, t_end]."""
        if t_end <= 0.0 or self.total_instances == 0:
            return 1.0
        down = 0.0
        for s, e in self._down_intervals:
            down += max(0.0, min(e, t_end) - min(s, t_end))
        for s in self._down_started.values():
            down += max(0.0, t_end - min(s, t_end))
        return 1.0 - down / (t_end * self.total_instances)
