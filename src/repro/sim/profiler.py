"""Analytical profiler: pool throughput + fleet sizing (Appendix A layer 2).

Computes the theoretical maximum throughput μ_max of each pool configuration
from a trace CDF (or an explicit request list) and the timing model, then
sizes fleets with the queuing-headroom factors β. This is the layer that
produces Table 1 (μ per pool), Table 2 (fleet sizes), Figure 6 (sensitivity
sweep) and the Table 5 projection.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.core.pools import PoolConfig, n_seq_for_cmax
from repro.core.router import Request
from repro.sim.timing import TimingModel

#: Queuing-headroom factors β (Appendix A layer 2).
HEADROOM = {"homogeneous": 1.08, "short": 1.05, "long": 1.02}


@dataclasses.dataclass(frozen=True)
class PoolProfile:
    pool: str
    c_max: int
    n_seq: int
    mean_iters: float
    traffic_fraction: float  # share of requests this pool serves
    mu: float  # req/s per instance at full occupancy
    instances: int  # sized for `rate` with headroom


def mean_iterations(
    requests: Sequence[Request], timing: TimingModel
) -> float:
    if not requests:
        return 0.0
    total = sum(
        timing.iterations_for(r.true_input_tokens, r.true_output_tokens)
        for r in requests
    )
    return total / len(requests)


def profile_pool(
    name: str,
    requests: Sequence[Request],
    pool_requests: Sequence[Request],
    pool: PoolConfig,
    timing: TimingModel,
    rate: float,
    *,
    headroom: Optional[float] = None,
) -> PoolProfile:
    """Profile one pool over the subset of the trace routed to it."""
    frac = len(pool_requests) / max(1, len(requests))
    mean_iters = mean_iterations(pool_requests, timing)
    if mean_iters <= 0:
        return PoolProfile(name, pool.c_max, pool.n_seq, 0.0, 0.0, 0.0, 0)
    mu = timing.throughput(mean_iters, pool.n_seq)
    beta = pool.headroom if headroom is None else headroom
    instances = max(1, math.ceil(frac * rate / mu * beta))
    return PoolProfile(name, pool.c_max, pool.n_seq, mean_iters, frac, mu, instances)


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """Analytical fleet comparison: homogeneous vs token-budget dual pool."""

    trace: str
    rate: float
    b_short: int
    homogeneous: PoolProfile
    short: PoolProfile
    long: PoolProfile

    @property
    def g_homo(self) -> int:
        return self.homogeneous.instances

    @property
    def g_dual(self) -> int:
        return self.short.instances + self.long.instances

    @property
    def savings(self) -> float:
        return (self.g_homo - self.g_dual) / max(1, self.g_homo)

    @property
    def alpha(self) -> float:
        return self.short.traffic_fraction

    @property
    def rho(self) -> float:
        """Throughput gain ratio μ(C_S)/μ(C_H) for the closed-form model."""
        if self.homogeneous.mu <= 0:
            return 1.0
        return self.short.mu / self.homogeneous.mu


def split_by_budget(
    requests: Sequence[Request], b_short: int
) -> tuple[list[Request], list[Request]]:
    """Oracle split on the *true* total budget (analytical layer).

    The DES layer uses the router's calibrated estimates instead; at the
    analytical layer the paper splits on the trace's actual totals.
    """
    short = [r for r in requests if r.true_total <= b_short]
    long_ = [r for r in requests if r.true_total > b_short]
    return short, long_


def plan_fleet(
    trace_name: str,
    requests: Sequence[Request],
    timing: TimingModel,
    rate: float,
    *,
    b_short: int = 8192,
    c_homo: int = 65_536,
    homo_slots: int = 16,
    short_max_slots: int = 128,
    kv_block_budget_mult: float = 1.0,
) -> FleetPlan:
    """Analytical Table-2 computation for one trace and threshold.

    ``kv_block_budget_mult`` scales the KV block budget (e.g. 2.0 for an
    int8 KV cache, whose bytes/token halve).
    """
    from repro.core.pools import TOTAL_KV_BLOCKS

    homo_pool = PoolConfig(
        name="homogeneous",
        c_max=c_homo,
        n_seq=homo_slots,
        headroom=HEADROOM["homogeneous"],
    )
    short_cfg = PoolConfig(
        name="short",
        c_max=max(b_short, 1),
        n_seq=n_seq_for_cmax(
            b_short,
            max_slots=short_max_slots,
            total_blocks=int(TOTAL_KV_BLOCKS * kv_block_budget_mult),
        ),
        headroom=HEADROOM["short"],
    )
    long_cfg = PoolConfig(
        name="long",
        c_max=c_homo,
        n_seq=homo_slots,
        headroom=HEADROOM["long"],
    )

    short_reqs, long_reqs = split_by_budget(requests, b_short)
    return FleetPlan(
        trace=trace_name,
        rate=rate,
        b_short=b_short,
        homogeneous=profile_pool(
            "homogeneous", requests, requests, homo_pool, timing, rate
        ),
        short=profile_pool("short", requests, short_reqs, short_cfg, timing, rate),
        long=profile_pool("long", requests, long_reqs, long_cfg, timing, rate),
    )


def sensitivity_sweep(
    trace_name: str,
    requests: Sequence[Request],
    timing: TimingModel,
    rate: float,
    thresholds: Sequence[int] = (2048, 4096, 8192, 16384, 32768),
) -> list[FleetPlan]:
    """Figure 6: savings vs B_short, with N_seq(B_short) from the block budget."""
    return [
        plan_fleet(trace_name, requests, timing, rate, b_short=b)
        for b in thresholds
    ]
