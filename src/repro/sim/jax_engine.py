"""JIT-compiled fleet backend (``backend="jax"``) + vmapped sensitivity grids.

The third simulator tier. The reference engine (:mod:`repro.sim.engine`)
is one Python object per sequence; the vectorized engine
(:mod:`repro.sim.vector_engine`) is masked NumPy over ``(instances,
n_seq)`` arrays with a Python event loop. This module compiles the *whole*
event loop — admission, decode k-jumps, completion, truncation, AND the
order-free batch preemption pass — into one ``lax.while_loop`` body, so an
entire fleet run is a single XLA executable with no host round-trips. That
buys the thing neither host tier can do: ``jax.vmap`` over the loop turns a
16–256-point sensitivity sweep (thresholds × fleet sizes × controller
gains) into one batched device program (:func:`run_fleet_grid`).

Simulation semantics
--------------------
Identical to the host backends at ``coalesce_dt=0`` (per-arrival sync):

* fixed-shape per-pool slot state ``(I, S)`` carried through the loop;
* head-of-line FIFO admission with KV-block reservation, as an inner
  fixpoint ``while_loop`` (one admission wave per iteration — instances
  are independent, so wave order equals the host's per-instance order);
* event-distance k-jumps with the same integer/float formulas and the
  same IEEE-754 op order as ``VectorPoolSim._round`` (times are float64
  — the entry points run under ``jax.experimental.enable_x64``);
* the shared order-free batch preemption rule (advance → truncate →
  completion credit → evict the minimal youngest-first prefix of decoding
  survivors → allocate growth) as a ``lexsort`` + ``cumsum`` +
  ``jnp.where`` victim-selection pass — the same pass the NumPy engine
  runs, so routerless single-pool runs are *bit-identical* to both host
  backends (asserted by ``tests/test_vector_engine.py``).

FIFO queues are request-indexed linked lists (``q_next[rid]`` + per
instance head/tail); preempted sequences go to a bounded per-instance
victim stash that the admission loop drains before the FIFO (capacity
``n_seq`` suffices: FIFO admits only while the stash is empty, so
``n_active + stash ≤ n_seq`` is invariant).

Routing, calibration, and control
---------------------------------
* **Routing** is fused into the dispatch branch as a ``searchsorted``
  against the *carried* threshold vector — honest under threshold /
  controller vmap axes. Per-request budgets are precomputed on the host
  by folding the byte-length observation stream through the cached
  EMA kernels (:func:`precompute_budget_trajectory`) in arrival order
  with the same ramped epoch schedule the vectorized backend uses.
  Approximations vs the host routed path (documented, tolerance-class):
  feedback folds arrival-ordered trace observations instead of
  completion-ordered ones, and load-dependent spillover is off (static
  N-way + hard-constraint clamp only).
* **Adaptive control** mirrors :class:`repro.core.adaptive.AdaptiveController`
  in-step: the same AIMD decision rule, constants, and strict-ordering
  clamp run inside the compiled dispatch branch on the same
  dispatched-request windows, so controller *gains* can be a vmap axis.
* **Telemetry** is collected as per-window device snapshots (queue depth,
  active, KV-free, cumulative error counters, thresholds) and replayed
  into the host :class:`repro.obs.timeseries.FleetTelemetry` after the
  run — same windows, same columns; per-window calibration-error series
  use the final EMA state (device runs don't carry the float EMA).

When to prefer which tier: ``reference`` for unit-level ground truth;
``vectorized`` for one-off large host runs with faults / spillover /
event tracing; ``jax`` for grid sweeps and controller tuning where
compile time amortizes over many lanes. Fault injection is not supported
on this backend (``FleetSim`` raises).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import enable_x64

from repro.core.adaptive import (
    BoundaryMove,
    DEFAULT_DECREASE_FACTOR,
    DEFAULT_ERROR_RATE_HI,
    DEFAULT_INCREASE_STEP,
    DEFAULT_OVERLOAD_RATIO_HI,
)
from repro.core.calibration import (
    EmaCalibrator,
    jax_estimate_budget,
    jax_update_stream,
)
from repro.core.pools import KV_BLOCK_TOKENS, PoolConfig, TOTAL_KV_BLOCKS
from repro.sim.engine import _blocks_for
from repro.sim.timing import TimingModel
from repro.traces.generator import TraceColumns

#: Sentinels for "no constraint" in masked min-reductions (int32-safe).
_BIG_I = 1 << 30
_BIG_F = 1.0e18


# ---------------------------------------------------------------------------
# Static compile-time description
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _PoolSpec:
    """Static shape/capacity facts for one pool (hashable → jit cache key)."""

    name: str
    c_max: int
    n_seq: int
    total_blocks: int
    max_inst: int  # array dimension I (≥ every lane's instance count)


@dataclasses.dataclass(frozen=True)
class _SimSpec:
    pools: tuple[_PoolSpec, ...]
    w: float  # roofline W (seconds)
    h: float  # roofline H (seconds)
    prefill_chunk: int
    win_size: int  # monitoring window in dispatched requests; 0 = off


def _pool_spec(name: str, cfg: PoolConfig, max_inst: int) -> _PoolSpec:
    total = min(TOTAL_KV_BLOCKS, cfg.n_seq * _blocks_for(cfg.c_max))
    return _PoolSpec(
        name=name,
        c_max=int(cfg.c_max),
        n_seq=int(cfg.n_seq),
        total_blocks=int(total),
        max_inst=int(max_inst),
    )


# ---------------------------------------------------------------------------
# The compiled core
# ---------------------------------------------------------------------------


def _make_core(spec: _SimSpec, n: int, return_records: bool):
    """Build the single-lane simulation function for one (spec, n).

    Returned function signature: ``core(trace, lane) -> dict`` where
    ``trace`` holds shared arrival-ordered arrays and ``lane`` the
    per-lane (vmappable) parameters. Must be traced/executed inside an
    ``enable_x64()`` context — event times are float64 accumulations.
    """
    P = len(spec.pools)
    win = spec.win_size
    win_cap = (n // win + 2) if win > 0 else 1
    nb = max(P - 1, 1)  # threshold-column width (≥1 keeps shapes non-empty)
    i32 = jnp.int32
    f64 = jnp.float64
    W = np.float64(spec.w)
    H = np.float64(spec.h)
    CHUNK = spec.prefill_chunk

    def blocks_for(tok):
        return jnp.maximum(1, (tok + (KV_BLOCK_TOKENS - 1)) // KV_BLOCK_TOKENS)

    def init_pool(ps: _PoolSpec):
        I, S = ps.max_inst, ps.n_seq
        z2 = jnp.zeros((I, S), i32)
        return {
            "occ": jnp.zeros((I, S), bool),
            "rid": jnp.full((I, S), -1, i32),
            "enq": jnp.zeros((I, S), f64),
            "inp": z2,
            "outp": z2,
            "pre": z2,
            "rem": z2,
            "gen": z2,
            "blk": z2,
            "ft": jnp.full((I, S), jnp.nan, f64),
            "tr": jnp.zeros((I, S), bool),
            "pc": z2,
            "sq": z2,
            "free": jnp.full((I,), ps.total_blocks, i32),
            "wake": jnp.full((I,), jnp.inf, f64),
            "nact": jnp.zeros((I,), i32),
            "qlen": jnp.zeros((I,), i32),
            "load": jnp.zeros((I,), i32),
            "qh": jnp.full((I,), -1, i32),
            "qt": jnp.full((I,), -1, i32),
            "qnext": jnp.full((n + 1,), -1, i32),
            "vrid": jnp.zeros((I, S), i32),
            "vinp": jnp.zeros((I, S), i32),
            "vpc": jnp.zeros((I, S), i32),
            "vcnt": jnp.zeros((I,), i32),
            "sqc": jnp.asarray(0, i32),
            "npre": jnp.asarray(0, i32),
            "nrej": jnp.asarray(0, i32),
            "ntr": jnp.asarray(0, i32),
        }

    def pool_errors(pools_):
        return jnp.stack([p["npre"] + p["nrej"] + p["ntr"] for p in pools_])

    def wake_min_all(pools_):
        return functools.reduce(
            jnp.minimum, [jnp.min(p["wake"]) for p in pools_]
        )

    def core(trace, lane):
        arr_t = trace["arr"]
        inp_t = trace["inp"]
        out_t = trace["outp"]
        bud_t = trace["budget"]
        ctrl = lane["ctrl"]

        # ---- monitoring window + in-step AIMD controller ------------------
        def window_step(c, now_t):
            fire = (c["win_seen"] - c["win_prev"]) >= win
            cur = pool_errors(c["pools"])
            delta = cur - c["prev_err"]
            wr = c["win_seen"] - c["win_prev"]
            queues = jnp.stack([jnp.sum(p["qlen"], dtype=i32) for p in c["pools"]])
            pressure = queues.astype(jnp.float32) / jnp.maximum(
                1, lane["ninst"]
            ).astype(jnp.float32)
            old = c["th"]
            moved = jnp.asarray(False)
            th = old
            if P > 1:
                # AIMD per boundary — the exact decision rule and constants
                # of AdaptiveController._aimd_move / update().
                wrf = jnp.maximum(wr, 1).astype(jnp.float32)
                props = []
                for k in range(P - 1):
                    err_rate = delta[k].astype(jnp.float32) / wrf
                    p_lo, p_hi = pressure[k], pressure[k + 1]
                    dec = (err_rate > ctrl["err_hi"]) | (
                        (p_lo > ctrl["over_hi"] * jnp.maximum(p_hi, 0.25))
                        & (p_lo > 1.0)
                    )
                    inc = (~dec) & (p_hi < 0.25) & (p_lo < 1.0)
                    down = (
                        old[k].astype(jnp.float32) * ctrl["factor"]
                    ).astype(i32)
                    props.append(
                        jnp.where(
                            dec, down, jnp.where(inc, old[k] + ctrl["step"], old[k])
                        )
                    )
                # Feasibility projection: forward pass with a running lower
                # bound; degenerate case falls back to the old vector.
                lo = ctrl["b_min"]
                feasible = jnp.asarray(True)
                newv = []
                for k in range(P - 1):
                    cap = spec.pools[k].c_max
                    feasible = feasible & (lo <= cap)
                    nk = jnp.minimum(jnp.maximum(props[k], lo), cap)
                    newv.append(nk)
                    lo = nk + 1
                newv = jnp.where(feasible, jnp.stack(newv), old)
                apply = fire & (ctrl["enabled"] > 0) & (wr > 0)
                th = jnp.where(apply, newv, old)
                moved = apply & jnp.any(newv != old)

            # Device telemetry snapshot (post-controller thresholds, same
            # ordering as the host's _window_step).
            wn = c["win"]
            wdx = jnp.minimum(c["wi"], win_cap - 1)

            def put(name, val):
                return wn[name].at[wdx].set(
                    jnp.where(fire, val, wn[name][wdx])
                )

            th_row = th if P > 1 else jnp.zeros((nb,), i32)
            wn = {
                "t_req": put("t_req", c["win_seen"]),
                "now": put("now", now_t),
                "th": put("th", th_row),
                "queue": put("queue", queues),
                "active": put(
                    "active", jnp.stack([jnp.sum(p["nact"], dtype=i32) for p in c["pools"]])
                ),
                "freeb": put(
                    "freeb", jnp.stack([jnp.sum(p["free"], dtype=i32) for p in c["pools"]])
                ),
                "pre": put("pre", jnp.stack([p["npre"] for p in c["pools"]])),
                "rej": put("rej", jnp.stack([p["nrej"] for p in c["pools"]])),
                "trunc": put("trunc", jnp.stack([p["ntr"] for p in c["pools"]])),
            }
            return {
                **c,
                "th": th,
                "prev_err": jnp.where(fire, cur, c["prev_err"]),
                "win_prev": jnp.where(fire, c["win_seen"], c["win_prev"]),
                "wi": c["wi"] + jnp.where(fire, 1, 0),
                "moves": c["moves"] + jnp.where(moved, 1, 0),
                "win": wn,
            }

        # ---- dispatch one arrival -----------------------------------------
        def dispatch(c):
            a = c["a"]
            ai = jnp.minimum(a, n - 1)
            t = arr_t[ai]
            pidx = jnp.searchsorted(
                c["th"][: P - 1], bud_t[ai], side="left"
            ).astype(i32)
            rec = c["rec"]
            rec = {**rec, "pool": rec["pool"].at[ai].set(pidx)}
            pools_ = list(c["pools"])
            for p in range(P):
                ps = spec.pools[p]
                st = pools_[p]
                sel = pidx == p
                alive = jnp.arange(ps.max_inst) < lane["ninst"][p]
                i = jnp.argmin(jnp.where(alive, st["load"], _BIG_I))
                rej = inp_t[ai] >= ps.c_max
                # Submit-time rejection: prompt alone exceeds C_max.
                ridx = jnp.where(sel & rej, ai, n)
                rec = {
                    **rec,
                    "first": rec["first"].at[ridx].set(t),
                    "finish": rec["finish"].at[ridx].set(t),
                    "rej": rec["rej"].at[ridx].set(True),
                }
                ok = sel & ~rej
                qh_i = st["qh"][i]
                was_empty = qh_i < 0
                qnext = st["qnext"].at[jnp.where(ok, ai, n)].set(-1)
                qnext = qnext.at[
                    jnp.where(ok & ~was_empty, st["qt"][i], n)
                ].set(ai.astype(i32))
                pools_[p] = {
                    **st,
                    "qnext": qnext,
                    "qh": st["qh"].at[i].set(
                        jnp.where(ok & was_empty, ai.astype(i32), qh_i)
                    ),
                    "qt": st["qt"].at[i].set(
                        jnp.where(ok, ai.astype(i32), st["qt"][i])
                    ),
                    "qlen": st["qlen"].at[i].add(jnp.where(ok, 1, 0)),
                    "load": st["load"].at[i].add(jnp.where(ok, 1, 0)),
                    "wake": st["wake"].at[i].set(
                        jnp.where(
                            ok & jnp.isinf(st["wake"][i]), t, st["wake"][i]
                        )
                    ),
                    "nrej": st["nrej"] + jnp.where(sel & rej, 1, 0),
                }
            c = {
                **c,
                "a": a + 1,
                "pools": tuple(pools_),
                "rec": rec,
                "win_seen": c["win_seen"] + 1,
            }
            if win > 0:
                c = window_step(c, t)
            return c

        # ---- one masked round for one pool --------------------------------
        def pool_round(p, st, rec, t_limit):
            ps = spec.pools[p]
            I, S = ps.max_inst, ps.n_seq
            rows = jnp.arange(I)
            due = st["wake"] < t_limit

            # Admission fixpoint: one wave admits/rejects at most one head
            # per due instance; loops until no instance can make progress.
            # (Instances are independent, so wave order ≡ the host's
            # per-instance sequential admission.)
            def adm_masks(st_):
                stash = st_["vcnt"] > 0
                hrid = jnp.where(stash, st_["vrid"][:, 0], st_["qh"])
                has = due & (stash | (st_["qh"] >= 0))
                hc = jnp.clip(hrid, 0, n - 1)
                hinp = jnp.where(stash, st_["vinp"][:, 0], inp_t[hc])
                hpc = jnp.where(stash, st_["vpc"][:, 0], 0)
                need = blocks_for(hinp)
                can = st_["nact"] < S
                rejm = has & can & (need > ps.total_blocks)
                admm = has & can & ~rejm & (need <= st_["free"])
                return stash, hrid, hc, hinp, hpc, need, rejm, admm

            def adm_cond(val):
                st_, _ = val
                *_, rejm, admm = adm_masks(st_)
                return jnp.any(rejm | admm)

            def adm_body(val):
                st_, rec_ = val
                stash, hrid, hc, hinp, hpc, need, rejm, admm = adm_masks(st_)
                prog = rejm | admm
                # pop the head (victim stash first — head-of-line order)
                pop_st = prog & stash
                pop_f = prog & ~stash

                def shiftl(arr2):
                    return jnp.concatenate(
                        [arr2[:, 1:], arr2[:, :1]], axis=1
                    )

                vrid = jnp.where(pop_st[:, None], shiftl(st_["vrid"]), st_["vrid"])
                vinp = jnp.where(pop_st[:, None], shiftl(st_["vinp"]), st_["vinp"])
                vpc = jnp.where(pop_st[:, None], shiftl(st_["vpc"]), st_["vpc"])
                nxt = st_["qnext"][jnp.clip(st_["qh"], 0, n)]
                qh = jnp.where(pop_f, nxt, st_["qh"])
                qt = jnp.where(pop_f & (nxt < 0), -1, st_["qt"])
                # admission-reject record at now = wake (host: add_one with
                # first = finish = now, zero output/preemptions)
                ridx = jnp.where(rejm, hc, n)
                rec_ = {
                    **rec_,
                    "first": rec_["first"].at[ridx].set(st_["wake"]),
                    "finish": rec_["finish"].at[ridx].set(st_["wake"]),
                    "rej": rec_["rej"].at[ridx].set(True),
                }
                # admit into the first free slot (argmin over occupied —
                # the host's np.argmin tie-break)
                slot = jnp.argmin(st_["occ"], axis=1)
                base = st_["sqc"]
                rank = (jnp.cumsum(admm) - admm).astype(i32)

                def w2(arr2, val):
                    return arr2.at[rows, slot].set(
                        jnp.where(admm, val, arr2[rows, slot])
                    )

                return (
                    {
                        **st_,
                        "vrid": vrid,
                        "vinp": vinp,
                        "vpc": vpc,
                        "vcnt": st_["vcnt"] - pop_st,
                        "qh": qh,
                        "qt": qt,
                        "qlen": st_["qlen"] - prog,
                        "load": st_["load"] - rejm,
                        "nrej": st_["nrej"] + jnp.sum(rejm, dtype=i32),
                        "occ": w2(st_["occ"], True),
                        "rid": w2(st_["rid"], hrid),
                        "enq": w2(st_["enq"], arr_t[hc]),
                        "inp": w2(st_["inp"], hinp),
                        "outp": w2(st_["outp"], out_t[hc]),
                        "pre": w2(st_["pre"], hinp),
                        "rem": w2(st_["rem"], out_t[hc]),
                        "gen": w2(st_["gen"], 0),
                        "blk": w2(st_["blk"], need),
                        "ft": w2(st_["ft"], jnp.nan),
                        "tr": w2(st_["tr"], False),
                        "pc": w2(st_["pc"], hpc),
                        "sq": w2(st_["sq"], base + rank),
                        "sqc": base + jnp.sum(admm, dtype=i32),
                        "free": st_["free"] - jnp.where(admm, need, 0),
                        "nact": st_["nact"] + admm,
                    },
                    rec_,
                )

            st, rec = lax.while_loop(adm_cond, adm_body, (st, rec))

            nact = st["nact"]
            busy = due & (nact > 0)
            idle = due & ~busy
            wake_idle = jnp.where(
                idle,
                jnp.where(st["qlen"] > 0, st["wake"] + 1e-9, jnp.inf),
                st["wake"],
            )
            now = jnp.where(busy, st["wake"], 0.0)
            t_it = W + H * nact.astype(f64)
            bb = busy[:, None]
            occ = st["occ"]

            # one prefill chunk to the oldest prefilling sequence
            pmask = occ & (st["pre"] > 0)
            has_pre = pmask.any(axis=1) & busy
            oldest = jnp.argmin(jnp.where(pmask, st["sq"], _BIG_I), axis=1)
            take = jnp.minimum(st["pre"][rows, oldest], CHUNK)
            pre_arr = st["pre"].at[rows, oldest].add(
                jnp.where(has_pre, -take, 0)
            )

            # event-distance k-jump (identical formulas to the host round)
            dec = occ & (pre_arr == 0) & (st["rem"] > 0)
            inp2, gen0, rem0, blk0 = st["inp"], st["gen"], st["rem"], st["blk"]
            ctx0 = inp2 + gen0
            k_complete = jnp.min(jnp.where(dec, rem0, _BIG_I), axis=1)
            k_trunc = jnp.min(jnp.where(dec, ps.c_max - ctx0, _BIG_I), axis=1)
            q = (t_limit - now) / t_it
            k_time = jnp.where(jnp.isfinite(q), jnp.ceil(q - 1e-9), _BIG_F)
            k = jnp.minimum(
                jnp.minimum(k_complete, k_trunc).astype(f64), k_time
            )
            k = jnp.where(has_pre, 1.0, jnp.maximum(k, 1.0))
            k = jnp.minimum(k, float(_BIG_I)).astype(i32)

            def growth(kk):
                ng = gen0 + jnp.where(dec, kk[:, None], 0)
                nd = jnp.where(occ, blocks_for(inp2 + ng), 0)
                return jnp.maximum(nd - blk0, 0).sum(axis=1, dtype=i32)

            over = busy & (growth(k) > st["free"])
            k = jnp.where(over, 1, k)
            end = now + k.astype(f64) * t_it

            # unified decode pass — the order-free batch preemption rule
            kcol = jnp.where(dec, k[:, None], 0)
            gen_a = gen0 + kcol
            rem_a = rem0 - kcol
            ft_a = jnp.where(
                dec & jnp.isnan(st["ft"]), (now + t_it)[:, None], st["ft"]
            )
            trunc_n = dec & (inp2 + gen_a >= ps.c_max) & (rem_a > 0) & bb
            rem_a = jnp.where(trunc_n, 0, rem_a)
            tr_a = st["tr"] | trunc_n
            ntr = st["ntr"] + jnp.sum(trunc_n, dtype=i32)

            comp = dec & (rem_a == 0) & bb
            ridx = jnp.where(comp, st["rid"], n)
            rec = {
                **rec,
                "first": rec["first"].at[ridx].set(ft_a),
                "finish": rec["finish"].at[ridx].set(
                    jnp.broadcast_to(end[:, None], (I, S))
                ),
                "out": rec["out"].at[ridx].set(gen_a),
                "pre": rec["pre"].at[ridx].set(st["pc"]),
                "trunc": rec["trunc"].at[ridx].set(tr_a),
            }
            free1 = st["free"] + jnp.sum(jnp.where(comp, blk0, 0), axis=1, dtype=i32)
            ncomp = jnp.sum(comp, axis=1, dtype=i32)

            surv = dec & (rem_a > 0) & bb
            need_s = jnp.where(surv, blocks_for(inp2 + gen_a), blk0)
            grow = jnp.where(surv, need_s - blk0, 0)
            demand = grow.sum(axis=1, dtype=i32)
            keyq = jnp.where(surv, -st["enq"], jnp.inf)
            order = jnp.lexsort((st["sq"], keyq), axis=1)
            sblk = jnp.take_along_axis(
                jnp.where(surv, blk0, 0), order, axis=1
            )
            sgrow = jnp.take_along_axis(grow, order, axis=1)
            okj = demand[:, None] - jnp.cumsum(sgrow, axis=1) <= (
                free1[:, None] + jnp.cumsum(sblk, axis=1)
            )
            jsel = jnp.where(
                demand <= free1, 0, jnp.argmax(okj, axis=1) + 1
            )
            inv = jnp.argsort(order, axis=1)  # inverse permutation = rank
            evict = (inv < jsel[:, None]) & surv
            npre = st["npre"] + jnp.sum(evict, dtype=i32)
            free1 = free1 + jnp.sum(jnp.where(evict, blk0, 0), axis=1, dtype=i32)
            nevict = jnp.sum(evict, axis=1, dtype=i32)

            # victims → stash, in admission (seq_no) order, ahead of the
            # previous stash (requeue-at-head semantics)
            gord = jnp.argsort(jnp.where(evict, st["sq"], _BIG_I), axis=1)
            g_rid = jnp.take_along_axis(st["rid"], gord, axis=1)
            g_inp = jnp.take_along_axis(inp2 + gen_a, gord, axis=1)
            g_pc = jnp.take_along_axis(st["pc"] + 1, gord, axis=1)
            rr = jnp.arange(S)[None, :]
            in_new = rr < nevict[:, None]
            old_idx = jnp.clip(rr - nevict[:, None], 0, S - 1)
            vrid = jnp.where(
                in_new, g_rid, jnp.take_along_axis(st["vrid"], old_idx, axis=1)
            )
            vinp = jnp.where(
                in_new, g_inp, jnp.take_along_axis(st["vinp"], old_idx, axis=1)
            )
            vpc = jnp.where(
                in_new, g_pc, jnp.take_along_axis(st["vpc"], old_idx, axis=1)
            )

            keep = surv & ~evict
            free1 = free1 - jnp.sum(jnp.where(keep, grow, 0), axis=1, dtype=i32)
            cleared = comp | evict
            nact_a = nact - ncomp - nevict
            qlen_a = st["qlen"] + nevict
            alive_r = (nact_a > 0) | (qlen_a > 0)

            st = {
                **st,
                "occ": jnp.where(bb, occ & ~cleared, occ),
                "pre": pre_arr,
                "rem": jnp.where(bb, rem_a, rem0),
                "gen": jnp.where(bb, gen_a, gen0),
                "blk": jnp.where(
                    bb, jnp.where(cleared, 0, jnp.where(keep, need_s, blk0)), blk0
                ),
                "ft": jnp.where(bb, ft_a, st["ft"]),
                "tr": jnp.where(bb, tr_a, st["tr"]),
                "vrid": jnp.where(bb, vrid, st["vrid"]),
                "vinp": jnp.where(bb, vinp, st["vinp"]),
                "vpc": jnp.where(bb, vpc, st["vpc"]),
                "vcnt": jnp.where(busy, st["vcnt"] + nevict, st["vcnt"]),
                "free": jnp.where(busy, free1, st["free"]),
                "nact": jnp.where(busy, nact_a, nact),
                "qlen": jnp.where(busy, qlen_a, st["qlen"]),
                "load": jnp.where(busy, st["load"] - ncomp, st["load"]),
                "wake": jnp.where(
                    busy, jnp.where(alive_r, end, jnp.inf), wake_idle
                ),
                "npre": npre,
                "ntr": ntr,
            }
            return st, rec

        def round_(c, t_limit):
            pools_ = list(c["pools"])
            rec = c["rec"]
            for p in range(P):
                pools_[p], rec = pool_round(p, pools_[p], rec, t_limit)
            return {**c, "pools": tuple(pools_), "rec": rec}

        # ---- outer event loop ---------------------------------------------
        def next_arr(c):
            return jnp.where(
                c["a"] < n, arr_t[jnp.minimum(c["a"], n - 1)], jnp.inf
            )

        def cond_fn(c):
            return (c["a"] < n) | jnp.isfinite(wake_min_all(c["pools"]))

        # Arrival-first tie-break: dispatch while t_arr ≤ every wake
        # (matches the host heap's ``next_arrival <= next_event``). The
        # arrival drain is its own inner while_loop rather than one arm of
        # a lax.cond: vmapped cond lowers to select and would execute the
        # expensive round body once per *arrival* across every lane — the
        # split keeps the grid's per-iteration cost at dispatch cost while
        # draining and pays for a round only when an instance is due.
        def disp_cond(c):
            return (c["a"] < n) & (
                next_arr(c) <= wake_min_all(c["pools"])
            )

        def body_fn(c):
            c = lax.while_loop(disp_cond, dispatch, c)
            return round_(c, next_arr(c))

        c0 = {
            "a": jnp.asarray(0, i32),
            "pools": tuple(init_pool(ps) for ps in spec.pools),
            "rec": {
                "first": jnp.zeros((n + 1,), f64),
                "finish": jnp.zeros((n + 1,), f64),
                "out": jnp.zeros((n + 1,), i32),
                "pre": jnp.zeros((n + 1,), i32),
                "trunc": jnp.zeros((n + 1,), bool),
                "rej": jnp.zeros((n + 1,), bool),
                "pool": jnp.zeros((n + 1,), i32),
            },
            "th": lane["th"],
            "prev_err": jnp.zeros((P,), i32),
            "win_seen": jnp.asarray(0, i32),
            "win_prev": jnp.asarray(0, i32),
            "wi": jnp.asarray(0, i32),
            "moves": jnp.asarray(0, i32),
            "win": {
                "t_req": jnp.zeros((win_cap,), i32),
                "now": jnp.zeros((win_cap,), f64),
                "th": jnp.zeros((win_cap, nb), i32),
                "queue": jnp.zeros((win_cap, P), i32),
                "active": jnp.zeros((win_cap, P), i32),
                "freeb": jnp.zeros((win_cap, P), i32),
                "pre": jnp.zeros((win_cap, P), i32),
                "rej": jnp.zeros((win_cap, P), i32),
                "trunc": jnp.zeros((win_cap, P), i32),
            },
        }
        c = lax.while_loop(cond_fn, body_fn, c0)

        rec = {k: v[:n] for k, v in c["rec"].items()}
        compm = ~rec["rej"]
        ttft = jnp.where(compm, rec["first"] - arr_t, jnp.nan)
        tpot = jnp.where(
            compm & (rec["out"] > 1),
            (rec["finish"] - rec["first"]) / jnp.maximum(rec["out"] - 1, 1),
            jnp.nan,
        )
        out = {
            "metrics": {
                "completed": jnp.sum(compm),
                "rejected": jnp.sum(rec["rej"]),
                "truncated": jnp.sum(rec["trunc"]),
                "routed": jnp.stack(
                    [jnp.sum(rec["pool"] == p) for p in range(P)]
                ),
                "ttft_mean": jnp.nanmean(ttft),
                "ttft_p50": jnp.nanpercentile(ttft, 50),
                "ttft_p99": jnp.nanpercentile(ttft, 99),
                "tpot_mean": jnp.nanmean(tpot),
                "tpot_p99": jnp.nanpercentile(tpot, 99),
                "t_end": jnp.max(rec["finish"]),
                "makespan": jnp.max(rec["finish"]) - jnp.min(arr_t),
            },
            "preempt": jnp.stack([p["npre"] for p in c["pools"]]),
            "reject": jnp.stack([p["nrej"] for p in c["pools"]]),
            "truncate": jnp.stack([p["ntr"] for p in c["pools"]]),
            "th": c["th"],
            "moves": c["moves"],
            "nwin": c["wi"],
            "win": c["win"],
        }
        if return_records:
            out["rec"] = rec
        return out

    return core


@functools.lru_cache(maxsize=None)
def _runner(spec: _SimSpec, n: int, return_records: bool, grid: bool):
    """Cached jitted simulation, specialized per (spec, n, outputs, vmap)."""
    core = _make_core(spec, n, return_records)
    fn = jax.vmap(core, in_axes=(None, 0)) if grid else core
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# Host-side routing precompute
# ---------------------------------------------------------------------------


def precompute_budget_trajectory(
    cols: TraceColumns,
    calibrator: EmaCalibrator,
    *,
    epoch_cap: int,
):
    """Per-request estimated budgets with epoch-lagged EMA feedback.

    Mirrors the vectorized backend's ramped routing epochs (64 doubling to
    ``epoch_cap``): requests in one epoch route with the EMA state as of
    the epoch start, then the epoch's observations fold in through the
    cached ``lax.scan`` kernel. The device loop then only needs a
    ``searchsorted`` per dispatch — thresholds stay honest vmap axes while
    the float EMA never enters the compiled loop. Approximation vs the
    host: observations fold in *arrival* order (host folds completions),
    which the routed-tolerance test class bounds.

    Returns ``(budgets int32 (n,), final CalibState)``.
    """
    n = len(cols)
    budgets = np.zeros(n, dtype=np.int32)
    state = calibrator.to_state()
    gamma = float(calibrator.gamma)
    beta = float(calibrator.beta)
    chunk = min(64, epoch_cap)
    pos = 0
    while pos < n:
        start = pos
        pos = min(n, pos + chunk)
        chunk = min(epoch_cap, chunk * 2)
        cat = jnp.asarray(cols.category[start:pos], jnp.int32)
        budgets[start:pos] = np.asarray(
            jax_estimate_budget(
                state,
                jnp.asarray(cols.byte_len[start:pos]),
                jnp.asarray(cols.max_output_tokens[start:pos]),
                cat,
                gamma=gamma,
            )
        )
        state = jax_update_stream(
            state,
            jnp.asarray(cols.byte_len[start:pos], jnp.float32),
            jnp.asarray(cols.true_input_tokens[start:pos], jnp.float32),
            cat,
            beta=beta,
        )
    return budgets, state


def _trace_arrays(cols: TraceColumns, budgets: Optional[np.ndarray]):
    n = len(cols)
    return {
        "arr": np.asarray(cols.arrival_time, np.float64),
        "inp": np.asarray(cols.true_input_tokens, np.int32),
        "outp": np.asarray(cols.true_output_tokens, np.int32),
        "budget": (
            np.zeros(n, np.int32) if budgets is None else budgets
        ),
    }


def _ctrl_params(controller, enabled: bool):
    """Controller gains as a traced scalar dict (a vmappable lane axis)."""
    if controller is None:
        return {
            "enabled": np.int32(0),
            "b_min": np.int32(512),
            "step": np.int32(DEFAULT_INCREASE_STEP),
            "factor": np.float32(DEFAULT_DECREASE_FACTOR),
            "err_hi": np.float32(DEFAULT_ERROR_RATE_HI),
            "over_hi": np.float32(DEFAULT_OVERLOAD_RATIO_HI),
        }
    return {
        "enabled": np.int32(1 if enabled else 0),
        "b_min": np.int32(controller.b_min),
        "step": np.int32(controller.increase_step),
        "factor": np.float32(controller.decrease_factor),
        "err_hi": np.float32(controller.error_rate_hi),
        "over_hi": np.float32(controller.overload_ratio_hi),
    }


# ---------------------------------------------------------------------------
# FleetSim backend entry (single lane)
# ---------------------------------------------------------------------------


def run_fleet_jax(fleet, trace):
    """Execute one fleet run on the compiled backend; returns FleetResult.

    Called by ``FleetSim.run`` for ``backend="jax"``. The fleet's
    ``VectorPoolSim`` shells receive the device-computed records and
    counters afterwards, so ``fleet.pools[name].record_arrays()``,
    telemetry replay, and ``router.stats()`` all behave like a host run.
    """
    # Import here: fleet imports this module lazily, and metrics/fleet
    # are imported lazily here, to keep the module graph acyclic.
    from repro.sim.fleet import FleetResult
    from repro.sim.metrics import summarize_columns

    cols = (
        trace
        if isinstance(trace, TraceColumns)
        else TraceColumns.from_requests(trace)
    ).sorted_by_arrival()
    n = len(cols)

    ordered = sorted(fleet._pool_index, key=fleet._pool_index.get)
    shells = [fleet.pools[name] for name in ordered]
    spec = _SimSpec(
        # Capacities come from the live shells (not recomputed from the
        # config) so post-construction total_blocks overrides are honored.
        pools=tuple(
            _PoolSpec(
                name=name,
                c_max=int(s.config.c_max),
                n_seq=int(s.config.n_seq),
                total_blocks=int(s.total_blocks),
                max_inst=int(s.num_instances),
            )
            for name, s in zip(ordered, shells)
        ),
        w=float(fleet.timing.w_base),
        h=float(fleet.timing.h_per_seq),
        prefill_chunk=int(fleet.timing.prefill_chunk),
        win_size=int(fleet._win_size),
    )
    P = len(spec.pools)

    router = fleet.router
    budgets = None
    if router is not None and n:
        epoch_cap = (
            fleet.epoch
            if fleet.controller is None
            else max(1, min(fleet.epoch, fleet.control_window))
        )
        budgets, final_state = precompute_budget_trajectory(
            cols, router.calibrator, epoch_cap=epoch_cap
        )
        router.calibrator.load_state(final_state)
        th0 = [int(b) for b in router.pools.thresholds]
    else:
        th0 = []

    lane = {
        "th": np.asarray(th0, np.int32),
        "ninst": np.asarray(
            [fleet.pools[name].num_instances for name in ordered], np.int32
        ),
        "ctrl": _ctrl_params(fleet.controller, enabled=True),
    }
    if self_telemetry := fleet.telemetry:
        self_telemetry.set_trace(
            cols.byte_len, cols.category, cols.true_input_tokens,
            cols.max_output_tokens,
        )

    if n == 0:
        empty = {k: np.empty(0, dt) for k, dt in (
            ("request_id", np.int64), ("arrival", np.float64),
            ("first_token", np.float64), ("finish", np.float64),
            ("output_tokens", np.int64), ("preemptions", np.int64),
            ("truncated", bool), ("rejected", bool),
        )}
        return FleetResult(
            summary=summarize_columns("fleet", empty),
            per_pool={name: summarize_columns(name, empty) for name in ordered},
            router_stats=router.stats() if router else {},
            preemptions=0, rejections=0, truncations=0,
            telemetry=fleet.telemetry, slo=fleet.slo,
        )

    with enable_x64():
        out = _runner(spec, n, True, False)(_trace_arrays(cols, budgets), lane)
        out = jax.tree_util.tree_map(np.asarray, out)

    rec = out["rec"]
    ids = np.asarray(cols.request_id, np.int64)
    arr = np.asarray(cols.arrival_time, np.float64)
    fleet_cols = {
        "request_id": ids,
        "arrival": arr,
        "first_token": rec["first"],
        "finish": rec["finish"],
        "output_tokens": rec["out"].astype(np.int64),
        "preemptions": rec["pre"].astype(np.int64),
        "truncated": rec["trunc"],
        "rejected": rec["rej"],
    }
    per_pool_cols = {}
    for idx, name in enumerate(ordered):
        m = rec["pool"] == idx
        pc = {k: v[m] for k, v in fleet_cols.items()}
        per_pool_cols[name] = pc
        shell = shells[idx]
        shell._records.add_bulk(*(pc[k] for k, _ in shell._records.COLUMNS))
        shell.preemption_count = int(out["preempt"][idx])
        shell.rejection_count = int(out["reject"][idx])
        shell.truncation_count = int(out["truncate"][idx])
        if router is not None:
            router.routed[name] += int(out["metrics"]["routed"][idx])

    final_th = [int(b) for b in out["th"][: P - 1]]
    if router is not None and fleet.controller is not None:
        router.pools.set_thresholds(final_th)
        _synthesize_history(fleet.controller, out, th0)

    t_end = float(out["metrics"]["t_end"])
    if fleet.telemetry is not None:
        _replay_telemetry(fleet, ordered, shells, spec, out, n, t_end, final_th)

    return FleetResult(
        summary=summarize_columns("fleet", fleet_cols),
        per_pool={
            name: summarize_columns(name, c)
            for name, c in per_pool_cols.items()
        },
        router_stats=router.stats() if router else {},
        preemptions=int(out["preempt"].sum()),
        rejections=int(out["reject"].sum()),
        truncations=int(out["truncate"].sum()),
        telemetry=fleet.telemetry,
        slo=fleet.slo,
    )


def _synthesize_history(controller, out, th0):
    """Rebuild a BoundaryMove trajectory from the device window snapshots.

    The device loop records the post-controller threshold vector at every
    window; diffing consecutive snapshots recovers when each boundary
    moved and to what value. The AIMD input signals are not re-derived —
    moves carry reason "device"."""
    nwin = int(out["nwin"])
    prev = list(th0)
    for w in range(nwin):
        cur = [int(b) for b in out["win"]["th"][w][: len(prev)]]
        for k, (a, b) in enumerate(zip(prev, cur)):
            if a != b:
                controller.history.append(
                    BoundaryMove(
                        t=int(out["win"]["t_req"][w]),
                        boundary=k,
                        value=b,
                        reason="device",
                    )
                )
        prev = cur


def _replay_telemetry(fleet, ordered, shells, spec, out, n, t_end, final_th):
    """Replay device window snapshots into the host FleetTelemetry.

    Same windows, same sampling order (controller's thresholds first,
    then the sample) as the host backends. Counter columns come from the
    device's cumulative per-pool counters; gauges (queue depth, active,
    kv_frac) from the snapshot state. The calibration-error series uses
    the final EMA state for every window (the device run does not carry
    the float EMA) — documented approximation."""
    telemetry = fleet.telemetry
    win = out["win"]
    nwin = int(out["nwin"])
    router = fleet.router
    prev_req = 0
    for name, shell in zip(ordered, shells):
        shell.blocks_free = np.zeros(shell.num_instances, dtype=np.int64)
    for w in range(nwin):
        for idx, shell in enumerate(shells):
            shell.preemption_count = int(win["pre"][w, idx])
            shell.rejection_count = int(win["rej"][w, idx])
            shell.truncation_count = int(win["trunc"][w, idx])
            shell.state.queue_depth = int(win["queue"][w, idx])
            shell.state.active = int(win["active"][w, idx])
            shell.blocks_free[:] = 0
            shell.blocks_free[0] = int(win["freeb"][w, idx])
        if router is not None and fleet.controller is not None:
            router.pools.set_thresholds(
                [int(b) for b in win["th"][w][: len(router.pools) - 1]]
            )
        t_req = int(win["t_req"][w])
        telemetry.sample(
            t_req=t_req, now=float(win["now"][w]), lo=prev_req, hi=t_req
        )
        prev_req = t_req
    # final flush (host _finish_windows): drained end state
    for idx, shell in enumerate(shells):
        shell.preemption_count = int(out["preempt"][idx])
        shell.rejection_count = int(out["reject"][idx])
        shell.truncation_count = int(out["truncate"][idx])
        shell.state.queue_depth = 0
        shell.state.active = 0
        shell.blocks_free[:] = spec.pools[idx].total_blocks
    if router is not None and fleet.controller is not None:
        router.pools.set_thresholds(final_th)
    telemetry.sample(t_req=n, now=t_end, lo=prev_req, hi=n)


# ---------------------------------------------------------------------------
# Vmapped sensitivity grids
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetGridResult:
    """Columnar results of one vmapped fleet sweep (G grid lanes).

    Per-lane reductions are computed on device over the *full* run (no
    warm-up discard — grid metrics are for relative comparisons across
    lanes; use a single-lane ``FleetSim`` run for paper-grade numbers).
    Percentiles are linear-interpolation (``jnp.nanpercentile``), not the
    nearest-rank convention of :func:`repro.sim.metrics.summarize`.
    """

    pool_names: tuple[str, ...]
    thresholds: np.ndarray  # (G, P-1) initial boundary vectors
    instances: np.ndarray  # (G, P) instance counts
    completed: np.ndarray  # (G,)
    rejected: np.ndarray  # (G,)
    truncated: np.ndarray  # (G,)
    preemptions: np.ndarray  # (G,) fleet total
    routed: np.ndarray  # (G, P) dispatches per pool
    ttft_mean: np.ndarray
    ttft_p50: np.ndarray
    ttft_p99: np.ndarray
    tpot_mean: np.ndarray
    tpot_p99: np.ndarray
    makespan: np.ndarray  # (G,) max finish − min arrival
    final_thresholds: np.ndarray  # (G, P-1) post-controller vectors
    controller_moves: np.ndarray  # (G,)
    #: (G, n) per-request record arrays when ``return_records=True``.
    records: Optional[dict] = None

    def __len__(self) -> int:
        return len(self.completed)

    def goodput(self) -> np.ndarray:
        """Completed non-truncated requests per second, per lane."""
        span = np.maximum(self.makespan, 1e-12)
        return (self.completed - self.truncated) / span


def _broadcast_axis(values, g: int, name: str):
    if len(values) == 1:
        return [values[0]] * g
    if len(values) != g:
        raise ValueError(
            f"grid axis {name!r} has length {len(values)}, expected 1 or {g}"
        )
    return list(values)


def run_fleet_grid(
    trace,
    pools: dict[str, tuple[PoolConfig, int]],
    timing: TimingModel,
    *,
    thresholds: Optional[Sequence[Sequence[int]]] = None,
    instances: Optional[Sequence[Sequence[int]]] = None,
    gains: Optional[Sequence[Optional[dict]]] = None,
    b_short: int = 8192,
    calibrator: Optional[EmaCalibrator] = None,
    epoch: int = 2048,
    control_window: int = 512,
    return_records: bool = False,
) -> FleetGridResult:
    """Run a whole sensitivity sweep as ONE vmapped device program.

    Grid axes (all optional, zip semantics — length G or 1, broadcast):

    ``thresholds``
        Sequence of boundary vectors (each length P−1, pool-budget order).
    ``instances``
        Sequence of per-pool instance-count vectors (length P). Lanes run
        padded to the max count with dead-lane masking, so mixed fleet
        sizes share one compiled program.
    ``gains``
        Sequence of AIMD controller parameter dicts (keys ``b_min``,
        ``increase_step``, ``decrease_factor``, ``error_rate_hi``,
        ``overload_ratio_hi`` — defaults from :mod:`repro.core.adaptive`),
        or ``None`` entries for uncontrolled lanes.

    Budgets are precomputed once on the host — the EMA feedback trajectory
    depends only on the observation stream, not on routing — so every lane
    shares the same budget array and the sweep stays exact w.r.t. the
    single-lane jax backend (asserted by the grid-parity test).
    """
    cols = (
        trace
        if isinstance(trace, TraceColumns)
        else TraceColumns.from_requests(trace)
    ).sorted_by_arrival()
    n = len(cols)
    if n == 0:
        raise ValueError("run_fleet_grid needs a non-empty trace")

    # Budget-ordered pool frame, like FleetSim.
    ordered = sorted(pools.items(), key=lambda kv: kv[1][0].c_max)
    names = tuple(name for name, _ in ordered)
    base_inst = [int(ni) for _, (_, ni) in ordered]
    configs = [cfg for _, (cfg, _) in ordered]
    P = len(ordered)

    if thresholds is None:
        if set(names) == {"short", "long"}:
            base_th = [min(b_short, configs[0].c_max)]
        else:
            base_th = [c.c_max for c in configs[:-1]]
        thresholds = [base_th]
    if instances is None:
        instances = [base_inst]
    if gains is None:
        gains = [None]

    g = max(len(thresholds), len(instances), len(gains))
    thresholds = _broadcast_axis(list(thresholds), g, "thresholds")
    instances = _broadcast_axis(list(instances), g, "instances")
    gains = _broadcast_axis(list(gains), g, "gains")

    th_arr = np.asarray(thresholds, np.int32).reshape(g, P - 1)
    inst_arr = np.asarray(instances, np.int32).reshape(g, P)
    any_ctrl = any(gn is not None for gn in gains)
    ctrl_rows = []
    for gn in gains:
        row = {
            "enabled": np.int32(0 if gn is None else 1),
            "b_min": np.int32((gn or {}).get("b_min", 512)),
            "step": np.int32(
                (gn or {}).get("increase_step", DEFAULT_INCREASE_STEP)
            ),
            "factor": np.float32(
                (gn or {}).get("decrease_factor", DEFAULT_DECREASE_FACTOR)
            ),
            "err_hi": np.float32(
                (gn or {}).get("error_rate_hi", DEFAULT_ERROR_RATE_HI)
            ),
            "over_hi": np.float32(
                (gn or {}).get("overload_ratio_hi", DEFAULT_OVERLOAD_RATIO_HI)
            ),
        }
        ctrl_rows.append(row)
    ctrl = {
        k: np.stack([r[k] for r in ctrl_rows]) for k in ctrl_rows[0]
    }

    spec = _SimSpec(
        pools=tuple(
            _pool_spec(name, cfg, int(inst_arr[:, j].max()))
            for j, (name, cfg) in enumerate(zip(names, configs))
        ),
        w=float(timing.w_base),
        h=float(timing.h_per_seq),
        prefill_chunk=int(timing.prefill_chunk),
        win_size=int(control_window) if any_ctrl else 0,
    )

    budgets = None
    if P > 1:
        cal = calibrator or EmaCalibrator()
        epoch_cap = (
            max(1, min(epoch, control_window)) if any_ctrl else epoch
        )
        budgets, _ = precompute_budget_trajectory(cols, cal, epoch_cap=epoch_cap)

    lane = {"th": th_arr, "ninst": inst_arr, "ctrl": ctrl}
    with enable_x64():
        out = _runner(spec, n, return_records, True)(
            _trace_arrays(cols, budgets), lane
        )
        out = jax.tree_util.tree_map(np.asarray, out)

    m = out["metrics"]
    return FleetGridResult(
        pool_names=names,
        thresholds=th_arr,
        instances=inst_arr,
        completed=m["completed"].astype(np.int64),
        rejected=m["rejected"].astype(np.int64),
        truncated=m["truncated"].astype(np.int64),
        preemptions=out["preempt"].sum(axis=1).astype(np.int64),
        routed=m["routed"].astype(np.int64),
        ttft_mean=m["ttft_mean"],
        ttft_p50=m["ttft_p50"],
        ttft_p99=m["ttft_p99"],
        tpot_mean=m["tpot_mean"],
        tpot_p99=m["tpot_p99"],
        makespan=m["makespan"],
        final_thresholds=out["th"].reshape(g, P - 1)[:, : P - 1],
        controller_moves=out["moves"].astype(np.int64),
        records=out.get("rec"),
    )
