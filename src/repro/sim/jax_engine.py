"""JIT-compiled fleet backend (``backend="jax"``) + vmapped sensitivity grids.

The third simulator tier. The reference engine (:mod:`repro.sim.engine`)
is one Python object per sequence; the vectorized engine
(:mod:`repro.sim.vector_engine`) is masked NumPy over ``(instances,
n_seq)`` arrays with a Python event loop. This module compiles the *whole*
event loop — admission, decode k-jumps, completion, truncation, AND the
order-free batch preemption pass — into one ``lax.while_loop`` body, so an
entire fleet run is a single XLA executable with no host round-trips. That
buys the thing neither host tier can do: ``jax.vmap`` over the loop turns a
16–256-point sensitivity sweep (thresholds × fleet sizes × controller
gains) into one batched device program (:func:`run_fleet_grid`).

Simulation semantics
--------------------
Identical to the host backends at ``coalesce_dt=0`` (per-arrival sync):

* fixed-shape per-pool slot state ``(I, S)`` carried through the loop;
* head-of-line FIFO admission with KV-block reservation, as an inner
  fixpoint ``while_loop`` (one admission wave per iteration — instances
  are independent, so wave order equals the host's per-instance order);
* event-distance k-jumps with the same integer/float formulas and the
  same IEEE-754 op order as ``VectorPoolSim._round`` (times are float64
  — the entry points run under ``jax.experimental.enable_x64``);
* the shared order-free batch preemption rule (advance → truncate →
  completion credit → evict the minimal youngest-first prefix of decoding
  survivors → allocate growth) as a *sort-free* victim-selection pass:
  pairwise-comparison ranks and masked prefix sums over the tiny
  ``(S, S)`` slot square replace the host tier's ``lexsort`` + ``cumsum``
  (XLA:CPU sorts, batched gathers, and batched scatters all lower to
  ~40–50 µs serial loops inside a while body; the one-hot reduces fuse).
  The selected victims are identical, so routerless single-pool runs are
  *bit-identical* to both host backends (asserted by
  ``tests/test_vector_engine.py``).

FIFO queues are request-indexed linked lists (``q_next[rid]`` + per
instance head/tail); preempted sequences go to a bounded per-instance
victim stash that the admission loop drains before the FIFO (capacity
``n_seq`` suffices: FIFO admits only while the stash is empty, so
``n_active + stash ≤ n_seq`` is invariant).

Carry layout and donation contract
----------------------------------
The run is three nested ``lax.while_loop``\\ s with deliberately *small*
carries — under ``vmap`` every loop iteration pays a masked select over
its whole carry, so what rides each carry is the backend's main cost
model (``benchmarks/sim_throughput.py`` tracks the byte totals as
``carry_bytes`` / ``sweep_carry_bytes`` / ``drain_carry_bytes``):

* **outer epoch loop** — one iteration per arrival burst: drain all
  arrivals that precede the next instance wake, then sweep rounds until
  the next arrival. Iteration count is surfaced as ``iters`` (bounded by
  ``n + 1``: every non-final epoch dispatches at least one arrival).
* **arrival drain** — carries only dispatch state: the FIFO linked
  lists, per-instance ``load``/``wake``, controller/window state, and
  the single ``(n+1,)`` pool-assignment record. No ``(I, S)`` slot
  arrays, no other record columns.
* **round sweep** — carries the slot arrays plus exactly the record
  columns that completion scatters write (``first``/``finish``/``out``/
  ``pre``/``trunc``) and the admission-reject staging column ``rejt``.
  Iteration count is surfaced as ``rounds`` (the pre-coalescing outer
  loop ran one round per outer iteration, so ``rounds / iters`` is the
  measured coalescing factor).

Per-request record arrays live in **preallocated donated buffers**: the
compiled entry takes a third argument ``rec0`` (see ``_fresh_records``)
that is donated to XLA (``jax.jit(..., donate_argnums=(2,))``), so the
in-loop scatters update the caller's buffers in place instead of copying
the record tree through every call. Callers must therefore pass *fresh*
buffers on every call and never reuse a previously-donated array — both
entry points allocate via ``_fresh_records`` per call, which the
donated-buffer parity tests pin down. Submit-time rejection is a pure
function of the recorded pool id and the trace, and admission-time
rejection is staged as a reject *timestamp* (``rejt``, +inf = not
rejected), so the boolean ``rej`` column and the reject first/finish
times are folded in once after the loop rather than scattered inside it.

The executables themselves are compiled ahead of time and cached
(:func:`aot_compile` / ``_aot``): ``.lower().compile()`` under
``enable_x64`` keyed by the static ``(spec, n, grid, g)`` shape, with
wall-clock lower/compile times recorded in ``_COMPILE_STATS`` so the
benchmark's ``jax_compile`` row measures compilation alone. The hot
decode-advance pass is shared with :mod:`repro.kernels.sim_decode`,
which provides a jnp twin (default on CPU/GPU hosts) and a Pallas kernel
(default on TPU; force with ``REPRO_SIM_PALLAS=1``, interpreter mode off
TPU) — both bit-identical, selected at trace time per ``_pallas_enabled``.

Routing, calibration, and control
---------------------------------
* **Routing** is fused into the dispatch branch as a ``searchsorted``
  against the *carried* threshold vector (shared helper
  :func:`repro.core.router.jax_pool_ids` — the same decision the batch
  routing kernel makes) — honest under threshold / controller vmap axes.
  Per-request budgets are precomputed on the host by folding the
  byte-length observation stream through the cached EMA kernels
  (:func:`precompute_budget_trajectory`) in arrival order with the same
  ramped epoch schedule the vectorized backend uses. Approximations vs
  the host routed path (documented, tolerance-class): feedback folds
  arrival-ordered trace observations instead of completion-ordered ones,
  and load-dependent spillover is off (static N-way + hard-constraint
  clamp only).
* **Adaptive control** mirrors :class:`repro.core.adaptive.AdaptiveController`
  in-step: the same AIMD decision rule, constants, and strict-ordering
  clamp run inside the compiled dispatch branch on the same
  dispatched-request windows, so controller *gains* can be a vmap axis.
* **Telemetry** is collected as per-window device snapshots (queue depth,
  active, KV-free, cumulative error counters, thresholds) and replayed
  into the host :class:`repro.obs.timeseries.FleetTelemetry` after the
  run — same windows, same columns; per-window calibration-error series
  use the final EMA state (device runs don't carry the float EMA).

When to prefer which tier: ``reference`` for unit-level ground truth;
``vectorized`` for one-off large host runs with faults / spillover /
event tracing; ``jax`` for grid sweeps and controller tuning where
compile time amortizes over many lanes. Fault injection is not supported
on this backend (``FleetSim`` raises).
"""

from __future__ import annotations

import dataclasses
import functools
import os
import time
import warnings
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import enable_x64

from repro.core.adaptive import (
    BoundaryMove,
    DEFAULT_DECREASE_FACTOR,
    DEFAULT_ERROR_RATE_HI,
    DEFAULT_INCREASE_STEP,
    DEFAULT_OVERLOAD_RATIO_HI,
)
from repro.core.calibration import (
    EmaCalibrator,
    _count_trace,
    _estimate_budget_kernel,
    _update_stream_kernel,
)
from repro.core.pools import KV_BLOCK_TOKENS, PoolConfig, TOTAL_KV_BLOCKS
from repro.core.router import jax_pool_ids
from repro.kernels.sim_decode import decode_advance_jnp, decode_advance_pallas
from repro.sim.engine import _blocks_for
from repro.sim.timing import TimingModel
from repro.traces.generator import TraceColumns

#: Sentinels for "no constraint" in masked min-reductions (int32-safe).
_BIG_I = 1 << 30
_BIG_F = 1.0e18

#: Donated record buffers (name, dtype, width). Same-dtype columns are
#: packed along a trailing width axis so each completion round issues
#: one scatter per buffer instead of one per column — XLA:CPU charges
#: ~40 µs per batched scatter inside a while body regardless of row
#: width. ``recf`` packs [first_token, finish]; ``reci`` packs
#: [out_tokens, preemptions, truncated(0/1)]. ``rejt`` stages the
#: admission-reject timestamp (+inf = not rejected); the boolean ``rej``
#: column is derived post-loop, so it never rides a loop carry.
_REC_DTYPES = (
    ("recf", np.float64, 2),
    ("reci", np.int32, 3),
    ("pool", np.int32, 1),
    ("rejt", np.float64, 1),
)

#: Per-pool state that the arrival drain actually mutates (FIFO lists,
#: load-balance picks, wake seeding, submit-reject counter). Everything
#: else is loop-invariant during a drain and stays out of its carry.
_DRAIN_POOL_KEYS = ("qnext", "qh", "qt", "qlen", "load", "wake", "nrej")

#: Test hook: force the Pallas decode path on (True) / off (False).
_PALLAS_FORCE: Optional[bool] = None


def _pallas_enabled() -> bool:
    """Decode-advance path selection (part of the executable cache key).

    Defaults to the Pallas kernel only on TPU (where it compiles via
    Mosaic); hosts use the jnp twin — running the interpreter inside the
    hot compiled loop would be pure overhead. ``REPRO_SIM_PALLAS=1``
    forces the kernel (interpreter mode off-TPU; used by the parity
    tests), ``=0`` forces it off.
    """
    if _PALLAS_FORCE is not None:
        return bool(_PALLAS_FORCE)
    env = os.environ.get("REPRO_SIM_PALLAS")
    if env is not None:
        return env.strip().lower() not in ("", "0", "false", "off")
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Static compile-time description
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _PoolSpec:
    """Static shape/capacity facts for one pool (hashable → jit cache key)."""

    name: str
    c_max: int
    n_seq: int
    total_blocks: int
    max_inst: int  # array dimension I (≥ every lane's instance count)


@dataclasses.dataclass(frozen=True)
class _SimSpec:
    pools: tuple[_PoolSpec, ...]
    w: float  # roofline W (seconds)
    h: float  # roofline H (seconds)
    prefill_chunk: int
    win_size: int  # monitoring window in dispatched requests; 0 = off


def _pool_spec(name: str, cfg: PoolConfig, max_inst: int) -> _PoolSpec:
    total = min(TOTAL_KV_BLOCKS, cfg.n_seq * _blocks_for(cfg.c_max))
    return _PoolSpec(
        name=name,
        c_max=int(cfg.c_max),
        n_seq=int(cfg.n_seq),
        total_blocks=int(total),
        max_inst=int(max_inst),
    )


# ---------------------------------------------------------------------------
# Carry construction (shared by the compiled core and the size probe)
# ---------------------------------------------------------------------------


def _init_pools(spec: _SimSpec, n: int) -> dict:
    """Stacked ``(P, I, S)`` pool state — one pytree for every pool.

    Pools are padded to the widest instance/slot counts so a single
    traced round body covers all of them (the XLA:CPU backend is
    op-dispatch bound, so P separately-traced pool bodies cost ~P× one
    stacked body). Padding is inert by construction: padded slots are
    unoccupied and guarded by the per-pool ``n_seq`` admission cap,
    padded instances never wake (``wake = inf``) and contribute zero
    free blocks to the telemetry sums.
    """
    i32 = jnp.int32
    f64 = jnp.float64
    P = len(spec.pools)
    I = max(ps.max_inst for ps in spec.pools)
    S = max(ps.n_seq for ps in spec.pools)
    ivalid = np.arange(I)[None, :] < np.asarray(
        [ps.max_inst for ps in spec.pools]
    )[:, None]
    tblocks = np.asarray([ps.total_blocks for ps in spec.pools], np.int32)
    z2 = jnp.zeros((P, I, S), i32)
    return {
        "occ": jnp.zeros((P, I, S), bool),
        "rid": jnp.full((P, I, S), -1, i32),
        "enq": jnp.zeros((P, I, S), f64),
        "inp": z2,
        "outp": z2,
        "pre": z2,
        "rem": z2,
        "gen": z2,
        "blk": z2,
        "ft": jnp.full((P, I, S), jnp.nan, f64),
        "tr": jnp.zeros((P, I, S), bool),
        "pc": z2,
        "sq": z2,
        "free": jnp.asarray(
            np.where(ivalid, tblocks[:, None], 0), i32
        ),
        "wake": jnp.full((P, I), jnp.inf, f64),
        "nact": jnp.zeros((P, I), i32),
        "qlen": jnp.zeros((P, I), i32),
        "load": jnp.zeros((P, I), i32),
        "qh": jnp.full((P, I), -1, i32),
        "qt": jnp.full((P, I), -1, i32),
        "qnext": jnp.full((P, n + 1), -1, i32),
        "vrid": jnp.zeros((P, I, S), i32),
        "vinp": jnp.zeros((P, I, S), i32),
        "vpc": jnp.zeros((P, I, S), i32),
        "vcnt": jnp.zeros((P, I), i32),
        "sqc": jnp.zeros((P,), i32),
        "npre": jnp.zeros((P,), i32),
        "nrej": jnp.zeros((P,), i32),
        "ntr": jnp.zeros((P,), i32),
    }


def _init_windows(P: int, nb: int, win_cap: int) -> dict:
    i32 = jnp.int32
    f64 = jnp.float64
    return {
        "t_req": jnp.zeros((win_cap,), i32),
        "now": jnp.zeros((win_cap,), f64),
        "th": jnp.zeros((win_cap, nb), i32),
        "queue": jnp.zeros((win_cap, P), i32),
        "active": jnp.zeros((win_cap, P), i32),
        "freeb": jnp.zeros((win_cap, P), i32),
        "pre": jnp.zeros((win_cap, P), i32),
        "rej": jnp.zeros((win_cap, P), i32),
        "trunc": jnp.zeros((win_cap, P), i32),
    }


def _fresh_records(n: int, g: Optional[int] = None) -> dict:
    """Freshly-zeroed donated record buffers for one compiled call.

    Donation contract: these arrays are consumed by the executable —
    allocate a new set per call, never hand back a previously-donated
    buffer. ``rejt`` is +inf-filled (no admission reject)."""
    base = (n + 1,) if g is None else (g, n + 1)
    buf = {}
    for name, dt, w in _REC_DTYPES:
        shape = base if w == 1 else base + (w,)
        buf[name] = (
            np.full(shape, np.inf, dt)
            if name == "rejt"
            else np.zeros(shape, dt)
        )
    return buf


def _unpack_records(rec: dict, n: int) -> dict:
    """Split the packed record buffers back into named host columns.

    Handles single-lane ``(n + 1, …)`` and grid ``(g, n + 1, …)``
    shapes alike (the request axis is always the one sliced by ``:n``,
    dropping the scratch row)."""
    rf = rec["recf"][..., :n, :]
    ri = rec["reci"][..., :n, :]
    return {
        "first": rf[..., 0],
        "finish": rf[..., 1],
        "out": ri[..., 0],
        "pre": ri[..., 1],
        "trunc": ri[..., 2].astype(bool),
        "pool": rec["pool"][..., :n],
        "rejt": rec["rejt"][..., :n],
        "rej": rec["rej"][..., :n],
    }


# ---------------------------------------------------------------------------
# The compiled core
# ---------------------------------------------------------------------------


def _make_core(
    spec: _SimSpec,
    n: int,
    return_records: bool,
    use_pallas: bool,
    gate: bool = True,
):
    """Build the single-lane simulation function for one (spec, n).

    Returned function signature: ``core(trace, lane, rec0) -> dict``
    where ``trace`` holds shared arrival-ordered arrays, ``lane`` the
    per-lane (vmappable) parameters, and ``rec0`` the donated record
    buffers (see ``_fresh_records``). Must be traced/executed inside an
    ``enable_x64()`` context — event times are float64 accumulations.

    The pool state is a single stacked ``(P, I, S)`` pytree (see
    ``_init_pools``) so one traced round body covers every pool — on the
    op-dispatch-bound XLA:CPU backend P separately-traced bodies cost
    ~P× as much.

    ``gate`` short-circuits the eviction pass with ``lax.cond`` when no
    instance is over budget; the skipped branch is bit-identical to the
    masked pass (``jsel = 0`` evicts nothing), so gating never changes
    results — but under ``vmap`` a batched ``cond`` runs both branches
    anyway, so ``_runner`` disables it for grid mode. ``gate`` also
    selects the outer-loop shape: nested drain→sweep epochs for the
    single-lane path, drain + exactly one round per outer iteration for
    vmapped grids (a nested sweep loop would run to the max round count
    over lanes per epoch — a measured 5.6× lockstep blowup at G=16).
    """
    P = len(spec.pools)
    win = spec.win_size
    win_cap = (n // win + 2) if win > 0 else 1
    nb = max(P - 1, 1)  # threshold-column width (≥1 keeps shapes non-empty)
    i32 = jnp.int32
    f64 = jnp.float64
    W = np.float64(spec.w)
    H = np.float64(spec.h)
    CHUNK = spec.prefill_chunk
    I = max(ps.max_inst for ps in spec.pools)
    S = max(ps.n_seq for ps in spec.pools)
    # Per-pool parameters as (P,) closure constants over the stacked
    # state (dtype-pinned so padding arithmetic stays int32).
    cmax_v = jnp.asarray([ps.c_max for ps in spec.pools], jnp.int32)
    nseq_v = jnp.asarray([ps.n_seq for ps in spec.pools], jnp.int32)
    tblk_v = jnp.asarray(
        [ps.total_blocks for ps in spec.pools], jnp.int32
    )
    pg2 = jnp.arange(P)[:, None]
    ig2 = jnp.arange(I)[None, :]

    if use_pallas:
        # The Pallas kernel takes c_max as a static compile-time
        # parameter, so the stacked decode runs one kernel call per
        # pool and restacks (CI-parity path; the jnp twin below is the
        # default off-TPU).
        _advance_p = tuple(
            functools.partial(
                decode_advance_pallas, w=W, h=H, chunk=CHUNK, c_max=ps.c_max
            )
            for ps in spec.pools
        )

        def advance_all(t_limit, *args):
            outs = [
                _advance_p[p](t_limit, *(a[p] for a in args))
                for p in range(P)
            ]
            return {k: jnp.stack([o[k] for o in outs]) for k in outs[0]}

    else:
        _advance_1 = functools.partial(
            decode_advance_jnp, w=W, h=H, chunk=CHUNK
        )

        def advance_all(t_limit, *args):
            # One vmapped twin over the pool axis; c_max rides along as
            # a traced per-pool scalar (pure arithmetic in the twin).
            return jax.vmap(
                lambda cm, *a: _advance_1(t_limit, *a, c_max=cm),
                in_axes=(0,) * (len(args) + 1),
            )(cmax_v, *args)

    def blocks_for(tok):
        return jnp.maximum(1, (tok + (KV_BLOCK_TOKENS - 1)) // KV_BLOCK_TOKENS)

    def wake_min_all(pools_):
        return jnp.min(pools_["wake"])

    def core(trace, lane, rec0):
        _count_trace(("sim_core", P, n, bool(return_records), bool(use_pallas)))
        arr_t = trace["arr"]
        inp_t = trace["inp"]
        out_t = trace["outp"]
        bud_t = trace["budget"]
        ctrl = lane["ctrl"]

        def next_arr_at(a):
            return jnp.where(a < n, arr_t[jnp.minimum(a, n - 1)], jnp.inf)

        # ---- arrival drain (small carry: dispatch state only) -------------
        def drain(c):
            # Loop-invariant pool state during a drain: dispatch touches
            # only the FIFO/load/wake/nrej fields, so the window snapshot's
            # other inputs are frozen closures — values identical to the
            # full-carry formulation, but the masked per-iteration select
            # covers only the small carry below.
            frozen = {
                "npre": c["pools"]["npre"],
                "ntr": c["pools"]["ntr"],
                "nact": c["pools"]["nact"],
                "free": c["pools"]["free"],
            }

            # ---- monitoring window + in-step AIMD controller --------------
            def window_step(sc, now_t):
                fire = (sc["win_seen"] - sc["win_prev"]) >= win
                cur = frozen["npre"] + sc["pools"]["nrej"] + frozen["ntr"]
                delta = cur - sc["prev_err"]
                wr = sc["win_seen"] - sc["win_prev"]
                queues = jnp.sum(sc["pools"]["qlen"], axis=1, dtype=i32)
                pressure = queues.astype(jnp.float32) / jnp.maximum(
                    1, lane["ninst"]
                ).astype(jnp.float32)
                old = sc["th"]
                moved = jnp.asarray(False)
                th = old
                if P > 1:
                    # AIMD per boundary — the exact decision rule and
                    # constants of AdaptiveController._aimd_move / update().
                    wrf = jnp.maximum(wr, 1).astype(jnp.float32)
                    props = []
                    for k in range(P - 1):
                        err_rate = delta[k].astype(jnp.float32) / wrf
                        p_lo, p_hi = pressure[k], pressure[k + 1]
                        dec = (err_rate > ctrl["err_hi"]) | (
                            (p_lo > ctrl["over_hi"] * jnp.maximum(p_hi, 0.25))
                            & (p_lo > 1.0)
                        )
                        inc = (~dec) & (p_hi < 0.25) & (p_lo < 1.0)
                        down = (
                            old[k].astype(jnp.float32) * ctrl["factor"]
                        ).astype(i32)
                        props.append(
                            jnp.where(
                                dec,
                                down,
                                jnp.where(inc, old[k] + ctrl["step"], old[k]),
                            )
                        )
                    # Feasibility projection: forward pass with a running
                    # lower bound; degenerate case falls back to the old
                    # vector.
                    lo = ctrl["b_min"]
                    feasible = jnp.asarray(True)
                    newv = []
                    for k in range(P - 1):
                        cap = spec.pools[k].c_max
                        feasible = feasible & (lo <= cap)
                        nk = jnp.minimum(jnp.maximum(props[k], lo), cap)
                        newv.append(nk)
                        lo = nk + 1
                    newv = jnp.where(feasible, jnp.stack(newv), old)
                    apply = fire & (ctrl["enabled"] > 0) & (wr > 0)
                    th = jnp.where(apply, newv, old)
                    moved = apply & jnp.any(newv != old)

                # Device telemetry snapshot (post-controller thresholds,
                # same ordering as the host's _window_step).
                wn = sc["win"]
                wdx = jnp.minimum(sc["wi"], win_cap - 1)

                def put(name, val):
                    return wn[name].at[wdx].set(
                        jnp.where(fire, val, wn[name][wdx])
                    )

                th_row = th if P > 1 else jnp.zeros((nb,), i32)
                wn = {
                    "t_req": put("t_req", sc["win_seen"]),
                    "now": put("now", now_t),
                    "th": put("th", th_row),
                    "queue": put("queue", queues),
                    "active": put(
                        "active", jnp.sum(frozen["nact"], axis=1, dtype=i32)
                    ),
                    "freeb": put(
                        "freeb", jnp.sum(frozen["free"], axis=1, dtype=i32)
                    ),
                    "pre": put("pre", frozen["npre"]),
                    "rej": put("rej", sc["pools"]["nrej"]),
                    "trunc": put("trunc", frozen["ntr"]),
                }
                return {
                    **sc,
                    "th": th,
                    "prev_err": jnp.where(fire, cur, sc["prev_err"]),
                    "win_prev": jnp.where(fire, sc["win_seen"], sc["win_prev"]),
                    "wi": sc["wi"] + jnp.where(fire, 1, 0),
                    "moves": sc["moves"] + jnp.where(moved, 1, 0),
                    "win": wn,
                }

            # ---- dispatch one arrival -------------------------------------
            def dispatch(sc):
                a = sc["a"]
                ai = jnp.minimum(a, n - 1)
                t = arr_t[ai]
                pidx = jax_pool_ids(sc["th"][: P - 1], bud_t[ai])
                pool_rec = sc["pool"].at[ai].set(pidx)
                st = sc["pools"]
                pg = jnp.arange(P)
                sel = pidx == pg
                alive = ig2 < lane["ninst"][:, None]
                i = jnp.argmin(jnp.where(alive, st["load"], _BIG_I), axis=1)
                # Submit-time rejection (prompt alone exceeds C_max) is
                # a pure function of the recorded pool id and the trace;
                # the record columns are folded in post-loop and only
                # the counter lives here.
                rej = inp_t[ai] >= cmax_v
                ok = sel & ~rej
                qh_i = st["qh"][pg, i]
                qt_i = st["qt"][pg, i]
                wake_i = st["wake"][pg, i]
                was_empty = qh_i < 0
                qnext = st["qnext"].at[pg, jnp.where(ok, ai, n)].set(-1)
                qnext = qnext.at[
                    pg, jnp.where(ok & ~was_empty, qt_i, n)
                ].set(ai.astype(i32))
                st = {
                    **st,
                    "qnext": qnext,
                    "qh": st["qh"].at[pg, i].set(
                        jnp.where(ok & was_empty, ai.astype(i32), qh_i)
                    ),
                    "qt": st["qt"].at[pg, i].set(
                        jnp.where(ok, ai.astype(i32), qt_i)
                    ),
                    "qlen": st["qlen"].at[pg, i].add(jnp.where(ok, 1, 0)),
                    "load": st["load"].at[pg, i].add(jnp.where(ok, 1, 0)),
                    "wake": st["wake"].at[pg, i].set(
                        jnp.where(ok & jnp.isinf(wake_i), t, wake_i)
                    ),
                    "nrej": st["nrej"] + jnp.where(sel & rej, 1, 0),
                }
                sc = {
                    **sc,
                    "a": a + 1,
                    "pools": st,
                    "pool": pool_rec,
                    "win_seen": sc["win_seen"] + 1,
                }
                if win > 0:
                    sc = window_step(sc, t)
                return sc

            # Arrival-first tie-break: dispatch while t_arr ≤ every wake
            # (matches the host heap's ``next_arrival <= next_event``).
            def disp_cond(sc):
                return (sc["a"] < n) & (
                    next_arr_at(sc["a"]) <= wake_min_all(sc["pools"])
                )

            sc = {
                "a": c["a"],
                "th": c["th"],
                "prev_err": c["prev_err"],
                "win_seen": c["win_seen"],
                "win_prev": c["win_prev"],
                "wi": c["wi"],
                "moves": c["moves"],
                "win": c["win"],
                "pool": c["pool"],
                "pools": {k: c["pools"][k] for k in _DRAIN_POOL_KEYS},
            }
            sc = lax.while_loop(disp_cond, dispatch, sc)
            return {
                **c,
                "a": sc["a"],
                "th": sc["th"],
                "prev_err": sc["prev_err"],
                "win_seen": sc["win_seen"],
                "win_prev": sc["win_prev"],
                "wi": sc["wi"],
                "moves": sc["moves"],
                "win": sc["win"],
                "pool": sc["pool"],
                "pools": {**c["pools"], **sc["pools"]},
            }

        # ---- one masked round over the stacked pools ----------------------
        def pool_round(st, rec, rejt, t_limit):
            due = st["wake"] < t_limit

            # Admission fixpoint: one wave admits/rejects at most one head
            # per due instance; loops until no instance can make progress.
            # (Instances are independent, so wave order ≡ the host's
            # per-instance sequential admission.) The carry is the slot
            # state plus the one staging column admission writes.
            def adm_masks(st_):
                stash = st_["vcnt"] > 0
                hrid = jnp.where(stash, st_["vrid"][:, :, 0], st_["qh"])
                has = due & (stash | (st_["qh"] >= 0))
                hc = jnp.clip(hrid, 0, n - 1)
                hinp = jnp.where(stash, st_["vinp"][:, :, 0], inp_t[hc])
                hpc = jnp.where(stash, st_["vpc"][:, :, 0], 0)
                need = blocks_for(hinp)
                can = st_["nact"] < nseq_v[:, None]
                rejm = has & can & (need > tblk_v[:, None])
                admm = has & can & ~rejm & (need <= st_["free"])
                return stash, hrid, hc, hinp, hpc, need, rejm, admm

            def adm_cond(val):
                st_, _ = val
                *_, rejm, admm = adm_masks(st_)
                return jnp.any(rejm | admm)

            def adm_body(val):
                st_, rejt_ = val
                stash, hrid, hc, hinp, hpc, need, rejm, admm = adm_masks(st_)
                prog = rejm | admm
                # pop the head (victim stash first — head-of-line order)
                pop_st = prog & stash
                pop_f = prog & ~stash

                def shiftl(arr3):
                    return jnp.concatenate(
                        [arr3[:, :, 1:], arr3[:, :, :1]], axis=2
                    )

                vrid = jnp.where(
                    pop_st[:, :, None], shiftl(st_["vrid"]), st_["vrid"]
                )
                vinp = jnp.where(
                    pop_st[:, :, None], shiftl(st_["vinp"]), st_["vinp"]
                )
                vpc = jnp.where(
                    pop_st[:, :, None], shiftl(st_["vpc"]), st_["vpc"]
                )
                nxt = jnp.take_along_axis(
                    st_["qnext"], jnp.clip(st_["qh"], 0, n), axis=1
                )
                qh = jnp.where(pop_f, nxt, st_["qh"])
                qt = jnp.where(pop_f & (nxt < 0), -1, st_["qt"])
                # admission-reject: stage the reject timestamp only (host:
                # add_one with first = finish = now); the record columns
                # fold in post-loop from rejt. One flattened scatter
                # covers every pool (request ids are disjoint across
                # pools; non-rejecting heads aim at the scratch row).
                ridx = jnp.where(rejm, hc, n)
                rejt_ = rejt_.at[ridx].set(
                    st_["wake"], mode="promise_in_bounds"
                )
                # admit into the first free slot (argmin over occupied —
                # the host's np.argmin tie-break; padded slots sit past
                # every real slot, and ``can`` already gates full pools)
                slot = jnp.argmin(st_["occ"], axis=2)
                base = st_["sqc"]
                rank = (jnp.cumsum(admm, axis=1) - admm).astype(i32)

                # One-hot admit writes: each instance fills at most one
                # slot per wave, so a masked eltwise where over (P, I, S)
                # replaces a gather + 2-update scatter pair per column —
                # XLA:CPU expands each of those into a serial while with
                # full-array boundary copies; the where fuses instead.
                sl_hot = (
                    jnp.arange(S)[None, None, :] == slot[:, :, None]
                ) & admm[:, :, None]

                def w2(arr3, val):
                    v = jnp.broadcast_to(
                        jnp.asarray(val, arr3.dtype), slot.shape
                    )
                    return jnp.where(sl_hot, v[:, :, None], arr3)

                return (
                    {
                        **st_,
                        "vrid": vrid,
                        "vinp": vinp,
                        "vpc": vpc,
                        "vcnt": st_["vcnt"] - pop_st,
                        "qh": qh,
                        "qt": qt,
                        "qlen": st_["qlen"] - prog,
                        "load": st_["load"] - rejm,
                        "nrej": st_["nrej"]
                        + jnp.sum(rejm, axis=1, dtype=i32),
                        "occ": w2(st_["occ"], True),
                        "rid": w2(st_["rid"], hrid),
                        "enq": w2(st_["enq"], arr_t[hc]),
                        "inp": w2(st_["inp"], hinp),
                        "outp": w2(st_["outp"], out_t[hc]),
                        "pre": w2(st_["pre"], hinp),
                        "rem": w2(st_["rem"], out_t[hc]),
                        "gen": w2(st_["gen"], 0),
                        "blk": w2(st_["blk"], need),
                        "ft": w2(st_["ft"], jnp.nan),
                        "tr": w2(st_["tr"], False),
                        "pc": w2(st_["pc"], hpc),
                        "sq": w2(st_["sq"], base[:, None] + rank),
                        "sqc": base + jnp.sum(admm, axis=1, dtype=i32),
                        "free": st_["free"] - jnp.where(admm, need, 0),
                        "nact": st_["nact"] + admm,
                    },
                    rejt_,
                )

            st, rejt = lax.while_loop(adm_cond, adm_body, (st, rejt))

            nact = st["nact"]
            busy = due & (nact > 0)
            idle = due & ~busy
            wake_idle = jnp.where(
                idle,
                jnp.where(st["qlen"] > 0, st["wake"] + 1e-9, jnp.inf),
                st["wake"],
            )
            now = jnp.where(busy, st["wake"], 0.0)
            bb = busy[:, :, None]
            occ = st["occ"]
            inp2, gen0, rem0, blk0 = st["inp"], st["gen"], st["rem"], st["blk"]

            # fused decode-advance (repro.kernels.sim_decode): prefill
            # chunk + event-distance k-jump + advance + completion staging,
            # as the jnp twin (vmapped over the pool axis) or the Pallas
            # kernel (one call per pool) — bit-identical paths.
            adv = advance_all(
                t_limit,
                busy,
                now,
                nact,
                st["free"],
                occ,
                st["pre"],
                st["sq"],
                inp2,
                gen0,
                rem0,
                blk0,
                st["ft"],
                st["tr"],
            )
            pre_arr = adv["pre"]
            dec = adv["dec"]
            end = adv["end"]
            gen_a = adv["gen"]
            rem_a = adv["rem"]
            ft_a = adv["ft"]
            trunc_n = adv["trunc_new"]
            tr_a = adv["tr"]
            comp = adv["comp"]
            ntr = st["ntr"] + jnp.sum(trunc_n, axis=(1, 2), dtype=i32)

            # One scatter per pool per packed record buffer, over that
            # pool's *real* ``(max_inst, n_seq)`` slot block. The
            # stacked arrays are padded to ``(P, max I, max S)``, and
            # XLA:CPU lowers a batched scatter to a serial per-row
            # loop, so scattering the padded block pays for slots that
            # can never complete (a ragged 4×128 + 12×16 topology pads
            # 4.4×). Request ids are globally unique, so per-pool
            # updates stay disjoint; non-completing slots hit the
            # scratch row. Packing same-dtype columns keeps this at
            # two scatter ops per pool per round instead of five.
            ridx = jnp.where(comp, st["rid"], n)
            recf_new = jnp.stack(
                [ft_a, jnp.broadcast_to(end[:, :, None], (P, I, S))],
                axis=-1,
            )
            reci_new = jnp.stack(
                [gen_a, st["pc"], tr_a.astype(i32)], axis=-1
            )
            rf, ri = rec["recf"], rec["reci"]
            for p in range(P):
                ip, sp = spec.pools[p].max_inst, spec.pools[p].n_seq
                idx_p = ridx[p, :ip, :sp]
                rf = rf.at[idx_p].set(
                    recf_new[p, :ip, :sp], mode="promise_in_bounds"
                )
                ri = ri.at[idx_p].set(
                    reci_new[p, :ip, :sp], mode="promise_in_bounds"
                )
            rec = {"recf": rf, "reci": ri}
            free1 = st["free"] + jnp.sum(
                jnp.where(comp, blk0, 0), axis=2, dtype=i32
            )
            ncomp = jnp.sum(comp, axis=2, dtype=i32)

            surv = dec & (rem_a > 0) & bb
            need_s = jnp.where(surv, blocks_for(inp2 + gen_a), blk0)
            grow = jnp.where(surv, need_s - blk0, 0)
            demand = grow.sum(axis=2, dtype=i32)

            def evict_pass(_):
                # Sort-free eviction scan. XLA:CPU sorts cost ~40 µs
                # each inside a while body, so instead of
                # lexsort/argsort the scan order (enq youngest-first,
                # admission seq_no tie-break — a total order: seq_no is
                # unique per instance) comes from pairwise-comparison
                # ranks over the tiny (S, S) slot square, prefix sums
                # from the same mask, and the victim stash from a
                # rank-indexed scatter. Values are bit-identical to the
                # sorted formulation (keys carry no NaNs and no -0/+0
                # mix, so IEEE compare ≡ the sort's total order).
                keyq = jnp.where(surv, -st["enq"], jnp.inf)
                sq = st["sq"]
                k_a, k_b = keyq[:, :, :, None], keyq[:, :, None, :]
                sq_lt = sq[:, :, None, :] < sq[:, :, :, None]  # [a,b]: b<a
                prec = (k_b < k_a) | ((k_b == k_a) & sq_lt)
                rank = jnp.sum(prec, axis=3, dtype=i32)
                le = prec | jnp.eye(S, dtype=bool)[None, None]
                blkv = jnp.where(surv, blk0, 0)
                cum_blk = jnp.sum(
                    jnp.where(le, blkv[:, :, None, :], 0), axis=3, dtype=i32
                )
                cum_grow = jnp.sum(
                    jnp.where(le, grow[:, :, None, :], 0), axis=3, dtype=i32
                )
                okj = (
                    demand[:, :, None] - cum_grow
                    <= free1[:, :, None] + cum_blk
                )
                first_ok = jnp.min(jnp.where(okj, rank, S), axis=2)
                jsel = jnp.where(
                    demand <= free1,
                    0,
                    jnp.where(first_ok < S, first_ok + 1, 1),
                )
                ev = (rank < jsel[:, :, None]) & surv
                nev = jnp.sum(ev, axis=2, dtype=i32)

                # victims → stash, in admission (seq_no) order, ahead of
                # the previous stash (requeue-at-head semantics). The
                # permutation runs as one-hot select-reduces over the
                # (S, S) square instead of gather/scatter: XLA:CPU's
                # batched scatter and gather both cost ~50 µs inside a
                # while body versus ~10 µs for the masked reduce, and
                # the one-hot sums are exact (one source per slot).
                vrank = jnp.sum(ev[:, :, None, :] & sq_lt, axis=3, dtype=i32)
                rr = jnp.arange(S)
                in_new = rr[None, None, :] < nev[:, :, None]
                # vm[j, a]: stash slot j takes the victim in slot a
                # (the one whose victim-rank is j); om[j, a]: slot j
                # takes previous-stash slot a = j − n_victims.
                vm = (
                    ev[:, :, None, :]
                    & (vrank[:, :, None, :] == rr[None, None, :, None])
                    & in_new[:, :, :, None]
                )
                om = (
                    rr[None, None, None, :]
                    == rr[None, None, :, None] - nev[:, :, None, None]
                ) & ~in_new[:, :, :, None]

                def stash(old3, vals):
                    return jnp.sum(
                        jnp.where(vm, vals[:, :, None, :], 0),
                        axis=3,
                        dtype=i32,
                    ) + jnp.sum(
                        jnp.where(om, old3[:, :, None, :], 0),
                        axis=3,
                        dtype=i32,
                    )

                vr = stash(st["vrid"], st["rid"])
                vi = stash(st["vinp"], inp2 + gen_a)
                vp = stash(st["vpc"], st["pc"] + 1)
                return ev, nev, vr, vi, vp

            def no_evict(_):
                # demand ≤ free everywhere ⇒ jsel = 0 ⇒ nothing evicts
                # and the stash is untouched — same values, no sorts.
                return (
                    jnp.zeros((P, I, S), bool),
                    jnp.zeros((P, I), i32),
                    st["vrid"],
                    st["vinp"],
                    st["vpc"],
                )

            if gate:
                evict, nevict, vrid, vinp, vpc = lax.cond(
                    jnp.any(demand > free1), evict_pass, no_evict, None
                )
            else:
                evict, nevict, vrid, vinp, vpc = evict_pass(None)
            npre = st["npre"] + jnp.sum(evict, axis=(1, 2), dtype=i32)
            free1 = free1 + jnp.sum(
                jnp.where(evict, blk0, 0), axis=2, dtype=i32
            )

            keep = surv & ~evict
            free1 = free1 - jnp.sum(
                jnp.where(keep, grow, 0), axis=2, dtype=i32
            )
            cleared = comp | evict
            nact_a = nact - ncomp - nevict
            qlen_a = st["qlen"] + nevict
            alive_r = (nact_a > 0) | (qlen_a > 0)

            st = {
                **st,
                "occ": jnp.where(bb, occ & ~cleared, occ),
                "pre": pre_arr,
                "rem": jnp.where(bb, rem_a, rem0),
                "gen": jnp.where(bb, gen_a, gen0),
                "blk": jnp.where(
                    bb, jnp.where(cleared, 0, jnp.where(keep, need_s, blk0)), blk0
                ),
                "ft": jnp.where(bb, ft_a, st["ft"]),
                "tr": jnp.where(bb, tr_a, st["tr"]),
                "vrid": jnp.where(bb, vrid, st["vrid"]),
                "vinp": jnp.where(bb, vinp, st["vinp"]),
                "vpc": jnp.where(bb, vpc, st["vpc"]),
                "vcnt": jnp.where(busy, st["vcnt"] + nevict, st["vcnt"]),
                "free": jnp.where(busy, free1, st["free"]),
                "nact": jnp.where(busy, nact_a, nact),
                "qlen": jnp.where(busy, qlen_a, st["qlen"]),
                "load": jnp.where(busy, st["load"] - ncomp, st["load"]),
                "wake": jnp.where(
                    busy, jnp.where(alive_r, end, jnp.inf), wake_idle
                ),
                "npre": npre,
                "ntr": ntr,
            }
            return st, rec, rejt

        # ---- outer epoch loop: drain arrivals, then sweep rounds ----------
        def cond_fn(c):
            return (c["a"] < n) | jnp.isfinite(wake_min_all(c["pools"]))

        def one_round(c, t_limit):
            pools_s, rec_s, rejt_s = pool_round(
                c["pools"], c["rec"], c["rejt"], t_limit
            )
            return {
                **c,
                "pools": pools_s,
                "rec": rec_s,
                "rejt": rejt_s,
                "rounds": c["rounds"] + 1,
            }

        if gate:

            def body_fn(c):
                c = drain(c)
                # Coalesced sweep: run rounds back-to-back until the
                # next arrival (t_limit is loop-invariant — `a` doesn't
                # move during a sweep), instead of re-entering the outer
                # body per round. The sweep carry is the slot state +
                # the completion-written record columns only.
                t_limit = next_arr_at(c["a"])

                def sweep_cond(s):
                    return wake_min_all(s[0]) < t_limit

                def sweep_body(s):
                    pools_s, rec_s, rejt_s, rounds = s
                    cs = one_round(
                        {
                            **c,
                            "pools": pools_s,
                            "rec": rec_s,
                            "rejt": rejt_s,
                            "rounds": rounds,
                        },
                        t_limit,
                    )
                    return (cs["pools"], cs["rec"], cs["rejt"], cs["rounds"])

                pools_s, rec_s, rejt_s, rounds = lax.while_loop(
                    sweep_cond,
                    sweep_body,
                    (c["pools"], c["rec"], c["rejt"], c["rounds"]),
                )
                return {
                    **c,
                    "pools": pools_s,
                    "rec": rec_s,
                    "rejt": rejt_s,
                    "rounds": rounds,
                    "iters": c["iters"] + 1,
                }

        else:

            def body_fn(c):
                # Vmapped lanes: drain arrivals, then exactly ONE round
                # per outer iteration. A nested sweep loop (rounds
                # back-to-back until the next arrival) would run to the
                # max round count over lanes per epoch — Σ_epochs
                # max_lanes ≫ max_lanes Σ_epochs once lanes diverge, a
                # measured 5.6× blowup on the 16-lane threshold sweep —
                # while a flat one-action ``lax.cond`` pays both branch
                # bodies plus two full-carry selects per iteration under
                # vmap. One unconditional round per outer step keeps
                # lockstep losses near zero (arrival streams are shared
                # across lanes, so the drain while stays synchronized)
                # and a lane with nothing due runs a masked no-op round
                # — bit-identical, modulo the scratch record row.
                c = drain(c)
                c = one_round(c, next_arr_at(c["a"]))
                return {**c, "iters": c["iters"] + 1}

        c0 = {
            "a": jnp.asarray(0, i32),
            "pools": _init_pools(spec, n),
            "rec": {"recf": rec0["recf"], "reci": rec0["reci"]},
            "pool": rec0["pool"],
            "rejt": rec0["rejt"],
            "th": lane["th"],
            "prev_err": jnp.zeros((P,), i32),
            "win_seen": jnp.asarray(0, i32),
            "win_prev": jnp.asarray(0, i32),
            "wi": jnp.asarray(0, i32),
            "moves": jnp.asarray(0, i32),
            "iters": jnp.asarray(0, i32),
            "rounds": jnp.asarray(0, i32),
            "win": _init_windows(P, nb, win_cap),
        }
        c = lax.while_loop(cond_fn, body_fn, c0)

        # ---- post-loop record folding -------------------------------------
        # Admission rejects: staged timestamp is finite. Submit rejects:
        # the prompt alone exceeds the recorded pool's C_max. Both write
        # first = finish = reject time, exactly as the host's add_one.
        # The fold runs at full (n+1,) length so the outputs can alias the
        # donated input buffers (the scratch row n is sliced off on the
        # host; its folded value is meaningless).
        arr_p = jnp.concatenate([arr_t, jnp.zeros((1,), f64)])
        inp_p = jnp.concatenate([inp_t, jnp.zeros((1,), i32)])
        rejt = c["rejt"]
        arej = jnp.isfinite(rejt)
        srej = inp_p >= cmax_v[c["pool"]]
        rejm = arej | srej
        # Rejected rows get first = finish = reject time, so the fold
        # is one masked where over the packed f64 buffer (the output
        # keeps the donated buffer's (n + 1, 2) shape and aliases it).
        recf_full = jnp.where(
            rejm[:, None],
            jnp.where(arej, rejt, arr_p)[:, None],
            c["rec"]["recf"],
        )
        rec_full = {
            "recf": recf_full,
            "reci": c["rec"]["reci"],
            "pool": c["pool"],
            "rejt": rejt,
            "rej": rejm,
        }
        first = recf_full[:n, 0]
        finish = recf_full[:n, 1]
        out_tok = rec_full["reci"][:n, 0]
        trunc = rec_full["reci"][:n, 2]
        pool_c = c["pool"][:n]

        compm = ~rejm[:n]
        ttft = jnp.where(compm, first - arr_t, jnp.nan)
        tpot = jnp.where(
            compm & (out_tok > 1),
            (finish - first) / jnp.maximum(out_tok - 1, 1),
            jnp.nan,
        )
        out = {
            "metrics": {
                "completed": jnp.sum(compm),
                "rejected": jnp.sum(rejm[:n]),
                "truncated": jnp.sum(trunc),
                "routed": jnp.stack(
                    [jnp.sum(pool_c == p) for p in range(P)]
                ),
                "ttft_mean": jnp.nanmean(ttft),
                "ttft_p50": jnp.nanpercentile(ttft, 50),
                "ttft_p99": jnp.nanpercentile(ttft, 99),
                "tpot_mean": jnp.nanmean(tpot),
                "tpot_p99": jnp.nanpercentile(tpot, 99),
                "t_end": jnp.max(finish),
                "makespan": jnp.max(finish) - jnp.min(arr_t),
            },
            "preempt": c["pools"]["npre"],
            "reject": c["pools"]["nrej"],
            "truncate": c["pools"]["ntr"],
            "th": c["th"],
            "moves": c["moves"],
            "nwin": c["wi"],
            "win": c["win"],
            "iters": c["iters"],
            "rounds": c["rounds"],
        }
        if return_records:
            # Full (n + 1,) leaves so every output can alias its donated
            # input buffer; callers slice off the scratch row.
            out["rec"] = rec_full
        return out

    return core


@functools.lru_cache(maxsize=None)
def _runner(
    spec: _SimSpec,
    n: int,
    return_records: bool,
    grid: bool,
    use_pallas: bool = False,
):
    """Cached jitted simulation, specialized per (spec, n, outputs, vmap).

    The third argument (record buffers) is donated — XLA writes the
    scatters into the caller's buffers in place."""
    core = _make_core(spec, n, return_records, use_pallas, gate=not grid)
    fn = jax.vmap(core, in_axes=(None, 0, 0)) if grid else core
    return jax.jit(fn, donate_argnums=(2,))


# ---------------------------------------------------------------------------
# AOT executable cache + probes
# ---------------------------------------------------------------------------

#: {(spec, n, return_records, grid, g, pallas): {"lower_s", "compile_s"}}
_COMPILE_STATS: dict = {}

#: Counters from the most recent compiled run (see :func:`last_run_stats`).
_LAST_RUN: dict = {}


def _abstract_inputs(spec: _SimSpec, n: int, grid: bool, g: int):
    """ShapeDtypeStructs matching the runtime arguments of ``_runner``."""
    P = len(spec.pools)
    sds = jax.ShapeDtypeStruct

    def L(shape, dt):
        return sds(((g,) + shape) if grid else shape, dt)

    trace = {
        "arr": sds((n,), np.float64),
        "inp": sds((n,), np.int32),
        "outp": sds((n,), np.int32),
        "budget": sds((n,), np.int32),
    }
    lane = {
        "th": L((P - 1,), np.int32),
        "ninst": L((P,), np.int32),
        "ctrl": {
            "enabled": L((), np.int32),
            "b_min": L((), np.int32),
            "step": L((), np.int32),
            "factor": L((), np.float32),
            "err_hi": L((), np.float32),
            "over_hi": L((), np.float32),
        },
    }
    rec = {
        name: L((n + 1,) if w == 1 else (n + 1, w), dt)
        for name, dt, w in _REC_DTYPES
    }
    return trace, lane, rec


@functools.lru_cache(maxsize=None)
def _aot(
    spec: _SimSpec,
    n: int,
    return_records: bool,
    grid: bool,
    g: int,
    use_pallas: bool,
):
    """AOT-compiled executable for one static shape key.

    ``.lower().compile()`` runs here exactly once per key; wall-clock
    lower/compile times land in ``_COMPILE_STATS`` so the benchmark's
    ``jax_compile`` row can report compilation alone (no run attached).
    """
    with enable_x64(), warnings.catch_warnings():
        if not return_records:
            # Without record outputs the donated buffers have no output
            # to alias into — donation still lets XLA recycle them as
            # in-loop scratch, so the "not usable" note is expected.
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable"
            )
        fn = _runner(spec, n, return_records, grid, use_pallas)
        targs, lane, rec = _abstract_inputs(spec, n, grid, g)
        t0 = time.perf_counter()
        lowered = fn.lower(targs, lane, rec)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
    _COMPILE_STATS[(spec, n, return_records, grid, g, use_pallas)] = {
        "lower_s": t1 - t0,
        "compile_s": t2 - t1,
    }
    return compiled


def last_run_stats() -> dict:
    """Loop counters from the most recent compiled run on this host.

    Keys: ``iters`` (outer epochs, coalesced bound ``n + 1``),
    ``rounds`` (coalesced sweep rounds ≈ the pre-coalescing outer
    iteration count), ``n``, and ``mode`` (``"fleet"``/``"grid"``; grid
    adds ``g`` and reports per-lane maxima plus totals)."""
    return dict(_LAST_RUN)


def compile_stats() -> list[dict]:
    """Every AOT compilation this process paid, with readable keys.

    One dict per ``_aot`` cache entry: ``n``, ``return_records``,
    ``grid``, ``g``, ``pallas`` plus the measured ``lower_s`` /
    ``compile_s`` walls. Benchmarks use this to report grid-executable
    compile time without re-deriving the cache key."""
    return [
        {
            "n": k[1],
            "return_records": k[2],
            "grid": k[3],
            "g": k[4],
            "pallas": k[5],
            **v,
        }
        for k, v in _COMPILE_STATS.items()
    ]


def carry_report(fleet, trace) -> dict:
    """Byte sizes of the compiled loop carries for one (fleet, trace).

    Shapes come from ``jax.eval_shape`` over the carry constructors (no
    tracing of the loop itself). ``record_bytes`` is the donated buffer
    set, which no longer rides the outer/drain carries."""
    cols = _as_columns(trace)
    spec, _, _ = _fleet_spec(fleet, cols)
    return _carry_report(spec, len(cols))


def _carry_report(spec: _SimSpec, n: int) -> dict:
    P = len(spec.pools)
    win = spec.win_size
    win_cap = (n // win + 2) if win > 0 else 1
    nb = max(P - 1, 1)

    def nbytes(tree) -> int:
        return int(
            sum(
                int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
                for leaf in jax.tree_util.tree_leaves(tree)
            )
        )

    with enable_x64():
        pools = jax.eval_shape(lambda: _init_pools(spec, n))
        wins = jax.eval_shape(lambda: _init_windows(P, nb, win_cap))
    rec_bytes = sum(
        (n + 1) * w * np.dtype(dt).itemsize for _, dt, w in _REC_DTYPES
    )
    sweep_rec = sum(
        (n + 1) * w * np.dtype(dt).itemsize
        for name, dt, w in _REC_DTYPES
        if name in ("recf", "reci", "rejt")
    )
    i4 = np.dtype(np.int32).itemsize
    scalars = 7 * i4  # a, win_seen, win_prev, wi, moves, iters, rounds
    th_bytes = (P - 1) * i4 + P * i4  # th + prev_err
    drain_pools = nbytes({k: pools[k] for k in _DRAIN_POOL_KEYS})
    drain = (
        drain_pools
        + nbytes(wins)
        + (n + 1) * i4  # pool column
        + th_bytes
        + 5 * i4  # a, win_seen, win_prev, wi, moves
    )
    sweep = nbytes(pools) + sweep_rec + i4  # + rounds
    outer = nbytes(pools) + nbytes(wins) + rec_bytes + th_bytes + scalars
    return {
        "carry_bytes": outer,
        "drain_carry_bytes": drain,
        "sweep_carry_bytes": sweep,
        "record_bytes": rec_bytes,
    }


def aot_compile(fleet, trace) -> dict:
    """Compile the single-lane executable for (fleet, trace) ahead of time.

    Returns the ``_COMPILE_STATS`` entry (``lower_s``, ``compile_s``)
    plus ``cached`` (True when the executable already existed, i.e. the
    times are from the original compilation). The subsequent
    ``run_fleet`` call for the same shape hits the cache and pays no
    compilation."""
    cols = _as_columns(trace)
    spec, _, _ = _fleet_spec(fleet, cols)
    key = (spec, len(cols), True, False, 0, _pallas_enabled())
    cached = key in _COMPILE_STATS
    with enable_x64():
        _aot(*key)
    stats = dict(_COMPILE_STATS[key])
    stats["cached"] = cached
    return stats


# ---------------------------------------------------------------------------
# Host-side routing precompute
# ---------------------------------------------------------------------------


def precompute_budget_trajectory(
    cols: TraceColumns,
    calibrator: EmaCalibrator,
    *,
    epoch_cap: int,
):
    """Per-request estimated budgets with epoch-lagged EMA feedback.

    Mirrors the vectorized backend's ramped routing epochs (64 doubling to
    ``epoch_cap``): requests in one epoch route with the EMA state as of
    the epoch start, then the epoch's observations fold in through the
    cached ``lax.scan`` kernel. The device loop then only needs a
    ``searchsorted`` per dispatch — thresholds stay honest vmap axes while
    the float EMA never enters the compiled loop. Approximation vs the
    host: observations fold in *arrival* order (host folds completions),
    which the routed-tolerance test class bounds.

    Both the estimate and the EMA fold go through the cached kernel
    factories (``("estimate", chunk, γ)`` / ``("observe", chunk, β)`` in
    ``kernel_trace_counts()``): epochs are padded to their ramp width, so
    the whole precompute compiles a handful of shapes once per process
    instead of dispatching eager ops per chunk. Padding rows carry
    ``prompt_tokens=0`` and are sliced off before use, so the budgets and
    the final EMA state are bit-identical to the unpadded fold.

    Returns ``(budgets int32 (n,), final CalibState)``.
    """
    n = len(cols)
    budgets = np.zeros(n, dtype=np.int32)
    state = calibrator.to_state()
    gamma = float(calibrator.gamma)
    beta = float(calibrator.beta)
    chunk = min(64, epoch_cap)
    pos = 0
    while pos < n:
        start = pos
        width = chunk  # kernel shape for this epoch (pre-ramp)
        pos = min(n, pos + chunk)
        chunk = min(epoch_cap, chunk * 2)
        m = pos - start
        pad = width - m
        cat = jnp.asarray(
            np.pad(np.asarray(cols.category[start:pos]), (0, pad)), jnp.int32
        )
        est = _estimate_budget_kernel(width, gamma)
        budgets[start:pos] = np.asarray(
            est(
                state,
                jnp.asarray(
                    np.pad(np.asarray(cols.byte_len[start:pos]), (0, pad))
                ),
                jnp.asarray(
                    np.pad(
                        np.asarray(cols.max_output_tokens[start:pos]), (0, pad)
                    )
                ),
                cat,
            )
        )[:m]
        upd = _update_stream_kernel(width, beta)
        state = upd(
            state,
            jnp.asarray(
                np.pad(
                    np.asarray(cols.byte_len[start:pos], np.float32), (0, pad)
                ),
                jnp.float32,
            ),
            jnp.asarray(
                np.pad(
                    np.asarray(
                        cols.true_input_tokens[start:pos], np.float32
                    ),
                    (0, pad),
                ),
                jnp.float32,
            ),
            cat,
        )
    return budgets, state


def _trace_arrays(cols: TraceColumns, budgets: Optional[np.ndarray]):
    n = len(cols)
    return {
        "arr": np.asarray(cols.arrival_time, np.float64),
        "inp": np.asarray(cols.true_input_tokens, np.int32),
        "outp": np.asarray(cols.true_output_tokens, np.int32),
        "budget": (
            np.zeros(n, np.int32) if budgets is None else budgets
        ),
    }


def _ctrl_params(controller, enabled: bool):
    """Controller gains as a traced scalar dict (a vmappable lane axis)."""
    if controller is None:
        return {
            "enabled": np.int32(0),
            "b_min": np.int32(512),
            "step": np.int32(DEFAULT_INCREASE_STEP),
            "factor": np.float32(DEFAULT_DECREASE_FACTOR),
            "err_hi": np.float32(DEFAULT_ERROR_RATE_HI),
            "over_hi": np.float32(DEFAULT_OVERLOAD_RATIO_HI),
        }
    return {
        "enabled": np.int32(1 if enabled else 0),
        "b_min": np.int32(controller.b_min),
        "step": np.int32(controller.increase_step),
        "factor": np.float32(controller.decrease_factor),
        "err_hi": np.float32(controller.error_rate_hi),
        "over_hi": np.float32(controller.overload_ratio_hi),
    }


def _as_columns(trace) -> TraceColumns:
    return (
        trace
        if isinstance(trace, TraceColumns)
        else TraceColumns.from_requests(trace)
    ).sorted_by_arrival()


def _fleet_spec(fleet, cols: TraceColumns):
    """Build the static spec for a live FleetSim (shared with the probes)."""
    ordered = sorted(fleet._pool_index, key=fleet._pool_index.get)
    shells = [fleet.pools[name] for name in ordered]
    spec = _SimSpec(
        # Capacities come from the live shells (not recomputed from the
        # config) so post-construction total_blocks overrides are honored.
        pools=tuple(
            _PoolSpec(
                name=name,
                c_max=int(s.config.c_max),
                n_seq=int(s.config.n_seq),
                total_blocks=int(s.total_blocks),
                max_inst=int(s.num_instances),
            )
            for name, s in zip(ordered, shells)
        ),
        w=float(fleet.timing.w_base),
        h=float(fleet.timing.h_per_seq),
        prefill_chunk=int(fleet.timing.prefill_chunk),
        win_size=int(fleet._win_size),
    )
    return spec, ordered, shells


# ---------------------------------------------------------------------------
# FleetSim backend entry (single lane)
# ---------------------------------------------------------------------------


def run_fleet_jax(fleet, trace):
    """Execute one fleet run on the compiled backend; returns FleetResult.

    Called by ``FleetSim.run`` for ``backend="jax"``. The fleet's
    ``VectorPoolSim`` shells receive the device-computed records and
    counters afterwards, so ``fleet.pools[name].record_arrays()``,
    telemetry replay, and ``router.stats()`` all behave like a host run.
    """
    # Import here: fleet imports this module lazily, and metrics/fleet
    # are imported lazily here, to keep the module graph acyclic.
    from repro.sim.fleet import FleetResult
    from repro.sim.metrics import summarize_columns

    cols = _as_columns(trace)
    n = len(cols)
    spec, ordered, shells = _fleet_spec(fleet, cols)
    P = len(spec.pools)

    router = fleet.router
    budgets = None
    if router is not None and n:
        epoch_cap = (
            fleet.epoch
            if fleet.controller is None
            else max(1, min(fleet.epoch, fleet.control_window))
        )
        budgets, final_state = precompute_budget_trajectory(
            cols, router.calibrator, epoch_cap=epoch_cap
        )
        router.calibrator.load_state(final_state)
        th0 = [int(b) for b in router.pools.thresholds]
    else:
        th0 = []

    lane = {
        "th": np.asarray(th0, np.int32),
        "ninst": np.asarray(
            [fleet.pools[name].num_instances for name in ordered], np.int32
        ),
        "ctrl": _ctrl_params(fleet.controller, enabled=True),
    }
    if self_telemetry := fleet.telemetry:
        self_telemetry.set_trace(
            cols.byte_len, cols.category, cols.true_input_tokens,
            cols.max_output_tokens,
        )

    if n == 0:
        empty = {k: np.empty(0, dt) for k, dt in (
            ("request_id", np.int64), ("arrival", np.float64),
            ("first_token", np.float64), ("finish", np.float64),
            ("output_tokens", np.int64), ("preemptions", np.int64),
            ("truncated", bool), ("rejected", bool),
        )}
        return FleetResult(
            summary=summarize_columns("fleet", empty),
            per_pool={name: summarize_columns(name, empty) for name in ordered},
            router_stats=router.stats() if router else {},
            preemptions=0, rejections=0, truncations=0,
            telemetry=fleet.telemetry, slo=fleet.slo,
        )

    with enable_x64():
        exe = _aot(spec, n, True, False, 0, _pallas_enabled())
        out = exe(_trace_arrays(cols, budgets), lane, _fresh_records(n))
        out = jax.tree_util.tree_map(np.asarray, out)
    _LAST_RUN.clear()
    _LAST_RUN.update(
        mode="fleet",
        n=n,
        iters=int(out["iters"]),
        rounds=int(out["rounds"]),
    )

    rec = _unpack_records(out["rec"], n)
    ids = np.asarray(cols.request_id, np.int64)
    arr = np.asarray(cols.arrival_time, np.float64)
    fleet_cols = {
        "request_id": ids,
        "arrival": arr,
        "first_token": rec["first"],
        "finish": rec["finish"],
        "output_tokens": rec["out"].astype(np.int64),
        "preemptions": rec["pre"].astype(np.int64),
        "truncated": rec["trunc"],
        "rejected": rec["rej"],
    }
    per_pool_cols = {}
    for idx, name in enumerate(ordered):
        m = rec["pool"] == idx
        pc = {k: v[m] for k, v in fleet_cols.items()}
        per_pool_cols[name] = pc
        shell = shells[idx]
        shell._records.add_bulk(*(pc[k] for k, _ in shell._records.COLUMNS))
        shell.preemption_count = int(out["preempt"][idx])
        shell.rejection_count = int(out["reject"][idx])
        shell.truncation_count = int(out["truncate"][idx])
        if router is not None:
            router.routed[name] += int(out["metrics"]["routed"][idx])

    final_th = [int(b) for b in out["th"][: P - 1]]
    if router is not None and fleet.controller is not None:
        router.pools.set_thresholds(final_th)
        _synthesize_history(fleet.controller, out, th0)

    t_end = float(out["metrics"]["t_end"])
    if fleet.telemetry is not None:
        _replay_telemetry(fleet, ordered, shells, spec, out, n, t_end, final_th)

    return FleetResult(
        summary=summarize_columns("fleet", fleet_cols),
        per_pool={
            name: summarize_columns(name, c)
            for name, c in per_pool_cols.items()
        },
        router_stats=router.stats() if router else {},
        preemptions=int(out["preempt"].sum()),
        rejections=int(out["reject"].sum()),
        truncations=int(out["truncate"].sum()),
        telemetry=fleet.telemetry,
        slo=fleet.slo,
    )


def _synthesize_history(controller, out, th0):
    """Rebuild a BoundaryMove trajectory from the device window snapshots.

    The device loop records the post-controller threshold vector at every
    window; diffing consecutive snapshots recovers when each boundary
    moved and to what value. The AIMD input signals are not re-derived —
    moves carry reason "device"."""
    nwin = int(out["nwin"])
    prev = list(th0)
    for w in range(nwin):
        cur = [int(b) for b in out["win"]["th"][w][: len(prev)]]
        for k, (a, b) in enumerate(zip(prev, cur)):
            if a != b:
                controller.history.append(
                    BoundaryMove(
                        t=int(out["win"]["t_req"][w]),
                        boundary=k,
                        value=b,
                        reason="device",
                    )
                )
        prev = cur


def _replay_telemetry(fleet, ordered, shells, spec, out, n, t_end, final_th):
    """Replay device window snapshots into the host FleetTelemetry.

    Same windows, same sampling order (controller's thresholds first,
    then the sample) as the host backends. Counter columns come from the
    device's cumulative per-pool counters; gauges (queue depth, active,
    kv_frac) from the snapshot state. The calibration-error series uses
    the final EMA state for every window (the device run does not carry
    the float EMA) — documented approximation."""
    telemetry = fleet.telemetry
    win = out["win"]
    nwin = int(out["nwin"])
    router = fleet.router
    prev_req = 0
    for name, shell in zip(ordered, shells):
        shell.blocks_free = np.zeros(shell.num_instances, dtype=np.int64)
    for w in range(nwin):
        for idx, shell in enumerate(shells):
            shell.preemption_count = int(win["pre"][w, idx])
            shell.rejection_count = int(win["rej"][w, idx])
            shell.truncation_count = int(win["trunc"][w, idx])
            shell.state.queue_depth = int(win["queue"][w, idx])
            shell.state.active = int(win["active"][w, idx])
            shell.blocks_free[:] = 0
            shell.blocks_free[0] = int(win["freeb"][w, idx])
        if router is not None and fleet.controller is not None:
            router.pools.set_thresholds(
                [int(b) for b in win["th"][w][: len(router.pools) - 1]]
            )
        t_req = int(win["t_req"][w])
        telemetry.sample(
            t_req=t_req, now=float(win["now"][w]), lo=prev_req, hi=t_req
        )
        prev_req = t_req
    # final flush (host _finish_windows): drained end state
    for idx, shell in enumerate(shells):
        shell.preemption_count = int(out["preempt"][idx])
        shell.rejection_count = int(out["reject"][idx])
        shell.truncation_count = int(out["truncate"][idx])
        shell.state.queue_depth = 0
        shell.state.active = 0
        shell.blocks_free[:] = spec.pools[idx].total_blocks
    if router is not None and fleet.controller is not None:
        router.pools.set_thresholds(final_th)
    telemetry.sample(t_req=n, now=t_end, lo=prev_req, hi=n)


# ---------------------------------------------------------------------------
# Vmapped sensitivity grids
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetGridResult:
    """Columnar results of one vmapped fleet sweep (G grid lanes).

    Per-lane reductions are computed on device over the *full* run (no
    warm-up discard — grid metrics are for relative comparisons across
    lanes; use a single-lane ``FleetSim`` run for paper-grade numbers).
    Percentiles are linear-interpolation (``jnp.nanpercentile``), not the
    nearest-rank convention of :func:`repro.sim.metrics.summarize`.
    """

    pool_names: tuple[str, ...]
    thresholds: np.ndarray  # (G, P-1) initial boundary vectors
    instances: np.ndarray  # (G, P) instance counts
    completed: np.ndarray  # (G,)
    rejected: np.ndarray  # (G,)
    truncated: np.ndarray  # (G,)
    preemptions: np.ndarray  # (G,) fleet total
    routed: np.ndarray  # (G, P) dispatches per pool
    ttft_mean: np.ndarray
    ttft_p50: np.ndarray
    ttft_p99: np.ndarray
    tpot_mean: np.ndarray
    tpot_p99: np.ndarray
    makespan: np.ndarray  # (G,) max finish − min arrival
    final_thresholds: np.ndarray  # (G, P-1) post-controller vectors
    controller_moves: np.ndarray  # (G,)
    #: (G, n) per-request record arrays when ``return_records=True``.
    records: Optional[dict] = None

    def __len__(self) -> int:
        return len(self.completed)

    def goodput(self) -> np.ndarray:
        """Completed non-truncated requests per second, per lane."""
        span = np.maximum(self.makespan, 1e-12)
        return (self.completed - self.truncated) / span


def _broadcast_axis(values, g: int, name: str):
    if len(values) == 1:
        return [values[0]] * g
    if len(values) != g:
        raise ValueError(
            f"grid axis {name!r} has length {len(values)}, expected 1 or {g}"
        )
    return list(values)


def run_fleet_grid(
    trace,
    pools: dict[str, tuple[PoolConfig, int]],
    timing: TimingModel,
    *,
    thresholds: Optional[Sequence[Sequence[int]]] = None,
    instances: Optional[Sequence[Sequence[int]]] = None,
    gains: Optional[Sequence[Optional[dict]]] = None,
    b_short: int = 8192,
    calibrator: Optional[EmaCalibrator] = None,
    epoch: int = 2048,
    control_window: int = 512,
    return_records: bool = False,
) -> FleetGridResult:
    """Run a whole sensitivity sweep as ONE vmapped device program.

    Grid axes (all optional, zip semantics — length G or 1, broadcast):

    ``thresholds``
        Sequence of boundary vectors (each length P−1, pool-budget order).
    ``instances``
        Sequence of per-pool instance-count vectors (length P). Lanes run
        padded to the max count with dead-lane masking, so mixed fleet
        sizes share one compiled program.
    ``gains``
        Sequence of AIMD controller parameter dicts (keys ``b_min``,
        ``increase_step``, ``decrease_factor``, ``error_rate_hi``,
        ``overload_ratio_hi`` — defaults from :mod:`repro.core.adaptive`),
        or ``None`` entries for uncontrolled lanes.

    Budgets are precomputed once on the host — the EMA feedback trajectory
    depends only on the observation stream, not on routing — so every lane
    shares the same budget array and the sweep stays exact w.r.t. the
    single-lane jax backend (asserted by the grid-parity test).
    """
    cols = _as_columns(trace)
    n = len(cols)
    if n == 0:
        raise ValueError("run_fleet_grid needs a non-empty trace")

    # Budget-ordered pool frame, like FleetSim.
    ordered = sorted(pools.items(), key=lambda kv: kv[1][0].c_max)
    names = tuple(name for name, _ in ordered)
    base_inst = [int(ni) for _, (_, ni) in ordered]
    configs = [cfg for _, (cfg, _) in ordered]
    P = len(ordered)

    if thresholds is None:
        if set(names) == {"short", "long"}:
            base_th = [min(b_short, configs[0].c_max)]
        else:
            base_th = [c.c_max for c in configs[:-1]]
        thresholds = [base_th]
    if instances is None:
        instances = [base_inst]
    if gains is None:
        gains = [None]

    g = max(len(thresholds), len(instances), len(gains))
    thresholds = _broadcast_axis(list(thresholds), g, "thresholds")
    instances = _broadcast_axis(list(instances), g, "instances")
    gains = _broadcast_axis(list(gains), g, "gains")

    th_arr = np.asarray(thresholds, np.int32).reshape(g, P - 1)
    inst_arr = np.asarray(instances, np.int32).reshape(g, P)
    any_ctrl = any(gn is not None for gn in gains)
    ctrl_rows = []
    for gn in gains:
        row = {
            "enabled": np.int32(0 if gn is None else 1),
            "b_min": np.int32((gn or {}).get("b_min", 512)),
            "step": np.int32(
                (gn or {}).get("increase_step", DEFAULT_INCREASE_STEP)
            ),
            "factor": np.float32(
                (gn or {}).get("decrease_factor", DEFAULT_DECREASE_FACTOR)
            ),
            "err_hi": np.float32(
                (gn or {}).get("error_rate_hi", DEFAULT_ERROR_RATE_HI)
            ),
            "over_hi": np.float32(
                (gn or {}).get("overload_ratio_hi", DEFAULT_OVERLOAD_RATIO_HI)
            ),
        }
        ctrl_rows.append(row)
    ctrl = {
        k: np.stack([r[k] for r in ctrl_rows]) for k in ctrl_rows[0]
    }

    spec = _SimSpec(
        pools=tuple(
            _pool_spec(name, cfg, int(inst_arr[:, j].max()))
            for j, (name, cfg) in enumerate(zip(names, configs))
        ),
        w=float(timing.w_base),
        h=float(timing.h_per_seq),
        prefill_chunk=int(timing.prefill_chunk),
        win_size=int(control_window) if any_ctrl else 0,
    )

    budgets = None
    if P > 1:
        cal = calibrator or EmaCalibrator()
        epoch_cap = (
            max(1, min(epoch, control_window)) if any_ctrl else epoch
        )
        budgets, _ = precompute_budget_trajectory(cols, cal, epoch_cap=epoch_cap)

    lane = {"th": th_arr, "ninst": inst_arr, "ctrl": ctrl}
    with enable_x64():
        exe = _aot(spec, n, return_records, True, g, _pallas_enabled())
        out = exe(_trace_arrays(cols, budgets), lane, _fresh_records(n, g))
        out = jax.tree_util.tree_map(np.asarray, out)
    _LAST_RUN.clear()
    _LAST_RUN.update(
        mode="grid",
        n=n,
        g=g,
        iters=int(out["iters"].max()),
        rounds=int(out["rounds"].max()),
        iters_total=int(out["iters"].sum()),
        rounds_total=int(out["rounds"].sum()),
    )

    m = out["metrics"]
    return FleetGridResult(
        pool_names=names,
        thresholds=th_arr,
        instances=inst_arr,
        completed=m["completed"].astype(np.int64),
        rejected=m["rejected"].astype(np.int64),
        truncated=m["truncated"].astype(np.int64),
        preemptions=out["preempt"].sum(axis=1).astype(np.int64),
        routed=m["routed"].astype(np.int64),
        ttft_mean=m["ttft_mean"],
        ttft_p50=m["ttft_p50"],
        ttft_p99=m["ttft_p99"],
        tpot_mean=m["tpot_mean"],
        tpot_p99=m["tpot_p99"],
        makespan=m["makespan"],
        final_thresholds=out["th"].reshape(g, P - 1)[:, : P - 1],
        controller_moves=out["moves"].astype(np.int64),
        records=(
            _unpack_records(out["rec"], n) if "rec" in out else None
        ),
    )
