"""Instance-level discrete-event simulator (paper Appendix A, layer 1).

Each vLLM-style engine is an *iteration-based continuous-batching server*:

* every iteration processes one prefill chunk of up to ``C`` tokens plus one
  decode token for every active-decoding sequence;
* block-level KV accounting (16-token blocks) gates admission; exhaustion
  during decode triggers vLLM-style preemption-by-recompute of the youngest
  sequence;
* iteration wall-clock time follows the linear-overhead roofline
  ``t_iter = W + H · n_active``.

The fleet layer (:mod:`repro.sim.fleet`) drives many instances plus the
token-budget router; this module is single-instance and time is advanced by
the caller, which makes it directly unit-testable.

This scalar engine is the **reference backend** (``backend="reference"``):
one Python object per sequence, one call per instance per iteration. The
struct-of-arrays **vectorized backend** (:mod:`repro.sim.vector_engine`,
``backend="vectorized"``) steps every instance of a pool in bulk NumPy ops
and must stay behaviourally equivalent to this implementation — the
equivalence suite in ``tests/test_vector_engine.py`` locks the two together.
When changing admission, preemption, truncation, or timing semantics here,
mirror the change there.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Optional

from repro.core.pools import (
    KV_BLOCK_TOKENS,
    PoolConfig,
    PoolState,
    TOTAL_KV_BLOCKS,
)
from repro.core.router import Request
from repro.obs.events import ADMIT, PREEMPT, REJECT, TRUNCATE
from repro.sim.metrics import RequestRecord
from repro.sim.timing import TimingModel


@dataclasses.dataclass
class _Seq:
    """One in-flight sequence inside an instance."""

    request: Request
    enqueue_time: float
    prefill_remaining: int
    decode_remaining: int
    generated: int = 0
    blocks: int = 0
    first_token_time: Optional[float] = None
    preemptions: int = 0
    truncated: bool = False

    @property
    def context_len(self) -> int:
        done_prefill = self.request.true_input_tokens - self.prefill_remaining
        return done_prefill + self.generated

    @property
    def decoding(self) -> bool:
        return self.prefill_remaining == 0 and self.decode_remaining > 0


def _blocks_for(tokens: int) -> int:
    return max(1, math.ceil(tokens / KV_BLOCK_TOKENS))


class InstanceSim:
    """One serving instance with `pool.n_seq` slots and a KV block budget."""

    def __init__(
        self,
        pool: PoolConfig,
        timing: TimingModel,
        *,
        total_blocks: Optional[int] = None,
        name: str = "instance",
        pool_state: Optional[PoolState] = None,
    ) -> None:
        self.pool = pool
        self.timing = timing
        self.name = name
        # Shared dispatch state, maintained *incrementally* on every
        # submit/admit/preempt/complete so the router reads O(1) counters
        # instead of sweeping all instances per arrival (paper §2.2).
        self.pool_state = pool_state
        # The block budget reserves C_max tokens per slot (the paper's
        # provisioning rule): n_seq slots x ceil(C_max/16) blocks.
        if total_blocks is None:
            total_blocks = min(
                TOTAL_KV_BLOCKS, pool.n_seq * _blocks_for(pool.c_max)
            )
        self.total_blocks = total_blocks
        self.blocks_free = total_blocks
        self.queue: deque[tuple[Request, float]] = deque()
        self.active: list[_Seq] = []
        self.records: list[RequestRecord] = []
        self.preemption_count = 0
        self.rejection_count = 0
        self.truncation_count = 0
        self.busy_time = 0.0
        self._carried_preemptions: dict[int, int] = {}
        # Optional event tracing (repro.obs): the fleet layer installs an
        # EventTrace and this instance's pool index. None (the default)
        # keeps every emission site a single predicate on the hot path.
        self.tracer = None
        self.pool_index = 0
        self._now = 0.0  # iteration-end time, maintained only when tracing
        # Fault-injection state (repro.sim.faults). Defaults are the
        # fault-free fast path: `now < 0.0` is false and `slow_factor`
        # stays exactly 1.0, so un-faulted runs are bit-identical.
        self.downed = False
        self.down_until = 0.0
        self.slow_factor = 1.0

    # -- queue interface (fleet layer) ---------------------------------------
    @property
    def load(self) -> int:
        return len(self.queue) + len(self.active)

    @property
    def idle(self) -> bool:
        return not self.queue and not self.active

    def _state_add(self, d_queue: int, d_active: int) -> None:
        if self.pool_state is not None:
            self.pool_state.queue_depth += d_queue
            self.pool_state.active += d_active

    def submit(self, request: Request, now: float) -> bool:
        """Enqueue a request; reject if the prompt alone exceeds C_max."""
        if request.true_input_tokens >= self.pool.c_max:
            self.rejection_count += 1
            if self.tracer is not None:
                self.tracer.emit(
                    REJECT, now, self.pool_index, request.request_id
                )
            self.records.append(
                RequestRecord(
                    request_id=request.request_id,
                    pool=self.pool.name,
                    arrival=request.arrival_time,
                    first_token=now,
                    finish=now,
                    output_tokens=0,
                    rejected=True,
                )
            )
            return False
        self.queue.append((request, now))
        self._state_add(+1, 0)
        return True

    # -- admission ------------------------------------------------------------
    def _try_admit(self, now: float) -> None:
        while self.queue and len(self.active) < self.pool.n_seq:
            request, enq = self.queue[0]
            need = _blocks_for(request.true_input_tokens)
            if need > self.total_blocks:
                # can never fit, even on an empty instance → reject
                self.queue.popleft()
                self._state_add(-1, 0)
                self.rejection_count += 1
                if self.tracer is not None:
                    self.tracer.emit(
                        REJECT, now, self.pool_index, request.request_id
                    )
                self.records.append(
                    RequestRecord(
                        request_id=request.request_id,
                        pool=self.pool.name,
                        arrival=request.arrival_time,
                        first_token=now,
                        finish=now,
                        output_tokens=0,
                        rejected=True,
                    )
                )
                continue
            if need > self.blocks_free:
                break  # head-of-line: wait for blocks
            self.queue.popleft()
            self._state_add(-1, +1)
            self.blocks_free -= need
            if self.tracer is not None:
                self.tracer.emit(
                    ADMIT, now, self.pool_index, request.request_id
                )
            self.active.append(
                _Seq(
                    request=request,
                    enqueue_time=enq,
                    prefill_remaining=request.true_input_tokens,
                    decode_remaining=request.true_output_tokens,
                    blocks=need,
                    preemptions=self._carried_preemptions.get(
                        request.request_id, 0
                    ),
                )
            )

    # -- preemption (vLLM recompute mode: youngest victims, batch rule) --------
    def _evict_victims(self, victims: list[_Seq]) -> None:
        """Preempt ``victims`` (given in admission order): free their blocks
        and requeue them recompute-style at the queue head, preserving
        admission order among the group (vLLM behaviour)."""
        for seq in victims:
            self.active.remove(seq)
            self.blocks_free += seq.blocks
            seq.blocks = 0
            seq.preemptions += 1
            self.preemption_count += 1
            if self.tracer is not None:
                self.tracer.emit(
                    PREEMPT, self._now, self.pool_index, seq.request.request_id
                )
            self._carried_preemptions[seq.request.request_id] = seq.preemptions
        for seq in reversed(victims):
            # Recompute mode: restart prefill over prompt + generated-so-far
            # with the original output budget.
            req = seq.request
            restart = dataclasses.replace(
                req, true_input_tokens=req.true_input_tokens + seq.generated
            )
            self.queue.appendleft((restart, seq.enqueue_time))
        self._state_add(+len(victims), -len(victims))

    # -- fault application (repro.sim.faults) ----------------------------------
    def _drop_sequences(self, victims: list[_Seq], requeue: bool) -> list[int]:
        """Destroy in-flight sequences; requeue locally or report them lost.

        Victims must be in admission order; requeue preserves that order at
        the head of the queue (recompute-style, generated tokens folded into
        the prompt). Returns the lost request ids (empty when requeueing).
        """
        for seq in victims:
            self.blocks_free += seq.blocks
            seq.blocks = 0
        self._state_add(0, -len(victims))
        if requeue:
            for seq in reversed(victims):
                req = seq.request
                self._carried_preemptions[req.request_id] = seq.preemptions
                restart = dataclasses.replace(
                    req, true_input_tokens=req.true_input_tokens + seq.generated
                )
                self.queue.appendleft((restart, seq.enqueue_time))
            self._state_add(+len(victims), 0)
            return []
        lost = [seq.request.request_id for seq in victims]
        for rid in lost:
            self._carried_preemptions.pop(rid, None)
        return lost

    def fault_crash(self, now: float, requeue: bool) -> list[int]:
        """Hard crash: every in-flight sequence is dropped.

        Downtime itself is handled by the fleet via ``down_until`` — the
        instance's pending iteration event self-reschedules through the
        early return in :meth:`step`.
        """
        victims = self.active
        self.active = []
        return self._drop_sequences(victims, requeue)

    def fault_oom(self, now: float, evict_frac: float, requeue: bool) -> list[int]:
        """KV-OOM kill: evict the youngest ``evict_frac`` of resident seqs."""
        n = len(self.active)
        if n == 0:
            return []
        k = min(n, max(1, math.ceil(evict_frac * n)))
        victims = self.active[n - k :]
        del self.active[n - k :]
        return self._drop_sequences(victims, requeue)

    # -- one engine iteration ---------------------------------------------------
    def step(self, now: float) -> tuple[float, list[RequestRecord]]:
        """Run one iteration starting at `now`; returns (t_iter, completions)."""
        if now < self.down_until:
            # Crashed: sleep (not busy) until recovery, then resume. Queued
            # work survives; admission happens at recovery time.
            return self.down_until - now, []
        self._try_admit(now)
        if not self.active:
            return 0.0, []

        n_active = len(self.active)
        t_iter = self.timing.iter_time(n_active)
        if self.slow_factor != 1.0:
            t_iter *= self.slow_factor
        end = now + t_iter
        if self.tracer is not None:
            self._now = end  # timestamp for mid-iteration preempt events
        completed: list[RequestRecord] = []

        # 1) One prefill chunk of up to C tokens (oldest prefilling sequence).
        budget = self.timing.prefill_chunk
        for seq in self.active:
            if seq.prefill_remaining > 0 and budget > 0:
                chunk = min(seq.prefill_remaining, budget)
                seq.prefill_remaining -= chunk
                budget -= chunk
                # Blocks were reserved for the whole prompt at admission
                # (the paper's point: chunking does NOT shrink KV footprint).
                break  # a single chunk per iteration (Appendix A)

        # 2) One decode token per active-decoding sequence — *order-free batch
        # semantics*, shared verbatim with the vectorized and jax backends:
        #   a. advance every decoding sequence one token (prefill→decode
        #      fusion: a sequence whose last prefill chunk landed this
        #      iteration emits its first token in the same iteration);
        #   b. truncate sequences that hit C_max mid-generation;
        #   c. completions free their blocks (completion credit) *before*
        #      KV growth is resolved;
        #   d. if the survivors' block growth exceeds blocks_free, evict the
        #      minimal youngest-first prefix of decoding survivors (max
        #      enqueue_time first, first-admitted tie-break) whose freed
        #      blocks cover the deficit — one batch decision per iteration,
        #      with no dependence on within-iteration sequence order.
        done: list[_Seq] = []
        growers: list[_Seq] = []  # admission order (self.active invariant)
        for seq in self.active:
            if not seq.decoding:
                continue
            if seq.first_token_time is None:
                seq.first_token_time = end
            seq.generated += 1
            seq.decode_remaining -= 1

            # Context-window truncation (hits C_max mid-generation).
            if seq.context_len >= self.pool.c_max and seq.decode_remaining > 0:
                seq.truncated = True
                seq.decode_remaining = 0
                self.truncation_count += 1
                if self.tracer is not None:
                    self.tracer.emit(
                        TRUNCATE, end, self.pool_index, seq.request.request_id
                    )
            if seq.decode_remaining == 0:
                done.append(seq)
            else:
                growers.append(seq)

        # c) Completion credit: finished sequences release their blocks
        # before growth is charged.
        for seq in done:
            self.active.remove(seq)
            self._state_add(0, -1)
            self.blocks_free += seq.blocks
            completed.append(
                RequestRecord(
                    request_id=seq.request.request_id,
                    pool=self.pool.name,
                    arrival=seq.request.arrival_time,
                    first_token=seq.first_token_time or end,
                    finish=end,
                    output_tokens=seq.generated,
                    preemptions=seq.preemptions,
                    truncated=seq.truncated,
                )
            )

        # d) KV growth: a new block every KV_BLOCK_TOKENS generated tokens.
        grow = [
            _blocks_for(s.request.true_input_tokens + s.generated) - s.blocks
            for s in growers
        ]
        demand = sum(grow)
        if demand > self.blocks_free:
            # Youngest-first eviction order; `sorted` is stable, so ties on
            # enqueue_time keep admission order (first-admitted evicted
            # first — the reference `max()` victim rule).
            order = sorted(
                range(len(growers)), key=lambda j: -growers[j].enqueue_time
            )
            supply = self.blocks_free
            evicted: set[int] = set()
            for j in order:
                if demand <= supply:
                    break
                demand -= grow[j]
                supply += growers[j].blocks
                evicted.add(j)
            self._evict_victims([growers[j] for j in sorted(evicted)])
            growers = [s for j, s in enumerate(growers) if j not in evicted]
        for seq in growers:
            need = _blocks_for(seq.request.true_input_tokens + seq.generated)
            self.blocks_free -= need - seq.blocks
            seq.blocks = need

        self.records.extend(completed)
        self.busy_time += t_iter
        return t_iter, completed
