"""Linear-overhead roofline timing model (paper Appendix A, Eq. 9).

    t_iter = W + H · n_active

W — base per-iteration cost (model-weight HBM read amortized over pipeline
stages and AllReduce overlap); H — per-active-sequence overhead (KV-cache
attention reads, sampling, scheduler bookkeeping).

Calibrations:

* ``A100_LLAMA3_70B`` — the paper's defaults (W=8.0 ms, H=0.65 ms), used to
  reproduce Tables 1–3.
* ``MI300X_QWEN3`` — §4.7 projection constants. The paper sizes the
  homogeneous MI300X fleet at 197 nodes for 10,000 req/s (Table 5); we
  back-derive (W, H) from that operating point and the 4× concurrency ratio
  (derivation in benchmarks/table5_mi300x.py).
* ``TPU_V5E_REF`` — our TPU adaptation: W from weight HBM read per chip
  (bytes/819 GB/s over the TP group), H from per-sequence KV read at the
  pool's mean context. Used by the serving engine's performance model.

The physics behind W and H on TPU v5e: a decode iteration must stream the
(TP-sharded) weights once (W) and each active sequence's KV pages once (H·n),
both bounded by HBM bandwidth — exactly the memory-roofline decomposition
used in EXPERIMENTS.md §Roofline for decode shapes.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class TimingModel:
    """t_iter = W + H·n_active, with a chunked-prefill token budget C."""

    name: str
    w_base: float  # seconds
    h_per_seq: float  # seconds
    prefill_chunk: int = 512  # C tokens per iteration (Appendix A)

    def iter_time(self, n_active: int) -> float:
        return self.w_base + self.h_per_seq * n_active

    def iter_time_batch(self, n_active: np.ndarray) -> np.ndarray:
        """Vectorized roofline: t_iter for a whole fleet of instances.

        Computed as ``W + H·n`` with the same float64 operation order as
        :meth:`iter_time` so the vectorized simulator backend reproduces the
        scalar backend's event times bit-for-bit.
        """
        return self.w_base + self.h_per_seq * n_active.astype(np.float64)

    def constants_f64(self) -> tuple[np.ndarray, np.ndarray]:
        """(W, H) as float64 scalars for device backends.

        Event times are IEEE-754 double accumulations of ``W + H·n`` terms;
        the jax backend (:mod:`repro.sim.jax_engine`) must carry them at
        float64 (x64 mode) and multiply/add in the same order as
        :meth:`iter_time` to stay bit-identical with the host backends.
        Handing the constants out pre-coerced keeps that dtype discipline in
        one place — a float32 W would silently poison every event time.
        """
        return np.float64(self.w_base), np.float64(self.h_per_seq)

    def iterations_for(self, l_in: int, l_out: int) -> int:
        """ceil(L_in/C) prefill iterations + L_out decode iterations."""
        return math.ceil(max(1, l_in) / self.prefill_chunk) + max(1, l_out)

    def service_time(self, l_in: int, l_out: int, n_active: int) -> float:
        """S = iters · t_iter at a given occupancy (Appendix A)."""
        return self.iterations_for(l_in, l_out) * self.iter_time(n_active)

    def throughput(self, mean_iters: float, n_slots: int) -> float:
        """μ = n_slots / E[S] at full occupancy (Appendix A calibration)."""
        return n_slots / (mean_iters * self.iter_time(n_slots))


#: Paper's calibration for Llama-3-70B on A100 (Appendix A).
A100_LLAMA3_70B = TimingModel(name="a100-llama3-70b", w_base=8.0e-3, h_per_seq=0.65e-3)

#: §4.7 projection constants (see benchmarks/table5_mi300x.py for derivation).
MI300X_QWEN3 = TimingModel(name="mi300x-qwen3-235b", w_base=1.6e-3, h_per_seq=0.062e-3)


def tpu_v5e_model(
    *,
    weight_bytes_total: float,
    tensor_parallel: int,
    kv_bytes_per_token: float,
    mean_context: float,
    hbm_bw: float = 819e9,
    overlap_factor: float = 0.55,
    sched_overhead: float = 0.25e-3,
) -> TimingModel:
    """Derive (W, H) for TPU v5e from first principles.

    W: one full weight read per iteration per chip, discounted by
    ``overlap_factor`` for collective/compute overlap (the XLA latency-hiding
    scheduler overlaps the TP all-reduces with the next layer's weight
    streams). H: one KV read of the sequence's mean context per step, plus
    fixed per-sequence scheduler/sampling overhead.
    """
    w = (weight_bytes_total / tensor_parallel) / hbm_bw * (1.0 + overlap_factor)
    h = (kv_bytes_per_token / tensor_parallel) * mean_context / hbm_bw
    return TimingModel(
        name=f"tpu-v5e(tp={tensor_parallel})",
        w_base=w,
        h_per_seq=h + sched_overhead / 1000.0,
    )
