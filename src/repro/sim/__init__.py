"""Discrete-event simulator for fleet sizing / latency / reliability
(paper Appendix A: instance DES, analytical profiler, fleet verification)."""

from repro.sim.engine import InstanceSim
from repro.sim.fleet import FleetResult, FleetSim, PoolSim, run_fleet
from repro.sim.metrics import RequestRecord, SimSummary, percentile, summarize
from repro.sim.profiler import (
    HEADROOM,
    FleetPlan,
    PoolProfile,
    mean_iterations,
    plan_fleet,
    profile_pool,
    sensitivity_sweep,
    split_by_budget,
)
from repro.sim.timing import (
    A100_LLAMA3_70B,
    MI300X_QWEN3,
    TimingModel,
    tpu_v5e_model,
)

__all__ = [
    "InstanceSim",
    "FleetResult",
    "FleetSim",
    "PoolSim",
    "run_fleet",
    "RequestRecord",
    "SimSummary",
    "percentile",
    "summarize",
    "HEADROOM",
    "FleetPlan",
    "PoolProfile",
    "mean_iterations",
    "plan_fleet",
    "profile_pool",
    "sensitivity_sweep",
    "split_by_budget",
    "A100_LLAMA3_70B",
    "MI300X_QWEN3",
    "TimingModel",
    "tpu_v5e_model",
]
