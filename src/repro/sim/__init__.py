"""Discrete-event simulator for fleet sizing / latency / reliability
(paper Appendix A: instance DES, analytical profiler, fleet verification).

Three interchangeable fleet backends (``FleetSim(backend=...)``):

* ``"reference"`` — scalar engine (:mod:`repro.sim.engine`): one Python
  object per sequence; ground truth for unit tests.
* ``"vectorized"`` — struct-of-arrays engine
  (:mod:`repro.sim.vector_engine`): all instances of a pool step together
  in masked NumPy ops with event-distance jumps, epoch-batched N-way JAX
  routing and EMA sync, consuming traces natively as
  :class:`~repro.traces.generator.TraceColumns`; 10×+ faster at fleet
  scale (``benchmarks/sim_throughput.py``) and behaviourally equivalent
  (``tests/test_vector_engine.py``).
* ``"jax"`` — fully compiled engine (:mod:`repro.sim.jax_engine`): the
  whole event loop as a jitted ``lax.while_loop`` over fixed-shape slot
  arrays, bit-identical to the host backends in the exact classes.
  Its batched sweep API :func:`run_fleet_grid` ``vmap``\\ s entire fleet
  simulations across threshold / instance-count / controller-gain axes —
  5×+ faster than the serial vectorized loop on ≥16-point sensitivity
  grids once the one-off XLA compile is amortized. Prefer ``vectorized``
  for one-off runs with faults / spillover / event tracing; prefer
  ``jax`` for grids and controller tuning.

Fleets route over a budget-ordered :class:`~repro.core.pools.PoolSet` —
any pool count, the paper's short/long pair being P=2.

Fault injection (:mod:`repro.sim.faults`): pass
``FleetSim(..., injector=FaultInjector(specs), retry_policy=RetryPolicy())``
to subject either backend to instance crashes, KV-OOM kills, and transient
slowdowns with retry/timeout/backoff and health-gated routing. Both
backends implement identical fault semantics; fault-off runs are
bit-identical to pre-fault builds.
"""

from repro.sim.engine import InstanceSim
from repro.sim.faults import FaultInjector, FaultRuntime, FaultSpec, RetryPolicy
from repro.sim.fleet import FleetResult, FleetSim, PoolSim, run_fleet
from repro.sim.jax_engine import FleetGridResult, run_fleet_grid
from repro.sim.metrics import (
    PAPER_SLO,
    RequestRecord,
    SimSummary,
    SLOTarget,
    concat_record_columns,
    percentile,
    summarize,
    summarize_columns,
)
from repro.sim.vector_engine import VectorPoolSim
from repro.sim.profiler import (
    HEADROOM,
    FleetPlan,
    PoolProfile,
    mean_iterations,
    plan_fleet,
    profile_pool,
    sensitivity_sweep,
    split_by_budget,
)
from repro.sim.timing import (
    A100_LLAMA3_70B,
    MI300X_QWEN3,
    TimingModel,
    tpu_v5e_model,
)

__all__ = [
    "InstanceSim",
    "FaultInjector",
    "FaultRuntime",
    "FaultSpec",
    "RetryPolicy",
    "FleetResult",
    "FleetSim",
    "PoolSim",
    "run_fleet",
    "FleetGridResult",
    "run_fleet_grid",
    "RequestRecord",
    "SimSummary",
    "SLOTarget",
    "PAPER_SLO",
    "concat_record_columns",
    "percentile",
    "summarize",
    "summarize_columns",
    "VectorPoolSim",
    "HEADROOM",
    "FleetPlan",
    "PoolProfile",
    "mean_iterations",
    "plan_fleet",
    "profile_pool",
    "sensitivity_sweep",
    "split_by_budget",
    "A100_LLAMA3_70B",
    "MI300X_QWEN3",
    "TimingModel",
    "tpu_v5e_model",
]
