"""Struct-of-arrays vectorized pool simulator (``backend="vectorized"``).

The scalar reference engine (:mod:`repro.sim.engine`) models one sequence as
one Python object and one instance-iteration as one method call — perfect for
unit tests, painfully slow for million-request fleet sweeps. This module
re-expresses the *same* iteration semantics as dense NumPy arrays:

* per-slot state lives in ``(num_instances, n_seq)`` arrays
  (``prefill_remaining``, ``decode_remaining``, ``generated``, ``blocks``,
  …) and per-instance state in ``(num_instances,)`` arrays
  (``blocks_free``, ``next_wake``, ``load``);
* one *round* advances every due instance by ``k ≥ 1`` engine iterations in
  bulk masked array ops, where ``k`` is the per-instance distance to the
  next discrete event (completion, context-window truncation, prefill
  chunk, KV-pressure, or the sweep horizon) — between events all iterations
  are identical, so jumping is exact;
* iteration wall-clock times come from the ``t_iter = W + H·n_active``
  roofline in one vectorized expression
  (:meth:`repro.sim.timing.TimingModel.iter_time_batch`).

Equivalence contract with the scalar engine
-------------------------------------------
Admission (head-of-line FIFO with block reservation), KV-block growth, and
truncation are replicated exactly. KV-pressure rounds — where block growth
would exceed ``blocks_free`` — use the *order-free batch preemption rule*
shared verbatim by all three backends (reference, vectorized, jax): advance
→ truncate → completion credit → evict the minimal youngest-first prefix of
decoding survivors whose freed blocks cover the growth deficit (vLLM-style
preemption-by-recompute, enqueue-time descending with admission-order
tie-break). Because the rule is a single batch decision per iteration, it
vectorizes as a lexsort + cumsum masked pass here and as a ``jnp.where``
victim-selection pass in :mod:`repro.sim.jax_engine`, with no scalar
fallback. ``tests/test_vector_engine.py`` asserts record-level equality on
seeded preemption-heavy traces (with power-of-two timing constants so float
accumulation is exact in both backends).
"""

from __future__ import annotations

from collections import deque
from typing import Optional

import numpy as np

from repro.core.pools import (
    KV_BLOCK_TOKENS,
    PoolConfig,
    PoolState,
    TOTAL_KV_BLOCKS,
)
from repro.core.router import Request
from repro.obs.events import ADMIT, PREEMPT, REJECT, TRUNCATE
from repro.sim.engine import _blocks_for  # single source for KV rounding
from repro.sim.metrics import RequestRecord
from repro.sim.timing import TimingModel

#: Sentinel for "no constraint" in integer min-reductions.
_BIG = np.int64(1) << 62
_BIGF = 1.0e18

#: Queue entries are tuples to keep the admission loop allocation-light:
#: (request_id, arrival, input_tokens, output_tokens, enqueue, preemptions).
_QID, _QARR, _QIN, _QOUT, _QENQ, _QPRE = range(6)


class _ColumnStore:
    """Columnar request-record accumulator (bulk chunks + scalar buffer)."""

    COLUMNS = (
        ("request_id", np.int64),
        ("arrival", np.float64),
        ("first_token", np.float64),
        ("finish", np.float64),
        ("output_tokens", np.int64),
        ("preemptions", np.int64),
        ("truncated", np.bool_),
        ("rejected", np.bool_),
    )

    def __init__(self) -> None:
        self._chunks: list[tuple[np.ndarray, ...]] = []
        self._buffer: list[tuple] = []

    def add_bulk(self, *arrays: np.ndarray) -> None:
        if len(arrays[0]):
            self._chunks.append(tuple(np.ascontiguousarray(a) for a in arrays))

    def add_one(self, *values) -> None:
        self._buffer.append(values)

    def __len__(self) -> int:
        return sum(len(c[0]) for c in self._chunks) + len(self._buffer)

    def _flush(self) -> None:
        if self._buffer:
            cols = list(zip(*self._buffer))
            self._chunks.append(
                tuple(
                    np.asarray(col, dtype=dt)
                    for col, (_, dt) in zip(cols, self.COLUMNS)
                )
            )
            self._buffer.clear()

    def arrays(self) -> dict[str, np.ndarray]:
        """Concatenate every chunk into one array per column."""
        self._flush()
        if not self._chunks:
            return {
                name: np.empty(0, dtype=dt) for name, dt in self.COLUMNS
            }
        return {
            name: np.concatenate([c[j] for c in self._chunks])
            for j, (name, dt) in enumerate(self.COLUMNS)
        }


class VectorPoolSim:
    """All instances of one pool, stepped together as dense arrays.

    Drop-in behavioural twin of ``PoolSim`` + ``InstanceSim`` for the fleet
    layer: ``least_loaded``/``submit`` dispatch, ``sweep(t_limit)`` advances
    every instance through all engine iterations that start strictly before
    ``t_limit`` (matching the reference heap's arrival-first tie-break).
    """

    def __init__(
        self,
        config: PoolConfig,
        num_instances: int,
        timing: TimingModel,
        *,
        total_blocks: Optional[int] = None,
        name: Optional[str] = None,
    ) -> None:
        self.config = config
        self.timing = timing
        self.name = name or config.name
        if total_blocks is None:
            total_blocks = min(
                TOTAL_KV_BLOCKS, config.n_seq * _blocks_for(config.c_max)
            )
        self.total_blocks = total_blocks
        self.num_instances = num_instances
        self.state = PoolState(config=config, num_instances=num_instances)

        ii, ss = num_instances, config.n_seq
        # Token/block counts fit comfortably in int32 (c_max ≤ 65536); the
        # narrower dtype halves the memory traffic of the hot round.
        # -- per-slot SoA state, shape (I, S) --------------------------------
        self.occupied = np.zeros((ii, ss), dtype=bool)
        self.req_id = np.full((ii, ss), -1, dtype=np.int64)
        self.arrival = np.zeros((ii, ss), dtype=np.float64)
        self.enqueue = np.zeros((ii, ss), dtype=np.float64)
        self.input_tokens = np.zeros((ii, ss), dtype=np.int32)  # incl. recompute
        self.output_tokens = np.zeros((ii, ss), dtype=np.int32)  # original L_out
        self.prefill_remaining = np.zeros((ii, ss), dtype=np.int32)
        self.decode_remaining = np.zeros((ii, ss), dtype=np.int32)
        self.generated = np.zeros((ii, ss), dtype=np.int32)
        self.blocks = np.zeros((ii, ss), dtype=np.int32)
        self.first_token = np.full((ii, ss), np.nan, dtype=np.float64)
        self.truncated = np.zeros((ii, ss), dtype=bool)
        self.preempt_carried = np.zeros((ii, ss), dtype=np.int32)
        self.seq_no = np.zeros((ii, ss), dtype=np.int64)  # admission order
        # -- per-instance state, shape (I,) ----------------------------------
        self.blocks_free = np.full(ii, total_blocks, dtype=np.int64)
        self.next_wake = np.full(ii, np.inf, dtype=np.float64)
        self.n_active = np.zeros(ii, dtype=np.int64)
        self.queue_len = np.zeros(ii, dtype=np.int64)
        self.load = np.zeros(ii, dtype=np.int64)  # queue + active
        self.busy_time = np.zeros(ii, dtype=np.float64)
        self.queues: list[deque] = [deque() for _ in range(ii)]

        self.wake_min = np.inf
        self.preemption_count = 0
        self.rejection_count = 0
        self.truncation_count = 0
        self._seq_counter = 0
        self._records = _ColumnStore()
        self._completed_ids: list[np.ndarray] = []
        # Optional event tracing (repro.obs): installed by the fleet layer;
        # None keeps the fast-path rounds free of any telemetry work.
        self.tracer = None
        self.pool_index = 0
        # Fault-injection lanes (repro.sim.faults): per-instance slowdown
        # factors and down masks, applied as masked array ops inside the
        # round. ``_faulty`` stays False on fault-free runs so the hot path
        # is one extra predicate, exactly like ``tracer is None``.
        self._faulty = False
        self._n_down = 0
        self.slow = np.ones(ii, dtype=np.float64)
        self.down = np.zeros(ii, dtype=bool)
        self.down_until = np.zeros(ii, dtype=np.float64)

    # -- dispatch interface (fleet layer) ------------------------------------
    @property
    def preemptions(self) -> int:
        return self.preemption_count

    @property
    def rejections(self) -> int:
        return self.rejection_count

    @property
    def truncations(self) -> int:
        return self.truncation_count

    @property
    def busy(self) -> bool:
        return bool(np.isfinite(self.wake_min))

    def kv_occupancy(self) -> float:
        """Pool-wide KV block utilization: 1 − blocks_free / total_blocks."""
        cap = self.total_blocks * self.num_instances
        return 1.0 - float(self.blocks_free.sum()) / cap if cap else 0.0

    def least_loaded(self) -> int:
        """First instance with minimal load — same tie-break as the
        reference path's ``min(instances, key=load)``.

        Down instances are ejected from dispatch (masked to an impossible
        load); when *every* instance is down, dispatch falls back to plain
        least-loaded so requests queue for recovery instead of vanishing.
        """
        if 0 < self._n_down < self.num_instances:
            return int(np.argmin(np.where(self.down, _BIG, self.load)))
        return int(np.argmin(self.load))

    def submit(self, instance: int, request: Request, now: float) -> bool:
        """Enqueue a Request object on one instance (reference-parity API)."""
        return self.submit_raw(
            instance,
            request.request_id,
            request.arrival_time,
            request.true_input_tokens,
            request.true_output_tokens,
            now,
        )

    def submit_raw(
        self,
        instance: int,
        request_id: int,
        arrival: float,
        true_input_tokens: int,
        true_output_tokens: int,
        now: float,
    ) -> bool:
        """Columnar-native enqueue (scalar fields, no Request object);
        rejects if the prompt alone exceeds C_max."""
        if true_input_tokens >= self.config.c_max:
            self.rejection_count += 1
            if self.tracer is not None:
                self.tracer.emit(REJECT, now, self.pool_index, request_id)
            self._records.add_one(
                request_id, arrival, now, now, 0, 0, False, True,
            )
            return False
        self.queues[instance].append(
            (request_id, arrival, true_input_tokens, true_output_tokens, now, 0)
        )
        self.queue_len[instance] += 1
        self.load[instance] += 1
        self.state.queue_depth += 1
        if not np.isfinite(self.next_wake[instance]):
            t0 = now
            if (
                self._faulty
                and self.down[instance]
                and now < self.down_until[instance]
            ):
                # Reference parity: a sleeping crashed instance woken by a
                # submit self-reschedules to its recovery time.
                t0 = float(self.down_until[instance])
            self.next_wake[instance] = t0
            self.wake_min = min(self.wake_min, t0)
        return True

    # -- records -------------------------------------------------------------
    def record_arrays(self) -> dict[str, np.ndarray]:
        return self._records.arrays()

    @property
    def records(self) -> list[RequestRecord]:
        """Materialize RequestRecord objects (tests / debugging only)."""
        cols = self.record_arrays()
        return [
            RequestRecord(
                request_id=int(cols["request_id"][j]),
                pool=self.config.name,
                arrival=float(cols["arrival"][j]),
                first_token=float(cols["first_token"][j]),
                finish=float(cols["finish"][j]),
                output_tokens=int(cols["output_tokens"][j]),
                preemptions=int(cols["preemptions"][j]),
                truncated=bool(cols["truncated"][j]),
                rejected=bool(cols["rejected"][j]),
            )
            for j in range(len(cols["request_id"]))
        ]

    def drain_completed_ids(self) -> np.ndarray:
        """Request ids completed since the last drain (for router feedback)."""
        if not self._completed_ids:
            return np.empty(0, dtype=np.int64)
        out = np.concatenate(self._completed_ids)
        self._completed_ids.clear()
        return out

    # -- admission (exact mirror of InstanceSim._try_admit) ------------------
    def _try_admit(self, i: int, now: float) -> None:
        q = self.queues[i]
        n_seq = self.config.n_seq
        while q and self.n_active[i] < n_seq:
            entry = q[0]
            need = _blocks_for(entry[_QIN])
            if need > self.total_blocks:
                q.popleft()
                self.queue_len[i] -= 1
                self.load[i] -= 1
                self.state.queue_depth -= 1
                self.rejection_count += 1
                if self.tracer is not None:
                    self.tracer.emit(REJECT, now, self.pool_index, entry[_QID])
                self._records.add_one(
                    entry[_QID], entry[_QARR], now, now, 0, 0, False, True
                )
                continue
            if need > self.blocks_free[i]:
                break  # head-of-line: wait for blocks
            q.popleft()
            self.queue_len[i] -= 1
            self.state.queue_depth -= 1
            self.state.active += 1
            self.blocks_free[i] -= need
            self.n_active[i] += 1
            if self.tracer is not None:
                self.tracer.emit(ADMIT, now, self.pool_index, entry[_QID])
            slot = int(np.argmin(self.occupied[i]))  # first free slot
            self.occupied[i, slot] = True
            self.req_id[i, slot] = entry[_QID]
            self.arrival[i, slot] = entry[_QARR]
            self.enqueue[i, slot] = entry[_QENQ]
            self.input_tokens[i, slot] = entry[_QIN]
            self.output_tokens[i, slot] = entry[_QOUT]
            self.prefill_remaining[i, slot] = entry[_QIN]
            self.decode_remaining[i, slot] = entry[_QOUT]
            self.generated[i, slot] = 0
            self.blocks[i, slot] = need
            self.first_token[i, slot] = np.nan
            self.truncated[i, slot] = False
            self.preempt_carried[i, slot] = entry[_QPRE]
            self.seq_no[i, slot] = self._seq_counter
            self._seq_counter += 1

    # -- fault application (repro.sim.faults) --------------------------------
    def install_faults(self) -> None:
        """Arm the per-round fault lanes (slowdown multiply, down masks)."""
        self._faulty = True

    def set_down(self, instance: int, down: bool, until: float = 0.0) -> None:
        if down and not self.down[instance]:
            self._n_down += 1
        if not down and self.down[instance]:
            self._n_down -= 1
        self.down[instance] = down
        if down:
            self.down_until[instance] = until

    def set_slow(self, instance: int, factor: float) -> None:
        self.slow[instance] = factor

    def _drop_slots(self, i: int, order: np.ndarray, requeue: bool) -> list[int]:
        """Destroy the given slots (admission order); requeue or report lost.

        Mirrors ``InstanceSim._drop_sequences``: blocks freed, recompute-
        style head-of-queue reinsertion preserving admission order.
        """
        k = len(order)
        if k == 0:
            return []
        self.blocks_free[i] += int(self.blocks[i, order].sum())
        self.blocks[i, order] = 0
        self.occupied[i, order] = False
        self.n_active[i] -= k
        self.state.active -= k
        if requeue:
            for s in order[::-1]:
                self.queues[i].appendleft(
                    (
                        int(self.req_id[i, s]),
                        float(self.arrival[i, s]),
                        int(self.input_tokens[i, s] + self.generated[i, s]),
                        int(self.output_tokens[i, s]),
                        float(self.enqueue[i, s]),
                        int(self.preempt_carried[i, s]),
                    )
                )
            self.queue_len[i] += k
            self.state.queue_depth += k
            return []
        self.load[i] -= k
        return [int(self.req_id[i, s]) for s in order]

    def fault_crash(self, instance: int, now: float, requeue: bool) -> list[int]:
        """Hard crash: drop all in-flight sequences, sleep until recovery.

        Call :meth:`set_down` first so the reschedule below sees the
        recovery time. Queued work survives; the pending wake becomes
        ``max(pending wake, down_until)`` — exactly when the reference
        instance's self-rescheduling heap event next admits (its in-heap
        event fires at the old time and either admits there, post-recovery,
        or re-sleeps until ``down_until``). A crash on an idle instance
        leaves it asleep; ``submit_raw``'s downtime guard covers later
        arrivals.
        """
        i = instance
        slots = np.flatnonzero(self.occupied[i])
        order = slots[np.argsort(self.seq_no[i, slots], kind="stable")]
        lost = self._drop_slots(i, order, requeue)
        nw = float(self.next_wake[i])
        if np.isfinite(nw):
            self.next_wake[i] = max(nw, float(self.down_until[i]))
            self.wake_min = float(self.next_wake.min())
        return lost

    def fault_oom(
        self, instance: int, now: float, evict_frac: float, requeue: bool
    ) -> list[int]:
        """KV-OOM kill: evict the youngest ``evict_frac`` of resident seqs
        (last in admission order — the same direction preemption victims
        go). The instance itself stays up."""
        i = instance
        slots = np.flatnonzero(self.occupied[i])
        n = len(slots)
        if n == 0:
            return []
        order = slots[np.argsort(self.seq_no[i, slots], kind="stable")]
        k = min(n, max(1, int(np.ceil(evict_frac * n))))
        return self._drop_slots(i, order[n - k :], requeue)

    # -- masked-lane pass for KV-pressure rounds (k == 1) --------------------
    def _pressure_rows(
        self,
        gi: np.ndarray,
        decp: np.ndarray,
        now: np.ndarray,
        t_it: np.ndarray,
        end: np.ndarray,
    ) -> None:
        """Decode phase for lanes whose block growth exceeds ``blocks_free``.

        Implements the order-free batch semantics shared with the reference
        engine's ``step()`` and the jax backend's compiled round: advance
        every decoding lane one token → truncate at C_max → completions free
        their blocks (completion credit) → evict the minimal youngest-first
        prefix of decoding survivors whose freed blocks cover the remaining
        growth deficit → allocate growth. Victim selection is one lexsort +
        cumsum pass per lane (``enqueue`` descending, admission order
        tie-break) — no per-sequence Python loop, no dependence on
        within-iteration sequence order.
        """
        c_max = self.config.c_max
        inp = self.input_tokens[gi]
        gen = self.generated[gi] + decp  # a) advance one token
        rem = self.decode_remaining[gi] - decp
        ft = self.first_token[gi]
        ft = np.where(decp & np.isnan(ft), (now + t_it)[:, None], ft)

        # b) context-window truncation at C_max mid-generation
        trunc = decp & (inp + gen >= c_max) & (rem > 0)
        rem = np.where(trunc, 0, rem)
        trunc_all = self.truncated[gi] | trunc
        self.truncation_count += int(trunc.sum())
        if self.tracer is not None and trunc.any():
            for ri, si in zip(*np.nonzero(trunc)):
                self.tracer.emit(
                    TRUNCATE,
                    float(end[ri]),
                    self.pool_index,
                    int(self.req_id[gi[ri], si]),
                )

        self.generated[gi] = gen
        self.decode_remaining[gi] = rem
        self.first_token[gi] = ft
        self.truncated[gi] = trunc_all

        # c) completion credit: finished lanes release their blocks before
        # growth is charged.
        comp = decp & (rem == 0)
        if comp.any():
            ri, si = np.nonzero(comp)
            ci = gi[ri]
            self._records.add_bulk(
                self.req_id[ci, si],
                self.arrival[ci, si],
                ft[ri, si],
                end[ri],
                gen[ri, si],
                self.preempt_carried[ci, si],
                trunc_all[ri, si],
                np.zeros(len(ri), dtype=bool),
            )
            self._completed_ids.append(self.req_id[ci, si].copy())
            np.add.at(self.blocks_free, ci, self.blocks[ci, si])
            self.blocks[ci, si] = 0
            self.occupied[ci, si] = False
            done_per_row = np.bincount(ri, minlength=len(gi)).astype(np.int64)
            self.n_active[gi] -= done_per_row
            self.load[gi] -= done_per_row
            self.state.active -= len(ri)

        # d) growth deficit + minimal youngest-first prefix eviction
        surv = decp & (rem > 0)
        blk = self.blocks[gi]
        need = np.where(
            surv,
            np.maximum(1, (inp + gen + (KV_BLOCK_TOKENS - 1)) // KV_BLOCK_TOKENS),
            blk,
        )
        grow = np.where(surv, need - blk, 0)
        demand = grow.sum(axis=1)
        free = self.blocks_free[gi]

        # Victim order per lane: enqueue descending (youngest first),
        # admission order (seq_no) tie-break; non-candidates sort last.
        keyq = np.where(surv, -self.enqueue[gi], np.inf)
        order = np.lexsort((self.seq_no[gi], keyq), axis=1)
        sblk = np.take_along_axis(np.where(surv, blk, 0), order, axis=1)
        sgrow = np.take_along_axis(grow, order, axis=1)
        # Evicting the first j victims frees cum(blocks) and cancels
        # cum(grow); both sides are monotone in j, so the first prefix that
        # covers the deficit is minimal. j == 0 means no eviction (growth
        # fits once completion credit is applied).
        okj = demand[:, None] - np.cumsum(sgrow, axis=1) <= (
            free[:, None] + np.cumsum(sblk, axis=1)
        )
        j = np.where(demand <= free, 0, np.argmax(okj, axis=1) + 1)
        evict = np.zeros_like(surv)
        np.put_along_axis(
            evict, order, np.arange(okj.shape[1])[None, :] < j[:, None], axis=1
        )
        evict &= surv

        if evict.any():
            self.preemption_count += int(evict.sum())
            for r in np.flatnonzero(evict.any(axis=1)):
                i = int(gi[r])
                slots = np.flatnonzero(evict[r])
                vorder = slots[np.argsort(self.seq_no[i, slots], kind="stable")]
                if self.tracer is not None:
                    for s in vorder:
                        self.tracer.emit(
                            PREEMPT,
                            float(end[r]),
                            self.pool_index,
                            int(self.req_id[i, s]),
                        )
                self.blocks_free[i] += int(self.blocks[i, vorder].sum())
                # Recompute mode: requeue at the head preserving admission
                # order among the victim group, prompt += generated-so-far,
                # original output budget (reference engine semantics).
                for s in vorder[::-1]:
                    self.queues[i].appendleft(
                        (
                            int(self.req_id[i, s]),
                            float(self.arrival[i, s]),
                            int(self.input_tokens[i, s] + gen[r, s]),
                            int(self.output_tokens[i, s]),
                            float(self.enqueue[i, s]),
                            int(self.preempt_carried[i, s]) + 1,
                        )
                    )
                nv = len(vorder)
                self.occupied[i, vorder] = False
                self.blocks[i, vorder] = 0
                self.n_active[i] -= nv
                self.queue_len[i] += nv
                self.state.queue_depth += nv
                self.state.active -= nv

        # e) allocate growth to the remaining survivors
        keep = surv & ~evict
        self.blocks_free[gi] -= np.where(keep, grow, 0).sum(axis=1)
        self.blocks[gi] = np.where(keep, need, self.blocks[gi])

    # -- the vectorized round ------------------------------------------------
    def sweep(self, t_limit: float = np.inf) -> None:
        """Run every engine iteration starting strictly before ``t_limit``."""
        while self.wake_min < t_limit:
            self._round(t_limit)

    def _round(self, t_limit: float) -> None:
        due = np.flatnonzero(self.next_wake < t_limit)
        # Admission first, exactly like the reference step() prologue.
        for i in due[self.queue_len[due] > 0]:
            self._try_admit(i, float(self.next_wake[i]))

        nact = self.n_active[due]
        busy = nact > 0
        # Instances with nothing admitted go back to sleep (reference: idle
        # instances leave the wake heap). A non-empty queue here means the
        # head is future-dated relative to this instance — cannot happen,
        # but a defensive retry avoids a livelock if it ever does.
        idle_rows = due[~busy]
        if len(idle_rows):
            has_q = self.queue_len[idle_rows] > 0
            self.next_wake[idle_rows] = np.where(
                has_q, self.next_wake[idle_rows] + 1e-9, np.inf
            )
        rows = due[busy]
        if not len(rows):
            self.wake_min = float(self.next_wake.min())
            return

        nact = nact[busy]
        now = self.next_wake[rows]
        t_it = self.timing.iter_time_batch(nact)
        if self._faulty:
            # Straggler lanes: per-instance iteration-time multiplier.
            # Multiplying by exactly 1.0 is a bit-exact no-op, so healthy
            # lanes are unaffected (reference parity: base time first,
            # then the factor).
            t_it = t_it * self.slow[rows]

        # 1) One prefill chunk of up to C tokens to the oldest prefilling
        #    sequence of each instance (admission order == seq_no order).
        occ = self.occupied[rows]
        pre = self.prefill_remaining[rows]
        pmask = occ & (pre > 0)
        has_pre = pmask.any(axis=1)
        if has_pre.any():
            key = np.where(pmask, self.seq_no[rows], _BIG)
            oldest = key.argmin(axis=1)
            pr = np.flatnonzero(has_pre)
            gi, gs = rows[pr], oldest[pr]
            take = np.minimum(
                self.prefill_remaining[gi, gs], self.timing.prefill_chunk
            )
            self.prefill_remaining[gi, gs] -= take
            pre[pr, oldest[pr]] -= take  # keep the local copy in sync

        # 2) Decode phase. ``dec`` is the decoding mask at round start —
        #    sequences whose final prefill chunk just landed are included
        #    (prefill→decode fusion, as in the reference engine).
        dec = occ & (pre == 0) & (self.decode_remaining[rows] > 0)
        dec_rem = self.decode_remaining[rows]
        gen = self.generated[rows]
        inp = self.input_tokens[rows]
        ctx0 = inp + gen

        # Event-distance jump: k iterations are identical until the nearest
        # completion / truncation / prefill boundary / sweep horizon.
        k_complete = np.where(dec, dec_rem, _BIG).min(axis=1)
        k_trunc = np.where(dec, self.config.c_max - ctx0, _BIG).min(axis=1)
        with np.errstate(invalid="ignore"):
            q = (t_limit - now) / t_it
        k_time = np.where(np.isfinite(q), np.ceil(q - 1e-9), _BIGF)
        k = np.minimum(np.minimum(k_complete, k_trunc).astype(np.float64), k_time)
        k = np.where(has_pre, 1.0, np.maximum(k, 1.0))
        k = np.minimum(k, float(_BIG)).astype(np.int64)

        # KV growth over the whole jump; shrink to k=1 (and then to the
        # exact scalar fallback) when blocks_free cannot absorb it.
        blocks_r = self.blocks[rows]

        def growth(kk: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
            new_gen = gen + np.where(dec, kk[:, None], 0)
            need = np.where(
                occ,
                np.maximum(
                    1, (inp + new_gen + (KV_BLOCK_TOKENS - 1)) // KV_BLOCK_TOKENS
                ),
                0,
            )
            grow = np.maximum(need - blocks_r, 0)
            return need, grow.sum(axis=1)

        need_end, total_grow = growth(k)
        over = total_grow > self.blocks_free[rows]
        if over.any():
            k = np.where(over, 1, k)
            need_end, total_grow = growth(k)
            pressure = total_grow > self.blocks_free[rows]
        else:
            pressure = np.zeros(len(rows), dtype=bool)

        end = now + k * t_it
        self.busy_time[rows] += k * t_it

        # -- vectorized fast path (no preemption possible) -------------------
        v = np.flatnonzero(~pressure)
        if len(v):
            gv = rows[v]
            decv = dec[v]
            kv = k[v][:, None]
            endv = end[v]

            ft = self.first_token[gv]
            ft_new = np.where(
                decv & np.isnan(ft), (now[v] + t_it[v])[:, None], ft
            )
            gen_after = gen[v] + np.where(decv, kv, 0)
            rem_after = dec_rem[v] - np.where(decv, kv, 0)

            # context-window truncation at C_max mid-generation
            trunc = decv & (inp[v] + gen_after >= self.config.c_max) & (
                rem_after > 0
            )
            rem_after = np.where(trunc, 0, rem_after)
            trunc_all = self.truncated[gv] | trunc
            self.truncation_count += int(trunc.sum())
            if self.tracer is not None and trunc.any():
                for ri, si in zip(*np.nonzero(trunc)):
                    self.tracer.emit(
                        TRUNCATE,
                        float(endv[ri]),
                        self.pool_index,
                        int(self.req_id[gv[ri], si]),
                    )

            grow_v = np.maximum(need_end[v] - blocks_r[v], 0)
            self.blocks_free[gv] -= grow_v.sum(axis=1)
            self.blocks[gv] = np.where(occ[v], need_end[v], blocks_r[v])

            comp = decv & (rem_after == 0)
            self.generated[gv] = gen_after
            self.decode_remaining[gv] = rem_after
            self.first_token[gv] = ft_new
            self.truncated[gv] = trunc_all

            if comp.any():
                ri, si = np.nonzero(comp)
                gi = gv[ri]
                self._records.add_bulk(
                    self.req_id[gi, si],
                    self.arrival[gi, si],
                    ft_new[ri, si],
                    endv[ri],
                    gen_after[ri, si],
                    self.preempt_carried[gi, si],
                    trunc_all[ri, si],
                    np.zeros(len(ri), dtype=bool),
                )
                self._completed_ids.append(self.req_id[gi, si].copy())
                np.add.at(self.blocks_free, gi, self.blocks[gi, si])
                self.blocks[gi, si] = 0
                self.occupied[gi, si] = False
                done_per_row = np.bincount(ri, minlength=len(v)).astype(np.int64)
                self.n_active[gv] -= done_per_row
                self.load[gv] -= done_per_row
                self.state.active -= len(ri)

        # -- masked-lane pass for KV-pressure rounds (k == 1) ----------------
        pj = np.flatnonzero(pressure)
        if len(pj):
            self._pressure_rows(rows[pj], dec[pj], now[pj], t_it[pj], end[pj])

        # 3) Reschedule: wake at iteration end while work remains.
        alive_rows = (self.n_active[rows] > 0) | (self.queue_len[rows] > 0)
        self.next_wake[rows] = np.where(alive_rows, end, np.inf)
        self.wake_min = float(self.next_wake.min())
