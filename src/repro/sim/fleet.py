"""Fleet-level discrete-event simulation (paper Appendix A, layer 3).

Drives N instances per pool plus the token-budget router over a trace:

* arrivals are routed with Algorithm 1 over a budget-ordered
  :class:`~repro.core.pools.PoolSet` — any number of pools, the paper's
  short/long pair being the P=2 case (calibrated estimates + spillover,
  reading live queue depths);
* each instance runs the iteration-level engine; instance wake-ups are a
  single heapq (reference backend) or a coalesced per-pool sweep
  (vectorized backend);
* responses feed ``usage.prompt_tokens`` back into the router's EMA.

Three interchangeable backends behind ``FleetSim(backend=...)``:

``"reference"``
    The scalar engine of :mod:`repro.sim.engine` — one Python object per
    sequence, one heap pop per instance iteration, one router call and one
    EMA update per request. Ground truth for unit tests.

``"vectorized"``
    The struct-of-arrays engine of :mod:`repro.sim.vector_engine` — all
    instances of a pool step together in masked NumPy ops, instances that
    share a wake-up epoch advance in one coalesced round, routing happens
    per-epoch through :func:`repro.core.router.jax_route_batch` (N-way
    integer pool ids), and EMA calibration feedback syncs once per epoch
    (:meth:`repro.core.calibration.EmaCalibrator.observe_batch`). Traces
    are consumed natively in columnar form
    (:class:`~repro.traces.generator.TraceColumns`) — no per-request
    ``Request`` objects on the hot path. ~10–100× faster at fleet scale;
    behaviourally equivalent (exactly so for routerless pools, within
    calibration-lag tolerance for routed fleets) — see
    ``tests/test_vector_engine.py``.

``"jax"``
    The fully compiled engine of :mod:`repro.sim.jax_engine` — the whole
    event loop as one jitted ``lax.while_loop`` over fixed-shape slot
    arrays, bit-identical to the host backends in the exact classes and
    tolerance-equivalent on routed fleets (documented approximations:
    arrival-ordered calibration feedback, spillover off). Fault injection
    and event tracing are not supported (``FleetSim`` raises); windowed
    telemetry is replayed into the host registry after the run. Its
    :func:`repro.sim.jax_engine.run_fleet_grid` vmaps whole
    threshold/instance/controller-gain sweeps as one device computation —
    prefer it for sensitivity grids, the vectorized tier for one-off runs.

All backends accept either a ``Sequence[Request]`` or a ``TraceColumns``;
the reference backend materializes objects from columns, the columnar
backends columnarize an object list once at entry.

The router reads O(1) ``PoolState`` counters that the engines maintain
incrementally on every submit/admit/preempt/complete — dispatch never
sweeps instances (the paper's O(1) claim, §2.2).

This verifies that the analytically-sized fleet (profiler layer) meets the
SLO under Poisson arrivals — the "definitive numbers" path of the paper.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.adaptive import AdaptiveController
from repro.core.calibration import EmaCalibrator
from repro.core.pools import PoolConfig, PoolSet, PoolState
from repro.core.router import Request, TokenBudgetRouter
from repro.obs.events import (
    ARRIVAL,
    DISPATCH,
    RETRY,
    ROUTER_TRACK,
    SPILL,
    THRESHOLD_MOVE,
    EventTrace,
)
from repro.obs.timeseries import FleetTelemetry, TelemetryConfig
from repro.sim.engine import InstanceSim
from repro.sim.faults import FaultInjector, FaultRuntime, RetryPolicy
from repro.sim.metrics import (
    PAPER_SLO,
    RequestRecord,
    SimSummary,
    SLOTarget,
    concat_record_columns,
    summarize,
    summarize_columns,
)
from repro.sim.timing import TimingModel
from repro.sim.vector_engine import VectorPoolSim
from repro.traces.generator import TraceColumns

Trace = Union[Sequence[Request], TraceColumns]


class PoolSim:
    """A pool of identical instances with join-least-loaded dispatch."""

    def __init__(
        self, config: PoolConfig, num_instances: int, timing: TimingModel
    ) -> None:
        self.config = config
        self.state = PoolState(config=config, num_instances=num_instances)
        self.instances = [
            InstanceSim(
                config,
                timing,
                name=f"{config.name}[{i}]",
                pool_state=self.state,
            )
            for i in range(num_instances)
        ]
        self._n_down = 0

    def refresh_state(self) -> None:
        """Recompute the dispatch counters from scratch.

        The engines maintain ``state.queue_depth``/``state.active``
        incrementally, so this is a consistency check / repair hook rather
        than a per-arrival necessity (it used to be O(instances) on every
        route call).
        """
        self.state.queue_depth = sum(len(i.queue) for i in self.instances)
        self.state.active = sum(len(i.active) for i in self.instances)

    def least_loaded(self) -> InstanceSim:
        # Health gating (fault injection): down instances are ejected from
        # dispatch; with every instance down, fall back to plain least-
        # loaded so requests queue for recovery instead of vanishing. Same
        # tie-break as the vectorized backend's masked argmin.
        if 0 < self._n_down < len(self.instances):
            return min(
                (i for i in self.instances if not i.downed),
                key=lambda i: i.load,
            )
        return min(self.instances, key=lambda i: i.load)

    # -- fault application (repro.sim.faults) --------------------------------
    def install_faults(self) -> None:
        """API twin of ``VectorPoolSim.install_faults`` (the reference
        instances check their fault fields unconditionally)."""

    def set_down(self, instance: int, down: bool, until: float = 0.0) -> None:
        inst = self.instances[instance]
        if down and not inst.downed:
            self._n_down += 1
        if not down and inst.downed:
            self._n_down -= 1
        inst.downed = down
        if down:
            inst.down_until = until

    def set_slow(self, instance: int, factor: float) -> None:
        self.instances[instance].slow_factor = factor

    def fault_crash(self, instance: int, now: float, requeue: bool) -> list[int]:
        return self.instances[instance].fault_crash(now, requeue)

    def fault_oom(
        self, instance: int, now: float, evict_frac: float, requeue: bool
    ) -> list[int]:
        return self.instances[instance].fault_oom(now, evict_frac, requeue)

    def kv_occupancy(self) -> float:
        """Pool-wide KV block utilization: 1 − blocks_free / total_blocks."""
        cap = sum(i.total_blocks for i in self.instances)
        free = sum(i.blocks_free for i in self.instances)
        return 1.0 - free / cap if cap else 0.0

    @property
    def records(self) -> list[RequestRecord]:
        return [r for inst in self.instances for r in inst.records]

    @property
    def preemptions(self) -> int:
        return sum(i.preemption_count for i in self.instances)

    @property
    def rejections(self) -> int:
        return sum(i.rejection_count for i in self.instances)

    @property
    def truncations(self) -> int:
        return sum(i.truncation_count for i in self.instances)


@dataclasses.dataclass
class FleetResult:
    summary: SimSummary
    per_pool: dict[str, SimSummary]
    router_stats: dict
    preemptions: int
    rejections: int
    #: Mid-generation context-window truncations across the fleet — the
    #: third component of the adaptive controller's error signal.
    truncations: int = 0
    #: Fault-injection counters (zero on fault-free runs): re-dispatches of
    #: requests whose in-flight state a fault destroyed, deadline drops,
    #: retry-budget drops, and instance-level fault applications
    #: (crashes + KV-OOM kills).
    retries: int = 0
    timeouts: int = 0
    shed: int = 0
    instance_failures: int = 0
    #: Up instance-seconds / total instance-seconds over [0, t_end].
    availability: float = 1.0
    #: Canonical per-request outcomes — every submitted request appears
    #: exactly once (completed, truncated, or rejected). Populated by the
    #: reference backend; the vectorized backend keeps outcomes columnar
    #: for speed and leaves this None — reach per-request data through
    #: ``FleetSim.pools[name].record_arrays()`` (or ``.records`` to
    #: materialize RequestRecord objects) on the vectorized pools.
    records: Optional[list[RequestRecord]] = None
    #: Fleet-level terminal-failure records (``pool="fleet"``,
    #: ``rejected=True``) for requests dropped by fault injection after
    #: exhausting retries or their deadline. Populated by BOTH backends
    #: (they are few); already folded into ``summary`` and — on the
    #: reference backend — into ``records``, but absent from ``per_pool``.
    fail_records: list[RequestRecord] = dataclasses.field(default_factory=list)
    #: Windowed time series (+ optional event trace at ``telemetry.events``)
    #: from :mod:`repro.obs`; populated when the fleet ran with telemetry.
    telemetry: Optional[FleetTelemetry] = None
    #: The SLO this fleet is evaluated against (``meets_slo()``).
    slo: SLOTarget = PAPER_SLO

    def meets_slo(self) -> bool:
        return self.summary.meets_slo(self.slo)

    def goodput(self) -> float:
        """Useful throughput: completed non-truncated requests per second."""
        s = self.summary
        if s.makespan <= 0:
            return 0.0
        return (s.completed - s.truncated) / s.makespan


class FleetSim:
    """Token-budget-routed fleet over any budget-ordered pool topology.

    ``pools`` maps pool name → ``(PoolConfig, num_instances)``. One pool
    runs routerless (the homogeneous baseline); two or more pools get a
    :class:`~repro.core.router.TokenBudgetRouter` over the budget-ordered
    :class:`~repro.core.pools.PoolSet`. Routing thresholds come from
    ``thresholds`` (ascending, one fewer than the pool count); when omitted
    they default to each non-last pool's ``C_max`` — except for the classic
    ``{"short", "long"}`` pair, where ``b_short`` keeps its original
    meaning as the single boundary.

    Closed-loop adaptive control (paper §7/§8) is a first-class hook:
    pass ``controller=AdaptiveController(...)`` and every
    ``control_window`` dispatched requests the fleet reports windowed
    per-pool error deltas (preemptions + rejections + truncations) plus
    live queue depths, and the controller moves the PoolSet boundaries in
    place — the router's hot path sees the new thresholds immediately.
    Both backends fire the hook on the same request-count windows; the
    vectorized backend caps its routing epoch at the control window so a
    boundary move is never stale by more than one window.
    """

    def __init__(
        self,
        pools: dict[str, tuple[PoolConfig, int]],
        timing: TimingModel,
        *,
        b_short: int = 8192,
        thresholds: Optional[Sequence[int]] = None,
        calibrator: Optional[EmaCalibrator] = None,
        spillover: bool = True,
        backend: str = "reference",
        epoch: int = 2048,
        coalesce_dt: Optional[float] = None,
        controller: Optional[AdaptiveController] = None,
        control_window: int = 512,
        telemetry: Union[bool, TelemetryConfig, None] = None,
        slo: SLOTarget = PAPER_SLO,
        injector: Optional[FaultInjector] = None,
        retry_policy: Optional[RetryPolicy] = None,
    ) -> None:
        if backend not in ("reference", "vectorized", "jax"):
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self.epoch = epoch
        # Arrivals within one wake-up epoch step together (vectorized
        # backend): dispatch state is synced once per window instead of per
        # arrival, trading ≤ one-iteration staleness for ~10× fatter rounds.
        # 0.0 → sync at every arrival (exact reference event order).
        self.coalesce_dt = (
            timing.iter_time(1) if coalesce_dt is None else coalesce_dt
        )
        self.timing = timing
        if backend in ("vectorized", "jax"):
            # The jax backend computes on device and back-fills these
            # VectorPoolSim shells with records/counters afterwards, so
            # per-pool introspection works identically across backends.
            self.pools = {
                name: VectorPoolSim(cfg, n, timing)
                for name, (cfg, n) in pools.items()
            }
        else:
            self.pools = {
                name: PoolSim(cfg, n, timing) for name, (cfg, n) in pools.items()
            }
        self.router: Optional[TokenBudgetRouter] = None
        if len(self.pools) > 1:
            states = sorted(
                (p.state for p in self.pools.values()),
                key=lambda s: s.config.c_max,
            )
            if thresholds is None:
                if set(self.pools) == {"short", "long"}:
                    thresholds = [b_short]
                else:
                    thresholds = [s.config.c_max for s in states[:-1]]
            self.router = TokenBudgetRouter(
                pools=PoolSet(states, thresholds),
                calibrator=calibrator or EmaCalibrator(),
                spillover=spillover,
            )
        # -- closed-loop adaptive control (first-class hook) -----------------
        self.controller = controller
        self.control_window = int(control_window)
        self._ctrl_pools: list = []
        if controller is not None:
            if self.router is None:
                raise ValueError("adaptive control needs at least two pools")
            if self.control_window <= 0:
                raise ValueError("control_window must be positive")
            controller.bind(self.router.pools)
            # Pool sims in PoolSet budget order (the controller's frame),
            # matched by the shared PoolState identity.
            by_state = {id(p.state): p for p in self.pools.values()}
            self._ctrl_pools = [
                by_state[id(s)] for s in self.router.pools.states
            ]
            self._ctrl_prev_errors = [0] * len(self._ctrl_pools)

        # -- telemetry / event tracing (repro.obs) ----------------------------
        self.slo = slo
        if telemetry is True:
            telemetry = TelemetryConfig()
        self.telemetry: Optional[FleetTelemetry] = None
        self.tracer: Optional[EventTrace] = None
        # Pool sims in PoolSet budget order (the frame thresholds and the
        # controller use) — declaration order for the routerless baseline.
        if self.router is not None:
            by_state = {
                id(p.state): (name, p) for name, p in self.pools.items()
            }
            ordered = [by_state[id(s)] for s in self.router.pools.states]
        else:
            ordered = list(self.pools.items())
        self._pool_index = {name: i for i, (name, _) in enumerate(ordered)}
        # -- fault injection (repro.sim.faults) -------------------------------
        # Built in the same budget-ordered frame as telemetry and the
        # controller; None keeps every fault hook off the hot path.
        self.injector = injector
        self.retry_policy = retry_policy
        self._fault_rt: Optional[FaultRuntime] = None
        if injector is not None and backend == "jax":
            raise ValueError(
                "fault injection is not supported on the jax backend; "
                "use backend='vectorized' for chaos runs"
            )
        if injector is not None:
            for _, p in ordered:
                p.install_faults()
            self._fault_rt = FaultRuntime(
                injector,
                retry_policy,
                [name for name, _ in ordered],
                [p for _, p in ordered],
            )
        elif retry_policy is not None:
            raise ValueError("retry_policy has no effect without injector=")
        if telemetry is not None:
            self.telemetry = FleetTelemetry(
                telemetry,
                [name for name, _ in ordered],
                [p for _, p in ordered],
                router=self.router,
                health=self._fault_rt,
            )
            self.tracer = self.telemetry.events
            if self.tracer is not None and backend == "jax":
                raise ValueError(
                    "event tracing (telemetry events=True) is not supported "
                    "on the jax backend; windowed time series are"
                )
            if self.tracer is not None:
                for idx, (_, p) in enumerate(ordered):
                    engines = (
                        p.instances if isinstance(p, PoolSim) else (p,)
                    )
                    for eng in engines:
                        eng.tracer = self.tracer
                        eng.pool_index = idx
        if self._fault_rt is not None:
            self._fault_rt.tracer = self.tracer
        # Sampling/monitoring windows, counted in dispatched requests. With
        # a controller the window IS the control window (telemetry samples
        # land exactly on controller boundaries); telemetry alone may pick
        # its own window.
        self._win_size = 0
        if controller is not None:
            self._win_size = self.control_window
        elif self.telemetry is not None:
            self._win_size = int(
                self.telemetry.config.window or self.control_window
            )
            if self._win_size <= 0:
                raise ValueError("telemetry window must be positive")
        self._win_seen = 0
        self._win_prev_seen = 0
        self._ctrl_hist_len = 0

    # -- adaptive control ----------------------------------------------------
    def _control_step(self) -> None:
        """One monitoring window: report per-pool deltas, move boundaries.

        Errors follow the controller contract — preemptions + rejections +
        **truncations** accumulated since the previous window; queue depths
        and instance counts are the live O(1) PoolState counters (no
        instance sweep on the hot path). ``window_requests`` is the
        *actual* dispatched-request delta since the previous step, so the
        error rate stays correctly normalized even when the vectorized
        backend's coalesced rounds overshoot the nominal window.
        """
        totals = [
            p.preemptions + p.rejections + p.truncations
            for p in self._ctrl_pools
        ]
        self.controller.update(
            window_requests=self._win_seen - self._win_prev_seen,
            errors=[t - s for t, s in zip(totals, self._ctrl_prev_errors)],
            queues=[p.state.queue_depth for p in self._ctrl_pools],
            instances=[p.state.num_instances for p in self._ctrl_pools],
            t=self._win_seen,
        )
        self._ctrl_prev_errors = totals

    # -- monitoring windows (control + telemetry) -----------------------------
    def _win_tick(self, n: int, now: float) -> None:
        """Advance the dispatched-request counter by ``n``; close one
        monitoring window once at least ``_win_size`` requests have been
        dispatched since the previous boundary."""
        self._win_seen += n
        if self._win_seen - self._win_prev_seen >= self._win_size:
            self._window_step(now)

    def _window_step(self, now: float) -> None:
        """One window boundary: controller first (it may move thresholds),
        then the telemetry sample — so ``threshold.*`` records the vector
        the *next* window's requests will actually be routed with."""
        lo, hi = self._win_prev_seen, self._win_seen
        if self.controller is not None:
            self._control_step()
            if self.tracer is not None:
                hist = self.controller.history
                for mv in hist[self._ctrl_hist_len :]:
                    self.tracer.emit(
                        THRESHOLD_MOVE, now, ROUTER_TRACK, mv.boundary, mv.value
                    )
                self._ctrl_hist_len = len(hist)
        if self.telemetry is not None:
            self.telemetry.sample(t_req=hi, now=now, lo=lo, hi=hi)
        self._win_prev_seen = self._win_seen

    def _finish_windows(self, t_end: float) -> None:
        """Final telemetry-only flush after the drain.

        Captures the residual window plus the drained end state (queues
        empty, last completions). Never fires the controller — a residue
        smaller than a window must not move boundaries, keeping controller
        trajectories identical to runs without telemetry."""
        if self.telemetry is not None:
            self.telemetry.sample(
                t_req=self._win_seen,
                now=t_end,
                lo=self._win_prev_seen,
                hi=self._win_seen,
            )
            self._win_prev_seen = self._win_seen

    # -- routing (reference path) --------------------------------------------
    def _route(self, request: Request) -> PoolSim:
        if self.router is None:
            (pool,) = self.pools.values()
            if self.tracer is not None:
                t = request.arrival_time
                self.tracer.emit(ARRIVAL, t, ROUTER_TRACK, request.request_id)
                self.tracer.emit(DISPATCH, t, 0, request.request_id)
            return pool
        # PoolState counters are maintained incrementally by the engines —
        # dispatch is O(1), no per-arrival instance sweep.
        if self._fault_rt is not None:
            decision = self.router.route(
                request, blocked=self._fault_rt.blocked(request.arrival_time)
            )
        else:
            decision = self.router.route(request)
        if self.tracer is not None:
            t = request.arrival_time
            rid = request.request_id
            self.tracer.emit(ARRIVAL, t, ROUTER_TRACK, rid)
            self.tracer.emit(
                DISPATCH, t, decision.pool_index, rid, decision.estimated_total
            )
            if decision.spilled:
                self.tracer.emit(SPILL, t, decision.pool_index, rid)
        return self.pools[decision.pool]

    # -- fault application (both backends) ------------------------------------
    def _apply_fault(self, tr, on_fail) -> None:
        """Apply one compiled fault transition at exactly ``tr.t``.

        Backend-agnostic: both pool sim classes expose the same
        ``set_down``/``set_slow``/``fault_crash``/``fault_oom`` surface.
        ``on_fail(request_id, t)`` writes the backend's failure record for
        requests that are finally dropped (no retry scheduled).
        """
        rt = self._fault_rt
        pool = rt.pool_sims[tr.pool_idx]
        t = tr.t
        if tr.action == "crash":
            # Down state first: the engines' reschedule logic reads it.
            pool.set_down(tr.instance, True, until=tr.until)
            lost = pool.fault_crash(tr.instance, t, tr.requeue)
            rt.on_instance_fault(tr, len(lost), t)
            for rid in lost:
                if not rt.on_lost(rid, tr.pool_idx, t):
                    on_fail(rid, t)
        elif tr.action == "oom":
            lost = pool.fault_oom(tr.instance, t, tr.frac, tr.requeue)
            rt.on_instance_fault(tr, len(lost), t)
            for rid in lost:
                if not rt.on_lost(rid, tr.pool_idx, t):
                    on_fail(rid, t)
        elif tr.action == "slow":
            pool.set_slow(tr.instance, tr.factor)
            rt.on_slow(tr, t)
        elif tr.action == "recover":
            pool.set_down(tr.instance, False)
            # Warm-up: admit immediately but run degraded until warm.
            pool.set_slow(tr.instance, tr.factor)
            rt.on_recover(tr, t)
        else:  # slow_end / warm-up end
            pool.set_slow(tr.instance, 1.0)
            rt.on_recover(tr, t)

    def _route_retry(self, request: Request, t: float, avoid_idx: int):
        """Re-route one retry: skip the failed pool and any health-blocked
        pool, count it, emit the RETRY event. Returns the target pool sim.

        Retries deliberately do not tick the monitoring windows — windows
        count *trace* arrivals in both backends, keeping controller
        trajectories comparable between faulted and fault-free runs.
        """
        rt = self._fault_rt
        rt.retries += 1
        if self.router is None:
            ((_, pool),) = self.pools.items()
            idx = 0
        else:
            blocked = rt.blocked(t)
            blocked = (
                frozenset((avoid_idx,))
                if blocked is None
                else blocked | {avoid_idx}
            )
            decision = self.router.route(request, blocked=blocked)
            pool = self.pools[decision.pool]
            idx = decision.pool_index
        if self.tracer is not None:
            attempt = rt.attempts.get(request.request_id, 0)
            self.tracer.emit(
                RETRY, t, idx, request.request_id, float(attempt)
            )
        return pool

    # -- main loop -------------------------------------------------------------
    def run(self, trace: Trace) -> FleetResult:
        if self.backend == "jax":
            from repro.sim import jax_engine

            return jax_engine.run_fleet_jax(self, trace)
        if self.backend == "vectorized":
            return self._run_vectorized(trace)
        if isinstance(trace, TraceColumns):
            trace = trace.to_requests()
        return self._run_reference(trace)

    def _run_reference(self, trace: Sequence[Request]) -> FleetResult:
        # Wake-up heap over instances; counter breaks ties deterministically.
        counter = itertools.count()
        heap: list[tuple[float, int, InstanceSim]] = []
        sleeping: set[int] = {id(i) for p in self.pools.values() for i in p.instances}

        def wake(inst: InstanceSim, t: float) -> None:
            if id(inst) in sleeping:
                sleeping.discard(id(inst))
                heapq.heappush(heap, (t, next(counter), inst))

        arrivals = sorted(trace, key=lambda r: r.arrival_time)
        lookup = {r.request_id: r for r in arrivals}
        ai = 0
        if self.telemetry is not None:
            self.telemetry.set_trace(
                np.asarray([r.byte_len for r in arrivals]),
                np.asarray([r.category for r in arrivals]),
                np.asarray([r.true_input_tokens for r in arrivals]),
                np.asarray([r.max_output_tokens for r in arrivals]),
            )
        last_t = 0.0

        # Fault injection: compiled transitions and scheduled retries join
        # the event race below; requests that are finally dropped get a
        # fleet-level failure record (rejected=True at the drop time) so
        # every trace request still appears exactly once in the summary.
        rt = self._fault_rt
        fail_records: list[RequestRecord] = []
        if rt is not None:
            rt.begin(arrival_of=lambda rid: lookup[rid].arrival_time)

        def on_fail(rid: int, t: float) -> None:
            req = lookup[rid]
            fail_records.append(
                RequestRecord(
                    request_id=rid,
                    pool="fleet",
                    arrival=req.arrival_time,
                    first_token=t,
                    finish=t,
                    output_tokens=0,
                    rejected=True,
                )
            )

        while ai < len(arrivals) or heap or (rt is not None and rt.pending()):
            next_arrival = arrivals[ai].arrival_time if ai < len(arrivals) else None
            next_event = heap[0][0] if heap else None

            if rt is not None:
                # Faults and retries win exact-time ties against arrivals
                # and engine iterations (the vectorized pump mirrors this).
                t_f = rt.next_time()
                if (
                    t_f != math.inf
                    and (next_arrival is None or t_f <= next_arrival)
                    and (next_event is None or t_f <= next_event)
                ):
                    kind, item = rt.pop()
                    last_t = t_f
                    if kind == "fault":
                        self._apply_fault(item, on_fail)
                    else:
                        t_r, _, rid, _attempt, avoid = item
                        pool = self._route_retry(lookup[rid], t_r, avoid)
                        inst = pool.least_loaded()
                        if inst.submit(lookup[rid], t_r):
                            wake(inst, t_r)
                    continue

            if next_event is None or (
                next_arrival is not None and next_arrival <= next_event
            ):
                request = arrivals[ai]
                ai += 1
                pool = self._route(request)
                inst = pool.least_loaded()
                if inst.submit(request, request.arrival_time):
                    wake(inst, request.arrival_time)
                last_t = request.arrival_time
                if self._win_size:
                    self._win_tick(1, request.arrival_time)
                continue

            now, _, inst = heapq.heappop(heap)
            last_t = now
            t_iter, done = inst.step(now)
            # `done` feeds the router's EMA only — the records themselves
            # stay on the instance, which is the single canonical store.
            if self.router is not None:
                for rec in done:
                    # usage.prompt_tokens feedback (Algorithm 1, line 15).
                    req = lookup.get(rec.request_id)
                    if req is not None:
                        self.router.on_response(req, req.true_input_tokens)
            if inst.idle:
                sleeping.add(id(inst))
            else:
                heapq.heappush(heap, (now + max(t_iter, 1e-9), next(counter), inst))

        # Canonical record list: one entry per submitted request (completed
        # or rejected), collected exactly once from the instances — plus
        # the fleet-level failure records of requests dropped by faults.
        all_records = [r for p in self.pools.values() for r in p.records]
        all_records.extend(fail_records)
        # Final flush at the drain end (max finish — matching the vectorized
        # backend's notion of the run's end time exactly).
        t_end = max((r.finish for r in all_records), default=last_t)
        self._finish_windows(t_end)
        spills = self.router.spill_count if self.router else 0
        per_pool = {
            name: summarize(name, p.records, total_spills=0)
            for name, p in self.pools.items()
        }
        return FleetResult(
            summary=summarize("fleet", all_records, total_spills=spills),
            per_pool=per_pool,
            router_stats=self.router.stats() if self.router else {},
            preemptions=sum(p.preemptions for p in self.pools.values()),
            rejections=sum(p.rejections for p in self.pools.values()),
            truncations=sum(p.truncations for p in self.pools.values()),
            retries=rt.retries if rt is not None else 0,
            timeouts=rt.timeouts if rt is not None else 0,
            shed=rt.shed if rt is not None else 0,
            instance_failures=rt.instance_failures if rt is not None else 0,
            availability=rt.availability(t_end) if rt is not None else 1.0,
            records=all_records,
            fail_records=fail_records,
            telemetry=self.telemetry,
            slo=self.slo,
        )

    def _dispatch_one(
        self,
        pool_ids: Optional[np.ndarray],
        budgets: Optional[np.ndarray],
        j: int,
        t: float = 0.0,
        rid: int = -1,
    ):
        """Pick the target pool for one arrival (vectorized backend).

        The static N-way decision comes from the epoch's ``route_batch``
        call; the load-dependent tail of Algorithm 1 (hard-constraint
        escalation, spillover, counters) is the router's
        :meth:`~repro.core.router.TokenBudgetRouter.route_decided`, shared
        with the scalar dispatch path. ``t``/``rid`` are only passed (and
        only used) when event tracing or fault injection is on.
        """
        if self.router is None:
            (pool,) = self.pools.values()
            if self.tracer is not None:
                self.tracer.emit(ARRIVAL, t, ROUTER_TRACK, rid)
                self.tracer.emit(DISPATCH, t, 0, rid)
            return pool
        blocked = (
            self._fault_rt.blocked(t) if self._fault_rt is not None else None
        )
        if self.tracer is None:
            name = self.router.route_decided(
                int(pool_ids[j]), int(budgets[j]), blocked
            )
            return self.pools[name]
        spills0 = self.router.spill_count
        name = self.router.route_decided(
            int(pool_ids[j]), int(budgets[j]), blocked
        )
        idx = self._pool_index[name]
        self.tracer.emit(ARRIVAL, t, ROUTER_TRACK, rid)
        self.tracer.emit(DISPATCH, t, idx, rid, float(budgets[j]))
        if self.router.spill_count > spills0:
            self.tracer.emit(SPILL, t, idx, rid)
        return self.pools[name]

    # -- vectorized loop -------------------------------------------------------
    def _run_vectorized(self, trace: Trace) -> FleetResult:
        cols = (
            trace
            if isinstance(trace, TraceColumns)
            else TraceColumns.from_requests(trace)
        ).sorted_by_arrival()
        pools = list(self.pools.values())
        router = self.router

        # Routing observables stay columnar end-to-end: the epoch router
        # batches and the EMA feedback joins below index straight into the
        # trace arrays — no Request objects anywhere on this path.
        ids = cols.request_id
        id_order = np.argsort(ids, kind="stable")
        ids_sorted = ids[id_order]
        arrival = cols.arrival_time
        byte_by = cols.byte_len
        inp_by = cols.true_input_tokens
        out_by = cols.true_output_tokens
        cat_by = cols.category
        mot_by = cols.max_output_tokens
        if self.telemetry is not None:
            self.telemetry.set_trace(byte_by, cat_by, inp_by, mot_by)
        tracer = self.tracer

        def feedback() -> None:
            done = [p.drain_completed_ids() for p in pools]
            if router is None:
                return
            done_ids = np.concatenate([d for d in done if len(d)] or [ids[:0]])
            if not len(done_ids):
                return
            j = id_order[np.searchsorted(ids_sorted, done_ids)]
            router.on_response_batch(byte_by[j], inp_by[j], cat_by[j])

        def sweep_all(t: float) -> float:
            for p in pools:
                if p.wake_min < t:
                    p.sweep(t)
            return min(p.wake_min for p in pools)

        wake_min = np.inf

        # Fault injection: transitions and retries are pumped in time order
        # between coalesced windows, with sweeps to each exact fault time so
        # an instance's state at a crash is the same state the reference
        # backend sees (iterations starting strictly before the fault have
        # run; the one at the fault time has not).
        rt = self._fault_rt
        fail_rows: list[tuple[int, float, float]] = []

        def _trace_index(rid: int) -> int:
            return int(id_order[np.searchsorted(ids_sorted, rid)])

        if rt is not None:
            rt.begin(
                arrival_of=lambda rid: float(arrival[_trace_index(rid)])
            )

        def on_fail(rid: int, t: float) -> None:
            fail_rows.append((rid, float(arrival[_trace_index(rid)]), t))

        def pump_faults(t_until: float) -> None:
            nonlocal wake_min
            while rt.pending():
                t_next = rt.next_time()
                if t_next > t_until:
                    break
                wake_min = sweep_all(t_next)
                kind, item = rt.pop()
                if kind == "fault":
                    self._apply_fault(item, on_fail)
                    wake_min = min(p.wake_min for p in pools)
                else:
                    t_r, _, rid, _attempt, avoid = item
                    jx = _trace_index(rid)
                    req = Request(
                        request_id=rid,
                        byte_len=int(byte_by[jx]),
                        max_output_tokens=int(mot_by[jx]),
                        category=int(cat_by[jx]),
                        arrival_time=float(arrival[jx]),
                        true_input_tokens=int(inp_by[jx]),
                        true_output_tokens=int(out_by[jx]),
                    )
                    pool = self._route_retry(req, t_r, avoid)
                    if pool.submit_raw(
                        pool.least_loaded(),
                        rid,
                        float(arrival[jx]),
                        int(inp_by[jx]),
                        int(out_by[jx]),
                        t_r,
                    ):
                        wake_min = min(wake_min, pool.wake_min)

        n = len(cols)
        pos = 0
        pool_ids = budgets = None
        # Ramp the epoch size (64 → self.epoch): the first requests route
        # with the cold-start calibrator, so sync feedback frequently until
        # the EMA has converged — otherwise early long prompts get
        # underestimated, mis-routed to a too-small pool, and hard-rejected
        # where the per-request reference path would have served them.
        # Under adaptive control the epoch is additionally capped at the
        # control window, so a boundary move reaches route_batch within one
        # window of the request count that triggered it.
        epoch_cap = (
            self.epoch
            if self.controller is None
            else max(1, min(self.epoch, self.control_window))
        )
        chunk_size = min(64, epoch_cap)
        while pos < n:
            start = pos
            pos = min(n, pos + chunk_size)
            chunk_size = min(epoch_cap, chunk_size * 2)
            if router is not None:
                # Epoch-batched Algorithm 1: one jitted routing call per
                # chunk, using the calibration state as of the epoch start
                # and the whole-trace columns built above. route_batch
                # slices its shape-padding off before returning, so only
                # the chunk's real arrivals reach dispatch below.
                pool_ids, budgets = router.route_batch(
                    byte_by[start:pos], mot_by[start:pos], cat_by[start:pos]
                )
            j = start
            while j < pos:
                # Coalesce arrivals sharing one wake-up epoch: one sweep
                # serves the whole window, so due instances step together.
                horizon = arrival[j] + self.coalesce_dt
                jend = j + int(
                    np.searchsorted(arrival[j:pos], horizon, side="right")
                )
                jend = max(jend, j + 1)
                t_sync = arrival[jend - 1]
                if rt is not None:
                    pump_faults(float(t_sync))
                if t_sync > wake_min:
                    wake_min = sweep_all(t_sync)
                for jj in range(j, jend):
                    if tracer is None and rt is None:
                        pool = self._dispatch_one(pool_ids, budgets, jj - start)
                    else:
                        pool = self._dispatch_one(
                            pool_ids,
                            budgets,
                            jj - start,
                            float(arrival[jj]),
                            int(ids[jj]),
                        )
                    if pool.submit_raw(
                        pool.least_loaded(),
                        int(ids[jj]),
                        float(arrival[jj]),
                        int(inp_by[jj]),
                        int(out_by[jj]),
                        float(arrival[jj]),
                    ):
                        wake_min = min(wake_min, pool.wake_min)
                # Monitoring windows align to coalesced rounds: the windowed
                # per-pool error/queue deltas are read after each round's
                # arrivals land, mirroring the reference backend's cadence
                # within one coalescing horizon.
                if self._win_size:
                    self._win_tick(jend - j, float(t_sync))
                j = jend
            # Epoch boundary: sync completed-request feedback into the EMA.
            feedback()

        if rt is not None:
            # Drain the full fault/retry schedule in time order (sweeping to
            # each event), then finish whatever work is still in flight.
            pump_faults(np.inf)
        sweep_all(np.inf)
        feedback()

        per_pool_cols = {name: p.record_arrays() for name, p in self.pools.items()}
        all_cols = list(per_pool_cols.values())
        if rt is not None and fail_rows:
            nf = len(fail_rows)
            zeros = np.zeros(nf, dtype=np.int64)
            t_fail = np.asarray([r[2] for r in fail_rows], dtype=np.float64)
            all_cols.append(
                {
                    "request_id": np.asarray(
                        [r[0] for r in fail_rows], dtype=np.int64
                    ),
                    "arrival": np.asarray(
                        [r[1] for r in fail_rows], dtype=np.float64
                    ),
                    "first_token": t_fail,
                    "finish": t_fail,
                    "output_tokens": zeros,
                    "preemptions": zeros,
                    "truncated": np.zeros(nf, dtype=bool),
                    "rejected": np.ones(nf, dtype=bool),
                }
            )
        fleet_cols = concat_record_columns(all_cols)
        finish = fleet_cols.get("finish")
        t_end = (
            float(finish.max())
            if finish is not None and len(finish)
            else (float(arrival[-1]) if n else 0.0)
        )
        if self.telemetry is not None:
            self._finish_windows(t_end)
        spills = router.spill_count if router else 0
        return FleetResult(
            summary=summarize_columns("fleet", fleet_cols, total_spills=spills),
            per_pool={
                name: summarize_columns(name, c, total_spills=0)
                for name, c in per_pool_cols.items()
            },
            router_stats=router.stats() if router else {},
            preemptions=sum(p.preemptions for p in pools),
            rejections=sum(p.rejections for p in pools),
            truncations=sum(p.truncations for p in pools),
            retries=rt.retries if rt is not None else 0,
            timeouts=rt.timeouts if rt is not None else 0,
            shed=rt.shed if rt is not None else 0,
            instance_failures=rt.instance_failures if rt is not None else 0,
            availability=rt.availability(t_end) if rt is not None else 1.0,
            fail_records=[
                RequestRecord(
                    request_id=rid,
                    pool="fleet",
                    arrival=arr,
                    first_token=t_f,
                    finish=t_f,
                    output_tokens=0,
                    rejected=True,
                )
                for rid, arr, t_f in fail_rows
            ],
            telemetry=self.telemetry,
            slo=self.slo,
        )


def run_fleet(
    trace: Trace,
    pools: dict[str, tuple[PoolConfig, int]],
    timing: TimingModel,
    *,
    b_short: int = 8192,
    thresholds: Optional[Sequence[int]] = None,
    calibrator: Optional[EmaCalibrator] = None,
    spillover: bool = True,
    backend: str = "reference",
    coalesce_dt: Optional[float] = None,
    controller: Optional[AdaptiveController] = None,
    control_window: int = 512,
    telemetry: Union[bool, TelemetryConfig, None] = None,
    slo: SLOTarget = PAPER_SLO,
    injector: Optional[FaultInjector] = None,
    retry_policy: Optional[RetryPolicy] = None,
) -> FleetResult:
    """Convenience wrapper: build a FleetSim and run the trace."""
    sim = FleetSim(
        pools,
        timing,
        b_short=b_short,
        thresholds=thresholds,
        calibrator=calibrator,
        spillover=spillover,
        backend=backend,
        coalesce_dt=coalesce_dt,
        controller=controller,
        control_window=control_window,
        telemetry=telemetry,
        slo=slo,
        injector=injector,
        retry_policy=retry_policy,
    )
    return sim.run(trace)
