"""Fleet-level discrete-event simulation (paper Appendix A, layer 3).

Drives N instances per pool plus the token-budget router over a trace:

* arrivals are routed with Algorithm 1 (calibrated estimates + spillover,
  reading live queue depths);
* each instance runs the iteration-level engine of
  :mod:`repro.sim.engine`; instance wake-ups are a single heapq;
* responses feed ``usage.prompt_tokens`` back into the router's EMA.

This verifies that the analytically-sized fleet (profiler layer) meets the
SLO under Poisson arrivals — the "definitive numbers" path of the paper.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Optional, Sequence

from repro.core.calibration import EmaCalibrator
from repro.core.pools import PoolConfig, PoolState
from repro.core.router import Request, TokenBudgetRouter
from repro.sim.engine import InstanceSim
from repro.sim.metrics import RequestRecord, SimSummary, summarize
from repro.sim.timing import TimingModel


class PoolSim:
    """A pool of identical instances with join-least-loaded dispatch."""

    def __init__(
        self, config: PoolConfig, num_instances: int, timing: TimingModel
    ) -> None:
        self.config = config
        self.instances = [
            InstanceSim(config, timing, name=f"{config.name}[{i}]")
            for i in range(num_instances)
        ]
        self.state = PoolState(config=config, num_instances=num_instances)

    def refresh_state(self) -> None:
        self.state.queue_depth = sum(len(i.queue) for i in self.instances)
        self.state.active = sum(len(i.active) for i in self.instances)

    def least_loaded(self) -> InstanceSim:
        return min(self.instances, key=lambda i: i.load)

    @property
    def records(self) -> list[RequestRecord]:
        return [r for inst in self.instances for r in inst.records]

    @property
    def preemptions(self) -> int:
        return sum(i.preemption_count for i in self.instances)

    @property
    def rejections(self) -> int:
        return sum(i.rejection_count for i in self.instances)


@dataclasses.dataclass
class FleetResult:
    summary: SimSummary
    per_pool: dict[str, SimSummary]
    router_stats: dict
    preemptions: int
    rejections: int


class FleetSim:
    """Token-budget-routed fleet (or a single homogeneous pool)."""

    def __init__(
        self,
        pools: dict[str, tuple[PoolConfig, int]],
        timing: TimingModel,
        *,
        b_short: int = 8192,
        calibrator: Optional[EmaCalibrator] = None,
        spillover: bool = True,
    ) -> None:
        self.pools = {
            name: PoolSim(cfg, n, timing) for name, (cfg, n) in pools.items()
        }
        self.timing = timing
        self.router: Optional[TokenBudgetRouter] = None
        if "short" in self.pools and "long" in self.pools:
            self.router = TokenBudgetRouter(
                self.pools["short"].state,
                self.pools["long"].state,
                b_short=b_short,
                calibrator=calibrator or EmaCalibrator(),
                spillover=spillover,
            )

    # -- routing --------------------------------------------------------------
    def _route(self, request: Request) -> PoolSim:
        if self.router is None:
            (pool,) = self.pools.values()
            return pool
        for p in self.pools.values():
            p.refresh_state()
        decision = self.router.route(request)
        return self.pools[decision.pool]

    # -- main loop --------------------------------------------------------------
    def run(self, trace: Sequence[Request]) -> FleetResult:
        # Wake-up heap over instances; counter breaks ties deterministically.
        counter = itertools.count()
        heap: list[tuple[float, int, InstanceSim]] = []
        sleeping: set[int] = {id(i) for p in self.pools.values() for i in p.instances}

        def wake(inst: InstanceSim, t: float) -> None:
            if id(inst) in sleeping:
                sleeping.discard(id(inst))
                heapq.heappush(heap, (t, next(counter), inst))

        arrivals = sorted(trace, key=lambda r: r.arrival_time)
        lookup = {r.request_id: r for r in arrivals}
        ai = 0
        completions: list[RequestRecord] = []

        while ai < len(arrivals) or heap:
            next_arrival = arrivals[ai].arrival_time if ai < len(arrivals) else None
            next_event = heap[0][0] if heap else None

            if next_event is None or (
                next_arrival is not None and next_arrival <= next_event
            ):
                request = arrivals[ai]
                ai += 1
                pool = self._route(request)
                inst = pool.least_loaded()
                if inst.submit(request, request.arrival_time):
                    wake(inst, request.arrival_time)
                continue

            now, _, inst = heapq.heappop(heap)
            t_iter, done = inst.step(now)
            for rec in done:
                completions.append(rec)
                if self.router is not None and not rec.rejected:
                    # usage.prompt_tokens feedback (Algorithm 1, line 15).
                    req = lookup.get(rec.request_id)
                    if req is not None:
                        self.router.on_response(req, req.true_input_tokens)
            if inst.idle:
                sleeping.add(id(inst))
            else:
                heapq.heappush(heap, (now + max(t_iter, 1e-9), next(counter), inst))

        # Collect rejected-record entries too (kept on the instances).
        all_records = [r for p in self.pools.values() for r in p.records]
        spills = self.router.spill_count if self.router else 0
        per_pool = {
            name: summarize(name, p.records, total_spills=0)
            for name, p in self.pools.items()
        }
        return FleetResult(
            summary=summarize("fleet", all_records, total_spills=spills),
            per_pool=per_pool,
            router_stats=self.router.stats() if self.router else {},
            preemptions=sum(p.preemptions for p in self.pools.values()),
            rejections=sum(p.rejections for p in self.pools.values()),
        )


def run_fleet(
    trace: Sequence[Request],
    pools: dict[str, tuple[PoolConfig, int]],
    timing: TimingModel,
    *,
    b_short: int = 8192,
    calibrator: Optional[EmaCalibrator] = None,
    spillover: bool = True,
) -> FleetResult:
    """Convenience wrapper: build a FleetSim and run the trace."""
    sim = FleetSim(
        pools,
        timing,
        b_short=b_short,
        calibrator=calibrator,
        spillover=spillover,
    )
    return sim.run(trace)
