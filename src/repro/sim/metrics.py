"""Latency / reliability metrics for the DES (paper Tables 2–3).

Two aggregation paths with identical semantics:

* :func:`summarize` — over a list of :class:`RequestRecord` objects, used by
  the scalar reference backend;
* :func:`summarize_columns` — over columnar NumPy arrays, used by the
  vectorized backend so a million-request run never materializes a million
  Python objects. Percentiles use the same nearest-rank definition.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

import numpy as np


def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (q in [0,100]); 0.0 on empty input."""
    if not values:
        return 0.0
    s = sorted(values)
    rank = max(0, min(len(s) - 1, math.ceil(q / 100.0 * len(s)) - 1))
    return s[rank]


@dataclasses.dataclass
class RequestRecord:
    """Per-request outcome recorded by the simulator."""

    request_id: int
    pool: str
    arrival: float
    first_token: float  # absolute time of first generated token
    finish: float
    output_tokens: int
    preemptions: int = 0
    truncated: bool = False
    rejected: bool = False

    @property
    def ttft(self) -> float:
        return self.first_token - self.arrival

    @property
    def tpot(self) -> float:
        if self.output_tokens <= 1:
            return 0.0
        return (self.finish - self.first_token) / (self.output_tokens - 1)


@dataclasses.dataclass(frozen=True)
class SLOTarget:
    """A latency service-level objective (paper §3: P99 targets).

    One shared definition threaded through the fleet simulator and the
    capacity-bisection benchmarks, replacing per-call-site hardcoded
    targets. The defaults are the paper's: P99 TTFT ≤ 2 s, P99 TPOT ≤ 80 ms.
    """

    ttft_p99: float = 2.0  # seconds
    tpot_p99: float = 0.080  # seconds per output token

    def met_by(self, summary: "SimSummary") -> bool:
        return (
            summary.ttft_p99 <= self.ttft_p99
            and summary.tpot_p99 <= self.tpot_p99
        )


#: The paper's SLO operating point (Tables 2–3).
PAPER_SLO = SLOTarget()


@dataclasses.dataclass
class SimSummary:
    """Aggregate metrics (after warm-up discard) for one simulation run."""

    name: str
    num_requests: int
    completed: int
    rejected: int
    truncated: int
    preemptions: int
    spills: int
    ttft_p50: float
    ttft_p99: float
    tpot_p50: float
    tpot_p99: float
    makespan: float
    throughput: float  # completed / makespan

    @property
    def success_rate(self) -> float:
        if self.num_requests == 0:
            return 1.0
        return self.completed / self.num_requests

    @property
    def error_rate(self) -> float:
        """(preemptions + rejections + truncations) / requests — the same
        composite the adaptive controller monitors (§8), post-warmup."""
        if self.num_requests == 0:
            return 0.0
        return (
            self.preemptions + self.rejected + self.truncated
        ) / self.num_requests

    def meets_slo(self, slo: SLOTarget = PAPER_SLO) -> bool:
        """Check this run against an :class:`SLOTarget` (default: paper's)."""
        return slo.met_by(self)


def summarize(
    name: str,
    records: Sequence[RequestRecord],
    *,
    warmup_frac: float = 0.20,
    total_spills: int = 0,
) -> SimSummary:
    """Aggregate with the paper's 20% warm-up discard (Appendix A)."""
    if not records:
        return SimSummary(name, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0.0, 0.0)

    by_arrival = sorted(records, key=lambda r: r.arrival)
    cut = int(len(by_arrival) * warmup_frac)
    window = by_arrival[cut:]

    done = [r for r in window if not r.rejected]
    ttfts = [r.ttft for r in done]
    tpots = [r.tpot for r in done if r.output_tokens > 1]
    finish_times = [r.finish for r in done]
    start = window[0].arrival if window else 0.0
    makespan = (max(finish_times) - start) if finish_times else 0.0

    return SimSummary(
        name=name,
        num_requests=len(window),
        completed=len(done),
        rejected=sum(1 for r in window if r.rejected),
        truncated=sum(1 for r in window if r.truncated),
        preemptions=sum(r.preemptions for r in window),
        spills=total_spills,
        ttft_p50=percentile(ttfts, 50),
        ttft_p99=percentile(ttfts, 99),
        tpot_p50=percentile(tpots, 50),
        tpot_p99=percentile(tpots, 99),
        makespan=makespan,
        throughput=len(done) / makespan if makespan > 0 else 0.0,
    )


def _percentile_sorted(values: np.ndarray, q: float) -> float:
    """Nearest-rank percentile of an already-sorted array, matching
    :func:`percentile` exactly (sort once, index per quantile)."""
    n = len(values)
    if n == 0:
        return 0.0
    rank = max(0, min(n - 1, math.ceil(q / 100.0 * n) - 1))
    return float(values[rank])


def concat_record_columns(
    column_maps: Sequence[Mapping[str, np.ndarray]],
) -> dict[str, np.ndarray]:
    """Merge per-pool record columns into one fleet-level column map.

    Used by the fleet layer to aggregate any number of pools (the N-pool
    generalization has no fixed pool count) without materializing records.
    """
    if not column_maps:
        return {}
    return {
        key: np.concatenate([cols[key] for cols in column_maps])
        for key in column_maps[0]
    }


def summarize_columns(
    name: str,
    cols: Mapping[str, np.ndarray],
    *,
    warmup_frac: float = 0.20,
    total_spills: int = 0,
) -> SimSummary:
    """Columnar twin of :func:`summarize` (same 20% warm-up discard).

    ``cols`` holds one array per :class:`RequestRecord` field:
    ``request_id, arrival, first_token, finish, output_tokens, preemptions,
    truncated, rejected``.
    """
    n = len(cols["arrival"])
    if n == 0:
        return SimSummary(name, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0.0, 0.0)

    order = np.argsort(cols["arrival"], kind="stable")
    window = order[int(n * warmup_frac) :]

    rejected = cols["rejected"][window]
    done = window[~rejected]
    ttfts = np.sort(cols["first_token"][done] - cols["arrival"][done])
    out = cols["output_tokens"][done]
    multi = out > 1
    tpots = np.sort(
        (cols["finish"][done] - cols["first_token"][done])[multi]
        / (out[multi] - 1)
    )
    start = float(cols["arrival"][window[0]]) if len(window) else 0.0
    makespan = (
        float(cols["finish"][done].max()) - start if len(done) else 0.0
    )

    return SimSummary(
        name=name,
        num_requests=len(window),
        completed=len(done),
        rejected=int(rejected.sum()),
        truncated=int(cols["truncated"][window].sum()),
        preemptions=int(cols["preemptions"][window].sum()),
        spills=total_spills,
        ttft_p50=_percentile_sorted(ttfts, 50),
        ttft_p99=_percentile_sorted(ttfts, 99),
        tpot_p50=_percentile_sorted(tpots, 50),
        tpot_p99=_percentile_sorted(tpots, 99),
        makespan=makespan,
        throughput=len(done) / makespan if makespan > 0 else 0.0,
    )
