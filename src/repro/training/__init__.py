"""Training substrate: optimizers, data pipeline, distributed train step."""

from repro.training.data import DataConfig, SyntheticLM, make_batch_fn
from repro.training.optimizer import (
    AdafactorState,
    AdamW,
    AdamWState,
    Adafactor,
    clip_by_global_norm,
    cosine_schedule,
    get_optimizer,
    global_norm,
)
from repro.training.train_loop import (
    TrainConfig,
    abstract_train_state,
    init_train_state,
    make_train_step,
    opt_state_axes,
)

__all__ = [
    "DataConfig",
    "SyntheticLM",
    "make_batch_fn",
    "AdamW",
    "AdamWState",
    "Adafactor",
    "AdafactorState",
    "clip_by_global_norm",
    "cosine_schedule",
    "get_optimizer",
    "global_norm",
    "TrainConfig",
    "abstract_train_state",
    "init_train_state",
    "make_train_step",
    "opt_state_axes",
]
