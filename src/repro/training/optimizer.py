"""Optimizers (pure JAX, no optax): AdamW and Adafactor, with schedules.

AdamW is the default; Adafactor (factored second moment) is selectable for
the very large MoE configs where full AdamW state exceeds per-chip HBM at
the assigned mesh size (llama4-maverick: 3.2 TB of m/v over 256 chips —
see EXPERIMENTS.md §Dry-run notes). Optimizer state inherits the parameter
sharding (ZeRO-1-style: same NamedSharding tree).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def cosine_schedule(
    step: jax.Array,
    *,
    peak_lr: float,
    warmup_steps: int,
    total_steps: int,
    final_frac: float = 0.1,
) -> jax.Array:
    warm = jnp.minimum((step + 1.0) / jnp.maximum(warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0, 1
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return peak_lr * warm * (final_frac + (1 - final_frac) * cos)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(tree: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


class AdamWState(NamedTuple):
    count: jax.Array
    mu: Any  # fp32 first moment
    nu: Any  # fp32 second moment


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params: Any) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            count=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(
        self, grads: Any, state: AdamWState, params: Any, lr: jax.Array
    ) -> tuple[Any, AdamWState]:
        count = state.count + 1
        b1, b2 = self.b1, self.b2

        def moment1(m, g):
            return b1 * m + (1 - b1) * g.astype(jnp.float32)

        def moment2(v, g):
            gf = g.astype(jnp.float32)
            return b2 * v + (1 - b2) * jnp.square(gf)

        mu = jax.tree.map(moment1, state.mu, grads)
        nu = jax.tree.map(moment2, state.nu, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(p, m, v):
            step = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(count=count, mu=mu, nu=nu)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; Shazeer & Stern 2018)
# ---------------------------------------------------------------------------


class AdafactorState(NamedTuple):
    count: jax.Array
    vr: Any  # row stats (or full v for rank<2 leaves)
    vc: Any  # col stats (zeros for rank<2 leaves)


@dataclasses.dataclass(frozen=True)
class Adafactor:
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def _factored(self, shape: tuple[int, ...]) -> bool:
        return len(shape) >= 2

    def init(self, params: Any) -> AdafactorState:
        def row(p):
            if self._factored(p.shape):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def col(p):
            if self._factored(p.shape):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((), jnp.float32)

        return AdafactorState(
            count=jnp.zeros((), jnp.int32),
            vr=jax.tree.map(row, params),
            vc=jax.tree.map(col, params),
        )

    def update(
        self, grads: Any, state: AdafactorState, params: Any, lr: jax.Array
    ) -> tuple[Any, AdafactorState]:
        count = state.count + 1
        beta = 1.0 - (count.astype(jnp.float32) + 1.0) ** (-self.decay)

        def upd(p, g, vr, vc):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + self.eps
            if self._factored(p.shape):
                new_vr = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                new_vc = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                r = new_vr / jnp.maximum(
                    jnp.mean(new_vr, axis=-1, keepdims=True), self.eps
                )
                step = gf / (
                    jnp.sqrt(r)[..., None] * jnp.sqrt(new_vc)[..., None, :]
                    + self.eps
                )
            else:
                new_vr = beta * vr + (1 - beta) * g2
                new_vc = vc
                step = gf / (jnp.sqrt(new_vr) + self.eps)
            # update clipping (RMS threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(step)) + self.eps)
            step = step / jnp.maximum(1.0, rms / self.clip_threshold)
            if self.weight_decay:
                step = step + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), new_vr, new_vc

        flat, treedef = jax.tree.flatten(params)
        gflat = treedef.flatten_up_to(grads)
        vrflat = treedef.flatten_up_to(state.vr)
        vcflat = treedef.flatten_up_to(state.vc)
        out = [upd(p, g, vr, vc) for p, g, vr, vc in zip(flat, gflat, vrflat, vcflat)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_vr = treedef.unflatten([o[1] for o in out])
        new_vc = treedef.unflatten([o[2] for o in out])
        return new_params, AdafactorState(count=count, vr=new_vr, vc=new_vc)


def get_optimizer(name: str, **kw):
    if name == "adamw":
        return AdamW(**kw)
    if name == "adafactor":
        return Adafactor(**kw)
    raise ValueError(f"unknown optimizer {name!r}")
