"""Synthetic LM data pipeline.

Deterministic, seekable token streams: batch ``i`` is a pure function of
(seed, i), so a restarted job resumes mid-epoch with no state beyond the
step counter — the data-side half of fault-tolerant training. Per-host
sharding takes disjoint slices of the global batch by process index.

The generator synthesizes structured sequences (repeated n-gram motifs over
a Zipfian vocabulary) rather than iid noise so a ~100M model shows a real
learning curve in examples/train_small.py.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    motif_len: int = 8
    n_motifs: int = 512
    zipf_a: float = 1.2


class SyntheticLM:
    """Seekable synthetic token stream."""

    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        # fixed motif table (the "knowledge" the model can learn)
        self.motifs = root.integers(
            2, cfg.vocab, size=(cfg.n_motifs, cfg.motif_len), dtype=np.int64
        )

    def batch(self, index: int, *, process_index: int = 0, process_count: int = 1):
        """Batch `index`, host shard `process_index` of `process_count`.

        Returns dict(tokens (b_local, L) int32, labels shifted by one).
        """
        cfg = self.cfg
        if cfg.global_batch % process_count:
            raise ValueError("global batch must divide process count")
        b_local = cfg.global_batch // process_count
        rng = np.random.default_rng(
            (cfg.seed, index, process_index, 0xD1E5EED)
        )
        n_slots = cfg.seq_len // cfg.motif_len + 1
        motif_ids = rng.zipf(cfg.zipf_a, size=(b_local, n_slots))
        motif_ids = np.minimum(motif_ids - 1, cfg.n_motifs - 1)
        seq = self.motifs[motif_ids].reshape(b_local, -1)[:, : cfg.seq_len + 1]
        # sprinkle noise tokens so the task isn't trivially memorizable
        noise_mask = rng.random(seq.shape) < 0.05
        noise = rng.integers(2, cfg.vocab, size=seq.shape)
        seq = np.where(noise_mask, noise, seq)
        tokens = seq[:, :-1].astype(np.int32)
        labels = seq[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}


def make_batch_fn(cfg: ArchConfig, seq_len: int, global_batch: int, seed: int = 0):
    data = SyntheticLM(
        DataConfig(
            vocab=cfg.vocab, seq_len=seq_len, global_batch=global_batch, seed=seed
        )
    )
    return data.batch
