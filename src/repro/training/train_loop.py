"""Distributed training step and loop.

``make_train_step`` builds the jit-able (params, opt_state, batch, step) →
(params, opt_state, metrics) function that the dry-run lowers for the
``train_4k`` cells and the examples run for real:

* DP over ("pod","data"), TP over "model" via the logical-axis shardings;
* optional microbatch gradient accumulation (``lax.scan`` over microbatches
  — fewer collective rounds per optimizer step, the cheap form of gradient
  "compression");
* activation rematerialization on the scanned layer stacks (model-level
  ``remat``);
* global-norm clipping, cosine LR, donated params/opt_state buffers.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.model_zoo import Model
from repro.training.optimizer import (
    AdamW,
    clip_by_global_norm,
    cosine_schedule,
    get_optimizer,
)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    optimizer: str = "adamw"
    weight_decay: float = 0.1
    microbatches: int = 1  # gradient accumulation factor


def make_train_step(
    model: Model,
    tcfg: TrainConfig = TrainConfig(),
) -> Callable:
    """Returns train_step(params, opt_state, batch, step) → (p, s, metrics)."""
    if tcfg.optimizer == "adamw":
        opt = get_optimizer("adamw", weight_decay=tcfg.weight_decay)
    else:
        opt = get_optimizer(tcfg.optimizer)

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def compute_grads(params, batch):
        if tcfg.microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            return loss, metrics, grads

        # microbatch accumulation: split the global batch along dim 0
        def split(x):
            b = x.shape[0]
            if b % tcfg.microbatches:
                raise ValueError("batch must divide microbatches")
            return x.reshape(tcfg.microbatches, b // tcfg.microbatches, *x.shape[1:])

        micro = jax.tree.map(
            lambda x: split(x) if hasattr(x, "shape") and x.ndim >= 1 else x,
            batch,
        )
        # positions has a leading modality dim (3, B, L) — handle specially
        if "positions" in batch:
            p = batch["positions"]
            micro["positions"] = jnp.moveaxis(split(jnp.moveaxis(p, 0, 1)), 1, 2)

        zero_grads = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params
        )

        def body(carry, mb):
            acc, loss_acc = carry
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb
            )
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / tcfg.microbatches,
                acc,
                grads,
            )
            return (acc, loss_acc + loss / tcfg.microbatches), None

        (grads, loss), _ = jax.lax.scan(
            body, (zero_grads, jnp.zeros((), jnp.float32)), micro
        )
        return loss, {"loss": loss}, grads

    def train_step(params, opt_state, batch, step):
        loss, metrics, grads = compute_grads(params, batch)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = cosine_schedule(
            step,
            peak_lr=tcfg.peak_lr,
            warmup_steps=tcfg.warmup_steps,
            total_steps=tcfg.total_steps,
        )
        new_params, new_state = opt.update(grads, opt_state, params, lr)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return new_params, new_state, metrics

    return train_step, opt


def init_train_state(model: Model, tcfg: TrainConfig, rng: jax.Array):
    params = model.init(rng)
    _, opt = make_train_step(model, tcfg)
    return params, opt.init(params)


def abstract_train_state(model: Model, tcfg: TrainConfig):
    """ShapeDtypeStruct stand-ins for (params, opt_state) — dry-run path."""
    params = model.abstract()
    _, opt = make_train_step(model, tcfg)
    opt_state = jax.eval_shape(opt.init, params)
    return params, opt_state


def opt_state_axes(model: Model, tcfg: TrainConfig):
    """Logical axes for the optimizer state (mirrors the param tree).

    AdamW m/v inherit the param axes exactly; Adafactor row/col stats drop
    the last / second-to-last axis respectively; counts are replicated.
    """
    from repro.training.optimizer import AdamWState

    p_axes = model.axes()
    if tcfg.optimizer == "adamw":
        return AdamWState(count=(), mu=p_axes, nu=p_axes)
    # adafactor
    def row_axes(ax):
        return ax[:-1] if len(ax) >= 2 else ax

    def col_axes(ax):
        return ax[:-2] + ax[-1:] if len(ax) >= 2 else ()

    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x
    )
    from repro.training.optimizer import AdafactorState

    return AdafactorState(
        count=(),
        vr=jax.tree.map(row_axes, p_axes, is_leaf=is_ax),
        vc=jax.tree.map(col_axes, p_axes, is_leaf=is_ax),
    )
